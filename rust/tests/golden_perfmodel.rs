//! Golden snapshots of `StepBreakdown` over a fixed
//! (device x precision x plan x phase) grid, so refactors of the
//! perf model cannot silently shift the single-chip numbers the seed
//! tests lock in (or the multi-chip numbers this PR introduces).
//!
//! * `tests/golden/perfmodel.json` holds the snapshot.
//! * If the file is missing, the test writes it and passes
//!   (bootstrap); commit the generated file to lock the numbers.
//! * Set `GOLDEN_REGEN=1` to regenerate intentionally after a
//!   deliberate model change, and say why in the commit message.

use std::collections::BTreeMap;
use std::fs;
use std::path::PathBuf;

use fp8_tco::analysis::perfmodel::{decode_step, prefill, PrecisionMode, StepBreakdown, StepConfig};
use fp8_tco::hwsim::spec::Device;
use fp8_tco::util::json::Json;
use fp8_tco::workload::llama::by_name;

const REL_TOL: f64 = 1e-9;

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/perfmodel.json")
}

/// The fixed grid. Keep stable: editing it invalidates the snapshot.
fn grid() -> Vec<(String, StepBreakdown)> {
    let m8 = by_name("llama-8b").unwrap();
    let m70 = by_name("llama-70b").unwrap();
    let devices = [Device::H100, Device::Gaudi2, Device::Gaudi3, Device::A100];
    let precisions = [
        PrecisionMode::Bf16,
        PrecisionMode::fp8_static(),
        PrecisionMode::fp8_dynamic(),
    ];
    // Single-chip plus the TP-only, PP-only and TP x PP shard shapes:
    // interconnect-model refactors cannot silently drift
    // `t_tp_comm`/`t_pp_comm` on any of the comm regimes.
    let plans: [(usize, usize); 5] = [(1, 1), (2, 1), (8, 1), (1, 2), (4, 2)];
    let mut out = Vec::new();
    for dev in devices {
        for prec in precisions {
            for (tp, pp) in plans {
                let cfg = StepConfig::new(dev, prec).with_tp(tp).with_pp(pp);
                let key = format!("{}|{}|tp{tp}-pp{pp}", dev.name(), prec.name());
                out.push((
                    format!("{key}|decode-8b-b32-s1024"),
                    decode_step(m8, &cfg, 32, 1024),
                ));
                out.push((format!("{key}|prefill-8b-b1-s2048"), prefill(m8, &cfg, 1, 2048)));
            }
        }
    }
    // 70B multi-chip anchors per vendor (the deployment shapes the
    // single-chip model could not express): pure TP and TP x PP.
    for dev in [Device::H100, Device::Gaudi2] {
        let cfg = StepConfig::new(dev, PrecisionMode::fp8_static()).with_tp(4);
        out.push((
            format!("{}|fp8-static|tp4-pp1|decode-70b-b32-s1024", dev.name()),
            decode_step(m70, &cfg, 32, 1024),
        ));
        let cfg2 = StepConfig::new(dev, PrecisionMode::fp8_static())
            .with_tp(4)
            .with_pp(2);
        out.push((
            format!("{}|fp8-static|tp4-pp2|decode-70b-b32-s1024", dev.name()),
            decode_step(m70, &cfg2, 32, 1024),
        ));
        out.push((
            format!("{}|fp8-static|tp4-pp2|prefill-70b-b1-s2048", dev.name()),
            prefill(m70, &cfg2, 1, 2048),
        ));
    }
    out
}

fn breakdown_to_json(bd: &StepBreakdown) -> Json {
    let mut m = BTreeMap::new();
    let mut put = |k: &str, v: f64| {
        m.insert(k.to_string(), Json::Num(v));
    };
    put("seconds", bd.seconds);
    put("t_linears_s", bd.t_linears_s);
    put("t_attention_kv_s", bd.t_attention_kv_s);
    put("t_softmax_s", bd.t_softmax_s);
    put("t_lm_head_s", bd.t_lm_head_s);
    put("t_tp_comm_s", bd.t_tp_comm_s);
    put("t_pp_comm_s", bd.t_pp_comm_s);
    put("pp_bubble_frac", bd.pp_bubble_frac);
    put("flops", bd.flops);
    put("achieved_flops", bd.achieved_flops);
    put("util_frac", bd.util_frac);
    put("watts", bd.watts);
    Json::Obj(m)
}

fn snapshot() -> Json {
    let mut m = BTreeMap::new();
    for (key, bd) in grid() {
        m.insert(key, breakdown_to_json(&bd));
    }
    Json::Obj(m)
}

fn write_snapshot(j: &Json) {
    let path = golden_path();
    fs::create_dir_all(path.parent().unwrap()).expect("mkdir tests/golden");
    fs::write(&path, format!("{j}\n")).expect("write golden snapshot");
}

#[test]
fn perfmodel_matches_golden_snapshot() {
    let current = snapshot();
    let path = golden_path();
    if std::env::var("GOLDEN_REGEN").ok().as_deref() == Some("1") {
        write_snapshot(&current);
        eprintln!("regenerated {}", path.display());
        return;
    }
    let Ok(text) = fs::read_to_string(&path) else {
        write_snapshot(&current);
        eprintln!(
            "bootstrap: wrote {} — commit it to lock the numbers",
            path.display()
        );
        return;
    };
    let golden = Json::parse(&text).expect("golden snapshot parses");
    let (Json::Obj(gold), Json::Obj(cur)) = (&golden, &current) else {
        panic!("snapshot roots must be objects");
    };
    // Every golden entry must still exist and match; new grid entries
    // (a widened grid) are only allowed via explicit regeneration.
    assert_eq!(
        gold.keys().collect::<Vec<_>>(),
        cur.keys().collect::<Vec<_>>(),
        "grid changed; rerun with GOLDEN_REGEN=1 if intentional"
    );
    let mut drift = Vec::new();
    for (key, gval) in gold {
        let (Json::Obj(gm), Some(Json::Obj(cm))) = (gval, cur.get(key)) else {
            panic!("malformed snapshot entry {key}");
        };
        for (field, gf) in gm {
            let g = gf.as_f64().expect("golden fields are numbers");
            let c = cm
                .get(field)
                .and_then(Json::as_f64)
                .unwrap_or_else(|| panic!("missing field {key}.{field}"));
            let scale = g.abs().max(c.abs()).max(1e-300);
            if (g - c).abs() / scale > REL_TOL {
                drift.push(format!("{key}.{field}: golden {g} vs current {c}"));
            }
        }
    }
    assert!(
        drift.is_empty(),
        "perf model drifted from golden snapshot ({} fields):\n{}\n\
         If the change is deliberate, regenerate with GOLDEN_REGEN=1.",
        drift.len(),
        drift.join("\n")
    );
}

#[test]
fn multichip_grid_entries_expose_comm_terms() {
    // Structural guard independent of the snapshot file: every sharded
    // shape in the grid must carry its comm terms (and the single-chip
    // shape must carry exactly none), so a refactor that zeroes or
    // miscounts `t_tp_comm`/`t_pp_comm` fails even on a fresh checkout
    // where the snapshot is still bootstrapping.
    let m8 = by_name("llama-8b").unwrap();
    for dev in [Device::H100, Device::Gaudi2, Device::Gaudi3, Device::A100] {
        for (tp, pp) in [(1usize, 1usize), (2, 1), (8, 1), (1, 2), (4, 2)] {
            let cfg = StepConfig::new(dev, PrecisionMode::fp8_static())
                .with_tp(tp)
                .with_pp(pp);
            let cases = [
                ("decode", decode_step(m8, &cfg, 32, 1024)),
                ("prefill", prefill(m8, &cfg, 1, 2048)),
            ];
            for (phase, bd) in cases {
                let tag = format!("{} {phase} tp{tp} pp{pp}", dev.name());
                assert!(bd.seconds.is_finite() && bd.seconds > 0.0, "{tag}");
                if tp > 1 {
                    assert!(bd.t_tp_comm_s > 0.0, "{tag}: missing TP comm");
                } else {
                    assert_eq!(bd.t_tp_comm_s, 0.0, "{tag}: phantom TP comm");
                }
                if pp > 1 {
                    assert!(bd.t_pp_comm_s > 0.0, "{tag}: missing PP comm");
                    assert!(bd.pp_bubble_frac > 0.0, "{tag}: missing PP bubble");
                } else {
                    assert_eq!(bd.t_pp_comm_s, 0.0, "{tag}: phantom PP comm");
                    assert_eq!(bd.pp_bubble_frac, 0.0, "{tag}: phantom bubble");
                }
            }
        }
    }
}

#[test]
fn golden_grid_is_deterministic() {
    // The snapshot itself must be reproducible within a run, or the
    // golden comparison would be meaningless.
    let a = snapshot().to_string();
    let b = snapshot().to_string();
    assert_eq!(a, b);
}
