//! Property tests for disaggregated prefill/decode serving:
//!
//! * infinite-bandwidth + identical devices reproduce the colocated
//!   request timeline exactly, and the priced $/Mtok-at-SLO lands in
//!   the colocated band;
//! * token conservation and no-lost-requests hold across KV
//!   migration, including under decode-pool memory pressure;
//! * TTFT is monotonically non-decreasing in transfer latency;
//! * the KV-transfer closed form matches values pinned against the
//!   Python mirror (`python/tests/test_kv_transfer_mirror.py`).

use fp8_tco::analysis::disagg::{auto_size, DisaggPlan, PoolSpec};
use fp8_tco::analysis::parallel::ParallelismPlan;
use fp8_tco::analysis::perfmodel::{PrecisionMode, StepConfig};
use fp8_tco::coordinator::cluster::{
    disagg_sim_cluster, max_sustainable_qps, sharded_sim_cluster, Cluster, DisaggCluster,
    SloSpec, SweepConfig,
};
use fp8_tco::coordinator::router::{EngineRating, RoutePolicy, Router};
use fp8_tco::coordinator::{Engine, EngineConfig, KvCacheConfig, SimBackend};
use fp8_tco::hwsim::interconnect::KvLink;
use fp8_tco::hwsim::spec::Device;
use fp8_tco::tco::{assumed_server_price, InfraModel, RackConfig};
use fp8_tco::workload::llama::by_name;
use fp8_tco::workload::trace::{Request, TraceConfig, TraceGenerator};

fn engine(dev: Device, total_blocks: usize) -> Engine<SimBackend> {
    let kv = KvCacheConfig { block_tokens: 16, total_blocks };
    let backend = SimBackend::new(
        by_name("llama-8b").unwrap(),
        StepConfig::new(dev, PrecisionMode::fp8_static()),
    );
    Engine::new(EngineConfig::new(kv), backend)
}

fn router(engines: Vec<Engine<SimBackend>>) -> Router<SimBackend> {
    let n = engines.len();
    let ratings = vec![EngineRating { prefill_score: 1.0, decode_score: 1.0 }; n];
    Router::new(engines, ratings, RoutePolicy::LeastLoaded)
}

#[test]
fn infinite_bandwidth_disagg_matches_colocated_request_timeline() {
    // Identical device, free link, serial (non-overlapping) requests:
    // the disaggregated timeline must reproduce the colocated one
    // request by request — prefill at the same instant, migration at
    // zero cost, decode steps of identical cost.
    let model = by_name("llama-8b").unwrap();
    let reqs: Vec<Request> = (0..3)
        .map(|i| Request {
            id: i,
            arrival: i as f64 * 1000.0,
            prompt_len: 200 + 37 * i as usize,
            output_len: 24,
        })
        .collect();
    let mut colo = Cluster::new(router(vec![engine(Device::H100, 50_000)]));
    assert!(colo.run(reqs.clone()));
    let mut dis = DisaggCluster::new(
        router(vec![engine(Device::H100, 50_000)]),
        router(vec![engine(Device::H100, 50_000)]),
        KvLink::infinite(),
        model.kv_bytes_per_token(2.0),
    );
    assert!(dis.run(reqs.clone()));
    for r in &reqs {
        let c = colo.router.engines[0].sequence(r.id).unwrap();
        let d = dis.decode.engines[0].sequence(r.id).unwrap();
        let (cf, df) = (c.first_token_at.unwrap(), d.first_token_at.unwrap());
        assert!((cf - df).abs() < 1e-9, "req {}: first token {cf} vs {df}", r.id);
        let (ce, de) = (c.finished_at.unwrap(), d.finished_at.unwrap());
        assert!((ce - de).abs() < 1e-9, "req {}: finish {ce} vs {de}", r.id);
    }
    let (cm, dm) = (colo.merged_metrics(), dis.merged_metrics());
    assert_eq!(cm.requests_done, dm.requests_done);
    assert_eq!(cm.tokens_out, dm.tokens_out, "token conservation across modes");
    assert!((cm.ttft.pct(95.0) - dm.ttft.pct(95.0)).abs() < 1e-9);
    assert!((cm.tpot.pct(95.0) - dm.tpot.pct(95.0)).abs() < 1e-9);
    assert_eq!(dm.migrations, 3);
}

#[test]
fn infinite_bandwidth_identical_pools_cost_converges_to_colocated() {
    // The $/Mtok-at-SLO acceptance property: equal total chips, same
    // device and precision everywhere, free fabric — the
    // disaggregated price must land in the colocated band. (Exact
    // equality is not expected: splitting the chips between phase
    // pools changes batching dynamics; the per-request timeline
    // equivalence above plus the pricing identity in tco::rack pin
    // the exact parts.)
    let model = by_name("llama-8b").unwrap();
    let slo = SloSpec::interactive();
    let cfg = SweepConfig { iters: 3, n_requests: 40, seed: 7, ..SweepConfig::new(0.25, 24.0) };
    let colo_out = max_sustainable_qps(
        &|| {
            sharded_sim_cluster(
                model,
                Device::H100,
                PrecisionMode::fp8_dynamic(),
                ParallelismPlan::single().with_replicas(4),
            )
            .unwrap()
        },
        &TraceConfig::chat,
        &slo,
        &cfg,
    );
    let pool = PoolSpec::new(
        Device::H100,
        PrecisionMode::fp8_dynamic(),
        ParallelismPlan::single(),
    );
    // Balance the 4 instances from the chat mix's median shape.
    let plan = auto_size(model, pool, pool, 245, 148, 4);
    let dis_out = max_sustainable_qps(
        &|| {
            let mut c = disagg_sim_cluster(model, &plan).unwrap();
            c.link = KvLink::infinite();
            c
        },
        &TraceConfig::chat,
        &slo,
        &cfg,
    );
    let cp = colo_out.best.expect("colocated floor feasible");
    let dp = dis_out.best.expect("disaggregated floor feasible");
    let infra = InfraModel::new(RackConfig::a100_era());
    let h100 = assumed_server_price(Device::H100);
    let colo_cost = infra.cost_per_mtok_sharded(h100, 4, cp.watts_mean, cp.tokens_per_sec);
    // Merged watts for both pools: identical devices, and the band
    // below is wide; the example/bench do the per-pool split.
    let dis_cost =
        infra.cost_per_mtok_disagg_plan(&plan, dp.watts_mean, dp.watts_mean, dp.tokens_per_sec);
    let ratio = dis_cost / colo_cost;
    assert!(
        ratio > 1.0 / 3.0 && ratio < 3.0,
        "disagg ${dis_cost}/Mtok vs colocated ${colo_cost}/Mtok (ratio {ratio})"
    );
}

#[test]
fn tokens_conserved_and_no_requests_lost_across_migration() {
    // Open-loop Poisson traffic through ample pools: every request
    // finishes, every token is delivered exactly once, every
    // multi-token request migrates exactly once.
    let model = by_name("llama-8b").unwrap();
    let plan = DisaggPlan::new(
        PoolSpec::new(
            Device::H100,
            PrecisionMode::fp8_dynamic(),
            ParallelismPlan::single(),
        ),
        PoolSpec::new(
            Device::Gaudi2,
            PrecisionMode::fp8_static(),
            ParallelismPlan::single().with_replicas(3),
        ),
    );
    let mut c = disagg_sim_cluster(model, &plan).expect("8B fits");
    let reqs: Vec<Request> = TraceGenerator::new(TraceConfig::chat(6.0), 42).stream(60).collect();
    let expected: u64 = reqs.iter().map(|r| r.output_len as u64).sum();
    let multi = reqs.iter().filter(|r| r.output_len > 1).count() as u64;
    assert!(c.run(reqs));
    let m = c.merged_metrics();
    assert_eq!(m.requests_done, 60, "no request lost across migration");
    assert_eq!(m.tokens_out, expected, "token conservation across pools");
    assert_eq!(m.migrations, multi, "every multi-token request migrates once");
    assert_eq!(m.ttft.count(), 60, "TTFT sampled exactly once per request");
}

#[test]
fn tokens_conserved_under_decode_pool_memory_pressure() {
    // Tiny decode pools force preemption of migrated sequences (their
    // fabric-delivered KV is evicted and recomputed locally); the
    // delivered-token invariant must survive the role demotion.
    let model = by_name("llama-8b").unwrap();
    let mut c = DisaggCluster::new(
        router(vec![engine(Device::H100, 10_000)]),
        router(vec![engine(Device::Gaudi2, 8), engine(Device::Gaudi2, 8)]),
        KvLink { bw: 37.5e9, lat_s: 1.1e-5 },
        model.kv_bytes_per_token(2.0),
    );
    let reqs: Vec<Request> = (0..6)
        .map(|i| Request {
            id: i,
            arrival: i as f64 * 0.01,
            prompt_len: 32,
            output_len: 40,
        })
        .collect();
    let expected: u64 = reqs.iter().map(|r| r.output_len as u64).sum();
    assert!(c.run(reqs));
    let m = c.merged_metrics();
    assert_eq!(m.requests_done, 6);
    assert!(c.preemptions() > 0, "decode pools must preempt under pressure");
    assert_eq!(m.tokens_out, expected, "preempted migrated tokens double-counted");
    assert_eq!(m.restarts, c.preemptions(), "restart accounting");
    assert_eq!(m.ttft.count(), 6);
    assert_eq!(m.migrations, 6);
}

#[test]
fn ttft_monotone_in_transfer_latency() {
    // With ample pools the prefill timeline is latency-independent and
    // TTFT_i = prefill_finish_i + bytes_i/bw + lat: every percentile
    // must be non-decreasing in the link latency.
    let model = by_name("llama-8b").unwrap();
    let plan = DisaggPlan::new(
        PoolSpec::new(
            Device::H100,
            PrecisionMode::fp8_dynamic(),
            ParallelismPlan::single(),
        ),
        PoolSpec::new(
            Device::H100,
            PrecisionMode::fp8_dynamic(),
            ParallelismPlan::single().with_replicas(2),
        ),
    );
    let at = |lat_s: f64| {
        let mut c = disagg_sim_cluster(model, &plan).expect("8B fits");
        c.link = c.link.with_latency(lat_s);
        let gen = TraceGenerator::new(TraceConfig::chat(4.0), 11);
        assert!(c.run(gen.stream(40)));
        let m = c.merged_metrics();
        (m.ttft.pct(50.0), m.ttft.pct(95.0))
    };
    let (a50, a95) = at(0.0);
    let (b50, b95) = at(0.005);
    let (c50, c95) = at(0.1);
    assert!(b50 >= a50 && c50 >= b50, "p50 not monotone: {a50} {b50} {c50}");
    assert!(b95 >= a95 && c95 >= b95, "p95 not monotone: {a95} {b95} {c95}");
    // The 100 ms link shifts every request by at least ~100 ms.
    assert!(c50 - a50 >= 0.09, "latency not visible in TTFT: {a50} vs {c50}");
}

#[test]
fn kv_transfer_closed_form_pinned_against_python_mirror() {
    // (model, context, src device, src chips, dst device, dst chips,
    // expected seconds). The same table lives in
    // python/tests/test_kv_transfer_mirror.py; both sides compute
    // bytes/token x tokens / link_bw + lat and must agree with the
    // pinned value to 1e-9 relative.
    let cases: [(&str, usize, Device, usize, Device, usize, f64); 4] = [
        (
            "llama-8b",
            2048,
            Device::H100,
            1,
            Device::H100,
            1,
            0.005378709119999999,
        ),
        (
            "llama-8b",
            512,
            Device::H100,
            1,
            Device::Gaudi2,
            1,
            0.0018005697066666665,
        ),
        (
            "llama-70b",
            4096,
            Device::H100,
            4,
            Device::Gaudi2,
            1,
            0.03580239413333333,
        ),
        (
            "llama-70b",
            2048,
            Device::Gaudi3,
            2,
            Device::Gaudi3,
            2,
            0.004483924266666666,
        ),
    ];
    for (name, ctx, src, sc, dst, dc, want) in cases {
        let m = by_name(name).unwrap();
        let link = KvLink::between(src.interconnect(), sc, dst.interconnect(), dc);
        let t = link.transfer_time(ctx as f64 * m.kv_bytes_per_token(2.0));
        assert!(
            (t / want - 1.0).abs() < 1e-9,
            "{name} ctx {ctx}: got {t}, pinned {want}"
        );
    }
    // The per-token KV footprints the closed form rides on.
    assert_eq!(by_name("llama-8b").unwrap().kv_bytes_per_token(2.0), 131072.0);
    assert_eq!(by_name("llama-70b").unwrap().kv_bytes_per_token(2.0), 327680.0);
}
