//! Property tests for disaggregated prefill/decode serving:
//!
//! * infinite-bandwidth + identical devices reproduce the colocated
//!   request timeline exactly, and the priced $/Mtok-at-SLO lands in
//!   the colocated band;
//! * token conservation and no-lost-requests hold across KV
//!   migration, including under decode-pool memory pressure;
//! * TTFT is monotonically non-decreasing in transfer latency;
//! * chunked KV streaming: chunk count 1 reproduces the single-shot
//!   closed form (and timeline) bit-exactly, total stream time is
//!   monotone non-decreasing in chunk count, and overlap strictly
//!   improves TTFT at finite bandwidth;
//! * decode-pool admission control: an accepted migration never
//!   preempts within its first decode step, a bounced migration
//!   completes as `SeqRole::Full` with token conservation and no lost
//!   requests, and `Metrics` counts bounces;
//! * the KV-transfer closed form (single-shot and chunked) matches
//!   values pinned against the Python mirror
//!   (`python/tests/test_kv_transfer_mirror.py`).

use fp8_tco::analysis::disagg::{auto_size, DisaggPlan, PoolSpec};
use fp8_tco::analysis::parallel::ParallelismPlan;
use fp8_tco::analysis::perfmodel::{PrecisionMode, StepConfig};
use fp8_tco::coordinator::cluster::{
    disagg_sim_cluster, max_sustainable_qps, sharded_sim_cluster, Cluster, DisaggCluster,
    SloSpec, SweepConfig,
};
use fp8_tco::coordinator::router::{EngineRating, RoutePolicy, Router};
use fp8_tco::coordinator::{Engine, EngineConfig, KvCacheConfig, SimBackend};
use fp8_tco::hwsim::interconnect::KvLink;
use fp8_tco::hwsim::spec::Device;
use fp8_tco::tco::{assumed_server_price_usd, InfraModel, RackConfig};
use fp8_tco::workload::llama::by_name;
use fp8_tco::workload::trace::{Request, TenantClass, TraceConfig, TraceGenerator};

fn engine(dev: Device, total_blocks: usize) -> Engine<SimBackend> {
    let kv = KvCacheConfig { block_tokens: 16, total_blocks };
    let backend = SimBackend::new(
        by_name("llama-8b").unwrap(),
        StepConfig::new(dev, PrecisionMode::fp8_static()),
    );
    Engine::new(EngineConfig::new(kv), backend)
}

fn router(engines: Vec<Engine<SimBackend>>) -> Router<SimBackend> {
    let n = engines.len();
    let ratings = vec![EngineRating { prefill_score: 1.0, decode_score: 1.0 }; n];
    Router::new(engines, ratings, RoutePolicy::LeastLoaded)
}

/// Single-vendor plan with spec-sized (ample) KV pools: one H100
/// prefill instance feeding two H100 decode instances — no memory
/// pressure, so streaming properties isolate the link model.
fn pressure_free_plan() -> DisaggPlan {
    DisaggPlan::new(
        PoolSpec::new(
            Device::H100,
            PrecisionMode::fp8_dynamic(),
            ParallelismPlan::single(),
        ),
        PoolSpec::new(
            Device::H100,
            PrecisionMode::fp8_dynamic(),
            ParallelismPlan::single().with_replicas(2),
        ),
    )
}

#[test]
fn infinite_bandwidth_disagg_matches_colocated_request_timeline() {
    // Identical device, free link, serial (non-overlapping) requests:
    // the disaggregated timeline must reproduce the colocated one
    // request by request — prefill at the same instant, migration at
    // zero cost, decode steps of identical cost.
    let model = by_name("llama-8b").unwrap();
    let reqs: Vec<Request> = (0..3)
        .map(|i| Request {
            id: i,
            arrival: i as f64 * 1000.0,
            prompt_len: 200 + 37 * i as usize,
            output_len: 24,
            class: TenantClass::Interactive,
        })
        .collect();
    let mut colo = Cluster::new(router(vec![engine(Device::H100, 50_000)]));
    assert!(colo.run(reqs.clone()));
    let mut dis = DisaggCluster::new(
        router(vec![engine(Device::H100, 50_000)]),
        router(vec![engine(Device::H100, 50_000)]),
        KvLink::infinite(),
        model.kv_bytes_per_token(2.0),
    );
    assert!(dis.run(reqs.clone()));
    for r in &reqs {
        let c = colo.router.engines[0].sequence(r.id).unwrap();
        let d = dis.decode.engines[0].sequence(r.id).unwrap();
        let (cf, df) = (c.first_token_at.unwrap(), d.first_token_at.unwrap());
        assert!((cf - df).abs() < 1e-9, "req {}: first token {cf} vs {df}", r.id);
        let (ce, de) = (c.finished_at.unwrap(), d.finished_at.unwrap());
        assert!((ce - de).abs() < 1e-9, "req {}: finish {ce} vs {de}", r.id);
    }
    let (cm, dm) = (colo.merged_metrics(), dis.merged_metrics());
    assert_eq!(cm.requests_done, dm.requests_done);
    assert_eq!(cm.tokens_out, dm.tokens_out, "token conservation across modes");
    assert!((cm.ttft.pct(95.0) - dm.ttft.pct(95.0)).abs() < 1e-9);
    assert!((cm.tpot.pct(95.0) - dm.tpot.pct(95.0)).abs() < 1e-9);
    assert_eq!(dm.migrations, 3);
}

#[test]
fn infinite_bandwidth_identical_pools_cost_converges_to_colocated() {
    // The $/Mtok-at-SLO acceptance property: equal total chips, same
    // device and precision everywhere, free fabric — the
    // disaggregated price must land in the colocated band. (Exact
    // equality is not expected: splitting the chips between phase
    // pools changes batching dynamics; the per-request timeline
    // equivalence above plus the pricing identity in tco::rack pin
    // the exact parts.)
    let model = by_name("llama-8b").unwrap();
    let slo = SloSpec::interactive();
    let cfg = SweepConfig { iters: 3, n_requests: 40, seed: 7, ..SweepConfig::new(0.25, 24.0) };
    let colo_out = max_sustainable_qps(
        &|| {
            sharded_sim_cluster(
                model,
                Device::H100,
                PrecisionMode::fp8_dynamic(),
                ParallelismPlan::single().with_replicas(4),
            )
            .unwrap()
        },
        &TraceConfig::chat,
        &slo,
        &cfg,
    );
    let pool = PoolSpec::new(
        Device::H100,
        PrecisionMode::fp8_dynamic(),
        ParallelismPlan::single(),
    );
    // Balance the 4 instances from the chat mix's median shape.
    let plan = auto_size(model, pool, pool, 245, 148, 4);
    let dis_out = max_sustainable_qps(
        &|| {
            let mut c = disagg_sim_cluster(model, &plan).unwrap();
            c.link = KvLink::infinite();
            c
        },
        &TraceConfig::chat,
        &slo,
        &cfg,
    );
    let cp = colo_out.best.expect("colocated floor feasible");
    let dp = dis_out.best.expect("disaggregated floor feasible");
    let infra = InfraModel::new(RackConfig::a100_era());
    let h100 = assumed_server_price_usd(Device::H100);
    let colo_cost = infra.cost_per_mtok_sharded(h100, 4, cp.watts_mean, cp.tokens_per_sec);
    // Merged watts for both pools: identical devices, and the band
    // below is wide; the example/bench do the per-pool split.
    let dis_cost =
        infra.cost_per_mtok_disagg_plan(&plan, dp.watts_mean, dp.watts_mean, dp.tokens_per_sec);
    let ratio = dis_cost / colo_cost;
    assert!(
        ratio > 1.0 / 3.0 && ratio < 3.0,
        "disagg ${dis_cost}/Mtok vs colocated ${colo_cost}/Mtok (ratio {ratio})"
    );
}

#[test]
fn tokens_conserved_and_no_requests_lost_across_migration() {
    // Open-loop Poisson traffic through ample pools: every request
    // finishes, every token is delivered exactly once, every
    // multi-token request migrates exactly once.
    let model = by_name("llama-8b").unwrap();
    let plan = DisaggPlan::new(
        PoolSpec::new(
            Device::H100,
            PrecisionMode::fp8_dynamic(),
            ParallelismPlan::single(),
        ),
        PoolSpec::new(
            Device::Gaudi2,
            PrecisionMode::fp8_static(),
            ParallelismPlan::single().with_replicas(3),
        ),
    );
    let mut c = disagg_sim_cluster(model, &plan).expect("8B fits");
    let reqs: Vec<Request> = TraceGenerator::new(TraceConfig::chat(6.0), 42).stream(60).collect();
    let expected: u64 = reqs.iter().map(|r| r.output_len as u64).sum();
    let multi = reqs.iter().filter(|r| r.output_len > 1).count() as u64;
    assert!(c.run(reqs));
    let m = c.merged_metrics();
    assert_eq!(m.requests_done, 60, "no request lost across migration");
    assert_eq!(m.tokens_out, expected, "token conservation across pools");
    assert_eq!(m.migrations, multi, "every multi-token request migrates once");
    assert_eq!(m.ttft.count(), 60, "TTFT sampled exactly once per request");
}

#[test]
fn tokens_conserved_under_decode_pool_memory_pressure() {
    // Tiny decode pools force preemption of migrated sequences (their
    // fabric-delivered KV is evicted and recomputed locally); the
    // delivered-token invariant must survive the role demotion.
    let model = by_name("llama-8b").unwrap();
    let mut c = DisaggCluster::new(
        router(vec![engine(Device::H100, 10_000)]),
        router(vec![engine(Device::Gaudi2, 8), engine(Device::Gaudi2, 8)]),
        KvLink { bw: 37.5e9, lat_s: 1.1e-5 },
        model.kv_bytes_per_token(2.0),
    );
    let reqs: Vec<Request> = (0..6)
        .map(|i| Request {
            id: i,
            arrival: i as f64 * 0.01,
            prompt_len: 32,
            output_len: 40,
            class: TenantClass::Interactive,
        })
        .collect();
    let expected: u64 = reqs.iter().map(|r| r.output_len as u64).sum();
    assert!(c.run(reqs));
    let m = c.merged_metrics();
    assert_eq!(m.requests_done, 6);
    assert!(c.preemptions() > 0, "decode pools must preempt under pressure");
    assert_eq!(m.tokens_out, expected, "preempted migrated tokens double-counted");
    assert_eq!(m.restarts, c.preemptions(), "restart accounting");
    assert_eq!(m.ttft.count(), 6);
    assert_eq!(m.migrations, 6);
}

#[test]
fn ttft_monotone_in_transfer_latency() {
    // With ample pools the prefill timeline is latency-independent and
    // TTFT_i = prefill_finish_i + bytes_i/bw + lat: every percentile
    // must be non-decreasing in the link latency.
    let model = by_name("llama-8b").unwrap();
    let plan = DisaggPlan::new(
        PoolSpec::new(
            Device::H100,
            PrecisionMode::fp8_dynamic(),
            ParallelismPlan::single(),
        ),
        PoolSpec::new(
            Device::H100,
            PrecisionMode::fp8_dynamic(),
            ParallelismPlan::single().with_replicas(2),
        ),
    );
    let at = |lat_s: f64| {
        let mut c = disagg_sim_cluster(model, &plan).expect("8B fits");
        c.link = c.link.with_latency(lat_s);
        let gen = TraceGenerator::new(TraceConfig::chat(4.0), 11);
        assert!(c.run(gen.stream(40)));
        let m = c.merged_metrics();
        (m.ttft.pct(50.0), m.ttft.pct(95.0))
    };
    let (a50, a95) = at(0.0);
    let (b50, b95) = at(0.005);
    let (c50, c95) = at(0.1);
    assert!(b50 >= a50 && c50 >= b50, "p50 not monotone: {a50} {b50} {c50}");
    assert!(b95 >= a95 && c95 >= b95, "p95 not monotone: {a95} {b95} {c95}");
    // The 100 ms link shifts every request by at least ~100 ms.
    assert!(c50 - a50 >= 0.09, "latency not visible in TTFT: {a50} vs {c50}");
}

#[test]
fn chunk_count_one_reproduces_single_shot_bit_exactly() {
    // Limit equivalence at both layers. (a) The schedule: one chunk
    // (equivalently, chunk size >= total KV bytes) lands exactly at
    // the single-shot closed form, to the bit. (b) The cluster: a
    // streaming-configured run with chunk count 1 produces the same
    // timeline and metrics as the default single-shot path.
    let model = by_name("llama-8b").unwrap();
    let link = KvLink { bw: 37.5e9, lat_s: 1.1e-5 };
    for ctx in [1usize, 137, 512, 2048, 8192] {
        let bytes = ctx as f64 * model.kv_bytes_per_token(2.0);
        let single = link.transfer_time_s(bytes);
        let sched = link.chunked(bytes, 1);
        assert_eq!(sched.first_time_s().to_bits(), single.to_bits());
        assert_eq!(sched.total_time_s().to_bits(), single.to_bits());
    }
    let run = |chunks: usize| {
        let mut c = disagg_sim_cluster(model, &pressure_free_plan())
            .expect("8B fits")
            .with_streaming(chunks, false);
        let gen = TraceGenerator::new(TraceConfig::chat(4.0), 31);
        assert!(c.run(gen.stream(40)));
        let m = c.merged_metrics();
        (c.makespan(), m.report())
    };
    let (mk1, rep1) = run(1);
    let (mk_default, rep_default) = {
        let mut c = disagg_sim_cluster(model, &pressure_free_plan()).expect("8B fits");
        let gen = TraceGenerator::new(TraceConfig::chat(4.0), 31);
        assert!(c.run(gen.stream(40)));
        let m = c.merged_metrics();
        (c.makespan(), m.report())
    };
    assert_eq!(mk1.to_bits(), mk_default.to_bits(), "chunks=1 must be the PR 3 path");
    assert_eq!(rep1, rep_default);
}

#[test]
fn total_stream_time_monotone_in_chunk_count() {
    // More chunks = more per-chunk latency on the same bytes: the
    // last-chunk landing time never decreases, while the first-chunk
    // landing time never increases — the overlap trade the tentpole
    // exploits.
    let model = by_name("llama-70b").unwrap();
    let link = KvLink { bw: 37.5e9, lat_s: 1.1e-5 };
    let bytes = 4096.0 * model.kv_bytes_per_token(2.0);
    let single = link.transfer_time_s(bytes);
    let mut prev_total = 0.0;
    let mut prev_first = f64::INFINITY;
    for chunks in 1..=64 {
        let s = link.chunked(bytes, chunks);
        assert!(s.total_time_s() >= prev_total, "total dipped at {chunks} chunks");
        assert!(s.total_time_s() >= single, "chunking must not beat the wire");
        assert!(s.first_time_s() <= prev_first, "first chunk got later at {chunks}");
        assert!(s.first_time_s() <= s.total_time_s());
        prev_total = s.total_time_s();
        prev_first = s.first_time_s();
    }
}

#[test]
fn overlap_strictly_improves_ttft_at_finite_bandwidth() {
    // Same trace, same pools, finite link: every chunked TTFT
    // percentile is <= the single-shot one, and the median strictly
    // improves (first-chunk delivery beats whole-transfer delivery).
    // On an infinite link chunking changes nothing at all.
    let model = by_name("llama-8b").unwrap();
    let at = |chunks: usize, link: Option<KvLink>| {
        let mut c = disagg_sim_cluster(model, &pressure_free_plan())
            .expect("8B fits")
            .with_streaming(chunks, false);
        if let Some(l) = link {
            c.link = l;
        }
        let gen = TraceGenerator::new(TraceConfig::chat(4.0), 19);
        assert!(c.run(gen.stream(40)));
        let m = c.merged_metrics();
        (m.ttft.pct(50.0), m.ttft.pct(95.0), m.tokens_out)
    };
    let slow = KvLink { bw: 3.75e9, lat_s: 1.1e-5 }; // 1/10 fabric
    let (s50, s95, st) = at(1, Some(slow));
    let (c50, c95, ct) = at(8, Some(slow));
    assert_eq!(st, ct, "token conservation is chunking-invariant");
    assert!(c50 < s50, "overlap must strictly improve median TTFT: {c50} vs {s50}");
    assert!(c95 <= s95 + 1e-12, "p95 must not regress: {c95} vs {s95}");
    let (i50, i95, _) = at(1, Some(KvLink::infinite()));
    let (j50, j95, _) = at(16, Some(KvLink::infinite()));
    assert_eq!(i50.to_bits(), j50.to_bits(), "free fabric: chunking is a no-op");
    assert_eq!(i95.to_bits(), j95.to_bits());
}

#[test]
fn accepted_migrations_never_preempt_within_first_decode_step() {
    // Every migrated request has remaining_out = 1: exactly one decode
    // step runs on the decode pool per accepted migration, so *any*
    // decode-pool preemption would be a first-step preemption. With
    // admission control on, the tiny decode pool forces bounces
    // instead — and zero preemptions anywhere.
    let model = by_name("llama-8b").unwrap();
    let mut c = DisaggCluster::new(
        router(vec![engine(Device::H100, 10_000)]),
        router(vec![engine(Device::Gaudi2, 8)]), // 128 KV tokens
        KvLink { bw: 37.5e9, lat_s: 1.1e-5 },
        model.kv_bytes_per_token(2.0),
    )
    .with_streaming(4, true);
    let reqs: Vec<Request> = (0..12)
        .map(|i| Request {
            id: i,
            arrival: i as f64 * 0.002,
            prompt_len: 48 + (i as usize % 3) * 40,
            output_len: 2,
            class: TenantClass::Interactive,
        })
        .collect();
    assert!(c.run(reqs));
    let m = c.merged_metrics();
    assert_eq!(m.requests_done, 12, "no request lost");
    assert_eq!(m.tokens_out, 24, "token conservation");
    assert!(m.bounces > 0, "the 128-token pool must bounce some contexts");
    assert!(m.migrations > 0, "small contexts still migrate");
    assert_eq!(m.migrations + m.bounces, 12);
    assert_eq!(
        c.preemptions(),
        0,
        "an accepted migration must never preempt within its first decode step"
    );
    assert_eq!(m.restarts, 0);
    assert_eq!(m.ttft.count(), 12, "TTFT sampled exactly once per request");
}

#[test]
fn bounced_migrations_complete_colocated_with_conservation() {
    // A decode pool too small for *any* context: admission control
    // bounces everything, each request completes as SeqRole::Full on
    // its prefill engine, tokens are conserved, and the decode pool
    // never wakes up.
    let model = by_name("llama-8b").unwrap();
    let mut c = DisaggCluster::new(
        router(vec![engine(Device::H100, 10_000)]),
        router(vec![engine(Device::Gaudi2, 2)]), // 32 KV tokens
        KvLink { bw: 37.5e9, lat_s: 1.1e-5 },
        model.kv_bytes_per_token(2.0),
    )
    .with_streaming(1, true);
    let reqs: Vec<Request> = (0..5)
        .map(|i| Request {
            id: i,
            arrival: i as f64 * 0.05,
            prompt_len: 64,
            output_len: 16,
            class: TenantClass::Interactive,
        })
        .collect();
    let expected: u64 = reqs.iter().map(|r| r.output_len as u64).sum();
    assert!(c.run(reqs));
    let m = c.merged_metrics();
    assert_eq!(m.requests_done, 5, "no request lost across bounces");
    assert_eq!(m.tokens_out, expected, "token conservation across bounces");
    assert_eq!(m.bounces, 5, "Metrics counts every bounce");
    assert_eq!(m.migrations, 0, "nothing crossed the fabric");
    assert_eq!(m.kv_bytes_migrated, 0.0);
    let (pm, dm) = c.pool_metrics();
    assert_eq!(pm.requests_done, 5, "bounced requests finish on the prefill pool");
    assert_eq!(dm.steps, 0, "decode pool never woke up");
    for e in c.prefill.engines.iter() {
        for s in e.sequences() {
            assert_eq!(
                s.role,
                fp8_tco::coordinator::SeqRole::Full,
                "bounced sequences end as Full"
            );
        }
        assert_eq!(e.kv_utilization(), 0.0, "bounced KV fully released");
    }
}

#[test]
fn kv_transfer_closed_form_pinned_against_python_mirror() {
    // (model, context, src device, src chips, dst device, dst chips,
    // expected seconds). The same table lives in
    // python/tests/test_kv_transfer_mirror.py; both sides compute
    // bytes/token x tokens / link_bw + lat and must agree with the
    // pinned value to 1e-9 relative.
    let cases: [(&str, usize, Device, usize, Device, usize, f64); 4] = [
        (
            "llama-8b",
            2048,
            Device::H100,
            1,
            Device::H100,
            1,
            0.005378709119999999,
        ),
        (
            "llama-8b",
            512,
            Device::H100,
            1,
            Device::Gaudi2,
            1,
            0.0018005697066666665,
        ),
        (
            "llama-70b",
            4096,
            Device::H100,
            4,
            Device::Gaudi2,
            1,
            0.03580239413333333,
        ),
        (
            "llama-70b",
            2048,
            Device::Gaudi3,
            2,
            Device::Gaudi3,
            2,
            0.004483924266666666,
        ),
    ];
    for (name, ctx, src, sc, dst, dc, want) in cases {
        let m = by_name(name).unwrap();
        let link = KvLink::between(src.interconnect(), sc, dst.interconnect(), dc);
        let t = link.transfer_time_s(ctx as f64 * m.kv_bytes_per_token(2.0));
        assert!(
            (t / want - 1.0).abs() < 1e-9,
            "{name} ctx {ctx}: got {t}, pinned {want}"
        );
    }
    // The per-token KV footprints the closed form rides on.
    assert_eq!(by_name("llama-8b").unwrap().kv_bytes_per_token(2.0), 131072.0);
    assert_eq!(by_name("llama-70b").unwrap().kv_bytes_per_token(2.0), 327680.0);
}

#[test]
fn chunked_schedule_pinned_against_python_mirror() {
    // (model, context, src device, src chips, dst device, dst chips,
    // chunks, first-chunk s, last-chunk s). The same table lives in
    // python/tests/test_kv_transfer_mirror.py (PINNED_CHUNKED); both
    // sides compute bytes*(i+1)/chunks / bw + (i+1)*lat and must agree
    // with the pinned values to 1e-9 relative.
    let cases: [(&str, usize, Device, usize, Device, usize, usize, f64, f64); 4] = [
        (
            "llama-8b",
            2048,
            Device::H100,
            1,
            Device::H100,
            1,
            4,
            0.00135217728,
            0.00540870912,
        ),
        (
            "llama-8b",
            512,
            Device::H100,
            1,
            Device::Gaudi2,
            1,
            8,
            0.00023469621333333332,
            0.0018775697066666665,
        ),
        (
            "llama-70b",
            4096,
            Device::H100,
            4,
            Device::Gaudi2,
            1,
            8,
            0.0044849242666666666,
            0.03587939413333333,
        ),
        (
            "llama-70b",
            2048,
            Device::Gaudi3,
            2,
            Device::Gaudi3,
            2,
            16,
            0.0002896202666666667,
            0.004633924266666667,
        ),
    ];
    for (name, ctx, src, sc, dst, dc, chunks, first, total) in cases {
        let m = by_name(name).unwrap();
        let link = KvLink::between(src.interconnect(), sc, dst.interconnect(), dc);
        let sched = link.chunked(ctx as f64 * m.kv_bytes_per_token(2.0), chunks);
        assert!(
            (sched.first_time_s() / first - 1.0).abs() < 1e-9,
            "{name} ctx {ctx} x{chunks}: first {} vs pinned {first}",
            sched.first_time_s()
        );
        assert!(
            (sched.total_time_s() / total - 1.0).abs() < 1e-9,
            "{name} ctx {ctx} x{chunks}: total {} vs pinned {total}",
            sched.total_time_s()
        );
        // The single-shot closed form brackets the schedule.
        let single = link.transfer_time_s(ctx as f64 * m.kv_bytes_per_token(2.0));
        assert!(sched.first_time_s() < single && sched.total_time_s() >= single);
    }
}

#[test]
fn admission_probes_decode_pool_at_delivery_not_harvest() {
    // One 8-block decode engine: requests A (id 0) and B (id 1) each
    // need ~7 blocks of KV, so they can never coexist. B's prefill
    // finishes while A still occupies the pool -- probing at harvest
    // (transfer start) would bounce B -- but A drains during B's slow
    // transfer, so the delivery-time probe admits it.
    let model = by_name("llama-8b").unwrap();
    let k = model.kv_bytes_per_token(2.0);
    // Link sized so a 101-token context streams for ~150 ms.
    let link = KvLink { bw: 101.0 * k / 0.15, lat_s: 0.0 };
    let mut c = DisaggCluster::new(
        router(vec![engine(Device::H100, 10_000)]),
        router(vec![engine(Device::Gaudi2, 8)]),
        link,
        k,
    )
    .with_streaming(1, true);
    let reqs = vec![
        Request {
            id: 0,
            arrival: 0.0,
            prompt_len: 100,
            output_len: 16,
            class: TenantClass::Interactive,
        },
        Request {
            id: 1,
            arrival: 0.158,
            prompt_len: 100,
            output_len: 16,
            class: TenantClass::Interactive,
        },
    ];
    assert!(c.run(reqs));
    let m = c.merged_metrics();
    assert_eq!(m.requests_done, 2, "no request lost");
    assert_eq!(m.migrations, 2, "delivery-time probe must admit both");
    assert_eq!(m.bounces, 0, "harvest-time probing would have bounced B");
    // The race the probe placement decides, reconstructed from the
    // run's own timestamps: B's transfer started while A held the
    // pool, and delivered only after A finished and released.
    let a_deliver = c.decode.engines[0].sequence(0).unwrap().first_token_at.unwrap();
    let a_done = c.decode.engines[0].sequence(0).unwrap().finished_at.unwrap();
    let b_harvest = c.prefill.engines[0].sequence(1).unwrap().finished_at.unwrap();
    let b_deliver = c.decode.engines[0].sequence(1).unwrap().first_token_at.unwrap();
    assert!(
        a_deliver < b_harvest && b_harvest < a_done,
        "scenario must start B's transfer while A occupies the pool \
         (a_deliver {a_deliver}, b_harvest {b_harvest}, a_done {a_done})"
    );
    assert!(b_deliver > a_done, "B lands after A's release ({b_deliver} vs {a_done})");
}
