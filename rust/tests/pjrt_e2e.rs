//! End-to-end integration: the serving engine over the real PJRT
//! backend (AOT artifacts → PJRT CPU → continuous batching).
//!
//! Requires `make artifacts`; skips otherwise.
//!
//! Supported PJRT pattern (see runtime::executor::pjrt_guard and
//! coordinator::pjrt_backend::global_executor): **one backend per
//! process, all PJRT work on one thread**. xla_extension 0.5.1
//! corrupts buffers when a process uses several CPU clients or several
//! model instances, so this suite is a single #[test] that threads one
//! backend through every scenario.

use fp8_tco::coordinator::{Engine, EngineConfig, KvCacheConfig, PjrtBackend};
use fp8_tco::runtime::ArtifactDir;
use fp8_tco::workload::trace::{Request, TenantClass};

fn req(id: u64, p: usize, o: usize) -> Request {
    Request { id, arrival: 0.0, prompt_len: p, output_len: o, class: TenantClass::Interactive }
}

fn engine_for(backend: PjrtBackend) -> Engine<PjrtBackend> {
    let kv = KvCacheConfig { block_tokens: 16, total_blocks: 4096 };
    let mut cfg = EngineConfig::new(kv);
    // Bucket cap 2: xla_extension 0.5.1 (the AOT consumer) executes the
    // b>=4 executables unreliably (sporadic NaN buffers; the identical
    // HLO runs clean under jax's own CPU runtime — upstream miscompile,
    // see EXPERIMENTS.md caveats). b<=2 is stable across repeated runs.
    cfg.batcher.max_batch = 2;
    Engine::new(cfg, backend)
}

#[test]
fn pjrt_e2e_suite() {
    let dir = ArtifactDir::discover();
    if !dir.exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let backend = PjrtBackend::load(&dir, "1b").expect("load pjrt backend");
    let backend = serves_batched_requests(backend);
    let backend = deterministic_rerun(backend);
    single_long_decode(backend);
}

fn serves_batched_requests(backend: PjrtBackend) -> PjrtBackend {
    let max_seq = backend.meta().max_seq;
    let mut engine = engine_for(backend);
    let n_req = 6;
    for i in 0..n_req {
        // prompts <= prefill bucket seq; total context < max_seq.
        engine.submit(&req(i, 8 + (i as usize % 3) * 7, 12));
    }
    assert!(engine.run_to_completion(10_000), "engine drained");
    assert_eq!(engine.metrics.requests_done, n_req);
    assert_eq!(engine.metrics.tokens_out, n_req * 12);

    let vocab = engine.backend.meta().vocab as i32;
    for i in 0..n_req {
        let toks = &engine.backend.emitted[&i];
        assert_eq!(toks.len(), 12, "seq {i}");
        assert!(toks.iter().all(|&t| (0..vocab).contains(&t)));
        assert!(engine.sequence(i).unwrap().context_len() <= max_seq);
    }
    println!("e2e: {}", engine.metrics.report());
    engine.backend
}

fn deterministic_rerun(mut backend: PjrtBackend) -> PjrtBackend {
    // Same ids + lengths rerun from scratch => identical tokens
    // (greedy decoding, deterministic artifacts).
    backend.reset_emitted();
    let mut e1 = engine_for(backend);
    e1.submit(&req(100, 10, 8));
    e1.submit(&req(101, 16, 8));
    assert!(e1.run_to_completion(10_000));
    let first = e1.backend.emitted.clone();

    let mut backend = e1.backend;
    backend.reset_emitted();
    let mut e2 = engine_for(backend);
    e2.submit(&req(100, 10, 8));
    e2.submit(&req(101, 16, 8));
    assert!(e2.run_to_completion(10_000));
    assert_eq!(first, e2.backend.emitted);
    println!("determinism: ok ({:?})", first[&100]);
    e2.backend
}

fn single_long_decode(mut backend: PjrtBackend) {
    backend.reset_emitted();
    let max_seq = backend.meta().max_seq;
    let out = max_seq - 40;
    let mut engine = engine_for(backend);
    engine.submit(&req(200, 24, out));
    assert!(engine.run_to_completion(100_000));
    assert_eq!(engine.backend.emitted[&200].len(), out);
    assert!(engine.sequence(200).unwrap().context_len() <= max_seq);
    println!("long decode: {}", engine.metrics.report());
}
