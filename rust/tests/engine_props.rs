//! Property tests over coordinator invariants (hand-rolled harness —
//! the vendored crate set has no proptest; `Rng`-driven random cases
//! with seeds printed on failure serve the same role).
//!
//! Invariants:
//!  * conservation: allocator blocks never leak or double-free;
//!  * completion: every admitted request finishes (given capacity);
//!  * accounting: tokens out == sum of output lengths;
//!  * monotone clock; TTFT <= E2E latency;
//!  * throughput monotone in batch cap;
//!  * preemption preserves total output.

use fp8_tco::analysis::perfmodel::{PrecisionMode, StepConfig};
use fp8_tco::coordinator::{Engine, EngineConfig, KvCacheConfig, SimBackend};
use fp8_tco::hwsim::spec::Device;
use fp8_tco::util::rng::Rng;
use fp8_tco::workload::llama::by_name;
use fp8_tco::workload::trace::{Request, TenantClass};

fn req(id: u64, arrival: f64, p: usize, o: usize) -> Request {
    Request {
        id,
        arrival,
        prompt_len: p,
        output_len: o,
        class: TenantClass::Interactive,
    }
}

fn engine(total_blocks: usize, max_batch: usize) -> Engine<SimBackend> {
    let kv = KvCacheConfig { block_tokens: 16, total_blocks };
    let backend = SimBackend::new(
        by_name("llama-8b").unwrap(),
        StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()),
    );
    let mut cfg = EngineConfig::new(kv);
    cfg.batcher.max_batch = max_batch;
    Engine::new(cfg, backend)
}

#[test]
fn prop_all_requests_finish_and_blocks_balance() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed);
        let n_req = rng.usize(1, 30);
        let blocks = rng.usize(64, 4000);
        let max_batch = rng.usize(1, 128);
        let mut e = engine(blocks, max_batch);
        let mut expected_tokens = 0u64;
        let mut feasible = true;
        let pool_tokens = blocks * 16;
        for i in 0..n_req as u64 {
            let p = rng.usize(1, 300);
            let o = rng.usize(1, 200);
            // Requests that can never fit make the run legitimately
            // undrainable; keep the workload feasible.
            if p + o + 16 > pool_tokens {
                feasible = false;
                break;
            }
            expected_tokens += o as u64;
            e.submit(&req(i, 0.0, p, o));
        }
        if !feasible {
            continue;
        }
        let drained = e.run_to_completion(2_000_000);
        assert!(drained, "seed {seed}: engine did not drain");
        assert_eq!(
            e.metrics.tokens_out, expected_tokens,
            "seed {seed}: token accounting"
        );
        // Conservation: all KV released at the end.
        assert_eq!(e.kv_utilization(), 0.0, "seed {seed}: leaked blocks");
    }
}

#[test]
fn prop_clock_monotone_and_latencies_ordered() {
    for seed in 40..60u64 {
        let mut rng = Rng::new(seed);
        let mut e = engine(4000, 64);
        let n = rng.usize(2, 20);
        let mut t = 0.0;
        for i in 0..n as u64 {
            t += rng.f64() * 0.05;
            e.submit(&req(i, t, rng.usize(1, 256), rng.usize(1, 64)));
        }
        let mut last_clock = e.clock();
        for _ in 0..1_000_000 {
            if e.pending() == 0 {
                break;
            }
            e.step();
            assert!(e.clock() >= last_clock, "seed {seed}: clock went backwards");
            last_clock = e.clock();
        }
        assert_eq!(e.pending(), 0, "seed {seed}");
        let ttft = e.metrics.ttft.pct(95.0);
        let e2e = e.metrics.e2e_latency.pct(95.0);
        assert!(ttft <= e2e + 1e-12, "seed {seed}: ttft {ttft} > e2e {e2e}");
    }
}

#[test]
fn prop_heavy_pressure_still_drains_with_preemptions() {
    // Small pools + long decodes force preemption churn; the engine
    // must still converge and never lose tokens.
    for seed in 60..75u64 {
        let mut rng = Rng::new(seed);
        let blocks = rng.usize(20, 60); // 320..960 tokens total
        let mut e = engine(blocks, 32);
        let mut expected = 0u64;
        let n = rng.usize(2, 6);
        for i in 0..n as u64 {
            let p = rng.usize(1, 40);
            let max_o = blocks * 16 - p - 16;
            let o = rng.usize(1, max_o.min(150).max(2));
            expected += o as u64;
            e.submit(&req(i, 0.0, p, o));
        }
        assert!(e.run_to_completion(3_000_000), "seed {seed}");
        assert_eq!(e.metrics.tokens_out, expected, "seed {seed}");
        assert_eq!(e.kv_utilization(), 0.0, "seed {seed}");
    }
}

#[test]
fn prop_throughput_monotone_in_batch_cap() {
    // Raising max_batch can only help virtual-time completion for a
    // uniform workload (more batching, same per-step ~constant cost).
    let mk = |max_batch: usize| {
        let mut e = engine(100_000, max_batch);
        for i in 0..64u64 {
            e.submit(&req(i, 0.0, 128, 64));
        }
        assert!(e.run_to_completion(1_000_000));
        e.clock()
    };
    let t1 = mk(1);
    let t8 = mk(8);
    let t64 = mk(64);
    assert!(t8 < t1, "{t8} {t1}");
    assert!(t64 < t8, "{t64} {t8}");
}

#[test]
fn prop_fp8_never_slower_than_bf16_on_gaudi_decode_workloads() {
    // The TCO argument's throughput premise, randomized across
    // workloads: Gaudi FP8 decode throughput >= BF16.
    for seed in 80..95u64 {
        let mut rng = Rng::new(seed);
        let b = rng.usize(4, 128);
        let s = rng.usize(64, 4096);
        let m = by_name("llama-8b").unwrap();
        let fp8 = fp8_tco::analysis::perfmodel::decode_step(
            m, &StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()), b, s);
        let bf16 = fp8_tco::analysis::perfmodel::decode_step(
            m, &StepConfig::new(Device::Gaudi2, PrecisionMode::Bf16), b, s);
        assert!(
            fp8.seconds <= bf16.seconds * 1.001,
            "seed {seed} b={b} s={s}: fp8 {} bf16 {}",
            fp8.seconds,
            bf16.seconds
        );
    }
}
