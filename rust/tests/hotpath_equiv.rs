//! Equivalence and hygiene tests for the simulator hot-path overhaul
//! (DESIGN.md §9).
//!
//! * The *indexed batcher* (incrementally maintained decode set) is
//!   audited against the reference full-scan on every planned step in
//!   debug builds — every test in this suite (and the whole tier-1
//!   run) therefore exercises that equivalence on colocated,
//!   disaggregated and PhaseAffinity timelines, including preemption,
//!   resume and bounce transitions.
//! * The *memoized backend* must be a pure transparent cache: a cached
//!   run and an always-recompute run of the same trace produce
//!   bit-identical metrics and makespans.
//! * `par_map` sweeps must match serial sweeps probe-for-probe.
//! * `ExecutionBackend::release` must fire for every sequence that
//!   leaves service — finished ones included — so per-sequence backend
//!   state cannot leak across a long trace.

use std::collections::{HashMap, HashSet};

use fp8_tco::analysis::disagg::{DisaggPlan, PhaseAffinityPlan, PoolSpec};
use fp8_tco::analysis::parallel::ParallelismPlan;
use fp8_tco::analysis::perfmodel::{PrecisionMode, StepConfig};
use fp8_tco::coordinator::backend::StepResult;
use fp8_tco::coordinator::cluster::{
    disagg_sim_cluster, measure_load, phase_affinity_sim_cluster, sim_cluster, LoadPoint,
    SloSpec,
};
use fp8_tco::coordinator::router::Router;
use fp8_tco::coordinator::{
    Engine, EngineConfig, ExecutionBackend, KvCacheConfig, Metrics, SeqId, SimBackend,
};
use fp8_tco::hwsim::spec::Device;
use fp8_tco::util::par::par_map;
use fp8_tco::workload::llama::by_name;
use fp8_tco::workload::trace::{Request, TenantClass, TraceConfig, TraceGenerator};

fn press(id: u64, arrival: f64, p: usize, o: usize) -> Request {
    Request {
        id,
        arrival,
        prompt_len: p,
        output_len: o,
        class: TenantClass::Interactive,
    }
}

/// Everything a simulation outcome is made of, with floats as bits —
/// two runs compare equal iff they were bit-identical. Cache counters
/// are deliberately excluded: they are the one legitimate difference
/// between a cached and an uncached run.
fn fingerprint(makespan: f64, m: &Metrics) -> Vec<u64> {
    vec![
        makespan.to_bits(),
        m.tokens_out,
        m.requests_done,
        m.restarts,
        m.migrations,
        m.bounces,
        m.steps,
        m.kv_bytes_migrated.to_bits(),
        m.energy_j.to_bits(),
        m.energy_prefill_j.to_bits(),
        m.energy_decode_j.to_bits(),
        m.energy_idle_j.to_bits(),
        m.flops.to_bits(),
        m.span.to_bits(),
        m.idle_s.to_bits(),
        m.ttft.pct(50.0).to_bits(),
        m.ttft.pct(95.0).to_bits(),
        m.tpot.pct(50.0).to_bits(),
        m.tpot.pct(95.0).to_bits(),
        m.e2e_latency.pct(95.0).to_bits(),
    ]
}

fn uncache(router: &mut Router<SimBackend>) {
    for e in router.engines.iter_mut() {
        e.backend.set_cache(false);
    }
}

fn trace(n: usize) -> Vec<Request> {
    TraceGenerator::new(TraceConfig::chat(4.0), 23).take(n)
}

fn small_disagg_plan() -> DisaggPlan {
    DisaggPlan::new(
        PoolSpec::new(Device::H100, PrecisionMode::fp8_dynamic(), ParallelismPlan::single()),
        PoolSpec::new(
            Device::Gaudi2,
            PrecisionMode::fp8_static(),
            ParallelismPlan::single().with_replicas(2),
        ),
    )
}

#[test]
fn memoized_backend_bit_identical_colocated() {
    let run = |cached: bool| {
        let mut c = sim_cluster(Device::H100, PrecisionMode::fp8_static(), 2);
        if !cached {
            uncache(&mut c.router);
        }
        assert!(c.run(trace(80)), "trace must drain");
        let m = c.merged_metrics();
        if cached {
            assert!(
                m.step_cache_hits + m.step_cache_misses > 0,
                "cached run must actually exercise the cache"
            );
        } else {
            assert_eq!(m.step_cache_hits + m.step_cache_misses, 0);
        }
        fingerprint(c.makespan(), &m)
    };
    assert_eq!(run(true), run(false), "cache must be a transparent memoization");
}

#[test]
fn memoized_backend_bit_identical_disagg_chunked_admission() {
    let model = by_name("llama-8b").unwrap();
    let run = |cached: bool| {
        let mut c = disagg_sim_cluster(model, &small_disagg_plan())
            .expect("8B fits")
            .with_streaming(8, true);
        if !cached {
            uncache(&mut c.prefill);
            uncache(&mut c.decode);
        }
        assert!(c.run(trace(60)), "trace must drain");
        fingerprint(c.makespan(), &c.merged_metrics())
    };
    assert_eq!(run(true), run(false));
}

#[test]
fn memoized_backend_bit_identical_phase_affinity() {
    let model = by_name("llama-8b").unwrap();
    let plan = PhaseAffinityPlan::new(
        PoolSpec::new(Device::H100, PrecisionMode::fp8_dynamic(), ParallelismPlan::single()),
        small_disagg_plan(),
        512,
    );
    let run = |cached: bool| {
        let mut c = phase_affinity_sim_cluster(model, &plan)
            .expect("8B fits")
            .with_streaming(8, true);
        if !cached {
            uncache(&mut c.colocated);
            uncache(&mut c.disagg.prefill);
            uncache(&mut c.disagg.decode);
        }
        assert!(c.run(trace(60)), "trace must drain");
        fingerprint(c.makespan(), &c.merged_metrics())
    };
    assert_eq!(run(true), run(false));
}

#[test]
fn par_map_sweep_matches_serial_probe_for_probe() {
    let slo = SloSpec::interactive();
    let grid: Vec<f64> = vec![0.5, 1.0, 2.0, 4.0, 8.0];
    let probe = |qps: f64| {
        measure_load(
            &|| sim_cluster(Device::Gaudi2, PrecisionMode::fp8_static(), 2),
            &TraceConfig::chat,
            qps,
            40,
            7,
            &slo,
        )
    };
    let serial: Vec<LoadPoint> = par_map(grid.clone(), 1, |_, q| probe(q));
    let parallel: Vec<LoadPoint> = par_map(grid, 4, |_, q| probe(q));
    // Debug formatting prints every f64 exactly (shortest roundtrip),
    // so equal strings mean equal bits, probe for probe.
    assert_eq!(format!("{serial:?}"), format!("{parallel:?}"));
}

#[test]
fn archive_keeps_finished_sequences_inspectable() {
    // Finished sequences leave the hot map but must stay readable
    // through the same APIs (post-run inspection contract).
    let mut c = sim_cluster(Device::H100, PrecisionMode::fp8_static(), 2);
    let reqs = trace(30);
    let n = reqs.len() as u64;
    assert!(c.run(reqs));
    let seen: usize = c.router.engines.iter().map(|e| e.sequences().count()).sum();
    assert_eq!(seen as u64, n, "every request inspectable after finishing");
    for e in &c.router.engines {
        assert_eq!(e.pending(), 0);
        assert_eq!(
            e.finished_resident(),
            e.sequences().count(),
            "all sequences finished => all archived"
        );
        for s in e.sequences() {
            assert!(s.finished_at.is_some(), "archived sequence keeps its timestamps");
        }
    }
}

/// Wrapper backend that records which sequences currently hold backend
/// state (`live`: touched by prefill/decode, not yet released) and how
/// often each id was released.
struct ReleaseAudit {
    inner: SimBackend,
    live: HashSet<SeqId>,
    released: HashMap<SeqId, u32>,
}

impl ReleaseAudit {
    fn new() -> Self {
        ReleaseAudit {
            inner: SimBackend::new(
                by_name("llama-8b").unwrap(),
                StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()),
            ),
            live: HashSet::new(),
            released: HashMap::new(),
        }
    }
}

impl ExecutionBackend for ReleaseAudit {
    fn prefill(&mut self, seqs: &[(SeqId, usize)]) -> StepResult {
        for &(id, _) in seqs {
            self.live.insert(id);
        }
        self.inner.prefill(seqs)
    }

    fn decode(&mut self, seqs: &[(SeqId, usize)]) -> StepResult {
        for &(id, _) in seqs {
            self.live.insert(id);
        }
        self.inner.decode(seqs)
    }

    fn release(&mut self, id: SeqId) {
        self.live.remove(&id);
        *self.released.entry(id).or_insert(0) += 1;
        self.inner.release(id);
    }

    fn describe(&self) -> String {
        format!("release-audit:{}", self.inner.describe())
    }
}

fn audit_engine(total_blocks: usize) -> Engine<ReleaseAudit> {
    let kv = KvCacheConfig { block_tokens: 16, total_blocks };
    Engine::new(EngineConfig::new(kv), ReleaseAudit::new())
}

#[test]
fn release_fires_for_finished_sequences_no_backend_leak() {
    // Pressure workload: finishes AND preemptions AND clean finishes —
    // every sequence that ever touched the backend must be released by
    // the end, finished ones included (not just evicted ones).
    let mut e = audit_engine(8);
    for i in 0..3u64 {
        e.submit(&press(i, 0.0, 32, 40));
    }
    e.submit(&press(3, 0.5, 16, 4));
    assert!(e.run_to_completion(100_000));
    assert!(e.preemptions() > 0, "pressure must preempt");
    assert_eq!(e.metrics.requests_done, 4);
    assert!(
        e.backend.live.is_empty(),
        "backend state leaked for {:?}",
        e.backend.live
    );
    for id in 0..4u64 {
        assert!(
            e.backend.released.get(&id).copied().unwrap_or(0) >= 1,
            "finished sequence {id} never released"
        );
    }
}

#[test]
fn release_fires_for_handoff_legs_and_bounces() {
    // A prefill leg releases backend state when its prefill finishes
    // (the KV blocks stay for the migration, backend state must not);
    // a bounced leg decodes again and releases again at its real end.
    let mut e = audit_engine(1000);
    e.submit_handoff(&press(0, 0.0, 100, 40));
    assert!(e.run_to_completion(1000));
    assert_eq!(e.take_handoffs(), vec![0]);
    assert!(e.backend.live.is_empty(), "handoff leg must release at prefill finish");
    assert_eq!(e.backend.released[&0], 1);
    e.resume_bounced(0, 39);
    assert!(e.run_to_completion(10_000));
    assert_eq!(e.metrics.requests_done, 1);
    assert!(e.backend.live.is_empty(), "bounced leg must release at its real end");
    assert_eq!(e.backend.released[&0], 2);
    assert_eq!(e.kv_utilization(), 0.0);
}
