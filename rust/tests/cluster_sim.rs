//! Integration tests for the cluster event loop: arrival-order
//! fairness across engines, steady-state percentiles under a seeded
//! Poisson trace, determinism, and token conservation under memory
//! pressure — the open-loop properties the drain-the-queue router
//! could not express.

use fp8_tco::analysis::disagg::{DisaggPlan, PhaseAffinityPlan, PoolSpec};
use fp8_tco::analysis::parallel::ParallelismPlan;
use fp8_tco::analysis::perfmodel::{PrecisionMode, StepConfig};
use fp8_tco::coordinator::cluster::{
    disagg_sim_cluster, max_sustainable_qps, measure_load, phase_affinity_sim_cluster,
    sharded_sim_cluster, Cluster, DisaggCluster, SloSpec, SweepConfig,
};
use fp8_tco::coordinator::router::{EngineRating, RoutePolicy, Router};
use fp8_tco::coordinator::{Engine, EngineConfig, KvCacheConfig, SimBackend};
use fp8_tco::hwsim::spec::Device;
use fp8_tco::workload::llama::by_name;
use fp8_tco::workload::trace::{Request, TenantClass, TraceConfig, TraceGenerator};

fn engine(total_blocks: usize) -> Engine<SimBackend> {
    let kv = KvCacheConfig { block_tokens: 16, total_blocks };
    let backend = SimBackend::new(
        by_name("llama-8b").unwrap(),
        StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()),
    );
    Engine::new(EngineConfig::new(kv), backend)
}

/// A *sharded* engine (one multi-chip instance) with a deliberately
/// tiny KV pool, for pressure tests.
fn sharded_engine(total_blocks: usize, plan: ParallelismPlan) -> Engine<SimBackend> {
    let kv = KvCacheConfig { block_tokens: 16, total_blocks };
    let backend = SimBackend::new(
        by_name("llama-70b").unwrap(),
        StepConfig::new(Device::H100, PrecisionMode::fp8_dynamic()).with_plan(plan),
    );
    Engine::new(EngineConfig::new(kv), backend)
}

fn cluster(n_engines: usize, blocks: usize, policy: RoutePolicy) -> Cluster<SimBackend> {
    let engines: Vec<_> = (0..n_engines).map(|_| engine(blocks)).collect();
    let ratings =
        vec![EngineRating { prefill_score: 1.0, decode_score: 1.0 }; n_engines];
    Cluster::new(Router::new(engines, ratings, policy))
}

#[test]
fn arrival_order_fairness_across_engines() {
    let mut c = cluster(2, 50_000, RoutePolicy::RoundRobin);
    let gen = TraceGenerator::new(TraceConfig::chat(8.0), 11);
    assert!(c.run(gen.stream(60)));
    let m = c.merged_metrics();
    assert_eq!(m.requests_done, 60);
    for e in &c.router.engines {
        // Within an engine, FIFO admission: first tokens come out in
        // arrival order, and never before the request exists.
        let mut seqs: Vec<_> = e.sequences().collect();
        seqs.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        let mut last_first = f64::NEG_INFINITY;
        for s in seqs {
            let first = s.first_token_at.expect("every request served");
            assert!(first >= s.arrival, "TTFT reference precedes arrival");
            assert!(
                first >= last_first,
                "arrival order violated: {first} after {last_first}"
            );
            last_first = first;
        }
    }
}

#[test]
fn late_arrival_ttft_measured_from_own_arrival_in_cluster() {
    // Acceptance regression: a request arriving 10 s into the run must
    // report a prefill-scale TTFT, not one warped by the shared clock.
    let mut c = cluster(2, 50_000, RoutePolicy::RoundRobin);
    let reqs = vec![
        Request {
            id: 0,
            arrival: 0.0,
            prompt_len: 128,
            output_len: 16,
            class: TenantClass::Interactive,
        },
        Request {
            id: 1,
            arrival: 10.0,
            prompt_len: 128,
            output_len: 16,
            class: TenantClass::Interactive,
        },
    ];
    assert!(c.run(reqs));
    let m = c.merged_metrics();
    assert_eq!(m.ttft.count(), 2);
    assert!(m.ttft.pct(100.0) < 1.0, "10 s gap leaked into TTFT");
    assert!(c.makespan() >= 10.0);
}

#[test]
fn steady_state_percentiles_under_seeded_poisson_trace() {
    let mut c = cluster(2, 50_000, RoutePolicy::LeastLoaded);
    let gen = TraceGenerator::new(TraceConfig::chat(6.0), 42);
    assert!(c.run(gen.stream(120)));
    let m = c.merged_metrics();
    let makespan = c.makespan();
    assert!(makespan > 0.0);
    let (t0, t1) = SloSpec::interactive().window(makespan);
    assert!(m.ttft.count_in(t0, t1) > 0, "steady-state window holds samples");
    let p95_win = m.ttft.pct_in(t0, t1, 95.0);
    assert!(p95_win.is_finite() && p95_win > 0.0);
    // The window can only tighten (or match) the whole-run extremes.
    assert!(p95_win <= m.ttft.pct(100.0) + 1e-12);
    // TPOT exists and is positive under multi-token chat outputs.
    assert!(m.tpot.count() > 0);
    assert!(m.tpot.pct(0.0) > 0.0);
}

#[test]
fn determinism_same_seed_same_everything() {
    let run = || {
        let mut c = cluster(2, 50_000, RoutePolicy::LeastLoaded);
        let gen = TraceGenerator::new(TraceConfig::chat(10.0), 99);
        assert!(c.run(gen.stream(80)));
        let m = c.merged_metrics();
        (
            c.makespan(),
            m.tokens_out,
            m.requests_done,
            m.report(),
            c.router.routed_counts().to_vec(),
        )
    };
    let a = run();
    let b = run();
    assert_eq!(a.0.to_bits(), b.0.to_bits(), "makespan must be bit-identical");
    assert_eq!(a.1, b.1);
    assert_eq!(a.2, b.2);
    assert_eq!(a.3, b.3, "metric reports must match");
    assert_eq!(a.4, b.4, "routing must match");
}

#[test]
fn tokens_conserved_under_cluster_memory_pressure() {
    // Tiny per-engine pools force preemption churn; every token must
    // still be counted exactly once across the cluster.
    let mut c = cluster(2, 8, RoutePolicy::RoundRobin);
    let reqs: Vec<Request> = (0..6)
        .map(|i| Request {
            id: i,
            arrival: i as f64 * 0.01,
            prompt_len: 32,
            output_len: 40,
            class: TenantClass::Interactive,
        })
        .collect();
    let expected: u64 = reqs.iter().map(|r| r.output_len as u64).sum();
    assert!(c.run(reqs));
    let m = c.merged_metrics();
    assert_eq!(m.requests_done, 6);
    assert!(c.preemptions() > 0, "pressure workload must preempt");
    assert_eq!(m.tokens_out, expected, "preempted tokens double-counted");
    assert_eq!(m.restarts, c.preemptions(), "restart accounting");
    assert_eq!(m.ttft.count(), 6, "TTFT sampled once per request");
}

#[test]
fn load_sweep_is_deterministic_and_bracketed() {
    let slo = SloSpec::interactive();
    let cfg = SweepConfig { iters: 4, n_requests: 60, seed: 5, ..SweepConfig::new(0.5, 48.0) };
    let sweep = || {
        max_sustainable_qps(
            &|| cluster(2, 50_000, RoutePolicy::LeastLoaded),
            &TraceConfig::chat,
            &slo,
            &cfg,
        )
    };
    let a = sweep();
    let b = sweep();
    let (pa, pb) = (a.best.expect("feasible floor"), b.best.expect("feasible floor"));
    assert_eq!(pa.qps.to_bits(), pb.qps.to_bits(), "sweep must be deterministic");
    assert!(pa.qps >= 0.5 && pa.qps <= 48.0);
    assert!(pa.feasible && pa.ttft_p95 <= slo.ttft_p95_s && pa.tpot_p95 <= slo.tpot_p95_s);
    // Offered load above the found maximum must be no easier: the
    // direct measurement at a higher rate violates the SLO whenever
    // the search stopped below the ceiling.
    let last_infeasible = a.probes.iter().filter(|p| !p.feasible).last();
    if let Some(bad) = last_infeasible {
        assert!(bad.qps > pa.qps, "infeasible probe below the accepted max");
    }
}

#[test]
fn sharded_engines_preserve_determinism_invariant() {
    // The cluster_sim determinism guarantee must survive the engine
    // unit becoming a multi-chip instance: same seed, bit-identical
    // makespan/metrics/routing for a 70B TP=4 cluster.
    let run = || {
        let mut c = sharded_sim_cluster(
            by_name("llama-70b").unwrap(),
            Device::H100,
            PrecisionMode::fp8_dynamic(),
            ParallelismPlan::tp(4).with_replicas(2),
        )
        .expect("70B fits at tp4");
        let gen = TraceGenerator::new(TraceConfig::chat(2.0), 99);
        assert!(c.run(gen.stream(40)));
        let m = c.merged_metrics();
        (
            c.makespan(),
            m.tokens_out,
            m.requests_done,
            m.report(),
            c.router.routed_counts().to_vec(),
        )
    };
    let a = run();
    let b = run();
    assert_eq!(a.0.to_bits(), b.0.to_bits(), "sharded makespan must be bit-identical");
    assert_eq!(a.1, b.1);
    assert_eq!(a.2, b.2);
    assert_eq!(a.3, b.3);
    assert_eq!(a.4, b.4);
    assert_eq!(a.2, 40);
}

#[test]
fn sharded_engines_conserve_tokens_under_memory_pressure() {
    // Tiny pools force preemption churn on sharded instances too:
    // every delivered token still counted exactly once, TTFT sampled
    // once per request, restarts == preemptions.
    let engines: Vec<_> = (0..2)
        .map(|_| sharded_engine(8, ParallelismPlan::tp(4)))
        .collect();
    let ratings = vec![EngineRating { prefill_score: 1.0, decode_score: 1.0 }; 2];
    let mut c = Cluster::new(Router::new(engines, ratings, RoutePolicy::RoundRobin));
    let reqs: Vec<Request> = (0..6)
        .map(|i| Request {
            id: i,
            arrival: i as f64 * 0.01,
            prompt_len: 32,
            output_len: 40,
            class: TenantClass::Interactive,
        })
        .collect();
    let expected: u64 = reqs.iter().map(|r| r.output_len as u64).sum();
    assert!(c.run(reqs));
    let m = c.merged_metrics();
    assert_eq!(m.requests_done, 6);
    assert!(c.preemptions() > 0, "pressure workload must preempt");
    assert_eq!(m.tokens_out, expected, "sharded preemption double-counted tokens");
    assert_eq!(m.restarts, c.preemptions());
    assert_eq!(m.ttft.count(), 6);
}

#[test]
fn sharded_70b_cluster_sustains_an_interactive_slo_point() {
    // End-to-end acceptance for the multi-chip path: a 70B TP=8
    // instance pool has a non-trivial SLO-feasible operating point
    // (the quantity cost_per_mtok prices).
    let slo = SloSpec::interactive();
    let cfg = SweepConfig { iters: 3, n_requests: 40, seed: 7, ..SweepConfig::new(0.25, 16.0) };
    let out = max_sustainable_qps(
        &|| {
            sharded_sim_cluster(
                by_name("llama-70b").unwrap(),
                Device::H100,
                PrecisionMode::fp8_dynamic(),
                ParallelismPlan::tp(8),
            )
            .expect("70B fits at tp8")
        },
        &TraceConfig::chat,
        &slo,
        &cfg,
    );
    let best = out.best.expect("tp8 70B must sustain a near-idle chat load");
    assert!(best.feasible && best.tokens_per_sec > 0.0);
    assert!(best.tpot_p95 <= slo.tpot_p95_s);
}

/// A small mixed deployment: 2 colocated H100 engines beside an
/// H100-prefill → Gaudi2-decode pair, split at 512 prompt tokens.
fn small_affinity_plan() -> PhaseAffinityPlan {
    let h100 = |plan| PoolSpec::new(Device::H100, PrecisionMode::fp8_dynamic(), plan);
    let gaudi2 = |plan| PoolSpec::new(Device::Gaudi2, PrecisionMode::fp8_static(), plan);
    PhaseAffinityPlan::new(
        h100(ParallelismPlan::single().with_replicas(2)),
        DisaggPlan::new(
            h100(ParallelismPlan::single()),
            gaudi2(ParallelismPlan::single()),
        ),
        512,
    )
}

#[test]
fn phase_affinity_determinism_same_seed_same_timelines() {
    // Same trace + seed must yield bit-identical timelines across
    // runs of the mixed colocated + disaggregated router, chunked
    // streaming and admission control included.
    let run = || {
        let model = by_name("llama-8b").unwrap();
        let mut c = phase_affinity_sim_cluster(model, &small_affinity_plan())
            .expect("8B fits everywhere")
            .with_streaming(4, true);
        let gen = TraceGenerator::new(TraceConfig::chat(6.0), 77);
        assert!(c.run(gen.stream(60)));
        let m = c.merged_metrics();
        let (cm, pm, dm) = c.pool_metrics();
        (
            c.makespan(),
            m.tokens_out,
            m.requests_done,
            m.migrations,
            m.bounces,
            m.report(),
            (cm.tokens_out, pm.tokens_out, dm.tokens_out),
        )
    };
    let a = run();
    let b = run();
    assert_eq!(a.0.to_bits(), b.0.to_bits(), "mixed makespan must be bit-identical");
    assert_eq!(a.1, b.1);
    assert_eq!(a.2, b.2);
    assert_eq!(a.3, b.3);
    assert_eq!(a.4, b.4);
    assert_eq!(a.5, b.5, "metric reports must match");
    assert_eq!(a.6, b.6, "per-pool splits must match");
}

#[test]
fn phase_affinity_conserves_tokens_across_both_pool_kinds() {
    // Every request finishes exactly once, every token is delivered
    // exactly once, and the colocated/disaggregated split accounts for
    // the whole trace: colocated requests + migrations + bounces ==
    // all requests, with TTFT sampled once each.
    let model = by_name("llama-8b").unwrap();
    let mut c = phase_affinity_sim_cluster(model, &small_affinity_plan())
        .expect("8B fits")
        .with_streaming(4, true);
    let gen = TraceGenerator::new(TraceConfig::chat(5.0), 41);
    let reqs: Vec<Request> = gen.stream(80).collect();
    let expected: u64 = reqs.iter().map(|r| r.output_len as u64).sum();
    let disagg_bound: u64 = reqs.iter().filter(|r| c.routes_disagg(r)).count() as u64;
    assert!(disagg_bound > 0, "the chat mix must exercise the disagg path");
    assert!(
        (disagg_bound as usize) < reqs.len(),
        "the chat mix must exercise the colocated path too"
    );
    assert!(c.run(reqs));
    let m = c.merged_metrics();
    assert_eq!(m.requests_done, 80, "no request lost in the mixed router");
    assert_eq!(m.tokens_out, expected, "token conservation across pool kinds");
    assert_eq!(m.ttft.count(), 80, "TTFT sampled exactly once per request");
    assert_eq!(
        m.migrations + m.bounces,
        disagg_bound,
        "every disagg-routed request either migrated or bounced"
    );
    let (cm, pm, dm) = c.pool_metrics();
    assert_eq!(
        cm.requests_done + pm.requests_done + dm.requests_done,
        80,
        "each request finishes in exactly one pool"
    );
    assert_eq!(cm.requests_done, 80 - disagg_bound, "colocated owns the short requests");
    assert_eq!(pm.requests_done, m.bounces, "bounces finish on the prefill pool");
    assert_eq!(dm.requests_done, m.migrations, "migrations finish on the decode pool");
    assert_eq!(cm.migrations, 0, "colocated engines never receive migrations");
}

#[test]
fn energy_conserved_across_cluster_rollup() {
    // Per-engine integrated energy must sum to the cluster total after
    // `absorb`, and joules/token must be consistent with
    // `watts_mean x span / tokens_out` (watts_mean = energy / span).
    let mut c = cluster(3, 50_000, RoutePolicy::LeastLoaded);
    let gen = TraceGenerator::new(TraceConfig::chat(6.0), 17);
    assert!(c.run(gen.stream(90)));
    let m = c.merged_metrics();
    assert!(m.energy_j > 0.0 && m.span > 0.0 && m.tokens_out > 0);
    let per_engine: f64 = c.router.engines.iter().map(|e| e.metrics.energy_j).sum();
    assert!(
        (m.energy_j - per_engine).abs() <= 1e-9 * per_engine,
        "cluster energy {} != sum of engines {}",
        m.energy_j,
        per_engine
    );
    let span_sum: f64 = c.router.engines.iter().map(|e| e.metrics.span).sum();
    assert!((m.span - span_sum).abs() <= 1e-9 * span_sum, "span rollup");
    let watts_mean = m.energy_j / m.span;
    let jpt = m.joules_per_token();
    let reconstructed = watts_mean * m.span / m.tokens_out as f64;
    assert!(
        (jpt - reconstructed).abs() <= 1e-9 * jpt,
        "J/token {jpt} inconsistent with watts_mean x span / tokens ({reconstructed})"
    );
    assert!(
        (jpt - m.energy_j / m.tokens_out as f64).abs() <= 1e-12 * jpt,
        "joules_per_token drifted from energy/tokens"
    );
}

#[test]
fn idle_aware_ledger_conserves_energy_at_makespan() {
    // The run closes every engine's ledger at the cluster makespan, so
    // each engine's time-at-power covers the whole timeline and the
    // integral of draw over the run reconstructs total energy exactly:
    // watts_mean x engines x makespan == sum of per-engine busy+idle J.
    let mut c = cluster(3, 50_000, RoutePolicy::LeastLoaded);
    let gen = TraceGenerator::new(TraceConfig::chat(2.0), 23);
    assert!(c.run(gen.stream(60)));
    let makespan = c.makespan();
    let m = c.merged_metrics();
    for e in &c.router.engines {
        let covered = e.metrics.span + e.metrics.idle_s;
        assert!(
            (covered - makespan).abs() <= 1e-9 * makespan,
            "engine time-at-power {covered} != makespan {makespan}"
        );
    }
    let n = c.router.engines.len() as f64;
    let total: f64 = c.router.engines.iter().map(|e| e.metrics.energy_j).sum();
    assert!(
        (m.watts_mean() * n * makespan - total).abs() <= 1e-9 * total,
        "mean draw x time != integrated energy: {} vs {}",
        m.watts_mean() * n * makespan,
        total
    );
    // The ledger splits exactly into its three components.
    let parts = m.energy_prefill_j + m.energy_decode_j + m.energy_idle_j;
    assert!(
        (m.energy_j - parts).abs() <= 1e-9 * m.energy_j,
        "ledger components drifted: {} vs {}",
        m.energy_j,
        parts
    );
    assert!(m.energy_idle_j > 0.0, "a 2 QPS chat trace leaves idle gaps");
    assert!(m.joules_per_token_in() > 0.0 && m.joules_per_token_out() > 0.0);
}

#[test]
fn disagg_ledger_covers_both_pools_to_the_shared_makespan() {
    // Disaggregated pools share one timeline: the decode pool idles
    // while the first prefill runs and the prefill pool idles through
    // the decode tail, yet every engine's ledger still closes at the
    // cluster-wide makespan and energy stays conserved.
    let model = by_name("llama-8b").unwrap();
    let plan = DisaggPlan::new(
        PoolSpec::new(Device::H100, PrecisionMode::fp8_dynamic(), ParallelismPlan::single()),
        PoolSpec::new(
            Device::Gaudi2,
            PrecisionMode::fp8_static(),
            ParallelismPlan::single().with_replicas(2),
        ),
    );
    let mut c = disagg_sim_cluster(model, &plan).expect("8B fits");
    let gen = TraceGenerator::new(TraceConfig::chat(3.0), 31);
    assert!(c.run(gen.stream(50)));
    let t = c.makespan();
    let mut total = 0.0;
    let mut n = 0.0;
    for e in c.prefill.engines.iter().chain(c.decode.engines.iter()) {
        let covered = e.metrics.span + e.metrics.idle_s;
        assert!(
            (covered - t).abs() <= 1e-9 * t,
            "pool engine time-at-power {covered} != makespan {t}"
        );
        total += e.metrics.energy_j;
        n += 1.0;
    }
    let merged = DisaggCluster::merged_metrics(&c);
    assert!(
        (merged.watts_mean() * n * t - total).abs() <= 1e-9 * total,
        "disagg mean draw x time != integrated energy"
    );
    assert!(merged.energy_idle_j > 0.0, "phase pools must bill their idle phases");
}

#[test]
fn low_qps_watts_mean_exceeds_busy_only_accounting() {
    // The idle-blind ledger understated sustained draw at low load:
    // busy-only energy spread over the makespan sits strictly below
    // the honest busy+idle mean, which in turn can never fall below
    // the device idle floor.
    let mut c = cluster(2, 50_000, RoutePolicy::LeastLoaded);
    let gen = TraceGenerator::new(TraceConfig::chat(0.2), 13);
    assert!(c.run(gen.stream(20)));
    let m = c.merged_metrics();
    let makespan = c.makespan();
    let busy_only_w = (m.energy_prefill_j + m.energy_decode_j) / (2.0 * makespan);
    assert!(
        m.watts_mean() > busy_only_w,
        "idle energy vanished from the mean: {} <= {busy_only_w}",
        m.watts_mean()
    );
    let idle_floor = Device::Gaudi2.spec().idle_w;
    assert!(
        m.watts_mean() >= idle_floor - 1e-9,
        "sustained draw {} below the {idle_floor} W idle floor",
        m.watts_mean()
    );
    assert!(m.idle_frac() > 0.3, "0.2 QPS chat must be idle-heavy: {}", m.idle_frac());
}

#[test]
fn decode_energy_per_token_non_increasing_in_batch() {
    // Batching amortizes the weight sweep and the idle-power floor:
    // J/token from a decode step must never rise as the batch grows
    // (memory-bound region: time/batch falls faster than draw rises;
    // compute-bound region: both flat).
    use fp8_tco::analysis::perfmodel::decode_step;
    let m = by_name("llama-8b").unwrap();
    let cfg = StepConfig::new(Device::H100, PrecisionMode::fp8_dynamic());
    let mut last = f64::INFINITY;
    for batch in [1usize, 2, 4, 8, 16, 32, 64] {
        let r = decode_step(m, &cfg, batch, 1024);
        let jpt = r.watts * r.seconds / batch as f64;
        assert!(
            jpt <= last * (1.0 + 1e-9),
            "J/token rose at batch {batch}: {jpt} > {last}"
        );
        last = jpt;
    }
}

#[test]
fn higher_load_does_not_improve_latency() {
    let slo = SloSpec::interactive();
    let mk = || cluster(2, 50_000, RoutePolicy::LeastLoaded);
    let quiet = measure_load(&mk, &TraceConfig::chat, 1.0, 60, 3, &slo);
    let slammed = measure_load(&mk, &TraceConfig::chat, 200.0, 60, 3, &slo);
    assert!(quiet.drained && slammed.drained);
    assert!(
        slammed.ttft_p95 >= quiet.ttft_p95,
        "queueing delay vanished: {} vs {}",
        slammed.ttft_p95,
        quiet.ttft_p95
    );
}
