//! Differential simulation-equivalence suite for the event-driven
//! engine core (DESIGN.md §13).
//!
//! The event engine collapses provably-static decode windows into
//! O(1)-per-step analytic charges (`Engine::try_fast_forward`). Its
//! correctness contract is *bit-identity*: every fast-forwarded
//! trajectory must produce exactly the metrics, ledger arms
//! (including `gated_s`), per-request latency distributions and
//! makespan of the step-by-step reference. This suite enforces that
//! contract with a seeded scenario fuzzer — deterministic, driven
//! only by `util::rng` (simlint rule D) — across every cluster shape
//! the simulator offers × every arrival process × model sizes × fault
//! plans (crash/repair, HBM derate windows, KV-link outages; DESIGN.md
//! §14), plus targeted ledger-conservation property tests under
//! fast-forward. Every fuzzed run additionally checks the fault-era
//! invariants: the four-arm ledger (`span + idle + gated + down`)
//! tiles the makespan per engine, and goodput equals the offered work
//! over every non-dropped request (token conservation).
//!
//! The scenario budget defaults to 200 and can be raised via the
//! `EVENT_EQUIV_SCENARIOS` env var (the CI `event-equiv` job pins
//! it); the RNG seed is fixed, so scenario `i` is the same scenario
//! on every machine and a failure's repro line identifies it exactly.

use fp8_tco::analysis::disagg::{DisaggPlan, PhaseAffinityPlan, PoolSpec};
use fp8_tco::analysis::parallel::ParallelismPlan;
use fp8_tco::analysis::perfmodel::PrecisionMode;
use fp8_tco::coordinator::cluster::{
    autoscaled_sim_cluster, disagg_sim_cluster, phase_affinity_sim_cluster,
    sharded_sim_cluster, sim_cluster, AutoscalerConfig,
};
use fp8_tco::coordinator::{FaultDriver, FaultPlan, Metrics, Pool, RetryPolicy};
use fp8_tco::hwsim::spec::Device;
use fp8_tco::util::rng::Rng;
use fp8_tco::workload::llama::by_name;
use fp8_tco::workload::trace::{
    ArrivalProcess, RateCurve, Request, TraceConfig, TraceGenerator, TrafficConfig,
    TrafficGenerator,
};

/// Everything a simulation outcome is made of, floats as bits: two
/// runs compare equal iff they were bit-identical. Extends the
/// `hotpath_equiv` fingerprint with `gated_s`, the fault-era counters
/// (`down_s`, `retries`, `lost_tokens`, `recompute_tokens_wasted`),
/// cache counters (the fast-forward path must replay the exact
/// hit/miss sequence) and a
/// quantile ladder over the per-request TTFT/TPOT/e2e distributions
/// (p0/p100 are raw extreme samples; interior quantiles hit distinct
/// samples as the count varies).
fn fingerprint(makespan: f64, m: &Metrics, preemptions: u64) -> Vec<u64> {
    let mut v = vec![
        makespan.to_bits(),
        m.tokens_out,
        m.tokens_in,
        m.requests_done,
        m.restarts,
        m.migrations,
        m.bounces,
        m.steps,
        m.step_cache_hits,
        m.step_cache_misses,
        preemptions,
        m.kv_bytes_migrated.to_bits(),
        m.energy_j.to_bits(),
        m.energy_prefill_j.to_bits(),
        m.energy_decode_j.to_bits(),
        m.energy_idle_j.to_bits(),
        m.flops.to_bits(),
        m.span.to_bits(),
        m.idle_s.to_bits(),
        m.gated_s.to_bits(),
        m.down_s.to_bits(),
        m.retries,
        m.lost_tokens,
        m.recompute_tokens_wasted,
        m.ttft.count() as u64,
        m.tpot.count() as u64,
        m.e2e_latency.count() as u64,
    ];
    for q in [0.0, 25.0, 50.0, 75.0, 95.0, 100.0] {
        v.push(m.ttft.pct(q).to_bits());
        v.push(m.tpot.pct(q).to_bits());
        v.push(m.e2e_latency.pct(q).to_bits());
    }
    v
}

/// One fuzzed configuration. `Debug` is the repro line: a failing
/// scenario prints as `Scenario { .. }` with every knob needed to
/// replay it in isolation.
#[derive(Debug, Clone)]
struct Scenario {
    /// 0 colocated, 1 sharded, 2 disagg(+chunks+admission),
    /// 3 PhaseAffinity, 4 autoscaled.
    kind: usize,
    /// 0 uniform Poisson, 1 diurnal multi-tenant, 2 MMPP bursts.
    process: usize,
    /// Sharded scenarios only: llama-70b at TP=4 instead of llama-8b.
    model_70b: bool,
    n_requests: usize,
    qps: f64,
    /// Disagg/PhaseAffinity streaming knobs.
    chunks: usize,
    admission: bool,
    trace_seed: u64,
    /// 0 no faults, 1 crash/repair, 2 HBM derate window, 3 KV-link
    /// outage (split-pool shapes; falls back to crash elsewhere).
    fault: usize,
    fault_t: f64,
    fault_dur: f64,
    /// Derate windows only: surviving HBM bandwidth fraction.
    derate: f64,
}

impl Scenario {
    fn draw(rng: &mut Rng) -> Self {
        Scenario {
            kind: rng.usize(0, 5),
            process: rng.usize(0, 3),
            model_70b: rng.bool(0.25),
            n_requests: rng.usize(12, 36),
            qps: 2.0 + 10.0 * rng.f64(),
            chunks: rng.usize(1, 9),
            admission: rng.bool(0.5),
            trace_seed: rng.next_u64(),
            fault: rng.usize(0, 4),
            fault_t: 0.2 + 1.5 * rng.f64(),
            // Stays well under the default retry budget (~7.15 s), so
            // even a whole-pool outage parks arrivals without drops.
            fault_dur: 0.2 + 0.5 * rng.f64(),
            derate: 0.25 + 0.5 * rng.f64(),
        }
    }
}

/// The scenario's fault plan. Pool targeting follows the cluster
/// shape: split-pool shapes (disagg, PhaseAffinity) alternate between
/// the prefill and decode pools; everything else aims at `Primary`.
fn fault_plan(sc: &Scenario) -> FaultPlan {
    let split = sc.kind == 2 || sc.kind == 3;
    let pool = if !split {
        Pool::Primary
    } else if sc.trace_seed % 2 == 0 {
        Pool::Prefill
    } else {
        Pool::Decode
    };
    match sc.fault {
        0 => FaultPlan::new(),
        1 => FaultPlan::new().crash_repair(pool, 0, sc.fault_t, sc.fault_dur),
        2 => FaultPlan::new().derate_window(pool, 0, sc.fault_t, sc.fault_dur, sc.derate),
        _ if split => FaultPlan::new().link_outage(sc.fault_t, sc.fault_dur),
        _ => FaultPlan::new().crash_repair(pool, 0, sc.fault_t, sc.fault_dur),
    }
}

/// Fault-era invariants checked on every fuzzed run: the four-arm
/// ledger tiles the makespan on each engine, and goodput equals the
/// offered work over every request that was not dropped (a crashed
/// victim's already-streamed tokens sit in both `tokens_out` and
/// `lost_tokens`, netting zero).
fn check_fault_invariants<'a>(
    sc: &Scenario,
    reqs: &[Request],
    dropped: &[u64],
    makespan: f64,
    merged: &Metrics,
    engines: impl Iterator<Item = &'a Metrics>,
) {
    for (i, m) in engines.enumerate() {
        let covered = m.span + m.idle_s + m.gated_s + m.down_s;
        assert!(
            (covered - makespan).abs() <= 1e-9 * makespan.max(1.0),
            "engine {i}: span {} + idle {} + gated {} + down {} != makespan \
             {makespan}: {sc:?}",
            m.span,
            m.idle_s,
            m.gated_s,
            m.down_s
        );
    }
    let expected: u64 = reqs
        .iter()
        .filter(|r| !dropped.contains(&r.id))
        .map(|r| r.output_len as u64)
        .sum();
    assert_eq!(
        merged.tokens_out - merged.lost_tokens,
        expected,
        "token conservation broke: {sc:?}"
    );
}

/// The scenario's arrival stream — materialized once so both runs
/// serve the identical request list.
fn arrivals(sc: &Scenario) -> Vec<Request> {
    match sc.process {
        0 => TraceGenerator::new(TraceConfig::chat(sc.qps), sc.trace_seed)
            .take(sc.n_requests),
        1 => {
            // A compressed diurnal day with a batch-class share: the
            // multi-tenant path exercises lane priorities + aging.
            let curve = RateCurve::diurnal(120.0, (sc.qps * 0.25).max(0.1), sc.qps);
            let cfg = TrafficConfig::multi_tenant(ArrivalProcess::Modulated(curve), 0.25);
            TrafficGenerator::new(cfg, sc.trace_seed).take(sc.n_requests)
        }
        _ => {
            let cfg = TrafficConfig::chat_on(ArrivalProcess::Mmpp {
                base_qps: (sc.qps * 0.5).max(0.1),
                burst_qps: sc.qps * 4.0,
                mean_base_s: 10.0,
                mean_burst_s: 2.0,
            });
            TrafficGenerator::new(cfg, sc.trace_seed).take(sc.n_requests)
        }
    }
}

fn small_disagg_plan() -> DisaggPlan {
    DisaggPlan::new(
        PoolSpec::new(Device::H100, PrecisionMode::fp8_dynamic(), ParallelismPlan::single()),
        PoolSpec::new(
            Device::Gaudi2,
            PrecisionMode::fp8_static(),
            ParallelismPlan::single().with_replicas(2),
        ),
    )
}

fn small_affinity_plan() -> PhaseAffinityPlan {
    PhaseAffinityPlan::new(
        PoolSpec::new(Device::H100, PrecisionMode::fp8_dynamic(), ParallelismPlan::single()),
        small_disagg_plan(),
        512,
    )
}

fn scaler_cfg() -> AutoscalerConfig {
    AutoscalerConfig {
        min_replicas: 1,
        scale_up_depth: 2.0,
        scale_down_depth: 0.5,
        provisioning_delay_s: 2.0,
        decision_interval_s: 0.5,
        depth_window: 2,
    }
}

/// Serve the scenario with the engine's fast-forward on or off and
/// fingerprint the outcome. The two calls build identical clusters;
/// `event_mode` is the only difference. A non-empty fault plan (or
/// `inert_driver`, which attaches an empty one — the bit-invisibility
/// pin) rides along on both runs; the fingerprint then also covers
/// the dropped-request list.
fn run_scenario(sc: &Scenario, event_mode: bool, inert_driver: bool) -> Vec<u64> {
    let reqs = arrivals(sc);
    let plan = fault_plan(sc);
    let attach = !plan.is_empty() || inert_driver;
    let fd = || FaultDriver::new(plan.clone(), RetryPolicy::default());
    let model8 = by_name("llama-8b").unwrap();
    match sc.kind {
        0 => {
            let mut c = sim_cluster(Device::Gaudi2, PrecisionMode::fp8_static(), 2);
            if attach {
                c = c.with_faults(fd());
            }
            for e in c.router.engines.iter_mut() {
                e.set_event_mode(event_mode);
            }
            assert!(c.run(reqs.clone()), "colocated scenario must drain: {sc:?}");
            let merged = c.merged_metrics();
            check_fault_invariants(
                sc,
                &reqs,
                &c.faults.dropped,
                c.makespan(),
                &merged,
                c.router.engines.iter().map(|e| &e.metrics),
            );
            let mut v = fingerprint(c.makespan(), &merged, c.preemptions());
            v.extend(c.faults.dropped.iter().copied());
            v
        }
        1 => {
            let (model, plan) = if sc.model_70b {
                (by_name("llama-70b").unwrap(), ParallelismPlan::tp(4).with_replicas(2))
            } else {
                (model8, ParallelismPlan::single().with_replicas(2))
            };
            let mut c =
                sharded_sim_cluster(model, Device::H100, PrecisionMode::fp8_dynamic(), plan)
                    .expect("fuzzed sharded plan must be feasible");
            if attach {
                c = c.with_faults(fd());
            }
            for e in c.router.engines.iter_mut() {
                e.set_event_mode(event_mode);
            }
            assert!(c.run(reqs.clone()), "sharded scenario must drain: {sc:?}");
            let merged = c.merged_metrics();
            check_fault_invariants(
                sc,
                &reqs,
                &c.faults.dropped,
                c.makespan(),
                &merged,
                c.router.engines.iter().map(|e| &e.metrics),
            );
            let mut v = fingerprint(c.makespan(), &merged, c.preemptions());
            v.extend(c.faults.dropped.iter().copied());
            v
        }
        2 => {
            let mut c = disagg_sim_cluster(model8, &small_disagg_plan())
                .expect("8B fits")
                .with_streaming(sc.chunks, sc.admission);
            if attach {
                c = c.with_faults(fd());
            }
            for e in c.prefill.engines.iter_mut().chain(c.decode.engines.iter_mut()) {
                e.set_event_mode(event_mode);
            }
            assert!(c.run(reqs.clone()), "disagg scenario must drain: {sc:?}");
            let merged = c.merged_metrics();
            check_fault_invariants(
                sc,
                &reqs,
                &c.faults.dropped,
                c.makespan(),
                &merged,
                c.prefill
                    .engines
                    .iter()
                    .chain(c.decode.engines.iter())
                    .map(|e| &e.metrics),
            );
            let mut v = fingerprint(c.makespan(), &merged, c.preemptions());
            v.extend(c.faults.dropped.iter().copied());
            v
        }
        3 => {
            let mut c = phase_affinity_sim_cluster(model8, &small_affinity_plan())
                .expect("8B fits")
                .with_streaming(sc.chunks, sc.admission);
            if attach {
                c = c.with_faults(fd());
            }
            for e in c
                .colocated
                .engines
                .iter_mut()
                .chain(c.disagg.prefill.engines.iter_mut())
                .chain(c.disagg.decode.engines.iter_mut())
            {
                e.set_event_mode(event_mode);
            }
            assert!(c.run(reqs.clone()), "affinity scenario must drain: {sc:?}");
            let merged = c.merged_metrics();
            check_fault_invariants(
                sc,
                &reqs,
                &c.faults.dropped,
                c.makespan(),
                &merged,
                c.colocated
                    .engines
                    .iter()
                    .chain(c.disagg.prefill.engines.iter())
                    .chain(c.disagg.decode.engines.iter())
                    .map(|e| &e.metrics),
            );
            let mut v = fingerprint(c.makespan(), &merged, c.preemptions());
            v.extend(c.faults.dropped.iter().copied());
            v
        }
        _ => {
            let mut c = autoscaled_sim_cluster(
                model8,
                Device::Gaudi2,
                PrecisionMode::fp8_static(),
                ParallelismPlan::single().with_replicas(3),
                scaler_cfg(),
            )
            .expect("8B fits");
            if attach {
                c = c.with_faults(fd());
            }
            for e in c.engines.iter_mut() {
                e.set_event_mode(event_mode);
            }
            assert!(c.run(reqs.clone()), "autoscaled scenario must drain: {sc:?}");
            let merged = c.merged_metrics();
            check_fault_invariants(
                sc,
                &reqs,
                &c.faults.dropped,
                c.makespan(),
                &merged,
                c.engines.iter().map(|e| &e.metrics),
            );
            let mut v = fingerprint(c.makespan(), &merged, c.preemptions());
            v.extend(c.faults.dropped.iter().copied());
            v.push(c.scale_ups);
            v.push(c.scale_downs);
            v
        }
    }
}

#[test]
fn fuzzed_scenarios_are_bit_identical_to_the_stepper() {
    let budget: usize = std::env::var("EVENT_EQUIV_SCENARIOS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let mut rng = Rng::new(0x0e0e_2026);
    let mut by_kind = [0usize; 5];
    let mut by_fault = [0usize; 4];
    for i in 0..budget {
        let sc = Scenario::draw(&mut rng);
        by_kind[sc.kind] += 1;
        by_fault[sc.fault] += 1;
        let event = run_scenario(&sc, true, false);
        let stepper = run_scenario(&sc, false, false);
        assert_eq!(
            event, stepper,
            "fast-forward diverged from the stepper — repro: scenario #{i} of \
             seed 0x0e0e_2026: {sc:?}"
        );
    }
    // The fixed seed must actually cover every cluster shape and
    // every fault kind; a budget too small to reach one is a hole,
    // not a pass.
    if budget >= 200 {
        assert!(
            by_kind.iter().all(|&n| n > 0),
            "scenario mix left a cluster shape uncovered: {by_kind:?}"
        );
        assert!(
            by_fault.iter().all(|&n| n > 0),
            "scenario mix left a fault kind uncovered: {by_fault:?}"
        );
    }
}

#[test]
fn inert_fault_driver_is_bit_invisible_across_fuzzed_scenarios() {
    // Attaching a `FaultDriver` with an empty plan must leave every
    // trajectory bit-identical to a cluster built with no driver at
    // all — the fault layer costs nothing when unused. Fuzzed across
    // shapes and arrival processes with faults forced off.
    let mut rng = Rng::new(0xfa17_2026);
    for i in 0..12 {
        let mut sc = Scenario::draw(&mut rng);
        sc.fault = 0;
        let bare = run_scenario(&sc, true, false);
        let inert = run_scenario(&sc, true, true);
        assert_eq!(
            bare, inert,
            "an inert fault driver perturbed the run — repro: scenario #{i} of \
             seed 0xfa17_2026: {sc:?}"
        );
    }
}

#[test]
fn fast_forward_actually_engages_on_the_fuzz_mix() {
    // Guard against the suite passing vacuously: on a decode-heavy
    // colocated scenario the event engine must finish in strictly
    // fewer `Engine::step` invocations' worth of planning work —
    // observable as identical metrics.steps (virtual steps are
    // preserved) but with the fast-forward path claiming most of
    // them. We detect engagement structurally: event mode must not
    // change steps, and a stepper-only knob (event_mode=false) must
    // be respected.
    let reqs: Vec<Request> = (0..6)
        .map(|i| Request {
            id: i,
            arrival: i as f64 * 0.05,
            prompt_len: 128,
            output_len: 600,
            class: fp8_tco::workload::trace::TenantClass::Interactive,
        })
        .collect();
    let run = |event_mode: bool| {
        let mut c = sim_cluster(Device::Gaudi2, PrecisionMode::fp8_static(), 1);
        for e in c.router.engines.iter_mut() {
            e.set_event_mode(event_mode);
            assert_eq!(e.event_mode(), event_mode);
        }
        assert!(c.run(reqs.clone()));
        let m = c.merged_metrics();
        (fingerprint(c.makespan(), &m, c.preemptions()), m.steps)
    };
    let (ev, ev_steps) = run(true);
    let (st, st_steps) = run(false);
    assert_eq!(ev, st, "decode-heavy trajectory must be bit-identical");
    assert_eq!(ev_steps, st_steps, "virtual step count is part of the contract");
    assert!(ev_steps as usize > 600, "the trajectory must be decode-dominated");
}

/// Ledger conservation under fast-forward (satellite 2): after a
/// close, every engine's `span + idle_s + gated_s` tiles the
/// makespan, and the merged mean draw times powered time reproduces
/// total energy — both at 1e-9 relative.
#[test]
fn ledger_tiles_makespan_under_fast_forward() {
    let mut c = sim_cluster(Device::Gaudi2, PrecisionMode::fp8_static(), 2);
    let curve = RateCurve::diurnal(120.0, 1.0, 8.0);
    let cfg = TrafficConfig::multi_tenant(ArrivalProcess::Modulated(curve), 0.3);
    let reqs = TrafficGenerator::new(cfg, 51).take(60);
    assert!(c.run(reqs));
    let end = c.makespan();
    for e in &c.router.engines {
        assert!(e.event_mode(), "event engine must be the default path");
        let m = &e.metrics;
        let covered = m.span + m.idle_s + m.gated_s;
        assert!(
            (covered - end).abs() <= 1e-9 * end.max(1.0),
            "span {} + idle {} + gated {} != makespan {end}",
            m.span,
            m.idle_s,
            m.gated_s
        );
    }
    let m = c.merged_metrics();
    let engines = c.router.engines.len() as f64;
    let energy_from_mean = m.watts_mean() * engines * end;
    assert!(
        (energy_from_mean - m.energy_j).abs() <= 1e-9 * m.energy_j.max(1.0),
        "watts_mean x engines x makespan {energy_from_mean} != energy {}",
        m.energy_j
    );
}

#[test]
fn ledger_conserves_across_autoscale_power_transitions() {
    // The fleet's power envelope changes mid-day via scale events:
    // replicas gate to 0 W and wake through idle-billed provisioning
    // windows. The conservation identities must hold through every
    // transition, with the event engine on its default fast path.
    let model8 = by_name("llama-8b").unwrap();
    let mut c = autoscaled_sim_cluster(
        model8,
        Device::Gaudi2,
        PrecisionMode::fp8_static(),
        ParallelismPlan::single().with_replicas(3),
        AutoscalerConfig {
            min_replicas: 1,
            scale_up_depth: 2.0,
            scale_down_depth: 0.5,
            provisioning_delay_s: 5.0,
            decision_interval_s: 0.5,
            depth_window: 1,
        },
    )
    .expect("8B fits");
    // Heavy ramp then sparse tail: forces wake + sleep transitions.
    let mut reqs: Vec<Request> = (0..40)
        .map(|i| Request {
            id: i,
            arrival: i as f64 * 0.25,
            prompt_len: 2048,
            output_len: 256,
            class: fp8_tco::workload::trace::TenantClass::Interactive,
        })
        .collect();
    for i in 0..10 {
        reqs.push(Request {
            id: 40 + i,
            arrival: 15.0 + i as f64 * 5.0,
            prompt_len: 64,
            output_len: 8,
            class: fp8_tco::workload::trace::TenantClass::Interactive,
        });
    }
    assert!(c.run(reqs));
    assert!(c.scale_ups >= 1, "the ramp must wake a replica");
    assert!(c.scale_downs >= 1, "the tail must gate one back down");
    let end = c.makespan();
    let m = c.merged_metrics();
    assert!(m.gated_s > 0.0, "gating must appear on the ledger");
    for e in &c.engines {
        let em = &e.metrics;
        let covered = em.span + em.idle_s + em.gated_s;
        assert!(
            (covered - end).abs() <= 1e-9 * end.max(1.0),
            "span {} + idle {} + gated {} != makespan {end}",
            em.span,
            em.idle_s,
            em.gated_s
        );
        let split = em.energy_prefill_j + em.energy_decode_j + em.energy_idle_j;
        assert!(
            (em.energy_j - split).abs() <= 1e-9 * em.energy_j.max(1.0),
            "energy arms must tile the total"
        );
    }
    let engines = c.engines.len() as f64;
    let energy_from_mean = m.watts_mean() * engines * end;
    assert!(
        (energy_from_mean - m.energy_j).abs() <= 1e-9 * m.energy_j.max(1.0),
        "watts_mean x engines x makespan {energy_from_mean} != energy {}",
        m.energy_j
    );
}
