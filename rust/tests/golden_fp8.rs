//! Cross-language golden tests: the rust `fp8` module and the L1
//! Pallas emulation must agree bit-exactly, and the standalone FP8
//! GEMM artifact must reproduce python's output through PJRT.
//!
//! Requires `make artifacts`; tests skip (with a loud note) otherwise.

use fp8_tco::fp8::{quantize_rtn, Format};
use fp8_tco::runtime::{ArtifactDir, Executor};

fn artifacts() -> Option<ArtifactDir> {
    let dir = ArtifactDir::discover();
    if dir.exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn rust_quantizer_matches_python_bit_exactly() {
    let Some(dir) = artifacts() else { return };
    let golden = dir.golden("fp8_quantize.json").expect("golden vectors");
    let xs = golden.get("x").unwrap().as_f32_vec().unwrap();
    assert!(xs.len() > 500);
    for fmt in Format::ALL {
        let want = golden.get(fmt.name()).unwrap().as_f32_vec().unwrap();
        assert_eq!(xs.len(), want.len());
        let mut mismatches = 0;
        for (i, (&x, &w)) in xs.iter().zip(&want).enumerate() {
            let got = quantize_rtn(x, fmt);
            if got != w {
                mismatches += 1;
                if mismatches < 5 {
                    eprintln!("{}: x={x} rust={got} python={w} (idx {i})", fmt.name());
                }
            }
        }
        assert_eq!(mismatches, 0, "{}: {mismatches} mismatches", fmt.name());
    }
}

#[test]
fn gemm_artifact_reproduces_python_output_via_pjrt() {
    let Some(dir) = artifacts() else { return };
    let golden = dir.golden("fp8_gemm_io.json").expect("gemm golden");
    let m = golden.get("m").unwrap().as_usize().unwrap();
    let k = golden.get("k").unwrap().as_usize().unwrap();
    let n = golden.get("n").unwrap().as_usize().unwrap();
    let x = golden.get("x").unwrap().as_f32_vec().unwrap();
    let w = golden.get("w").unwrap().as_f32_vec().unwrap();
    let want = golden.get("y").unwrap().as_f32_vec().unwrap();

    let exec = Executor::cpu().expect("pjrt cpu client");
    let exe = exec
        .load(&dir.root.join("gemm").join(format!("fp8_gemm_{m}x{k}x{n}.hlo.txt")))
        .expect("compile gemm artifact");
    let xl = xla::Literal::vec1(&x).reshape(&[m as i64, k as i64]).unwrap();
    let wl = xla::Literal::vec1(&w).reshape(&[k as i64, n as i64]).unwrap();
    let out = exec.run(&exe, &[xl, wl]).expect("execute");
    assert_eq!(out.len(), 1);
    let got = out[0].to_vec::<f32>().unwrap();
    assert_eq!(got.len(), want.len());
    let mut max_rel = 0.0f32;
    for (&g, &w_) in got.iter().zip(&want) {
        let rel = (g - w_).abs() / w_.abs().max(1e-3);
        max_rel = max_rel.max(rel);
    }
    // Same HLO, same inputs: should be numerically identical up to
    // run-to-run nondeterminism in reductions (none on CPU).
    assert!(max_rel < 1e-5, "max rel err {max_rel}");
}

#[test]
fn rust_fp8_gemm_semantics_match_golden_inputs() {
    // Software check (no PJRT): quantize golden x/w with the rust fp8
    // module using the same per-row/per-column dynamic scheme, GEMM in
    // f64, and compare against python's kernel output with kernel-level
    // tolerance. Validates the shared FP8 semantics end to end.
    let Some(dir) = artifacts() else { return };
    let golden = dir.golden("fp8_gemm_io.json").expect("gemm golden");
    let m = golden.get("m").unwrap().as_usize().unwrap();
    let k = golden.get("k").unwrap().as_usize().unwrap();
    let n = golden.get("n").unwrap().as_usize().unwrap();
    let x = golden.get("x").unwrap().as_f32_vec().unwrap();
    let w = golden.get("w").unwrap().as_f32_vec().unwrap();
    let want = golden.get("y").unwrap().as_f32_vec().unwrap();
    let fmt = Format::E4M3FN;

    // column scales of w
    let mut sw = vec![0.0f32; n];
    for j in 0..n {
        let mut amax = 0.0f32;
        for i in 0..k {
            amax = amax.max(w[i * n + j].abs());
        }
        sw[j] = amax.max(1e-12) / fmt.max_finite();
    }
    // row scales of x
    let mut sx = vec![0.0f32; m];
    for i in 0..m {
        let mut amax = 0.0f32;
        for j in 0..k {
            amax = amax.max(x[i * k + j].abs());
        }
        sx[i] = amax.max(1e-12) / fmt.max_finite();
    }
    let xq: Vec<f32> = (0..m * k)
        .map(|idx| quantize_rtn(x[idx] / sx[idx / k], fmt))
        .collect();
    let wq: Vec<f32> = (0..k * n)
        .map(|idx| quantize_rtn(w[idx] / sw[idx % n], fmt))
        .collect();
    let mut max_rel = 0.0f64;
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f64;
            for kk in 0..k {
                acc += xq[i * k + kk] as f64 * wq[kk * n + j] as f64;
            }
            let y = acc * sx[i] as f64 * sw[j] as f64;
            let w_ = want[i * n + j] as f64;
            let rel = (y - w_).abs() / w_.abs().max(1e-3);
            max_rel = max_rel.max(rel);
        }
    }
    assert!(max_rel < 1e-4, "max rel err {max_rel}");
}
