//! Property tests for the interconnect-aware parallelism model:
//!
//! * TP=1/PP=1 is *exactly* the single-chip model the paper measures
//!   (no comm terms, seconds == sum of work parts);
//! * step time is monotonically non-increasing in TP while work
//!   dominates, and the comm-dominated U-turn exists;
//! * the PP bubble fraction equals the closed form
//!   `(pp-1)/(pp-1+microbatches)`;
//! * paper-anchored: communication overhead *shrinks* the
//!   Gaudi-vs-H100 deltas of Figs. 4–5 rather than inverting the
//!   single-chip conclusions.

use fp8_tco::analysis::parallel::ParallelismPlan;
use fp8_tco::analysis::perfmodel::{decode_step, prefill, PrecisionMode, StepConfig};
use fp8_tco::hwsim::spec::Device;
use fp8_tco::workload::llama::by_name;

fn cfg(dev: Device, prec: PrecisionMode) -> StepConfig {
    StepConfig::new(dev, prec)
}

#[test]
fn tp1_pp1_reproduces_single_chip_model_exactly() {
    // The no-comm baseline: every comm term is zero and the step is
    // exactly the sum of its single-chip work parts, for both phases
    // on both vendors.
    for dev in [Device::H100, Device::Gaudi2] {
        for prec in [PrecisionMode::Bf16, PrecisionMode::fp8_static()] {
            let m = by_name("llama-8b").unwrap();
            let d = decode_step(m, &cfg(dev, prec), 32, 1024);
            assert_eq!(d.t_tp_comm_s, 0.0);
            assert_eq!(d.t_pp_comm_s, 0.0);
            assert_eq!(d.pp_bubble_frac, 0.0);
            let sum = d.t_linears_s + d.t_attention_kv_s + d.t_softmax_s + d.t_lm_head_s;
            assert!(
                (sum / d.seconds - 1.0).abs() < 1e-12,
                "{} {}: decode {} != {}",
                dev.name(),
                prec.name(),
                sum,
                d.seconds
            );
            let p = prefill(m, &cfg(dev, prec), 1, 2048);
            assert_eq!(p.t_tp_comm_s, 0.0);
            let psum = p.t_linears_s + p.t_attention_kv_s + p.t_softmax_s + p.t_lm_head_s;
            assert!((psum / p.seconds - 1.0).abs() < 1e-12);
        }
    }
}

#[test]
fn explicit_plan_at_unit_shape_changes_nothing() {
    let m = by_name("llama-8b").unwrap();
    let base = decode_step(m, &cfg(Device::H100, PrecisionMode::fp8_dynamic()), 16, 512);
    let planned = decode_step(
        m,
        &cfg(Device::H100, PrecisionMode::fp8_dynamic()).with_plan(ParallelismPlan::single()),
        16,
        512,
    );
    assert_eq!(base.seconds.to_bits(), planned.seconds.to_bits());
}

#[test]
fn tp_beyond_one_shard_pays_collectives() {
    let m = by_name("llama-8b").unwrap();
    let d = decode_step(m, &cfg(Device::H100, PrecisionMode::fp8_dynamic()).with_tp(2), 32, 1024);
    assert!(d.t_tp_comm_s > 0.0);
    // seconds = work + comm: strictly more than the sum of work parts.
    let work = d.t_linears_s + d.t_attention_kv_s + d.t_softmax_s + d.t_lm_head_s;
    assert!((d.seconds - (work + d.t_tp_comm_s)).abs() < 1e-12 * d.seconds);
}

#[test]
fn tp_sweep_has_u_turn() {
    // Small model, batch 1: work shrinks ~1/tp while the ring's
    // latency term grows ~tp, so the sweep must dip and come back up.
    let m = by_name("llama-1b").unwrap();
    let tps = [1usize, 2, 4, 8, 16, 32];
    let secs: Vec<f64> = tps
        .iter()
        .map(|&tp| {
            decode_step(m, &cfg(Device::H100, PrecisionMode::fp8_static()).with_tp(tp), 1, 128)
                .seconds
        })
        .collect();
    let argmin = secs
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    assert!(argmin > 0, "sharding must help initially: {secs:?}");
    assert!(argmin < tps.len() - 1, "comm must eventually dominate: {secs:?}");
    // Monotone non-increasing up to the minimum...
    for i in 0..argmin {
        assert!(
            secs[i + 1] <= secs[i] * 1.001,
            "pre-min wiggle at tp{}: {secs:?}",
            tps[i + 1]
        );
    }
    // ...then monotone non-decreasing: the U-turn.
    for i in argmin..tps.len() - 1 {
        assert!(
            secs[i + 1] >= secs[i] * 0.999,
            "post-min dip at tp{}: {secs:?}",
            tps[i + 1]
        );
    }
    assert!(
        secs[tps.len() - 1] > secs[argmin] * 1.5,
        "comm-dominated tail must clearly exceed the optimum: {secs:?}"
    );
}

#[test]
fn tp_monotone_while_work_dominates_on_large_model() {
    // 70B decode at batch 64 is work-dominated through tp8 on both
    // fabrics: step time strictly decreases.
    for dev in [Device::H100, Device::Gaudi2] {
        let m = by_name("llama-70b").unwrap();
        let secs: Vec<f64> = [1usize, 2, 4, 8]
            .iter()
            .map(|&tp| {
                decode_step(m, &cfg(dev, PrecisionMode::fp8_static()).with_tp(tp), 64, 1024)
                    .seconds
            })
            .collect();
        for i in 0..secs.len() - 1 {
            assert!(
                secs[i + 1] < secs[i],
                "{}: tp{} not faster: {secs:?}",
                dev.name(),
                [1, 2, 4, 8][i + 1]
            );
        }
    }
}

#[test]
fn pp_bubble_fraction_matches_closed_form() {
    let m = by_name("llama-8b").unwrap();
    for pp in [2usize, 4, 8] {
        for mb in [1usize, 2, 4, 8, 16] {
            let bd = decode_step(
                m,
                &cfg(Device::H100, PrecisionMode::fp8_dynamic())
                    .with_pp(pp)
                    .with_microbatches(mb),
                32,
                1024,
            );
            let mb_eff = mb.min(32); // clamped to the batch
            let expect = (pp - 1) as f64 / (pp - 1 + mb_eff) as f64;
            assert!(
                (bd.pp_bubble_frac - expect).abs() < 1e-12,
                "pp{pp} mb{mb}: {} != {expect}",
                bd.pp_bubble_frac
            );
            assert!(bd.t_pp_comm_s > 0.0);
        }
    }
}

#[test]
fn pp_microbatching_pipelines_prefill_but_not_decode() {
    // The phase asymmetry the thin-GEMM thesis predicts: prefill is
    // compute-bound, so microbatches pipeline and the step speeds up;
    // decode is weight-streaming bound, so every extra microbatch
    // re-streams the weights and the step can only get slower.
    let m = by_name("llama-8b").unwrap();
    let pre = |mb: usize| {
        prefill(
            m,
            &cfg(Device::H100, PrecisionMode::fp8_static())
                .with_pp(4)
                .with_microbatches(mb),
            1,
            4096,
        )
    };
    let pre_coarse = pre(1);
    let pre_fine = pre(8);
    assert!(pre_fine.pp_bubble_frac < pre_coarse.pp_bubble_frac);
    assert!(
        pre_fine.seconds < pre_coarse.seconds,
        "prefill must pipeline: {} vs {}",
        pre_fine.seconds,
        pre_coarse.seconds
    );

    let dec = |mb: usize| {
        decode_step(
            m,
            &cfg(Device::H100, PrecisionMode::fp8_dynamic())
                .with_pp(4)
                .with_microbatches(mb),
            32,
            1024,
        )
    };
    let dec_coarse = dec(1);
    let dec_fine = dec(16);
    assert!(
        dec_fine.seconds > dec_coarse.seconds,
        "decode microbatches re-stream weights: {} vs {}",
        dec_fine.seconds,
        dec_coarse.seconds
    );
    // With one microbatch the pipeline is fully serialized: no faster
    // than the unsharded step (the bubble eats the parallelism).
    let single = decode_step(m, &cfg(Device::H100, PrecisionMode::fp8_dynamic()), 32, 1024);
    assert!(dec_coarse.seconds >= single.seconds * 0.999);
    // The bubble fraction itself still vanishes with depth regardless
    // of phase — it is pure pipeline geometry.
    let deep = dec(32);
    assert!(deep.pp_bubble_frac < 0.10, "{}", deep.pp_bubble_frac);
}

#[test]
fn pp_stages_outside_scale_up_domain_pay_scale_out() {
    let m = by_name("llama-70b").unwrap();
    let mk = |tp: usize, pp: usize| {
        decode_step(
            m,
            &cfg(Device::H100, PrecisionMode::fp8_dynamic())
                .with_tp(tp)
                .with_pp(pp)
                .with_microbatches(4),
            32,
            1024,
        )
    };
    // 8 chips fit the NVSwitch domain; 16 chips force the pipeline
    // hop onto the scale-out NIC.
    let inside = mk(4, 2);
    let outside = mk(8, 2);
    assert!(outside.t_pp_comm_s > inside.t_pp_comm_s * 2.0,
            "{} vs {}", outside.t_pp_comm_s, inside.t_pp_comm_s);
}

#[test]
fn comm_shrinks_gaudi_decode_advantage_without_inverting_fig5() {
    // Fig. 5 / §5.4 single-chip conclusion: Gaudi 2 + FP8 decodes
    // competitively with the H100 (step-time ratio < 1.3). NVLink
    // outclasses the on-die RoCE fabric, so TP sharding erodes the
    // Gaudi side — the delta shrinks toward (and past) parity — but
    // must not explode into an inversion of the competitiveness claim.
    let m = by_name("llama-8b").unwrap();
    let ratio = |tp: usize| {
        let g = decode_step(m, &cfg(Device::Gaudi2, PrecisionMode::fp8_static()).with_tp(tp), 64, 1024);
        let h = decode_step(m, &cfg(Device::H100, PrecisionMode::fp8_dynamic()).with_tp(tp), 64, 1024);
        g.seconds / h.seconds
    };
    let r1 = ratio(1);
    let r4 = ratio(4);
    let r8 = ratio(8);
    assert!(r1 < 1.3, "single-chip competitiveness is the premise: {r1}");
    // The fabric gap costs Gaudi relative ground at scale...
    assert!(r4 >= r1 - 0.02, "tp4 must not flatter Gaudi: {r1} -> {r4}");
    assert!(r8 > r1, "at tp8 the RoCE fabric must show: {r1} -> {r8}");
    // ...but never inverts the conclusion: Gaudi stays in contention.
    assert!(r4 < 1.3, "tp4 inverts Fig. 5: {r4}");
    assert!(r8 < 1.6, "tp8 explodes the delta: {r8}");
}

#[test]
fn prefill_fig4_conclusion_survives_sharding() {
    // Fig. 4: H100 reaches ~2x Gaudi 2 prefill TFLOPS on 8B. With
    // TP=4 both pay collectives; the ratio stays in the same regime.
    let m = by_name("llama-8b").unwrap();
    let h = prefill(m, &cfg(Device::H100, PrecisionMode::fp8_static()).with_tp(4), 1, 4096);
    let g = prefill(m, &cfg(Device::Gaudi2, PrecisionMode::fp8_static()).with_tp(4), 1, 4096);
    let ratio = h.tflops() / g.tflops();
    assert!(ratio > 1.2 && ratio < 3.2, "tp4 prefill ratio {ratio}");
}

#[test]
fn seventy_b_sharded_decode_meets_interactive_tpot() {
    // The deployment the single-chip model could not express: 70B at
    // TP8 on one NVSwitch domain decodes a 32-batch step well under
    // the 50 ms interactive TPOT budget.
    let m = by_name("llama-70b").unwrap();
    let bd = decode_step(m, &cfg(Device::H100, PrecisionMode::fp8_dynamic()).with_tp(8), 32, 1024);
    assert!(bd.seconds < 0.050, "tp8 70B decode step {}", bd.seconds);
    // Per-chip FLOPs account for the sharding.
    let single_equiv = decode_step(m, &cfg(Device::H100, PrecisionMode::fp8_dynamic()), 32, 1024);
    assert!((single_equiv.flops / bd.flops - 8.0).abs() < 1e-9);
}
