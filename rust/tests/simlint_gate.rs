//! The blocking lint gate (tier 1): the merged tree must carry zero
//! unwaived simlint findings, and every waiver must carry a reason.
//!
//! This is the same check `cargo run --bin simlint` performs in CI,
//! run in-process so `cargo test` alone enforces the policy.

use std::path::PathBuf;

use fp8_tco::simlint::{check_tree, Finding};

fn tree() -> Vec<Finding> {
    check_tree(&PathBuf::from(env!("CARGO_MANIFEST_DIR")))
}

#[test]
fn tree_has_no_unwaived_findings() {
    let unwaived: Vec<Finding> =
        tree().into_iter().filter(|f| f.waived.is_none()).collect();
    let listing: String = unwaived
        .iter()
        .map(|f| format!("  {}:{}: [{}] {}\n", f.file, f.line, f.rule.name(), f.msg))
        .collect();
    assert!(
        unwaived.is_empty(),
        "simlint found {} unwaived finding(s):\n{listing}\
         fix the code or add `// simlint: allow(<rule>) -- <reason>`",
        unwaived.len()
    );
}

#[test]
fn every_waiver_carries_a_reason() {
    let waived: Vec<Finding> =
        tree().into_iter().filter(|f| f.waived.is_some()).collect();
    // The tree is expected to carry at least the pjrt backend's
    // wall-clock waiver — an empty inventory means the waiver parser
    // silently broke, not that the tree got cleaner.
    assert!(
        waived
            .iter()
            .any(|f| f.file == "src/coordinator/pjrt_backend.rs"),
        "expected the pjrt_backend wall-clock waiver in the inventory; got: {:?}",
        waived.iter().map(|f| &f.file).collect::<Vec<_>>()
    );
    for f in &waived {
        let reason = f.waived.as_deref().unwrap_or_default();
        assert!(
            !reason.is_empty() && reason != "(no reason given)",
            "{}:{} [{}] is waived without a reason",
            f.file,
            f.line,
            f.rule.name()
        );
    }
}
