//! simlint self-tests: every rule family exercised in both directions
//! (a fixture that must fire, and a near-identical one that must not),
//! plus the lexer-immunity cases — rule-looking text inside string
//! literals, raw strings, and doc comments must never fire.

use fp8_tco::simlint::{check_file, Rule};

/// Unwaived rule hits for a fixture.
fn active(rel: &str, src: &str) -> Vec<Rule> {
    check_file(rel, src)
        .into_iter()
        .filter(|f| f.waived.is_none())
        .map(|f| f.rule)
        .collect()
}

fn fires(rel: &str, src: &str, rule: Rule) -> bool {
    active(rel, src).contains(&rule)
}

// ---------------------------------------------------------------- determinism

#[test]
fn determinism_denies_wall_clock_anywhere() {
    let src = "fn f() { let t = std::time::Instant::now(); }";
    assert!(fires("src/hwsim/gemm.rs", src, Rule::Determinism));
    assert!(fires("benches/foo.rs", src, Rule::Determinism));
    assert!(fires("src/coordinator/engine.rs", "use std::time::SystemTime;", Rule::Determinism));
}

#[test]
fn determinism_waiver_suppresses_wall_clock() {
    let src = "// simlint: allow(determinism) -- measurement harness\n\
               fn f() { let t = std::time::Instant::now(); }";
    assert!(!fires("src/hwsim/gemm.rs", src, Rule::Determinism));
    // ...and the waived finding is still inventoried.
    let all = check_file("src/hwsim/gemm.rs", src);
    assert!(all.iter().any(|f| f.waived.as_deref() == Some("measurement harness")));
}

#[test]
fn determinism_denies_rng_outside_util_rng() {
    let src = "fn f() { let mut r = thread_rng(); }";
    assert!(fires("src/analysis/foo.rs", src, Rule::Determinism));
    assert!(fires("src/coordinator/engine.rs", "use rand::Rng;", Rule::Determinism));
    // The seeded substrate itself is the one legitimate home.
    assert!(!fires("src/util/rng.rs", src, Rule::Determinism));
}

#[test]
fn determinism_denies_hash_iteration_in_coordinator() {
    let src = "struct S { m: HashMap<u64, u32> }\n\
               impl S { fn f(&self) { for v in self.m.values() { drop(v); } } }";
    assert!(fires("src/coordinator/foo.rs", src, Rule::Determinism));
    let for_loop = "fn f(m: &HashMap<u64, u32>) { for v in m { drop(v); } }";
    assert!(fires("src/coordinator/foo.rs", for_loop, Rule::Determinism));
}

#[test]
fn determinism_allows_hash_iteration_outside_coordinator() {
    let src = "struct S { m: HashMap<u64, u32> }\n\
               impl S { fn f(&self) { for v in self.m.values() { drop(v); } } }";
    assert!(!fires("src/analysis/foo.rs", src, Rule::Determinism));
}

#[test]
fn determinism_allows_point_lookups_on_hash_maps() {
    let src = "struct S { m: HashMap<u64, u32> }\n\
               impl S { fn f(&self) -> Option<&u32> { self.m.get(&1) } }";
    assert!(!fires("src/coordinator/foo.rs", src, Rule::Determinism));
}

// --------------------------------------------------------------------- units

#[test]
fn units_denies_bare_f64_param_in_scoped_file() {
    let src = "pub fn f(x: f64) -> usize { x as usize }";
    assert!(fires("src/tco/fake.rs", src, Rule::Units));
    assert!(fires("src/analysis/perfmodel.rs", src, Rule::Units));
}

#[test]
fn units_does_not_apply_outside_scoped_files() {
    let src = "pub fn f(x: f64) -> usize { x as usize }";
    assert!(!fires("src/hwsim/gemm.rs", src, Rule::Units));
}

#[test]
fn units_accepts_suffixed_names() {
    let src = "pub struct A { pub draw_w: f64, pub cost_usd: f64 }\n\
               pub fn total_s(x_s: f64) -> f64 { x_s }\n\
               pub fn cost_per_mtok(tokens: f64) -> f64 { tokens }";
    assert!(!fires("src/tco/fake.rs", src, Rule::Units));
}

#[test]
fn units_denies_unsuffixed_pub_field_and_return() {
    let field = "pub struct A { pub power: f64 }";
    assert!(fires("src/tco/fake.rs", field, Rule::Units));
    let ret = "pub fn compute() -> f64 { 1.0 }";
    assert!(fires("src/tco/fake.rs", ret, Rule::Units));
}

#[test]
fn units_ignores_private_and_non_f64_surfaces() {
    let src = "struct A { power: f64 }\n\
               fn helper(x: f64) -> f64 { x }\n\
               pub fn count(n: usize) -> usize { n }";
    assert!(!fires("src/tco/fake.rs", src, Rule::Units));
}

// ------------------------------------------------------------------ unit-mix

#[test]
fn unit_mix_denies_cross_unit_addition() {
    let src = "fn f(a_s: f64, b_w: f64) -> f64 { a_s + b_w }";
    assert!(fires("src/hwsim/power.rs", src, Rule::UnitMix));
    let sub = "fn f(t_s: f64, e_j: f64) -> f64 { t_s - e_j }";
    assert!(fires("src/hwsim/interconnect.rs", sub, Rule::UnitMix));
}

#[test]
fn unit_mix_accepts_same_class_and_products() {
    // Same class (s + seconds) is fine.
    let same = "fn f(a_s: f64, b_seconds: f64) -> f64 { a_s + b_seconds }";
    assert!(!fires("src/hwsim/power.rs", same, Rule::UnitMix));
    // A quotient result added to a latency is dimensionally sane:
    // `bytes / bw + lat_s` must not fire.
    let closed_form = "fn f(n_bytes: f64, bw: f64, lat_s: f64) -> f64 { n_bytes / bw + lat_s }";
    assert!(!fires("src/hwsim/interconnect.rs", closed_form, Rule::UnitMix));
    // Products on either side opt out too.
    let scaled = "fn f(p_w: f64, t_s: f64, e_j: f64) -> f64 { e_j + p_w * t_s }";
    assert!(!fires("src/hwsim/power.rs", scaled, Rule::UnitMix));
}

// --------------------------------------------------------------------- panic

#[test]
fn panic_denies_unwrap_on_hot_path() {
    let src = "fn f(o: Option<u32>) -> u32 { o.unwrap() }";
    assert!(fires("src/coordinator/engine.rs", src, Rule::Panic));
    let exp = "fn f(o: Option<u32>) -> u32 { o.expect(\"x\") }";
    assert!(fires("src/coordinator/batcher.rs", exp, Rule::Panic));
    let mac = "fn f() { panic!(\"boom\") }";
    assert!(fires("src/coordinator/router.rs", mac, Rule::Panic));
}

#[test]
fn panic_policy_scopes_to_hot_path_files_only() {
    let src = "fn f(o: Option<u32>) -> u32 { o.unwrap() }";
    assert!(!fires("src/coordinator/metrics.rs", src, Rule::Panic));
    assert!(!fires("src/workload/llama.rs", src, Rule::Panic));
}

#[test]
fn panic_allows_cfg_test_and_asserts() {
    let test_mod = "#[cfg(test)]\nmod tests {\n    fn f(o: Option<u32>) -> u32 { o.unwrap() }\n}";
    assert!(!fires("src/coordinator/engine.rs", test_mod, Rule::Panic));
    let audits = "fn f(x: usize) { assert!(x > 0); debug_assert!(x < 10, \"bound\"); }";
    assert!(!fires("src/coordinator/engine.rs", audits, Rule::Panic));
}

#[test]
fn panic_waiver_with_reason_is_honored_and_inventoried() {
    let src = "fn f(o: Option<u32>) -> u32 {\n\
               // simlint: allow(panic) -- init-time invariant\n\
               o.unwrap()\n\
               }";
    let all = check_file("src/coordinator/engine.rs", src);
    assert_eq!(all.len(), 1);
    assert_eq!(all[0].waived.as_deref(), Some("init-time invariant"));
}

#[test]
fn multi_rule_waiver_covers_both_rules() {
    let src = "// simlint: allow(panic,determinism) -- probe\n\
               fn f() { std::time::Instant::now().elapsed().as_secs_f64(); }";
    // determinism waived on the next line; nothing else fires.
    assert!(active("src/coordinator/engine.rs", src).is_empty());
}

// ------------------------------------------------------------ lexer immunity

#[test]
fn rule_text_in_string_literals_does_not_fire() {
    let src = r#"fn f() -> &'static str { "Instant::now().unwrap() panic!" }"#;
    assert!(active("src/coordinator/engine.rs", src).is_empty());
}

#[test]
fn rule_text_in_raw_strings_does_not_fire() {
    let src = "fn f() -> &'static str { r#\"std::time::SystemTime thread_rng() .expect(\"#  }";
    assert!(active("src/coordinator/engine.rs", src).is_empty());
}

#[test]
fn rule_text_in_doc_comments_does_not_fire() {
    let src = "/// Calls `.unwrap()` on an `Instant` from `thread_rng()`.\n\
               /* block: panic! std::time */\n\
               fn f() {}";
    assert!(active("src/coordinator/engine.rs", src).is_empty());
}

#[test]
fn range_expressions_survive_the_lexer() {
    // `0..n` must lex as number, dot, dot, ident — not eat the range
    // dots into a float and desync the token stream.
    let src = "fn f(n: usize) { for i in 0..n { drop(i); } }";
    assert!(active("src/coordinator/engine.rs", src).is_empty());
}
