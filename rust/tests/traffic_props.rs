//! Property tests over the non-stationary traffic layer
//! (`workload::trace`): realized arrival counts must conserve the
//! [`RateCurve`] integral window by window (thinning produces the
//! right *time structure*, not just the right total), MMPP must hit
//! its sojourn-weighted mean rate while staying overdispersed, and
//! the multi-tenant mix must stamp classes at the configured fraction
//! with each class's own length distribution.
//!
//! All tolerances are sigma-scaled for the fixed seeds used here —
//! generous enough to be draw-stable, tight enough that a broken
//! thinning envelope or a dropped state transition fails loudly.

use fp8_tco::workload::trace::{
    ArrivalProcess, RateCurve, TenantClass, TrafficConfig, TrafficGenerator,
};

#[test]
fn modulated_counts_conserve_the_curve_integral() {
    let day_s = 3600.0;
    let curve = RateCurve::diurnal(day_s, 2.0, 12.0);
    let cfg = TrafficConfig::chat_on(ArrivalProcess::Modulated(curve.clone()));
    let reqs = TrafficGenerator::new(cfg, 41).until(day_s);
    // Whole-day conservation: the realized count sits within a few
    // sigma of the exact integral (Poisson sigma = sqrt(mean)).
    let expected = curve.expected_arrivals(0.0, day_s);
    let got = reqs.len() as f64;
    assert!(
        (got - expected).abs() <= 5.0 * expected.sqrt(),
        "day count {got} vs integral {expected}"
    );
    // Window by window: each 10-minute bucket tracks its own slice of
    // the integral.
    let mut bucket_counts = [0.0f64; 6];
    for r in &reqs {
        bucket_counts[((r.arrival / day_s * 6.0) as usize).min(5)] += 1.0;
    }
    for (k, &n) in bucket_counts.iter().enumerate() {
        let (t0, t1) = (day_s * k as f64 / 6.0, day_s * (k + 1) as f64 / 6.0);
        let e = curve.expected_arrivals(t0, t1);
        assert!(
            (n - e).abs() <= 5.0 * e.sqrt() + 5.0,
            "bucket {k}: {n} arrivals vs integral {e}"
        );
    }
    // And the shape is actually diurnal: the bucket holding the peak
    // (16/24 of the day) out-draws the one holding the trough (4/24).
    assert!(
        bucket_counts[4] > 2.0 * bucket_counts[0],
        "peak bucket {} vs trough bucket {}",
        bucket_counts[4],
        bucket_counts[0]
    );
}

#[test]
fn mmpp_hits_its_sojourn_weighted_mean_and_stays_bursty() {
    let process = ArrivalProcess::Mmpp {
        base_qps: 2.0,
        burst_qps: 20.0,
        mean_base_s: 30.0,
        mean_burst_s: 5.0,
    };
    let mean = process.mean_qps();
    assert!((mean - 160.0 / 35.0).abs() < 1e-12, "sojourn-weighted mean: {mean}");
    let horizon_s = 20_000.0;
    let reqs = TrafficGenerator::new(TrafficConfig::chat_on(process), 7).until(horizon_s);
    let rate = reqs.len() as f64 / horizon_s;
    assert!(
        (rate / mean - 1.0).abs() < 0.15,
        "long-run rate {rate} vs sojourn-weighted mean {mean}"
    );
    // Overdispersion: the index of dispersion of bucket counts sits
    // far above Poisson's 1 — the reason MMPP is in the model at all.
    // (These sojourns mix ~40/bucket base with ~400/bucket burst, so
    // the index lands in the hundreds; 1.5 is a loose floor.)
    let bucket_s = 20.0;
    let n_buckets = (horizon_s / bucket_s) as usize;
    let mut counts = vec![0.0f64; n_buckets];
    for r in &reqs {
        counts[((r.arrival / bucket_s) as usize).min(n_buckets - 1)] += 1.0;
    }
    let m = counts.iter().sum::<f64>() / n_buckets as f64;
    let var = counts.iter().map(|c| (c - m) * (c - m)).sum::<f64>() / n_buckets as f64;
    assert!(var / m > 1.5, "dispersion index {} — not bursty", var / m);
}

#[test]
fn multi_tenant_mix_stamps_classes_and_length_mixes() {
    let day_s = 2_000.0;
    let flat = RateCurve::new(vec![(0.0, 5.0), (day_s, 5.0)]);
    let cfg = TrafficConfig::multi_tenant(ArrivalProcess::Modulated(flat), 0.3);
    let reqs = TrafficGenerator::new(cfg, 11).until(day_s);
    assert!(reqs.len() > 8_000, "need a real sample: {}", reqs.len());
    let batch: Vec<_> = reqs.iter().filter(|r| r.class == TenantClass::Batch).collect();
    let interactive: Vec<_> =
        reqs.iter().filter(|r| r.class == TenantClass::Interactive).collect();
    assert_eq!(batch.len() + interactive.len(), reqs.len());
    let frac = batch.len() as f64 / reqs.len() as f64;
    assert!((frac - 0.3).abs() < 0.03, "batch fraction {frac} vs configured 0.3");
    // Each class carries its own length mix: summarize-shaped batch
    // prompts dwarf chat-shaped interactive ones (median ~2440 vs
    // ~245), and the output skew points the other way.
    let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len() as f64;
    let b_prompt = mean(&batch.iter().map(|r| r.prompt_len as f64).collect::<Vec<_>>());
    let i_prompt =
        mean(&interactive.iter().map(|r| r.prompt_len as f64).collect::<Vec<_>>());
    assert!(
        b_prompt > 4.0 * i_prompt,
        "batch prompts {b_prompt} not summarize-shaped vs interactive {i_prompt}"
    );
    let b_out = mean(&batch.iter().map(|r| r.output_len as f64).collect::<Vec<_>>());
    let i_out =
        mean(&interactive.iter().map(|r| r.output_len as f64).collect::<Vec<_>>());
    assert!(
        i_out > 1.5 * b_out,
        "interactive outputs {i_out} not chat-shaped vs batch {b_out}"
    );
}

#[test]
fn zero_rate_tail_terminates_instead_of_spinning() {
    // A curve that ramps to zero and stays there: thinning can never
    // accept a candidate past the ramp, so the generator must park
    // the arrival at +inf (and `until` must return) rather than spin
    // the rejection loop forever.
    let curve = RateCurve::new(vec![(0.0, 6.0), (50.0, 0.0)]);
    assert!(!curve.is_zero_after(0.0));
    assert!(!curve.is_zero_after(49.9));
    assert!(curve.is_zero_after(50.0));
    assert!(curve.is_zero_after(1e9));
    let cfg = TrafficConfig::chat_on(ArrivalProcess::Modulated(curve.clone()));
    let reqs = TrafficGenerator::new(cfg, 13).until(1e12);
    assert!(!reqs.is_empty(), "the positive ramp must produce arrivals");
    assert!(reqs.iter().all(|r| r.arrival.is_finite() && r.arrival < 51.0));
    let expected = curve.expected_arrivals(0.0, 50.0);
    let got = reqs.len() as f64;
    assert!(
        (got - expected).abs() <= 5.0 * expected.sqrt() + 5.0,
        "ramp count {got} vs integral {expected}"
    );
    // An interior zero-rate valley is NOT a tail: the generator must
    // coast through it and keep producing arrivals on the far side.
    let valley =
        RateCurve::new(vec![(0.0, 6.0), (10.0, 0.0), (20.0, 0.0), (30.0, 6.0)]);
    assert!(!valley.is_zero_after(15.0), "positive rate ahead of the valley");
    let cfg = TrafficConfig::chat_on(ArrivalProcess::Modulated(valley));
    let reqs = TrafficGenerator::new(cfg, 13).until(60.0);
    assert!(
        reqs.iter().any(|r| r.arrival > 30.0),
        "arrivals must resume past the valley"
    );
    assert!(
        !reqs.iter().any(|r| r.arrival > 10.5 && r.arrival < 19.5),
        "no arrivals inside the zero-rate valley"
    );
}

#[test]
fn mmpp_with_equal_rates_degenerates_to_poisson() {
    // Equal-rate states make the modulation invisible: the process is
    // plain Poisson, so the dispersion index of bucket counts must
    // sit near 1 (the same statistic the bursty test pushes past 1.5).
    let process = ArrivalProcess::Mmpp {
        base_qps: 8.0,
        burst_qps: 8.0,
        mean_base_s: 30.0,
        mean_burst_s: 5.0,
    };
    assert!((process.mean_qps() - 8.0).abs() < 1e-12);
    let horizon_s = 20_000.0;
    let reqs = TrafficGenerator::new(TrafficConfig::chat_on(process), 29).until(horizon_s);
    let rate = reqs.len() as f64 / horizon_s;
    assert!((rate / 8.0 - 1.0).abs() < 0.05, "long-run rate {rate} vs 8");
    let bucket_s = 20.0;
    let n_buckets = (horizon_s / bucket_s) as usize;
    let mut counts = vec![0.0f64; n_buckets];
    for r in &reqs {
        counts[((r.arrival / bucket_s) as usize).min(n_buckets - 1)] += 1.0;
    }
    let m = counts.iter().sum::<f64>() / n_buckets as f64;
    let var = counts.iter().map(|c| (c - m) * (c - m)).sum::<f64>() / n_buckets as f64;
    let dispersion = var / m;
    assert!(
        (dispersion - 1.0).abs() < 0.25,
        "equal-rate MMPP dispersion {dispersion} should be ~Poisson"
    );
}

#[test]
fn zero_share_class_never_appears_in_the_mix() {
    // batch_frac at the boundaries: 0 must stamp everything
    // interactive, 1 must stamp everything batch — no stray draws
    // from the other class's length mix.
    let mk = |frac: f64| {
        let flat = RateCurve::flat(10.0);
        let cfg = TrafficConfig::multi_tenant(ArrivalProcess::Modulated(flat), frac);
        TrafficGenerator::new(cfg, 17).until(200.0)
    };
    let all_interactive = mk(0.0);
    assert!(all_interactive.len() > 1_000);
    assert!(all_interactive.iter().all(|r| r.class == TenantClass::Interactive));
    let all_batch = mk(1.0);
    assert!(all_batch.len() > 1_000);
    assert!(all_batch.iter().all(|r| r.class == TenantClass::Batch));
    // The zero-share class's absence shows in the lengths too: the
    // all-batch trace is summarize-shaped (prompt-heavy), the
    // all-interactive one chat-shaped.
    let mean_prompt = |rs: &[fp8_tco::workload::trace::Request]| {
        rs.iter().map(|r| r.prompt_len as f64).sum::<f64>() / rs.len() as f64
    };
    assert!(mean_prompt(&all_batch) > 4.0 * mean_prompt(&all_interactive));
}

#[test]
fn until_is_sorted_with_contiguous_ids() {
    let cfg = TrafficConfig::multi_tenant(
        ArrivalProcess::Mmpp {
            base_qps: 3.0,
            burst_qps: 15.0,
            mean_base_s: 20.0,
            mean_burst_s: 4.0,
        },
        0.5,
    );
    let horizon_s = 500.0;
    let reqs = TrafficGenerator::new(cfg, 3).until(horizon_s);
    assert!(!reqs.is_empty());
    for (k, r) in reqs.iter().enumerate() {
        assert_eq!(r.id, k as u64, "ids are arrival-ordered");
        assert!(r.arrival < horizon_s, "horizon bounds every arrival");
        if k > 0 {
            assert!(r.arrival >= reqs[k - 1].arrival, "timestamps sorted");
        }
    }
}
