//! PJRT runtime: loads AOT HLO-text artifacts and executes them on the
//! CPU PJRT client (`xla` crate). Python never runs here — artifacts
//! are produced once by `make artifacts`.

pub mod artifacts;
pub mod executor;

pub use artifacts::{ArtifactDir, ModelMeta};
pub use executor::{Executor, LoadedModel};
