//! Artifact discovery + metadata (artifacts/ directory layout is
//! defined by python/compile/aot.py).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Parsed `model/<tier>/meta.json`.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub tier: String,
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
    /// (batch, seq) pairs with a prefill executable.
    pub prefill_shapes: Vec<(usize, usize)>,
    /// batch sizes with a decode executable.
    pub decode_batches: Vec<usize>,
    pub precision: String,
}

impl ModelMeta {
    pub fn parse(text: &str) -> Result<ModelMeta> {
        let j = Json::parse(text).context("meta.json parse")?;
        let get = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("meta.json missing {k}"))
        };
        let prefill_shapes = j
            .get("prefill_shapes")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing prefill_shapes"))?
            .iter()
            .map(|p| {
                Ok((
                    p.idx(0).and_then(Json::as_usize).ok_or_else(|| anyhow!("bad shape"))?,
                    p.idx(1).and_then(Json::as_usize).ok_or_else(|| anyhow!("bad shape"))?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        let decode_batches = j
            .get("decode_batches")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing decode_batches"))?
            .iter()
            .map(|p| p.as_usize().ok_or_else(|| anyhow!("bad batch")))
            .collect::<Result<Vec<_>>>()?;
        Ok(ModelMeta {
            tier: j
                .get("tier")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
            vocab: get("vocab")?,
            hidden: get("hidden")?,
            layers: get("layers")?,
            heads: get("heads")?,
            kv_heads: get("kv_heads")?,
            head_dim: get("head_dim")?,
            max_seq: get("max_seq")?,
            prefill_shapes,
            decode_batches,
            precision: j
                .get("precision")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
        })
    }

    /// Smallest exported prefill batch that fits `n` sequences of
    /// length <= seq.
    pub fn prefill_bucket(&self, n: usize, seq: usize) -> Option<(usize, usize)> {
        self.prefill_shapes
            .iter()
            .copied()
            .filter(|&(b, s)| b >= n && s >= seq)
            .min_by_key(|&(b, s)| (b, s))
    }

    /// Smallest exported decode batch >= n.
    pub fn decode_bucket(&self, n: usize) -> Option<usize> {
        self.decode_batches.iter().copied().filter(|&b| b >= n).min()
    }
}

/// Locator for the artifacts directory.
#[derive(Debug, Clone)]
pub struct ArtifactDir {
    pub root: PathBuf,
}

impl ArtifactDir {
    pub fn new(root: impl AsRef<Path>) -> Self {
        ArtifactDir { root: root.as_ref().to_path_buf() }
    }

    /// Default location: $FP8_TCO_ARTIFACTS or ./artifacts.
    pub fn discover() -> Self {
        let root = std::env::var("FP8_TCO_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"));
        ArtifactDir { root }
    }

    pub fn exists(&self) -> bool {
        self.root.join(".stamp").exists()
    }

    pub fn model_dir(&self, tier: &str) -> PathBuf {
        self.root.join("model").join(tier)
    }

    pub fn meta(&self, tier: &str) -> Result<ModelMeta> {
        let path = self.model_dir(tier).join("meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        ModelMeta::parse(&text)
    }

    pub fn prefill_hlo(&self, tier: &str, batch: usize, seq: usize) -> PathBuf {
        self.model_dir(tier).join(format!("prefill_b{batch}_s{seq}.hlo.txt"))
    }

    pub fn decode_hlo(&self, tier: &str, batch: usize) -> PathBuf {
        self.model_dir(tier).join(format!("decode_b{batch}.hlo.txt"))
    }

    pub fn golden(&self, name: &str) -> Result<Json> {
        let path = self.root.join("golden").join(name);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Ok(Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const META: &str = r#"{
        "tier": "1b", "vocab": 256, "hidden": 64, "layers": 2,
        "heads": 4, "kv_heads": 2, "head_dim": 16, "intermediate": 172,
        "max_seq": 128, "prefill_shapes": [[1, 32], [2, 32], [4, 32], [8, 32]],
        "decode_batches": [1, 2, 4, 8],
        "precision": "fp8_e4m3fn_dynamic_rowwise", "param_count": 12345
    }"#;

    #[test]
    fn parses_meta() {
        let m = ModelMeta::parse(META).unwrap();
        assert_eq!(m.tier, "1b");
        assert_eq!(m.hidden, 64);
        assert_eq!(m.prefill_shapes.len(), 4);
        assert_eq!(m.decode_batches, vec![1, 2, 4, 8]);
    }

    #[test]
    fn bucket_selection() {
        let m = ModelMeta::parse(META).unwrap();
        assert_eq!(m.prefill_bucket(3, 20), Some((4, 32)));
        assert_eq!(m.prefill_bucket(1, 32), Some((1, 32)));
        assert_eq!(m.prefill_bucket(9, 32), None);
        assert_eq!(m.decode_bucket(3), Some(4));
        assert_eq!(m.decode_bucket(8), Some(8));
        assert_eq!(m.decode_bucket(9), None);
    }

    #[test]
    fn rejects_malformed_meta() {
        assert!(ModelMeta::parse("{}").is_err());
        assert!(ModelMeta::parse("not json").is_err());
    }

    #[test]
    fn paths_layout() {
        let d = ArtifactDir::new("/tmp/a");
        assert_eq!(
            d.prefill_hlo("1b", 4, 32),
            PathBuf::from("/tmp/a/model/1b/prefill_b4_s32.hlo.txt")
        );
        assert_eq!(d.decode_hlo("1b", 2), PathBuf::from("/tmp/a/model/1b/decode_b2.hlo.txt"));
    }
}
