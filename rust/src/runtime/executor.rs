//! PJRT executor: compile HLO-text artifacts once, execute many times.
//!
//! Follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO *text* is the interchange format
//! (jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//! rejects in proto form).

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use super::artifacts::{ArtifactDir, ModelMeta};

/// Process-wide PJRT serialization: xla_extension 0.5.1's CPU path is
/// not safe under concurrent use from multiple clients/threads in one
/// process (observed: corrupted result buffers / NaN logits). Hold
/// this guard around any sequence of xla calls (literal creation,
/// compile, execute, transfer). `Executor` methods do NOT lock
/// internally (a non-reentrant Mutex would deadlock callers that need
/// to span several calls) — callers serialize at their level.
pub fn pjrt_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::OnceLock<Mutex<()>> = std::sync::OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap()
}

/// Thread-safe PJRT CPU client + executable cache.
pub struct Executor {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl Executor {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;
        Ok(Executor { client, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an HLO-text file.
    pub fn load(&self, path: &Path) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        let key = path.to_string_lossy().to_string();
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| anyhow!("parse hlo {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
        let exe = Arc::new(exe);
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Execute with literal inputs; returns the flattened output tuple.
    pub fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True.
        lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))
    }

    pub fn cached_executables(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

/// A serving model: compiled prefill/decode executables per bucket.
pub struct LoadedModel {
    pub meta: ModelMeta,
    exec: Arc<Executor>,
    dir: ArtifactDir,
    tier: String,
}

/// Dense KV caches for a batch bucket, threaded through decode steps.
pub struct KvState {
    pub k: xla::Literal,
    pub v: xla::Literal,
    pub batch: usize,
}

impl LoadedModel {
    pub fn load(exec: Arc<Executor>, dir: &ArtifactDir, tier: &str) -> Result<Self> {
        let meta = dir.meta(tier)?;
        // Eagerly compile every bucket so the request path never JITs.
        for &(b, s) in &meta.prefill_shapes {
            exec.load(&dir.prefill_hlo(tier, b, s))
                .with_context(|| format!("prefill bucket b{b} s{s}"))?;
        }
        for &b in &meta.decode_batches {
            exec.load(&dir.decode_hlo(tier, b))
                .with_context(|| format!("decode bucket b{b}"))?;
        }
        Ok(LoadedModel { meta, exec, dir: dir.clone(), tier: tier.to_string() })
    }

    /// Run a prefill over `tokens` (row-major batch x seq, padded) and
    /// per-sequence lengths. Returns (logits (b,s,v) flattened, KV).
    pub fn prefill(
        &self,
        bucket: (usize, usize),
        tokens: &[i32],
        lengths: &[i32],
    ) -> Result<(Vec<f32>, KvState)> {
        let (b, s) = bucket;
        anyhow::ensure!(tokens.len() == b * s, "tokens len");
        anyhow::ensure!(lengths.len() == b, "lengths len");
        let exe = self.exec.load(&self.dir.prefill_hlo(&self.tier, b, s))?;
        let t = xla::Literal::vec1(tokens).reshape(&[b as i64, s as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let l = xla::Literal::vec1(lengths);
        let mut out = self.exec.run(&exe, &[t, l])?;
        anyhow::ensure!(out.len() == 3, "prefill returns (logits, k, v)");
        let v_cache = out.pop().unwrap();
        let k_cache = out.pop().unwrap();
        let logits = out.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        Ok((logits, KvState { k: k_cache, v: v_cache, batch: b }))
    }

    /// Run one decode step. `tokens`/`lengths` are per-slot; the KV
    /// state is consumed and the updated one returned (buffer
    /// threading, vLLM-style step loop).
    pub fn decode_step(
        &self,
        kv: KvState,
        tokens: &[i32],
        lengths: &[i32],
    ) -> Result<(Vec<f32>, KvState)> {
        let b = kv.batch;
        anyhow::ensure!(tokens.len() == b && lengths.len() == b, "batch mismatch");
        let exe = self.exec.load(&self.dir.decode_hlo(&self.tier, b))?;
        let t = xla::Literal::vec1(tokens);
        let l = xla::Literal::vec1(lengths);
        let mut out = self.exec.run(&exe, &[t, l, kv.k, kv.v])?;
        anyhow::ensure!(out.len() == 3, "decode returns (logits, k, v)");
        let v_cache = out.pop().unwrap();
        let k_cache = out.pop().unwrap();
        let logits = out.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        Ok((logits, KvState { k: k_cache, v: v_cache, batch: b }))
    }

    /// Greedy argmax over a (n, vocab)-flattened logits buffer.
    pub fn argmax_rows(&self, logits: &[f32], rows: usize) -> Vec<i32> {
        let v = self.meta.vocab;
        (0..rows)
            .map(|r| {
                let row = &logits[r * v..(r + 1) * v];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as i32)
                    .unwrap_or(0)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT-backed integration tests live in rust/tests/pjrt_smoke.rs
    // (they need artifacts). Here: pure helpers.

    #[test]
    fn argmax_rows_picks_max() {
        let meta = ModelMeta::parse(
            r#"{"tier":"t","vocab":4,"hidden":8,"layers":1,"heads":1,
                "kv_heads":1,"head_dim":8,"max_seq":8,
                "prefill_shapes":[[1,8]],"decode_batches":[1],
                "precision":"x"}"#,
        )
        .unwrap();
        // Fake a LoadedModel-less call: argmax_rows only uses vocab.
        let logits = vec![0.0, 1.0, 0.5, -1.0, /* row 2 */ 9.0, 1.0, 2.0, 3.0];
        let lm = LoadedModelForTest { vocab: meta.vocab };
        assert_eq!(lm.argmax(&logits, 2), vec![1, 0]);
    }

    struct LoadedModelForTest {
        vocab: usize,
    }

    impl LoadedModelForTest {
        fn argmax(&self, logits: &[f32], rows: usize) -> Vec<i32> {
            let v = self.vocab;
            (0..rows)
                .map(|r| {
                    let row = &logits[r * v..(r + 1) * v];
                    row.iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(i, _)| i as i32)
                        .unwrap()
                })
                .collect()
        }
    }
}
