//! Bit-exact FP8 formats in rust — mirrors `python/compile/kernels/fp8.py`.
//!
//! The L3 coordinator needs FP8 semantics natively (KV-cache quantization
//! in the simulator, request-path sanity checks, golden-vector
//! cross-validation against the L1 Pallas emulation). Three lattices
//! (paper §3.2):
//!
//! * [`Format::E4M3FN`]    — NVIDIA variant, max finite 448, no inf,
//!   one NaN code per sign.
//! * [`Format::E4M3Gaudi`] — Gaudi 2 IEEE-style E4M3, exponent 15
//!   reserved, max finite 240 ("seven fewer magnitude representations").
//! * [`Format::E5M2`]      — IEEE-style, max finite 57344.
//!
//! Quantization saturates on overflow and supports round-to-nearest-even
//! and stochastic rounding (paper Eq. 2). Cross-language agreement is
//! enforced by `tests/golden_fp8.rs` against vectors emitted at
//! artifact-build time.

pub mod quantize;
pub mod scaling;

pub use quantize::{quantize_rtn, quantize_sr, Rounding};
pub use scaling::{amax_scale_rows, amax_scale_tensor, pow2_snap, GAUDI2_HW_SCALES};

/// An FP8 value lattice.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Format {
    E4M3FN,
    E4M3Gaudi,
    E5M2,
}

impl Format {
    pub const ALL: [Format; 3] = [Format::E4M3FN, Format::E4M3Gaudi, Format::E5M2];

    pub fn name(self) -> &'static str {
        match self {
            Format::E4M3FN => "e4m3fn",
            Format::E4M3Gaudi => "e4m3_gaudi",
            Format::E5M2 => "e5m2",
        }
    }

    pub fn from_name(name: &str) -> Option<Format> {
        Format::ALL.iter().copied().find(|f| f.name() == name)
    }

    /// Mantissa bits (excluding the implicit leading one).
    pub fn man_bits(self) -> u32 {
        match self {
            Format::E4M3FN | Format::E4M3Gaudi => 3,
            Format::E5M2 => 2,
        }
    }

    /// Exponent of the smallest *normal* binade.
    pub fn emin(self) -> i32 {
        match self {
            Format::E4M3FN | Format::E4M3Gaudi => -6,
            Format::E5M2 => -14,
        }
    }

    /// Largest finite value.
    pub fn max_finite(self) -> f32 {
        match self {
            Format::E4M3FN => 448.0,
            Format::E4M3Gaudi => 240.0,
            Format::E5M2 => 57344.0,
        }
    }

    /// Smallest positive subnormal.
    pub fn min_subnormal(self) -> f32 {
        exp2i(self.emin() - self.man_bits() as i32)
    }

    /// Bytes per element when stored (always 1 for FP8).
    pub fn bytes(self) -> usize {
        1
    }

    /// Enumerate every non-negative finite lattice value, ascending.
    /// (<= 128 values; used by tests and the error-analysis tooling.)
    pub fn lattice(self) -> Vec<f32> {
        let mut vals = vec![0.0f32];
        let mb = self.man_bits();
        for m in 1..(1u32 << mb) {
            vals.push(m as f32 * self.min_subnormal());
        }
        let mut e = self.emin();
        loop {
            let base = exp2i(e);
            if base > self.max_finite() {
                break;
            }
            for m in 0..(1u32 << mb) {
                let v = (1.0 + m as f32 / (1u32 << mb) as f32) * base;
                if v <= self.max_finite() {
                    vals.push(v);
                }
            }
            e += 1;
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        vals.dedup();
        vals
    }
}

/// Exact 2^e as f32 (e within normal f32 range).
pub fn exp2i(e: i32) -> f32 {
    debug_assert!((-126..=127).contains(&e));
    f32::from_bits(((e + 127) as u32) << 23)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp2i_exact() {
        assert_eq!(exp2i(0), 1.0);
        assert_eq!(exp2i(-9), 2.0_f32.powi(-9));
        assert_eq!(exp2i(15), 32768.0);
    }

    #[test]
    fn lattice_extremes() {
        for fmt in Format::ALL {
            let lat = fmt.lattice();
            assert_eq!(lat[0], 0.0);
            assert_eq!(lat[1], fmt.min_subnormal());
            assert_eq!(*lat.last().unwrap(), fmt.max_finite());
            // strictly ascending
            for w in lat.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
    }

    #[test]
    fn gaudi_has_seven_fewer_magnitudes() {
        // Paper §3.2 (E4M3 range).
        let nv = Format::E4M3FN.lattice().len();
        let gd = Format::E4M3Gaudi.lattice().len();
        assert_eq!(nv - gd, 7);
    }

    #[test]
    fn names_roundtrip() {
        for fmt in Format::ALL {
            assert_eq!(Format::from_name(fmt.name()), Some(fmt));
        }
        assert_eq!(Format::from_name("bogus"), None);
    }
}
