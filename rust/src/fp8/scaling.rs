//! Scaling strategies for FP8 quantization (paper §3.2, §4.1).

use super::Format;

/// Gaudi-2 hardware-accelerated per-tensor exponent-bias scales
/// (fixed set 2^-8, 2^-4, 2^0, 2^4 — paper §3.2 "Power-of-2 scaling").
pub const GAUDI2_HW_SCALES: [f32; 4] = [
    0.00390625, // 2^-8
    0.0625,     // 2^-4
    1.0,        // 2^0
    16.0,       // 2^4
];

/// Dynamic per-tensor amax scale: s such that x/s fills the range.
pub fn amax_scale_tensor(xs: &[f32], fmt: Format) -> f32 {
    let amax = xs.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
    amax.max(1e-12) / fmt.max_finite()
}

/// Dynamic per-row amax scales for an (rows x cols) row-major matrix.
pub fn amax_scale_rows(xs: &[f32], rows: usize, cols: usize, fmt: Format) -> Vec<f32> {
    assert_eq!(xs.len(), rows * cols);
    (0..rows)
        .map(|r| amax_scale_tensor(&xs[r * cols..(r + 1) * cols], fmt))
        .collect()
}

/// Snap a scale to the Gaudi hardware set: smallest member >= scale,
/// clamped to the largest member.
pub fn pow2_snap(scale: f32, hw_set: &[f32]) -> f32 {
    let mut sorted: Vec<f32> = hw_set.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for &s in &sorted {
        if s >= scale {
            return s;
        }
    }
    *sorted.last().expect("empty hw scale set")
}

/// Quantization SNR (dB) of a tensor under a given format+scale — the
/// error-analysis primitive behind the Table 4/5 orderings.
pub fn quant_snr_db(xs: &[f32], fmt: Format, scale: f32) -> f64 {
    let mut sig = 0.0f64;
    let mut err = 0.0f64;
    for &x in xs {
        let q = super::quantize_rtn(x / scale, fmt) * scale;
        sig += (x as f64) * (x as f64);
        let e = (q - x) as f64;
        err += e * e;
    }
    if err == 0.0 {
        f64::INFINITY
    } else {
        10.0 * (sig / err).log10()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn tensor_scale_fills_range() {
        let xs = [1.0, -3.0, 2.0];
        let s = amax_scale_tensor(&xs, Format::E4M3FN);
        assert!((s - 3.0 / 448.0).abs() < 1e-9);
    }

    #[test]
    fn row_scales_per_row() {
        let xs = [1.0, 2.0, /* row 1 */ 10.0, -20.0];
        let s = amax_scale_rows(&xs, 2, 2, Format::E4M3FN);
        assert!((s[0] - 2.0 / 448.0).abs() < 1e-9);
        assert!((s[1] - 20.0 / 448.0).abs() < 1e-9);
    }

    #[test]
    fn pow2_snap_behaviour() {
        assert_eq!(pow2_snap(0.01, &GAUDI2_HW_SCALES), 0.0625);
        assert_eq!(pow2_snap(1.0, &GAUDI2_HW_SCALES), 1.0);
        assert_eq!(pow2_snap(3.0, &GAUDI2_HW_SCALES), 16.0);
        assert_eq!(pow2_snap(1e6, &GAUDI2_HW_SCALES), 16.0);
    }

    #[test]
    fn e4m3_has_better_snr_than_e5m2_on_normals() {
        // The Table 5 mechanism: for activation-like (unit-scale
        // gaussian) data, E4M3's extra mantissa bit beats E5M2's range.
        let mut rng = Rng::new(4);
        let xs: Vec<f32> = (0..20_000).map(|_| rng.normal() as f32).collect();
        let s4 = amax_scale_tensor(&xs, Format::E4M3FN);
        let s5 = amax_scale_tensor(&xs, Format::E5M2);
        let snr4 = quant_snr_db(&xs, Format::E4M3FN, s4);
        let snr5 = quant_snr_db(&xs, Format::E5M2, s5);
        assert!(snr4 > snr5 + 3.0, "snr4={snr4} snr5={snr5}");
    }

    #[test]
    fn dynamic_rowwise_beats_static_with_outliers() {
        // The Table 4 mechanism: a static per-tensor scale calibrated
        // without outliers clips them; dynamic row scales do not.
        let mut rng = Rng::new(6);
        let mut xs: Vec<f32> = (0..4096).map(|_| rng.normal() as f32).collect();
        xs[17] = 80.0; // outlier
        let static_scale = 3.0 / Format::E4M3FN.max_finite(); // calibrated on N(0,1)
        let snr_static = quant_snr_db(&xs, Format::E4M3FN, static_scale);
        let dyn_scale = amax_scale_tensor(&xs, Format::E4M3FN);
        let snr_dyn = quant_snr_db(&xs, Format::E4M3FN, dyn_scale);
        // static clips the outlier -> large error energy
        assert!(snr_dyn > snr_static, "dyn={snr_dyn} static={snr_static}");
    }
}
