//! FP8 rounding: round-to-nearest-even and stochastic rounding.
//!
//! Same algorithm as the L1 kernel emulation (exponent arithmetic on
//! the f32 bit pattern; exact, no transcendental functions), verified
//! bit-exactly against it via golden vectors.

use super::{exp2i, Format};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rounding {
    /// Round-to-nearest, ties to even (hardware default).
    Rtn,
    /// Stochastic rounding, paper Eq. 2 (Gaudi hardware feature).
    Sr,
}

/// Lattice spacing ("quantum") at |x|.
fn quantum(fmt: Format, x: f32) -> f32 {
    let ax = x.abs();
    // floor(log2(ax)) from the exponent field; subnormal f32 inputs all
    // fall below every FP8 binade, so clamping handles them.
    let e = if ax == 0.0 {
        fmt.emin()
    } else {
        let bits = ax.to_bits();
        let biased = (bits >> 23) as i32;
        if biased == 0 {
            -127 // f32 subnormal: far below any FP8 emin
        } else {
            biased - 127
        }
    };
    let e = e.max(fmt.emin());
    exp2i(e - fmt.man_bits() as i32)
}

/// Round one f32 onto the FP8 lattice with RTN (saturating).
pub fn quantize_rtn(x: f32, fmt: Format) -> f32 {
    if x.is_nan() {
        return f32::NAN;
    }
    if x.is_infinite() {
        return x.signum() * fmt.max_finite();
    }
    let q = quantum(fmt, x);
    let scaled = x / q;
    let r = round_half_even(scaled);
    let y = r * q;
    y.clamp(-fmt.max_finite(), fmt.max_finite())
}

/// Round one f32 onto the FP8 lattice with stochastic rounding.
///
/// P(round up) = (x - x_down) / (x_up - x_down)  — paper Eq. 2.
pub fn quantize_sr(x: f32, fmt: Format, rng: &mut Rng) -> f32 {
    if x.is_nan() {
        return f32::NAN;
    }
    if x.is_infinite() {
        return x.signum() * fmt.max_finite();
    }
    let q = quantum(fmt, x);
    let scaled = x / q;
    let lo = scaled.floor();
    let p_up = scaled - lo;
    let r = if (rng.f64() as f32) < p_up { lo + 1.0 } else { lo };
    (r * q).clamp(-fmt.max_finite(), fmt.max_finite())
}

/// Round half to even, matching `jnp.round` / IEEE roundTiesToEven.
fn round_half_even(x: f32) -> f32 {
    let r = x.round(); // half away from zero
    if (x - x.trunc()).abs() == 0.5 {
        // exact tie: pick the even neighbour
        let down = x.trunc();
        let up = down + x.signum();
        if (down as i64) % 2 == 0 {
            down
        } else {
            up
        }
    } else {
        r
    }
}

/// Quantize a slice (RTN).
pub fn quantize_slice_rtn(xs: &[f32], fmt: Format) -> Vec<f32> {
    xs.iter().map(|&x| quantize_rtn(x, fmt)).collect()
}

/// Quantize a slice with the given rounding mode.
pub fn quantize_slice(xs: &[f32], fmt: Format, mode: Rounding, rng: &mut Rng) -> Vec<f32> {
    match mode {
        Rounding::Rtn => quantize_slice_rtn(xs, fmt),
        Rounding::Sr => xs.iter().map(|&x| quantize_sr(x, fmt, rng)).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lattice_values_are_fixed_points() {
        for fmt in Format::ALL {
            for &v in &fmt.lattice() {
                assert_eq!(quantize_rtn(v, fmt), v, "{} {v}", fmt.name());
                assert_eq!(quantize_rtn(-v, fmt), -v);
            }
        }
    }

    #[test]
    fn saturates_at_max() {
        assert_eq!(quantize_rtn(1e9, Format::E4M3FN), 448.0);
        assert_eq!(quantize_rtn(-1e9, Format::E4M3FN), -448.0);
        assert_eq!(quantize_rtn(250.0, Format::E4M3Gaudi), 240.0);
        assert_eq!(quantize_rtn(f32::INFINITY, Format::E5M2), 57344.0);
    }

    #[test]
    fn nearest_with_ties_to_even() {
        // E4M3FN around 1.0: spacing 1/8. 1.0625 is the midpoint of
        // [1.0, 1.125]; even mantissa is 1.0 (code 000).
        assert_eq!(quantize_rtn(1.0625, Format::E4M3FN), 1.0);
        // midpoint of [1.125, 1.25] -> 1.25 (code 010 even).
        assert_eq!(quantize_rtn(1.1875, Format::E4M3FN), 1.25);
        // strictly above the midpoint rounds up
        assert_eq!(quantize_rtn(1.07, Format::E4M3FN), 1.125);
    }

    #[test]
    fn underflow_to_zero() {
        for fmt in Format::ALL {
            let tiny = fmt.min_subnormal() / 2.0;
            assert_eq!(quantize_rtn(tiny * 0.99, fmt), 0.0);
            // exact half ties to even -> 0
            assert_eq!(quantize_rtn(tiny, fmt), 0.0);
            assert_eq!(quantize_rtn(tiny * 1.01, fmt), fmt.min_subnormal());
        }
    }

    #[test]
    fn rtn_error_bounded_by_half_quantum() {
        let mut rng = Rng::new(5);
        for fmt in Format::ALL {
            for _ in 0..10_000 {
                let x = (rng.f64() as f32 - 0.5) * 2.0 * fmt.max_finite();
                let q = quantize_rtn(x, fmt);
                let spacing = quantum(fmt, x);
                assert!(
                    (q - x).abs() <= spacing / 2.0 + 1e-12,
                    "{} x={x} q={q} sp={spacing}",
                    fmt.name()
                );
            }
        }
    }

    #[test]
    fn sr_is_unbiased() {
        let mut rng = Rng::new(1);
        let fmt = Format::E4M3FN;
        // x 30% of the way between 1.0 and 1.125.
        let x = 1.0 + 0.3 * 0.125;
        let n = 40_000;
        let mut ups = 0;
        for _ in 0..n {
            let q = quantize_sr(x, fmt, &mut rng);
            assert!(q == 1.0 || q == 1.125);
            if q == 1.125 {
                ups += 1;
            }
        }
        let p = ups as f64 / n as f64;
        assert!((p - 0.3).abs() < 0.01, "p_up {p}");
    }

    #[test]
    fn sr_on_lattice_is_exact() {
        let mut rng = Rng::new(2);
        for fmt in Format::ALL {
            for &v in fmt.lattice().iter().take(40) {
                assert_eq!(quantize_sr(v, fmt, &mut rng), v);
            }
        }
    }

    #[test]
    fn matches_enumerated_nearest_search() {
        // Independent oracle: explicit nearest-lattice search.
        let mut rng = Rng::new(33);
        for fmt in Format::ALL {
            let lat = fmt.lattice();
            for _ in 0..2_000 {
                let x = (rng.f64() as f32 - 0.5) * 2.2 * fmt.max_finite();
                let got = quantize_rtn(x, fmt);
                // brute force nearest (ties resolved by even index)
                let ax = x.abs();
                let mut best = lat[0];
                let mut best_d = f32::INFINITY;
                for (i, &v) in lat.iter().enumerate() {
                    let d = (v - ax).abs();
                    if d < best_d || (d == best_d && i % 2 == 0) {
                        best_d = d;
                        best = v;
                    }
                }
                let want = x.signum() * best;
                assert_eq!(got, want, "{} x={x}", fmt.name());
            }
        }
    }
}
