//! Physical rack / infrastructure model behind the Eq. 1 ratios.
//!
//! Paper §2.1: rack power is the binding resource — "the per-chip cost
//! of infrastructure is inversely proportional to the number of
//! servers that can fit in a single rack", and electricity itself is
//! usually outweighed by amortized rack/cooling equipment. This module
//! turns device power draw into R_IC and absolute per-server infra
//! cost so TCO scenarios can be derived from hwsim measurements rather
//! than assumed.

use crate::hwsim::spec::Device;

#[derive(Debug, Clone)]
pub struct RackConfig {
    /// Usable rack power budget (W). Common AI-DC racks: 30-120 kW.
    pub power_budget_w: f64,
    /// Amortized fixed cost of the rack + cooling + power equipment
    /// over the planning horizon ($/rack).
    pub fixed_cost_usd: f64,
    /// Electricity price ($/kWh).
    pub usd_per_kwh: f64,
    /// Planning horizon (hours).
    pub horizon_hours: f64,
    /// Accelerators per server.
    pub chips_per_server: usize,
    /// Non-accelerator server overhead power (CPU, NICs, fans) per
    /// server (W).
    pub server_overhead_w: f64,
    /// Power usage effectiveness: facility energy drawn from the grid
    /// per unit of IT energy (cooling, distribution losses). Scales
    /// the electricity bill and the Wh/Mtok axis; rack *packing* stays
    /// on IT power — `power_budget_w` is the usable IT budget, the
    /// cooling overhead lives outside it.
    pub pue_ratio: f64,
}

impl RackConfig {
    /// A typical air-cooled AI rack (A100-era 40 kW provisioning, the
    /// §5.5 "much existing infrastructure ... built around the A100").
    /// PUE 1.3: between a hyperscaler's ~1.1 and the air-cooled fleet
    /// average ~1.4.
    pub fn a100_era() -> Self {
        RackConfig {
            power_budget_w: 40_000.0,
            fixed_cost_usd: 120_000.0,
            usd_per_kwh: 0.08,
            horizon_hours: 5.0 * 365.0 * 24.0, // 5-year amortization
            chips_per_server: 8,
            server_overhead_w: 1_500.0,
            pue_ratio: 1.3,
        }
    }
}

/// Illustrative per-server list prices ($, 8 accelerators each) used
/// by the examples and benches: the paper's premise that Gaudi 2
/// servers sell at a steep discount to H100 (Fig. 1's R_SC axis).
/// Knobs, not measurements — sweep them via [`TcoInputs`] for
/// sensitivity.
///
/// [`TcoInputs`]: crate::tco::TcoInputs
pub fn assumed_server_price_usd(dev: Device) -> f64 {
    match dev {
        Device::H100 => 250_000.0,
        Device::Gaudi2 => 125_000.0,
        Device::Gaudi3 => 160_000.0,
        Device::A100 => 150_000.0,
    }
}

/// One day of a replica fleet's measured usage, as the idle-aware
/// ledger reports it: device energy actually drawn, replica-seconds
/// powered (busy + idle, provisioning included) and replica-seconds
/// spent power-gated at 0 W. The diurnal pricing methods integrate
/// this instead of assuming one sustained draw held forever — a fleet
/// that sleeps through the trough pays for the capacity it owns but
/// only for the energy it draws.
#[derive(Debug, Clone, Copy)]
pub struct DayUsage {
    /// Device energy over the day (J), all chips: busy + idle through
    /// the ledger; gated spans add none.
    pub energy_j: f64,
    /// Sum over replicas of powered seconds (`span + idle_s`).
    pub powered_replica_s: f64,
    /// Sum over replicas of power-gated seconds (`gated_s`).
    pub gated_replica_s: f64,
    /// Sum over replicas of crashed-awaiting-repair seconds
    /// (`down_s`): owned capacity drawing 0 W, like gated time, but
    /// *involuntarily* — the availability ledger's arm.
    pub down_replica_s: f64,
    /// Output tokens the fleet delivered over the day.
    pub tokens_out: u64,
    /// Tokens that were streamed and then invalidated by a crash
    /// (`lost_tokens`): counted inside `tokens_out`'s work but not
    /// deliverable. Goodput pricing divides by
    /// `tokens_out - lost_tokens`.
    pub lost_tokens: u64,
    /// The day itself (s): the shared ledger-close instant, so a
    /// fully-closed fleet has `powered_replica_s + gated_replica_s +
    /// down_replica_s == n_replicas * day_s`.
    pub day_s: f64,
}

impl DayUsage {
    /// Build from a fleet's merged [`Metrics`] closed at `day_s`.
    /// Engine-level energy is per chip (the step model's convention),
    /// so the fleet's device energy scales by `chips_per_replica`.
    ///
    /// [`Metrics`]: crate::coordinator::Metrics
    pub fn from_fleet(
        m: &crate::coordinator::Metrics,
        chips_per_replica: usize,
        day_s: f64,
    ) -> Self {
        assert!(chips_per_replica > 0, "replicas need chips");
        DayUsage {
            energy_j: m.energy_j * chips_per_replica as f64,
            powered_replica_s: m.span + m.idle_s,
            gated_replica_s: m.gated_s,
            down_replica_s: m.down_s,
            tokens_out: m.tokens_out,
            lost_tokens: m.lost_tokens,
            day_s,
        }
    }

    /// Tokens actually delivered to clients: streamed output minus
    /// what a crash invalidated mid-stream. The denominator of every
    /// goodput-priced axis.
    pub fn goodput_tokens(&self) -> u64 {
        self.tokens_out.saturating_sub(self.lost_tokens)
    }
}

#[derive(Debug, Clone)]
pub struct InfraModel {
    pub rack: RackConfig,
}

impl InfraModel {
    pub fn new(rack: RackConfig) -> Self {
        InfraModel { rack }
    }

    /// Server power at a sustained per-chip draw.
    pub fn server_power_w(&self, chip_draw_w: f64) -> f64 {
        self.rack.server_overhead_w + self.rack.chips_per_server as f64 * chip_draw_w
    }

    /// Servers that fit in one rack at the given sustained chip draw
    /// (power-limited packing, §2.1).
    pub fn servers_per_rack(&self, chip_draw_w: f64) -> usize {
        (self.rack.power_budget_w / self.server_power_w(chip_draw_w)).floor() as usize
    }

    /// Infra cost per server over the horizon: amortized rack share +
    /// electricity. The electricity term is billed at *facility*
    /// energy — IT draw times the PUE — while the rack share packs on
    /// IT power (the budget is IT-side; cooling is outside it).
    pub fn infra_cost_per_server(&self, chip_draw_w: f64) -> f64 {
        let per_rack = self.servers_per_rack(chip_draw_w).max(1) as f64;
        let rack_share = self.rack.fixed_cost_usd / per_rack;
        let energy_kwh = self.server_power_w(chip_draw_w) / 1000.0
            * self.rack.pue_ratio
            * self.rack.horizon_hours;
        rack_share + energy_kwh * self.rack.usd_per_kwh
    }

    /// R_IC between two devices at given sustained draws.
    pub fn infra_cost_ratio(&self, a_draw_w: f64, b_draw_w: f64) -> f64 {
        self.infra_cost_per_server(a_draw_w) / self.infra_cost_per_server(b_draw_w)
    }

    /// Absolute cost per million output tokens served *at SLO*: the
    /// server's capex plus its horizon infra cost (rack share +
    /// electricity at the sustained draw), divided by the tokens the
    /// server delivers over the horizon at the measured SLO-feasible
    /// goodput. This is where the serving simulator's load sweep
    /// (`coordinator::cluster::max_sustainable_qps`) meets Eq. 1: the
    /// throughput entering the ratio is goodput under a latency SLO,
    /// not peak tokens/s.
    pub fn cost_per_mtok(
        &self,
        server_price_usd: f64,
        chip_draw_w: f64,
        server_tokens_per_sec: f64,
    ) -> f64 {
        assert!(server_tokens_per_sec > 0.0, "goodput must be positive");
        let total_cost = server_price_usd + self.infra_cost_per_server(chip_draw_w);
        let tokens = server_tokens_per_sec * self.rack.horizon_hours * 3600.0;
        total_cost / tokens * 1e6
    }

    /// $/Mtok-at-SLO for a *sharded* deployment: `tokens_per_sec` is
    /// the goodput produced by `chips` accelerators — one instance's
    /// `chips_per_instance()` when pricing a single engine, or a whole
    /// replicated cluster's `total_chips()` with its merged goodput.
    /// Normalizing to per-chip goodput and scaling to the server's
    /// chip count prices multi-chip plans on the same axis as
    /// single-chip ones (a TP=8 instance simply *is* one server here).
    /// `server_price_usd` stays a caller knob like in [`Self::cost_per_mtok`]
    /// (pass [`assumed_server_price_usd`] for the illustrative defaults).
    pub fn cost_per_mtok_sharded(
        &self,
        server_price_usd: f64,
        chips: usize,
        watts_per_chip: f64,
        tokens_per_sec: f64,
    ) -> f64 {
        assert!(chips > 0, "deployment needs chips");
        let per_chip_tps = tokens_per_sec / chips as f64;
        let server_tps = per_chip_tps * self.rack.chips_per_server as f64;
        self.cost_per_mtok(server_price_usd, watts_per_chip, server_tps)
    }

    /// $/Mtok-at-SLO for a *heterogeneous, disaggregated* deployment:
    /// each pool contributes `chips / chips_per_server` servers' worth
    /// of capex plus horizon infra at that pool's sustained draw, and
    /// the summed cost is divided by the tokens the whole deployment
    /// delivers at SLO — one workload, one $/Mtok axis, even when the
    /// prefill and decode pools are different vendors. Each pool tuple
    /// is `(server_price_usd, chips, watts_per_chip)`. For a single pool
    /// this reduces exactly to [`Self::cost_per_mtok_sharded`].
    pub fn cost_per_mtok_disagg(
        &self,
        pools: &[(f64, usize, f64)],
        tokens_per_sec: f64,
    ) -> f64 {
        assert!(tokens_per_sec > 0.0, "goodput must be positive");
        assert!(!pools.is_empty(), "deployment needs at least one pool");
        let mut total_cost = 0.0;
        for &(server_price_usd, chips, watts_per_chip) in pools {
            assert!(chips > 0, "every pool needs chips");
            let servers = chips as f64 / self.rack.chips_per_server as f64;
            total_cost += servers * (server_price_usd + self.infra_cost_per_server(watts_per_chip));
        }
        let tokens = tokens_per_sec * self.rack.horizon_hours * 3600.0;
        total_cost / tokens * 1e6
    }

    /// Price a [`DisaggPlan`] at a measured operating point: each pool
    /// at its device's assumed server price, its shape-derived chip
    /// count and its measured sustained draw. Keeps the plan→pools
    /// mapping in one place for the bench, the example and the tests.
    ///
    /// [`DisaggPlan`]: crate::analysis::disagg::DisaggPlan
    pub fn cost_per_mtok_disagg_plan(
        &self,
        plan: &crate::analysis::disagg::DisaggPlan,
        prefill_watts: f64,
        decode_watts: f64,
        tokens_per_sec: f64,
    ) -> f64 {
        self.cost_per_mtok_disagg(
            &[
                (
                    assumed_server_price_usd(plan.prefill.device),
                    plan.prefill.plan.total_chips(),
                    prefill_watts,
                ),
                (
                    assumed_server_price_usd(plan.decode.device),
                    plan.decode.plan.total_chips(),
                    decode_watts,
                ),
            ],
            tokens_per_sec,
        )
    }

    /// Price a [`PhaseAffinityPlan`] (mixed colocated + disaggregated
    /// deployment) at a measured operating point: the colocated pool,
    /// the prefill pool and the decode pool each at their device's
    /// assumed server price, shape-derived chip count and measured
    /// sustained draw, over the one shared goodput.
    ///
    /// [`PhaseAffinityPlan`]: crate::analysis::disagg::PhaseAffinityPlan
    pub fn cost_per_mtok_phase_affinity_plan(
        &self,
        plan: &crate::analysis::disagg::PhaseAffinityPlan,
        colocated_watts: f64,
        prefill_watts: f64,
        decode_watts: f64,
        tokens_per_sec: f64,
    ) -> f64 {
        self.cost_per_mtok_disagg(
            &[
                (
                    assumed_server_price_usd(plan.colocated.device),
                    plan.colocated.plan.total_chips(),
                    colocated_watts,
                ),
                (
                    assumed_server_price_usd(plan.disagg.prefill.device),
                    plan.disagg.prefill.plan.total_chips(),
                    prefill_watts,
                ),
                (
                    assumed_server_price_usd(plan.disagg.decode.device),
                    plan.disagg.decode.plan.total_chips(),
                    decode_watts,
                ),
            ],
            tokens_per_sec,
        )
    }

    /// $/Mtok for one measured day of a replica fleet ([`DayUsage`]):
    /// the capacity the fleet *owns* — server capex plus rack share,
    /// both amortized over the day's fraction of the horizon — plus
    /// the electricity it actually *drew*, over the day's tokens.
    /// Unlike [`Self::cost_per_mtok`], which assumes one sustained
    /// draw held for the whole horizon, this separates the two sides:
    /// all `n_replicas` are owned (and rack-provisioned at
    /// `provision_draw_w`, the per-chip draw the rack must be packed
    /// for) whether or not they sleep, while the energy bill follows
    /// the ledger — power-gated replica-seconds cost nothing, powered
    /// ones add server overhead, and the PUE scales the lot. For a
    /// fleet powered at one constant draw all day this reduces exactly
    /// to [`Self::cost_per_mtok`].
    pub fn cost_per_mtok_diurnal(
        &self,
        server_price_usd: f64,
        chips_per_replica: usize,
        n_replicas: usize,
        provision_draw_w: f64,
        usage: &DayUsage,
    ) -> f64 {
        assert!(chips_per_replica > 0 && n_replicas > 0, "fleet needs replicas and chips");
        assert!(usage.day_s > 0.0, "day must have positive length");
        assert!(usage.tokens_out > 0, "fleet must deliver tokens");
        let replica_s =
            usage.powered_replica_s + usage.gated_replica_s + usage.down_replica_s;
        assert!(
            replica_s <= n_replicas as f64 * usage.day_s * (1.0 + 1e-9) + 1e-6,
            "ledger overruns the day: {replica_s} replica-s > {n_replicas} x {} s",
            usage.day_s
        );
        let server_equiv = chips_per_replica as f64 / self.rack.chips_per_server as f64;
        // Owned capacity, amortized over the day's slice of the horizon.
        let per_rack = self.servers_per_rack(provision_draw_w).max(1) as f64;
        let day_frac = usage.day_s / (self.rack.horizon_hours * 3600.0);
        let owned_usd = n_replicas as f64
            * server_equiv
            * (server_price_usd + self.rack.fixed_cost_usd / per_rack)
            * day_frac;
        // Drawn energy: the ledger's device joules plus server
        // overhead over powered replica-seconds, billed at facility
        // (PUE-scaled) energy. Gated time adds nothing.
        let overhead_j =
            self.rack.server_overhead_w * usage.powered_replica_s * server_equiv;
        let energy_kwh = (usage.energy_j + overhead_j) / 3.6e6;
        let electricity_usd = energy_kwh * self.rack.pue_ratio * self.rack.usd_per_kwh;
        (owned_usd + electricity_usd) / usage.tokens_out as f64 * 1e6
    }

    /// Availability-priced $/Mtok for one measured (possibly faulty)
    /// day: [`Self::cost_per_mtok_diurnal`]'s owned-vs-drawn split,
    /// with two resilience corrections. First, the fleet owns
    /// `n_replicas + k_spares` replicas — the N+k redundancy a
    /// provider provisions so a crash fails over instead of shedding
    /// load; spares sit power-gated (capex and rack share, zero
    /// electricity) until promoted. Second, the denominator is
    /// *goodput* — `tokens_out - lost_tokens` — so tokens a crash
    /// invalidated are paid for (their energy was drawn, the capacity
    /// was owned) but never credited. Crashed-awaiting-repair time
    /// rides the `down_replica_s` ledger arm: owned, 0 W, exactly like
    /// gated time on the bill. With `k_spares = 0` and a fault-free
    /// ledger this reduces bit-for-bit to
    /// [`Self::cost_per_mtok_diurnal`].
    pub fn cost_per_mtok_resilient(
        &self,
        server_price_usd: f64,
        chips_per_replica: usize,
        n_replicas: usize,
        k_spares: usize,
        provision_draw_w: f64,
        usage: &DayUsage,
    ) -> f64 {
        assert!(chips_per_replica > 0 && n_replicas > 0, "fleet needs replicas and chips");
        assert!(usage.day_s > 0.0, "day must have positive length");
        assert!(usage.goodput_tokens() > 0, "fleet must deliver goodput");
        let replica_s =
            usage.powered_replica_s + usage.gated_replica_s + usage.down_replica_s;
        assert!(
            replica_s <= n_replicas as f64 * usage.day_s * (1.0 + 1e-9) + 1e-6,
            "ledger overruns the day: {replica_s} replica-s > {n_replicas} x {} s",
            usage.day_s
        );
        let server_equiv = chips_per_replica as f64 / self.rack.chips_per_server as f64;
        let per_rack = self.servers_per_rack(provision_draw_w).max(1) as f64;
        let day_frac = usage.day_s / (self.rack.horizon_hours * 3600.0);
        let owned_usd = (n_replicas + k_spares) as f64
            * server_equiv
            * (server_price_usd + self.rack.fixed_cost_usd / per_rack)
            * day_frac;
        let overhead_j =
            self.rack.server_overhead_w * usage.powered_replica_s * server_equiv;
        let energy_kwh = (usage.energy_j + overhead_j) / 3.6e6;
        let electricity_usd = energy_kwh * self.rack.pue_ratio * self.rack.usd_per_kwh;
        (owned_usd + electricity_usd) / usage.goodput_tokens() as f64 * 1e6
    }

    /// Facility watt-hours per million output tokens for one measured
    /// day — the energy twin of [`Self::cost_per_mtok_diurnal`]: its
    /// electricity component is exactly `wh / 1000 * usd_per_kwh`.
    pub fn wh_per_mtok_diurnal(&self, chips_per_replica: usize, usage: &DayUsage) -> f64 {
        assert!(chips_per_replica > 0, "replicas need chips");
        assert!(usage.tokens_out > 0, "fleet must deliver tokens");
        let server_equiv = chips_per_replica as f64 / self.rack.chips_per_server as f64;
        let overhead_j =
            self.rack.server_overhead_w * usage.powered_replica_s * server_equiv;
        let wh = (usage.energy_j + overhead_j) / 3600.0 * self.rack.pue_ratio;
        wh / usage.tokens_out as f64 * 1e6
    }

    /// Convenience: sustained draw for a device at a utilization,
    /// optionally power-capped.
    pub fn sustained_draw_w(&self, dev: Device, util_frac: f64, cap_w: Option<f64>) -> f64 {
        let p = crate::hwsim::power::power_draw_w(dev, util_frac);
        match cap_w {
            Some(c) => p.min(c),
            None => p,
        }
    }

    /// Facility watt-hours per million output tokens served at SLO:
    /// one server's sustained IT draw (chips + overhead) times the
    /// PUE, over the goodput the server delivers. The energy twin of
    /// [`Self::cost_per_mtok`] — its electricity component is exactly
    /// `wh_per_mtok / 1000 * usd_per_kwh`.
    pub fn wh_per_mtok(&self, chip_draw_w: f64, server_tokens_per_sec: f64) -> f64 {
        assert!(server_tokens_per_sec > 0.0, "goodput must be positive");
        let facility_w = self.server_power_w(chip_draw_w) * self.rack.pue_ratio;
        facility_w / server_tokens_per_sec * 1e6 / 3600.0
    }

    /// Wh/Mtok-at-SLO for a *sharded* deployment — the energy twin of
    /// [`Self::cost_per_mtok_sharded`], with the same per-chip goodput
    /// normalization.
    pub fn wh_per_mtok_sharded(
        &self,
        chips: usize,
        watts_per_chip: f64,
        tokens_per_sec: f64,
    ) -> f64 {
        assert!(chips > 0, "deployment needs chips");
        let per_chip_tps = tokens_per_sec / chips as f64;
        let server_tps = per_chip_tps * self.rack.chips_per_server as f64;
        self.wh_per_mtok(watts_per_chip, server_tps)
    }

    /// Wh/Mtok-at-SLO for a heterogeneous deployment: each pool's
    /// server-equivalents draw at that pool's sustained per-chip
    /// power, the summed facility power divides by the shared goodput.
    /// Each pool tuple is `(chips, watts_per_chip)`. For a single pool
    /// this reduces exactly to [`Self::wh_per_mtok_sharded`]. The
    /// energy twin of [`Self::cost_per_mtok_disagg`].
    pub fn wh_per_mtok_disagg(&self, pools: &[(usize, f64)], tokens_per_sec: f64) -> f64 {
        assert!(tokens_per_sec > 0.0, "goodput must be positive");
        assert!(!pools.is_empty(), "deployment needs at least one pool");
        let mut facility_w = 0.0;
        for &(chips, watts_per_chip) in pools {
            assert!(chips > 0, "every pool needs chips");
            let servers = chips as f64 / self.rack.chips_per_server as f64;
            facility_w += servers * self.server_power_w(watts_per_chip) * self.rack.pue_ratio;
        }
        facility_w / tokens_per_sec * 1e6 / 3600.0
    }

    /// Wh/Mtok-at-SLO for a [`DisaggPlan`] at a measured operating
    /// point — the energy twin of [`Self::cost_per_mtok_disagg_plan`].
    ///
    /// [`DisaggPlan`]: crate::analysis::disagg::DisaggPlan
    pub fn wh_per_mtok_disagg_plan(
        &self,
        plan: &crate::analysis::disagg::DisaggPlan,
        prefill_watts: f64,
        decode_watts: f64,
        tokens_per_sec: f64,
    ) -> f64 {
        self.wh_per_mtok_disagg(
            &[
                (plan.prefill.plan.total_chips(), prefill_watts),
                (plan.decode.plan.total_chips(), decode_watts),
            ],
            tokens_per_sec,
        )
    }

    /// Wh/Mtok-at-SLO for a [`PhaseAffinityPlan`] at a measured
    /// operating point — the energy twin of
    /// [`Self::cost_per_mtok_phase_affinity_plan`].
    ///
    /// [`PhaseAffinityPlan`]: crate::analysis::disagg::PhaseAffinityPlan
    pub fn wh_per_mtok_phase_affinity_plan(
        &self,
        plan: &crate::analysis::disagg::PhaseAffinityPlan,
        colocated_watts: f64,
        prefill_watts: f64,
        decode_watts: f64,
        tokens_per_sec: f64,
    ) -> f64 {
        self.wh_per_mtok_disagg(
            &[
                (plan.colocated.plan.total_chips(), colocated_watts),
                (plan.disagg.prefill.plan.total_chips(), prefill_watts),
                (plan.disagg.decode.plan.total_chips(), decode_watts),
            ],
            tokens_per_sec,
        )
    }

    /// Per-chip power caps for a deployment sharing this rack's IT
    /// budget: reserve each server-equivalent's overhead off the top,
    /// then water-fill the remaining chip budget over the chips'
    /// uncapped demands
    /// ([`rack_allocation`](crate::hwsim::power::rack_allocation)).
    /// Unlike `PowerCap::PerRack`'s even-share fallback inside the
    /// step model, this sees real per-pool demand: a hot prefill pool
    /// borrows the headroom a memory-bound decode pool leaves unused
    /// (§5.5). Feed the results into
    /// [`PoolSpec::with_cap`](crate::analysis::disagg::PoolSpec::with_cap)
    /// to re-measure QPS-at-SLO under the cap.
    pub fn rack_capped_per_gpu_w(&self, demands_per_chip: &[f64]) -> Vec<f64> {
        let chips = demands_per_chip.len();
        let servers = (chips as f64 / self.rack.chips_per_server as f64).ceil();
        let chip_budget_w =
            (self.rack.power_budget_w - servers * self.rack.server_overhead_w).max(0.0);
        crate::hwsim::power::rack_allocation(chip_budget_w, demands_per_chip)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> InfraModel {
        InfraModel::new(RackConfig::a100_era())
    }

    #[test]
    fn packing_is_power_limited() {
        let m = model();
        // 8x700W + 1.5kW = 7.1 kW/server -> 5 servers in 40 kW.
        assert_eq!(m.servers_per_rack(700.0), 5);
        // Capped at 400 W: 8*400+1500 = 4.7kW -> 8 servers.
        assert_eq!(m.servers_per_rack(400.0), 8);
    }

    #[test]
    fn lower_power_lowers_infra_cost_per_server() {
        // §2.1: "the benefits of lower power consumption are twofold".
        let m = model();
        let hot = m.infra_cost_per_server(700.0);
        let cool = m.infra_cost_per_server(430.0);
        assert!(cool < hot, "{cool} {hot}");
    }

    #[test]
    fn rack_share_dominates_energy() {
        // §2.1: "the cost of electricity per se is outweighed by the
        // cost of the rack and other equipment".
        let m = model();
        let per_rack = m.servers_per_rack(600.0) as f64;
        let rack_share = m.rack.fixed_cost_usd / per_rack;
        let energy = m.server_power_w(600.0) / 1000.0 * m.rack.horizon_hours * m.rack.usd_per_kwh;
        // With 5-year horizon energy is material but same order; the
        // fixed share must be at least comparable.
        assert!(rack_share * 2.0 > energy, "rack {rack_share} energy {energy}");
    }

    #[test]
    fn infra_ratio_favors_cooler_device() {
        let m = model();
        // Gaudi 2 at high util (~460 W) vs H100 pegged (~690 W).
        let r = m.infra_cost_ratio(460.0, 690.0);
        assert!(r < 1.0, "{r}");
    }

    #[test]
    fn cost_per_mtok_scales_inversely_with_goodput() {
        let m = model();
        let slow = m.cost_per_mtok(200_000.0, 600.0, 1_000.0);
        let fast = m.cost_per_mtok(200_000.0, 600.0, 2_000.0);
        assert!(slow > 0.0);
        assert!((slow / fast - 2.0).abs() < 1e-9, "2x goodput = half the $/Mtok");
        // Cooler chips cut the infra share of $/Mtok at equal goodput.
        let cool = m.cost_per_mtok(200_000.0, 400.0, 1_000.0);
        assert!(cool < slow);
    }

    #[test]
    #[should_panic(expected = "goodput must be positive")]
    fn cost_per_mtok_rejects_zero_goodput() {
        model().cost_per_mtok(200_000.0, 600.0, 0.0);
    }

    #[test]
    fn sharded_cost_normalizes_by_instance_chips() {
        // A tp8 instance with 8x the goodput of a tp1 instance costs
        // the same per token: the normalization is per chip.
        let m = model();
        let h100 = assumed_server_price_usd(Device::H100);
        let single = m.cost_per_mtok_sharded(h100, 1, 600.0, 1_000.0);
        let tp8 = m.cost_per_mtok_sharded(h100, 8, 600.0, 8_000.0);
        assert!((single / tp8 - 1.0).abs() < 1e-9, "{single} vs {tp8}");
        // Same per-chip goodput on a cheaper server is cheaper.
        let gaudi =
            m.cost_per_mtok_sharded(assumed_server_price_usd(Device::Gaudi2), 8, 450.0, 8_000.0);
        assert!(gaudi < tp8);
    }

    #[test]
    fn disagg_pricing_reduces_to_sharded_for_one_pool() {
        let m = model();
        let h100 = assumed_server_price_usd(Device::H100);
        for (chips, tps) in [(1usize, 900.0), (8, 7200.0), (12, 9000.0)] {
            let sharded = m.cost_per_mtok_sharded(h100, chips, 600.0, tps);
            let disagg = m.cost_per_mtok_disagg(&[(h100, chips, 600.0)], tps);
            assert!(
                (sharded / disagg - 1.0).abs() < 1e-12,
                "chips {chips}: sharded {sharded} vs disagg {disagg}"
            );
        }
    }

    #[test]
    fn disagg_pricing_of_identical_pools_matches_merged_pool() {
        // Two identical pools priced separately must equal one pool of
        // the summed chips — the arithmetic backbone of the
        // infinite-bandwidth colocated-equivalence property.
        let m = model();
        let price = assumed_server_price_usd(Device::Gaudi2);
        let split = m.cost_per_mtok_disagg(&[(price, 2, 450.0), (price, 6, 450.0)], 4000.0);
        let merged = m.cost_per_mtok_disagg(&[(price, 8, 450.0)], 4000.0);
        assert!((split / merged - 1.0).abs() < 1e-12, "{split} vs {merged}");
    }

    #[test]
    fn mixed_vendor_pools_price_by_their_own_draw_and_capex() {
        let m = model();
        let h = assumed_server_price_usd(Device::H100);
        let g = assumed_server_price_usd(Device::Gaudi2);
        // Swapping the pricier pool for the cheaper one at equal shape
        // and goodput lowers $/Mtok.
        let all_h100 = m.cost_per_mtok_disagg(&[(h, 2, 650.0), (h, 6, 650.0)], 4000.0);
        let mixed = m.cost_per_mtok_disagg(&[(h, 2, 650.0), (g, 6, 450.0)], 4000.0);
        assert!(mixed < all_h100, "{mixed} vs {all_h100}");
    }

    #[test]
    #[should_panic(expected = "every pool needs chips")]
    fn disagg_pricing_rejects_empty_pool() {
        model().cost_per_mtok_disagg(&[(100_000.0, 0, 500.0)], 1000.0);
    }

    #[test]
    fn phase_affinity_pricing_sums_the_three_pools() {
        use crate::analysis::disagg::{DisaggPlan, PhaseAffinityPlan, PoolSpec};
        use crate::analysis::parallel::ParallelismPlan;
        use crate::analysis::perfmodel::PrecisionMode;
        let m = model();
        let h100 = |plan| PoolSpec::new(Device::H100, PrecisionMode::fp8_dynamic(), plan);
        let plan = PhaseAffinityPlan::new(
            h100(ParallelismPlan::single().with_replicas(2)),
            DisaggPlan::new(h100(ParallelismPlan::single()), h100(ParallelismPlan::single())),
            512,
        );
        // Identical devices at identical draw: the three-pool price
        // must equal one merged pool of the same total chips.
        let mixed = m.cost_per_mtok_phase_affinity_plan(&plan, 600.0, 600.0, 600.0, 4000.0);
        let merged = m.cost_per_mtok_disagg(
            &[(assumed_server_price_usd(Device::H100), plan.total_chips(), 600.0)],
            4000.0,
        );
        assert!((mixed / merged - 1.0).abs() < 1e-12, "{mixed} vs {merged}");
    }

    /// A synthetic measured day for an 8-chip-per-replica fleet:
    /// `gated_frac` of every replica-day is power-gated, the rest is
    /// powered at a flat `chip_w` per chip.
    fn day(n_replicas: usize, day_s: f64, chip_w: f64, gated_frac: f64, tokens: u64) -> DayUsage {
        let powered = n_replicas as f64 * day_s * (1.0 - gated_frac);
        DayUsage {
            energy_j: 8.0 * chip_w * powered,
            powered_replica_s: powered,
            gated_replica_s: n_replicas as f64 * day_s * gated_frac,
            down_replica_s: 0.0,
            tokens_out: tokens,
            lost_tokens: 0,
            day_s,
        }
    }

    #[test]
    fn diurnal_pricing_reduces_to_horizon_pricing_when_always_on() {
        // One 8-chip replica powered all day at a constant draw must
        // price exactly like cost_per_mtok at goodput tokens/day —
        // the two models agree wherever both apply.
        let m = model();
        let (w, day_s) = (600.0, 86_400.0);
        let tokens = 86_400u64 * 1_000;
        let u = day(1, day_s, w, 0.0, tokens);
        let diurnal = m.cost_per_mtok_diurnal(250_000.0, 8, 1, w, &u);
        let horizon = m.cost_per_mtok(250_000.0, w, tokens as f64 / day_s);
        assert!((diurnal / horizon - 1.0).abs() < 1e-12, "{diurnal} vs {horizon}");
    }

    #[test]
    fn gating_saves_exactly_the_gated_electricity() {
        // Same owned fleet, same tokens: the gated day draws no chip
        // energy and no server overhead over its gated replica-seconds,
        // so the whole cost delta is that electricity and nothing else
        // (capex and rack share are for owned capacity, gated or not).
        let m = model();
        let tokens = 5_000_000_000u64;
        let awake = day(4, 86_400.0, 500.0, 0.0, tokens);
        let gated = day(4, 86_400.0, 500.0, 0.25, tokens);
        let c_awake = m.cost_per_mtok_diurnal(160_000.0, 8, 4, 500.0, &awake);
        let c_gated = m.cost_per_mtok_diurnal(160_000.0, 8, 4, 500.0, &gated);
        assert!(c_gated < c_awake, "{c_gated} vs {c_awake}");
        let gated_server_s = awake.powered_replica_s - gated.powered_replica_s;
        let saved_kwh = m.server_power_w(500.0) * gated_server_s / 3.6e6;
        let saved_usd_per_mtok =
            saved_kwh * m.rack.pue_ratio * m.rack.usd_per_kwh / tokens as f64 * 1e6;
        assert!(
            ((c_awake - c_gated) / saved_usd_per_mtok - 1.0).abs() < 1e-9,
            "delta {} vs electricity {saved_usd_per_mtok}",
            c_awake - c_gated
        );
    }

    #[test]
    fn diurnal_wh_is_the_electricity_share_exactly() {
        // With capex zeroed out, $/Mtok is pure electricity and must
        // equal wh_per_mtok_diurnal / 1000 * usd_per_kwh.
        let free_capex = InfraModel::new(RackConfig {
            fixed_cost_usd: 0.0,
            ..RackConfig::a100_era()
        });
        let u = day(4, 86_400.0, 500.0, 0.4, 2_000_000_000);
        let c = free_capex.cost_per_mtok_diurnal(0.0, 8, 4, 500.0, &u);
        let wh = free_capex.wh_per_mtok_diurnal(8, &u);
        let electricity = wh / 1000.0 * free_capex.rack.usd_per_kwh;
        assert!((c / electricity - 1.0).abs() < 1e-12, "{c} vs {electricity}");
    }

    #[test]
    fn resilient_reduces_to_diurnal_without_faults_or_spares() {
        // A fault-free ledger with zero spares must price bit-for-bit
        // like the diurnal model — the resilience axis is a strict
        // superset, not a reinterpretation.
        let m = model();
        let u = day(4, 86_400.0, 500.0, 0.25, 5_000_000_000);
        let diurnal = m.cost_per_mtok_diurnal(160_000.0, 8, 4, 500.0, &u);
        let resilient = m.cost_per_mtok_resilient(160_000.0, 8, 4, 0, 500.0, &u);
        assert_eq!(diurnal.to_bits(), resilient.to_bits());
    }

    #[test]
    fn spares_add_exactly_their_owned_capacity() {
        // Each gated spare adds capex + rack share, amortized over the
        // day, and nothing else — no electricity, no goodput.
        let m = model();
        let u = day(4, 86_400.0, 500.0, 0.0, 5_000_000_000);
        let base = m.cost_per_mtok_resilient(160_000.0, 8, 4, 0, 500.0, &u);
        let plus2 = m.cost_per_mtok_resilient(160_000.0, 8, 4, 2, 500.0, &u);
        let per_rack = m.servers_per_rack(500.0).max(1) as f64;
        let day_frac = u.day_s / (m.rack.horizon_hours * 3600.0);
        let spare_usd = 2.0 * (160_000.0 + m.rack.fixed_cost_usd / per_rack) * day_frac;
        let expected = spare_usd / u.tokens_out as f64 * 1e6;
        assert!(
            ((plus2 - base) / expected - 1.0).abs() < 1e-9,
            "delta {} vs owned {expected}",
            plus2 - base
        );
    }

    #[test]
    fn lost_tokens_inflate_the_goodput_price() {
        // Same fleet, same energy, same streamed work: tokens a crash
        // invalidated shrink the denominator, so the faulty day costs
        // strictly more per *delivered* token.
        let m = model();
        let clean = day(4, 86_400.0, 500.0, 0.0, 5_000_000_000);
        let mut faulty = clean;
        faulty.lost_tokens = 1_000_000_000;
        faulty.down_replica_s = 4.0 * 3_600.0;
        faulty.powered_replica_s -= 4.0 * 3_600.0;
        let c_clean = m.cost_per_mtok_resilient(160_000.0, 8, 4, 0, 500.0, &clean);
        let c_faulty = m.cost_per_mtok_resilient(160_000.0, 8, 4, 0, 500.0, &faulty);
        assert!(c_faulty > c_clean, "{c_faulty} vs {c_clean}");
        assert_eq!(faulty.goodput_tokens(), 4_000_000_000);
    }

    #[test]
    fn down_time_bills_no_electricity() {
        // Moving replica-seconds from powered to down at equal energy
        // accounting cannot *raise* the bill: down time is owned but
        // draws nothing (the overhead term shrinks with powered time).
        let m = model();
        let awake = day(4, 86_400.0, 500.0, 0.0, 5_000_000_000);
        let mut crashed = awake;
        let moved = 2.0 * 3_600.0;
        crashed.down_replica_s = moved;
        crashed.powered_replica_s -= moved;
        crashed.energy_j -= 8.0 * 500.0 * moved;
        let c_awake = m.cost_per_mtok_resilient(160_000.0, 8, 4, 0, 500.0, &awake);
        let c_crashed = m.cost_per_mtok_resilient(160_000.0, 8, 4, 0, 500.0, &crashed);
        assert!(c_crashed < c_awake, "{c_crashed} vs {c_awake}");
    }

    #[test]
    #[should_panic(expected = "ledger overruns the day")]
    fn resilient_pricing_rejects_overcommitted_down_ledger() {
        let m = model();
        let mut u = day(2, 1_000.0, 500.0, 0.0, 1_000_000);
        u.down_replica_s = 2.0 * 1_000.0; // a third replica's worth
        m.cost_per_mtok_resilient(100_000.0, 8, 2, 0, 500.0, &u);
    }

    #[test]
    #[should_panic(expected = "ledger overruns the day")]
    fn diurnal_pricing_rejects_overcommitted_ledger() {
        let m = model();
        let mut u = day(2, 1_000.0, 500.0, 0.0, 1_000_000);
        u.powered_replica_s *= 2.0; // 4 replica-days on a 2-replica fleet
        m.cost_per_mtok_diurnal(100_000.0, 8, 2, 500.0, &u);
    }

    #[test]
    fn sustained_draw_caps() {
        let m = model();
        let uncapped = m.sustained_draw_w(Device::H100, 0.6, None);
        let capped = m.sustained_draw_w(Device::H100, 0.6, Some(400.0));
        assert!(uncapped > 600.0);
        assert_eq!(capped, 400.0);
    }

    #[test]
    fn wh_per_mtok_prices_the_electricity_share_exactly() {
        // The energy axis and the cost axis must agree: the
        // electricity component of $/Mtok is wh_per_mtok / 1000 *
        // usd_per_kwh, with no second place the PUE or overhead could
        // diverge.
        let m = model();
        let (draw, tps) = (600.0, 2_000.0);
        let wh = m.wh_per_mtok(draw, tps);
        let electricity_usd = m.server_power_w(draw) / 1000.0
            * m.rack.pue_ratio
            * m.rack.horizon_hours
            * m.rack.usd_per_kwh;
        let mtok_over_horizon = tps * 3600.0 * m.rack.horizon_hours / 1e6;
        let usd_per_mtok = electricity_usd / mtok_over_horizon;
        assert!(
            (wh / 1000.0 * m.rack.usd_per_kwh / usd_per_mtok - 1.0).abs() < 1e-12,
            "wh {wh} vs electricity {usd_per_mtok} $/Mtok"
        );
    }

    #[test]
    fn wh_per_mtok_disagg_reduces_to_sharded_for_one_pool() {
        let m = model();
        for (chips, tps) in [(1usize, 900.0), (8, 7_200.0), (12, 9_000.0)] {
            let sharded = m.wh_per_mtok_sharded(chips, 600.0, tps);
            let disagg = m.wh_per_mtok_disagg(&[(chips, 600.0)], tps);
            assert!(
                (sharded / disagg - 1.0).abs() < 1e-12,
                "chips {chips}: sharded {sharded} vs disagg {disagg}"
            );
        }
    }

    #[test]
    fn wh_per_mtok_plans_sum_their_pools() {
        use crate::analysis::disagg::{DisaggPlan, PhaseAffinityPlan, PoolSpec};
        use crate::analysis::parallel::ParallelismPlan;
        use crate::analysis::perfmodel::PrecisionMode;
        let m = model();
        let h100 = |plan| PoolSpec::new(Device::H100, PrecisionMode::fp8_dynamic(), plan);
        let plan = PhaseAffinityPlan::new(
            h100(ParallelismPlan::single().with_replicas(2)),
            DisaggPlan::new(h100(ParallelismPlan::single()), h100(ParallelismPlan::single())),
            512,
        );
        let mixed = m.wh_per_mtok_phase_affinity_plan(&plan, 600.0, 600.0, 600.0, 4_000.0);
        let merged = m.wh_per_mtok_disagg(&[(plan.total_chips(), 600.0)], 4_000.0);
        assert!((mixed / merged - 1.0).abs() < 1e-12, "{mixed} vs {merged}");
        let two_pool =
            m.wh_per_mtok_disagg_plan(&plan.disagg, 600.0, 600.0, 2_000.0);
        let two_merged = m.wh_per_mtok_disagg(&[(plan.disagg.total_chips(), 600.0)], 2_000.0);
        assert!((two_pool / two_merged - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pue_scales_wh_per_mtok_linearly() {
        let lean = InfraModel::new(RackConfig { pue_ratio: 1.1, ..RackConfig::a100_era() });
        let fat = InfraModel::new(RackConfig { pue_ratio: 1.4, ..RackConfig::a100_era() });
        let r = fat.wh_per_mtok(600.0, 1_000.0) / lean.wh_per_mtok(600.0, 1_000.0);
        assert!((r - 1.4 / 1.1).abs() < 1e-12, "{r}");
    }

    #[test]
    fn rack_cap_passes_through_when_budget_is_loose() {
        // 8 chips = 1 server-equivalent: 40 kW - 1.5 kW overhead
        // leaves far more than 8 x 700 W of chip budget.
        let m = model();
        let demands = vec![700.0; 8];
        let alloc = m.rack_capped_per_gpu_w(&demands);
        assert_eq!(alloc, demands);
    }

    #[test]
    fn rack_cap_binds_at_even_share_for_uniform_demand() {
        // 48 chips = 6 server-equivalents: 40 kW - 9 kW overhead =
        // 31 kW of chip budget < 48 x 700 W of demand.
        let m = model();
        let alloc = m.rack_capped_per_gpu_w(&vec![700.0; 48]);
        let even = 31_000.0 / 48.0;
        assert!(alloc.iter().all(|&w| (w - even).abs() < 1e-9), "{alloc:?}");
        let total: f64 = alloc.iter().sum();
        assert!((total - 31_000.0).abs() < 1e-6, "budget fully spent: {total}");
    }

    #[test]
    fn rack_cap_lets_hot_chip_borrow_cool_siblings_headroom() {
        // A 4.7 kW rack over one 8-chip server leaves 3.2 kW of chip
        // budget (an even share of 400 W). A pegged prefill chip among
        // seven 380 W decode siblings gets their unclaimed headroom:
        // 3200 - 7 x 380 = 540 W, not the 400 W even share PerRack's
        // in-step fallback would hand it.
        let tight = InfraModel::new(RackConfig {
            power_budget_w: 4_700.0,
            ..RackConfig::a100_era()
        });
        let mut demands = vec![380.0; 8];
        demands[0] = 700.0;
        let alloc = tight.rack_capped_per_gpu_w(&demands);
        assert!((alloc[0] - 540.0).abs() < 1e-9, "hot chip got {}", alloc[0]);
        for &w in &alloc[1..] {
            assert!((w - 380.0).abs() < 1e-9, "cool siblings keep their demand: {w}");
        }
    }
}
