//! The paper's TCO model (§2, Eq. 1; Figs. 1 & 9) generalized.
//!
//! Core identity (iso-traffic): with `N` servers of system B handling
//! the traffic, system A needs `N / R_Th` servers, so
//!
//! ```text
//! TCO_A / TCO_B = (C_S·R_SC·N/R_Th + C_I·R_IC·N/R_Th) / (C_S·N + C_I·N)
//! ```
//!
//! The paper's Fig. 1 grid assumes `C_S = C_I` and `R_IC = 1`; this
//! module keeps all four knobs free and layers a physical rack/infra
//! model on top (power-limited rack packing — §2.1's observation that
//! per-chip infra cost is inversely proportional to servers per rack).

pub mod rack;

pub use rack::{assumed_server_price_usd, DayUsage, InfraModel, RackConfig};

/// Relative-cost inputs of the paper's Eq. 1.
#[derive(Debug, Clone, Copy)]
pub struct TcoInputs {
    /// R_SC: ServerCost_A / ServerCost_B.
    pub server_cost_ratio: f64,
    /// R_IC: InfraCost_A / InfraCost_B (paper Fig. 1 assumes 1.0).
    pub infra_cost_ratio: f64,
    /// R_Th: Throughput_A / Throughput_B on the *target task*.
    pub throughput_ratio: f64,
    /// C_S weight: share of baseline TCO attributable to the server
    /// (paper Fig. 1 assumes C_S = C_I, i.e. 0.5).
    pub server_cost_share: f64,
}

impl TcoInputs {
    /// The paper's Fig. 1 setting: C_S = C_I, R_IC = 1.
    // simlint: allow(units) -- paper Eq. 1 notation (R_SC, R_Th are ratios)
    pub fn fig1(r_sc: f64, r_th: f64) -> Self {
        TcoInputs {
            server_cost_ratio: r_sc,
            infra_cost_ratio: 1.0,
            throughput_ratio: r_th,
            server_cost_share: 0.5,
        }
    }
}

/// Eq. 1: TCO_A / TCO_B. Values < 1 mean system A is cheaper for the
/// same traffic.
pub fn tco_ratio(inp: TcoInputs) -> f64 {
    assert!(inp.throughput_ratio > 0.0, "R_Th must be positive");
    assert!((0.0..=1.0).contains(&inp.server_cost_share));
    let cs = inp.server_cost_share;
    let ci = 1.0 - cs;
    (cs * inp.server_cost_ratio + ci * inp.infra_cost_ratio) / inp.throughput_ratio
}

/// The exact grid of paper Fig. 1: rows R_Th in {1.0 .. 0.3}, columns
/// R_SC in {1.0 .. 0.1}. Returns (r_th, r_sc, ratio) triples in the
/// paper's row-major order.
pub fn fig1_grid() -> Vec<(f64, f64, f64)> {
    let r_ths = [1.0, 0.9, 0.8, 0.7, 0.6, 0.5, 0.4, 0.3];
    let r_scs = [1.0, 0.9, 0.8, 0.7, 0.6, 0.5, 0.4, 0.3, 0.2, 0.1];
    let mut out = Vec::new();
    for &r_th in &r_ths {
        for &r_sc in &r_scs {
            out.push((r_th, r_sc, tco_ratio(TcoInputs::fig1(r_sc, r_th))));
        }
    }
    out
}

/// Break-even R_SC: the server-cost ratio at which A and B tie, given
/// R_Th (and the C_S share). Above this price ratio, A loses.
// simlint: allow(units) -- paper Eq. 1 notation (R_Th, R_IC are ratios)
pub fn breakeven_server_cost_ratio(r_th: f64, server_cost_share: f64, r_ic: f64) -> f64 {
    // Solve (cs·x + ci·r_ic) / r_th = 1. A zero server-cost share has
    // no break-even price (the server is free in the TCO), so reject
    // it instead of returning ±inf.
    assert!(
        server_cost_share > 0.0 && server_cost_share <= 1.0,
        "C_S share must be in (0, 1]"
    );
    let cs = server_cost_share;
    let ci = 1.0 - cs;
    (r_th - ci * r_ic) / cs
}

/// A named deployment scenario for Fig. 9-style analysis: a measured
/// throughput ratio annotated with the workload that produced it.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    // simlint: allow(units) -- paper Eq. 1 notation (R_Th is a ratio)
    pub r_th: f64,
    // simlint: allow(units) -- paper Eq. 1 notation (R_SC is a ratio)
    pub r_sc: f64,
}

impl Scenario {
    pub fn tco_ratio(&self) -> f64 {
        tco_ratio(TcoInputs::fig1(self.r_sc, self.r_th))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Fig. 1, transcribed. Rows: R_Th 1.0→0.3; cols R_SC 1.0→0.1.
    const FIG1_PAPER: [[f64; 10]; 8] = [
        [1.00, 0.95, 0.90, 0.85, 0.80, 0.75, 0.70, 0.65, 0.60, 0.55],
        [1.11, 1.06, 1.00, 0.94, 0.89, 0.83, 0.78, 0.72, 0.67, 0.61],
        [1.25, 1.19, 1.13, 1.06, 1.00, 0.94, 0.88, 0.81, 0.75, 0.69],
        [1.43, 1.36, 1.29, 1.21, 1.14, 1.07, 1.00, 0.93, 0.86, 0.79],
        [1.67, 1.58, 1.50, 1.42, 1.33, 1.25, 1.17, 1.08, 1.00, 0.92],
        [2.00, 1.90, 1.80, 1.70, 1.60, 1.50, 1.40, 1.30, 1.20, 1.10],
        [2.50, 2.38, 2.25, 2.13, 2.00, 1.88, 1.75, 1.63, 1.50, 1.38],
        [3.33, 3.17, 3.00, 2.83, 2.67, 2.50, 2.33, 2.17, 2.00, 1.83],
    ];

    #[test]
    fn reproduces_fig1_exactly() {
        let grid = fig1_grid();
        for (idx, &(r_th, r_sc, ratio)) in grid.iter().enumerate() {
            let row = idx / 10;
            let col = idx % 10;
            let paper = FIG1_PAPER[row][col];
            assert!(
                (ratio - paper).abs() < 0.005 + 1e-9,
                "R_Th={r_th} R_SC={r_sc}: got {ratio:.4}, paper {paper}"
            );
        }
    }

    #[test]
    fn equal_systems_tie() {
        assert!((tco_ratio(TcoInputs::fig1(1.0, 1.0)) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn faster_and_cheaper_always_wins() {
        let r = tco_ratio(TcoInputs::fig1(0.5, 1.2));
        assert!(r < 1.0);
    }

    #[test]
    fn monotonicity() {
        // TCO ratio decreases in R_Th and increases in R_SC.
        let base = tco_ratio(TcoInputs::fig1(0.5, 0.8));
        assert!(tco_ratio(TcoInputs::fig1(0.5, 0.9)) < base);
        assert!(tco_ratio(TcoInputs::fig1(0.6, 0.8)) > base);
    }

    #[test]
    fn infra_ratio_knob_matters() {
        // If A needs 2x the infra per server, it must be much faster.
        let mut inp = TcoInputs::fig1(1.0, 1.0);
        inp.infra_cost_ratio = 2.0;
        assert!(tco_ratio(inp) > 1.0);
    }

    #[test]
    fn breakeven_matches_grid() {
        // Row R_Th=0.7 crosses 1.00 at R_SC=0.4 in Fig. 1.
        let be = breakeven_server_cost_ratio(0.7, 0.5, 1.0);
        assert!((be - 0.4).abs() < 1e-9, "{be}");
        // Sanity: at the breakeven the ratio is exactly 1.
        let r = tco_ratio(TcoInputs::fig1(be, 0.7));
        assert!((r - 1.0).abs() < 1e-12);
    }

    #[test]
    fn server_share_zero_reduces_to_infra_only() {
        // With all cost in infra and R_IC=1, ratio = 1/R_Th.
        let mut inp = TcoInputs::fig1(0.123, 0.8);
        inp.server_cost_share = 0.0;
        assert!((tco_ratio(inp) - 1.25).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "R_Th must be positive")]
    fn zero_throughput_rejected() {
        tco_ratio(TcoInputs::fig1(1.0, 0.0));
    }

    #[test]
    #[should_panic(expected = "C_S share must be in (0, 1]")]
    fn zero_server_share_has_no_breakeven() {
        breakeven_server_cost_ratio(0.7, 0.0, 1.0);
    }
}
