//! Deterministic fault injection for the serving stack (DESIGN.md §14).
//!
//! A [`FaultPlan`] is a *sorted schedule* of fault events compiled
//! up-front — seeded draws (Poisson crash arrivals via
//! [`crate::util::rng::Rng::exp`]) happen at plan-construction time
//! only, so the cluster hot path replays a fixed event list and stays
//! bit-identical between the stepper and the event-driven fast-forward
//! (the cluster loops clamp every advancement target at the next fault
//! instant, making each fault a window boundary in both modes).
//!
//! The [`FaultDriver`] owns the schedule cursor plus the
//! capped-exponential-backoff retry queue for work lost to crashes:
//! victims are re-submitted from scratch (vLLM-style recompute — the
//! crashed replica's KV is gone, so there is nothing to resume), their
//! already-streamed tokens counted in `Metrics::lost_tokens` and their
//! destroyed context in `Metrics::recompute_tokens_wasted`.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use super::request::SeqId;
use crate::util::rng::Rng;
use crate::workload::trace::Request;

/// Which pool a replica-scoped fault targets. Colocated clusters only
/// have [`Pool::Primary`]; a `DisaggCluster` adds the prefill/decode
/// pools; `PhaseAffinityCluster` uses all three (Primary = its
/// colocated pool). Events aimed at a pool the cluster shape does not
/// have are ignored.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Pool {
    Primary,
    Prefill,
    Decode,
}

/// One kind of injected fault. Replica-scoped kinds carry their target;
/// link-scoped kinds apply to the cluster's KV-migration fabric.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The replica dies: resident KV lost, in-flight sequences bounced
    /// to the retry queue, ledger switches to the 0 W `down_s` arm.
    Crash { pool: Pool, replica: usize },
    /// A crashed replica comes back empty (repair completed). A repair
    /// for a replica that is not down is ignored.
    Repair { pool: Pool, replica: usize },
    /// Degraded mode: the replica keeps serving but its HBM bandwidth
    /// is multiplied by `factor` (0 < factor <= 1) — thermal
    /// throttling / partial-HBM fault.
    Derate { pool: Pool, replica: usize, factor: f64 },
    /// Degraded mode ends: bandwidth derate back to 1.0 (bit-exact
    /// identity, so post-repair trajectories match a healthy engine).
    DerateEnd { pool: Pool, replica: usize },
    /// The KV-migration link goes dark: chunked transfers in flight
    /// stall and resume when the link returns.
    LinkDown,
    /// The KV-migration link recovers.
    LinkUp,
}

/// A fault at a virtual-time instant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub t_s: f64,
    pub kind: FaultKind,
}

/// Capped exponential backoff for crash retries: attempt `k` (0-based)
/// waits `min(base_s * 2^k, cap_s)`; after `max_attempts` the request
/// is dropped (counted by the driver, surfaced in the run report).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    pub base_s: f64,
    pub cap_s: f64,
    pub max_attempts: u32,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { base_s: 0.05, cap_s: 2.0, max_attempts: 8 }
    }
}

impl RetryPolicy {
    /// Backoff delay before attempt `attempt` (0-based).
    pub fn delay_s(&self, attempt: u32) -> f64 {
        let exp = attempt.min(52); // 2^53 saturates f64 integer range
        (self.base_s * (1u64 << exp) as f64).min(self.cap_s)
    }
}

/// A sorted, replayable schedule of fault events. Construction is the
/// only place randomness may enter (seeded, via `util::rng`); the
/// driver consumes the schedule monotonically.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    pub fn new() -> Self {
        FaultPlan { events: Vec::new() }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Add one event (builder-style). Events may be pushed in any
    /// order; the plan is sorted on [`FaultPlan::compile`] / first use.
    pub fn with(mut self, t_s: f64, kind: FaultKind) -> Self {
        self.push(t_s, kind);
        self
    }

    pub fn push(&mut self, t_s: f64, kind: FaultKind) {
        debug_assert!(t_s.is_finite() && t_s >= 0.0, "fault at t={t_s}");
        self.events.push(FaultEvent { t_s, kind });
    }

    /// Crash `replica` at `t_s` and repair it `repair_s` later.
    pub fn crash_repair(self, pool: Pool, replica: usize, t_s: f64, repair_s: f64) -> Self {
        self.with(t_s, FaultKind::Crash { pool, replica })
            .with(t_s + repair_s, FaultKind::Repair { pool, replica })
    }

    /// Derate `replica`'s HBM bandwidth to `factor` over
    /// `[t_s, t_s + dur_s)`.
    pub fn derate_window(
        self,
        pool: Pool,
        replica: usize,
        t_s: f64,
        dur_s: f64,
        factor: f64,
    ) -> Self {
        debug_assert!(factor > 0.0 && factor <= 1.0, "derate factor {factor}");
        self.with(t_s, FaultKind::Derate { pool, replica, factor })
            .with(t_s + dur_s, FaultKind::DerateEnd { pool, replica })
    }

    /// KV-link outage over `[t_s, t_s + dur_s)`.
    pub fn link_outage(self, t_s: f64, dur_s: f64) -> Self {
        self.with(t_s, FaultKind::LinkDown).with(t_s + dur_s, FaultKind::LinkUp)
    }

    /// Seeded Poisson crash/repair process: exponential inter-crash
    /// gaps at `1/mtbf_s`, each crash repaired after `repair_s`,
    /// round-robin over `pool`'s `replicas`, within `[0, horizon_s)`.
    /// All draws happen here, at construction.
    pub fn poisson_crashes(
        mut self,
        seed: u64,
        pool: Pool,
        replicas: usize,
        mtbf_s: f64,
        repair_s: f64,
        horizon_s: f64,
    ) -> Self {
        debug_assert!(replicas > 0 && mtbf_s > 0.0);
        let mut rng = Rng::new(seed);
        let mut t_s = 0.0;
        let mut victim = 0usize;
        loop {
            t_s += rng.exp(1.0 / mtbf_s);
            if t_s >= horizon_s {
                break;
            }
            self = self.crash_repair(pool, victim, t_s, repair_s);
            victim = (victim + 1) % replicas;
        }
        self
    }

    /// Sort into the deterministic replay order: by time, ties broken
    /// by a stable kind rank (repairs before crashes at the same
    /// instant, so a zero-length outage is a no-op rather than a
    /// permanently-down replica) and then target identity.
    pub fn compile(mut self) -> Self {
        // Construction debug_asserts finiteness; a NaN smuggled past a
        // release build sorts as equal rather than aborting the run.
        self.events.sort_by(|a, b| {
            (a.t_s, rank(&a.kind), target(&a.kind))
                .partial_cmp(&(b.t_s, rank(&b.kind), target(&b.kind)))
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        self
    }

    /// The link outage windows `[down, up)` implied by the plan, for
    /// shifting chunked-transfer schedules. An unclosed `LinkDown`
    /// extends to infinity. Assumes a compiled (sorted) plan.
    pub fn link_outages(&self) -> Vec<(f64, f64)> {
        let mut out = Vec::new();
        let mut down_at: Option<f64> = None;
        for ev in &self.events {
            match ev.kind {
                FaultKind::LinkDown => {
                    if down_at.is_none() {
                        down_at = Some(ev.t_s);
                    }
                }
                FaultKind::LinkUp => {
                    if let Some(a) = down_at.take() {
                        if ev.t_s > a {
                            out.push((a, ev.t_s));
                        }
                    }
                }
                _ => {}
            }
        }
        if let Some(a) = down_at {
            out.push((a, f64::INFINITY));
        }
        out
    }
}

/// Rank for same-instant ordering: recoveries first, then degradations,
/// then crashes — so `crash@t, repair@t` (a zero-length outage) leaves
/// the replica up, matching the half-open `[down, up)` convention.
fn rank(k: &FaultKind) -> u8 {
    match k {
        FaultKind::LinkUp => 0,
        FaultKind::Repair { .. } => 1,
        FaultKind::DerateEnd { .. } => 2,
        FaultKind::Derate { .. } => 3,
        FaultKind::LinkDown => 4,
        FaultKind::Crash { .. } => 5,
    }
}

fn target(k: &FaultKind) -> (u8, usize) {
    match k {
        FaultKind::Crash { pool, replica }
        | FaultKind::Repair { pool, replica }
        | FaultKind::Derate { pool, replica, .. }
        | FaultKind::DerateEnd { pool, replica } => (*pool as u8, *replica),
        FaultKind::LinkDown | FaultKind::LinkUp => (u8::MAX, 0),
    }
}

/// What the driver hands the cluster loop next: either the next
/// scheduled fault, or a due retry of a crash victim.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultTick {
    Fault(FaultEvent),
    Retry { t_s: f64, id: SeqId },
}

impl FaultTick {
    pub fn t_s(&self) -> f64 {
        match self {
            FaultTick::Fault(ev) => ev.t_s,
            FaultTick::Retry { t_s, .. } => *t_s,
        }
    }
}

/// Schedule cursor + retry queue, consumed by a cluster loop. With an
/// empty plan the driver is inert: `is_active()` is false from the
/// first instant, every clamp is `min(t, ∞) = t`, and `register` is a
/// no-op — the run is structurally identical to a fault-free one,
/// which is what pins empty-plan bit-identity.
#[derive(Debug, Clone)]
pub struct FaultDriver {
    plan: FaultPlan,
    cursor: usize,
    retry: RetryPolicy,
    /// Due retries, ordered (t, id): ties resubmit in id order.
    queue: BinaryHeap<Reverse<(OrdF64, SeqId)>>,
    /// Original requests of everything ever submitted while faults
    /// were still possible — point lookups only (no iteration), so the
    /// map's order never feeds the schedule.
    registry: HashMap<SeqId, Request>,
    attempts: HashMap<SeqId, u32>,
    /// Victims that exhausted `max_attempts` and were dropped.
    pub dropped: Vec<SeqId>,
    /// Retries handed out (cluster loops also bump the serving
    /// engine's `Metrics::retries`; this is the driver-side total).
    pub retries_scheduled: u64,
}

/// Total order for finite f64 retry instants (no NaNs by construction).
#[derive(Debug, Clone, Copy, PartialEq)]
struct OrdF64(f64);

impl Eq for OrdF64 {}

impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Retry instants are finite by construction (backoff sums of
        // finite delays); NaN compares equal rather than panicking.
        self.0.partial_cmp(&other.0).unwrap_or(std::cmp::Ordering::Equal)
    }
}

impl FaultDriver {
    /// A driver that never fires: the fault-free fast path.
    pub fn none() -> Self {
        FaultDriver::new(FaultPlan::new(), RetryPolicy::default())
    }

    pub fn new(plan: FaultPlan, retry: RetryPolicy) -> Self {
        FaultDriver {
            plan: plan.compile(),
            cursor: 0,
            retry,
            queue: BinaryHeap::new(),
            registry: HashMap::new(),
            attempts: HashMap::new(),
            dropped: Vec::new(),
            retries_scheduled: 0,
        }
    }

    /// Anything left that can still perturb the run? Once false it
    /// stays false: the registry stops growing and the loops stop
    /// clamping on `next_event_time()`.
    pub fn is_active(&self) -> bool {
        self.cursor < self.plan.events.len() || !self.queue.is_empty()
    }

    pub fn has_retries(&self) -> bool {
        !self.queue.is_empty()
    }

    /// Instant of the next fault or retry (`∞` when neither remains).
    /// Cluster loops clamp every engine-advancement target here, which
    /// is what makes fault instants fast-forward window boundaries.
    pub fn next_event_time(&self) -> f64 {
        let t_fault = self
            .plan
            .events
            .get(self.cursor)
            .map_or(f64::INFINITY, |ev| ev.t_s);
        let t_retry = self.queue.peek().map_or(f64::INFINITY, |Reverse((t, _))| t.0);
        t_fault.min(t_retry)
    }

    /// Remember a request so a crash can resubmit it from scratch.
    /// No-op once the driver is inert, keeping fault-free runs free of
    /// bookkeeping.
    pub fn register(&mut self, r: &Request) {
        if self.is_active() {
            self.registry.insert(r.id, r.clone());
        }
    }

    /// Pop the next due tick at or before `t_s` (faults before retries
    /// at the same instant — a retry must not land on a replica that
    /// crashes in the same breath without the crash being applied
    /// first; the resubmission then simply re-queues it).
    pub fn next_due(&mut self, t_s: f64) -> Option<FaultTick> {
        let t_fault = self
            .plan
            .events
            .get(self.cursor)
            .map_or(f64::INFINITY, |ev| ev.t_s);
        let t_retry = self.queue.peek().map_or(f64::INFINITY, |Reverse((t, _))| t.0);
        if t_fault.min(t_retry) > t_s {
            return None;
        }
        if t_fault <= t_retry {
            let ev = self.plan.events[self.cursor];
            self.cursor += 1;
            Some(FaultTick::Fault(ev))
        } else {
            let Some(Reverse((t, id))) = self.queue.pop() else {
                debug_assert!(false, "peek said non-empty");
                return None;
            };
            Some(FaultTick::Retry { t_s: t.0, id })
        }
    }

    /// Queue a crash victim for retry with capped exponential backoff.
    /// Returns false (and records the drop) once `max_attempts` is
    /// exhausted.
    pub fn schedule_retry(&mut self, id: SeqId, now_s: f64) -> bool {
        let attempt = *self.attempts.get(&id).unwrap_or(&0);
        if attempt >= self.retry.max_attempts {
            self.dropped.push(id);
            return false;
        }
        self.attempts.insert(id, attempt + 1);
        let due = now_s + self.retry.delay_s(attempt);
        self.queue.push(Reverse((OrdF64(due), id)));
        self.retries_scheduled += 1;
        true
    }

    /// The original request for a retry tick. The returned request's
    /// `arrival` must be overridden to the retry instant by the caller
    /// (recompute-from-scratch: the fleet sees a fresh arrival).
    pub fn request_for(&self, id: SeqId) -> Option<&Request> {
        self.registry.get(&id)
    }

    /// Link outage windows of the compiled plan (see
    /// [`FaultPlan::link_outages`]).
    pub fn link_outages(&self) -> Vec<(f64, f64)> {
        self.plan.link_outages()
    }
}

/// Finish time of `work_s` seconds of link work starting at `start_s`,
/// given sorted outage windows `[down, up)`: transfer progress stalls
/// inside an outage and resumes after it (chunks already pipelined
/// through fabric buffers are unaffected — the stall applies to the
/// remaining active time). With no outages this is exactly
/// `start_s + work_s`, bit-identically.
pub fn finish_after(outages: &[(f64, f64)], start_s: f64, work_s: f64) -> f64 {
    let mut t_s = start_s;
    let mut rem_s = work_s;
    for &(down_s, up_s) in outages {
        if up_s <= t_s {
            continue;
        }
        let gap_s = (down_s - t_s).max(0.0);
        if rem_s <= gap_s {
            return t_s + rem_s;
        }
        rem_s -= gap_s;
        t_s = up_s;
    }
    t_s + rem_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::trace::TenantClass;

    fn req(id: u64) -> Request {
        Request {
            id,
            arrival: 0.0,
            prompt_len: 8,
            output_len: 4,
            class: TenantClass::Interactive,
        }
    }

    #[test]
    fn plan_compiles_sorted_with_recoveries_first_at_ties() {
        let plan = FaultPlan::new()
            .with(5.0, FaultKind::Crash { pool: Pool::Primary, replica: 0 })
            .with(5.0, FaultKind::Repair { pool: Pool::Primary, replica: 1 })
            .with(1.0, FaultKind::LinkDown)
            .compile();
        let ev = plan.events();
        assert_eq!(ev[0].kind, FaultKind::LinkDown);
        assert_eq!(ev[1].kind, FaultKind::Repair { pool: Pool::Primary, replica: 1 });
        assert_eq!(ev[2].kind, FaultKind::Crash { pool: Pool::Primary, replica: 0 });
    }

    #[test]
    fn poisson_plan_is_seed_deterministic_and_bounded() {
        let mk = || {
            FaultPlan::new()
                .poisson_crashes(42, Pool::Primary, 3, 50.0, 5.0, 200.0)
                .compile()
        };
        let a = mk();
        let b = mk();
        assert_eq!(a, b, "same seed, same plan");
        assert!(!a.is_empty(), "200s horizon at 50s MTBF crashes");
        for ev in a.events() {
            assert!(ev.t_s < 205.01, "repair may trail the horizon by repair_s only");
        }
        let c = FaultPlan::new()
            .poisson_crashes(43, Pool::Primary, 3, 50.0, 5.0, 200.0)
            .compile();
        assert_ne!(a, c, "different seed, different plan");
    }

    #[test]
    fn link_outages_pair_down_with_up() {
        let plan = FaultPlan::new()
            .link_outage(10.0, 2.0)
            .with(20.0, FaultKind::LinkDown)
            .compile();
        let w = plan.link_outages();
        assert_eq!(w[0], (10.0, 12.0));
        assert_eq!(w[1].0, 20.0);
        assert!(w[1].1.is_infinite(), "unclosed outage extends forever");
    }

    #[test]
    fn finish_after_stalls_inside_outages_and_is_identity_without() {
        let outages = [(10.0, 12.0), (20.0, 21.0)];
        // Entirely before the first outage.
        assert_eq!(finish_after(&outages, 0.0, 5.0), 5.0);
        // Straddles the first outage: 8s active before it, stall 2s.
        assert_eq!(finish_after(&outages, 2.0, 10.0), 14.0);
        // Starts inside an outage: waits for the link.
        assert_eq!(finish_after(&outages, 11.0, 1.0), 13.0);
        // Crosses both outages.
        assert_eq!(finish_after(&outages, 9.0, 12.0), 24.0);
        // No outages: bit-exact identity.
        assert_eq!(finish_after(&[], 3.5, 2.25), 5.75);
    }

    #[test]
    fn retry_backoff_caps_and_drops_after_max_attempts() {
        let pol = RetryPolicy { base_s: 0.1, cap_s: 0.5, max_attempts: 3 };
        assert_eq!(pol.delay_s(0), 0.1);
        assert_eq!(pol.delay_s(1), 0.2);
        assert_eq!(pol.delay_s(2), 0.4);
        assert_eq!(pol.delay_s(3), 0.5, "capped");
        assert_eq!(pol.delay_s(60), 0.5, "shift saturates safely");

        let mut fd = FaultDriver::new(
            FaultPlan::new().with(1.0, FaultKind::Crash { pool: Pool::Primary, replica: 0 }),
            pol,
        );
        fd.register(&req(7));
        for k in 0..3 {
            assert!(fd.schedule_retry(7, 10.0 * k as f64), "attempt {k} accepted");
            let tick = fd.next_due(f64::INFINITY).unwrap();
            match tick {
                FaultTick::Retry { id, .. } => assert_eq!(id, 7),
                other => panic!("expected retry, got {other:?}"),
            }
        }
        assert!(!fd.schedule_retry(7, 100.0), "attempt 3 dropped");
        assert_eq!(fd.dropped, vec![7]);
        assert_eq!(fd.retries_scheduled, 3);
    }

    #[test]
    fn driver_orders_faults_before_retries_at_same_instant() {
        let plan = FaultPlan::new().with(5.0, FaultKind::LinkDown);
        let mut fd = FaultDriver::new(plan, RetryPolicy { base_s: 5.0, cap_s: 5.0, max_attempts: 2 });
        fd.register(&req(3));
        assert!(fd.schedule_retry(3, 0.0)); // due at exactly 5.0
        assert_eq!(fd.next_event_time(), 5.0);
        assert!(matches!(fd.next_due(5.0), Some(FaultTick::Fault(_))));
        assert!(matches!(fd.next_due(5.0), Some(FaultTick::Retry { id: 3, .. })));
        assert!(fd.next_due(f64::INFINITY).is_none());
        assert!(!fd.is_active());
        assert_eq!(fd.request_for(3).unwrap().prompt_len, 8);
    }

    #[test]
    fn inert_driver_is_structurally_invisible() {
        let mut fd = FaultDriver::none();
        assert!(!fd.is_active());
        assert_eq!(fd.next_event_time(), f64::INFINITY);
        fd.register(&req(1));
        assert!(fd.request_for(1).is_none(), "inert driver records nothing");
        assert!(fd.next_due(f64::INFINITY).is_none());
    }
}
