//! Serving metrics: TTFT, TPOT, token/request throughput, energy.
//!
//! §5.2 notes TTFT/TPOT "do not facilitate comparisons across stages";
//! the engine therefore records both the classic latency metrics and
//! FLOPs-based throughput so benches can report either view.
//!
//! Latency samples are timestamped with the virtual time at which they
//! completed ([`TimedPercentiles`]), so open-loop runs can cut a
//! steady-state window out of the run (`pct_in`) instead of letting
//! warmup/cooldown transients pollute the percentiles. Accounting
//! rules under preemption (DESIGN.md §5):
//!
//! * TTFT is sampled exactly once per request, at its *first* token
//!   emission — a recompute re-prefill after preemption does not
//!   re-sample it (it bumps [`Metrics::restarts`] instead);
//! * `tokens_out` counts each delivered token exactly once — a token
//!   whose KV growth failed is rolled back and re-counted only when it
//!   is actually re-generated after the re-prefill.

use crate::util::stats::{Summary, TimedPercentiles};

#[derive(Debug, Default)]
pub struct Metrics {
    pub ttft: TimedPercentiles,
    pub tpot: TimedPercentiles,
    pub e2e_latency: TimedPercentiles,
    pub tokens_out: u64,
    pub tokens_in: u64,
    pub requests_done: u64,
    /// Re-prefills after preemption. Each one re-enters the prefill
    /// queue but does NOT contribute a second TTFT sample.
    pub restarts: u64,
    /// KV migrations received from a prefill pool (disaggregated
    /// serving; recorded by the decode engine at delivery).
    pub migrations: u64,
    /// KV bytes that crossed the scale-out fabric into this engine.
    pub kv_bytes_migrated: f64,
    /// Migrations rejected by decode-pool admission control and bounced
    /// back to colocated (`SeqRole::Full`) execution on the prefill
    /// engine that already holds the KV (recorded there).
    pub bounces: u64,
    pub steps: u64,
    /// Cumulative step-cost cache hits of this engine's backend
    /// (mirrored from `ExecutionBackend::cache_stats` after each step;
    /// 0 for non-memoizing backends). Summed across engines by
    /// [`Metrics::absorb`].
    pub step_cache_hits: u64,
    /// Cumulative step-cost cache misses (see `step_cache_hits`).
    pub step_cache_misses: u64,
    pub step_time: Summary,
    /// Integrated device energy (J) over the engine's whole timeline:
    /// busy steps at their modelled draw *plus* idle gaps at the
    /// device's idle draw. Always equals `energy_prefill_j +
    /// energy_decode_j + energy_idle_j`.
    pub energy_j: f64,
    /// Busy energy attributed to prefill steps (J).
    pub energy_prefill_j: f64,
    /// Busy energy attributed to decode steps (J).
    pub energy_decode_j: f64,
    /// Energy accrued at idle draw over the gaps between steps (J).
    /// The engine bills these gaps as they are skipped (idle-advance,
    /// `advance_to`) and the cluster closes the ledger at drain
    /// ([`Engine::close_ledger`](super::engine::Engine::close_ledger)),
    /// so `span + idle_s` covers the cluster makespan exactly.
    pub energy_idle_j: f64,
    /// Model FLOPs executed.
    pub flops: f64,
    /// Busy time covered by executed steps (s). For a single engine
    /// this equals the clock span actually spent serving; when metrics
    /// from several engines are [`Metrics::absorb`]ed it is the *sum*
    /// of their busy times — divide by the cluster makespan, not by
    /// `span`, for cluster-level rates.
    pub span: f64,
    /// Idle time accrued between steps (s), the complement of `span`
    /// on the engine's timeline. Summed across engines by `absorb`,
    /// like `span`.
    pub idle_s: f64,
    /// Time spent power-gated (autoscaler sleep state, s): the replica
    /// drew 0 W, so no energy accrues — only the timeline component.
    /// With an autoscaler in play, `span + idle_s + gated_s` covers
    /// the closed timeline; without one `gated_s` stays 0 and the
    /// PR 7 two-term identity is unchanged.
    pub gated_s: f64,
    /// Time spent crashed/under repair (s): the replica drew 0 W and
    /// served nothing. Fourth ledger arm; with fault injection in play
    /// `span + idle_s + gated_s + down_s` tiles the closed timeline.
    pub down_s: f64,
    /// Requests re-submitted through the fault-recovery retry queue
    /// (recorded on the engine that received the retry).
    pub retries: u64,
    /// Output tokens that had been generated (delivered to the stream)
    /// by sequences killed in a crash before they finished — goodput
    /// the fleet produced but could not complete.
    pub lost_tokens: u64,
    /// Context tokens (prompt + generated) whose compute was destroyed
    /// by a crash and must be recomputed from scratch on retry. Only
    /// sequences whose prefill had actually run are counted.
    pub recompute_tokens_wasted: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Sample TTFT for a request first emitted at `now` (virtual s).
    pub fn record_first_token(&mut self, arrival: f64, now: f64) {
        self.ttft.add(now, now - arrival);
    }

    /// A preempted request re-entered prefill (recompute preemption).
    pub fn record_restart(&mut self) {
        self.restarts += 1;
    }

    /// A KV migration of `bytes` landed on this engine (disaggregated
    /// prefill→decode handoff).
    pub fn record_migration(&mut self, bytes: f64) {
        self.migrations += 1;
        self.kv_bytes_migrated += bytes;
    }

    /// A migration was rejected by decode-pool admission control; the
    /// request fell back to colocated execution on this engine.
    pub fn record_bounce(&mut self) {
        self.bounces += 1;
    }

    pub fn record_finish(&mut self, arrival: f64, first_token: f64, now: f64, out_tokens: usize) {
        self.e2e_latency.add(now, now - arrival);
        if out_tokens > 1 {
            self.tpot.add(now, (now - first_token) / (out_tokens - 1) as f64);
        }
        self.requests_done += 1;
    }

    fn record_step(&mut self, dt: f64, watts: f64, flops: f64, new_tokens: usize) {
        self.steps += 1;
        self.step_time.add(dt);
        self.energy_j += watts * dt;
        self.flops += flops;
        self.tokens_out += new_tokens as u64;
        self.span += dt;
    }

    /// One executed prefill step: its energy lands in the prefill
    /// ledger and `prompt_tokens` (context tokens processed, recompute
    /// re-prefills included — they are real prefill work) accrue to
    /// `tokens_in`.
    pub fn record_prefill_step(
        &mut self,
        dt: f64,
        watts: f64,
        flops: f64,
        new_tokens: usize,
        prompt_tokens: usize,
    ) {
        self.energy_prefill_j += watts * dt;
        self.tokens_in += prompt_tokens as u64;
        self.record_step(dt, watts, flops, new_tokens);
    }

    /// One executed decode step: its energy lands in the decode ledger.
    pub fn record_decode_step(&mut self, dt: f64, watts: f64, flops: f64, new_tokens: usize) {
        self.energy_decode_j += watts * dt;
        self.record_step(dt, watts, flops, new_tokens);
    }

    /// An idle gap of `dt` seconds billed at the device's idle draw.
    /// Not a step: `steps`/`span`/`step_time` are untouched; the gap
    /// accrues to `idle_s` and the idle energy ledger.
    pub fn record_idle(&mut self, dt: f64, idle_w: f64) {
        debug_assert!(dt >= 0.0, "idle gap must be non-negative");
        self.energy_idle_j += idle_w * dt;
        self.energy_j += idle_w * dt;
        self.idle_s += dt;
    }

    /// A power-gated gap of `dt` seconds (autoscaler sleep): the
    /// replica is off, drawing 0 W — time accrues so the ledger still
    /// tiles the makespan, energy does not.
    pub fn record_gated(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0, "gated gap must be non-negative");
        self.gated_s += dt;
    }

    /// A crashed/under-repair gap of `dt` seconds: the replica is dead,
    /// drawing 0 W — time accrues to the `down_s` ledger arm so the
    /// closed timeline still tiles the makespan, energy does not.
    pub fn record_down(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0, "down gap must be non-negative");
        self.down_s += dt;
    }

    /// A crashed request was re-submitted to this engine through the
    /// fault-recovery retry queue.
    pub fn record_retry(&mut self) {
        self.retries += 1;
    }

    /// Merge another engine's metrics into this one (cluster rollup).
    /// Percentile samples keep their timestamps, so windowed queries
    /// remain valid on the shared virtual timeline.
    pub fn absorb(&mut self, other: &Metrics) {
        self.ttft.absorb(&other.ttft);
        self.tpot.absorb(&other.tpot);
        self.e2e_latency.absorb(&other.e2e_latency);
        self.tokens_out += other.tokens_out;
        self.tokens_in += other.tokens_in;
        self.requests_done += other.requests_done;
        self.restarts += other.restarts;
        self.migrations += other.migrations;
        self.kv_bytes_migrated += other.kv_bytes_migrated;
        self.bounces += other.bounces;
        self.steps += other.steps;
        self.step_cache_hits += other.step_cache_hits;
        self.step_cache_misses += other.step_cache_misses;
        self.step_time.absorb(&other.step_time);
        self.energy_j += other.energy_j;
        self.energy_prefill_j += other.energy_prefill_j;
        self.energy_decode_j += other.energy_decode_j;
        self.energy_idle_j += other.energy_idle_j;
        self.flops += other.flops;
        self.span += other.span;
        self.idle_s += other.idle_s;
        self.gated_s += other.gated_s;
        self.down_s += other.down_s;
        self.retries += other.retries;
        self.lost_tokens += other.lost_tokens;
        self.recompute_tokens_wasted += other.recompute_tokens_wasted;
    }

    /// Step-cost cache hit rate across every lookup the backend(s)
    /// served (0 when nothing was looked up / nothing memoizes).
    pub fn step_cache_hit_rate(&self) -> f64 {
        crate::coordinator::backend::CacheStats {
            hits: self.step_cache_hits,
            misses: self.step_cache_misses,
        }
        .hit_rate()
    }

    /// Mean device draw over the engine's whole covered timeline —
    /// busy steps *and* idle gaps (W; 0 when nothing ran). Once the
    /// cluster has closed every engine's ledger at the makespan, the
    /// merged value is the mean sustained per-engine draw, the figure
    /// rack packing and electricity pricing need.
    pub fn watts_mean(&self) -> f64 {
        let covered = self.span + self.idle_s + self.gated_s + self.down_s;
        if covered > 0.0 {
            self.energy_j / covered
        } else {
            0.0
        }
    }

    /// Output tokens per second over the covered span.
    pub fn tokens_per_sec(&self) -> f64 {
        if self.span == 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / self.span
        }
    }

    /// Achieved model FLOP/s.
    pub fn model_flops_per_sec(&self) -> f64 {
        if self.span == 0.0 {
            0.0
        } else {
            self.flops / self.span
        }
    }

    /// Joules per output token — the §2.1 power-vs-TCO bridge. Total
    /// energy (prefill + decode + idle) over delivered output tokens,
    /// the quantity TokenPowerBench-style references report (Llama3-70B
    /// ≈ 0.39 J/token on H100-FP8 is the sanity band).
    pub fn joules_per_token(&self) -> f64 {
        if self.tokens_out == 0 {
            0.0
        } else {
            self.energy_j / self.tokens_out as f64
        }
    }

    /// Prefill energy per processed input token (J; 0 when no prefill
    /// ran). Phase-attributed: idle energy is excluded.
    pub fn joules_per_token_in(&self) -> f64 {
        if self.tokens_in == 0 {
            0.0
        } else {
            self.energy_prefill_j / self.tokens_in as f64
        }
    }

    /// Decode energy per delivered output token (J; 0 when nothing was
    /// delivered). Phase-attributed: idle energy is excluded.
    pub fn joules_per_token_out(&self) -> f64 {
        if self.tokens_out == 0 {
            0.0
        } else {
            self.energy_decode_j / self.tokens_out as f64
        }
    }

    /// Fraction of the covered timeline spent idle (0 when nothing was
    /// covered).
    pub fn idle_frac(&self) -> f64 {
        let covered = self.span + self.idle_s + self.gated_s + self.down_s;
        if covered > 0.0 {
            self.idle_s / covered
        } else {
            0.0
        }
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} tokens_out={} span={:.2}s idle={:.2}s tok/s={:.1} \
             TTFT p50/p95={:.3}/{:.3}s TPOT p50/p95={:.4}/{:.4}s \
             J/token={:.2} J/tok_in={:.3} J/tok_out={:.2} W_mean={:.1} \
             model TFLOP/s={:.2} restarts={} migrations={} bounces={} \
             retries={} lost_tokens={} recompute_wasted={} down={:.2}s \
             cache_hit={:.3}",
            self.requests_done,
            self.tokens_out,
            self.span,
            self.idle_s,
            self.tokens_per_sec(),
            self.ttft.pct(50.0),
            self.ttft.pct(95.0),
            self.tpot.pct(50.0),
            self.tpot.pct(95.0),
            self.joules_per_token(),
            self.joules_per_token_in(),
            self.joules_per_token_out(),
            self.watts_mean(),
            self.model_flops_per_sec() / 1e12,
            self.restarts,
            self.migrations,
            self.bounces,
            self.retries,
            self.lost_tokens,
            self.recompute_tokens_wasted,
            self.down_s,
            self.step_cache_hit_rate(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_and_energy() {
        let mut m = Metrics::new();
        m.record_prefill_step(0.5, 400.0, 1e12, 10, 100);
        m.record_decode_step(0.5, 600.0, 1e12, 30);
        assert_eq!(m.tokens_out, 40);
        assert_eq!(m.tokens_in, 100);
        assert!((m.tokens_per_sec() - 40.0).abs() < 1e-9);
        assert!((m.energy_j - 500.0).abs() < 1e-9);
        assert!((m.energy_prefill_j - 200.0).abs() < 1e-9);
        assert!((m.energy_decode_j - 300.0).abs() < 1e-9);
        assert!((m.joules_per_token() - 12.5).abs() < 1e-9);
        assert!((m.joules_per_token_in() - 2.0).abs() < 1e-9);
        assert!((m.joules_per_token_out() - 7.5).abs() < 1e-9);
        assert!((m.model_flops_per_sec() - 2e12).abs() < 1e-3);
    }

    #[test]
    fn idle_gaps_accrue_energy_without_counting_as_steps() {
        let mut m = Metrics::new();
        m.record_decode_step(1.0, 500.0, 1e12, 10);
        m.record_idle(3.0, 100.0);
        assert_eq!(m.steps, 1, "idle is not a step");
        assert!((m.span - 1.0).abs() < 1e-12);
        assert!((m.idle_s - 3.0).abs() < 1e-12);
        assert!((m.energy_idle_j - 300.0).abs() < 1e-9);
        assert!((m.energy_j - 800.0).abs() < 1e-9, "busy + idle energy");
        // Mean draw over the whole covered timeline, not just busy.
        assert!((m.watts_mean() - 200.0).abs() < 1e-9);
        assert!((m.idle_frac() - 0.75).abs() < 1e-12);
        // The headline J/token includes idle energy — an idle-heavy
        // engine pays for its gaps.
        assert!((m.joules_per_token() - 80.0).abs() < 1e-9);
        // Phase attribution excludes it.
        assert!((m.joules_per_token_out() - 50.0).abs() < 1e-9);
        // The ledger identity the conservation tests lean on.
        let split = m.energy_prefill_j + m.energy_decode_j + m.energy_idle_j;
        assert!((split - m.energy_j).abs() < 1e-9);
    }

    #[test]
    fn latency_accounting() {
        let mut m = Metrics::new();
        m.record_first_token(0.0, 0.25);
        m.record_finish(0.0, 0.25, 2.25, 11);
        assert!((m.ttft.pct(50.0) - 0.25).abs() < 1e-9);
        assert!((m.tpot.pct(50.0) - 0.2).abs() < 1e-9);
        assert!((m.e2e_latency.pct(50.0) - 2.25).abs() < 1e-9);
        assert_eq!(m.requests_done, 1);
    }

    #[test]
    fn single_token_output_has_no_tpot() {
        let mut m = Metrics::new();
        m.record_finish(0.0, 0.1, 0.1, 1);
        assert_eq!(m.tpot.count(), 0);
    }

    #[test]
    fn windowed_percentiles_exclude_warmup() {
        let mut m = Metrics::new();
        // Cold-start request with a huge TTFT at t=1, then steady state.
        m.record_first_token(0.0, 1.0);
        for i in 0..20 {
            let t = 10.0 + i as f64;
            m.record_first_token(t - 0.05, t);
        }
        assert!(m.ttft.pct(100.0) > 0.9);
        assert!(m.ttft.pct_in(5.0, 40.0, 100.0) < 0.1);
    }

    #[test]
    fn absorb_merges_engines() {
        let mut a = Metrics::new();
        let mut b = Metrics::new();
        a.record_decode_step(1.0, 100.0, 1e12, 5);
        a.record_idle(1.0, 60.0);
        a.record_first_token(0.0, 0.5);
        a.record_finish(0.0, 0.5, 1.0, 5);
        b.record_prefill_step(1.0, 300.0, 3e12, 15, 128);
        b.record_first_token(0.0, 1.5);
        b.record_finish(0.0, 1.5, 2.0, 15);
        b.record_restart();
        a.absorb(&b);
        assert_eq!(a.tokens_out, 20);
        assert_eq!(a.tokens_in, 128);
        assert_eq!(a.requests_done, 2);
        assert_eq!(a.restarts, 1);
        assert_eq!(a.ttft.count(), 2);
        assert!((a.ttft.median() - 1.0).abs() < 1e-9);
        assert!((a.energy_j - 460.0).abs() < 1e-9);
        assert!((a.energy_prefill_j - 300.0).abs() < 1e-9);
        assert!((a.energy_decode_j - 100.0).abs() < 1e-9);
        assert!((a.energy_idle_j - 60.0).abs() < 1e-9);
        assert!((a.span - 2.0).abs() < 1e-9);
        assert!((a.idle_s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn migration_accounting_absorbs() {
        let mut a = Metrics::new();
        let mut b = Metrics::new();
        a.record_migration(1e6);
        b.record_migration(2e6);
        b.record_migration(3e6);
        a.record_bounce();
        b.record_bounce();
        a.absorb(&b);
        assert_eq!(a.migrations, 3);
        assert!((a.kv_bytes_migrated - 6e6).abs() < 1e-9);
        assert_eq!(a.bounces, 2);
    }

    #[test]
    fn cache_counters_absorb_and_rate() {
        let mut a = Metrics::new();
        let mut b = Metrics::new();
        assert_eq!(a.step_cache_hit_rate(), 0.0, "no lookups: rate 0");
        a.step_cache_hits = 3;
        a.step_cache_misses = 1;
        b.step_cache_hits = 5;
        b.step_cache_misses = 7;
        a.absorb(&b);
        assert_eq!(a.step_cache_hits, 8);
        assert_eq!(a.step_cache_misses, 8);
        assert!((a.step_cache_hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn gated_time_accrues_no_energy() {
        let mut m = Metrics::new();
        m.record_decode_step(1.0, 500.0, 1e12, 10);
        m.record_idle(1.0, 100.0);
        m.record_gated(2.0);
        assert!((m.gated_s - 2.0).abs() < 1e-12);
        assert!((m.energy_j - 600.0).abs() < 1e-9, "gating adds no joules");
        // Mean draw is over the full covered timeline, sleep included:
        // a replica that sleeps half the day halves its mean watts.
        assert!((m.watts_mean() - 150.0).abs() < 1e-9);
        assert!((m.idle_frac() - 0.25).abs() < 1e-12);
        let mut other = Metrics::new();
        other.record_gated(3.0);
        m.absorb(&other);
        assert!((m.gated_s - 5.0).abs() < 1e-12);
    }

    #[test]
    fn down_time_accrues_no_energy_and_absorbs() {
        let mut m = Metrics::new();
        m.record_decode_step(1.0, 500.0, 1e12, 10);
        m.record_idle(1.0, 100.0);
        m.record_down(2.0);
        assert!((m.down_s - 2.0).abs() < 1e-12);
        assert!((m.energy_j - 600.0).abs() < 1e-9, "downtime adds no joules");
        // Mean draw covers the down arm: a replica dead half the time
        // halves its mean watts.
        assert!((m.watts_mean() - 150.0).abs() < 1e-9);
        assert!((m.idle_frac() - 0.25).abs() < 1e-12);
        let mut other = Metrics::new();
        other.record_down(3.0);
        other.record_retry();
        other.lost_tokens = 7;
        other.recompute_tokens_wasted = 42;
        m.absorb(&other);
        assert!((m.down_s - 5.0).abs() < 1e-12);
        assert_eq!(m.retries, 1);
        assert_eq!(m.lost_tokens, 7);
        assert_eq!(m.recompute_tokens_wasted, 42);
    }

    #[test]
    fn report_is_formatted() {
        let mut m = Metrics::new();
        m.record_decode_step(1.0, 100.0, 1e12, 5);
        let r = m.report();
        assert!(r.contains("tokens_out=5"));
        assert!(r.contains("tok/s=5.0"));
        assert!(r.contains("restarts=0"));
        assert!(r.contains("W_mean=100.0"));
        assert!(r.contains("idle=0.00s"));
    }
}
