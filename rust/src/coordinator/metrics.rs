//! Serving metrics: TTFT, TPOT, token/request throughput, energy.
//!
//! §5.2 notes TTFT/TPOT "do not facilitate comparisons across stages";
//! the engine therefore records both the classic latency metrics and
//! FLOPs-based throughput so benches can report either view.

use crate::util::stats::{Percentiles, Summary};

#[derive(Debug, Default)]
pub struct Metrics {
    pub ttft: Percentiles,
    pub tpot: Percentiles,
    pub e2e_latency: Percentiles,
    pub tokens_out: u64,
    pub tokens_in: u64,
    pub requests_done: u64,
    pub steps: u64,
    pub step_time: Summary,
    /// Integrated device energy (J).
    pub energy_j: f64,
    /// Model FLOPs executed.
    pub flops: f64,
    /// Clock span covered (s).
    pub span: f64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_first_token(&mut self, arrival: f64, now: f64) {
        self.ttft.add(now - arrival);
    }

    pub fn record_finish(&mut self, arrival: f64, first_token: f64, now: f64, out_tokens: usize) {
        self.e2e_latency.add(now - arrival);
        if out_tokens > 1 {
            self.tpot.add((now - first_token) / (out_tokens - 1) as f64);
        }
        self.requests_done += 1;
    }

    pub fn record_step(&mut self, dt: f64, watts: f64, flops: f64, new_tokens: usize) {
        self.steps += 1;
        self.step_time.add(dt);
        self.energy_j += watts * dt;
        self.flops += flops;
        self.tokens_out += new_tokens as u64;
        self.span += dt;
    }

    /// Output tokens per second over the covered span.
    pub fn tokens_per_sec(&self) -> f64 {
        if self.span == 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / self.span
        }
    }

    /// Achieved model FLOP/s.
    pub fn model_flops_per_sec(&self) -> f64 {
        if self.span == 0.0 {
            0.0
        } else {
            self.flops / self.span
        }
    }

    /// Joules per output token — the §2.1 power-vs-TCO bridge.
    pub fn joules_per_token(&self) -> f64 {
        if self.tokens_out == 0 {
            0.0
        } else {
            self.energy_j / self.tokens_out as f64
        }
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} tokens_out={} span={:.2}s tok/s={:.1} \
             TTFT p50/p95={:.3}/{:.3}s TPOT p50/p95={:.4}/{:.4}s \
             J/token={:.2} model TFLOP/s={:.2}",
            self.requests_done,
            self.tokens_out,
            self.span,
            self.tokens_per_sec(),
            self.ttft.pct(50.0),
            self.ttft.pct(95.0),
            self.tpot.pct(50.0),
            self.tpot.pct(95.0),
            self.joules_per_token(),
            self.model_flops_per_sec() / 1e12,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_and_energy() {
        let mut m = Metrics::new();
        m.record_step(0.5, 400.0, 1e12, 10);
        m.record_step(0.5, 600.0, 1e12, 30);
        assert_eq!(m.tokens_out, 40);
        assert!((m.tokens_per_sec() - 40.0).abs() < 1e-9);
        assert!((m.energy_j - 500.0).abs() < 1e-9);
        assert!((m.joules_per_token() - 12.5).abs() < 1e-9);
        assert!((m.model_flops_per_sec() - 2e12).abs() < 1e-3);
    }

    #[test]
    fn latency_accounting() {
        let mut m = Metrics::new();
        m.record_first_token(0.0, 0.25);
        m.record_finish(0.0, 0.25, 2.25, 11);
        assert!((m.ttft.pct(50.0) - 0.25).abs() < 1e-9);
        assert!((m.tpot.pct(50.0) - 0.2).abs() < 1e-9);
        assert!((m.e2e_latency.pct(50.0) - 2.25).abs() < 1e-9);
        assert_eq!(m.requests_done, 1);
    }

    #[test]
    fn single_token_output_has_no_tpot() {
        let mut m = Metrics::new();
        m.record_finish(0.0, 0.1, 0.1, 1);
        assert_eq!(m.tpot.count(), 0);
    }

    #[test]
    fn report_is_formatted() {
        let mut m = Metrics::new();
        m.record_step(1.0, 100.0, 1e12, 5);
        let r = m.report();
        assert!(r.contains("tokens_out=5"));
        assert!(r.contains("tok/s=5.0"));
    }
}
