//! Cluster simulator: a virtual-time event loop over the router's
//! engine pool, plus the open-loop SLO load sweep built on it.
//!
//! `Router::drain_closed_batch` drains each engine independently —
//! fine for closed batches, wrong for open-loop traffic, where
//! arrivals and step completions interleave on one timeline (its old
//! `run_to_completion` name is deprecated). [`Cluster::run`]
//! merges a streaming arrival source (any `Iterator<Item = Request>`,
//! e.g. [`TraceGenerator`](crate::workload::trace::TraceGenerator))
//! with per-engine step completions:
//!
//! 1. while the next arrival lies in the future, every engine steps
//!    forward ([`Engine::step_until`]) — a step that begins before the
//!    arrival may finish past it, exactly as on real hardware;
//! 2. at the arrival instant the request is routed
//!    ([`Router::submit_at`]); an idle target engine's clock is lifted
//!    to the arrival, a busy one simply queues it;
//! 3. once the source is exhausted, engines drain.
//!
//! Engines interact only through routing decisions, which happen at
//! arrival instants — so between two arrivals each engine can advance
//! independently without violating the shared timeline. This is what
//! makes TTFT honest under Poisson traffic: every request is admitted
//! at its true arrival, and its TTFT is measured from that arrival.
//!
//! On top of the loop, [`max_sustainable_qps`] binary-searches the
//! highest arrival rate whose steady-state (windowed) TTFT/TPOT p95
//! still meets an [`SloSpec`] — the goodput that
//! [`InfraModel::cost_per_mtok`](crate::tco::InfraModel::cost_per_mtok)
//! turns into $/Mtok-at-SLO.

use super::backend::{ExecutionBackend, SimBackend};
use super::engine::{Engine, EngineConfig};
use super::kv_cache::KvCacheConfig;
use super::metrics::Metrics;
use super::router::{EngineRating, RoutePolicy, Router};
use crate::analysis::parallel::{CapacityError, ParallelismPlan};
use crate::analysis::perfmodel::{PrecisionMode, StepConfig};
use crate::hwsim::spec::Device;
use crate::workload::llama;
use crate::workload::llama::LlamaConfig;
use crate::workload::trace::{Request, TraceConfig, TraceGenerator};

pub struct Cluster<B: ExecutionBackend> {
    pub router: Router<B>,
    /// Safety cap on total executed steps across the run (guards
    /// against infeasible workloads spinning the virtual clock).
    pub step_cap: usize,
}

impl<B: ExecutionBackend> Cluster<B> {
    pub fn new(router: Router<B>) -> Self {
        Cluster { router, step_cap: 50_000_000 }
    }

    /// Run the event loop over an arrival stream. Returns true when
    /// every submitted request finished (drained) within the step cap.
    pub fn run(&mut self, arrivals: impl IntoIterator<Item = Request>) -> bool {
        let mut left = self.step_cap;
        for r in arrivals {
            // Advance every engine to the arrival instant on the
            // shared timeline (busy engines may overshoot by the step
            // in flight; idle ones stop short and are lifted below).
            for e in self.router.engines.iter_mut() {
                let taken = e.step_until(r.arrival, left);
                left = left.saturating_sub(taken);
            }
            if left == 0 {
                return false;
            }
            self.router.submit_at(&r);
        }
        // Arrival source exhausted: drain.
        for e in self.router.engines.iter_mut() {
            let s0 = e.metrics.steps;
            let ok = e.run_to_completion(left);
            left = left.saturating_sub((e.metrics.steps - s0) as usize);
            if !ok {
                return false;
            }
        }
        true
    }

    /// Slowest engine's virtual completion time.
    pub fn makespan(&self) -> f64 {
        self.router.makespan()
    }

    /// Cluster-level rollup of every engine's metrics. Latency samples
    /// keep their shared-timeline timestamps, so windowed percentiles
    /// remain meaningful; `span` becomes summed busy time (divide
    /// token counts by [`Cluster::makespan`] for cluster rates).
    pub fn merged_metrics(&self) -> Metrics {
        let mut m = Metrics::new();
        for e in &self.router.engines {
            m.absorb(&e.metrics);
        }
        m
    }

    pub fn preemptions(&self) -> u64 {
        self.router.engines.iter().map(|e| e.preemptions()).sum()
    }
}

/// Homogeneous simulated cluster of *sharded* model instances: the
/// plan's full deployment shape is honored — `plan.replicas` engines,
/// each one a `plan.tp x plan.pp`-chip instance of `model` on `dev`.
/// The KV pool is sized per instance from the device spec through the
/// HBM capacity check, so an infeasible (model x device x plan)
/// deployment is a typed error, not a cluster that happily simulates
/// impossible hardware. Least-loaded routing, batch cap 64 — the
/// `sim_cluster` conventions.
pub fn sharded_sim_cluster(
    model: &'static LlamaConfig,
    dev: Device,
    prec: PrecisionMode,
    plan: ParallelismPlan,
) -> Result<Cluster<SimBackend>, CapacityError> {
    let w_bytes = prec.weight_bytes_per_elem();
    let n_instances = plan.replicas.max(1);
    let mut engines = Vec::with_capacity(n_instances);
    for _ in 0..n_instances {
        let mut cfg = EngineConfig::for_instance(model, dev, plan, w_bytes, 2.0)?;
        cfg.batcher.max_batch = 64;
        let backend = SimBackend::new(model, StepConfig::new(dev, prec).with_plan(plan));
        engines.push(Engine::new(cfg, backend));
    }
    let ratings =
        vec![EngineRating { prefill_score: 1.0, decode_score: 1.0 }; n_instances];
    Ok(Cluster::new(Router::new(engines, ratings, RoutePolicy::LeastLoaded)))
}

/// Homogeneous simulated cluster for sweeps, examples and benches:
/// `n_engines` single-chip (TP=1) engines serving llama-8b — the
/// paper's own measurement shape. KV pool sized from device HBM (FP8
/// weights halve the weight footprint), least-loaded routing, batch
/// cap 64. Multi-chip deployments go through [`sharded_sim_cluster`].
pub fn sim_cluster(dev: Device, prec: PrecisionMode, n_engines: usize) -> Cluster<SimBackend> {
    let model = llama::by_name("llama-8b").unwrap();
    let w_bytes = prec.weight_bytes_per_elem();
    let engines: Vec<Engine<SimBackend>> = (0..n_engines)
        .map(|_| {
            let kv =
                KvCacheConfig::from_device(model, dev.spec().hbm_cap, w_bytes, 2.0, 16, 0.05);
            let backend = SimBackend::new(model, StepConfig::new(dev, prec));
            let mut cfg = EngineConfig::new(kv);
            cfg.batcher.max_batch = 64;
            Engine::new(cfg, backend)
        })
        .collect();
    let ratings =
        vec![EngineRating { prefill_score: 1.0, decode_score: 1.0 }; n_engines];
    Cluster::new(Router::new(engines, ratings, RoutePolicy::LeastLoaded))
}

/// Latency service-level objective for the load sweep, evaluated on
/// steady-state percentiles (a window of the run's makespan that
/// excludes warmup and cooldown transients).
#[derive(Debug, Clone, Copy)]
pub struct SloSpec {
    pub ttft_p95_s: f64,
    pub tpot_p95_s: f64,
    /// Fraction of the makespan discarded at the start of the window.
    pub warmup_frac: f64,
    /// Fraction discarded at the end (queue-drain tail).
    pub cooldown_frac: f64,
}

impl SloSpec {
    /// Interactive serving: TTFT p95 <= 2 s, TPOT p95 <= 50 ms.
    pub fn interactive() -> Self {
        SloSpec {
            ttft_p95_s: 2.0,
            tpot_p95_s: 0.050,
            warmup_frac: 0.1,
            cooldown_frac: 0.1,
        }
    }

    /// Steady-state window [t0, t1] for a run spanning `makespan`.
    pub fn window(&self, makespan: f64) -> (f64, f64) {
        (
            makespan * self.warmup_frac,
            makespan * (1.0 - self.cooldown_frac),
        )
    }
}

/// One measured operating point of the load sweep.
#[derive(Debug, Clone)]
pub struct LoadPoint {
    /// Offered arrival rate (requests/s across the whole cluster).
    pub qps: f64,
    pub drained: bool,
    /// Meets the SLO on steady-state percentiles.
    pub feasible: bool,
    pub ttft_p95: f64,
    pub tpot_p95: f64,
    /// Goodput: output tokens/s over the makespan, all engines.
    pub tokens_per_sec: f64,
    /// Mean device draw while serving (W per engine/chip).
    pub watts_mean: f64,
    pub requests_done: u64,
    pub preemptions: u64,
}

/// Steady-state p95; falls back to the whole run when the window holds
/// no samples (short runs), and to 0 (vacuously met) when the whole
/// run has none either — e.g. TPOT on single-token outputs.
fn p95_or_whole(p: &crate::util::stats::TimedPercentiles, t0: f64, t1: f64) -> f64 {
    let w = p.pct_in(t0, t1, 95.0);
    if !w.is_nan() {
        return w;
    }
    let whole = p.pct(95.0);
    if whole.is_nan() {
        0.0
    } else {
        whole
    }
}

/// Measure one operating point: a fresh cluster serving `n_requests`
/// Poisson arrivals at `qps`, judged against `slo` on the steady-state
/// window.
pub fn measure_load<B, C, T>(
    mk_cluster: &C,
    trace_at: &T,
    qps: f64,
    n_requests: usize,
    seed: u64,
    slo: &SloSpec,
) -> LoadPoint
where
    B: ExecutionBackend,
    C: Fn() -> Cluster<B>,
    T: Fn(f64) -> TraceConfig,
{
    let mut cluster = mk_cluster();
    let gen = TraceGenerator::new(trace_at(qps), seed);
    let drained = cluster.run(gen.stream(n_requests));
    let m = cluster.merged_metrics();
    let makespan = cluster.makespan();
    let (t0, t1) = slo.window(makespan);
    let ttft_p95 = p95_or_whole(&m.ttft, t0, t1);
    let tpot_p95 = p95_or_whole(&m.tpot, t0, t1);
    let feasible = drained
        && m.requests_done > 0
        && ttft_p95 <= slo.ttft_p95_s
        && tpot_p95 <= slo.tpot_p95_s;
    LoadPoint {
        qps,
        drained,
        feasible,
        ttft_p95,
        tpot_p95,
        tokens_per_sec: if makespan > 0.0 {
            m.tokens_out as f64 / makespan
        } else {
            0.0
        },
        watts_mean: if m.span > 0.0 { m.energy_j / m.span } else { 0.0 },
        requests_done: m.requests_done,
        preemptions: cluster.preemptions(),
    }
}

/// Search bracket and trial shape for [`max_sustainable_qps`].
#[derive(Debug, Clone, Copy)]
pub struct SweepConfig {
    pub qps_lo: f64,
    pub qps_hi: f64,
    /// Bisection refinements after the lo/hi probes.
    pub iters: usize,
    /// Poisson arrivals per probe.
    pub n_requests: usize,
    pub seed: u64,
}

impl SweepConfig {
    pub fn new(qps_lo: f64, qps_hi: f64) -> Self {
        SweepConfig { qps_lo, qps_hi, iters: 6, n_requests: 240, seed: 7 }
    }
}

/// Outcome of [`max_sustainable_qps`]: the best SLO-feasible point
/// found (None when even `qps_lo` violates the SLO) and every probe.
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    pub best: Option<LoadPoint>,
    pub probes: Vec<LoadPoint>,
}

/// Binary-search the highest offered QPS whose steady-state TTFT/TPOT
/// p95 meet `slo`. Builds a fresh cluster per probe (the search is
/// over *independent* open-loop runs, not a single warm system), so
/// `mk_cluster` is a factory. Deterministic for a fixed seed.
pub fn max_sustainable_qps<B, C, T>(
    mk_cluster: &C,
    trace_at: &T,
    slo: &SloSpec,
    cfg: &SweepConfig,
) -> SweepOutcome
where
    B: ExecutionBackend,
    C: Fn() -> Cluster<B>,
    T: Fn(f64) -> TraceConfig,
{
    assert!(cfg.qps_lo > 0.0 && cfg.qps_hi > cfg.qps_lo, "need 0 < lo < hi");
    let probe =
        |qps: f64| measure_load(mk_cluster, trace_at, qps, cfg.n_requests, cfg.seed, slo);
    let mut probes = Vec::new();
    let lo_pt = probe(cfg.qps_lo);
    let lo_feasible = lo_pt.feasible;
    probes.push(lo_pt.clone());
    if !lo_feasible {
        return SweepOutcome { best: None, probes };
    }
    let hi_pt = probe(cfg.qps_hi);
    probes.push(hi_pt.clone());
    if hi_pt.feasible {
        // Even the ceiling meets the SLO; report it rather than
        // pretending the search converged.
        return SweepOutcome { best: Some(hi_pt), probes };
    }
    let (mut lo, mut hi) = (cfg.qps_lo, cfg.qps_hi);
    let mut best = lo_pt;
    for _ in 0..cfg.iters {
        let mid = 0.5 * (lo + hi);
        let pt = probe(mid);
        let feasible = pt.feasible;
        probes.push(pt.clone());
        if feasible {
            best = pt;
            lo = mid;
        } else {
            hi = mid;
        }
    }
    SweepOutcome { best: Some(best), probes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::perfmodel::{PrecisionMode, StepConfig};
    use crate::coordinator::backend::SimBackend;
    use crate::coordinator::engine::{Engine, EngineConfig};
    use crate::coordinator::kv_cache::KvCacheConfig;
    use crate::coordinator::router::{EngineRating, RoutePolicy, Router};
    use crate::hwsim::spec::Device;
    use crate::workload::llama::by_name;

    fn engine(total_blocks: usize) -> Engine<SimBackend> {
        let kv = KvCacheConfig { block_tokens: 16, total_blocks };
        let backend = SimBackend::new(
            by_name("llama-8b").unwrap(),
            StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()),
        );
        Engine::new(EngineConfig::new(kv), backend)
    }

    fn cluster(n_engines: usize, blocks: usize) -> Cluster<SimBackend> {
        let engines: Vec<_> = (0..n_engines).map(|_| engine(blocks)).collect();
        let ratings = vec![EngineRating { prefill_score: 1.0, decode_score: 1.0 }; n_engines];
        Cluster::new(Router::new(engines, ratings, RoutePolicy::RoundRobin))
    }

    fn req(id: u64, arrival: f64, p: usize, o: usize) -> Request {
        Request { id, arrival, prompt_len: p, output_len: o }
    }

    #[test]
    fn arrivals_admitted_at_their_own_time_across_engines() {
        let mut c = cluster(2, 10_000);
        // Round-robin: r0 -> e0 at t=0, r1 -> e1 at t=3.
        let ok = c.run(vec![req(0, 0.0, 128, 16), req(1, 3.0, 128, 16)]);
        assert!(ok);
        let m = c.merged_metrics();
        assert_eq!(m.requests_done, 2);
        // Each request's first token comes after its OWN arrival, and
        // neither TTFT contains the 3 s gap.
        for e in &c.router.engines {
            for s in e.sequences() {
                assert!(s.first_token_at.unwrap() >= s.arrival);
            }
        }
        assert!(m.ttft.pct(100.0) < 1.0, "TTFT leaked the arrival gap");
        assert!(c.makespan() >= 3.0, "shared clock must cover the last arrival");
    }

    #[test]
    fn busy_engine_queues_arrival_idle_engine_starts_at_arrival() {
        let mut c = cluster(1, 10_000);
        // Long first request; the second arrives mid-service and must
        // wait (its TTFT includes genuine queueing delay), not warp.
        let ok = c.run(vec![req(0, 0.0, 2048, 256), req(1, 0.001, 64, 8)]);
        assert!(ok);
        let e = &c.router.engines[0];
        let s1 = e.sequences().find(|s| s.id == 1).unwrap();
        assert!(s1.first_token_at.unwrap() > 0.001);
        assert_eq!(e.metrics.requests_done, 2);
    }

    #[test]
    fn step_cap_aborts_instead_of_spinning() {
        let mut c = cluster(1, 10_000);
        c.step_cap = 3;
        assert!(!c.run(vec![req(0, 0.0, 64, 512)]));
    }

    #[test]
    fn sweep_none_when_slo_unmeetable() {
        // TTFT SLO of ~0: even a near-idle system fails.
        let slo = SloSpec {
            ttft_p95_s: 1e-9,
            tpot_p95_s: 1e-9,
            warmup_frac: 0.1,
            cooldown_frac: 0.1,
        };
        let cfg = SweepConfig { iters: 3, n_requests: 20, seed: 1, ..SweepConfig::new(0.5, 4.0) };
        let out = max_sustainable_qps(&|| cluster(2, 10_000), &TraceConfig::chat, &slo, &cfg);
        assert!(out.best.is_none());
        assert_eq!(out.probes.len(), 1, "stops after the infeasible floor");
    }

    #[test]
    fn sweep_finds_feasible_point_and_it_meets_slo() {
        let slo = SloSpec::interactive();
        let cfg =
            SweepConfig { iters: 4, n_requests: 60, seed: 7, ..SweepConfig::new(0.25, 64.0) };
        let out = max_sustainable_qps(&|| cluster(2, 20_000), &TraceConfig::chat, &slo, &cfg);
        let best = out.best.expect("near-idle chat load must meet a 2s/50ms SLO");
        assert!(best.feasible);
        assert!(best.qps >= 0.25);
        assert!(best.ttft_p95 <= slo.ttft_p95_s);
        assert!(best.tpot_p95 <= slo.tpot_p95_s);
        assert!(best.tokens_per_sec > 0.0);
        assert!(best.watts_mean > 0.0);
    }

    #[test]
    fn sim_cluster_factory_serves() {
        let mut c = sim_cluster(Device::H100, PrecisionMode::fp8_static(), 2);
        assert_eq!(c.router.engines.len(), 2);
        assert!(c.run(vec![req(0, 0.0, 64, 8), req(1, 0.5, 64, 8)]));
        assert_eq!(c.merged_metrics().requests_done, 2);
    }

    #[test]
    fn sharded_cluster_serves_70b_and_rejects_single_chip() {
        use crate::analysis::parallel::ParallelismPlan;
        let m70 = by_name("llama-70b").unwrap();
        // 70B BF16 on one H100 chip: typed capacity rejection.
        let err = sharded_sim_cluster(
            m70,
            Device::H100,
            PrecisionMode::Bf16,
            ParallelismPlan::single(),
        );
        assert!(err.is_err(), "70B BF16 must not fit one chip");
        // The same model at TP=4 FP8, twice replicated, is a working
        // two-engine pool.
        let mut c = sharded_sim_cluster(
            m70,
            Device::H100,
            PrecisionMode::fp8_dynamic(),
            ParallelismPlan::tp(4).with_replicas(2),
        )
        .expect("70B fits at tp4");
        assert_eq!(c.router.engines.len(), 2);
        assert!(c.run(vec![req(0, 0.0, 64, 8), req(1, 0.5, 64, 8)]));
        assert_eq!(c.merged_metrics().requests_done, 2);
    }
}
