//! Cluster simulator: a virtual-time event loop over the router's
//! engine pool, plus the open-loop SLO load sweep built on it.
//!
//! `Router::drain_closed_batch` drains each engine independently —
//! fine for closed batches, wrong for open-loop traffic, where
//! arrivals and step completions interleave on one timeline (its old
//! `run_to_completion` alias is gone as of 0.4). [`Cluster::run`]
//! merges a streaming arrival source (any `Iterator<Item = Request>`,
//! e.g. [`TraceGenerator`](crate::workload::trace::TraceGenerator))
//! with per-engine step completions:
//!
//! 1. while the next arrival lies in the future, every engine steps
//!    forward ([`Engine::step_until`]) — a step that begins before the
//!    arrival may finish past it, exactly as on real hardware;
//! 2. at the arrival instant the request is routed
//!    ([`Router::submit_at`]); an idle target engine's clock is lifted
//!    to the arrival, a busy one simply queues it;
//! 3. once the source is exhausted, engines drain.
//!
//! Engines interact only through routing decisions, which happen at
//! arrival instants — so between two arrivals each engine can advance
//! independently without violating the shared timeline. This is what
//! makes TTFT honest under Poisson traffic: every request is admitted
//! at its true arrival, and its TTFT is measured from that arrival.
//!
//! On top of the loop, [`max_sustainable_qps`] binary-searches the
//! highest arrival rate whose steady-state (windowed) TTFT/TPOT p95
//! still meets an [`SloSpec`] — the goodput that
//! [`InfraModel::cost_per_mtok`](crate::tco::InfraModel::cost_per_mtok)
//! turns into $/Mtok-at-SLO.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet, VecDeque};

use super::backend::{ExecutionBackend, SimBackend};
use super::engine::{Engine, EngineConfig};
use super::faults::{self, FaultDriver, FaultEvent, FaultKind, FaultTick, Pool};
use super::kv_cache::KvCacheConfig;
use super::metrics::Metrics;
use super::request::{MigratedRequest, SeqId};
use super::router::{EngineRating, RoutePolicy, Router};
use crate::analysis::disagg::{DisaggPlan, PhaseAffinityPlan, PoolSpec};
use crate::analysis::parallel::{CapacityError, ParallelismPlan};
use crate::analysis::perfmodel::{PrecisionMode, StepConfig};
use crate::hwsim::interconnect::KvLink;
use crate::hwsim::spec::Device;
use crate::workload::llama;
use crate::workload::llama::LlamaConfig;
use crate::workload::trace::{Request, TraceConfig, TraceGenerator};

#[cfg(test)]
use crate::workload::trace::TenantClass;

/// A serving system the SLO load sweep can drive: anything that
/// serves an open-loop arrival stream on a shared virtual timeline
/// and reports merged metrics. Implemented by [`Cluster`] (colocated
/// pools) and [`DisaggCluster`] (disaggregated prefill/decode pools),
/// so `measure_load` / `max_sustainable_qps` price both on the same
/// $/Mtok-at-SLO axis.
pub trait ServeSim {
    /// Serve an arrival stream to completion. False when the step cap
    /// was exhausted or the workload cannot drain.
    fn serve<I: IntoIterator<Item = Request>>(&mut self, arrivals: I) -> bool;
    /// Rollup of every engine's metrics (all pools).
    fn merged_metrics(&self) -> Metrics;
    /// Slowest engine's virtual completion time.
    fn makespan(&self) -> f64;
    /// Total preemptions across all pools.
    fn preemptions(&self) -> u64;
}

pub struct Cluster<B: ExecutionBackend> {
    pub router: Router<B>,
    /// Safety cap on total executed steps across the run (guards
    /// against infeasible workloads spinning the virtual clock).
    pub step_cap: usize,
    /// Fault schedule + crash-retry queue. Inert by default
    /// ([`FaultDriver::none`]): every clamp is `min(t, inf) = t` and
    /// the pump loops never fire, so fault-free runs are structurally
    /// identical to pre-fault builds (pinned by the event-equivalence
    /// fuzzer's empty-plan fingerprints).
    pub faults: FaultDriver,
}

impl<B: ExecutionBackend> Cluster<B> {
    pub fn new(router: Router<B>) -> Self {
        Cluster { router, step_cap: 50_000_000, faults: FaultDriver::none() }
    }

    /// Attach a fault schedule (builder-style). The driver survives
    /// the run, so callers can inspect `faults.dropped` /
    /// `faults.retries_scheduled` afterwards.
    pub fn with_faults(mut self, faults: FaultDriver) -> Self {
        self.faults = faults;
        self
    }

    /// Run the event loop over an arrival stream. Returns true when
    /// every submitted request finished (drained) within the step cap.
    pub fn run(&mut self, arrivals: impl IntoIterator<Item = Request>) -> bool {
        // The driver is moved out for the run (it and the router are
        // borrowed mutably together in the pump) and restored before
        // returning, so post-run inspection works.
        let mut faults = std::mem::replace(&mut self.faults, FaultDriver::none());
        let ok = self.run_faulty(arrivals, &mut faults);
        self.faults = faults;
        ok
    }

    fn run_faulty(
        &mut self,
        arrivals: impl IntoIterator<Item = Request>,
        faults: &mut FaultDriver,
    ) -> bool {
        let mut left = self.step_cap;
        for r in arrivals {
            // Apply every fault/retry tick before the arrival. Each
            // tick advances the fleet to its own instant first, so
            // fault instants bound every fast-forward window — the
            // stepper and event modes see identical trajectories.
            if !self.pump_faults(r.arrival, faults, &mut left) {
                return false;
            }
            // Advance every engine to the arrival instant on the
            // shared timeline (busy engines may overshoot by the step
            // in flight; idle ones stop short and are lifted below).
            // `step_to` skips engines whose next-event hint says they
            // have nothing to run before the arrival.
            if !self.router.step_to(r.arrival, &mut left) {
                return false;
            }
            faults.register(&r);
            if self.router.any_up() {
                self.router.submit_at(&r);
            } else {
                // The whole pool is down: the arrival waits in the
                // retry queue (burning one backoff attempt).
                faults.schedule_retry(r.id, r.arrival);
            }
        }
        // Arrival source exhausted: drain, fault-aware. While ticks
        // remain, serve in windows bounded by the next tick instant;
        // once the driver is inert, fall through to the plain drain.
        // Fault events scheduled past the end of all served work are
        // dropped — the run ends at the makespan of real work.
        loop {
            let busy = self.router.engines.iter().any(|e| e.pending() > 0);
            if !busy && !faults.has_retries() {
                break;
            }
            let t_next = faults.next_event_time();
            if t_next.is_finite() {
                if !self.router.step_to(t_next, &mut left) {
                    return false;
                }
                if !self.pump_faults(t_next, faults, &mut left) {
                    return false;
                }
                continue;
            }
            for e in self.router.engines.iter_mut() {
                let s0 = e.metrics.steps;
                let ok = e.run_to_completion(left);
                left = left.saturating_sub((e.metrics.steps - s0) as usize);
                if !ok {
                    return false;
                }
            }
        }
        // Close every engine's energy ledger at the makespan: engines
        // that drained early idle (at idle draw) until the slowest one
        // finishes — still-down replicas bill the tail on the 0 W
        // `down_s` arm — so summed busy + idle + gated + down time
        // tiles the whole run.
        self.router.close_ledgers(self.router.makespan());
        true
    }

    /// Apply every fault/retry tick due at or before `t`, stepping the
    /// pool to each tick instant first so a tick lands on a fleet that
    /// has served everything preceding it.
    fn pump_faults(&mut self, t: f64, faults: &mut FaultDriver, left: &mut usize) -> bool {
        while let Some(tick) = faults.next_due(t) {
            if !self.router.step_to(tick.t_s(), left) {
                return false;
            }
            match tick {
                FaultTick::Fault(ev) => self.apply_fault(&ev, faults),
                FaultTick::Retry { t_s, id } => {
                    if !self.router.any_up() {
                        // Still nowhere to run: re-queue with backoff.
                        faults.schedule_retry(id, t_s);
                    } else if let Some(mut r) = faults.request_for(id).cloned() {
                        // Recompute from scratch: the fleet sees a
                        // fresh arrival at the retry instant.
                        r.arrival = t_s;
                        self.router.submit_retry_at(&r);
                    }
                }
            }
        }
        true
    }

    /// Apply one scheduled fault. A colocated cluster only has the
    /// `Primary` pool; events aimed at other pools (or out-of-range
    /// replicas) are ignored, per the [`Pool`] contract.
    fn apply_fault(&mut self, ev: &FaultEvent, faults: &mut FaultDriver) {
        let n = self.router.engines.len();
        match ev.kind {
            FaultKind::Crash { pool: Pool::Primary, replica } if replica < n => {
                let lost = self.router.crash_engine(replica, ev.t_s);
                for id in lost.ids {
                    faults.schedule_retry(id, ev.t_s);
                }
            }
            FaultKind::Repair { pool: Pool::Primary, replica } if replica < n => {
                self.router.repair_engine(replica, ev.t_s);
            }
            FaultKind::Derate { pool: Pool::Primary, replica, factor } if replica < n => {
                self.router.set_derate(replica, factor);
            }
            FaultKind::DerateEnd { pool: Pool::Primary, replica } if replica < n => {
                self.router.set_derate(replica, 1.0);
            }
            _ => {}
        }
    }

    /// Slowest engine's virtual completion time.
    pub fn makespan(&self) -> f64 {
        self.router.makespan()
    }

    /// Cluster-level rollup of every engine's metrics. Latency samples
    /// keep their shared-timeline timestamps, so windowed percentiles
    /// remain meaningful; `span` becomes summed busy time (divide
    /// token counts by [`Cluster::makespan`] for cluster rates).
    pub fn merged_metrics(&self) -> Metrics {
        let mut m = Metrics::new();
        for e in &self.router.engines {
            m.absorb(&e.metrics);
        }
        m
    }

    pub fn preemptions(&self) -> u64 {
        self.router.engines.iter().map(|e| e.preemptions()).sum()
    }
}

impl<B: ExecutionBackend> ServeSim for Cluster<B> {
    fn serve<I: IntoIterator<Item = Request>>(&mut self, arrivals: I) -> bool {
        self.run(arrivals)
    }

    fn merged_metrics(&self) -> Metrics {
        Cluster::merged_metrics(self)
    }

    fn makespan(&self) -> f64 {
        Cluster::makespan(self)
    }

    fn preemptions(&self) -> u64 {
        Cluster::preemptions(self)
    }
}

/// Power state of one replica in an [`AutoscaledCluster`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ReplicaState {
    /// Serving traffic (idle gaps billed at idle draw).
    Active,
    /// Waking from sleep: becomes Active at `ready_at_s`. The
    /// provisioning window is billed at idle draw — the replica is
    /// powered (booting, loading weights) but serves nothing.
    Starting { ready_at_s: f64 },
    /// Power-gated: 0 W. The gap is billed as gated time
    /// ([`Metrics::gated_s`](crate::coordinator::metrics::Metrics))
    /// when the replica wakes or the run closes.
    Sleeping,
}

/// Scale policy for [`AutoscaledCluster`]: windowed queue-depth
/// thresholds with a fixed decision cadence on the virtual timeline.
/// Deterministic — no randomness, no wall-clock.
#[derive(Debug, Clone, Copy)]
pub struct AutoscalerConfig {
    /// Floor on Active replicas; never scales below (>= 1).
    pub min_replicas: usize,
    /// Wake a sleeping replica when the windowed mean of queued
    /// sequences per active replica exceeds this.
    pub scale_up_depth: f64,
    /// Sleep a drained replica when the windowed mean falls below
    /// this. Must sit below `scale_up_depth` (hysteresis band).
    pub scale_down_depth: f64,
    /// Sleep-to-Active latency (boot + weight load), seconds.
    pub provisioning_delay_s: f64,
    /// Seconds between scale decisions on the virtual timeline.
    pub decision_interval_s: f64,
    /// Depth samples averaged per decision (smooths Poisson noise).
    pub depth_window: usize,
}

impl Default for AutoscalerConfig {
    fn default() -> Self {
        AutoscalerConfig {
            min_replicas: 1,
            scale_up_depth: 4.0,
            scale_down_depth: 0.5,
            provisioning_delay_s: 30.0,
            decision_interval_s: 10.0,
            depth_window: 3,
        }
    }
}

/// A scheduled autoscaler event on the shared virtual timeline. The
/// controller runs off a min-heap of these, interleaved with external
/// arrivals in global time order: periodic decision `Tick`s (each one
/// re-arms the next) and provisioning-complete `Ready` events pushed
/// by scale-up decisions. At equal times `Ready` fires before `Tick`
/// — a replica whose provisioning window ends exactly on a decision
/// boundary counts as Active in that decision — and events at an
/// arrival's instant fire before the arrival is routed.
#[derive(Debug, Clone, Copy)]
enum ScaleEvent {
    /// Provisioning window over: replica flips Starting -> Active.
    Ready { at: f64, replica: usize },
    /// Periodic scale decision.
    Tick { at: f64 },
}

impl ScaleEvent {
    fn at(&self) -> f64 {
        match *self {
            ScaleEvent::Ready { at, .. } | ScaleEvent::Tick { at } => at,
        }
    }

    /// Total-order key: time, then Ready-before-Tick, then replica
    /// index (full determinism when two Ready events coincide).
    fn key(&self) -> (f64, u8, usize) {
        match *self {
            ScaleEvent::Ready { at, replica } => (at, 0, replica),
            ScaleEvent::Tick { at } => (at, 1, 0),
        }
    }
}

impl PartialEq for ScaleEvent {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for ScaleEvent {}

impl PartialOrd for ScaleEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for ScaleEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        let (ta, ka, ra) = self.key();
        let (tb, kb, rb) = other.key();
        ta.total_cmp(&tb).then(ka.cmp(&kb)).then(ra.cmp(&rb))
    }
}

/// A replica fleet that power-gates to load: replicas sleep at 0 W
/// when windowed queue depth runs low and wake — after a provisioning
/// delay — when it runs high. Pairs with the idle-aware energy ledger:
/// per replica, `span + idle_s + gated_s` tiles the makespan exactly,
/// so the fleet's mean draw honestly reflects gating (the quantity
/// [`InfraModel::cost_per_mtok_diurnal`](crate::tco::InfraModel::cost_per_mtok_diurnal)
/// prices over a day).
///
/// Mechanics, all on the shared virtual timeline of [`Cluster::run`],
/// driven by a min-heap of `ScaleEvent`s (decision ticks +
/// provisioning completions) interleaved with arrivals in global time
/// order:
///
/// * scale decisions fire at a fixed cadence; each samples mean
///   queued-per-active-replica into a short window and compares the
///   window mean against the hysteresis band;
/// * scale-up wakes the lowest-index sleeping replica (one per
///   decision): its gated gap is closed on the ledger and it turns
///   [`ReplicaState::Starting`], Active only `provisioning_delay_s`
///   later — arrivals in between keep queueing on the old fleet;
/// * scale-down sleeps the highest-index drained Active replica, never
///   dropping below `min_replicas` Active;
/// * arrivals route to the least-pending Active replica (lowest index
///   on ties) — Starting and Sleeping replicas take no work, so the
///   provisioning delay is a real capacity lag, not bookkeeping.
///
/// Deterministic for a fixed arrival stream, and O(active) per event:
/// sleeping replicas park behind a `+inf` next-event hint just like
/// drained engines in [`Router::step_to`].
pub struct AutoscaledCluster<B: ExecutionBackend> {
    pub engines: Vec<Engine<B>>,
    pub states: Vec<ReplicaState>,
    pub cfg: AutoscalerConfig,
    /// Safety cap on total executed steps across the run.
    pub step_cap: usize,
    /// Completed wake transitions (sleep -> starting).
    pub scale_ups: u64,
    /// Completed sleep transitions (active -> sleeping).
    pub scale_downs: u64,
    /// Pending controller events (decision ticks + provisioning
    /// completions), fired in global time order against arrivals.
    events: BinaryHeap<Reverse<ScaleEvent>>,
    depth_samples: VecDeque<f64>,
    /// Next-event hints, same contract as [`Router::step_to`]:
    /// `-inf` = recheck, `+inf` = idle/sleeping with nothing queued.
    hints: Vec<f64>,
    /// Fault schedule + crash-retry queue (inert by default).
    pub faults: FaultDriver,
    /// Crashed-and-unrepaired overlay, orthogonal to the power state:
    /// a down replica takes no work, is skipped by scale decisions and
    /// bills its outage on the 0 W `down_s` arm.
    down: Vec<bool>,
}

impl<B: ExecutionBackend> AutoscaledCluster<B> {
    /// The first `cfg.min_replicas` replicas start Active, the rest
    /// asleep — the fleet grows into its peak instead of idling at it.
    pub fn new(engines: Vec<Engine<B>>, cfg: AutoscalerConfig) -> Self {
        assert!(cfg.min_replicas >= 1, "autoscaler needs at least one active replica");
        assert!(cfg.min_replicas <= engines.len(), "min_replicas exceeds fleet size");
        assert!(
            cfg.scale_down_depth < cfg.scale_up_depth,
            "hysteresis band must be non-empty"
        );
        assert!(cfg.decision_interval_s > 0.0 && cfg.provisioning_delay_s >= 0.0);
        let n = engines.len();
        let states = (0..n)
            .map(|i| {
                if i < cfg.min_replicas {
                    ReplicaState::Active
                } else {
                    ReplicaState::Sleeping
                }
            })
            .collect();
        let mut events = BinaryHeap::new();
        events.push(Reverse(ScaleEvent::Tick { at: cfg.decision_interval_s }));
        AutoscaledCluster {
            engines,
            states,
            cfg,
            step_cap: 50_000_000,
            scale_ups: 0,
            scale_downs: 0,
            events,
            depth_samples: VecDeque::with_capacity(cfg.depth_window),
            hints: vec![f64::NEG_INFINITY; n],
            faults: FaultDriver::none(),
            down: vec![false; n],
        }
    }

    /// Attach a fault schedule (builder-style).
    pub fn with_faults(mut self, faults: FaultDriver) -> Self {
        self.faults = faults;
        self
    }

    /// Replicas currently Active (serving-eligible): awake and not
    /// crashed.
    pub fn active_replicas(&self) -> usize {
        (0..self.engines.len())
            .filter(|&i| matches!(self.states[i], ReplicaState::Active) && !self.down[i])
            .count()
    }

    /// Advance every Active replica to `t` (hint-gated, so parked
    /// replicas cost nothing). False when the step cap runs out.
    fn step_to(&mut self, t: f64, left: &mut usize) -> bool {
        for i in 0..self.engines.len() {
            if self.hints[i] >= t {
                continue;
            }
            if !matches!(self.states[i], ReplicaState::Active) || self.down[i] {
                // Starting/Sleeping replicas hold no work by
                // construction (routing targets Active only), and a
                // crash empties its replica.
                self.hints[i] = f64::INFINITY;
                continue;
            }
            let e = &mut self.engines[i];
            let s0 = e.metrics.steps;
            e.step_until(t, *left);
            *left = left.saturating_sub((e.metrics.steps - s0) as usize);
            if e.pending() > 0 && e.clock() < t {
                return false;
            }
            self.hints[i] = if e.pending() == 0 { f64::INFINITY } else { e.clock().max(t) };
        }
        true
    }

    /// Fire one controller event from the heap. A `Ready` flips its
    /// Starting replica to Active at the exact provisioning-end
    /// instant (its window billed at idle draw); a `Tick` advances the
    /// fleet to the decision time, decides, and re-arms the cadence.
    fn fire(&mut self, ev: ScaleEvent, left: &mut usize) -> bool {
        match ev {
            ScaleEvent::Ready { at, replica } => {
                debug_assert!(
                    matches!(self.states[replica], ReplicaState::Starting { .. }),
                    "Ready event for a replica that is not provisioning"
                );
                self.engines[replica].close_ledger(at);
                self.states[replica] = ReplicaState::Active;
                self.hints[replica] = f64::NEG_INFINITY;
            }
            ScaleEvent::Tick { at } => {
                if !self.step_to(at, left) {
                    return false;
                }
                self.decide(at);
                self.events.push(Reverse(ScaleEvent::Tick {
                    at: at + self.cfg.decision_interval_s,
                }));
            }
        }
        true
    }

    /// One scale decision at virtual time `t`. Replicas whose
    /// provisioning ended at or before `t` are already Active: their
    /// `Ready` events order ahead of this tick on the heap.
    fn decide(&mut self, t: f64) {
        let n_active = self.active_replicas();
        let queued: usize = (0..self.engines.len())
            .filter(|&i| matches!(self.states[i], ReplicaState::Active) && !self.down[i])
            .map(|i| self.engines[i].pending())
            .sum();
        self.depth_samples.push_back(queued as f64 / n_active.max(1) as f64);
        if self.depth_samples.len() > self.cfg.depth_window.max(1) {
            self.depth_samples.pop_front();
        }
        let mean: f64 =
            self.depth_samples.iter().sum::<f64>() / self.depth_samples.len() as f64;
        if mean > self.cfg.scale_up_depth {
            // Wake the lowest-index sleeper, one per decision — the
            // cadence itself rate-limits ramp speed.
            if let Some(i) = (0..self.engines.len())
                .find(|&i| matches!(self.states[i], ReplicaState::Sleeping))
            {
                self.engines[i].close_ledger_gated(t);
                let ready_at_s = t + self.cfg.provisioning_delay_s;
                self.states[i] = ReplicaState::Starting { ready_at_s };
                self.events.push(Reverse(ScaleEvent::Ready { at: ready_at_s, replica: i }));
                self.scale_ups += 1;
            }
        } else if mean < self.cfg.scale_down_depth && n_active > self.cfg.min_replicas {
            // Sleep the highest-index drained Active replica (down
            // replicas are not candidates: their outage bills on the
            // `down_s` arm, not as a voluntary 0 W gate).
            if let Some(i) = (0..self.engines.len())
                .rev()
                .find(|&i| {
                    matches!(self.states[i], ReplicaState::Active)
                        && !self.down[i]
                        && self.engines[i].pending() == 0
                })
            {
                self.engines[i].close_ledger(t);
                self.states[i] = ReplicaState::Sleeping;
                self.scale_downs += 1;
                self.hints[i] = f64::INFINITY;
            }
        }
    }

    /// Serve an arrival stream to completion, making scale decisions
    /// at the configured cadence. Returns true when everything
    /// drained within the step cap.
    pub fn run(&mut self, arrivals: impl IntoIterator<Item = Request>) -> bool {
        let mut faults = std::mem::replace(&mut self.faults, FaultDriver::none());
        let ok = self.run_faulty(arrivals, &mut faults);
        self.faults = faults;
        ok
    }

    fn run_faulty(
        &mut self,
        arrivals: impl IntoIterator<Item = Request>,
        faults: &mut FaultDriver,
    ) -> bool {
        let mut left = self.step_cap;
        for r in arrivals {
            // Fire every controller event (decision tick or
            // provisioning completion) and fault/retry tick at or
            // before this arrival, merged in global time order —
            // controller first at exact ties, so a replica ready at a
            // fault instant is up before the fault lands. Events at
            // the arrival instant fire before the arrival is routed.
            if !self.pump_to(r.arrival, faults, &mut left) {
                return false;
            }
            if !self.step_to(r.arrival, &mut left) {
                return false;
            }
            faults.register(&r);
            let target = (0..self.engines.len())
                .filter(|&i| {
                    matches!(self.states[i], ReplicaState::Active) && !self.down[i]
                })
                .min_by_key(|&i| self.engines[i].pending());
            match target {
                Some(target) => {
                    let e = &mut self.engines[target];
                    e.advance_to(r.arrival);
                    e.submit(&r);
                    self.hints[target] = f64::NEG_INFINITY;
                }
                // Every Active replica is down: the arrival waits in
                // the retry queue. Without faults the min_replicas
                // floor guarantees a target, so bail as before.
                None if faults.is_active() => {
                    faults.schedule_retry(r.id, r.arrival);
                }
                None => return false,
            }
        }
        // Drain, fault-aware. Controller events past the last arrival
        // stay on the heap unfired exactly as before — no new work can
        // appear, so further scale decisions are moot (replicas still
        // Starting bill their tail at idle draw via `close_to`). Only
        // fault ticks, and the retries they spawn, still fire.
        loop {
            let busy = self.engines.iter().any(|e| e.pending() > 0);
            if !busy && !faults.has_retries() {
                break;
            }
            let t_next = faults.next_event_time();
            if t_next.is_finite() {
                if !self.step_to(t_next, &mut left) {
                    return false;
                }
                if !self.pump_ticks(t_next, faults, &mut left) {
                    return false;
                }
                continue;
            }
            for e in self.engines.iter_mut() {
                let s0 = e.metrics.steps;
                let ok = e.run_to_completion(left);
                left = left.saturating_sub((e.metrics.steps - s0) as usize);
                if !ok {
                    return false;
                }
            }
        }
        // Close every ledger at the makespan: powered replicas bill
        // the tail at idle draw, sleeping ones as gated (0 W) time and
        // crashed ones as down (0 W) time, so per replica
        // span + idle_s + gated_s + down_s == makespan.
        let end = self.makespan();
        self.close_to(end);
        true
    }

    /// Fire controller events and fault ticks due at or before `t`,
    /// merged in global time order (controller wins exact ties).
    fn pump_to(&mut self, t: f64, faults: &mut FaultDriver, left: &mut usize) -> bool {
        loop {
            let t_scale = match self.events.peek() {
                Some(&Reverse(ev)) => ev.at(),
                None => f64::INFINITY,
            };
            let t_fault = faults.next_event_time();
            if t_scale > t && t_fault > t {
                return true;
            }
            if t_scale <= t_fault {
                let Some(Reverse(ev)) = self.events.pop() else { return true };
                if !self.fire(ev, left) {
                    return false;
                }
            } else {
                let Some(tick) = faults.next_due(t_fault) else { return true };
                if !self.step_to(tick.t_s(), left) {
                    return false;
                }
                self.apply_tick(tick, faults);
            }
        }
    }

    /// Apply fault/retry ticks due at or before `t` (drain phase: the
    /// controller heap stays parked, matching the fault-free drain).
    fn pump_ticks(&mut self, t: f64, faults: &mut FaultDriver, left: &mut usize) -> bool {
        while let Some(tick) = faults.next_due(t) {
            if !self.step_to(tick.t_s(), left) {
                return false;
            }
            self.apply_tick(tick, faults);
        }
        true
    }

    /// Apply one fault/retry tick to the fleet. Crashes only land on
    /// up, Active replicas: a Sleeping or Starting replica holds no
    /// work and draws nothing (or boot-idle), so its failure has no
    /// serving consequence the autoscaler would not immediately cover
    /// by waking another replica — such events are ignored, keeping
    /// the three-way power ledger (idle/gated/down) unambiguous.
    fn apply_tick(&mut self, tick: FaultTick, faults: &mut FaultDriver) {
        let n = self.engines.len();
        match tick {
            FaultTick::Fault(ev) => match ev.kind {
                FaultKind::Crash { pool: Pool::Primary, replica } if replica < n => {
                    if !matches!(self.states[replica], ReplicaState::Active)
                        || self.down[replica]
                    {
                        return;
                    }
                    let lost = self.engines[replica].crash(ev.t_s);
                    self.down[replica] = true;
                    self.hints[replica] = f64::INFINITY;
                    for id in lost.ids {
                        faults.schedule_retry(id, ev.t_s);
                    }
                }
                FaultKind::Repair { pool: Pool::Primary, replica } if replica < n => {
                    if !self.down[replica] {
                        return;
                    }
                    self.engines[replica].close_ledger_down(ev.t_s);
                    self.down[replica] = false;
                    self.hints[replica] = f64::NEG_INFINITY;
                }
                FaultKind::Derate { pool: Pool::Primary, replica, factor }
                    if replica < n =>
                {
                    self.engines[replica].set_bw_derate(factor);
                    self.hints[replica] = f64::NEG_INFINITY;
                }
                FaultKind::DerateEnd { pool: Pool::Primary, replica } if replica < n => {
                    self.engines[replica].set_bw_derate(1.0);
                    self.hints[replica] = f64::NEG_INFINITY;
                }
                _ => {}
            },
            FaultTick::Retry { t_s, id } => {
                let target = (0..n)
                    .filter(|&i| {
                        matches!(self.states[i], ReplicaState::Active) && !self.down[i]
                    })
                    .min_by_key(|&i| self.engines[i].pending());
                match target {
                    Some(i) => {
                        if let Some(mut r) = faults.request_for(id).cloned() {
                            r.arrival = t_s;
                            let e = &mut self.engines[i];
                            e.advance_to(t_s);
                            e.submit(&r);
                            e.metrics.record_retry();
                            self.hints[i] = f64::NEG_INFINITY;
                        }
                    }
                    None => {
                        faults.schedule_retry(id, t_s);
                    }
                }
            }
        }
    }

    /// Extend every replica's ledger to `t` — idle-billed while
    /// powered, gated (0 W) while asleep, down (0 W) while crashed.
    /// Idempotent, and a no-op for replicas already at or past `t`.
    /// [`Self::run`] closes at its own makespan; callers comparing
    /// several fleets over one shared day
    /// (`InfraModel::cost_per_mtok_diurnal`) re-close each fleet at
    /// the common day end so the capex and electricity windows
    /// coincide.
    pub fn close_to(&mut self, t: f64) {
        for i in 0..self.engines.len() {
            if self.down[i] {
                self.engines[i].close_ledger_down(t);
                continue;
            }
            match self.states[i] {
                ReplicaState::Sleeping => self.engines[i].close_ledger_gated(t),
                _ => self.engines[i].close_ledger(t),
            }
        }
    }

    pub fn makespan(&self) -> f64 {
        self.engines.iter().map(|e| e.clock()).fold(0.0, f64::max)
    }

    pub fn merged_metrics(&self) -> Metrics {
        let mut m = Metrics::new();
        for e in &self.engines {
            m.absorb(&e.metrics);
        }
        m
    }

    pub fn preemptions(&self) -> u64 {
        self.engines.iter().map(|e| e.preemptions()).sum()
    }
}

impl<B: ExecutionBackend> ServeSim for AutoscaledCluster<B> {
    fn serve<I: IntoIterator<Item = Request>>(&mut self, arrivals: I) -> bool {
        self.run(arrivals)
    }

    fn merged_metrics(&self) -> Metrics {
        AutoscaledCluster::merged_metrics(self)
    }

    fn makespan(&self) -> f64 {
        AutoscaledCluster::makespan(self)
    }

    fn preemptions(&self) -> u64 {
        AutoscaledCluster::preemptions(self)
    }
}

/// What a migration event means when it fires (chunked streaming
/// splits one transfer into a delivery event and a release event; the
/// single-shot limit keeps PR 3's combined semantics and ordering).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum TransferEvent {
    /// Whole transfer lands at once (chunk count 1, zero bytes, or an
    /// infinite link): release the source KV and deliver the decode
    /// leg in one event — the exact single-shot semantics.
    Single,
    /// First chunk landed: the first token and the leading KV layers
    /// are across, so the decode leg is delivered (TTFT sampled here)
    /// while the tail chunks still stream.
    Deliver,
    /// Last chunk landed: the source engine's in-flight KV blocks are
    /// released (back-pressure ends here, not at first chunk).
    Release,
}

/// An in-flight KV migration event: created when a prefill leg
/// finishes, fired on the shared timeline at `t`. Ordered by time
/// (id, then kind tiebreak) for the event loop's min-heap.
#[derive(Debug, Clone)]
struct Transfer {
    t: f64,
    id: SeqId,
    kind: TransferEvent,
    /// Prefill-pool engine holding the in-flight KV blocks.
    src: usize,
    /// Original request arrival (TTFT / e2e reference).
    arrival: f64,
    /// Context tokens migrated (prompt + the prefill token).
    context_len: usize,
    /// Output tokens still to generate on the decode pool.
    remaining_out: usize,
    bytes: f64,
    /// When the *last* chunk lands: decode on the delivered leg is
    /// gated here (per-layer decode gating, DESIGN.md §13.5). Equals
    /// the event time for single-shot transfers.
    kv_done: f64,
}

impl PartialEq for Transfer {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.id == other.id && self.kind == other.kind
    }
}

impl Eq for Transfer {}

impl PartialOrd for Transfer {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Transfer {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t
            .total_cmp(&other.t)
            .then(self.id.cmp(&other.id))
            .then(self.kind.cmp(&other.kind))
    }
}

/// Disaggregated serving: requests prefill on a dedicated pool, their
/// KV cache migrates over the scale-out fabric ([`KvLink`]), and a
/// decode pool streams the remaining tokens. One shared virtual
/// timeline spans both pools and the transfers between them:
///
/// 1. external arrivals drive the prefill pool exactly like
///    [`Cluster::run`] drives its engine pool;
/// 2. a finished prefill leg becomes an in-flight transfer costed at
///    the closed form `context_tokens x kv_bytes_per_token / link_bw
///    + link_lat`; its source KV blocks stay resident until delivery
///    (in-flight accounting), so a saturated prefill pool
///    back-pressures on slow fabrics;
/// 3. at `t_done` the sequence resumes on a decode engine
///    ([`Router::submit_migrated_at`]): TTFT is sampled there —
///    prefill queueing + compute + transfer — and the decode engine
///    generates the remaining tokens with zero prefill compute.
///
/// Single-token requests never migrate (prefill is the whole
/// service). Events are processed in global time order; within each
/// pool the [`Cluster::run`] independence argument applies unchanged.
///
/// Chunked/layerwise streaming (`chunks > 1`, DESIGN.md §8.1): the
/// migration becomes a [`ChunkedTransfer`](crate::hwsim::interconnect::ChunkedTransfer)
/// schedule. The decode leg is delivered when the *first* chunk lands
/// (the first token and the leading KV layers are across), so TTFT
/// reflects first-chunk-plus-compute overlap; but decode compute
/// needs every layer's KV resident, so local token generation on the
/// delivered leg is gated at the *last* chunk's landing
/// (`Sequence::ready_at_s`, per-layer decode gating — DESIGN.md
/// §13.5). The source KV is also released only when the last chunk
/// lands, so back-pressure still covers the whole stream. `chunks =
/// 1` reproduces the single-shot timeline bit-exactly.
///
/// Admission control (`admission = true`, DESIGN.md §8.2): at
/// *chunk-delivery time* — after the decode pool has stepped to the
/// delivery instant — the pool is probed for the migration's KV
/// footprint (context + one decode step); a migration no decode engine
/// can hold at that instant is *bounced* — the prefill engine, which
/// still holds the KV until the release event, finishes the request
/// locally as [`SeqRole::Full`] ([`Engine::resume_bounced`]) instead
/// of landing KV that would be evicted on arrival. Probing at
/// delivery rather than at harvest (transfer start) means admission
/// judges the decode pool's occupancy when the footprint actually
/// lands, not its stale pre-transfer state. Bounces are counted in
/// `Metrics::bounces`; a bounced chunked transfer's pending release
/// event is suppressed (the resumed sequence keeps its KV).
///
/// Known approximation: a prefill engine stalled on in-flight KV
/// resumes at its stall-time clock when the delivery releases the
/// blocks, which can predate the delivery instant by up to the
/// transfer time (DESIGN.md §7.3).
///
/// [`SeqRole::Full`]: crate::coordinator::request::SeqRole::Full
pub struct DisaggCluster<B: ExecutionBackend> {
    pub prefill: Router<B>,
    pub decode: Router<B>,
    /// Cross-pool migration link (swap for sensitivity sweeps; use
    /// [`KvLink::infinite`] for the colocated-equivalence limit).
    pub link: KvLink,
    /// KV bytes per migrated context token (model x KV dtype).
    pub kv_bytes_per_token: f64,
    /// KV-streaming chunk count (1 = single-shot, the PR 3 semantics).
    pub chunks: usize,
    /// Decode-pool admission control: bounce migrations whose KV
    /// footprint would trigger immediate preemption (off by default —
    /// the single-shot limit stays bit-exact).
    pub admission: bool,
    pub step_cap: usize,
    /// Original output lengths of requests currently in their prefill
    /// or transfer leg (the prefill pool only sees `output_len = 1`).
    out_len: HashMap<SeqId, usize>,
    /// In-flight migration events, fired in global time order.
    pending: BinaryHeap<Reverse<Transfer>>,
    /// Chunked transfers bounced at delivery time: their trailing
    /// release events must be suppressed, because the resumed sequence
    /// keeps (and later releases) its own KV. Point lookups only.
    bounced_ids: HashSet<SeqId>,
    /// Fault schedule + crash-retry queue (inert by default).
    pub faults: FaultDriver,
    /// Link outage windows `[down, up)`, cached from the fault plan at
    /// run start and applied analytically to transfer schedules in
    /// [`DisaggCluster::harvest`]. Empty without link faults, keeping
    /// the healthy timing expressions bit-exact.
    outages: Vec<(f64, f64)>,
}

impl<B: ExecutionBackend> DisaggCluster<B> {
    pub fn new(
        prefill: Router<B>,
        decode: Router<B>,
        link: KvLink,
        kv_bytes_per_token: f64,
    ) -> Self {
        DisaggCluster {
            prefill,
            decode,
            link,
            kv_bytes_per_token,
            chunks: 1,
            admission: false,
            step_cap: 50_000_000,
            out_len: HashMap::new(),
            pending: BinaryHeap::new(),
            bounced_ids: HashSet::new(),
            faults: FaultDriver::none(),
            outages: Vec::new(),
        }
    }

    /// Builder-style streaming knobs (chunk count + admission control)
    /// for the sweep factories.
    pub fn with_streaming(mut self, chunks: usize, admission: bool) -> Self {
        self.chunks = chunks.max(1);
        self.admission = admission;
        self
    }

    /// Attach a fault schedule (builder-style).
    pub fn with_faults(mut self, faults: FaultDriver) -> Self {
        self.faults = faults;
        self
    }

    /// Run the two-pool event loop over an arrival stream. Returns
    /// true when every submitted request finished within the step cap.
    pub fn run(&mut self, arrivals: impl IntoIterator<Item = Request>) -> bool {
        let mut faults = std::mem::replace(&mut self.faults, FaultDriver::none());
        self.outages = faults.link_outages();
        let ok = self.run_faulty(arrivals, &mut faults);
        self.faults = faults;
        if !ok {
            return false;
        }
        // Ledger close at the two-pool makespan — here and not inside
        // `drain_all`, because `PhaseAffinityCluster::run` reuses
        // `drain_all` and must close at its own (larger) makespan.
        let t = self.makespan();
        self.prefill.close_ledgers(t);
        self.decode.close_ledgers(t);
        true
    }

    fn run_faulty(
        &mut self,
        arrivals: impl IntoIterator<Item = Request>,
        faults: &mut FaultDriver,
    ) -> bool {
        let mut left = self.step_cap;
        // Phase 1: external arrivals, interleaved with migration
        // events and fault ticks in global time order.
        for r in arrivals {
            if !self.pump_faults(r.arrival, faults, &mut left) {
                return false;
            }
            if !self.advance_to(r.arrival, &mut left) {
                return false;
            }
            faults.register(&r);
            if self.prefill.any_up() {
                self.submit_prefill(&r);
            } else {
                // The whole prefill pool is down: the arrival waits
                // in the retry queue.
                faults.schedule_retry(r.id, r.arrival);
            }
        }
        self.drain_all(&mut left, faults)
    }

    /// Apply every fault/retry tick due at or before `t`. Both pools
    /// (and the transfer heap) advance to each tick instant first, so
    /// ticks bound every fast-forward window on the shared timeline.
    fn pump_faults(&mut self, t: f64, faults: &mut FaultDriver, left: &mut usize) -> bool {
        while let Some(tick) = faults.next_due(t) {
            let t_ev = tick.t_s();
            if !self.advance_to(t_ev, left) {
                return false;
            }
            if !self.decode.step_to(t_ev, left) {
                return false;
            }
            self.apply_tick(tick, faults);
        }
        true
    }

    /// Apply one fault/retry tick. Retries recompute from scratch
    /// through the prefill path, or re-queue with backoff when the
    /// prefill pool is entirely down.
    fn apply_tick(&mut self, tick: FaultTick, faults: &mut FaultDriver) {
        match tick {
            FaultTick::Fault(ev) => {
                self.apply_fault(&ev, faults);
            }
            FaultTick::Retry { t_s, id } => {
                if !self.prefill.any_up() {
                    faults.schedule_retry(id, t_s);
                } else if let Some(mut r) = faults.request_for(id).cloned() {
                    r.arrival = t_s;
                    self.submit_retry(&r);
                }
            }
        }
    }

    /// Apply one scheduled fault to the disaggregated pools. Returns
    /// false when the event targets a pool this cluster does not have
    /// (`Pool::Primary` — the [`PhaseAffinityCluster`] wrapper owns
    /// that pool and handles the event itself).
    fn apply_fault(&mut self, ev: &FaultEvent, faults: &mut FaultDriver) -> bool {
        let n_p = self.prefill.engines.len();
        let n_d = self.decode.engines.len();
        match ev.kind {
            FaultKind::Crash { pool: Pool::Prefill, replica } if replica < n_p => {
                self.crash_prefill(replica, ev.t_s, faults);
            }
            FaultKind::Crash { pool: Pool::Decode, replica } if replica < n_d => {
                let lost = self.decode.crash_engine(replica, ev.t_s);
                for id in lost.ids {
                    faults.schedule_retry(id, ev.t_s);
                }
            }
            FaultKind::Repair { pool: Pool::Prefill, replica } if replica < n_p => {
                self.prefill.repair_engine(replica, ev.t_s);
            }
            FaultKind::Repair { pool: Pool::Decode, replica } if replica < n_d => {
                self.decode.repair_engine(replica, ev.t_s);
            }
            FaultKind::Derate { pool: Pool::Prefill, replica, factor } if replica < n_p => {
                self.prefill.set_derate(replica, factor);
            }
            FaultKind::Derate { pool: Pool::Decode, replica, factor } if replica < n_d => {
                self.decode.set_derate(replica, factor);
            }
            FaultKind::DerateEnd { pool: Pool::Prefill, replica } if replica < n_p => {
                self.prefill.set_derate(replica, 1.0);
            }
            FaultKind::DerateEnd { pool: Pool::Decode, replica } if replica < n_d => {
                self.decode.set_derate(replica, 1.0);
            }
            FaultKind::LinkDown | FaultKind::LinkUp => {
                // Outage windows are applied analytically at harvest
                // time from the cached schedule; the events themselves
                // only pin step boundaries on the shared timeline.
            }
            _ => return false,
        }
        true
    }

    /// Crash a prefill replica. Resident work is lost and re-queued
    /// (via [`Router::crash_engine`]); additionally, every pending
    /// transfer event *sourced* at the crashed replica dies with it:
    /// the KV being streamed lived in the crashed HBM. Undelivered
    /// transfers (Single/Deliver still pending) send their victims to
    /// the retry queue — the decode leg never existed. Legs already
    /// delivered keep decoding (delivery commits the stream); their
    /// trailing Release event is dropped with the rest, since the
    /// crash rebuilt the allocator the release would have returned
    /// blocks to. Heap rebuild order is irrelevant: victims act in
    /// sorted-id order and the heap's total order fixes pop order.
    fn crash_prefill(&mut self, replica: usize, t_s: f64, faults: &mut FaultDriver) {
        let lost = self.prefill.crash_engine(replica, t_s);
        for id in lost.ids {
            faults.schedule_retry(id, t_s);
        }
        let mut died: Vec<Transfer> = Vec::new();
        let kept: Vec<Reverse<Transfer>> = self
            .pending
            .drain()
            .filter_map(|Reverse(tr)| {
                if tr.src == replica {
                    died.push(tr);
                    None
                } else {
                    Some(Reverse(tr))
                }
            })
            .collect();
        self.pending = kept.into();
        let mut victims: Vec<SeqId> = died
            .iter()
            .filter(|tr| !matches!(tr.kind, TransferEvent::Release))
            .map(|tr| tr.id)
            .collect();
        victims.sort_unstable();
        victims.dedup();
        for tr in &died {
            // Any bounce suppression for a dropped event is stale now.
            self.bounced_ids.remove(&tr.id);
        }
        for id in victims {
            self.prefill.engines[replica].void_migration(id);
            faults.schedule_retry(id, t_s);
        }
    }

    /// Resubmit a crash victim from scratch through the prefill path,
    /// marking the retry on the engine that takes it.
    fn submit_retry(&mut self, r: &Request) {
        if r.output_len <= 1 {
            self.prefill.submit_retry_at(r);
            return;
        }
        self.out_len.insert(r.id, r.output_len);
        let i = self.prefill.submit_handoff_at(r);
        self.prefill.engines[i].metrics.record_retry();
    }

    /// Process every migration event up to `t`, then bring the prefill
    /// pool to `t` and harvest fresh handoffs. The shared-timeline
    /// workhorse: [`DisaggCluster::run`] calls it per arrival and
    /// [`PhaseAffinityCluster`] interleaves it with its colocated pool.
    fn advance_to(&mut self, t: f64, left: &mut usize) -> bool {
        loop {
            let t_ev = match self.pending.peek() {
                Some(Reverse(tr)) => tr.t,
                None => f64::INFINITY,
            };
            if t_ev > t {
                break;
            }
            // Before committing to this event order, make every
            // prefill completion up to `t_ev` visible: transfer
            // durations vary with context length, so a prefill that
            // finishes *later* than another can still complete its
            // (shorter) transfer *earlier*. Stepping + harvesting
            // here guarantees the heap holds every event with
            // t <= t_ev, and the popped minimum is the true next one.
            if !self.prefill.step_to(t_ev, left) {
                return false;
            }
            self.harvest();
            // The peek above guarantees a populated heap; a let-else
            // keeps the pop panic-free regardless.
            let Some(Reverse(tr)) = self.pending.pop() else {
                break;
            };
            if !self.fire(tr, left) {
                return false;
            }
        }
        if !self.prefill.step_to(t, left) {
            return false;
        }
        self.harvest();
        true
    }

    /// Drain everything after the arrival source is exhausted.
    ///
    /// While fault/retry ticks remain, work is served in windows
    /// bounded by the next tick instant, so crash/derate instants stay
    /// fast-forward boundaries during the drain too; tail fault events
    /// past the last work are dropped, exactly as in [`Cluster`]. Once
    /// the driver is inert: phase 2 interleaves prefill draining with
    /// migration events *one event at a time*: releases free in-flight
    /// source KV (which can unblock queued prefills) and admission
    /// bounces resume decoding on their prefill engine, so each pop
    /// re-drains and re-harvests the prefill pool first (only the
    /// stall-clock skew documented in DESIGN.md §7.3 remains). Phase 3
    /// drains the decode pool.
    fn drain_all(&mut self, left: &mut usize, faults: &mut FaultDriver) -> bool {
        loop {
            let t_next = faults.next_event_time();
            if !t_next.is_finite() {
                break;
            }
            let busy = !self.pending.is_empty()
                || self.prefill.engines.iter().any(|e| e.pending() > 0)
                || self.decode.engines.iter().any(|e| e.pending() > 0);
            if !busy && !faults.has_retries() {
                break;
            }
            if !self.pump_faults(t_next, faults, left) {
                return false;
            }
        }
        loop {
            for e in self.prefill.engines.iter_mut() {
                let s0 = e.metrics.steps;
                e.run_to_completion(*left); // may stall on in-flight KV
                *left = left.saturating_sub((e.metrics.steps - s0) as usize);
                if *left == 0 {
                    return false;
                }
            }
            self.harvest();
            let Some(Reverse(tr)) = self.pending.pop() else {
                break;
            };
            // A delivery-time bounce re-opens decode work on the
            // prefill pool; the loop's next iteration runs it before
            // the heap-empty check can conclude the drain.
            if !self.fire(tr, left) {
                return false;
            }
        }
        if self.prefill.engines.iter().any(|e| e.pending() > 0) {
            return false; // stuck prefill work (infeasible request)
        }
        // Phase 3: drain the decode pool.
        for e in self.decode.engines.iter_mut() {
            let s0 = e.metrics.steps;
            let ok = e.run_to_completion(*left);
            *left = left.saturating_sub((e.metrics.steps - s0) as usize);
            if !ok {
                return false;
            }
        }
        true
    }

    /// Route one external arrival. Requests needing no decode phase
    /// (single-token outputs) are served entirely by the prefill pool;
    /// everything else runs as a prefill leg that will hand off.
    fn submit_prefill(&mut self, r: &Request) {
        if r.output_len <= 1 {
            self.prefill.submit_at(r);
            return;
        }
        self.out_len.insert(r.id, r.output_len);
        self.prefill.submit_handoff_at(r);
    }

    /// Collect freshly finished prefill legs and push their chunk
    /// events, costed by the streaming schedule. Every handoff starts
    /// its transfer — admission control probes at *delivery* time
    /// ([`DisaggCluster::fire`]), when the footprint actually lands on
    /// the decode pool, not here against its stale pre-transfer state.
    fn harvest(&mut self) {
        for (src, e) in self.prefill.engines.iter_mut().enumerate() {
            for id in e.take_handoffs() {
                let Some((context_len, finished_at, arrival)) =
                    e.sequence(id).map(|seq| {
                        debug_assert!(seq.finished_at.is_some(), "handoff finished");
                        (
                            seq.context_len(),
                            seq.finished_at.unwrap_or(seq.arrival),
                            seq.arrival,
                        )
                    })
                else {
                    debug_assert!(false, "handoff sequence {id} exists");
                    continue;
                };
                let Some(out) = self.out_len.remove(&id) else {
                    debug_assert!(false, "handoff {id} has a recorded output length");
                    continue;
                };
                let bytes = context_len as f64 * self.kv_bytes_per_token;
                let sched = self.link.chunked(bytes, self.chunks);
                // Link outages stall active transfer time: each chunk
                // lands when its share of link work completes around
                // the cached `[down, up)` windows. Without outages the
                // original expressions run, bit-exactly.
                let (t_first, t_done) = if self.outages.is_empty() {
                    (
                        finished_at + sched.first_time_s(),
                        finished_at + sched.total_time_s(),
                    )
                } else {
                    (
                        faults::finish_after(&self.outages, finished_at, sched.first_time_s()),
                        faults::finish_after(&self.outages, finished_at, sched.total_time_s()),
                    )
                };
                let tr = Transfer {
                    t: t_done,
                    id,
                    kind: TransferEvent::Single,
                    src,
                    arrival,
                    context_len,
                    remaining_out: out - 1,
                    bytes,
                    kv_done: t_done,
                };
                if t_first == t_done {
                    // Degenerate schedule (one chunk, zero bytes or a
                    // free link): one combined event, the single-shot
                    // ordering bit-for-bit.
                    self.pending.push(Reverse(tr));
                } else {
                    self.pending.push(Reverse(Transfer {
                        t: t_first,
                        kind: TransferEvent::Deliver,
                        ..tr.clone()
                    }));
                    self.pending.push(Reverse(Transfer {
                        kind: TransferEvent::Release,
                        ..tr
                    }));
                }
            }
        }
    }

    /// Delivery-time admission probe: with admission control on, can
    /// any decode engine hold the migrated footprint at the delivery
    /// instant (the pool has already stepped to `tr.t`)?
    fn admits(&self, tr: &Transfer) -> bool {
        !self.admission
            || self
                .decode
                .engines
                .iter()
                .any(|d| d.can_admit_migration(tr.context_len))
    }

    /// Bounce a migration at delivery time: the source engine still
    /// holds the KV (its release event has not fired), so the request
    /// resumes colocated there. An idle source is lifted to the
    /// delivery instant first — the resumed decode cannot begin before
    /// the bounce decision exists on the timeline.
    fn bounce(&mut self, tr: &Transfer) {
        self.prefill.engines[tr.src].advance_to(tr.t);
        self.prefill.engines[tr.src].resume_bounced(tr.id, tr.remaining_out);
        self.prefill.note_mutation(tr.src);
    }

    /// Fire one migration event.
    fn fire(&mut self, tr: Transfer, left: &mut usize) -> bool {
        match tr.kind {
            TransferEvent::Single => {
                if !self.decode.step_to(tr.t, left) {
                    return false;
                }
                if self.decode.all_down() || !self.admits(&tr) {
                    // No decode engine up (crashes), or none can hold
                    // the footprint. The whole transfer lands in one
                    // event, so the bounced sequence's KV release is
                    // simply skipped — the resumed sequence keeps (and
                    // later frees) it.
                    self.bounce(&tr);
                    return true;
                }
                self.prefill.release_migrated_on(tr.src, tr.id);
                self.deliver(&tr);
            }
            TransferEvent::Deliver => {
                if !self.decode.step_to(tr.t, left) {
                    return false;
                }
                if self.decode.all_down() || !self.admits(&tr) {
                    // Tail chunks are still streaming: suppress the
                    // pending release event, whose firing would free
                    // the resumed sequence's KV mid-decode.
                    self.bounced_ids.insert(tr.id);
                    self.bounce(&tr);
                    return true;
                }
                self.deliver(&tr);
            }
            TransferEvent::Release => {
                if self.bounced_ids.remove(&tr.id) {
                    return true; // bounced at delivery: KV stays put
                }
                self.prefill.release_migrated_on(tr.src, tr.id);
            }
        }
        true
    }

    /// Resume the sequence on a decode engine at the event instant.
    /// With admission control on, delivery is admission-aware too:
    /// the migration lands on an engine that can hold its footprint
    /// (the delivery-time probe in [`DisaggCluster::fire`] said *some*
    /// engine could; routing by load alone could still pick a full
    /// one).
    fn deliver(&mut self, tr: &Transfer) {
        let m = MigratedRequest {
            id: tr.id,
            arrival: tr.arrival,
            at: tr.t,
            kv_ready_s: tr.kv_done,
            context_len: tr.context_len,
            remaining_out: tr.remaining_out,
            bytes: tr.bytes,
        };
        if self.admission {
            self.decode.submit_migrated_at_admitting(&m);
        } else {
            self.decode.submit_migrated_at(&m);
        }
    }

    /// Slowest engine's virtual completion time across both pools.
    pub fn makespan(&self) -> f64 {
        self.prefill.makespan().max(self.decode.makespan())
    }

    /// Rollup across both pools. Migration counts/bytes ride along
    /// (`Metrics::migrations`, `Metrics::kv_bytes_migrated`).
    pub fn merged_metrics(&self) -> Metrics {
        let (mut p, d) = self.pool_metrics();
        p.absorb(&d);
        p
    }

    /// Per-pool rollups: (prefill, decode) — heterogeneous pools are
    /// priced separately (`InfraModel::cost_per_mtok_disagg`), so the
    /// caller needs each pool's sustained draw on its own.
    pub fn pool_metrics(&self) -> (Metrics, Metrics) {
        let mut p = Metrics::new();
        for e in &self.prefill.engines {
            p.absorb(&e.metrics);
        }
        let mut d = Metrics::new();
        for e in &self.decode.engines {
            d.absorb(&e.metrics);
        }
        (p, d)
    }

    pub fn preemptions(&self) -> u64 {
        let p: u64 = self.prefill.engines.iter().map(|e| e.preemptions()).sum();
        let d: u64 = self.decode.engines.iter().map(|e| e.preemptions()).sum();
        p + d
    }
}

impl<B: ExecutionBackend> ServeSim for DisaggCluster<B> {
    fn serve<I: IntoIterator<Item = Request>>(&mut self, arrivals: I) -> bool {
        self.run(arrivals)
    }

    fn merged_metrics(&self) -> Metrics {
        DisaggCluster::merged_metrics(self)
    }

    fn makespan(&self) -> f64 {
        DisaggCluster::makespan(self)
    }

    fn preemptions(&self) -> u64 {
        DisaggCluster::preemptions(self)
    }
}

/// PhaseAffinity deployment: a colocated pool and a disaggregated
/// prefill/decode pair serving one arrival stream on one shared
/// virtual timeline (DESIGN.md §8.3). The router's affinity rule is
/// prompt length: requests whose prompt is at least
/// `affinity_prompt_tokens` long (and that have a decode phase at
/// all) take the disaggregated path, where the prefill pool's compute
/// advantage and the decode pool's capacity advantage pay for the KV
/// migration; short-prompt requests stay on the colocated pool, whose
/// fused engines serve them without any fabric crossing. Between
/// arrivals the three pools advance independently — the same
/// independence argument as [`Cluster::run`], with the disaggregated
/// half's migration events interleaved in global time order by
/// [`DisaggCluster::advance_to`].
pub struct PhaseAffinityCluster<B: ExecutionBackend> {
    pub colocated: Router<B>,
    pub disagg: DisaggCluster<B>,
    /// Prompts at or above this length take the disaggregated path.
    pub affinity_prompt_tokens: usize,
    pub step_cap: usize,
    /// Fault schedule + crash-retry queue (inert by default).
    /// `Pool::Primary` targets the colocated pool; `Pool::Prefill` /
    /// `Pool::Decode` target the disaggregated half.
    pub faults: FaultDriver,
}

impl<B: ExecutionBackend> PhaseAffinityCluster<B> {
    pub fn new(
        colocated: Router<B>,
        disagg: DisaggCluster<B>,
        affinity_prompt_tokens: usize,
    ) -> Self {
        PhaseAffinityCluster {
            colocated,
            disagg,
            affinity_prompt_tokens,
            step_cap: 50_000_000,
            faults: FaultDriver::none(),
        }
    }

    /// Attach a fault schedule (builder-style).
    pub fn with_faults(mut self, faults: FaultDriver) -> Self {
        self.faults = faults;
        self
    }

    /// Streaming knobs for the disaggregated half — delegates to
    /// [`DisaggCluster::with_streaming`] so the chunk clamp lives in
    /// one place.
    pub fn with_streaming(mut self, chunks: usize, admission: bool) -> Self {
        self.disagg = self.disagg.with_streaming(chunks, admission);
        self
    }

    /// Which path an arrival takes (the affinity rule, exposed so
    /// tests can assert conservation per path).
    pub fn routes_disagg(&self, r: &Request) -> bool {
        r.output_len > 1 && r.prompt_len >= self.affinity_prompt_tokens
    }

    /// Run the mixed event loop over an arrival stream. Returns true
    /// when every submitted request finished within the step cap.
    pub fn run(&mut self, arrivals: impl IntoIterator<Item = Request>) -> bool {
        let mut faults = std::mem::replace(&mut self.faults, FaultDriver::none());
        self.disagg.outages = faults.link_outages();
        let ok = self.run_faulty(arrivals, &mut faults);
        self.faults = faults;
        if !ok {
            return false;
        }
        // Close all three pools' ledgers at the *combined* makespan:
        // the colocated pool and the disaggregated pair share one
        // timeline, so every engine idles until the slowest of them
        // finishes.
        let t = self.makespan();
        self.colocated.close_ledgers(t);
        self.disagg.prefill.close_ledgers(t);
        self.disagg.decode.close_ledgers(t);
        true
    }

    fn run_faulty(
        &mut self,
        arrivals: impl IntoIterator<Item = Request>,
        faults: &mut FaultDriver,
    ) -> bool {
        let mut left = self.step_cap;
        for r in arrivals {
            if !self.pump_faults(r.arrival, faults, &mut left) {
                return false;
            }
            if !self.disagg.advance_to(r.arrival, &mut left) {
                return false;
            }
            if !self.colocated.step_to(r.arrival, &mut left) {
                return false;
            }
            faults.register(&r);
            self.route(&r, r.arrival, false, faults);
        }
        // Drain, fault-aware: serve all three pools in windows bounded
        // by the next tick, then hand the fault-free tail to the
        // disaggregated drain and the colocated completion loop.
        loop {
            let t_next = faults.next_event_time();
            if !t_next.is_finite() {
                break;
            }
            let busy = !self.disagg.pending.is_empty()
                || self.colocated.engines.iter().any(|e| e.pending() > 0)
                || self.disagg.prefill.engines.iter().any(|e| e.pending() > 0)
                || self.disagg.decode.engines.iter().any(|e| e.pending() > 0);
            if !busy && !faults.has_retries() {
                break;
            }
            if !self.pump_faults(t_next, faults, &mut left) {
                return false;
            }
        }
        if !self.disagg.drain_all(&mut left, faults) {
            return false;
        }
        for e in self.colocated.engines.iter_mut() {
            let s0 = e.metrics.steps;
            let ok = e.run_to_completion(left);
            left = left.saturating_sub((e.metrics.steps - s0) as usize);
            if !ok {
                return false;
            }
        }
        true
    }

    /// Apply every fault/retry tick due at or before `t`, stepping all
    /// three pools (and the transfer heap) to each tick instant first.
    fn pump_faults(&mut self, t: f64, faults: &mut FaultDriver, left: &mut usize) -> bool {
        while let Some(tick) = faults.next_due(t) {
            let t_ev = tick.t_s();
            if !self.disagg.advance_to(t_ev, left) {
                return false;
            }
            if !self.disagg.decode.step_to(t_ev, left) {
                return false;
            }
            if !self.colocated.step_to(t_ev, left) {
                return false;
            }
            match tick {
                FaultTick::Fault(ev) => {
                    if !self.disagg.apply_fault(&ev, faults) {
                        self.apply_primary(&ev, faults);
                    }
                }
                FaultTick::Retry { t_s, id } => {
                    if let Some(mut r) = faults.request_for(id).cloned() {
                        r.arrival = t_s;
                        self.route(&r, t_s, true, faults);
                    }
                }
            }
        }
        true
    }

    /// Primary-pool (colocated) fault application, mirroring
    /// [`Cluster`]'s — disagg-pool events were already consumed by
    /// [`DisaggCluster::apply_fault`].
    fn apply_primary(&mut self, ev: &FaultEvent, faults: &mut FaultDriver) {
        let n = self.colocated.engines.len();
        match ev.kind {
            FaultKind::Crash { pool: Pool::Primary, replica } if replica < n => {
                let lost = self.colocated.crash_engine(replica, ev.t_s);
                for id in lost.ids {
                    faults.schedule_retry(id, ev.t_s);
                }
            }
            FaultKind::Repair { pool: Pool::Primary, replica } if replica < n => {
                self.colocated.repair_engine(replica, ev.t_s);
            }
            FaultKind::Derate { pool: Pool::Primary, replica, factor } if replica < n => {
                self.colocated.set_derate(replica, factor);
            }
            FaultKind::DerateEnd { pool: Pool::Primary, replica } if replica < n => {
                self.colocated.set_derate(replica, 1.0);
            }
            _ => {}
        }
    }

    /// Route one request (fresh arrival or retry) down its affinity
    /// path, parking it in the retry queue when that path's pool is
    /// entirely down. Retries re-evaluate the affinity rule on the
    /// original request, so they take the same path they originally
    /// did (the rule depends only on prompt/output lengths).
    fn route(&mut self, r: &Request, now_s: f64, is_retry: bool, faults: &mut FaultDriver) {
        if self.routes_disagg(r) {
            if !self.disagg.prefill.any_up() {
                faults.schedule_retry(r.id, now_s);
            } else if is_retry {
                self.disagg.submit_retry(r);
            } else {
                self.disagg.submit_prefill(r);
            }
        } else if !self.colocated.any_up() {
            faults.schedule_retry(r.id, now_s);
        } else if is_retry {
            self.colocated.submit_retry_at(r);
        } else {
            self.colocated.submit_at(r);
        }
    }

    /// Slowest engine's virtual completion time across all pools.
    pub fn makespan(&self) -> f64 {
        self.colocated.makespan().max(self.disagg.makespan())
    }

    /// Rollup across the colocated pool and both disaggregated pools.
    pub fn merged_metrics(&self) -> Metrics {
        let mut m = Metrics::new();
        for e in &self.colocated.engines {
            m.absorb(&e.metrics);
        }
        m.absorb(&self.disagg.merged_metrics());
        m
    }

    /// Per-pool rollups: (colocated, prefill, decode) — each pool is
    /// priced at its own capex and sustained draw
    /// (`InfraModel::cost_per_mtok_phase_affinity_plan`).
    pub fn pool_metrics(&self) -> (Metrics, Metrics, Metrics) {
        let mut c = Metrics::new();
        for e in &self.colocated.engines {
            c.absorb(&e.metrics);
        }
        let (p, d) = self.disagg.pool_metrics();
        (c, p, d)
    }

    pub fn preemptions(&self) -> u64 {
        let c: u64 = self.colocated.engines.iter().map(|e| e.preemptions()).sum();
        c + self.disagg.preemptions()
    }
}

impl<B: ExecutionBackend> ServeSim for PhaseAffinityCluster<B> {
    fn serve<I: IntoIterator<Item = Request>>(&mut self, arrivals: I) -> bool {
        self.run(arrivals)
    }

    fn merged_metrics(&self) -> Metrics {
        PhaseAffinityCluster::merged_metrics(self)
    }

    fn makespan(&self) -> f64 {
        PhaseAffinityCluster::makespan(self)
    }

    fn preemptions(&self) -> u64 {
        PhaseAffinityCluster::preemptions(self)
    }
}

/// Homogeneous simulated cluster of *sharded* model instances: the
/// plan's full deployment shape is honored — `plan.replicas` engines,
/// each one a `plan.tp x plan.pp`-chip instance of `model` on `dev`.
/// The KV pool is sized per instance from the device spec through the
/// HBM capacity check, so an infeasible (model x device x plan)
/// deployment is a typed error, not a cluster that happily simulates
/// impossible hardware. Least-loaded routing, batch cap 64 — the
/// `sim_cluster` conventions.
pub fn sharded_sim_cluster(
    model: &'static LlamaConfig,
    dev: Device,
    prec: PrecisionMode,
    plan: ParallelismPlan,
) -> Result<Cluster<SimBackend>, CapacityError> {
    let engines = sharded_sim_engines(model, dev, prec, plan)?;
    let n_instances = engines.len();
    let ratings =
        vec![EngineRating { prefill_score: 1.0, decode_score: 1.0 }; n_instances];
    Ok(Cluster::new(Router::new(engines, ratings, RoutePolicy::LeastLoaded)))
}

/// The engine fleet behind [`sharded_sim_cluster`], bare of any
/// router: `plan.replicas` capacity-checked instances. Building block
/// for deployments that manage their own routing, e.g.
/// [`AutoscaledCluster`].
pub fn sharded_sim_engines(
    model: &'static LlamaConfig,
    dev: Device,
    prec: PrecisionMode,
    plan: ParallelismPlan,
) -> Result<Vec<Engine<SimBackend>>, CapacityError> {
    let w_bytes = prec.weight_bytes_per_elem();
    let n_instances = plan.replicas.max(1);
    let mut engines = Vec::with_capacity(n_instances);
    for _ in 0..n_instances {
        let mut cfg = EngineConfig::for_instance(model, dev, plan, w_bytes, 2.0)?;
        cfg.batcher.max_batch = 64;
        let backend = SimBackend::new(model, StepConfig::new(dev, prec).with_plan(plan));
        engines.push(Engine::new(cfg, backend));
    }
    Ok(engines)
}

/// [`AutoscaledCluster`] over the [`sharded_sim_engines`] fleet:
/// `plan.replicas` instances, the first `cfg.min_replicas` awake and
/// the rest power-gated until traffic demands them.
pub fn autoscaled_sim_cluster(
    model: &'static LlamaConfig,
    dev: Device,
    prec: PrecisionMode,
    plan: ParallelismPlan,
    cfg: AutoscalerConfig,
) -> Result<AutoscaledCluster<SimBackend>, CapacityError> {
    Ok(AutoscaledCluster::new(sharded_sim_engines(model, dev, prec, plan)?, cfg))
}

/// One pool of sharded sim engines (the [`disagg_sim_cluster`]
/// building block): `pool.plan.replicas` instances of `model` on
/// `pool.device`, each KV-sized through the HBM capacity check.
fn sim_pool(
    model: &'static LlamaConfig,
    pool: &PoolSpec,
) -> Result<Router<SimBackend>, CapacityError> {
    let w_bytes = pool.precision.weight_bytes_per_elem();
    let n = pool.plan.replicas.max(1);
    let mut engines = Vec::with_capacity(n);
    for _ in 0..n {
        let mut cfg = EngineConfig::for_instance(model, pool.device, pool.plan, w_bytes, 2.0)?;
        cfg.batcher.max_batch = 64;
        // The pool's per-chip power cap rides into the step model; it
        // is fixed for the backend's lifetime, so step-cost cache keys
        // stay exact.
        let mut step = StepConfig::new(pool.device, pool.precision).with_plan(pool.plan);
        step.power_cap = pool.power_cap;
        let backend = SimBackend::new(model, step);
        engines.push(Engine::new(cfg, backend));
    }
    let ratings = vec![EngineRating { prefill_score: 1.0, decode_score: 1.0 }; n];
    Ok(Router::new(engines, ratings, RoutePolicy::LeastLoaded))
}

/// Colocated simulated cluster from a single [`PoolSpec`] — the
/// [`sharded_sim_cluster`] conventions, but honoring the pool's
/// per-chip power cap. This is the rack-capped frontier's colocated
/// building block: feed `tco::rack::rack_capped_per_gpu_w` output into
/// [`PoolSpec::with_cap`] and re-search max sustainable QPS here.
pub fn pool_sim_cluster(
    model: &'static LlamaConfig,
    pool: &PoolSpec,
) -> Result<Cluster<SimBackend>, CapacityError> {
    Ok(Cluster::new(sim_pool(model, pool)?))
}

/// Disaggregated simulated cluster from a [`DisaggPlan`]: a prefill
/// pool and a decode pool of capacity-checked sharded instances —
/// possibly different vendors — joined by the plan's implied
/// [`KvLink`]. KV dtype is BF16 (the `StepConfig` default), so the
/// migrated bytes/token match what the decode pool will hold.
pub fn disagg_sim_cluster(
    model: &'static LlamaConfig,
    plan: &DisaggPlan,
) -> Result<DisaggCluster<SimBackend>, CapacityError> {
    let prefill = sim_pool(model, &plan.prefill)?;
    let decode = sim_pool(model, &plan.decode)?;
    Ok(DisaggCluster::new(
        prefill,
        decode,
        plan.kv_link(),
        model.kv_bytes_per_token(2.0),
    ))
}

/// PhaseAffinity simulated cluster from a [`PhaseAffinityPlan`]: a
/// colocated pool of capacity-checked sharded instances beside a
/// [`disagg_sim_cluster`], joined by the prompt-length affinity rule.
/// Streaming knobs (chunks, admission) apply to the disaggregated
/// half via [`DisaggCluster::with_streaming`].
pub fn phase_affinity_sim_cluster(
    model: &'static LlamaConfig,
    plan: &PhaseAffinityPlan,
) -> Result<PhaseAffinityCluster<SimBackend>, CapacityError> {
    let colocated = sim_pool(model, &plan.colocated)?;
    let disagg = disagg_sim_cluster(model, &plan.disagg)?;
    Ok(PhaseAffinityCluster::new(
        colocated,
        disagg,
        plan.affinity_prompt_tokens,
    ))
}

/// Replay a measured disaggregated operating point on a fresh cluster
/// to split its metrics per pool (heterogeneous pools price at their
/// own capex and sustained draw). `chunks`/`admission` must match the
/// probe's streaming configuration. The caller passes the same trace
/// shape, request count and seed as the probe that found the point —
/// the simulator is deterministic, so the replay must drain exactly
/// as the probe did (asserted). Returns (prefill, decode, merged), or
/// the capacity error when the plan cannot host the model at all.
pub fn replay_disagg_point(
    model: &'static LlamaConfig,
    plan: &DisaggPlan,
    chunks: usize,
    admission: bool,
    trace: TraceConfig,
    n_requests: usize,
    seed: u64,
) -> Result<(Metrics, Metrics, Metrics), CapacityError> {
    let mut c = disagg_sim_cluster(model, plan)?.with_streaming(chunks, admission);
    let gen = TraceGenerator::new(trace, seed);
    let drained = c.run(gen.stream(n_requests));
    assert!(drained, "replay of the feasible probe must drain");
    let (p, d) = c.pool_metrics();
    let merged = DisaggCluster::merged_metrics(&c);
    Ok((p, d, merged))
}

/// Replay a measured PhaseAffinity operating point to split metrics
/// across the colocated, prefill and decode pools (same determinism
/// contract as [`replay_disagg_point`]). Returns (colocated, prefill,
/// decode, merged), or the capacity error when the plan is infeasible.
pub fn replay_affinity_point(
    model: &'static LlamaConfig,
    plan: &PhaseAffinityPlan,
    chunks: usize,
    admission: bool,
    trace: TraceConfig,
    n_requests: usize,
    seed: u64,
) -> Result<(Metrics, Metrics, Metrics, Metrics), CapacityError> {
    let mut c = phase_affinity_sim_cluster(model, plan)?.with_streaming(chunks, admission);
    let gen = TraceGenerator::new(trace, seed);
    let drained = c.run(gen.stream(n_requests));
    assert!(drained, "replay of the feasible probe must drain");
    let (colo, p, d) = c.pool_metrics();
    let merged = PhaseAffinityCluster::merged_metrics(&c);
    Ok((colo, p, d, merged))
}

/// Homogeneous simulated cluster for sweeps, examples and benches:
/// `n_engines` single-chip (TP=1) engines serving llama-8b — the
/// paper's own measurement shape. KV pool sized from device HBM (FP8
/// weights halve the weight footprint), least-loaded routing, batch
/// cap 64. Multi-chip deployments go through [`sharded_sim_cluster`].
pub fn sim_cluster(dev: Device, prec: PrecisionMode, n_engines: usize) -> Cluster<SimBackend> {
    let model = llama::llama_8b();
    let w_bytes = prec.weight_bytes_per_elem();
    let engines: Vec<Engine<SimBackend>> = (0..n_engines)
        .map(|_| {
            let kv =
                KvCacheConfig::from_device(model, dev.spec().hbm_cap, w_bytes, 2.0, 16, 0.05);
            let backend = SimBackend::new(model, StepConfig::new(dev, prec));
            let mut cfg = EngineConfig::new(kv);
            cfg.batcher.max_batch = 64;
            Engine::new(cfg, backend)
        })
        .collect();
    let ratings =
        vec![EngineRating { prefill_score: 1.0, decode_score: 1.0 }; n_engines];
    Cluster::new(Router::new(engines, ratings, RoutePolicy::LeastLoaded))
}

/// Latency service-level objective for the load sweep, evaluated on
/// steady-state percentiles (a window of the run's makespan that
/// excludes warmup and cooldown transients).
#[derive(Debug, Clone, Copy)]
pub struct SloSpec {
    pub ttft_p95_s: f64,
    pub tpot_p95_s: f64,
    /// Fraction of the makespan discarded at the start of the window.
    pub warmup_frac: f64,
    /// Fraction discarded at the end (queue-drain tail).
    pub cooldown_frac: f64,
}

impl SloSpec {
    /// Interactive serving: TTFT p95 <= 2 s, TPOT p95 <= 50 ms.
    pub fn interactive() -> Self {
        SloSpec {
            ttft_p95_s: 2.0,
            tpot_p95_s: 0.050,
            warmup_frac: 0.1,
            cooldown_frac: 0.1,
        }
    }

    /// Steady-state window [t0, t1] for a run spanning `makespan`.
    pub fn window(&self, makespan: f64) -> (f64, f64) {
        (
            makespan * self.warmup_frac,
            makespan * (1.0 - self.cooldown_frac),
        )
    }
}

/// One measured operating point of the load sweep.
#[derive(Debug, Clone)]
pub struct LoadPoint {
    /// Offered arrival rate (requests/s across the whole cluster).
    pub qps: f64,
    pub drained: bool,
    /// Meets the SLO on steady-state percentiles.
    pub feasible: bool,
    pub ttft_p95: f64,
    pub tpot_p95: f64,
    /// Goodput: output tokens/s over the makespan, all engines.
    pub tokens_per_sec: f64,
    /// Sustained per-engine device draw over the whole run (W): busy
    /// *and* idle energy divided by time-at-power, so low-QPS points
    /// pay for idle draw instead of reporting busy-only optimism.
    pub watts_mean: f64,
    pub requests_done: u64,
    pub preemptions: u64,
    /// Latency samples (TTFT + TPOT) inside the steady-state window.
    /// 0 means the probe was too short for its window and the SLO
    /// verdict rests on the whole-run fallback.
    pub window_samples: usize,
    /// True when a percentile fell back to whole-run samples because
    /// its window was empty: the verdict then includes warmup/cooldown
    /// transients, which can flip feasibility on short probes — the
    /// exact failure `p95_or_whole` used to hide. Vacuous cases (no
    /// samples anywhere, e.g. TPOT on single-token outputs) are not
    /// flagged: no window length could have measured them.
    pub window_fallback: bool,
}

/// Steady-state p95 with an explicit fallback signal: `(value, true)`
/// when the window held no samples and the whole run was used instead;
/// `(0.0, false)` (vacuously met) when the whole run has none either —
/// e.g. TPOT on single-token outputs.
fn p95_or_whole(p: &crate::util::stats::TimedPercentiles, t0: f64, t1: f64) -> (f64, bool) {
    let w = p.pct_in(t0, t1, 95.0);
    if !w.is_nan() {
        return (w, false);
    }
    let whole = p.pct(95.0);
    if whole.is_nan() {
        (0.0, false)
    } else {
        (whole, true)
    }
}

/// Measure one operating point: a fresh serving system (colocated
/// [`Cluster`] or [`DisaggCluster`]) serving `n_requests` Poisson
/// arrivals at `qps`, judged against `slo` on the steady-state window.
pub fn measure_load<S, C, T>(
    mk_cluster: &C,
    trace_at: &T,
    qps: f64,
    n_requests: usize,
    seed: u64,
    slo: &SloSpec,
) -> LoadPoint
where
    S: ServeSim,
    C: Fn() -> S,
    T: Fn(f64) -> TraceConfig,
{
    let mut cluster = mk_cluster();
    let gen = TraceGenerator::new(trace_at(qps), seed);
    let drained = cluster.serve(gen.stream(n_requests));
    let m = cluster.merged_metrics();
    let makespan = cluster.makespan();
    let (t0, t1) = slo.window(makespan);
    let (ttft_p95, ttft_fb) = p95_or_whole(&m.ttft, t0, t1);
    let (tpot_p95, tpot_fb) = p95_or_whole(&m.tpot, t0, t1);
    let feasible = drained
        && m.requests_done > 0
        && ttft_p95 <= slo.ttft_p95_s
        && tpot_p95 <= slo.tpot_p95_s;
    LoadPoint {
        qps,
        drained,
        feasible,
        ttft_p95,
        tpot_p95,
        tokens_per_sec: if makespan > 0.0 {
            m.tokens_out as f64 / makespan
        } else {
            0.0
        },
        watts_mean: m.watts_mean(),
        requests_done: m.requests_done,
        preemptions: cluster.preemptions(),
        window_samples: m.ttft.count_in(t0, t1) + m.tpot.count_in(t0, t1),
        window_fallback: ttft_fb || tpot_fb,
    }
}

/// Search bracket and trial shape for [`max_sustainable_qps`].
#[derive(Debug, Clone, Copy)]
pub struct SweepConfig {
    pub qps_lo: f64,
    pub qps_hi: f64,
    /// Bisection refinements after the lo/hi probes.
    pub iters: usize,
    /// Poisson arrivals per probe.
    pub n_requests: usize,
    pub seed: u64,
}

impl SweepConfig {
    pub fn new(qps_lo: f64, qps_hi: f64) -> Self {
        SweepConfig { qps_lo, qps_hi, iters: 6, n_requests: 240, seed: 7 }
    }
}

/// Outcome of [`max_sustainable_qps`]: the best SLO-feasible point
/// found (None when even `qps_lo` violates the SLO) and every probe.
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    pub best: Option<LoadPoint>,
    pub probes: Vec<LoadPoint>,
}

/// Binary-search the highest offered QPS whose steady-state TTFT/TPOT
/// p95 meet `slo`. Builds a fresh cluster per probe (the search is
/// over *independent* open-loop runs, not a single warm system), so
/// `mk_cluster` is a factory. Deterministic for a fixed seed. Works
/// for any [`ServeSim`] — colocated and disaggregated deployments
/// land on the same $/Mtok-at-SLO axis.
pub fn max_sustainable_qps<S, C, T>(
    mk_cluster: &C,
    trace_at: &T,
    slo: &SloSpec,
    cfg: &SweepConfig,
) -> SweepOutcome
where
    S: ServeSim,
    C: Fn() -> S,
    T: Fn(f64) -> TraceConfig,
{
    assert!(cfg.qps_lo > 0.0 && cfg.qps_hi > cfg.qps_lo, "need 0 < lo < hi");
    let probe =
        |qps: f64| measure_load(mk_cluster, trace_at, qps, cfg.n_requests, cfg.seed, slo);
    let mut probes = Vec::new();
    let lo_pt = probe(cfg.qps_lo);
    let lo_feasible = lo_pt.feasible;
    probes.push(lo_pt.clone());
    if !lo_feasible {
        return SweepOutcome { best: None, probes };
    }
    let hi_pt = probe(cfg.qps_hi);
    probes.push(hi_pt.clone());
    if hi_pt.feasible {
        // Even the ceiling meets the SLO; report it rather than
        // pretending the search converged.
        return SweepOutcome { best: Some(hi_pt), probes };
    }
    let (mut lo, mut hi) = (cfg.qps_lo, cfg.qps_hi);
    let mut best = lo_pt;
    for _ in 0..cfg.iters {
        let mid = 0.5 * (lo + hi);
        let pt = probe(mid);
        let feasible = pt.feasible;
        probes.push(pt.clone());
        if feasible {
            best = pt;
            lo = mid;
        } else {
            hi = mid;
        }
    }
    SweepOutcome { best: Some(best), probes }
}

/// Candidate [`PhaseAffinityCluster`] thresholds from the trace's
/// *empirical* prompt-length distribution: the {25, 50, 75, 90}th
/// percentiles of a seeded sample, plus the caller's fixed default.
/// The default is always in the set, so an argmin over measured cost
/// ([`auto_affinity_threshold`]) can never do worse than it under the
/// same scorer. Deterministic for a fixed (trace, seed, n_sample).
pub fn affinity_threshold_candidates(
    trace: TraceConfig,
    seed: u64,
    n_sample: usize,
    default: usize,
) -> Vec<usize> {
    let gen = TraceGenerator::new(trace, seed);
    let mut lens: Vec<usize> =
        gen.stream(n_sample.max(1)).map(|r| r.prompt_len).collect();
    lens.sort_unstable();
    let q = |p: f64| -> usize {
        let idx = ((lens.len() - 1) as f64 * p).round() as usize;
        lens[idx]
    };
    let mut out = vec![q(0.25), q(0.50), q(0.75), q(0.90), default];
    out.sort_unstable();
    out.dedup();
    out
}

/// Pick the candidate threshold with the lowest measured cost. The
/// scorer is a callback (typically a replay plus `InfraModel` pricing,
/// or a bench-local $/Mtok probe) so this layer stays free of TCO
/// dependencies; ties keep the smallest threshold. Pair with
/// [`affinity_threshold_candidates`], which includes the fixed default
/// — making the tuned threshold never worse than the default under the
/// same deterministic scorer, by construction.
pub fn auto_affinity_threshold<F>(candidates: &[usize], mut cost_of: F) -> usize
where
    F: FnMut(usize) -> f64,
{
    assert!(!candidates.is_empty(), "need at least one candidate threshold");
    let mut best = candidates[0];
    let mut best_cost = cost_of(candidates[0]);
    for &c in &candidates[1..] {
        let cost = cost_of(c);
        if cost < best_cost {
            best = c;
            best_cost = cost;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::perfmodel::{PrecisionMode, StepConfig};
    use crate::coordinator::backend::SimBackend;
    use crate::coordinator::engine::{Engine, EngineConfig};
    use crate::coordinator::kv_cache::KvCacheConfig;
    use crate::coordinator::router::{EngineRating, RoutePolicy, Router};
    use crate::hwsim::spec::Device;
    use crate::workload::llama::by_name;

    fn engine(total_blocks: usize) -> Engine<SimBackend> {
        let kv = KvCacheConfig { block_tokens: 16, total_blocks };
        let backend = SimBackend::new(
            by_name("llama-8b").unwrap(),
            StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()),
        );
        Engine::new(EngineConfig::new(kv), backend)
    }

    fn cluster(n_engines: usize, blocks: usize) -> Cluster<SimBackend> {
        let engines: Vec<_> = (0..n_engines).map(|_| engine(blocks)).collect();
        let ratings = vec![EngineRating { prefill_score: 1.0, decode_score: 1.0 }; n_engines];
        Cluster::new(Router::new(engines, ratings, RoutePolicy::RoundRobin))
    }

    fn req(id: u64, arrival: f64, p: usize, o: usize) -> Request {
        Request { id, arrival, prompt_len: p, output_len: o, class: TenantClass::Interactive }
    }

    #[test]
    fn arrivals_admitted_at_their_own_time_across_engines() {
        let mut c = cluster(2, 10_000);
        // Round-robin: r0 -> e0 at t=0, r1 -> e1 at t=3.
        let ok = c.run(vec![req(0, 0.0, 128, 16), req(1, 3.0, 128, 16)]);
        assert!(ok);
        let m = c.merged_metrics();
        assert_eq!(m.requests_done, 2);
        // Each request's first token comes after its OWN arrival, and
        // neither TTFT contains the 3 s gap.
        for e in &c.router.engines {
            for s in e.sequences() {
                assert!(s.first_token_at.unwrap() >= s.arrival);
            }
        }
        assert!(m.ttft.pct(100.0) < 1.0, "TTFT leaked the arrival gap");
        assert!(c.makespan() >= 3.0, "shared clock must cover the last arrival");
    }

    #[test]
    fn busy_engine_queues_arrival_idle_engine_starts_at_arrival() {
        let mut c = cluster(1, 10_000);
        // Long first request; the second arrives mid-service and must
        // wait (its TTFT includes genuine queueing delay), not warp.
        let ok = c.run(vec![req(0, 0.0, 2048, 256), req(1, 0.001, 64, 8)]);
        assert!(ok);
        let e = &c.router.engines[0];
        let s1 = e.sequences().find(|s| s.id == 1).unwrap();
        assert!(s1.first_token_at.unwrap() > 0.001);
        assert_eq!(e.metrics.requests_done, 2);
    }

    #[test]
    fn step_cap_aborts_instead_of_spinning() {
        let mut c = cluster(1, 10_000);
        c.step_cap = 3;
        assert!(!c.run(vec![req(0, 0.0, 64, 512)]));
    }

    #[test]
    fn sweep_none_when_slo_unmeetable() {
        // TTFT SLO of ~0: even a near-idle system fails.
        let slo = SloSpec {
            ttft_p95_s: 1e-9,
            tpot_p95_s: 1e-9,
            warmup_frac: 0.1,
            cooldown_frac: 0.1,
        };
        let cfg = SweepConfig { iters: 3, n_requests: 20, seed: 1, ..SweepConfig::new(0.5, 4.0) };
        let out = max_sustainable_qps(&|| cluster(2, 10_000), &TraceConfig::chat, &slo, &cfg);
        assert!(out.best.is_none());
        assert_eq!(out.probes.len(), 1, "stops after the infeasible floor");
    }

    #[test]
    fn sweep_finds_feasible_point_and_it_meets_slo() {
        let slo = SloSpec::interactive();
        let cfg =
            SweepConfig { iters: 4, n_requests: 60, seed: 7, ..SweepConfig::new(0.25, 64.0) };
        let out = max_sustainable_qps(&|| cluster(2, 20_000), &TraceConfig::chat, &slo, &cfg);
        let best = out.best.expect("near-idle chat load must meet a 2s/50ms SLO");
        assert!(best.feasible);
        assert!(best.qps >= 0.25);
        assert!(best.ttft_p95 <= slo.ttft_p95_s);
        assert!(best.tpot_p95 <= slo.tpot_p95_s);
        assert!(best.tokens_per_sec > 0.0);
        assert!(best.watts_mean > 0.0);
    }

    #[test]
    fn empty_window_fallback_is_flagged_not_silent() {
        // A middle-2% steady-state window that a one-request probe
        // cannot populate: the verdict comes from whole-run samples
        // and must say so.
        let slo = SloSpec {
            ttft_p95_s: 2.0,
            tpot_p95_s: 0.5,
            warmup_frac: 0.49,
            cooldown_frac: 0.49,
        };
        let short = measure_load(&|| cluster(1, 20_000), &TraceConfig::chat, 1.0, 1, 7, &slo);
        assert_eq!(short.window_samples, 0, "one request cannot reach the window");
        assert!(short.window_fallback, "whole-run fallback must be flagged");
        // A probe long enough to populate the window measures steady
        // state directly — no fallback, samples counted.
        let long = measure_load(&|| cluster(1, 20_000), &TraceConfig::chat, 1.0, 200, 7, &slo);
        assert!(long.window_samples > 0);
        assert!(!long.window_fallback);
    }

    #[test]
    fn whole_run_fallback_can_invert_feasibility() {
        use crate::util::stats::TimedPercentiles;
        // Steady-state truth: after a cold-start transient (two slow
        // TTFTs while the first batch forms), the system serves fast.
        let mut full = TimedPercentiles::new();
        full.add(0.5, 4.0);
        full.add(1.0, 3.5);
        for k in 0..20 {
            full.add(10.0 + k as f64, 0.05);
        }
        let (v, fb) = p95_or_whole(&full, 8.0, 40.0);
        assert!(!fb);
        assert!(v < 0.1, "windowed verdict: feasible at a 2 s SLO");
        // A probe cut short right after the transient has an empty
        // window; the old silent fallback judged the SLO on the
        // transient alone and flipped feasible -> infeasible. The
        // flag now exposes exactly that case.
        let mut short = TimedPercentiles::new();
        short.add(0.5, 4.0);
        short.add(1.0, 3.5);
        let (v2, fb2) = p95_or_whole(&short, 8.0, 40.0);
        assert!(fb2, "empty window must surface the fallback");
        assert!(v2 > 2.0, "fallback verdict is warmup-polluted");
        // Vacuous case (no samples at all) is 0.0 and unflagged.
        let empty = TimedPercentiles::new();
        assert_eq!(p95_or_whole(&empty, 0.0, 1.0), (0.0, false));
    }

    fn autoscaled(n: usize, blocks: usize, cfg: AutoscalerConfig) -> AutoscaledCluster<SimBackend> {
        AutoscaledCluster::new((0..n).map(|_| engine(blocks)).collect(), cfg)
    }

    fn autoscaler_cfg() -> AutoscalerConfig {
        AutoscalerConfig {
            min_replicas: 1,
            scale_up_depth: 2.0,
            scale_down_depth: 0.5,
            provisioning_delay_s: 5.0,
            decision_interval_s: 0.5,
            depth_window: 1,
        }
    }

    /// Busy ramp (heavy requests, queue builds on the one awake
    /// replica) followed by a long sparse tail (light requests that
    /// keep decision ticks firing while depth collapses).
    fn ramp_then_quiet() -> Vec<Request> {
        let mut reqs = Vec::new();
        // Heavy ramp, long enough (t = 0..9.75) that a replica woken
        // at the first overload tick is Active well before it ends.
        for i in 0..40 {
            reqs.push(req(i, i as f64 * 0.25, 2048, 256));
        }
        // Sparse light tail: keeps decision ticks firing while depth
        // collapses, so scale-down actually runs.
        for i in 0..10 {
            reqs.push(req(40 + i, 15.0 + i as f64 * 5.0, 64, 8));
        }
        reqs
    }

    #[test]
    fn autoscaler_wakes_sleeps_and_respects_provisioning_delay() {
        let cfg = autoscaler_cfg();
        let mut c = autoscaled(2, 10_000, cfg);
        assert!(c.run(ramp_then_quiet()));
        let m = c.merged_metrics();
        assert_eq!(m.requests_done, 50);
        assert!(c.scale_ups >= 1, "ramp must wake the sleeper");
        assert!(c.scale_downs >= 1, "quiet tail must put a replica back to sleep");
        // Provisioning delay is a real capacity lag: nothing served on
        // the woken replica before the earliest possible ready time
        // (first decision tick + delay).
        let earliest_ready = cfg.decision_interval_s + cfg.provisioning_delay_s;
        let served_on_1 = c.engines[1].sequences().count();
        assert!(served_on_1 > 0, "woken replica must take load off the ramp");
        for s in c.engines[1].sequences() {
            assert!(
                s.first_token_at.unwrap() >= earliest_ready,
                "token served before the replica could have provisioned"
            );
        }
        // The sleeper's pre-wake night is on the ledger as 0 W time.
        assert!(c.engines[1].metrics.gated_s > 0.0);
        assert!(m.gated_s > 0.0);
    }

    #[test]
    fn autoscaler_ledger_tiles_the_makespan() {
        let mut c = autoscaled(3, 10_000, autoscaler_cfg());
        assert!(c.run(ramp_then_quiet()));
        let end = c.makespan();
        for e in &c.engines {
            let m = &e.metrics;
            let covered = m.span + m.idle_s + m.gated_s;
            assert!(
                (covered - end).abs() <= 1e-6 * end.max(1.0),
                "span {} + idle {} + gated {} != makespan {}",
                m.span,
                m.idle_s,
                m.gated_s,
                end
            );
            // Gated time carries no energy: the ledger still splits
            // exactly into busy + idle joules.
            let split = m.energy_prefill_j + m.energy_decode_j + m.energy_idle_j;
            assert!((m.energy_j - split).abs() <= 1e-6 * m.energy_j.max(1.0));
        }
    }

    #[test]
    fn autoscaler_is_deterministic() {
        use crate::workload::trace::{ArrivalProcess, RateCurve, TrafficConfig, TrafficGenerator};
        // Diurnal multi-tenant day, compressed: same seed, same fleet
        // -> bit-identical metrics and scale decisions.
        let trace = || {
            let curve = RateCurve::diurnal(600.0, 0.5, 6.0);
            let cfg = TrafficConfig::multi_tenant(ArrivalProcess::Modulated(curve), 0.3);
            TrafficGenerator::new(cfg, 42).until(600.0)
        };
        let run = |reqs: Vec<Request>| {
            let mut c = autoscaled(3, 10_000, autoscaler_cfg());
            assert!(c.run(reqs));
            let m = c.merged_metrics();
            (
                m.energy_j.to_bits(),
                m.span.to_bits(),
                m.idle_s.to_bits(),
                m.gated_s.to_bits(),
                m.tokens_out,
                m.requests_done,
                c.makespan().to_bits(),
                c.scale_ups,
                c.scale_downs,
            )
        };
        let a = run(trace());
        let b = run(trace());
        assert_eq!(a, b, "autoscaler must be deterministic on the virtual timeline");
    }

    #[test]
    fn autoscaled_and_static_fleets_agree_on_work_done() {
        // Same arrivals into an autoscaled fleet and a static 2-engine
        // cluster: identical token totals (scaling changes where and
        // when work runs, never how much of it completes).
        let reqs = ramp_then_quiet();
        let expected: u64 = reqs.iter().map(|r| r.output_len as u64).sum();
        let mut auto_c = autoscaled(2, 10_000, autoscaler_cfg());
        assert!(auto_c.run(reqs.clone()));
        let mut static_c = cluster(2, 10_000);
        assert!(static_c.run(reqs));
        assert_eq!(auto_c.merged_metrics().tokens_out, expected);
        assert_eq!(static_c.merged_metrics().tokens_out, expected);
        // The static fleet never gates; the autoscaled one does.
        assert_eq!(static_c.merged_metrics().gated_s, 0.0);
        assert!(auto_c.merged_metrics().gated_s > 0.0);
    }

    #[test]
    fn sim_cluster_factory_serves() {
        let mut c = sim_cluster(Device::H100, PrecisionMode::fp8_static(), 2);
        assert_eq!(c.router.engines.len(), 2);
        assert!(c.run(vec![req(0, 0.0, 64, 8), req(1, 0.5, 64, 8)]));
        assert_eq!(c.merged_metrics().requests_done, 2);
    }

    fn small_disagg_plan() -> DisaggPlan {
        DisaggPlan::new(
            PoolSpec::new(
                Device::H100,
                PrecisionMode::fp8_dynamic(),
                ParallelismPlan::single(),
            ),
            PoolSpec::new(
                Device::Gaudi2,
                PrecisionMode::fp8_static(),
                ParallelismPlan::single().with_replicas(2),
            ),
        )
    }

    #[test]
    fn disagg_cluster_serves_and_conserves() {
        let model = by_name("llama-8b").unwrap();
        let mut c = disagg_sim_cluster(model, &small_disagg_plan()).expect("8B fits");
        let reqs: Vec<Request> = (0..10).map(|i| req(i, i as f64 * 0.2, 128, 16)).collect();
        let expected: u64 = reqs.iter().map(|r| r.output_len as u64).sum();
        assert!(c.run(reqs));
        let m = c.merged_metrics();
        assert_eq!(m.requests_done, 10, "no request lost across migration");
        assert_eq!(m.tokens_out, expected, "token conservation across pools");
        assert_eq!(m.migrations, 10);
        assert!(m.kv_bytes_migrated > 0.0);
        assert_eq!(m.ttft.count(), 10, "one TTFT sample per request");
        // The split of work between the pools is visible per pool:
        // prefill emits exactly the first token of each request, the
        // decode pool owns the request ends.
        let (pm, dm) = c.pool_metrics();
        assert_eq!(pm.requests_done, 0);
        assert_eq!(dm.requests_done, 10);
        assert_eq!(pm.tokens_out, 10);
        assert_eq!(dm.tokens_out, expected - 10);
        // All in-flight KV released by the end.
        for e in c.prefill.engines.iter().chain(c.decode.engines.iter()) {
            assert_eq!(e.kv_utilization(), 0.0, "leaked in-flight KV");
        }
    }

    #[test]
    fn single_token_requests_never_migrate() {
        let model = by_name("llama-8b").unwrap();
        let mut c = disagg_sim_cluster(model, &small_disagg_plan()).expect("8B fits");
        let reqs: Vec<Request> = (0..4).map(|i| req(i, i as f64 * 0.5, 256, 1)).collect();
        assert!(c.run(reqs));
        let m = c.merged_metrics();
        assert_eq!(m.requests_done, 4);
        assert_eq!(m.migrations, 0, "prefill-only requests stay put");
        let (pm, dm) = c.pool_metrics();
        assert_eq!(pm.requests_done, 4, "prefill pool owns single-token requests");
        assert_eq!(dm.steps, 0, "decode pool never woke up");
    }

    #[test]
    fn disagg_determinism_same_seed_same_everything() {
        let run = || {
            let model = by_name("llama-8b").unwrap();
            let mut c = disagg_sim_cluster(model, &small_disagg_plan()).expect("8B fits");
            let gen = TraceGenerator::new(TraceConfig::chat(4.0), 23);
            assert!(c.run(gen.stream(50)));
            let m = c.merged_metrics();
            (c.makespan(), m.tokens_out, m.requests_done, m.migrations, m.report())
        };
        let a = run();
        let b = run();
        assert_eq!(a.0.to_bits(), b.0.to_bits(), "disagg makespan must be bit-identical");
        assert_eq!(a.1, b.1);
        assert_eq!(a.2, b.2);
        assert_eq!(a.3, b.3);
        assert_eq!(a.4, b.4);
    }

    #[test]
    fn disagg_sweep_finds_feasible_point() {
        let slo = SloSpec::interactive();
        let cfg = SweepConfig { iters: 2, n_requests: 30, seed: 7, ..SweepConfig::new(0.25, 8.0) };
        let out = max_sustainable_qps(
            &|| disagg_sim_cluster(by_name("llama-8b").unwrap(), &small_disagg_plan()).unwrap(),
            &TraceConfig::chat,
            &slo,
            &cfg,
        );
        let best = out.best.expect("near-idle chat load must meet the SLO");
        assert!(best.feasible && best.tokens_per_sec > 0.0);
        assert!(best.ttft_p95 <= slo.ttft_p95_s);
    }

    #[test]
    fn chunked_streaming_conserves_and_beats_single_shot_ttft() {
        let model = by_name("llama-8b").unwrap();
        let run = |chunks: usize| {
            let mut c = disagg_sim_cluster(model, &small_disagg_plan())
                .expect("8B fits")
                .with_streaming(chunks, false);
            let reqs: Vec<Request> =
                (0..10).map(|i| req(i, i as f64 * 0.2, 512, 16)).collect();
            assert!(c.run(reqs));
            let m = c.merged_metrics();
            assert_eq!(m.requests_done, 10);
            assert_eq!(m.tokens_out, 10 * 16, "token conservation under chunking");
            assert_eq!(m.migrations, 10);
            for e in c.prefill.engines.iter().chain(c.decode.engines.iter()) {
                assert_eq!(e.kv_utilization(), 0.0, "leaked in-flight KV");
            }
            m.ttft.pct(95.0)
        };
        let single = run(1);
        let chunked = run(8);
        assert!(
            chunked < single,
            "first-chunk delivery must beat single-shot TTFT: {chunked} vs {single}"
        );
    }

    #[test]
    fn per_layer_gating_is_monotone_in_chunk_count() {
        // Per-layer decode gating: the streamed first token rides the
        // first chunk (TTFT improves with finer chunking), but local
        // decode waits for the last chunk — whose landing only moves
        // later as per-chunk link latency accumulates — so e2e
        // degrades monotonically. Low load keeps queueing out of the
        // comparison.
        let model = by_name("llama-8b").unwrap();
        let run = |chunks: usize| {
            let mut c = disagg_sim_cluster(model, &small_disagg_plan())
                .expect("8B fits")
                .with_streaming(chunks, false);
            let reqs: Vec<Request> =
                (0..8).map(|i| req(i, i as f64 * 0.5, 512, 16)).collect();
            assert!(c.run(reqs));
            let m = c.merged_metrics();
            assert_eq!(m.requests_done, 8);
            assert_eq!(m.tokens_out, 8 * 16, "token conservation under gating");
            assert_eq!(m.ttft.count(), 8, "first token correct at every chunking");
            (m.ttft.pct(95.0), m.e2e_latency.pct(95.0))
        };
        let (ttft1, e2e1) = run(1);
        let (ttft4, e2e4) = run(4);
        let (ttft16, e2e16) = run(16);
        assert!(
            ttft16 <= ttft4 && ttft4 <= ttft1,
            "TTFT must not worsen with finer chunking: {ttft1} {ttft4} {ttft16}"
        );
        assert!(
            e2e1 <= e2e4 && e2e4 <= e2e16,
            "gated decode start must not improve with chunking: {e2e1} {e2e4} {e2e16}"
        );
        assert!(
            e2e16 > e2e1,
            "per-chunk latency must actually delay the gated decode"
        );
    }

    #[test]
    fn admission_control_bounces_oversized_migrations() {
        let model = by_name("llama-8b").unwrap();
        // Decode pool of 64 KV tokens: a 100-token context can never
        // land there; without admission control it would deadlock
        // (debug-assert), with it the request bounces and completes
        // colocated on the prefill engine.
        let router = |engines: Vec<Engine<SimBackend>>| {
            let n = engines.len();
            let ratings =
                vec![EngineRating { prefill_score: 1.0, decode_score: 1.0 }; n];
            Router::new(engines, ratings, RoutePolicy::LeastLoaded)
        };
        let mut c = DisaggCluster::new(
            router(vec![engine(10_000)]),
            router(vec![engine(4)]),
            KvLink { bw: 37.5e9, lat_s: 1.1e-5 },
            model.kv_bytes_per_token(2.0),
        )
        .with_streaming(1, true);
        assert!(c.run(vec![req(0, 0.0, 100, 8), req(1, 0.5, 16, 8)]));
        let m = c.merged_metrics();
        assert_eq!(m.requests_done, 2, "no request lost");
        assert_eq!(m.tokens_out, 16, "token conservation across the bounce");
        assert_eq!(m.bounces, 1, "oversized context bounced");
        assert_eq!(m.migrations, 1, "small context still migrates");
        let (pm, dm) = c.pool_metrics();
        assert_eq!(pm.requests_done, 1, "bounced request finishes on prefill pool");
        assert_eq!(dm.requests_done, 1);
    }

    #[test]
    fn phase_affinity_cluster_splits_by_prompt_length() {
        let model = by_name("llama-8b").unwrap();
        let colo = PoolSpec::new(
            Device::H100,
            PrecisionMode::fp8_dynamic(),
            ParallelismPlan::single(),
        );
        let plan = PhaseAffinityPlan::new(colo, small_disagg_plan(), 512);
        let mut c = phase_affinity_sim_cluster(model, &plan).expect("8B fits");
        // Two short-prompt, one long-prompt, one long-prompt
        // single-token request (stays colocated: no decode phase).
        let reqs = vec![
            req(0, 0.0, 64, 8),
            req(1, 0.1, 2048, 8),
            req(2, 0.2, 64, 8),
            req(3, 0.3, 2048, 1),
        ];
        assert!(c.routes_disagg(&reqs[1]));
        assert!(!c.routes_disagg(&reqs[0]));
        assert!(!c.routes_disagg(&reqs[3]), "single-token stays colocated");
        let expected: u64 = reqs.iter().map(|r| r.output_len as u64).sum();
        assert!(c.run(reqs));
        let m = c.merged_metrics();
        assert_eq!(m.requests_done, 4);
        assert_eq!(m.tokens_out, expected, "token conservation across pool kinds");
        assert_eq!(m.migrations, 1, "only the long multi-token prompt migrated");
        let (cm, pm, dm) = c.pool_metrics();
        assert_eq!(cm.requests_done, 3, "short + single-token stay colocated");
        assert_eq!(pm.requests_done, 0, "prefill legs defer");
        assert_eq!(dm.requests_done, 1);
    }

    #[test]
    fn sharded_cluster_serves_70b_and_rejects_single_chip() {
        use crate::analysis::parallel::ParallelismPlan;
        let m70 = by_name("llama-70b").unwrap();
        // 70B BF16 on one H100 chip: typed capacity rejection.
        let err = sharded_sim_cluster(
            m70,
            Device::H100,
            PrecisionMode::Bf16,
            ParallelismPlan::single(),
        );
        assert!(err.is_err(), "70B BF16 must not fit one chip");
        // The same model at TP=4 FP8, twice replicated, is a working
        // two-engine pool.
        let mut c = sharded_sim_cluster(
            m70,
            Device::H100,
            PrecisionMode::fp8_dynamic(),
            ParallelismPlan::tp(4).with_replicas(2),
        )
        .expect("70B fits at tp4");
        assert_eq!(c.router.engines.len(), 2);
        assert!(c.run(vec![req(0, 0.0, 64, 8), req(1, 0.5, 64, 8)]));
        assert_eq!(c.merged_metrics().requests_done, 2);
    }

    // ---- fault injection ------------------------------------------------

    use crate::coordinator::faults::{FaultDriver, FaultKind, FaultPlan, Pool, RetryPolicy};

    fn driver(plan: FaultPlan) -> FaultDriver {
        FaultDriver::new(plan, RetryPolicy::default())
    }

    /// Bit-level fingerprint of a run: every f64 by its bit pattern.
    fn fingerprint(m: &Metrics, makespan: f64) -> Vec<u64> {
        vec![
            m.energy_j.to_bits(),
            m.span.to_bits(),
            m.idle_s.to_bits(),
            m.gated_s.to_bits(),
            m.down_s.to_bits(),
            makespan.to_bits(),
            m.tokens_out,
            m.requests_done,
            m.retries,
            m.lost_tokens,
            m.recompute_tokens_wasted,
        ]
    }

    fn assert_ledger_tiles(m: &Metrics, makespan: f64, what: &str) {
        let covered = m.span + m.idle_s + m.gated_s + m.down_s;
        assert!(
            (covered - makespan).abs() <= 1e-9 * makespan.max(1.0),
            "{what}: span {} + idle {} + gated {} + down {} != makespan {makespan}",
            m.span,
            m.idle_s,
            m.gated_s,
            m.down_s,
        );
    }

    #[test]
    fn crash_retry_conserves_tokens_on_colocated_cluster() {
        let reqs: Vec<Request> = (0..4).map(|i| req(i, i as f64 * 0.1, 2048, 64)).collect();
        let expected: u64 = reqs.iter().map(|r| r.output_len as u64).sum();
        let plan = FaultPlan::new().crash_repair(Pool::Primary, 0, 0.5, 0.5);
        let mut c = cluster(2, 10_000).with_faults(driver(plan));
        assert!(c.run(reqs), "crashed work must retry and drain");
        let m = c.merged_metrics();
        assert_eq!(m.requests_done, 4, "every request completes, possibly via retry");
        assert!(m.retries >= 1, "the crash must have produced retries");
        assert!(m.lost_tokens > 0, "mid-stream victims had streamed tokens");
        assert!(m.recompute_tokens_wasted > 0, "prefilled context was recomputed");
        assert_eq!(
            m.tokens_out - m.lost_tokens,
            expected,
            "goodput equals the offered work exactly"
        );
        assert!(c.faults.dropped.is_empty(), "no victim exhausted its retries");
        assert!(m.down_s > 0.0, "the outage is on the 0 W down arm");
        // Per-engine four-arm ledger conservation.
        let end = c.makespan();
        for e in &c.router.engines {
            assert_ledger_tiles(&e.metrics, end, "colocated engine");
        }
    }

    #[test]
    fn whole_pool_down_parks_arrivals_until_repair() {
        // Single replica crashed while idle at t=0.05, repaired at
        // 0.55: both arrivals land in the retry queue and are served
        // after the repair. down_s covers exactly the outage.
        let plan = FaultPlan::new().crash_repair(Pool::Primary, 0, 0.05, 0.5);
        let mut c = cluster(1, 10_000).with_faults(driver(plan));
        assert!(c.run(vec![req(0, 0.1, 64, 8), req(1, 0.2, 64, 8)]));
        let m = c.merged_metrics();
        assert_eq!(m.requests_done, 2);
        assert_eq!(m.lost_tokens, 0, "nothing was resident at crash time");
        assert!(m.retries >= 2, "both parked arrivals retried");
        assert!((m.down_s - 0.5).abs() < 1e-9, "down arm covers the outage");
        assert!(c.makespan() > 0.55, "all serving happens after the repair");
        assert_ledger_tiles(&m, c.makespan(), "single-replica cluster");
    }

    #[test]
    fn empty_fault_plan_runs_bit_identical_on_every_cluster_shape() {
        let reqs = || -> Vec<Request> {
            (0..8).map(|i| req(i, i as f64 * 0.15, 512, 16)).collect()
        };
        // Colocated.
        let mut a = cluster(2, 10_000);
        let mut b = cluster(2, 10_000).with_faults(driver(FaultPlan::new()));
        assert!(a.run(reqs()) && b.run(reqs()));
        assert_eq!(
            fingerprint(&a.merged_metrics(), a.makespan()),
            fingerprint(&b.merged_metrics(), b.makespan()),
            "colocated: empty plan must be structurally invisible"
        );
        // Autoscaled.
        let mut a = autoscaled(2, 10_000, autoscaler_cfg());
        let mut b = autoscaled(2, 10_000, autoscaler_cfg())
            .with_faults(driver(FaultPlan::new()));
        assert!(a.run(ramp_then_quiet()) && b.run(ramp_then_quiet()));
        assert_eq!(
            fingerprint(&a.merged_metrics(), a.makespan()),
            fingerprint(&b.merged_metrics(), b.makespan()),
            "autoscaled: empty plan must be structurally invisible"
        );
        // Disaggregated.
        let model = by_name("llama-8b").unwrap();
        let mut a = disagg_sim_cluster(model, &small_disagg_plan()).unwrap();
        let mut b = disagg_sim_cluster(model, &small_disagg_plan())
            .unwrap()
            .with_faults(driver(FaultPlan::new()));
        assert!(a.run(reqs()) && b.run(reqs()));
        assert_eq!(
            fingerprint(&a.merged_metrics(), a.makespan()),
            fingerprint(&b.merged_metrics(), b.makespan()),
            "disagg: empty plan must be structurally invisible"
        );
        // PhaseAffinity.
        let colo = PoolSpec::new(
            Device::H100,
            PrecisionMode::fp8_dynamic(),
            ParallelismPlan::single(),
        );
        let plan = PhaseAffinityPlan::new(colo, small_disagg_plan(), 512);
        let mut a = phase_affinity_sim_cluster(model, &plan).unwrap();
        let mut b = phase_affinity_sim_cluster(model, &plan)
            .unwrap()
            .with_faults(driver(FaultPlan::new()));
        assert!(a.run(reqs()) && b.run(reqs()));
        assert_eq!(
            fingerprint(&a.merged_metrics(), a.makespan()),
            fingerprint(&b.merged_metrics(), b.makespan()),
            "phase-affinity: empty plan must be structurally invisible"
        );
    }

    #[test]
    fn derate_window_slows_serving_then_restores_exactly() {
        let reqs = || -> Vec<Request> {
            (0..6).map(|i| req(i, i as f64 * 0.05, 2048, 64)).collect()
        };
        let mut healthy = cluster(1, 10_000);
        assert!(healthy.run(reqs()));
        let m_h = healthy.merged_metrics();
        // Derate covering the whole run: strictly slower.
        let slow_plan =
            FaultPlan::new().derate_window(Pool::Primary, 0, 0.0, 1e6, 0.25);
        let mut slow = cluster(1, 10_000).with_faults(driver(slow_plan));
        assert!(slow.run(reqs()));
        let m_s = slow.merged_metrics();
        assert_eq!(m_s.tokens_out, m_h.tokens_out, "derate loses no work");
        assert_eq!(m_s.retries, 0, "degraded mode is not a crash");
        assert!(
            slow.makespan() > healthy.makespan(),
            "quartered HBM bandwidth must lengthen the run ({} vs {})",
            slow.makespan(),
            healthy.makespan(),
        );
        assert_ledger_tiles(&m_s, slow.makespan(), "derated engine");
    }

    #[test]
    fn link_outage_stalls_transfers_and_conserves_work() {
        let model = by_name("llama-8b").unwrap();
        let reqs = || -> Vec<Request> {
            (0..6).map(|i| req(i, i as f64 * 0.2, 128, 16)).collect()
        };
        let expected: u64 = reqs().iter().map(|r| r.output_len as u64).sum();
        let mut healthy = disagg_sim_cluster(model, &small_disagg_plan()).unwrap();
        assert!(healthy.run(reqs()));
        let plan = FaultPlan::new().link_outage(0.05, 5.0);
        let mut faulty = disagg_sim_cluster(model, &small_disagg_plan())
            .unwrap()
            .with_faults(driver(plan));
        assert!(faulty.run(reqs()));
        let m = faulty.merged_metrics();
        assert_eq!(m.requests_done, 6);
        assert_eq!(m.tokens_out, expected, "outage delays, never destroys");
        assert_eq!(m.lost_tokens, 0);
        assert!(
            faulty.makespan() > healthy.makespan(),
            "a 5 s dark link must delay delivery ({} vs {})",
            faulty.makespan(),
            healthy.makespan(),
        );
        // In-flight KV held across the stall is fully released.
        for e in faulty.prefill.engines.iter().chain(faulty.decode.engines.iter()) {
            assert_eq!(e.kv_utilization(), 0.0, "leaked in-flight KV across outage");
        }
    }

    #[test]
    fn prefill_crash_kills_inflight_transfers_and_retries_them() {
        let model = by_name("llama-8b").unwrap();
        let reqs: Vec<Request> = (0..6).map(|i| req(i, i as f64 * 0.1, 256, 16)).collect();
        let expected: u64 = reqs.iter().map(|r| r.output_len as u64).sum();
        // The only prefill replica dies mid-stream and is repaired
        // 0.5 s later; retries back off until the pool returns.
        let plan = FaultPlan::new().crash_repair(Pool::Prefill, 0, 0.3, 0.5);
        let mut c = disagg_sim_cluster(model, &small_disagg_plan())
            .unwrap()
            .with_faults(driver(plan));
        assert!(c.run(reqs), "victims must recompute after the repair");
        let m = c.merged_metrics();
        assert_eq!(m.requests_done, 6);
        assert!(m.retries >= 1);
        assert_eq!(
            m.tokens_out - m.lost_tokens,
            expected,
            "goodput equals offered work across the crash"
        );
        assert!(c.faults.dropped.is_empty(), "repair came before retry exhaustion");
        assert!(m.down_s > 0.0);
        for e in c.prefill.engines.iter().chain(c.decode.engines.iter()) {
            assert_eq!(e.kv_utilization(), 0.0, "crash left KV resident");
        }
        let end = c.makespan();
        for e in c.prefill.engines.iter().chain(c.decode.engines.iter()) {
            assert_ledger_tiles(&e.metrics, end, "disagg engine");
        }
    }

    #[test]
    fn decode_crash_recomputes_migrated_sequences() {
        let model = by_name("llama-8b").unwrap();
        let reqs: Vec<Request> = (0..6).map(|i| req(i, i as f64 * 0.1, 256, 64)).collect();
        let expected: u64 = reqs.iter().map(|r| r.output_len as u64).sum();
        // One of two decode replicas dies while holding migrated legs;
        // victims recompute from scratch through the prefill pool.
        let plan = FaultPlan::new().crash_repair(Pool::Decode, 0, 1.0, 1.0);
        let mut c = disagg_sim_cluster(model, &small_disagg_plan())
            .unwrap()
            .with_faults(driver(plan));
        assert!(c.run(reqs));
        let m = c.merged_metrics();
        assert_eq!(m.requests_done, 6);
        assert_eq!(m.tokens_out - m.lost_tokens, expected);
        assert!(c.faults.dropped.is_empty());
        let end = c.makespan();
        for e in c.prefill.engines.iter().chain(c.decode.engines.iter()) {
            assert_ledger_tiles(&e.metrics, end, "disagg engine");
        }
    }

    #[test]
    fn autoscaler_crash_bills_down_arm_and_recovers() {
        // Replica 0 (the only Active one) dies at 0.5 and is repaired
        // at 1.0; parked arrivals and crash victims retry after.
        let reqs: Vec<Request> = (0..6).map(|i| req(i, i as f64 * 0.12, 1024, 32)).collect();
        let expected: u64 = reqs.iter().map(|r| r.output_len as u64).sum();
        let plan = FaultPlan::new().crash_repair(Pool::Primary, 0, 0.5, 0.5);
        let mut c = autoscaled(2, 10_000, autoscaler_cfg()).with_faults(driver(plan));
        assert!(c.run(reqs));
        let m = c.merged_metrics();
        assert_eq!(m.requests_done, 6);
        assert_eq!(m.tokens_out - m.lost_tokens, expected);
        assert!(m.down_s > 0.0, "the outage must be on the down arm");
        let end = c.makespan();
        for e in &c.engines {
            assert_ledger_tiles(&e.metrics, end, "autoscaled replica");
        }
    }

    #[test]
    fn phase_affinity_primary_crash_retries_colocated_work() {
        let model = by_name("llama-8b").unwrap();
        let colo = PoolSpec::new(
            Device::H100,
            PrecisionMode::fp8_dynamic(),
            ParallelismPlan::single(),
        );
        let plan = PhaseAffinityPlan::new(colo, small_disagg_plan(), 512);
        // Short prompts (colocated path) in flight when the colocated
        // replica dies; long prompts keep the disagg path busy.
        let reqs: Vec<Request> = vec![
            req(0, 0.0, 64, 64),
            req(1, 0.05, 2048, 16),
            req(2, 0.1, 64, 64),
            req(3, 0.15, 2048, 16),
        ];
        let expected: u64 = reqs.iter().map(|r| r.output_len as u64).sum();
        let fplan = FaultPlan::new().crash_repair(Pool::Primary, 0, 0.3, 0.5);
        let mut c = phase_affinity_sim_cluster(model, &plan)
            .unwrap()
            .with_faults(driver(fplan));
        assert!(c.run(reqs));
        let m = c.merged_metrics();
        assert_eq!(m.requests_done, 4);
        assert_eq!(m.tokens_out - m.lost_tokens, expected);
        assert!(m.retries >= 1, "colocated victims must retry");
        assert!(m.down_s > 0.0);
        let end = c.makespan();
        let (cm, pm, dm) = c.pool_metrics();
        for (m, what) in [(&cm, "colocated"), (&pm, "prefill"), (&dm, "decode")] {
            assert_ledger_tiles(m, end, what);
        }
    }

    #[test]
    fn fault_runs_are_deterministic() {
        let mk = || {
            let plan = FaultPlan::new()
                .crash_repair(Pool::Primary, 0, 0.4, 0.6)
                .derate_window(Pool::Primary, 1, 0.2, 1.0, 0.5);
            let reqs: Vec<Request> =
                (0..10).map(|i| req(i, i as f64 * 0.1, 1024, 32)).collect();
            let mut c = cluster(2, 10_000).with_faults(driver(plan));
            assert!(c.run(reqs));
            fingerprint(&c.merged_metrics(), c.makespan())
        };
        assert_eq!(mk(), mk(), "same plan, same arrivals, same bits");
    }

    #[test]
    fn affinity_threshold_candidates_are_sorted_and_include_default() {
        let cands = affinity_threshold_candidates(TraceConfig::chat(2.0), 11, 200, 512);
        assert!(cands.contains(&512), "the fixed default must be a candidate");
        assert!(cands.windows(2).all(|w| w[0] < w[1]), "sorted + deduped");
        let again = affinity_threshold_candidates(TraceConfig::chat(2.0), 11, 200, 512);
        assert_eq!(cands, again, "seeded sampling is deterministic");
    }

    #[test]
    fn auto_affinity_threshold_never_worse_than_default() {
        // Synthetic scorer with a sharp interior optimum; the argmin
        // over candidates-including-default can match but never exceed
        // the default's cost, by construction.
        let cands = affinity_threshold_candidates(TraceConfig::chat(2.0), 11, 200, 512);
        let cost = |t: usize| ((t as f64) - 700.0).abs() + 1.0;
        let best = auto_affinity_threshold(&cands, cost);
        assert!(cost(best) <= cost(512), "tuned threshold beats or ties the default");
        // Degenerate scorer (flat): ties keep the smallest candidate.
        let flat = auto_affinity_threshold(&cands, |_| 1.0);
        assert_eq!(flat, cands[0]);
    }
}
