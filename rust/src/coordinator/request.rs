//! Request lifecycle types.

use crate::workload::trace::{Request, TenantClass};

pub type SeqId = u64;

/// Lifecycle of a sequence in the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestState {
    /// Admitted, waiting for prefill.
    Queued,
    /// Prefill executed, first token emitted.
    Decoding,
    /// All output tokens generated.
    Finished,
    /// Evicted under memory pressure, awaiting re-prefill.
    Preempted,
}

/// Which serving leg a sequence represents (disaggregated serving
/// splits one request across a prefill pool and a decode pool).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SeqRole {
    /// Colocated request: prefill + decode on one engine.
    #[default]
    Full,
    /// Disaggregated prefill leg: compute the prompt KV + first token,
    /// then hold the KV for migration. Request-level metrics (TTFT,
    /// e2e, requests_done) are deferred to the decode pool, which owns
    /// the request's end — unless decode-pool admission control
    /// bounces the migration, in which case the leg resumes locally as
    /// `Full` (`Engine::resume_bounced`) and this engine samples the
    /// deferred TTFT at the original prefill emission.
    PrefillLeg,
    /// Disaggregated decode leg: the context KV arrived over the
    /// scale-out fabric — no local prefill compute; the engine streams
    /// the remaining output tokens. Recompute preemption demotes the
    /// sequence to `Full` (its KV is gone, so the re-prefill is real).
    DecodeLeg,
}

/// A prefilled sequence handed to the decode pool: its context KV
/// (and first token) materialize over the fabric at `at`.
#[derive(Debug, Clone)]
pub struct MigratedRequest {
    pub id: SeqId,
    /// Original request arrival (TTFT / e2e reference).
    pub arrival: f64,
    /// Delivery instant on the shared virtual timeline: when the
    /// decode pool learns about the request (first chunk landed, TTFT
    /// reference for the streamed prefill token).
    pub at: f64,
    /// When the *last* KV chunk lands. Decode compute needs every
    /// layer's KV resident, so local token generation is gated here
    /// (per-layer decode gating, DESIGN.md §13.5); single-shot
    /// transfers have `kv_ready_s == at`.
    pub kv_ready_s: f64,
    /// Context tokens whose KV arrived (prompt + the prefill token).
    pub context_len: usize,
    /// Output tokens still to generate on the decode pool.
    pub remaining_out: usize,
    /// KV bytes that crossed the fabric (migration accounting).
    pub bytes: f64,
}

/// A sequence tracked by the engine.
#[derive(Debug, Clone)]
pub struct Sequence {
    pub id: SeqId,
    pub state: RequestState,
    pub role: SeqRole,
    /// Tenant class: the batcher admits interactive sequences ahead of
    /// batch ones (aging-bounded, see `BatcherConfig::batch_aging_s`).
    pub class: TenantClass,
    pub prompt_len: usize,
    /// Target number of output tokens.
    pub output_len: usize,
    /// Tokens generated so far *in the current pass* — reset to zero
    /// by recompute preemption (the re-prefill regenerates them).
    pub generated: usize,
    /// Tokens actually delivered to the caller across all passes.
    /// Unlike `generated`, this survives preemption and ends equal to
    /// the request's original `output_len`.
    pub delivered: usize,
    /// Arrival time (engine clock, s). For a migrated decode leg this
    /// is the migration delivery instant — the moment the sequence
    /// becomes schedulable on this engine.
    pub arrival: f64,
    /// Earliest instant the batcher may schedule this sequence: its
    /// arrival for fresh requests, the last KV chunk's landing for
    /// migrated decode legs (decode needs every layer resident).
    pub ready_at_s: f64,
    /// Original request arrival for migrated sequences (e2e latency is
    /// measured from the origin, not from the migration delivery).
    pub origin_arrival: Option<f64>,
    /// Time of first token (TTFT reference), if prefilled.
    pub first_token_at: Option<f64>,
    /// Completion time.
    pub finished_at: Option<f64>,
    /// KV blocks held (block ids in the allocator).
    pub blocks: Vec<usize>,
}

impl Sequence {
    pub fn from_request(r: &Request) -> Self {
        Sequence {
            id: r.id,
            state: RequestState::Queued,
            role: SeqRole::Full,
            class: r.class,
            prompt_len: r.prompt_len,
            output_len: r.output_len,
            generated: 0,
            delivered: 0,
            arrival: r.arrival,
            ready_at_s: r.arrival,
            origin_arrival: None,
            first_token_at: None,
            finished_at: None,
            blocks: Vec::new(),
        }
    }

    /// A decode leg materializing from a KV migration: the context is
    /// already prefilled (the first token was delivered with the KV at
    /// `m.at`), so the sequence skips prefill compute entirely.
    pub fn migrated(m: &MigratedRequest) -> Self {
        Sequence {
            id: m.id,
            state: RequestState::Queued,
            role: SeqRole::DecodeLeg,
            // Migrations ride the interactive path: only multi-token
            // interactive-SLO requests disaggregate today.
            class: TenantClass::Interactive,
            prompt_len: m.context_len,
            output_len: m.remaining_out,
            generated: 0,
            delivered: 1, // the prefill-pool token, delivered at `at`
            arrival: m.at,
            ready_at_s: m.kv_ready_s.max(m.at),
            origin_arrival: Some(m.arrival),
            first_token_at: Some(m.at),
            finished_at: None,
            blocks: Vec::new(),
        }
    }

    /// Current context length (prompt + generated so far).
    pub fn context_len(&self) -> usize {
        self.prompt_len + self.generated
    }

    pub fn is_done(&self) -> bool {
        self.generated >= self.output_len
    }

    /// Tokens of KV the sequence will hold at completion.
    pub fn max_context(&self) -> usize {
        self.prompt_len + self.output_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> Request {
        Request {
            id: 7,
            arrival: 1.5,
            prompt_len: 100,
            output_len: 10,
            class: TenantClass::Interactive,
        }
    }

    #[test]
    fn lifecycle_fields() {
        let s = Sequence::from_request(&req());
        assert_eq!(s.id, 7);
        assert_eq!(s.state, RequestState::Queued);
        assert_eq!(s.context_len(), 100);
        assert_eq!(s.max_context(), 110);
        assert!(!s.is_done());
    }

    #[test]
    fn done_after_output_len() {
        let mut s = Sequence::from_request(&req());
        s.generated = 10;
        assert!(s.is_done());
        assert_eq!(s.context_len(), 110);
    }

    #[test]
    fn migrated_sequence_resumes_mid_request() {
        let m = MigratedRequest {
            id: 7,
            arrival: 1.5,
            at: 2.0,
            kv_ready_s: 2.0,
            context_len: 101, // prompt 100 + the prefill token
            remaining_out: 9,
            bytes: 101.0 * 131072.0,
        };
        let s = Sequence::migrated(&m);
        assert_eq!(s.role, SeqRole::DecodeLeg);
        assert_eq!(s.state, RequestState::Queued);
        assert_eq!(s.context_len(), 101);
        assert_eq!(s.delivered, 1, "the prefill token travelled with the KV");
        assert_eq!(s.arrival, 2.0, "schedulable only once the KV arrived");
        assert_eq!(s.ready_at_s, 2.0, "single-shot: decodable at delivery");
        assert_eq!(s.origin_arrival, Some(1.5));
        assert_eq!(s.first_token_at, Some(2.0));
        assert!(!s.is_done());
    }

    #[test]
    fn chunked_migration_gates_decode_at_last_chunk() {
        let m = MigratedRequest {
            id: 7,
            arrival: 1.5,
            at: 2.0,        // first chunk: delivery + TTFT reference
            kv_ready_s: 2.8, // last chunk: all layers resident
            context_len: 101,
            remaining_out: 9,
            bytes: 101.0 * 131072.0,
        };
        let s = Sequence::migrated(&m);
        assert_eq!(s.arrival, 2.0, "known to the decode pool at first chunk");
        assert_eq!(s.first_token_at, Some(2.0), "streamed token unaffected by gating");
        assert_eq!(s.ready_at_s, 2.8, "local decode waits for the last layer's KV");
    }
}
