//! Request lifecycle types.

use crate::workload::trace::Request;

pub type SeqId = u64;

/// Lifecycle of a sequence in the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestState {
    /// Admitted, waiting for prefill.
    Queued,
    /// Prefill executed, first token emitted.
    Decoding,
    /// All output tokens generated.
    Finished,
    /// Evicted under memory pressure, awaiting re-prefill.
    Preempted,
}

/// A sequence tracked by the engine.
#[derive(Debug, Clone)]
pub struct Sequence {
    pub id: SeqId,
    pub state: RequestState,
    pub prompt_len: usize,
    /// Target number of output tokens.
    pub output_len: usize,
    /// Tokens generated so far *in the current pass* — reset to zero
    /// by recompute preemption (the re-prefill regenerates them).
    pub generated: usize,
    /// Tokens actually delivered to the caller across all passes.
    /// Unlike `generated`, this survives preemption and ends equal to
    /// the request's original `output_len`.
    pub delivered: usize,
    /// Arrival time (engine clock, s).
    pub arrival: f64,
    /// Time of first token (TTFT reference), if prefilled.
    pub first_token_at: Option<f64>,
    /// Completion time.
    pub finished_at: Option<f64>,
    /// KV blocks held (block ids in the allocator).
    pub blocks: Vec<usize>,
}

impl Sequence {
    pub fn from_request(r: &Request) -> Self {
        Sequence {
            id: r.id,
            state: RequestState::Queued,
            prompt_len: r.prompt_len,
            output_len: r.output_len,
            generated: 0,
            delivered: 0,
            arrival: r.arrival,
            first_token_at: None,
            finished_at: None,
            blocks: Vec::new(),
        }
    }

    /// Current context length (prompt + generated so far).
    pub fn context_len(&self) -> usize {
        self.prompt_len + self.generated
    }

    pub fn is_done(&self) -> bool {
        self.generated >= self.output_len
    }

    /// Tokens of KV the sequence will hold at completion.
    pub fn max_context(&self) -> usize {
        self.prompt_len + self.output_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> Request {
        Request { id: 7, arrival: 1.5, prompt_len: 100, output_len: 10 }
    }

    #[test]
    fn lifecycle_fields() {
        let s = Sequence::from_request(&req());
        assert_eq!(s.id, 7);
        assert_eq!(s.state, RequestState::Queued);
        assert_eq!(s.context_len(), 100);
        assert_eq!(s.max_context(), 110);
        assert!(!s.is_done());
    }

    #[test]
    fn done_after_output_len() {
        let mut s = Sequence::from_request(&req());
        s.generated = 10;
        assert!(s.is_done());
        assert_eq!(s.context_len(), 110);
    }
}
