//! The serving engine: continuous-batching step loop over an
//! [`ExecutionBackend`].
//!
//! One `step()` = admission (batcher) → plan (scheduler) → execute
//! (backend) → bookkeeping (KV growth, completion, preemption,
//! metrics). The clock is virtual for `SimBackend` (advanced by
//! modelled step latency) and wall for `PjrtBackend` — identical
//! scheduling code either way (DESIGN.md §5).

use std::collections::HashMap;

use super::backend::ExecutionBackend;
use super::batcher::{Batcher, BatcherConfig};
use super::kv_cache::{BlockAllocator, KvCacheConfig};
use super::metrics::Metrics;
use super::request::{RequestState, SeqId, Sequence};
use super::scheduler::{plan, SchedulerPolicy, StepPlan};
use crate::workload::trace::Request;

#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub batcher: BatcherConfig,
    pub kv: KvCacheConfig,
    pub policy: SchedulerPolicy,
}

impl EngineConfig {
    pub fn new(kv: KvCacheConfig) -> Self {
        EngineConfig {
            batcher: BatcherConfig::default(),
            kv,
            policy: SchedulerPolicy::Fused,
        }
    }
}

pub struct Engine<B: ExecutionBackend> {
    pub backend: B,
    pub metrics: Metrics,
    seqs: HashMap<SeqId, Sequence>,
    batcher: Batcher,
    alloc: BlockAllocator,
    policy: SchedulerPolicy,
    clock: f64,
    preemptions: u64,
}

impl<B: ExecutionBackend> Engine<B> {
    pub fn new(cfg: EngineConfig, backend: B) -> Self {
        Engine {
            backend,
            metrics: Metrics::new(),
            seqs: HashMap::new(),
            batcher: Batcher::new(cfg.batcher),
            alloc: BlockAllocator::new(cfg.kv),
            policy: cfg.policy,
            clock: 0.0,
            preemptions: 0,
        }
    }

    pub fn clock(&self) -> f64 {
        self.clock
    }

    pub fn preemptions(&self) -> u64 {
        self.preemptions
    }

    pub fn pending(&self) -> usize {
        self.seqs
            .values()
            .filter(|s| s.state != RequestState::Finished)
            .count()
    }

    pub fn kv_utilization(&self) -> f64 {
        self.alloc.utilization()
    }

    /// Submit a request (the router's entry point).
    pub fn submit(&mut self, r: &Request) {
        let seq = Sequence::from_request(r);
        self.batcher.enqueue(seq.id);
        self.seqs.insert(seq.id, seq);
        self.clock = self.clock.max(r.arrival);
    }

    /// Run one engine step. Returns false if there was nothing to do.
    pub fn step(&mut self) -> bool {
        let adm = self.batcher.plan_step(&mut self.seqs, &mut self.alloc);
        let step_plan = plan(self.policy, adm);
        match step_plan {
            StepPlan::Idle => false,
            StepPlan::Prefill(ids) => {
                self.run_prefill(&ids);
                true
            }
            StepPlan::Decode(ids) => {
                self.run_decode(&ids);
                true
            }
            StepPlan::Both { prefills, decodes } => {
                // Disaggregated pools overlap; the engine's clock
                // advances by the max of the two phase latencies.
                let t0 = self.clock;
                self.run_prefill(&prefills);
                let t_pre = self.clock - t0;
                self.clock = t0;
                self.run_decode(&decodes);
                let t_dec = self.clock - t0;
                self.clock = t0 + t_pre.max(t_dec);
                true
            }
        }
    }

    /// Step until all submitted requests finish (or `max_steps`).
    pub fn run_to_completion(&mut self, max_steps: usize) -> bool {
        for _ in 0..max_steps {
            if self.pending() == 0 {
                return true;
            }
            if !self.step() && self.pending() > 0 {
                // Nothing schedulable but work remains: deadlock guard.
                return false;
            }
        }
        self.pending() == 0
    }

    fn run_prefill(&mut self, ids: &[SeqId]) {
        if ids.is_empty() {
            return;
        }
        let specs: Vec<(SeqId, usize)> = ids
            .iter()
            .map(|id| (*id, self.seqs[id].context_len()))
            .collect();
        let res = self.backend.prefill(&specs);
        self.clock += res.seconds;
        let n = ids.len();
        for id in ids {
            let arrival = {
                let seq = self.seqs.get_mut(id).expect("prefilled unknown seq");
                seq.state = RequestState::Decoding;
                seq.generated += 1; // prefill emits the first token
                seq.first_token_at = Some(self.clock);
                seq.arrival
            };
            self.metrics.record_first_token(arrival, self.clock);
            self.finish_if_done(*id);
        }
        self.metrics.record_step(res.seconds, res.watts, res.flops, n);
    }

    fn run_decode(&mut self, ids: &[SeqId]) {
        if ids.is_empty() {
            return;
        }
        let specs: Vec<(SeqId, usize)> = ids
            .iter()
            .map(|id| (*id, self.seqs[id].context_len()))
            .collect();
        let res = self.backend.decode(&specs);
        self.clock += res.seconds;
        for id in ids {
            let seq = self.seqs.get_mut(id).expect("decoded unknown seq");
            seq.generated += 1;
            let needed = seq.context_len();
            let mut blocks = std::mem::take(&mut seq.blocks);
            let ok = self.alloc.grow(&mut blocks, needed);
            let seq = self.seqs.get_mut(id).unwrap();
            seq.blocks = blocks;
            if !ok {
                self.preempt(*id);
                continue;
            }
            self.finish_if_done(*id);
        }
        self.metrics.record_step(res.seconds, res.watts, res.flops, ids.len());
    }

    fn finish_if_done(&mut self, id: SeqId) {
        let done = self.seqs[&id].is_done();
        if !done {
            return;
        }
        let seq = self.seqs.get_mut(&id).unwrap();
        seq.state = RequestState::Finished;
        seq.finished_at = Some(self.clock);
        let (arrival, first) = (seq.arrival, seq.first_token_at.unwrap_or(self.clock));
        let out = seq.generated;
        let mut blocks = std::mem::take(&mut seq.blocks);
        self.alloc.release(&mut blocks);
        self.backend.release(id);
        self.metrics.record_finish(arrival, first, self.clock, out);
    }

    /// Evict a sequence under memory pressure: drop its KV, requeue
    /// for a full re-prefill of prompt+generated (vLLM recompute-mode
    /// preemption).
    fn preempt(&mut self, id: SeqId) {
        self.preemptions += 1;
        let seq = self.seqs.get_mut(&id).unwrap();
        seq.state = RequestState::Preempted;
        let mut blocks = std::mem::take(&mut seq.blocks);
        self.alloc.release(&mut blocks);
        self.backend.release(id);
        // Re-prefill covers everything generated so far.
        let seq = self.seqs.get_mut(&id).unwrap();
        seq.prompt_len = seq.context_len();
        let gen = seq.generated;
        seq.output_len -= gen.min(seq.output_len);
        seq.generated = 0;
        seq.state = RequestState::Queued;
        self.batcher.enqueue(id);
    }

    pub fn sequence(&self, id: SeqId) -> Option<&Sequence> {
        self.seqs.get(&id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::perfmodel::{PrecisionMode, StepConfig};
    use crate::coordinator::backend::SimBackend;
    use crate::hwsim::spec::Device;
    use crate::workload::llama::by_name;

    fn engine(total_blocks: usize) -> Engine<SimBackend> {
        let kv = KvCacheConfig { block_tokens: 16, total_blocks };
        let backend = SimBackend::new(
            by_name("llama-8b").unwrap(),
            StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()),
        );
        Engine::new(EngineConfig::new(kv), backend)
    }

    fn req(id: u64, arrival: f64, p: usize, o: usize) -> Request {
        Request { id, arrival, prompt_len: p, output_len: o }
    }

    #[test]
    fn single_request_completes() {
        let mut e = engine(1000);
        e.submit(&req(0, 0.0, 100, 10));
        assert!(e.run_to_completion(1000));
        let s = e.sequence(0).unwrap();
        assert_eq!(s.state, RequestState::Finished);
        assert_eq!(s.generated, 10);
        assert_eq!(e.metrics.requests_done, 1);
        assert_eq!(e.metrics.tokens_out, 10);
        // KV fully released.
        assert_eq!(e.kv_utilization(), 0.0);
    }

    #[test]
    fn batch_of_requests_completes() {
        let mut e = engine(10_000);
        for i in 0..20 {
            e.submit(&req(i, 0.0, 64, 32));
        }
        assert!(e.run_to_completion(10_000));
        assert_eq!(e.metrics.requests_done, 20);
        assert_eq!(e.metrics.tokens_out, 20 * 32);
        assert_eq!(e.preemptions(), 0);
    }

    #[test]
    fn ttft_before_completion() {
        let mut e = engine(1000);
        e.submit(&req(0, 0.0, 100, 50));
        assert!(e.run_to_completion(1000));
        let ttft = e.metrics.ttft.pct(50.0);
        let e2e = e.metrics.e2e_latency.pct(50.0);
        assert!(ttft > 0.0 && ttft < e2e, "ttft {ttft} e2e {e2e}");
    }

    #[test]
    fn memory_pressure_triggers_preemption_and_still_finishes() {
        // Tiny pool: 8 blocks = 128 tokens of KV for everything.
        let mut e = engine(8);
        for i in 0..3 {
            e.submit(&req(i, 0.0, 32, 40));
        }
        assert!(e.run_to_completion(100_000), "must drain despite pressure");
        assert_eq!(e.metrics.requests_done, 3);
        assert!(e.preemptions() > 0, "expected preemption under pressure");
    }

    #[test]
    fn impossible_request_does_not_livelock() {
        // A sequence whose prompt alone exceeds the whole pool can
        // never be admitted: run_to_completion must return false, not
        // spin forever.
        let mut e = engine(2); // 32 tokens
        e.submit(&req(0, 0.0, 100, 4));
        assert!(!e.run_to_completion(1000));
    }

    #[test]
    fn batching_improves_throughput() {
        // The §5.1 batching claim, reproduced end-to-end: 32 requests
        // served together finish far sooner (virtual time) than
        // serially.
        let serial_time = {
            let mut total = 0.0;
            for i in 0..32 {
                let mut e = engine(100_000);
                e.submit(&req(i, 0.0, 128, 64));
                assert!(e.run_to_completion(10_000));
                total += e.clock();
            }
            total
        };
        let batched_time = {
            let mut e = engine(100_000);
            for i in 0..32 {
                e.submit(&req(i, 0.0, 128, 64));
            }
            assert!(e.run_to_completion(10_000));
            e.clock()
        };
        assert!(
            batched_time < serial_time / 4.0,
            "batched {batched_time} serial {serial_time}"
        );
    }

    #[test]
    fn disaggregated_policy_overlaps_phases() {
        let kv = KvCacheConfig { block_tokens: 16, total_blocks: 100_000 };
        let mk = |policy| {
            let backend = SimBackend::new(
                by_name("llama-8b").unwrap(),
                StepConfig::new(Device::H100, PrecisionMode::fp8_dynamic()),
            );
            let mut cfg = EngineConfig::new(kv.clone());
            cfg.policy = policy;
            Engine::new(cfg, backend)
        };
        // Steady stream so prefills and decodes coexist.
        let mut fused = mk(SchedulerPolicy::Fused);
        let mut disagg = mk(SchedulerPolicy::Disaggregated);
        for e in [&mut fused, &mut disagg] {
            for i in 0..64 {
                e.submit(&req(i, 0.0, 256, 64));
            }
            assert!(e.run_to_completion(100_000));
        }
        // Overlapping phases cannot be slower in virtual time.
        assert!(disagg.clock() <= fused.clock() * 1.05,
                "disagg {} fused {}", disagg.clock(), fused.clock());
    }
}
