//! The serving engine: continuous-batching step loop over an
//! [`ExecutionBackend`].
//!
//! One `step()` = admission (batcher) → plan (scheduler) → execute
//! (backend) → bookkeeping (KV growth, completion, preemption,
//! metrics). The clock is virtual for `SimBackend` (advanced by
//! modelled step latency) and wall for `PjrtBackend` — identical
//! scheduling code either way (DESIGN.md §5).
//!
//! Virtual-time semantics (DESIGN.md §5 addendum):
//!
//! * `submit` never moves the clock — a queued request becomes
//!   schedulable only once the clock reaches its arrival, so an
//!   open-loop Poisson trace keeps its shape instead of collapsing to
//!   batch-at-t0 (and TTFT is measured from each request's own
//!   arrival).
//! * When nothing is runnable *now* but queued work exists in the
//!   future, `step` jumps the clock to the next arrival (idle-advance)
//!   rather than reporting a deadlock.
//! * A cluster driver advances several engines on one shared timeline
//!   with [`Engine::step_until`] + [`Engine::advance_to`]
//!   (`coordinator::cluster`).
//! * Preemption accounting: TTFT is sampled once per request at its
//!   first emission (a recompute re-prefill bumps `metrics.restarts`
//!   instead), and a token whose KV growth fails is rolled back so
//!   `tokens_out` counts every delivered token exactly once.
//! * Event-driven fast-forward (DESIGN.md §13): `step_until` and
//!   `run_to_completion` collapse provably-static decode windows into
//!   O(1)-per-step analytic charges, bit-identical to stepping —
//!   `set_event_mode(false)` restores the pure stepper for the
//!   differential suite's reference runs.

use std::collections::HashMap;

use super::backend::ExecutionBackend;
use super::batcher::{AdmissionOutlook, Batcher, BatcherConfig};
use super::kv_cache::{BlockAllocator, KvCacheConfig};
use super::metrics::Metrics;
use super::request::{MigratedRequest, RequestState, SeqId, SeqRole, Sequence};
use super::scheduler::{plan, SchedulerPolicy, StepPlan};
use crate::workload::trace::Request;

#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub batcher: BatcherConfig,
    pub kv: KvCacheConfig,
    pub policy: SchedulerPolicy,
}

impl EngineConfig {
    pub fn new(kv: KvCacheConfig) -> Self {
        EngineConfig {
            batcher: BatcherConfig::default(),
            kv,
            policy: SchedulerPolicy::Fused,
        }
    }

    /// Config for one *sharded* model instance (the engine unit of a
    /// multi-chip deployment): the KV pool is sized from the device
    /// spec through the HBM capacity check, so an infeasible
    /// (model x device x plan) combination is a typed error here
    /// rather than a silently impossible simulation downstream.
    pub fn for_instance(
        model: &'static crate::workload::llama::LlamaConfig,
        device: crate::hwsim::spec::Device,
        plan: crate::analysis::parallel::ParallelismPlan,
        weight_bytes_per_elem: f64,
        kv_bytes_per_elem: f64,
    ) -> Result<Self, crate::analysis::parallel::CapacityError> {
        let kv = KvCacheConfig::for_instance(
            model,
            device,
            plan,
            weight_bytes_per_elem,
            kv_bytes_per_elem,
            crate::analysis::parallel::DEFAULT_MIN_KV_TOKENS,
        )?;
        Ok(EngineConfig::new(kv))
    }
}

/// What a replica crash destroyed ([`Engine::crash`]): the ids whose
/// requests died resident on the replica (the fault driver re-submits
/// them through the retry queue) and the token accounting the crash
/// charged to [`Metrics::lost_tokens`] / \
/// [`Metrics::recompute_tokens_wasted`].
#[derive(Debug, Default)]
pub struct LostWork {
    /// Sequences that died unfinished (queued, decoding, preempted, or
    /// a finished prefill leg whose hand-off had not been harvested) —
    /// in a deterministic order, so retry scheduling is reproducible.
    pub ids: Vec<SeqId>,
    /// Output tokens those sequences had already delivered to their
    /// streams — produced goodput that can never complete.
    pub lost_tokens: u64,
    /// Context tokens (prompt + generated) whose compute must be
    /// redone from scratch on retry (sequences that never prefilled
    /// wasted nothing).
    pub recompute_tokens_wasted: u64,
}

pub struct Engine<B: ExecutionBackend> {
    pub backend: B,
    pub metrics: Metrics,
    /// The *hot* map: only sequences that are still live (queued,
    /// decoding, preempted, or holding KV for an in-flight migration
    /// hand-off). Finished sequences move to `archive`, so per-step
    /// work scales with active load, not with trace length
    /// (DESIGN.md §9).
    seqs: HashMap<SeqId, Sequence>,
    /// Harvest archive: finished sequences, kept for post-run
    /// inspection (`sequences`, `sequence`) off the hot path. A
    /// hand-off leg parks here with its KV blocks until
    /// `release_migrated` / `resume_bounced` settles the migration.
    archive: HashMap<SeqId, Sequence>,
    batcher: Batcher,
    alloc: BlockAllocator,
    policy: SchedulerPolicy,
    clock: f64,
    preemptions: u64,
    /// Sequences not yet Finished — `pending()` must not rescan the
    /// maps (the cluster loop and `LeastLoaded` routing call it per
    /// step).
    active: usize,
    /// Prefill legs whose prefill finished and whose KV awaits
    /// migration to a decode pool (drained by `take_handoffs`).
    handoffs: Vec<SeqId>,
    /// Event-driven fast-forward (DESIGN.md §13) inside `step_until` /
    /// `run_to_completion`: when the batch composition is provably
    /// static, decode steps are charged analytically in O(1) each
    /// instead of through the full plan/execute/bookkeep loop. On by
    /// default — the differential suite's reference runs switch it off
    /// to produce the step-by-step trajectory the fast-forwarded one
    /// must match bit-for-bit.
    event_mode: bool,
}

impl<B: ExecutionBackend> Engine<B> {
    pub fn new(cfg: EngineConfig, backend: B) -> Self {
        Engine {
            backend,
            metrics: Metrics::new(),
            seqs: HashMap::new(),
            archive: HashMap::new(),
            batcher: Batcher::new(cfg.batcher),
            alloc: BlockAllocator::new(cfg.kv),
            policy: cfg.policy,
            clock: 0.0,
            preemptions: 0,
            active: 0,
            handoffs: Vec::new(),
            event_mode: true,
        }
    }

    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// Toggle the event-driven fast-forward (on by default). `step()`
    /// itself is always the step-by-step reference; this only governs
    /// whether `step_until`/`run_to_completion` may collapse static
    /// windows analytically.
    pub fn set_event_mode(&mut self, on: bool) {
        self.event_mode = on;
    }

    pub fn event_mode(&self) -> bool {
        self.event_mode
    }

    pub fn preemptions(&self) -> u64 {
        self.preemptions
    }

    pub fn pending(&self) -> usize {
        self.active
    }

    pub fn kv_utilization(&self) -> f64 {
        self.alloc.utilization()
    }

    /// Iterate every sequence the engine has ever accepted (finished
    /// ones included) — cluster tests and fairness audits read
    /// per-request timestamps through this.
    pub fn sequences(&self) -> impl Iterator<Item = &Sequence> + '_ {
        // simlint: allow(determinism) -- post-run inspection API; callers sort (tests, audits), nothing ordered feeds the schedule
        self.seqs.values().chain(self.archive.values())
    }

    /// Finished sequences parked in the harvest archive — the resident
    /// history the hot path must *not* scale with (asserted by
    /// `benches/perf_hotpath.rs`).
    pub fn finished_resident(&self) -> usize {
        self.archive.len()
    }

    /// Submit a request (the router's entry point). Does NOT move the
    /// clock: the request waits in the queue until the clock reaches
    /// its arrival.
    pub fn submit(&mut self, r: &Request) {
        let seq = Sequence::from_request(r);
        self.batcher.enqueue(seq.id, seq.class);
        if self.seqs.insert(seq.id, seq).is_none() {
            self.active += 1;
        }
    }

    /// Submit the prefill leg of a disaggregated request: compute the
    /// prompt KV + first token, then hold the KV for migration
    /// (`take_handoffs` / `release_migrated`). Request-level metrics
    /// are recorded by the decode pool, which owns the request's end.
    pub fn submit_handoff(&mut self, r: &Request) {
        let mut seq = Sequence::from_request(r);
        seq.role = SeqRole::PrefillLeg;
        seq.output_len = 1; // prefill emits exactly the first token
        self.batcher.enqueue(seq.id, seq.class);
        if self.seqs.insert(seq.id, seq).is_none() {
            self.active += 1;
        }
    }

    /// Submit a migrated decode leg: the context KV (and first token)
    /// arrived over the fabric at `m.at`. TTFT is sampled here — it
    /// spans prefill queueing, prefill compute AND the KV transfer,
    /// because the user sees the first token only when it lands with
    /// the migrated cache.
    pub fn submit_migrated(&mut self, m: &MigratedRequest) {
        debug_assert!(
            m.context_len <= self.alloc.config().tokens_capacity(),
            "migrated context ({} tokens) can never fit this decode pool \
             ({} KV tokens) — it would deadlock, not queue",
            m.context_len,
            self.alloc.config().tokens_capacity(),
        );
        let seq = Sequence::migrated(m);
        self.metrics.record_first_token(m.arrival, m.at);
        self.metrics.record_migration(m.bytes);
        self.batcher.enqueue(seq.id, seq.class);
        if self.seqs.insert(seq.id, seq).is_none() {
            self.active += 1;
        }
    }

    /// Decode-pool admission probe: can this engine hold a migrated
    /// context *and* its first locally generated token right now,
    /// without evicting anything? The footprint matches the batcher's
    /// resume reservation
    /// ([`migration_footprint_tokens`](super::batcher::migration_footprint_tokens)),
    /// so an
    /// accepted migration's first decode step can never fail its KV
    /// grow — admission control rejects exactly the migrations that
    /// would otherwise preempt immediately (or deadlock outright when
    /// the context exceeds the whole pool).
    pub fn can_admit_migration(&self, context_len: usize) -> bool {
        let blocks = self
            .alloc
            .config()
            .blocks_for_tokens(super::batcher::migration_footprint_tokens(context_len));
        self.alloc.can_allocate(blocks)
    }

    /// Bounce a finished prefill leg back to colocated execution:
    /// decode-pool admission control rejected its migration, so the
    /// sequence — which still holds its prompt KV — resumes decoding
    /// the remaining `remaining_out` tokens right here as
    /// `SeqRole::Full`. The first token was emitted locally at prefill
    /// time, so the deferred TTFT is sampled now from that original
    /// emission instant; the bounce is counted in
    /// [`Metrics::bounces`].
    pub fn resume_bounced(&mut self, id: SeqId, remaining_out: usize) {
        let Some(mut seq) = self.archive.remove(&id) else {
            debug_assert!(false, "resume_bounced: unknown sequence {id}");
            return;
        };
        debug_assert_eq!(seq.role, SeqRole::PrefillLeg, "only prefill legs bounce");
        debug_assert_eq!(seq.state, RequestState::Finished, "bounce follows handoff");
        seq.role = SeqRole::Full;
        let arrival = seq.arrival;
        debug_assert!(seq.first_token_at.is_some(), "prefill leg emitted its token");
        let first = seq.first_token_at.unwrap_or(self.clock);
        self.metrics.record_first_token(arrival, first);
        self.metrics.record_bounce();
        if remaining_out == 0 {
            // Nothing left to decode (the coordinator never hands off
            // single-token requests, but guard the API): the request
            // is already complete — close it out without re-activating
            // a done sequence, which would decode a phantom token.
            debug_assert!(seq.finished_at.is_some(), "prefill leg finished");
            let finished = seq.finished_at.unwrap_or(self.clock);
            let out = seq.delivered;
            let mut blocks = std::mem::take(&mut seq.blocks);
            self.alloc.release(&mut blocks);
            self.metrics.record_finish(arrival, first, finished, out);
            self.archive.insert(id, seq);
            return;
        }
        seq.state = RequestState::Decoding;
        seq.output_len += remaining_out;
        seq.finished_at = None;
        self.active += 1;
        self.batcher.mark_decoding(id);
        self.seqs.insert(id, seq);
    }

    /// Drain the handoff queue: prefill legs whose prefill finished
    /// since the last call, ready to start their KV migration.
    pub fn take_handoffs(&mut self) -> Vec<SeqId> {
        std::mem::take(&mut self.handoffs)
    }

    /// Release a handed-off sequence's KV blocks once its migration to
    /// the decode pool completes — in-flight transfers keep their
    /// source blocks resident until then, so a saturated prefill pool
    /// back-pressures on slow fabrics. The finished leg lives in the
    /// harvest archive by the time its transfer settles.
    pub fn release_migrated(&mut self, id: SeqId) {
        if let Some(seq) = self.archive.get_mut(&id).or_else(|| self.seqs.get_mut(&id)) {
            let mut blocks = std::mem::take(&mut seq.blocks);
            self.alloc.release(&mut blocks);
        }
    }

    /// Lift an *idle* engine's clock to `t` (the arrival instant of
    /// newly routed work), billing the skipped gap at the device's
    /// idle draw. A no-op while work is in flight — the clock then
    /// already reflects time spent serving and must not skip ahead of
    /// pending steps.
    pub fn advance_to(&mut self, t: f64) {
        if self.pending() == 0 {
            self.close_ledger(t);
        }
    }

    /// Close the energy ledger at `t` (typically the cluster
    /// makespan): accrue idle draw over the tail gap between this
    /// engine's clock and `t`, and lift the clock. After every engine
    /// is closed at the same instant, each one's `span + idle_s`
    /// covers the full timeline, so busy + idle energy equals the
    /// integral of draw over the makespan — the conservation property
    /// `tests/cluster_sim.rs` pins. No-op when `t <= clock`.
    pub fn close_ledger(&mut self, t: f64) {
        if t > self.clock {
            self.metrics.record_idle(t - self.clock, self.backend.idle_draw_w());
            self.clock = t;
        }
    }

    /// Close the ledger at `t` with the replica *power-gated* (the
    /// autoscaler's sleep state): the gap draws 0 W instead of idle
    /// draw. Gated time joins the timeline-tiling identity as its own
    /// component — `span + idle_s + gated_s` covers the closed
    /// timeline — without adding energy, which is exactly what makes
    /// an autoscaled fleet cheaper than a static one under the PR 7
    /// idle-aware ledger. No-op when `t <= clock`.
    pub fn close_ledger_gated(&mut self, t: f64) {
        if t > self.clock {
            self.metrics.record_gated(t - self.clock);
            self.clock = t;
        }
    }

    /// Close the ledger at `t` with the replica *crashed / under
    /// repair*: the gap draws 0 W and serves nothing. Down time is the
    /// fourth ledger arm — with fault injection in play
    /// `span + idle_s + gated_s + down_s` tiles the closed timeline
    /// exactly. No-op when `t <= clock`.
    pub fn close_ledger_down(&mut self, t: f64) {
        if t > self.clock {
            self.metrics.record_down(t - self.clock);
            self.clock = t;
        }
    }

    /// Thread a bandwidth derate (degraded mode: thermal throttling,
    /// partial-HBM fault) through to the backend's step-cost model.
    /// `1.0` restores healthy full-bandwidth behaviour.
    pub fn set_bw_derate(&mut self, factor: f64) {
        self.backend.set_bw_derate(factor);
    }

    /// Kill this replica at `t_s` (fault injection): everything
    /// resident dies with the HBM — queued, decoding and preempted
    /// sequences, plus finished prefill legs whose hand-off has not
    /// been harvested yet. Their ids come back in [`LostWork`] in a
    /// deterministic order so the fault driver can schedule retries
    /// reproducibly; delivered tokens are charged to
    /// [`Metrics::lost_tokens`] and already-computed context to
    /// [`Metrics::recompute_tokens_wasted`]. The KV allocator is
    /// rebuilt empty. Harvested hand-off legs parked in the archive
    /// with in-flight transfers are NOT revoked here — delivery
    /// commits the stream — but the caller must suppress their pending
    /// transfer/release events, because their block ids refer to the
    /// pre-crash allocator.
    ///
    /// Bills the pre-crash idle tail up to `t_s` first (a busy
    /// engine's clock is already at or past `t_s` and keeps its served
    /// span).
    pub fn crash(&mut self, t_s: f64) -> LostWork {
        self.close_ledger(t_s);
        let mut lost = LostWork::default();
        // Lane order (interactive front-to-back, batch lane, decode
        // set ascending) is the reproducible victim order. Lanes prune
        // lazily, so ids without a live sequence are skipped.
        for id in self.batcher.reset() {
            let Some(seq) = self.seqs.remove(&id) else {
                continue;
            };
            Self::charge_lost(&seq, &mut lost, id);
        }
        // Unharvested hand-offs live in the archive but their KV (and
        // the first token in the not-yet-started transfer) is gone.
        for id in self.take_handoffs() {
            let Some(seq) = self.archive.remove(&id) else {
                continue;
            };
            Self::charge_lost(&seq, &mut lost, id);
        }
        // Defensive: the lanes + decode set + handoffs cover every
        // live sequence by construction; if an invariant ever slips,
        // drain the remainder in sorted-id order rather than leak it.
        if !self.seqs.is_empty() {
            // simlint: allow(determinism) -- ids are sorted before use
            let mut rest: Vec<SeqId> = self.seqs.keys().copied().collect();
            rest.sort_unstable();
            for id in rest {
                if let Some(seq) = self.seqs.remove(&id) {
                    Self::charge_lost(&seq, &mut lost, id);
                }
            }
        }
        for id in &lost.ids {
            self.backend.release(*id);
        }
        self.active = 0;
        self.alloc = BlockAllocator::new(self.alloc.config().clone());
        self.metrics.lost_tokens += lost.lost_tokens;
        self.metrics.recompute_tokens_wasted += lost.recompute_tokens_wasted;
        lost
    }

    fn charge_lost(seq: &Sequence, lost: &mut LostWork, id: SeqId) {
        lost.lost_tokens += seq.delivered as u64;
        if seq.first_token_at.is_some() {
            // Prefill ran: the whole resident context is compute the
            // retry redoes from scratch. Never-prefilled queue entries
            // wasted nothing.
            lost.recompute_tokens_wasted += seq.context_len() as u64;
        }
        lost.ids.push(id);
    }

    /// Fault-layer accounting for a harvested hand-off leg whose
    /// in-flight KV transfer died with this (source) replica before
    /// delivery: charge its streamed token and recomputed context, and
    /// drop the parked archive entry — its block ids refer to the
    /// pre-crash allocator and must never be released into the rebuilt
    /// one. No-op for unknown ids (already delivered or never parked).
    pub fn void_migration(&mut self, id: SeqId) {
        if let Some(seq) = self.archive.remove(&id) {
            self.metrics.lost_tokens += seq.delivered as u64;
            if seq.first_token_at.is_some() {
                self.metrics.recompute_tokens_wasted += seq.context_len() as u64;
            }
        }
    }

    /// Run one engine step. Returns false if there was nothing to do
    /// (now or at any queued future arrival).
    pub fn step(&mut self) -> bool {
        let mut adm = self.batcher.plan_step(&mut self.seqs, &mut self.alloc, self.clock);
        if adm.prefills.is_empty() && adm.decodes.is_empty() {
            // Arrival-aware idle: nothing runnable at the current
            // clock, but queued work exists in the future — jump to
            // the next arrival instead of reporting a deadlock.
            if let Some(t) = self.batcher.head_arrival(&self.seqs) {
                if t > self.clock {
                    // The jumped-over gap is real time the device sat
                    // powered but unloaded: bill it at idle draw.
                    self.metrics.record_idle(t - self.clock, self.backend.idle_draw_w());
                    self.clock = t;
                    adm = self.batcher.plan_step(&mut self.seqs, &mut self.alloc, self.clock);
                }
            }
        }
        let step_plan = plan(self.policy, adm);
        let ran = match step_plan {
            StepPlan::Idle => false,
            StepPlan::Prefill(ids) => {
                self.run_prefill(&ids);
                true
            }
            StepPlan::Decode(ids) => {
                self.run_decode(&ids);
                true
            }
            StepPlan::Both { prefills, decodes } => {
                // Disaggregated pools overlap; the engine's clock
                // advances by the max of the two phase latencies.
                let t0 = self.clock;
                self.run_prefill(&prefills);
                let t_pre = self.clock - t0;
                self.clock = t0;
                self.run_decode(&decodes);
                let t_dec = self.clock - t0;
                self.clock = t0 + t_pre.max(t_dec);
                true
            }
        };
        if ran {
            // Mirror the backend's cumulative step-cost cache counters
            // (memoizing backends only) so cluster rollups report them.
            if let Some(cs) = self.backend.cache_stats() {
                self.metrics.step_cache_hits = cs.hits;
                self.metrics.step_cache_misses = cs.misses;
            }
        }
        ran
    }

    /// Advance virtual time toward `t`: execute steps while the clock
    /// is behind `t` and work is schedulable. As in any discrete-event
    /// simulation, a step that *begins* before `t` may finish past it.
    /// Returns the number of steps executed (fast-forwarded virtual
    /// steps included — `metrics.steps` counts them identically);
    /// stops early once the engine has nothing left to run (its clock
    /// then stays behind `t` — see [`Engine::advance_to`]) or after
    /// `max_steps`.
    pub fn step_until(&mut self, t: f64, max_steps: usize) -> usize {
        let mut n = 0;
        while self.clock < t && n < max_steps && self.pending() > 0 {
            let ff = self.try_fast_forward(t, max_steps - n);
            if ff > 0 {
                n += ff;
                continue;
            }
            if !self.step() {
                break;
            }
            n += 1;
        }
        n
    }

    /// Step until all submitted requests finish (or `max_steps`).
    pub fn run_to_completion(&mut self, max_steps: usize) -> bool {
        let mut n = 0;
        while n < max_steps {
            if self.pending() == 0 {
                return true;
            }
            let ff = self.try_fast_forward(f64::INFINITY, max_steps - n);
            if ff > 0 {
                n += ff;
                continue;
            }
            if !self.step() && self.pending() > 0 {
                // Nothing schedulable but work remains: deadlock guard.
                return false;
            }
            n += 1;
        }
        self.pending() == 0
    }

    /// Event-driven fast-forward (DESIGN.md §13): run up to
    /// `max_steps` pure decode steps analytically, stopping strictly
    /// before `t_target`, the batcher's next admission instant, the
    /// earliest in-batch finish, and the first step whose KV growth
    /// could fail. Within such a window the batch composition is
    /// static, so each virtual step's cost is the same
    /// `(batch, avg context)` lookup the stepper would make — the same
    /// per-step `f64` values accumulated in the same order, hence a
    /// bit-identical trajectory — at O(1) per step instead of
    /// O(batch). Returns the number of steps charged (0 = no window;
    /// caller falls back to [`Engine::step`]).
    fn try_fast_forward(&mut self, t_target: f64, max_steps: usize) -> usize {
        if !self.event_mode || max_steps == 0 || self.clock >= t_target {
            return 0;
        }
        let b = self.batcher.decoding_len();
        if b == 0 {
            return 0; // nothing decoding: idle-advance/prefill path
        }
        // Admission oracle: any possible admission before `t_adm`
        // means the composition is not static — step normally.
        let t_adm =
            match self.batcher.admission_outlook(&self.seqs, &self.alloc, self.clock) {
                AdmissionOutlook::Admit => return 0,
                AdmissionOutlook::StaticUntil(t) => t,
            };
        if self.clock >= t_adm {
            return 0;
        }
        // Finish boundary (the finishing step itself runs normally so
        // archival/release/metrics happen on the stepper path), plus
        // the per-sequence state the memory boundary needs.
        let mut k_finish = usize::MAX;
        let mut total_tokens = 0usize;
        let mut comps: Vec<(usize, usize)> = Vec::with_capacity(b);
        for id in self.batcher.decoding_ids() {
            let Some(s) = self.seqs.get(&id) else {
                debug_assert!(false, "decode index out of sync with the hot map");
                return 0;
            };
            debug_assert!(s.output_len > s.generated, "finished id still decoding");
            k_finish = k_finish.min((s.output_len - s.generated).saturating_sub(1));
            total_tokens += s.context_len();
            comps.push((s.context_len(), s.blocks.len()));
        }
        let mut k = k_finish.min(max_steps);
        if k == 0 {
            return 0;
        }
        // Memory boundary: after j steps every sequence holds context
        // c_i + j, so cumulative block growth through step j is
        // sum_i max(0, blocks_for(c_i + j) - held_i) — monotone in j.
        // Free blocks only shrink inside the window (no releases
        // without a finish/preemption), so growth within today's free
        // count certifies every step's grow succeeds: no preemption.
        let free = self.alloc.free_blocks();
        let kv_cfg = self.alloc.config().clone();
        let need_new = |j: usize| -> usize {
            comps
                .iter()
                .map(|&(c, held)| kv_cfg.blocks_for_tokens(c + j).saturating_sub(held))
                .sum()
        };
        if need_new(k) > free {
            if need_new(0) > free {
                return 0; // degenerate: next step already preempts
            }
            // Largest feasible j by bisection (need_new is monotone).
            let (mut lo, mut hi) = (0usize, k);
            while lo < hi {
                let mid = lo + (hi - lo).div_ceil(2);
                if need_new(mid) <= free {
                    lo = mid;
                } else {
                    hi = mid - 1;
                }
            }
            k = lo;
        }
        if k == 0 {
            return 0;
        }
        // The virtual step loop. Per-step costs must be *replayed*,
        // not algebraically summed: f64 accumulation is
        // order-sensitive, and the context (hence the cost key)
        // advances by exactly one token per sequence per step. Each
        // iteration reproduces the stepper's clock arithmetic for the
        // active policy bit-for-bit.
        let mut steps = 0usize;
        let mut tokens = total_tokens;
        while steps < k && self.clock < t_target && self.clock < t_adm {
            let Some(res) = self.backend.decode_uniform(b, tokens) else {
                break; // backend cannot price uniform steps
            };
            match self.policy {
                SchedulerPolicy::Fused => {
                    self.clock += res.seconds;
                }
                SchedulerPolicy::Disaggregated => {
                    // StepPlan::Both with zero prefills: replicate the
                    // overlap arithmetic exactly (t_pre == 0.0).
                    let t0 = self.clock;
                    self.clock += res.seconds;
                    let t_dec = self.clock - t0;
                    self.clock = t0 + 0.0f64.max(t_dec);
                }
            }
            self.metrics.record_decode_step(res.seconds, res.watts, res.flops, b);
            tokens += b;
            steps += 1;
        }
        if steps == 0 {
            return 0;
        }
        // Bulk-apply per-sequence progress and KV growth. Block-id
        // assignment order differs from the stepper's interleaved
        // per-step order, but only free/allocated *counts* feed any
        // decision, and the memory boundary certified every grow.
        let ids: Vec<SeqId> = self.batcher.decoding_ids().collect();
        for id in &ids {
            let Some(seq) = self.seqs.get_mut(id) else {
                debug_assert!(false, "decode index out of sync with the hot map");
                continue;
            };
            seq.generated += steps;
            seq.delivered += steps;
            let needed = seq.context_len();
            let mut blocks = std::mem::take(&mut seq.blocks);
            let grew = self.alloc.grow(&mut blocks, needed);
            seq.blocks = blocks;
            debug_assert!(grew, "certified KV growth failed in fast-forward");
        }
        if let Some(cs) = self.backend.cache_stats() {
            self.metrics.step_cache_hits = cs.hits;
            self.metrics.step_cache_misses = cs.misses;
        }
        steps
    }

    fn run_prefill(&mut self, ids: &[SeqId]) {
        if ids.is_empty() {
            return;
        }
        let specs: Vec<(SeqId, usize)> = ids
            .iter()
            .filter_map(|id| self.seqs.get(id).map(|s| (*id, s.context_len())))
            .collect();
        let res = self.backend.prefill(&specs);
        self.clock += res.seconds;
        let n = ids.len();
        for id in ids {
            // First emission outcome: sample TTFT (normal request),
            // defer it (prefill leg — the decode pool samples TTFT at
            // migration delivery), or count a recompute restart.
            enum Emit {
                Sample(f64),
                Defer,
                Restart,
            }
            let emit = {
                let Some(seq) = self.seqs.get_mut(id) else {
                    debug_assert!(false, "prefilled unknown sequence {id}");
                    continue;
                };
                seq.state = RequestState::Decoding;
                seq.generated += 1; // prefill emits one token
                seq.delivered += 1;
                if seq.first_token_at.is_none() {
                    seq.first_token_at = Some(self.clock);
                    if seq.role == SeqRole::PrefillLeg {
                        Emit::Defer
                    } else {
                        Emit::Sample(seq.arrival)
                    }
                } else {
                    Emit::Restart // recompute re-prefill: token is the
                                  // rolled-back one, TTFT already sampled
                }
            };
            match emit {
                Emit::Sample(arrival) => self.metrics.record_first_token(arrival, self.clock),
                Emit::Defer => {}
                Emit::Restart => self.metrics.record_restart(),
            }
            self.batcher.mark_decoding(*id);
            self.finish_if_done(*id);
        }
        // Context tokens processed this step (recompute re-prefills
        // included — re-reading a context is real prefill work).
        let prompt_tokens: usize = specs.iter().map(|&(_, l)| l).sum();
        self.metrics.record_prefill_step(res.seconds, res.watts, res.flops, n, prompt_tokens);
    }

    fn run_decode(&mut self, ids: &[SeqId]) {
        if ids.is_empty() {
            return;
        }
        let specs: Vec<(SeqId, usize)> = ids
            .iter()
            .filter_map(|id| self.seqs.get(id).map(|s| (*id, s.context_len())))
            .collect();
        let res = self.backend.decode(&specs);
        self.clock += res.seconds;
        let mut emitted = 0;
        for id in ids {
            let Some(seq) = self.seqs.get_mut(id) else {
                debug_assert!(false, "decoded unknown sequence {id}");
                continue;
            };
            seq.generated += 1;
            let needed = seq.context_len();
            let mut blocks = std::mem::take(&mut seq.blocks);
            let ok = self.alloc.grow(&mut blocks, needed);
            seq.blocks = blocks;
            if !ok {
                // The token generated this step has no KV backing:
                // roll it back so it is re-generated (and counted
                // exactly once) by the post-preemption re-prefill.
                seq.generated -= 1;
                self.preempt(*id);
                continue;
            }
            seq.delivered += 1;
            emitted += 1;
            self.finish_if_done(*id);
        }
        self.metrics.record_decode_step(res.seconds, res.watts, res.flops, emitted);
    }

    fn finish_if_done(&mut self, id: SeqId) {
        let done = self.seqs.get(&id).is_some_and(Sequence::is_done);
        if !done {
            return;
        }
        // Finished: out of the hot map and the decode index, into the
        // harvest archive — per-step cost stays O(active).
        let Some(mut seq) = self.seqs.remove(&id) else {
            return;
        };
        seq.state = RequestState::Finished;
        seq.finished_at = Some(self.clock);
        self.active -= 1;
        self.batcher.unmark_decoding(id);
        if seq.role == SeqRole::PrefillLeg {
            // Handoff: the KV blocks stay resident until the migration
            // completes (`release_migrated`); request-level metrics
            // are recorded by the decode pool, which owns the end of
            // the request. The coordinator harvests the id from the
            // handoff queue to start the transfer.
            self.backend.release(id);
            self.handoffs.push(id);
            self.archive.insert(id, seq);
            return;
        }
        let arrival = seq.origin_arrival.unwrap_or(seq.arrival);
        let first = seq.first_token_at.unwrap_or(self.clock);
        // Delivered (not `generated`) so TPOT spans all passes of a
        // preempted request, whose `generated` was reset on requeue.
        let out = seq.delivered;
        let mut blocks = std::mem::take(&mut seq.blocks);
        self.alloc.release(&mut blocks);
        self.backend.release(id);
        self.metrics.record_finish(arrival, first, self.clock, out);
        self.archive.insert(id, seq);
    }

    /// Evict a sequence under memory pressure: drop its KV, requeue
    /// for a full re-prefill of prompt+generated (vLLM recompute-mode
    /// preemption). `first_token_at` survives — the user saw the first
    /// token at its original emission time, so TTFT is never
    /// re-sampled; the re-prefill is counted via `metrics.restarts`.
    fn preempt(&mut self, id: SeqId) {
        self.preemptions += 1;
        self.batcher.unmark_decoding(id);
        let Some(seq) = self.seqs.get_mut(&id) else {
            debug_assert!(false, "preempted unknown sequence {id}");
            return;
        };
        seq.state = RequestState::Preempted;
        let mut blocks = std::mem::take(&mut seq.blocks);
        self.alloc.release(&mut blocks);
        self.backend.release(id);
        // Re-prefill covers everything generated so far.
        seq.prompt_len = seq.context_len();
        let gen = seq.generated;
        seq.output_len -= gen.min(seq.output_len);
        seq.generated = 0;
        seq.state = RequestState::Queued;
        // A preempted decode leg lost its migrated KV with the
        // eviction: demote it to a full sequence so the re-prefill is
        // a real local recompute, not a free "resume".
        seq.role = SeqRole::Full;
        // Front of its lane: the victim predates everything still
        // waiting there, and must never sit behind a not-yet-arrived
        // head (which would let idle-advance skip past its runnable
        // re-prefill and inflate its latency artificially).
        let class = seq.class;
        self.batcher.requeue_front(id, class);
    }

    pub fn sequence(&self, id: SeqId) -> Option<&Sequence> {
        self.seqs.get(&id).or_else(|| self.archive.get(&id))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::perfmodel::{PrecisionMode, StepConfig};
    use crate::coordinator::backend::SimBackend;
    use crate::hwsim::spec::Device;
    use crate::workload::llama::by_name;

    fn engine(total_blocks: usize) -> Engine<SimBackend> {
        let kv = KvCacheConfig { block_tokens: 16, total_blocks };
        let backend = SimBackend::new(
            by_name("llama-8b").unwrap(),
            StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()),
        );
        Engine::new(EngineConfig::new(kv), backend)
    }

    fn req(id: u64, arrival: f64, p: usize, o: usize) -> Request {
        Request {
            id,
            arrival,
            prompt_len: p,
            output_len: o,
            class: crate::workload::trace::TenantClass::Interactive,
        }
    }

    #[test]
    fn single_request_completes() {
        let mut e = engine(1000);
        e.submit(&req(0, 0.0, 100, 10));
        assert!(e.run_to_completion(1000));
        let s = e.sequence(0).unwrap();
        assert_eq!(s.state, RequestState::Finished);
        assert_eq!(s.generated, 10);
        assert_eq!(e.metrics.requests_done, 1);
        assert_eq!(e.metrics.tokens_out, 10);
        // KV fully released.
        assert_eq!(e.kv_utilization(), 0.0);
    }

    #[test]
    fn batch_of_requests_completes() {
        let mut e = engine(10_000);
        for i in 0..20 {
            e.submit(&req(i, 0.0, 64, 32));
        }
        assert!(e.run_to_completion(10_000));
        assert_eq!(e.metrics.requests_done, 20);
        assert_eq!(e.metrics.tokens_out, 20 * 32);
        assert_eq!(e.preemptions(), 0);
    }

    #[test]
    fn crash_loses_resident_work_and_resubmit_reconserves() {
        let mut e = engine(10_000);
        e.submit(&req(0, 0.0, 64, 400));
        e.submit(&req(1, 0.0, 64, 400));
        // Serve partway: both sequences are mid-decode at the crash.
        e.step_until(0.5, 10_000);
        assert!(e.pending() > 0, "long decodes outlive 0.5s");
        let streamed = e.metrics.tokens_out;
        assert!(streamed > 0, "some tokens delivered before the crash");
        let t_crash = e.clock();
        let lost = e.crash(t_crash);
        assert_eq!(lost.ids, vec![0, 1], "deterministic victim order");
        // Every token streamed so far belonged to the two victims.
        assert_eq!(lost.lost_tokens, streamed);
        assert!(lost.recompute_tokens_wasted >= 2 * 64, "both prefills wasted");
        assert_eq!(e.metrics.lost_tokens, lost.lost_tokens);
        assert_eq!(e.pending(), 0);
        assert_eq!(e.kv_utilization(), 0.0, "allocator rebuilt empty");
        // Repair + retry: recompute-from-scratch semantics.
        let t_up = t_crash + 3.0;
        e.close_ledger_down(t_up);
        assert_eq!(e.metrics.down_s, 3.0);
        for id in &lost.ids {
            e.submit(&req(*id, t_up, 64, 400));
        }
        assert!(e.run_to_completion(100_000));
        assert_eq!(e.metrics.requests_done, 2);
        // Goodput excludes the crashed attempts' streamed tokens.
        assert_eq!(e.metrics.tokens_out - e.metrics.lost_tokens, 2 * 400);
        // Four-arm ledger tiles the closed timeline exactly.
        let m = &e.metrics;
        let covered = m.span + m.idle_s + m.gated_s + m.down_s;
        assert!(
            (covered - e.clock()).abs() < 1e-9,
            "ledger arms {covered} != makespan {}",
            e.clock()
        );
    }

    #[test]
    fn crash_on_empty_engine_is_benign() {
        let mut e = engine(100);
        e.submit(&req(0, 0.0, 32, 4));
        assert!(e.run_to_completion(1000));
        let t = e.clock();
        let lost = e.crash(t + 1.0);
        assert!(lost.ids.is_empty());
        assert_eq!(lost.lost_tokens, 0);
        assert_eq!(e.metrics.requests_done, 1, "finished work survives");
        // The pre-crash gap was powered idle time, not down time.
        assert!((e.clock() - (t + 1.0)).abs() < 1e-12);
        assert_eq!(e.metrics.down_s, 0.0);
    }

    #[test]
    fn ttft_before_completion() {
        let mut e = engine(1000);
        e.submit(&req(0, 0.0, 100, 50));
        assert!(e.run_to_completion(1000));
        let ttft = e.metrics.ttft.pct(50.0);
        let e2e = e.metrics.e2e_latency.pct(50.0);
        assert!(ttft > 0.0 && ttft < e2e, "ttft {ttft} e2e {e2e}");
    }

    #[test]
    fn memory_pressure_triggers_preemption_and_still_finishes() {
        // Tiny pool: 8 blocks = 128 tokens of KV for everything.
        let mut e = engine(8);
        for i in 0..3 {
            e.submit(&req(i, 0.0, 32, 40));
        }
        assert!(e.run_to_completion(100_000), "must drain despite pressure");
        assert_eq!(e.metrics.requests_done, 3);
        assert!(e.preemptions() > 0, "expected preemption under pressure");
        // Preempted tokens are rolled back and re-generated exactly
        // once: the delivered-token invariant holds despite recompute.
        assert_eq!(e.metrics.tokens_out, 3 * 40, "no token double-count");
    }

    #[test]
    fn late_arrival_ttft_measured_from_own_arrival() {
        // Regression for the clock-warp bug: `submit` used to advance
        // the clock to max(clock, arrival), collapsing open-loop
        // traces to batch-at-t0 and corrupting TTFT.
        let mut e = engine(1000);
        e.submit(&req(0, 0.0, 100, 10));
        e.submit(&req(1, 10.0, 100, 10));
        assert!(e.run_to_completion(10_000));
        let s0 = e.sequence(0).unwrap();
        let s1 = e.sequence(1).unwrap();
        // The first request was served at t~0, long before the second
        // arrived — its timeline must not have been warped to t=10.
        assert!(s0.finished_at.unwrap() < 10.0, "r0 warped to r1's arrival");
        // The second request's first token comes after its arrival...
        assert!(s1.first_token_at.unwrap() >= 10.0);
        // ...and its TTFT is a prefill latency measured from its OWN
        // arrival — not ~0 (pre-fix r1 under drain-the-queue) and not
        // ~10s (r0 under the warped clock).
        assert_eq!(e.metrics.ttft.count(), 2);
        let worst = e.metrics.ttft.pct(100.0);
        assert!(worst < 1.0, "TTFT polluted by arrival gap: {worst}");
    }

    #[test]
    fn preemption_samples_ttft_once_and_counts_restarts() {
        // Same pressure workload as above: each preemption triggers a
        // re-prefill, which must NOT contribute a second TTFT sample.
        let mut e = engine(8);
        for i in 0..3 {
            e.submit(&req(i, 0.0, 32, 40));
        }
        assert!(e.run_to_completion(100_000));
        assert!(e.preemptions() > 0);
        assert_eq!(
            e.metrics.ttft.count(),
            3,
            "one TTFT sample per request, restarts notwithstanding"
        );
        assert_eq!(
            e.metrics.restarts,
            e.preemptions(),
            "every preemption shows up as exactly one counted restart"
        );
        // TPOT spans all passes: exactly one sample per multi-token
        // request, none of them negative.
        assert_eq!(e.metrics.tpot.count(), 3);
        assert!(e.metrics.tpot.pct(0.0) > 0.0);
    }

    #[test]
    fn preempted_request_not_starved_by_future_arrivals() {
        // Regression: a preemption victim requeues at the FRONT of the
        // batcher queue. Requeued at the back it would sit behind a
        // not-yet-arrived request, and idle-advance would warp the
        // clock to that arrival while the victim's re-prefill was
        // runnable immediately — inflating its latency by the gap.
        let mut e = engine(8); // tiny pool: the t=0 burst preempts
        for i in 0..3 {
            e.submit(&req(i, 0.0, 32, 40));
        }
        e.submit(&req(3, 50.0, 32, 4));
        assert!(e.run_to_completion(100_000));
        assert!(e.preemptions() > 0, "pressure must preempt");
        for i in 0..3 {
            let s = e.sequence(i).unwrap();
            let fin = s.finished_at.unwrap();
            assert!(fin < 10.0, "victim {i} warped to the future arrival: {fin}");
        }
        let s3 = e.sequence(3).unwrap();
        assert!(s3.first_token_at.unwrap() >= 50.0);
        assert_eq!(e.metrics.tokens_out, 3 * 40 + 4);
    }

    #[test]
    fn idle_engine_advances_to_next_arrival_instead_of_deadlocking() {
        let mut e = engine(1000);
        e.submit(&req(0, 5.0, 64, 4));
        // Nothing is runnable at t=0, but the engine must not report a
        // dead queue: it jumps to the arrival and serves.
        assert!(e.step(), "idle-advance step must run the prefill");
        assert!(e.clock() >= 5.0);
        assert!(e.run_to_completion(1000));
        let s = e.sequence(0).unwrap();
        assert!(s.first_token_at.unwrap() >= 5.0);
    }

    #[test]
    fn idle_gaps_are_billed_at_idle_draw() {
        let mut e = engine(1000);
        e.submit(&req(0, 5.0, 64, 4));
        assert!(e.run_to_completion(1000));
        // The 5 s pre-arrival gap was spent powered but unloaded: the
        // ledger bills it at the device's idle draw (Gaudi2: 100 W).
        assert!(e.metrics.idle_s >= 5.0 - 1e-9, "idle {}", e.metrics.idle_s);
        assert!(e.metrics.energy_idle_j >= 5.0 * 100.0 - 1e-6);
        // Closing the ledger extends the idle tail and is idempotent.
        let t = e.clock() + 2.0;
        e.close_ledger(t);
        e.close_ledger(t); // double close: no-op
        assert!((e.clock() - t).abs() < 1e-12);
        // Busy span + idle time tile the closed timeline exactly.
        assert!(
            (e.metrics.span + e.metrics.idle_s - t).abs() < 1e-9,
            "span {} + idle {} != {}",
            e.metrics.span,
            e.metrics.idle_s,
            t
        );
        // The full ledger identity: busy phases + idle = total.
        let m = &e.metrics;
        let sum = m.energy_prefill_j + m.energy_decode_j + m.energy_idle_j;
        assert!((sum - m.energy_j).abs() <= 1e-9 * m.energy_j.max(1.0));
        assert!(m.tokens_in >= 64, "prefill records context tokens");
    }

    #[test]
    fn impossible_request_does_not_livelock() {
        // A sequence whose prompt alone exceeds the whole pool can
        // never be admitted: run_to_completion must return false, not
        // spin forever.
        let mut e = engine(2); // 32 tokens
        e.submit(&req(0, 0.0, 100, 4));
        assert!(!e.run_to_completion(1000));
    }

    #[test]
    fn batching_improves_throughput() {
        // The §5.1 batching claim, reproduced end-to-end: 32 requests
        // served together finish far sooner (virtual time) than
        // serially.
        let serial_time = {
            let mut total = 0.0;
            for i in 0..32 {
                let mut e = engine(100_000);
                e.submit(&req(i, 0.0, 128, 64));
                assert!(e.run_to_completion(10_000));
                total += e.clock();
            }
            total
        };
        let batched_time = {
            let mut e = engine(100_000);
            for i in 0..32 {
                e.submit(&req(i, 0.0, 128, 64));
            }
            assert!(e.run_to_completion(10_000));
            e.clock()
        };
        assert!(
            batched_time < serial_time / 4.0,
            "batched {batched_time} serial {serial_time}"
        );
    }

    #[test]
    fn handoff_prefill_leg_holds_kv_and_defers_metrics() {
        let mut e = engine(1000);
        e.submit_handoff(&req(0, 0.0, 100, 40));
        assert!(e.run_to_completion(1000));
        let s = e.sequence(0).unwrap();
        assert_eq!(s.state, RequestState::Finished);
        assert_eq!(s.generated, 1, "prefill leg emits exactly the first token");
        // Request-level metrics defer to the decode pool.
        assert_eq!(e.metrics.requests_done, 0);
        assert_eq!(e.metrics.ttft.count(), 0);
        assert_eq!(e.metrics.tokens_out, 1);
        // KV held for the in-flight migration...
        assert!(e.kv_utilization() > 0.0, "handoff KV released too early");
        assert_eq!(e.take_handoffs(), vec![0]);
        assert!(e.take_handoffs().is_empty(), "handoffs drain once");
        // ...and released only when the transfer completes.
        e.release_migrated(0);
        assert_eq!(e.kv_utilization(), 0.0);
    }

    #[test]
    fn bounced_prefill_leg_finishes_colocated_with_full_accounting() {
        let mut e = engine(1000);
        e.submit_handoff(&req(0, 0.0, 100, 40));
        assert!(e.run_to_completion(1000));
        assert_eq!(e.take_handoffs(), vec![0]);
        // Admission control said no: resume locally as Full.
        e.resume_bounced(0, 39);
        assert_eq!(e.metrics.bounces, 1);
        assert_eq!(e.metrics.ttft.count(), 1, "deferred TTFT sampled at bounce");
        assert!(e.run_to_completion(10_000));
        let s = e.sequence(0).unwrap();
        assert_eq!(s.role, SeqRole::Full);
        assert_eq!(s.state, RequestState::Finished);
        assert_eq!(s.delivered, 40, "prefill token + locally decoded rest");
        assert_eq!(e.metrics.requests_done, 1);
        assert_eq!(e.metrics.tokens_out, 40, "token conservation across the bounce");
        assert_eq!(e.metrics.migrations, 0, "a bounce is not a migration");
        assert_eq!(e.metrics.tpot.count(), 1);
        assert_eq!(e.kv_utilization(), 0.0);
    }

    #[test]
    fn bounce_with_nothing_left_closes_out_without_phantom_decode() {
        // A prefill leg whose whole service was the first token: a
        // bounce with remaining_out = 0 must finish the request on the
        // spot, not re-activate a done sequence (which would decode a
        // phantom extra token).
        let mut e = engine(1000);
        e.submit_handoff(&req(0, 0.0, 100, 1));
        assert!(e.run_to_completion(1000));
        assert_eq!(e.take_handoffs(), vec![0]);
        e.resume_bounced(0, 0);
        assert_eq!(e.pending(), 0, "nothing re-activated");
        assert_eq!(e.metrics.bounces, 1);
        assert_eq!(e.metrics.requests_done, 1);
        assert_eq!(e.metrics.tokens_out, 1, "exactly the prefill token");
        assert_eq!(e.metrics.ttft.count(), 1);
        assert_eq!(e.kv_utilization(), 0.0);
        assert!(e.run_to_completion(10), "engine is quiescent");
    }

    #[test]
    fn migration_admission_probe_tracks_footprint_and_free_blocks() {
        let e = engine(4); // 64 tokens of KV
        // Context + first decode token must fit: 63 + 1 = 64 fits,
        // 64 + 1 = 65 does not.
        assert!(e.can_admit_migration(63));
        assert!(!e.can_admit_migration(64));
        // A busy engine's probe reflects what is free *now*.
        let mut busy = engine(4);
        busy.submit(&req(0, 0.0, 32, 64));
        assert!(busy.step(), "prefill holds 2 blocks");
        assert!(busy.can_admit_migration(31), "2 free blocks hold 32 tokens");
        assert!(!busy.can_admit_migration(32), "33-token footprint needs 3");
    }

    #[test]
    fn migrated_leg_streams_remaining_tokens_with_full_accounting() {
        use crate::coordinator::request::MigratedRequest;
        let mut e = engine(1000);
        let m = MigratedRequest {
            id: 3,
            arrival: 1.0,
            at: 4.0,
            kv_ready_s: 4.0,
            context_len: 101,
            remaining_out: 9,
            bytes: 101.0 * 131072.0,
        };
        e.submit_migrated(&m);
        // TTFT sampled at delivery, measured from the ORIGINAL arrival
        // (it includes prefill queueing, compute, and the transfer).
        assert_eq!(e.metrics.ttft.count(), 1);
        assert!((e.metrics.ttft.pct(50.0) - 3.0).abs() < 1e-12);
        assert_eq!(e.metrics.migrations, 1);
        assert!(e.run_to_completion(1000));
        let s = e.sequence(3).unwrap();
        assert_eq!(s.generated, 9, "only the remaining tokens run here");
        assert_eq!(s.delivered, 10, "prefill token + decode tokens");
        assert_eq!(e.metrics.requests_done, 1);
        assert_eq!(e.metrics.tokens_out, 9, "migrated token not re-counted");
        assert!(s.first_token_at.unwrap() >= 4.0);
        assert!(s.finished_at.unwrap() > 4.0);
        // e2e measured from the origin arrival, so it spans both legs.
        assert!(e.metrics.e2e_latency.pct(50.0) >= 3.0);
        assert_eq!(e.metrics.tpot.count(), 1);
        assert_eq!(e.kv_utilization(), 0.0);
    }

    #[test]
    fn preempted_migrated_leg_recomputes_locally_and_conserves_tokens() {
        use crate::coordinator::request::MigratedRequest;
        let mut e = engine(8); // 128 tokens of KV: force churn
        let m = MigratedRequest {
            id: 0,
            arrival: 0.0,
            at: 0.0,
            kv_ready_s: 0.0,
            context_len: 33,
            remaining_out: 40,
            bytes: 33.0 * 131072.0,
        };
        e.submit_migrated(&m);
        e.submit(&req(1, 0.0, 32, 40));
        assert!(e.run_to_completion(100_000));
        assert!(e.preemptions() > 0, "pressure must preempt");
        assert_eq!(e.metrics.requests_done, 2);
        // Migrated leg: 40 locally generated; full request: 40. The
        // migrated first token is never re-counted despite recompute.
        assert_eq!(e.metrics.tokens_out, 80, "token conservation across roles");
        assert_eq!(e.metrics.ttft.count(), 2, "TTFT sampled once per request");
        assert_eq!(e.metrics.restarts, e.preemptions());
        assert_eq!(e.sequence(0).unwrap().delivered, 41);
        assert_eq!(e.kv_utilization(), 0.0);
    }

    /// The simulation outcome with floats as bits: equality means the
    /// two runs were bit-identical.
    fn fingerprint(e: &Engine<SimBackend>) -> Vec<u64> {
        let m = &e.metrics;
        vec![
            e.clock().to_bits(),
            m.steps,
            m.tokens_out,
            m.requests_done,
            m.restarts,
            m.energy_j.to_bits(),
            m.energy_prefill_j.to_bits(),
            m.energy_decode_j.to_bits(),
            m.energy_idle_j.to_bits(),
            m.flops.to_bits(),
            m.span.to_bits(),
            m.idle_s.to_bits(),
            m.ttft.pct(95.0).to_bits(),
            m.tpot.pct(95.0).to_bits(),
            m.e2e_latency.pct(95.0).to_bits(),
            m.step_cache_hits,
            m.step_cache_misses,
        ]
    }

    #[test]
    fn fast_forward_is_bit_identical_to_stepper() {
        // Open-loop arrivals + long decodes: real fast-forward windows
        // interleaved with admissions and finishes.
        let run = |event: bool| {
            let mut e = engine(100_000);
            e.set_event_mode(event);
            for i in 0..24u64 {
                e.submit(&req(i, i as f64 * 0.4, 64 + (i as usize % 5) * 40, 120));
            }
            assert!(e.run_to_completion(200_000));
            fingerprint(&e)
        };
        assert_eq!(run(true), run(false), "event engine diverged from stepper");
    }

    #[test]
    fn fast_forward_bit_identical_under_memory_pressure() {
        // Tiny pool: preemptions and recompute restarts bound every
        // window; the trajectories must still match exactly.
        let run = |event: bool| {
            let mut e = engine(12);
            e.set_event_mode(event);
            for i in 0..4u64 {
                e.submit(&req(i, i as f64 * 0.1, 32, 40));
            }
            assert!(e.run_to_completion(200_000));
            (e.preemptions(), fingerprint(&e))
        };
        let (p_event, f_event) = run(true);
        let (p_ref, f_ref) = run(false);
        assert!(p_ref > 0, "pressure must preempt");
        assert_eq!(p_event, p_ref);
        assert_eq!(f_event, f_ref, "event engine diverged under preemption");
    }

    #[test]
    fn fast_forward_actually_collapses_steps() {
        // Sanity that the event path engages: a lone long decode is
        // one giant static window, so the step loop must not be the
        // only thing running (same metrics.steps, fewer step() calls
        // is unobservable — instead pin that step_until covers the
        // whole run in one call with a huge budget and stays exact).
        let mut e = engine(100_000);
        e.submit(&req(0, 0.0, 64, 2_000));
        let n = e.step_until(f64::INFINITY, usize::MAX);
        assert_eq!(e.metrics.steps, n as u64);
        assert_eq!(e.metrics.tokens_out, 2_000);
        assert_eq!(e.metrics.requests_done, 1);
        let s = e.sequence(0).unwrap();
        assert_eq!(s.generated, 2_000);
        assert!(s.finished_at.is_some());
    }

    #[test]
    fn disaggregated_policy_overlaps_phases() {
        let kv = KvCacheConfig { block_tokens: 16, total_blocks: 100_000 };
        let mk = |policy| {
            let backend = SimBackend::new(
                by_name("llama-8b").unwrap(),
                StepConfig::new(Device::H100, PrecisionMode::fp8_dynamic()),
            );
            let mut cfg = EngineConfig::new(kv.clone());
            cfg.policy = policy;
            Engine::new(cfg, backend)
        };
        // Steady stream so prefills and decodes coexist.
        let mut fused = mk(SchedulerPolicy::Fused);
        let mut disagg = mk(SchedulerPolicy::Disaggregated);
        for e in [&mut fused, &mut disagg] {
            for i in 0..64 {
                e.submit(&req(i, 0.0, 256, 64));
            }
            assert!(e.run_to_completion(100_000));
        }
        // Overlapping phases cannot be slower in virtual time.
        assert!(disagg.clock() <= fused.clock() * 1.05,
                "disagg {} fused {}", disagg.clock(), fused.clock());
    }
}
