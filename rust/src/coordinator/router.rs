//! Request router: front door over a pool of engines.
//!
//! The §2.2 observation (different chips/configs for prefill vs decode
//! — Splitwise [32]) becomes concrete here: a pool can mix engines
//! with different simulated devices/precisions, and the router's
//! policy decides placement. Policies:
//!
//! * `RoundRobin` — baseline.
//! * `LeastLoaded` — fewest in-flight sequences.
//! * `PhaseAffinity` — prefill-heavy requests (long prompt, short
//!   output) to prefill-rated engines, decode-heavy to decode-rated
//!   ones, using the per-engine throughput ratings the TCO analysis
//!   produces.

use super::backend::ExecutionBackend;
use super::engine::Engine;
use super::request::MigratedRequest;
use crate::workload::trace::Request;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastLoaded,
    PhaseAffinity,
}

/// Per-engine rating used by `PhaseAffinity` (derived from hwsim or
/// measured; higher = better at that phase).
#[derive(Debug, Clone, Copy)]
pub struct EngineRating {
    pub prefill_score: f64,
    pub decode_score: f64,
}

pub struct Router<B: ExecutionBackend> {
    pub engines: Vec<Engine<B>>,
    ratings: Vec<EngineRating>,
    policy: RoutePolicy,
    rr_next: usize,
    routed: Vec<u64>,
    /// Per-engine next-event hint: engine `i` executes no step before
    /// `hints[i]`, so [`Router::step_to`] skips it for targets at or
    /// below the hint instead of re-entering its step loop on every
    /// cluster event (DESIGN.md §9). `-inf` = unknown (must check);
    /// `+inf` = idle with an empty queue (nothing to do until new work
    /// arrives). Every path that injects work — the submit methods,
    /// [`Router::release_migrated_on`], [`Router::note_mutation`] —
    /// resets the hint, so a stale hint is always conservative. Fault
    /// events reset it too ([`Router::crash_engine`],
    /// [`Router::repair_engine`], [`Router::set_derate`]) — they
    /// mutate engine state outside the submit paths.
    hints: Vec<f64>,
    /// Crashed/under-repair flags (fault injection): a down engine
    /// receives no routed work and closes its ledger on the 0 W
    /// `down_s` arm. All-false in fault-free runs, leaving every
    /// selection path bit-identical to the pre-fault-layer router.
    down: Vec<bool>,
}

impl<B: ExecutionBackend> Router<B> {
    pub fn new(engines: Vec<Engine<B>>, ratings: Vec<EngineRating>,
               policy: RoutePolicy) -> Self {
        assert_eq!(engines.len(), ratings.len());
        assert!(!engines.is_empty());
        let n = engines.len();
        Router {
            engines,
            ratings,
            policy,
            rr_next: 0,
            routed: vec![0; n],
            hints: vec![f64::NEG_INFINITY; n],
            down: vec![false; n],
        }
    }

    /// Pick a target engine for a request (does not submit). Down
    /// engines are never selected; callers gate on [`Router::any_up`]
    /// before routing (the degenerate all-down fallback returns an
    /// arbitrary index).
    pub fn select(&mut self, r: &Request) -> usize {
        match self.policy {
            RoutePolicy::RoundRobin => {
                let n = self.engines.len();
                for k in 0..n {
                    let i = (self.rr_next + k) % n;
                    if !self.down[i] {
                        self.rr_next = (i + 1) % n;
                        return i;
                    }
                }
                self.rr_next
            }
            RoutePolicy::LeastLoaded => self
                .engines
                .iter()
                .enumerate()
                .filter(|&(i, _)| !self.down[i])
                .min_by_key(|(_, e)| e.pending())
                .map_or(0, |(i, _)| i),
            RoutePolicy::PhaseAffinity => {
                // Decode-heaviness of the request in [0, 1].
                let total = (r.prompt_len + r.output_len) as f64;
                let decode_w = r.output_len as f64 / total.max(1.0);
                self.ratings
                    .iter()
                    .enumerate()
                    .filter(|&(i, _)| !self.down[i])
                    .map(|(i, rt)| {
                        let fit = decode_w * rt.decode_score
                            + (1.0 - decode_w) * rt.prefill_score;
                        // Load-balance tiebreaker.
                        let load = self.engines[i].pending() as f64;
                        (i, fit / (1.0 + 0.1 * load))
                    })
                    .max_by(|a, b| a.1.total_cmp(&b.1))
                    .map_or(0, |(i, _)| i)
            }
        }
    }

    /// Route and submit.
    pub fn submit(&mut self, r: &Request) -> usize {
        let i = self.select(r);
        self.engines[i].submit(r);
        self.routed[i] += 1;
        self.hints[i] = f64::NEG_INFINITY;
        i
    }

    /// Time-ordered dispatch (the cluster loop's entry point): route
    /// the request and lift the target engine's clock to the arrival
    /// instant if it is idle, so service starts at the arrival rather
    /// than at a stale earlier clock. Callers must present requests in
    /// arrival order, with every engine already stepped up to
    /// `r.arrival` (see `cluster::Cluster::run`).
    pub fn submit_at(&mut self, r: &Request) -> usize {
        let i = self.select(r);
        self.engines[i].advance_to(r.arrival);
        self.engines[i].submit(r);
        self.routed[i] += 1;
        self.hints[i] = f64::NEG_INFINITY;
        i
    }

    /// Disaggregated front door of a *prefill pool*: route the prefill
    /// leg of `r` (prompt KV + first token, held for migration) with
    /// the same time-ordered semantics as [`Router::submit_at`].
    pub fn submit_handoff_at(&mut self, r: &Request) -> usize {
        let i = self.select(r);
        self.engines[i].advance_to(r.arrival);
        self.engines[i].submit_handoff(r);
        self.routed[i] += 1;
        self.hints[i] = f64::NEG_INFINITY;
        i
    }

    /// Disaggregated front door of a *decode pool*: route a migrated
    /// sequence whose KV lands at `m.at`. An idle target's clock is
    /// lifted to the delivery instant; a busy one queues the resume.
    /// Callers must present migrations in delivery order, with every
    /// engine already stepped up to `m.at` (see `cluster::DisaggCluster`).
    pub fn submit_migrated_at(&mut self, m: &MigratedRequest) -> usize {
        let probe = Request {
            id: m.id,
            arrival: m.at,
            prompt_len: m.context_len,
            output_len: m.remaining_out,
            class: crate::workload::trace::TenantClass::Interactive,
        };
        let i = self.select(&probe);
        self.engines[i].advance_to(m.at);
        self.engines[i].submit_migrated(m);
        self.routed[i] += 1;
        self.hints[i] = f64::NEG_INFINITY;
        i
    }

    /// Admission-aware variant of [`Router::submit_migrated_at`]: route
    /// to the least-loaded decode engine that can hold the migrated
    /// footprint *right now* ([`Engine::can_admit_migration`]), so an
    /// accepted migration lands where its KV fits instead of queueing
    /// behind a full sibling while another engine has room. Falls back
    /// to the plain policy when no engine can admit (blocks may free
    /// by the time the batcher looks). Used by `DisaggCluster` when
    /// admission control is on; the plain path stays byte-identical
    /// for single-shot (admission-off) runs.
    pub fn submit_migrated_at_admitting(&mut self, m: &MigratedRequest) -> usize {
        let fit = self
            .engines
            .iter()
            .enumerate()
            .filter(|&(i, e)| !self.down[i] && e.can_admit_migration(m.context_len))
            .min_by_key(|(_, e)| e.pending())
            .map(|(i, _)| i);
        match fit {
            Some(i) => {
                self.engines[i].advance_to(m.at);
                self.engines[i].submit_migrated(m);
                self.routed[i] += 1;
                self.hints[i] = f64::NEG_INFINITY;
                i
            }
            None => self.submit_migrated_at(m),
        }
    }

    /// Release engine `i`'s in-flight KV for a completed migration and
    /// invalidate its next-event hint — freed blocks can unblock a
    /// stalled prefill queue, so the engine must be re-checked.
    pub fn release_migrated_on(&mut self, i: usize, id: super::request::SeqId) {
        self.engines[i].release_migrated(id);
        self.hints[i] = f64::NEG_INFINITY;
    }

    /// Invalidate engine `i`'s next-event hint after work was injected
    /// outside the router's submit paths (e.g. an admission bounce
    /// resumed decoding directly on the engine).
    pub fn note_mutation(&mut self, i: usize) {
        self.hints[i] = f64::NEG_INFINITY;
    }

    /// Crash engine `i` at `t_s` ([`Engine::crash`]): mark it down and
    /// invalidate its hint — fault events mutate engine state outside
    /// the submit paths, so a hint computed pre-crash is stale (the
    /// regression `crash_invalidates_stale_hint_so_work_is_not_skipped`
    /// pins this). Returns the lost work for the retry queue.
    ///
    /// Crashing an already-down engine is a no-op (empty loss): a
    /// Poisson plan's crash/repair windows may overlap on the same
    /// replica, and re-crashing mid-outage would bill the down gap as
    /// idle through [`Engine::crash`]'s ledger close.
    pub fn crash_engine(&mut self, i: usize, t_s: f64) -> super::engine::LostWork {
        if self.down[i] {
            return super::engine::LostWork::default();
        }
        let lost = self.engines[i].crash(t_s);
        self.down[i] = true;
        self.hints[i] = f64::NEG_INFINITY;
        lost
    }

    /// Repair engine `i` at `t_s`: the crash→repair window is billed
    /// on the 0 W `down_s` ledger arm, the engine rejoins routing
    /// empty, and its hint is invalidated. Ignored if `i` is not down
    /// (a plan may schedule a repair for a replica that never
    /// crashed).
    pub fn repair_engine(&mut self, i: usize, t_s: f64) {
        if !self.down[i] {
            return;
        }
        self.engines[i].close_ledger_down(t_s);
        self.down[i] = false;
        self.hints[i] = f64::NEG_INFINITY;
    }

    /// Degrade (or restore, `factor == 1.0`) engine `i`'s HBM
    /// bandwidth. The hint is invalidated: step costs changed, so any
    /// cached notion of the engine's next event is stale.
    pub fn set_derate(&mut self, i: usize, factor: f64) {
        self.engines[i].set_bw_derate(factor);
        self.hints[i] = f64::NEG_INFINITY;
    }

    pub fn is_down(&self, i: usize) -> bool {
        self.down[i]
    }

    /// At least one engine can take work.
    pub fn any_up(&self) -> bool {
        self.down.iter().any(|d| !d)
    }

    /// Every engine is crashed (migrations must bounce; arrivals wait
    /// in the retry queue).
    pub fn all_down(&self) -> bool {
        self.down.iter().all(|d| *d)
    }

    /// Re-submit a crash victim from scratch (`r.arrival` is the retry
    /// instant — recompute semantics: the fleet sees a fresh arrival).
    /// Routes like [`Router::submit_at`] (down engines excluded) and
    /// counts the retry on the engine that received it.
    pub fn submit_retry_at(&mut self, r: &Request) -> usize {
        let i = self.submit_at(r);
        self.engines[i].metrics.record_retry();
        i
    }

    /// Advance every engine toward `t` on the shared timeline,
    /// charging executed steps against `left` (the run's step budget).
    /// False when the budget is exhausted. Engines whose next-event
    /// hint is at or past `t` are skipped — idle engines cost one
    /// float compare per event instead of a step-loop re-entry, so
    /// cluster event processing is O(engines with runnable work).
    pub fn step_to(&mut self, t: f64, left: &mut usize) -> bool {
        if *left == 0 {
            return false;
        }
        for i in 0..self.engines.len() {
            if self.hints[i] >= t {
                continue;
            }
            let e = &mut self.engines[i];
            let taken = e.step_until(t, *left);
            *left = (*left).saturating_sub(taken);
            self.hints[i] = if self.engines[i].pending() == 0 {
                // Empty queue: nothing can run until new work arrives
                // (every arrival path resets the hint).
                f64::INFINITY
            } else {
                // Busy (next step begins at its clock) or stalled on
                // KV back-pressure (re-check past `t`; the release
                // path resets the hint explicitly).
                self.engines[i].clock().max(t)
            };
            if *left == 0 {
                return false;
            }
        }
        true
    }

    pub fn routed_counts(&self) -> &[u64] {
        &self.routed
    }

    /// Drain a *closed batch*: drive every engine independently until
    /// its queue empties. Correct only when all requests are already
    /// submitted (arrival times in the past) — for open-loop traffic,
    /// where arrivals and step completions interleave on one shared
    /// timeline, use [`Cluster::run`](super::cluster::Cluster::run)
    /// instead (DESIGN.md §5.2).
    pub fn drain_closed_batch(&mut self, max_steps: usize) -> bool {
        self.engines
            .iter_mut()
            .all(|e| e.run_to_completion(max_steps))
    }

    /// Slowest engine's virtual completion time (makespan).
    pub fn makespan(&self) -> f64 {
        self.engines.iter().map(|e| e.clock()).fold(0.0, f64::max)
    }

    /// Close every engine's energy ledger at `t` (typically the
    /// cluster makespan): engines that finished early accrue idle draw
    /// over their tail gap, so summed busy + idle energy equals the
    /// integral of draw over the whole timeline
    /// ([`Engine::close_ledger`]). Idempotent; hints are untouched (a
    /// closed engine has no queued work, so its hint stays valid).
    /// Engines still down at `t` close on the 0 W `down_s` arm
    /// instead — an unrepaired replica draws nothing over its tail.
    pub fn close_ledgers(&mut self, t: f64) {
        for i in 0..self.engines.len() {
            if self.down[i] {
                self.engines[i].close_ledger_down(t);
            } else {
                self.engines[i].close_ledger(t);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::perfmodel::{PrecisionMode, StepConfig};
    use crate::coordinator::backend::SimBackend;
    use crate::coordinator::engine::EngineConfig;
    use crate::coordinator::kv_cache::KvCacheConfig;
    use crate::hwsim::spec::Device;
    use crate::workload::llama::by_name;

    fn engine(dev: Device) -> Engine<SimBackend> {
        let kv = KvCacheConfig { block_tokens: 16, total_blocks: 200_000 };
        let backend = SimBackend::new(
            by_name("llama-8b").unwrap(),
            StepConfig::new(dev, PrecisionMode::fp8_static()),
        );
        Engine::new(EngineConfig::new(kv), backend)
    }

    fn req(id: u64, p: usize, o: usize) -> Request {
        Request {
            id,
            arrival: 0.0,
            prompt_len: p,
            output_len: o,
            class: crate::workload::trace::TenantClass::Interactive,
        }
    }

    fn ratings_h100_gaudi() -> Vec<EngineRating> {
        // From the paper's result: H100 better at prefill, Gaudi2+FP8
        // at decode.
        vec![
            EngineRating { prefill_score: 2.0, decode_score: 1.0 }, // H100
            EngineRating { prefill_score: 1.0, decode_score: 1.4 }, // Gaudi2
        ]
    }

    #[test]
    fn round_robin_alternates() {
        let mut r = Router::new(
            vec![engine(Device::H100), engine(Device::Gaudi2)],
            ratings_h100_gaudi(),
            RoutePolicy::RoundRobin,
        );
        for i in 0..6 {
            r.submit(&req(i, 64, 16));
        }
        assert_eq!(r.routed_counts(), &[3, 3]);
    }

    #[test]
    fn least_loaded_balances() {
        let mut r = Router::new(
            vec![engine(Device::H100), engine(Device::Gaudi2)],
            ratings_h100_gaudi(),
            RoutePolicy::LeastLoaded,
        );
        for i in 0..10 {
            r.submit(&req(i, 64, 16));
        }
        let c = r.routed_counts();
        assert_eq!(c[0] + c[1], 10);
        assert!((c[0] as i64 - c[1] as i64).abs() <= 1, "{c:?}");
    }

    #[test]
    fn phase_affinity_separates_workloads() {
        let mut r = Router::new(
            vec![engine(Device::H100), engine(Device::Gaudi2)],
            ratings_h100_gaudi(),
            RoutePolicy::PhaseAffinity,
        );
        // Prefill-heavy: long prompt, one token out -> engine 0 (H100).
        let i = r.select(&req(0, 4000, 4));
        assert_eq!(i, 0);
        // Decode-heavy: short prompt, long reasoning output -> Gaudi2.
        let j = r.select(&req(1, 32, 4000));
        assert_eq!(j, 1);
    }

    #[test]
    fn pool_drains_and_counts_match() {
        let mut r = Router::new(
            vec![engine(Device::H100), engine(Device::Gaudi2)],
            ratings_h100_gaudi(),
            RoutePolicy::PhaseAffinity,
        );
        for i in 0..40 {
            let (p, o) = if i % 2 == 0 { (2000, 8) } else { (32, 512) };
            r.submit(&req(i, p, o));
        }
        assert!(r.drain_closed_batch(1_000_000));
        let done: u64 = r.engines.iter().map(|e| e.metrics.requests_done).sum();
        assert_eq!(done, 40);
        assert!(r.makespan() > 0.0);
    }

    #[test]
    fn disagg_submit_paths_route_and_count() {
        let mut r = Router::new(
            vec![engine(Device::H100), engine(Device::Gaudi2)],
            ratings_h100_gaudi(),
            RoutePolicy::LeastLoaded,
        );
        r.submit_handoff_at(&req(0, 2000, 64));
        let m = MigratedRequest {
            id: 1,
            arrival: 0.0,
            at: 0.5,
            kv_ready_s: 0.5,
            context_len: 2001,
            remaining_out: 63,
            bytes: 2001.0 * 131072.0,
        };
        r.submit_migrated_at(&m);
        assert_eq!(r.routed_counts().iter().sum::<u64>(), 2);
        assert!(r.drain_closed_batch(1_000_000));
        let done: u64 = r.engines.iter().map(|e| e.metrics.requests_done).sum();
        assert_eq!(done, 1, "prefill leg defers; migrated leg finishes");
        let handed: usize = r.engines.iter_mut().map(|e| e.take_handoffs().len()).sum();
        assert_eq!(handed, 1);
    }

    #[test]
    fn admitting_route_skips_kv_full_engine_despite_lower_load() {
        // Engine 0: roomy KV, two queued requests. Engine 1: idle but
        // only 32 KV tokens. Plain least-loaded would deliver to the
        // idle engine; the admission-aware route must place the
        // migration where its footprint actually fits.
        let kv_tiny = KvCacheConfig { block_tokens: 16, total_blocks: 2 };
        let tiny = Engine::new(
            EngineConfig::new(kv_tiny),
            SimBackend::new(
                by_name("llama-8b").unwrap(),
                StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()),
            ),
        );
        let mut r = Router::new(
            vec![engine(Device::H100), tiny],
            ratings_h100_gaudi(),
            RoutePolicy::LeastLoaded,
        );
        r.engines[0].submit(&req(0, 64, 16));
        r.engines[0].submit(&req(1, 64, 16));
        let m = MigratedRequest {
            id: 9,
            arrival: 0.0,
            at: 0.1,
            kv_ready_s: 0.1,
            context_len: 100,
            remaining_out: 4,
            bytes: 100.0 * 131072.0,
        };
        assert_eq!(r.select(&req(2, 100, 4)), 1, "plain policy prefers the idle engine");
        let i = r.submit_migrated_at_admitting(&m);
        assert_eq!(i, 0, "KV-full engine skipped despite lower load");
        assert!(r.drain_closed_batch(1_000_000));
    }

    #[test]
    fn crash_invalidates_stale_hint_so_work_is_not_skipped() {
        // Regression (fault layer): `step_to` hint-gates idle engines.
        // A crash mutates engine state outside the submit paths, so
        // the hint computed while the engine was busy (>= the step_to
        // target) MUST be invalidated — otherwise a post-repair direct
        // submit would be skipped by every later `step_to` below the
        // stale hint and the request would never drain.
        // The blocking stale hint is `+inf`: a drained engine's hint
        // parks at infinity until a submit path resets it — and the
        // fault path must count as such a reset.
        let mut r = Router::new(
            vec![engine(Device::Gaudi2)],
            vec![EngineRating { prefill_score: 1.0, decode_score: 1.0 }],
            RoutePolicy::LeastLoaded,
        );
        r.submit_at(&req(0, 64, 8));
        let mut left = usize::MAX;
        r.step_to(10.0, &mut left);
        assert_eq!(r.engines[0].metrics.requests_done, 1);
        assert_eq!(r.engines[0].pending(), 0, "drained: hint is parked at +inf");
        let lost = r.crash_engine(0, 10.0);
        assert!(lost.ids.is_empty(), "nothing resident at the crash");
        assert!(r.is_down(0) && !r.any_up());
        r.repair_engine(0, 11.0);
        assert!(r.any_up());
        // Inject the retry directly on the engine (outside the
        // router's submit paths, like a cluster-level resume would).
        let retry = Request {
            id: 1,
            arrival: 11.0,
            prompt_len: 64,
            output_len: 4,
            class: crate::workload::trace::TenantClass::Interactive,
        };
        r.engines[0].advance_to(retry.arrival);
        r.engines[0].submit(&retry);
        // Pre-fix (crash/repair not invalidating), hints[0] == +inf
        // would skip every step_to target forever.
        r.step_to(12.0, &mut left);
        assert_eq!(
            r.engines[0].metrics.requests_done, 2,
            "stale +inf hint skipped the repaired engine's work"
        );
        // Ledger: the crash→repair second sits on the down arm.
        assert_eq!(r.engines[0].metrics.down_s, 1.0);
    }

    #[test]
    fn down_engines_receive_no_routed_work() {
        let mut r = Router::new(
            vec![engine(Device::H100), engine(Device::Gaudi2)],
            ratings_h100_gaudi(),
            RoutePolicy::RoundRobin,
        );
        let _ = r.crash_engine(0, 0.0);
        for i in 0..4 {
            r.submit_at(&req(i, 64, 8));
        }
        assert_eq!(r.routed_counts(), &[0, 4], "round-robin skips the crashed engine");
        r.repair_engine(0, 1.0);
        let mut lr = Router::new(
            vec![engine(Device::H100), engine(Device::Gaudi2)],
            ratings_h100_gaudi(),
            RoutePolicy::LeastLoaded,
        );
        let _ = lr.crash_engine(1, 0.0);
        lr.submit_retry_at(&req(9, 64, 8));
        assert_eq!(lr.routed_counts(), &[1, 0], "least-loaded skips the crashed engine");
        assert_eq!(lr.engines[0].metrics.retries, 1, "retry counted on the server");
    }

    #[test]
    fn phase_affinity_beats_anti_affinity_on_mixed_traffic() {
        // The §2.2 claim quantified: placing each phase on the device
        // that is better at it lowers makespan vs the inverted
        // placement. (Round-robin sits between the two, depending on
        // the workload mix.)
        let run = |ratings: Vec<EngineRating>| {
            let mut r = Router::new(
                vec![engine(Device::H100), engine(Device::Gaudi2)],
                ratings,
                RoutePolicy::PhaseAffinity,
            );
            for i in 0..60 {
                let (p, o) = if i % 2 == 0 { (3000, 4) } else { (32, 768) };
                r.submit(&req(i, p, o));
            }
            assert!(r.drain_closed_batch(2_000_000));
            r.makespan()
        };
        let good = run(ratings_h100_gaudi());
        // Anti-affinity: swap the scores so prefill lands on Gaudi
        // and decode on the H100.
        let anti = run(vec![
            EngineRating { prefill_score: 1.0, decode_score: 1.4 },
            EngineRating { prefill_score: 2.0, decode_score: 1.0 },
        ]);
        assert!(good < anti, "affinity {good} vs anti {anti}");
    }
}
