//! Paged KV-cache block allocator (PagedAttention-style [21]).
//!
//! The §5.1 constraint this enforces: "the batch size is limited by
//! the memory capacity as each sequence in a batch requires its own KV
//! cache". Blocks are fixed-size token runs; capacity derives from
//! device HBM minus weights.

use crate::analysis::parallel::{check_capacity, CapacityError, ParallelismPlan};
use crate::hwsim::spec::Device;
use crate::workload::llama::LlamaConfig;

/// Default paged-KV block granularity (vLLM's 16-token blocks).
pub const DEFAULT_BLOCK_TOKENS: usize = 16;

#[derive(Debug, Clone)]
pub struct KvCacheConfig {
    /// Tokens per block (vLLM default 16).
    pub block_tokens: usize,
    /// Total blocks available.
    pub total_blocks: usize,
}

impl KvCacheConfig {
    /// Size the pool from device memory: (hbm - weights) / block bytes.
    pub fn from_device(
        model: &LlamaConfig,
        hbm_bytes: f64,
        weight_bytes_per_elem: f64,
        kv_bytes_per_elem: f64,
        block_tokens: usize,
        reserve_frac: f64,
    ) -> Self {
        let weights = model.weight_bytes(weight_bytes_per_elem);
        let usable = (hbm_bytes * (1.0 - reserve_frac) - weights).max(0.0);
        let block_bytes = model.kv_bytes_per_token(kv_bytes_per_elem) * block_tokens as f64;
        KvCacheConfig {
            block_tokens,
            total_blocks: (usable / block_bytes).floor() as usize,
        }
    }

    /// Size the pool for one *sharded* model instance directly from
    /// the device spec, going through the HBM capacity check: weights
    /// per shard plus the KV budget must fit `device.spec().hbm_cap`,
    /// or a typed [`CapacityError`] comes back instead of a pool for
    /// an impossible deployment. The block budget derives from the
    /// spec (no hard-coded totals): instance KV tokens / block size.
    pub fn for_instance(
        model: &'static LlamaConfig,
        device: Device,
        plan: ParallelismPlan,
        weight_bytes_per_elem: f64,
        kv_bytes_per_elem: f64,
        min_kv_tokens: usize,
    ) -> Result<Self, CapacityError> {
        let fit = check_capacity(
            model,
            device,
            plan,
            weight_bytes_per_elem,
            kv_bytes_per_elem,
            min_kv_tokens,
        )?;
        Ok(KvCacheConfig {
            block_tokens: DEFAULT_BLOCK_TOKENS,
            total_blocks: fit.max_kv_tokens / DEFAULT_BLOCK_TOKENS,
        })
    }

    pub fn blocks_for_tokens(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Total KV tokens the pool can hold. `Engine::submit_migrated`
    /// debug-asserts migrated contexts against this: a context larger
    /// than the whole pool could never be admitted and would only
    /// surface later as a generic drain failure.
    pub fn tokens_capacity(&self) -> usize {
        self.block_tokens * self.total_blocks
    }
}

/// Free-list block allocator.
#[derive(Debug)]
pub struct BlockAllocator {
    cfg: KvCacheConfig,
    free: Vec<usize>,
    allocated: usize,
}

impl BlockAllocator {
    pub fn new(cfg: KvCacheConfig) -> Self {
        let free = (0..cfg.total_blocks).rev().collect();
        BlockAllocator { cfg, free, allocated: 0 }
    }

    pub fn config(&self) -> &KvCacheConfig {
        &self.cfg
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn allocated_blocks(&self) -> usize {
        self.allocated
    }

    pub fn can_allocate(&self, blocks: usize) -> bool {
        self.free.len() >= blocks
    }

    /// Allocate `blocks` blocks, or None (never partial).
    pub fn allocate(&mut self, blocks: usize) -> Option<Vec<usize>> {
        if !self.can_allocate(blocks) {
            return None;
        }
        self.allocated += blocks;
        Some((0..blocks).map(|_| self.free.pop().unwrap()).collect())
    }

    /// Grow an existing allocation to cover `tokens` total tokens.
    pub fn grow(&mut self, held: &mut Vec<usize>, tokens: usize) -> bool {
        let need = self.cfg.blocks_for_tokens(tokens);
        if need <= held.len() {
            return true;
        }
        match self.allocate(need - held.len()) {
            Some(mut more) => {
                held.append(&mut more);
                true
            }
            None => false,
        }
    }

    pub fn release(&mut self, blocks: &mut Vec<usize>) {
        self.allocated -= blocks.len();
        self.free.append(blocks);
    }

    /// Utilization in [0, 1].
    pub fn utilization(&self) -> f64 {
        if self.cfg.total_blocks == 0 {
            return 1.0;
        }
        self.allocated as f64 / self.cfg.total_blocks as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::llama::by_name;

    fn cfg(total: usize) -> KvCacheConfig {
        KvCacheConfig { block_tokens: 16, total_blocks: total }
    }

    #[test]
    fn alloc_release_roundtrip() {
        let mut a = BlockAllocator::new(cfg(10));
        let mut b1 = a.allocate(4).unwrap();
        assert_eq!(a.free_blocks(), 6);
        assert_eq!(a.allocated_blocks(), 4);
        a.release(&mut b1);
        assert_eq!(a.free_blocks(), 10);
        assert_eq!(a.allocated_blocks(), 0);
    }

    #[test]
    fn never_partial() {
        let mut a = BlockAllocator::new(cfg(3));
        assert!(a.allocate(4).is_none());
        assert_eq!(a.free_blocks(), 3);
        assert!(a.allocate(3).is_some());
        assert!(a.allocate(1).is_none());
    }

    #[test]
    fn block_ids_unique() {
        let mut a = BlockAllocator::new(cfg(100));
        let mut seen = std::collections::HashSet::new();
        for _ in 0..10 {
            for id in a.allocate(10).unwrap() {
                assert!(seen.insert(id), "dup block {id}");
            }
        }
    }

    #[test]
    fn grow_allocates_marginal_blocks() {
        let mut a = BlockAllocator::new(cfg(10));
        let mut held = a.allocate(2).unwrap(); // covers 32 tokens
        assert!(a.grow(&mut held, 33)); // needs 3 blocks
        assert_eq!(held.len(), 3);
        assert!(a.grow(&mut held, 40)); // still 3
        assert_eq!(held.len(), 3);
        assert!(!a.grow(&mut held, 16 * 11)); // exceeds pool
        assert_eq!(held.len(), 3, "failed grow must not leak");
    }

    #[test]
    fn capacity_from_device_memory() {
        // 8B model BF16 weights on 80 GB H100: ~16 GB weights,
        // BF16 KV: block bytes = 16 tokens * 2*32*8*128*2 B = 2 MiB.
        let m = by_name("llama-8b").unwrap();
        let c = KvCacheConfig::from_device(m, 80e9, 2.0, 2.0, 16, 0.05);
        assert!(c.total_blocks > 20_000, "{}", c.total_blocks);
        // FP8 weights free up room for more blocks.
        let c8 = KvCacheConfig::from_device(m, 80e9, 1.0, 2.0, 16, 0.05);
        assert!(c8.total_blocks > c.total_blocks);
    }

    #[test]
    fn for_instance_enforces_capacity() {
        use crate::analysis::parallel::{CapacityError, ParallelismPlan, DEFAULT_MIN_KV_TOKENS};
        use crate::hwsim::spec::Device;
        let m8 = by_name("llama-8b").unwrap();
        let ok = KvCacheConfig::for_instance(
            m8,
            Device::H100,
            ParallelismPlan::single(),
            1.0,
            2.0,
            DEFAULT_MIN_KV_TOKENS,
        )
        .expect("8B fits one H100");
        assert!(ok.total_blocks * ok.block_tokens >= DEFAULT_MIN_KV_TOKENS);
        // 70B BF16 on one chip is a typed rejection, not a silent pool.
        let m70 = by_name("llama-70b").unwrap();
        let err = KvCacheConfig::for_instance(
            m70,
            Device::H100,
            ParallelismPlan::single(),
            2.0,
            2.0,
            DEFAULT_MIN_KV_TOKENS,
        )
        .unwrap_err();
        assert!(matches!(err, CapacityError::WeightsExceedHbm { .. }));
        // Sharded across 4 chips it becomes a real pool.
        let sharded = KvCacheConfig::for_instance(
            m70,
            Device::H100,
            ParallelismPlan::tp(4),
            2.0,
            2.0,
            DEFAULT_MIN_KV_TOKENS,
        )
        .expect("70B BF16 fits at tp4");
        assert!(sharded.total_blocks > ok.total_blocks / 100);
    }

    #[test]
    fn blocks_for_tokens_rounds_up() {
        let c = cfg(0);
        assert_eq!(c.blocks_for_tokens(1), 1);
        assert_eq!(c.blocks_for_tokens(16), 1);
        assert_eq!(c.blocks_for_tokens(17), 2);
        assert_eq!(c.blocks_for_tokens(0), 0);
    }

    #[test]
    fn utilization_tracks() {
        let mut a = BlockAllocator::new(cfg(10));
        assert_eq!(a.utilization(), 0.0);
        let _b = a.allocate(5).unwrap();
        assert_eq!(a.utilization(), 0.5);
    }
}
