//! Prefill/decode scheduling policies.
//!
//! The paper (§2.2, citing Splitwise [32]) observes that prefill and
//! decode have markedly different throughput profiles and that phase-
//! aware placement changes the TCO balance. Two policies:
//!
//! * [`SchedulerPolicy::Fused`] — classic vLLM: the same engine
//!   interleaves prefill and decode steps (prefill-priority).
//! * [`SchedulerPolicy::Disaggregated`] — Splitwise-style: prefill
//!   and decode run on separate (possibly different) simulated
//!   devices; this is what makes the Fig. 9 phase-split TCO scenarios
//!   expressible.

use super::batcher::Admission;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerPolicy {
    /// Interleave prefill and decode on one engine (prefill priority).
    Fused,
    /// Run prefill and decode as separate pools.
    Disaggregated,
}

/// What the engine executes this step.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StepPlan {
    /// No work.
    Idle,
    /// Run these prefills (sequence ids).
    Prefill(Vec<u64>),
    /// Run one batched decode step over these ids.
    Decode(Vec<u64>),
    /// Disaggregated: both phases concurrently (separate pools).
    Both { prefills: Vec<u64>, decodes: Vec<u64> },
}

/// Turn an admission into a step plan under the policy.
///
/// Fused engines prefer prefill (vLLM default: new requests reach
/// first token fast, decodes stall one step); disaggregated engines
/// run both pools concurrently.
pub fn plan(policy: SchedulerPolicy, adm: Admission) -> StepPlan {
    match policy {
        SchedulerPolicy::Fused => {
            if !adm.prefills.is_empty() {
                StepPlan::Prefill(adm.prefills)
            } else if !adm.decodes.is_empty() {
                StepPlan::Decode(adm.decodes)
            } else {
                StepPlan::Idle
            }
        }
        SchedulerPolicy::Disaggregated => {
            if adm.prefills.is_empty() && adm.decodes.is_empty() {
                StepPlan::Idle
            } else {
                StepPlan::Both { prefills: adm.prefills, decodes: adm.decodes }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn adm(p: Vec<u64>, d: Vec<u64>) -> Admission {
        Admission { prefills: p, decodes: d }
    }

    #[test]
    fn fused_prefill_priority() {
        let plan1 = plan(SchedulerPolicy::Fused, adm(vec![1], vec![2, 3]));
        assert_eq!(plan1, StepPlan::Prefill(vec![1]));
        let plan2 = plan(SchedulerPolicy::Fused, adm(vec![], vec![2, 3]));
        assert_eq!(plan2, StepPlan::Decode(vec![2, 3]));
    }

    #[test]
    fn fused_idle_when_empty() {
        assert_eq!(plan(SchedulerPolicy::Fused, adm(vec![], vec![])), StepPlan::Idle);
    }

    #[test]
    fn disaggregated_runs_both() {
        let p = plan(SchedulerPolicy::Disaggregated, adm(vec![1], vec![2]));
        assert_eq!(p, StepPlan::Both { prefills: vec![1], decodes: vec![2] });
    }
}
