//! Continuous batcher: admission control for the step loop.
//!
//! Implements the iteration-level batching of vLLM/Orca ([21], §5.1):
//! each step, running decodes continue and queued prefills are
//! admitted under (a) a token budget per step, (b) a max batch size,
//! and (c) KV-block availability (checked against the *full* future
//! context so admitted sequences never deadlock mid-decode).
//!
//! Complexity contract (DESIGN.md §9): the decode half of the batch is
//! an *incrementally maintained* sorted set — the engine marks every
//! state transition (prefill completion, finish, preemption, bounce
//! resume) and `plan_step` snapshots the set instead of rescanning and
//! re-sorting the whole sequence map, so planning one step costs
//! O(batch + admissions), independent of how many requests the engine
//! has ever served. Debug builds cross-check the set against a full
//! scan every step, so every test run audits the index.

use std::collections::{BTreeSet, VecDeque};

use super::kv_cache::BlockAllocator;
use super::request::{RequestState, SeqId, SeqRole, Sequence};
use crate::workload::trace::TenantClass;

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Max sequences decoding concurrently.
    pub max_batch: usize,
    /// Max new prompt tokens admitted per step (prefill chunk budget).
    pub prefill_token_budget: usize,
    /// Max prefills admitted per step.
    pub max_prefills_per_step: usize,
    /// Admit a prefill only if its whole (prompt + output) KV fits —
    /// conservative, no preemption needed. If false, admit on prompt
    /// fit and preempt on pressure.
    pub reserve_full_context: bool,
    /// No-starvation bound for the batch lane: a batch-class head that
    /// has waited at least this long schedules ahead of interactive
    /// arrivals. Interactive traffic otherwise always goes first.
    pub batch_aging_s: f64,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 64,
            prefill_token_budget: 8192,
            max_prefills_per_step: 8,
            reserve_full_context: false,
            batch_aging_s: 30.0,
        }
    }
}

/// Outcome of one admission pass.
#[derive(Debug, Default)]
pub struct Admission {
    /// Sequence ids to prefill this step.
    pub prefills: Vec<SeqId>,
    /// Sequence ids decoding this step.
    pub decodes: Vec<SeqId>,
}

/// KV tokens a migrated decode leg needs on arrival: its context plus
/// the first locally generated token. Both the batcher's resume
/// reservation and decode-pool admission control
/// ([`Engine::can_admit_migration`](super::engine::Engine::can_admit_migration))
/// use this, so "accepted" always means "first decode step covered".
pub fn migration_footprint_tokens(context_len: usize) -> usize {
    context_len + 1
}

/// Which waiting lane the next admission candidate comes from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Lane {
    Interactive,
    Batch,
}

/// What the next admission passes will do, as seen from `now` — the
/// static-composition oracle behind the engine's event-driven
/// fast-forward (DESIGN.md §13).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AdmissionOutlook {
    /// A `plan_step` at `now` would (or may) admit work: the batch
    /// composition is about to change, so fast-forward must not start.
    Admit,
    /// No `plan_step` at any instant strictly before the returned time
    /// can admit anything, provided no finish, preemption, release or
    /// submission happens in between (the caller bounds the window by
    /// those events separately). `f64::INFINITY` means admission is
    /// impossible until one of those events.
    StaticUntil(f64),
}

#[derive(Debug)]
pub struct Batcher {
    pub cfg: BatcherConfig,
    /// Interactive-class lane (FIFO). With no batch traffic this is
    /// the only lane and admission reduces exactly to the old single
    /// FIFO — bit-identical schedules for single-tenant traces.
    queue: VecDeque<SeqId>,
    /// Batch-class lane (FIFO). Admitted behind interactive heads
    /// unless its head has aged past `cfg.batch_aging_s`.
    batch_queue: VecDeque<SeqId>,
    /// Sequences currently in [`RequestState::Decoding`], kept sorted
    /// by id (the order the old full-scan-plus-sort produced). The
    /// engine updates it on every state transition, so `plan_step`
    /// costs O(batch), not O(every sequence ever submitted).
    decoding: BTreeSet<SeqId>,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        Batcher {
            cfg,
            queue: VecDeque::new(),
            batch_queue: VecDeque::new(),
            decoding: BTreeSet::new(),
        }
    }

    pub fn enqueue(&mut self, id: SeqId, class: TenantClass) {
        match class {
            TenantClass::Interactive => self.queue.push_back(id),
            TenantClass::Batch => self.batch_queue.push_back(id),
        }
    }

    /// A sequence entered [`RequestState::Decoding`] (prefill
    /// completed, or a bounced prefill leg resumed). Idempotent.
    pub fn mark_decoding(&mut self, id: SeqId) {
        self.decoding.insert(id);
    }

    /// A sequence left [`RequestState::Decoding`] (finished or
    /// preempted). A no-op for ids never marked.
    pub fn unmark_decoding(&mut self, id: SeqId) {
        self.decoding.remove(&id);
    }

    /// Requeue a preempted sequence at the *front* of its lane (vLLM
    /// recompute semantics): it was admitted before anything still
    /// waiting in that lane, so its re-prefill must not be gated
    /// behind later — possibly not-yet-arrived — requests.
    pub fn requeue_front(&mut self, id: SeqId, class: TenantClass) {
        match class {
            TenantClass::Interactive => self.queue.push_front(id),
            TenantClass::Batch => self.batch_queue.push_front(id),
        }
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len() + self.batch_queue.len()
    }

    /// Clear every lane and the decode set (replica crash: all resident
    /// work is lost). Returns the drained ids in a deterministic order
    /// — interactive lane front-to-back, then the batch lane, then the
    /// decode set in ascending-id order — so the crash handler can
    /// schedule retries reproducibly.
    pub fn reset(&mut self) -> Vec<SeqId> {
        let mut ids: Vec<SeqId> = self.queue.drain(..).collect();
        ids.extend(self.batch_queue.drain(..));
        ids.extend(std::mem::take(&mut self.decoding));
        ids
    }

    /// Number of sequences currently in the decode set.
    pub fn decoding_len(&self) -> usize {
        self.decoding.len()
    }

    /// The decode set in ascending-id order (the order `plan_step`
    /// snapshots it in).
    pub fn decoding_ids(&self) -> impl Iterator<Item = SeqId> + '_ {
        self.decoding.iter().copied()
    }

    /// Ready time of the earliest queued head across both lanes — the
    /// engine's idle-advance target when nothing is runnable at `now`
    /// (either lane's head may become admissible first). Ready time is
    /// arrival for fresh requests and last-chunk KV landing for
    /// migrated decode legs ([`Sequence::ready_at_s`]).
    pub fn head_arrival(
        &self,
        seqs: &std::collections::HashMap<SeqId, Sequence>,
    ) -> Option<f64> {
        let i = self.queue.iter().find_map(|id| seqs.get(id)).map(|s| s.ready_at_s);
        let b = self
            .batch_queue
            .iter()
            .find_map(|id| seqs.get(id))
            .map(|s| s.ready_at_s);
        match (i, b) {
            (Some(x), Some(y)) => Some(x.min(y)),
            (x, None) => x,
            (None, y) => y,
        }
    }

    /// Drop ids with no live sequence from the lane's front, then
    /// return the head's ready time (None if the lane is empty).
    fn prune_head(
        lane: &mut VecDeque<SeqId>,
        seqs: &std::collections::HashMap<SeqId, Sequence>,
    ) -> Option<f64> {
        while let Some(id) = lane.front() {
            match seqs.get(id) {
                Some(s) => return Some(s.ready_at_s),
                None => {
                    lane.pop_front();
                }
            }
        }
        None
    }

    /// Pick the lane whose head is admitted next at `now`: interactive
    /// ahead of batch, except a batch head that has waited at least
    /// `batch_aging_s` goes first (the no-starvation bound). Heads
    /// that have not arrived yet are invisible — an unarrived
    /// interactive head never gates an arrived batch head.
    fn choose_lane(
        &mut self,
        seqs: &std::collections::HashMap<SeqId, Sequence>,
        now: f64,
    ) -> Option<Lane> {
        let i = Self::prune_head(&mut self.queue, seqs).filter(|&a| a <= now);
        let b = Self::prune_head(&mut self.batch_queue, seqs).filter(|&a| a <= now);
        if let Some(ba) = b {
            if now - ba >= self.cfg.batch_aging_s {
                return Some(Lane::Batch);
            }
        }
        if i.is_some() {
            return Some(Lane::Interactive);
        }
        if b.is_some() {
            return Some(Lane::Batch);
        }
        None
    }

    /// Plan one step at virtual time `now`. `seqs` resolves ids to
    /// sequences; the batcher allocates KV blocks for admitted
    /// prefills and grows blocks for decodes (evicting nothing —
    /// callers preempt on `grow` failure). A queued request is
    /// admissible only once the clock has reached its arrival: the
    /// open-loop trace is honored rather than collapsed to batch-at-t0.
    pub fn plan_step(
        &mut self,
        seqs: &mut std::collections::HashMap<SeqId, Sequence>,
        alloc: &mut BlockAllocator,
        now: f64,
    ) -> Admission {
        let mut adm = Admission::default();

        // 1. Continue running decodes (iteration-level batching). The
        // incremental index already holds exactly the Decoding ids in
        // ascending order — the order the old scan-and-sort produced.
        self.audit_decoding_index(seqs);
        adm.decodes = self.decoding.iter().copied().collect();

        // 2. Admit prefills under budgets, choosing between the
        // interactive and batch lanes each iteration. A blocked head
        // (budget or memory) still breaks the whole pass: head-of-line
        // order within the chosen lane is the fairness contract.
        let mut token_budget = self.cfg.prefill_token_budget;
        while adm.prefills.len() < self.cfg.max_prefills_per_step
            && adm.decodes.len() + adm.prefills.len() < self.cfg.max_batch
        {
            let Some(lane) = self.choose_lane(seqs, now) else {
                break; // nothing admissible at `now` in either lane
            };
            let lane_queue = match lane {
                Lane::Interactive => &mut self.queue,
                Lane::Batch => &mut self.batch_queue,
            };
            let Some(&cand) = lane_queue.front() else { break };
            let Some(seq) = seqs.get_mut(&cand) else {
                lane_queue.pop_front();
                continue;
            };
            // A migrated decode leg "resumes": its context KV arrived
            // over the fabric, so admission allocates the blocks but
            // costs no prefill compute and no token budget — the
            // sequence joins this step's decode batch directly.
            let resume = seq.role == SeqRole::DecodeLeg;
            if !resume && seq.prompt_len > token_budget {
                // Oversized prompt (bigger than the whole per-step
                // budget): admit it alone so it cannot starve.
                if seq.prompt_len > self.cfg.prefill_token_budget
                    && adm.prefills.is_empty()
                {
                    token_budget = seq.prompt_len;
                } else {
                    break; // head-of-line: preserve FIFO fairness
                }
            }
            let reserve_tokens = if self.cfg.reserve_full_context {
                seq.max_context()
            } else if resume {
                // One decode step of lookahead: the migrated context
                // plus the first locally generated token. This is what
                // lets admission control promise that an *accepted*
                // migration never preempts within its first decode
                // step — the first `grow` is covered by construction.
                migration_footprint_tokens(seq.prompt_len)
            } else {
                seq.prompt_len
            };
            let blocks_needed = alloc.config().blocks_for_tokens(reserve_tokens);
            if !alloc.can_allocate(blocks_needed) {
                break; // memory pressure: wait for releases
            }
            let Some(blocks) = alloc.allocate(blocks_needed) else {
                debug_assert!(false, "allocate failed after can_allocate said yes");
                break;
            };
            seq.blocks = blocks;
            if resume {
                seq.state = RequestState::Decoding;
                self.decoding.insert(cand);
                adm.decodes.push(cand);
            } else {
                token_budget -= seq.prompt_len;
                adm.prefills.push(cand);
            }
            lane_queue.pop_front();
        }
        adm
    }

    /// Replicate the *first* iteration of `plan_step`'s admission loop
    /// at `now` without mutating anything (beyond pruning dead lane
    /// heads, which `plan_step` would also do), and report either that
    /// it would admit or the earliest future instant at which any
    /// admission decision could change.
    ///
    /// Why the first iteration suffices: if the first candidate is not
    /// admitted, `plan_step` breaks the whole pass (head-of-line
    /// fairness), so "first candidate blocked" == "nothing admitted".
    /// The per-step token budget can never block the first candidate —
    /// the oversized-alone path raises the budget for a lone oversized
    /// head — so only visibility (ready time), lane choice (batch
    /// aging flip) and KV memory gate it. Memory verdicts are stable
    /// across a fast-forward window because free blocks only shrink
    /// while decodes grow (releases come from finishes/preemptions,
    /// which the caller treats as window boundaries).
    pub fn admission_outlook(
        &mut self,
        seqs: &std::collections::HashMap<SeqId, Sequence>,
        alloc: &BlockAllocator,
        now: f64,
    ) -> AdmissionOutlook {
        // First loop-condition check: with a full decode batch (or a
        // zero prefill quota) the admission loop body never runs, no
        // matter what is queued — only a finish can change that.
        if self.cfg.max_prefills_per_step == 0 || self.decoding.len() >= self.cfg.max_batch
        {
            return AdmissionOutlook::StaticUntil(f64::INFINITY);
        }
        let i = Self::prune_head(&mut self.queue, seqs);
        let b = Self::prune_head(&mut self.batch_queue, seqs);
        if i.is_none() && b.is_none() {
            return AdmissionOutlook::StaticUntil(f64::INFINITY);
        }
        // The instants where `choose_lane`'s outcome can change: a head
        // becoming visible, or the batch head crossing the aging bound.
        let static_until = |now: f64| {
            let mut t = f64::INFINITY;
            for cand in [i, b, b.map(|ba| ba + self.cfg.batch_aging_s)]
                .into_iter()
                .flatten()
            {
                if cand > now {
                    t = t.min(cand);
                }
            }
            AdmissionOutlook::StaticUntil(t)
        };
        let lane = {
            let i_vis = i.filter(|&a| a <= now);
            let b_vis = b.filter(|&a| a <= now);
            if b_vis.is_some_and(|ba| now - ba >= self.cfg.batch_aging_s) {
                Some(Lane::Batch)
            } else if i_vis.is_some() {
                Some(Lane::Interactive)
            } else if b_vis.is_some() {
                Some(Lane::Batch)
            } else {
                None
            }
        };
        let Some(lane) = lane else {
            return static_until(now); // nothing visible yet
        };
        let lane_queue = match lane {
            Lane::Interactive => &self.queue,
            Lane::Batch => &self.batch_queue,
        };
        let seq = lane_queue.front().and_then(|id| seqs.get(id));
        let Some(seq) = seq else {
            // prune_head just certified a live head; unreachable, but
            // degrade to "no fast-forward" rather than panic.
            debug_assert!(false, "pruned lane lost its head");
            return AdmissionOutlook::Admit;
        };
        let reserve_tokens = if self.cfg.reserve_full_context {
            seq.max_context()
        } else if seq.role == SeqRole::DecodeLeg {
            migration_footprint_tokens(seq.prompt_len)
        } else {
            seq.prompt_len
        };
        if alloc.can_allocate(alloc.config().blocks_for_tokens(reserve_tokens)) {
            AdmissionOutlook::Admit
        } else {
            // Memory-blocked, and it stays blocked within the window;
            // only a lane flip could surface a different (smaller)
            // candidate before the next finish.
            static_until(now)
        }
    }

    /// Debug-build cross-check: the incremental decode index must be
    /// exactly the set a full scan of `seqs` would produce. Every test
    /// run therefore audits the index against the reference scan on
    /// every planned step; release builds skip the scan entirely.
    #[inline]
    fn audit_decoding_index(&self, seqs: &std::collections::HashMap<SeqId, Sequence>) {
        if cfg!(debug_assertions) {
            // simlint: allow(determinism) -- debug-only reference scan, sorted before the comparison
            let mut scan: Vec<SeqId> = seqs
                .values()
                .filter(|s| s.state == RequestState::Decoding)
                .map(|s| s.id)
                .collect();
            scan.sort_unstable();
            let index: Vec<SeqId> = self.decoding.iter().copied().collect();
            debug_assert_eq!(
                index, scan,
                "incremental decode index diverged from the reference scan"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::trace::Request;
    use crate::coordinator::kv_cache::KvCacheConfig;
    use std::collections::HashMap;

    fn setup(total_blocks: usize) -> (HashMap<SeqId, Sequence>, BlockAllocator) {
        let alloc = BlockAllocator::new(KvCacheConfig {
            block_tokens: 16,
            total_blocks,
        });
        (HashMap::new(), alloc)
    }

    fn add_seq(seqs: &mut HashMap<SeqId, Sequence>, b: &mut Batcher, id: u64,
               prompt: usize, output: usize) {
        add_classed(seqs, b, id, 0.0, prompt, output, TenantClass::Interactive);
    }

    fn add_classed(seqs: &mut HashMap<SeqId, Sequence>, b: &mut Batcher, id: u64,
                   arrival: f64, prompt: usize, output: usize, class: TenantClass) {
        let s = Sequence::from_request(&Request {
            id, arrival, prompt_len: prompt, output_len: output, class,
        });
        seqs.insert(id, s);
        b.enqueue(id, class);
    }

    #[test]
    fn admits_fifo_until_token_budget() {
        let (mut seqs, mut alloc) = setup(1000);
        let mut b = Batcher::new(BatcherConfig {
            prefill_token_budget: 250,
            ..Default::default()
        });
        add_seq(&mut seqs, &mut b, 0, 100, 5);
        add_seq(&mut seqs, &mut b, 1, 100, 5);
        add_seq(&mut seqs, &mut b, 2, 100, 5); // exceeds 250 budget
        let adm = b.plan_step(&mut seqs, &mut alloc, 0.0);
        assert_eq!(adm.prefills, vec![0, 1]);
        assert_eq!(b.queue_len(), 1);
    }

    #[test]
    fn respects_max_batch_with_running_decodes() {
        let (mut seqs, mut alloc) = setup(1000);
        let mut b = Batcher::new(BatcherConfig { max_batch: 3, ..Default::default() });
        // two already decoding (marked, as the engine does on the
        // prefill-completion transition)
        for id in [10u64, 11] {
            let mut s = Sequence::from_request(&Request {
                id, arrival: 0.0, prompt_len: 10, output_len: 10,
                class: TenantClass::Interactive,
            });
            s.state = RequestState::Decoding;
            seqs.insert(id, s);
            b.mark_decoding(id);
        }
        add_seq(&mut seqs, &mut b, 0, 16, 4);
        add_seq(&mut seqs, &mut b, 1, 16, 4);
        let adm = b.plan_step(&mut seqs, &mut alloc, 0.0);
        assert_eq!(adm.decodes, vec![10, 11]);
        assert_eq!(adm.prefills.len(), 1, "only one slot left");
    }

    #[test]
    fn blocks_gate_admission() {
        let (mut seqs, mut alloc) = setup(2); // 32 tokens of KV
        let mut b = Batcher::new(BatcherConfig::default());
        add_seq(&mut seqs, &mut b, 0, 40, 4); // needs 3 blocks
        let adm = b.plan_step(&mut seqs, &mut alloc, 0.0);
        assert!(adm.prefills.is_empty());
        assert_eq!(b.queue_len(), 1, "stays queued");
    }

    #[test]
    fn reserve_full_context_mode() {
        let (mut seqs, mut alloc) = setup(4); // 64 tokens
        let mut b = Batcher::new(BatcherConfig {
            reserve_full_context: true,
            ..Default::default()
        });
        // prompt 32 fits, but prompt+output = 80 does not.
        add_seq(&mut seqs, &mut b, 0, 32, 48);
        let adm = b.plan_step(&mut seqs, &mut alloc, 0.0);
        assert!(adm.prefills.is_empty());
        // Non-reserving batcher admits it.
        let mut b2 = Batcher::new(BatcherConfig::default());
        b2.enqueue(0, TenantClass::Interactive);
        let adm2 = b2.plan_step(&mut seqs, &mut alloc, 0.0);
        assert_eq!(adm2.prefills, vec![0]);
    }

    #[test]
    fn admitted_prefill_holds_blocks() {
        let (mut seqs, mut alloc) = setup(100);
        let mut b = Batcher::new(BatcherConfig::default());
        add_seq(&mut seqs, &mut b, 0, 100, 4);
        let _ = b.plan_step(&mut seqs, &mut alloc, 0.0);
        assert_eq!(seqs[&0].blocks.len(), 7); // ceil(100/16)
        assert_eq!(alloc.allocated_blocks(), 7);
    }

    #[test]
    fn oversized_prompt_admitted_alone_no_starvation() {
        // A prompt larger than the whole per-step budget is admitted
        // by itself (no bypass, no permanent starvation).
        let (mut seqs, mut alloc) = setup(1000);
        let mut b = Batcher::new(BatcherConfig {
            prefill_token_budget: 50,
            ..Default::default()
        });
        add_seq(&mut seqs, &mut b, 0, 100, 4);
        add_seq(&mut seqs, &mut b, 1, 10, 4);
        let adm = b.plan_step(&mut seqs, &mut alloc, 0.0);
        assert_eq!(adm.prefills, vec![0], "oversized head admitted alone");
        // Next step picks up the small one.
        let adm2 = b.plan_step(&mut seqs, &mut alloc, 0.0);
        assert_eq!(adm2.prefills, vec![1]);
    }

    #[test]
    fn future_arrivals_gated_until_their_time() {
        let (mut seqs, mut alloc) = setup(1000);
        let mut b = Batcher::new(BatcherConfig::default());
        let s = Sequence::from_request(&Request {
            id: 0, arrival: 5.0, prompt_len: 32, output_len: 4,
            class: TenantClass::Interactive,
        });
        seqs.insert(0, s);
        b.enqueue(0, TenantClass::Interactive);
        // Before the arrival: nothing admissible, head exposed for
        // idle-advance.
        let adm = b.plan_step(&mut seqs, &mut alloc, 0.0);
        assert!(adm.prefills.is_empty());
        assert_eq!(b.head_arrival(&seqs), Some(5.0));
        assert_eq!(alloc.allocated_blocks(), 0, "gating must not allocate");
        // At (or past) the arrival: admitted.
        let adm2 = b.plan_step(&mut seqs, &mut alloc, 5.0);
        assert_eq!(adm2.prefills, vec![0]);
        assert_eq!(b.head_arrival(&seqs), None);
    }

    #[test]
    fn migrated_decode_leg_resumes_without_prefill() {
        use crate::coordinator::request::MigratedRequest;
        let (mut seqs, mut alloc) = setup(1000);
        let mut b = Batcher::new(BatcherConfig::default());
        let m = MigratedRequest {
            id: 0,
            arrival: 0.0,
            at: 1.0,
            kv_ready_s: 1.0,
            context_len: 40,
            remaining_out: 9,
            bytes: 40.0 * 131072.0,
        };
        seqs.insert(0, Sequence::migrated(&m));
        b.enqueue(0, TenantClass::Interactive);
        // Before the KV arrives: gated like any future arrival.
        let adm0 = b.plan_step(&mut seqs, &mut alloc, 0.5);
        assert!(adm0.prefills.is_empty() && adm0.decodes.is_empty());
        assert_eq!(alloc.allocated_blocks(), 0);
        // At delivery: admitted straight into the decode batch, blocks
        // allocated for the migrated context, zero prefill compute.
        let adm = b.plan_step(&mut seqs, &mut alloc, 1.0);
        assert!(adm.prefills.is_empty());
        assert_eq!(adm.decodes, vec![0]);
        assert_eq!(seqs[&0].blocks.len(), 3); // ceil(40/16)
        assert_eq!(seqs[&0].state, RequestState::Decoding);
    }

    #[test]
    fn partial_budget_preserves_fifo() {
        // Head fits the full budget but not the remainder: FIFO holds
        // (no smaller request bypasses it).
        let (mut seqs, mut alloc) = setup(1000);
        let mut b = Batcher::new(BatcherConfig {
            prefill_token_budget: 100,
            ..Default::default()
        });
        add_seq(&mut seqs, &mut b, 0, 60, 4);
        add_seq(&mut seqs, &mut b, 1, 60, 4); // budget left: 40
        add_seq(&mut seqs, &mut b, 2, 10, 4);
        let adm = b.plan_step(&mut seqs, &mut alloc, 0.0);
        assert_eq!(adm.prefills, vec![0], "no bypass of seq 1");
    }

    #[test]
    fn interactive_schedules_ahead_of_batch() {
        let (mut seqs, mut alloc) = setup(1000);
        let mut b = Batcher::new(BatcherConfig::default());
        // Batch request queued first, interactive second — the
        // interactive one still prefills first.
        add_classed(&mut seqs, &mut b, 0, 0.0, 32, 4, TenantClass::Batch);
        add_classed(&mut seqs, &mut b, 1, 0.0, 32, 4, TenantClass::Interactive);
        let adm = b.plan_step(&mut seqs, &mut alloc, 0.0);
        assert_eq!(adm.prefills, vec![1, 0], "interactive head goes first");
    }

    #[test]
    fn batch_aging_bounds_starvation() {
        let (mut seqs, mut alloc) = setup(1000);
        let mut b = Batcher::new(BatcherConfig {
            max_prefills_per_step: 1,
            batch_aging_s: 2.0,
            ..Default::default()
        });
        add_classed(&mut seqs, &mut b, 0, 0.0, 32, 4, TenantClass::Batch);
        add_classed(&mut seqs, &mut b, 1, 0.0, 32, 4, TenantClass::Interactive);
        add_classed(&mut seqs, &mut b, 2, 0.0, 32, 4, TenantClass::Interactive);
        // Below the aging bound, interactive wins the single slot.
        let adm = b.plan_step(&mut seqs, &mut alloc, 1.0);
        assert_eq!(adm.prefills, vec![1]);
        // Past the bound (waited 2.5 s >= 2.0 s) the batch head jumps
        // the remaining interactive backlog: bounded starvation.
        let adm = b.plan_step(&mut seqs, &mut alloc, 2.5);
        assert_eq!(adm.prefills, vec![0], "aged batch head goes first");
        let adm = b.plan_step(&mut seqs, &mut alloc, 2.5);
        assert_eq!(adm.prefills, vec![2]);
    }

    #[test]
    fn unarrived_interactive_head_does_not_gate_batch() {
        let (mut seqs, mut alloc) = setup(1000);
        let mut b = Batcher::new(BatcherConfig::default());
        add_classed(&mut seqs, &mut b, 0, 5.0, 32, 4, TenantClass::Interactive);
        add_classed(&mut seqs, &mut b, 1, 0.0, 32, 4, TenantClass::Batch);
        let adm = b.plan_step(&mut seqs, &mut alloc, 1.0);
        assert_eq!(adm.prefills, vec![1], "arrived batch head admitted");
        // Idle-advance target is the earliest head across lanes.
        assert_eq!(b.head_arrival(&seqs), Some(5.0));
    }

    #[test]
    fn outlook_agrees_with_plan_step() {
        // The outlook's verdict must predict what plan_step does at
        // the same instant, and its StaticUntil horizon must name the
        // instant the verdict changes.
        let (mut seqs, mut alloc) = setup(1000);
        let mut b = Batcher::new(BatcherConfig::default());
        assert_eq!(
            b.admission_outlook(&seqs, &alloc, 0.0),
            AdmissionOutlook::StaticUntil(f64::INFINITY),
            "empty lanes: nothing can ever be admitted without a submit"
        );
        add_classed(&mut seqs, &mut b, 0, 5.0, 32, 4, TenantClass::Interactive);
        assert_eq!(
            b.admission_outlook(&seqs, &alloc, 1.0),
            AdmissionOutlook::StaticUntil(5.0),
            "unarrived head: static exactly until its ready time"
        );
        assert_eq!(b.admission_outlook(&seqs, &alloc, 5.0), AdmissionOutlook::Admit);
        let adm = b.plan_step(&mut seqs, &mut alloc, 5.0);
        assert_eq!(adm.prefills, vec![0]);
    }

    #[test]
    fn outlook_full_batch_and_memory_block() {
        let (mut seqs, mut alloc) = setup(2); // 32 tokens of KV
        let mut b = Batcher::new(BatcherConfig { max_batch: 2, ..Default::default() });
        add_seq(&mut seqs, &mut b, 0, 40, 4); // needs 3 blocks > 2 free
        assert_eq!(
            b.admission_outlook(&seqs, &alloc, 0.0),
            AdmissionOutlook::StaticUntil(f64::INFINITY),
            "memory-blocked head with no lane flip ahead"
        );
        // Saturated decode batch: the admission loop body cannot run.
        let (mut seqs2, alloc2) = setup(1000);
        let mut b2 = Batcher::new(BatcherConfig { max_batch: 2, ..Default::default() });
        for id in [7u64, 8] {
            let mut s = Sequence::from_request(&Request {
                id, arrival: 0.0, prompt_len: 10, output_len: 10,
                class: TenantClass::Interactive,
            });
            s.state = RequestState::Decoding;
            seqs2.insert(id, s);
            b2.mark_decoding(id);
        }
        add_seq(&mut seqs2, &mut b2, 0, 16, 4);
        assert_eq!(
            b2.admission_outlook(&seqs2, &alloc2, 0.0),
            AdmissionOutlook::StaticUntil(f64::INFINITY),
            "full decode batch admits nothing until a finish"
        );
    }

    #[test]
    fn outlook_sees_batch_aging_flip() {
        // Interactive head memory-blocked, batch head small enough to
        // fit: the outlook's horizon is the aging flip, where the lane
        // choice (and hence the admission verdict) can change.
        let (mut seqs, mut alloc) = setup(3); // 48 tokens of KV
        let mut b = Batcher::new(BatcherConfig { batch_aging_s: 2.0, ..Default::default() });
        add_classed(&mut seqs, &mut b, 0, 0.0, 60, 4, TenantClass::Interactive); // 4 blocks
        add_classed(&mut seqs, &mut b, 1, 0.5, 16, 4, TenantClass::Batch); // 1 block
        assert_eq!(
            b.admission_outlook(&seqs, &alloc, 1.0),
            AdmissionOutlook::StaticUntil(2.5),
            "blocked interactive head: next decision change at batch aging flip"
        );
        assert_eq!(b.admission_outlook(&seqs, &alloc, 2.5), AdmissionOutlook::Admit);
        let adm = b.plan_step(&mut seqs, &mut alloc, 2.5);
        assert_eq!(adm.prefills, vec![1], "aged batch head fits and goes first");
    }
}
