//! L3 coordinator: a vLLM-style serving engine.
//!
//! * [`request`] — request lifecycle types and sequence state.
//! * [`kv_cache`] — paged KV-cache block allocator (capacity admission).
//! * [`batcher`] — continuous batcher with token-budget admission.
//! * [`scheduler`] — prefill/decode scheduling policies (fused or
//!   disaggregated, §2.2 / Splitwise-style).
//! * [`backend`] — `ExecutionBackend` abstraction: `SimBackend` (hwsim
//!   timing, virtual clock — drives every paper figure) and
//!   `PjrtBackend` (real compute via the AOT artifacts, wall clock).
//! * [`engine`] — the step loop tying it all together.
//! * [`faults`] — deterministic fault injection: seeded `FaultPlan`
//!   compiled to a sorted schedule, crash/repair/derate/link-flap
//!   kinds, and the capped-backoff retry queue (`FaultDriver`).
//! * [`cluster`] — virtual-time event loops: [`Cluster`] over one
//!   colocated engine pool, [`DisaggCluster`] over disaggregated
//!   prefill/decode pools joined by a (optionally chunked/streaming)
//!   KV-migration link, [`PhaseAffinityCluster`] mixing both kinds
//!   behind a prompt-length router, and the SLO load sweep
//!   ([`ServeSim`]) that prices all of them.
//! * [`metrics`] — TTFT / TPOT / throughput accounting (§5.2 notes the
//!   paper's preference for FLOPs-based metrics; we record both),
//!   with steady-state (windowed) percentiles for open-loop runs.

pub mod backend;
pub mod batcher;
pub mod cluster;
pub mod engine;
pub mod faults;
pub mod kv_cache;
pub mod metrics;
#[cfg(feature = "pjrt")]
pub mod pjrt_backend;
pub mod request;
pub mod router;
pub mod scheduler;

pub use backend::{CacheStats, ExecutionBackend, SimBackend, StepCostCache};
pub use batcher::{Batcher, BatcherConfig};
pub use cluster::{
    affinity_threshold_candidates, auto_affinity_threshold, disagg_sim_cluster,
    phase_affinity_sim_cluster, sharded_sim_cluster, sim_cluster, Cluster, DisaggCluster,
    PhaseAffinityCluster, ServeSim, SloSpec, SweepConfig,
};
pub use engine::{Engine, EngineConfig, LostWork};
pub use faults::{FaultDriver, FaultEvent, FaultKind, FaultPlan, FaultTick, Pool, RetryPolicy};
pub use kv_cache::{BlockAllocator, KvCacheConfig};
pub use metrics::Metrics;
#[cfg(feature = "pjrt")]
pub use pjrt_backend::PjrtBackend;
pub use request::{MigratedRequest, RequestState, SeqId, SeqRole, Sequence};
pub use scheduler::{SchedulerPolicy, StepPlan};
