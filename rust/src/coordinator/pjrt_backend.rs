//! `PjrtBackend`: the real-compute execution backend.
//!
//! Drives the AOT-compiled HLO artifacts (tiny Llama tier, FP8 dynamic
//! row-wise linears via the L1 Pallas kernels) through PJRT on CPU.
//! Same `ExecutionBackend` interface as the simulator, so the engine's
//! scheduling code is identical — this is the end-to-end proof that
//! all three layers compose (DESIGN.md E2E).
//!
//! Sequence content: prompts are synthesized deterministically from
//! the sequence id (the engine schedules ids + lengths; content is the
//! backend's business). Per-sequence KV caches are host-resident
//! between steps and gathered/scattered around each batched decode —
//! the dense-cache analogue of paged KV at toy scale.

// simlint: allow-file(determinism) -- real-hardware backend: wall-clock measurement of actual PJRT execution is the point
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

/// One PJRT CPU client per thread, reused by every backend created on
/// that thread and never destroyed. xla_extension 0.5.1 misbehaves
/// with multiple CPU clients in one process (the second client's
/// executions return corrupted buffers — observed as NaN logits), and
/// `PjRtClient` is not `Send`, so the sharing granularity is the
/// thread. Consequently all PJRT work must stay on a single thread
/// (the e2e tests and examples comply; see rust/tests/pjrt_e2e.rs).
fn global_executor() -> Result<Arc<Executor>> {
    use std::cell::RefCell;
    thread_local! {
        static EXEC: RefCell<Option<Arc<Executor>>> = const { RefCell::new(None) };
    }
    EXEC.with(|cell| {
        if let Some(x) = &*cell.borrow() {
            return Ok(x.clone());
        }
        let x = Arc::new(Executor::cpu()?);
        *cell.borrow_mut() = Some(x.clone());
        // Never destroy the client: its destructor tears down global
        // runtime state that later clients depend on.
        std::mem::forget(x.clone());
        Ok(x)
    })
}

use crate::runtime::artifacts::ArtifactDir;
use crate::runtime::executor::{Executor, KvState, LoadedModel};
use crate::util::rng::Rng;

use super::backend::{ExecutionBackend, StepResult};
use super::request::SeqId;

struct SeqState {
    /// Full token history (prompt + generated).
    tokens: Vec<i32>,
    /// Valid KV length.
    kv_len: usize,
    /// Host copies of this sequence's KV: (layers, 1, max_seq, kv, d).
    k: Vec<f32>,
    v: Vec<f32>,
}

pub struct PjrtBackend {
    model: LoadedModel,
    seqs: HashMap<SeqId, SeqState>,
    /// Per-layer slice length (max_seq * kv_heads * head_dim).
    layer_stride: usize,
    layers: usize,
    vocab: usize,
    max_seq: usize,
    /// Tokens emitted per sequence (observable output for validation).
    pub emitted: HashMap<SeqId, Vec<i32>>,
}

impl PjrtBackend {
    pub fn load(dir: &ArtifactDir, tier: &str) -> Result<Self> {
        let _guard = crate::runtime::executor::pjrt_guard();
        let exec = global_executor()?;
        let model = LoadedModel::load(exec, dir, tier)?;
        let m = &model.meta;
        let layer_stride = m.max_seq * m.kv_heads * m.head_dim;
        Ok(PjrtBackend {
            layers: m.layers,
            vocab: m.vocab,
            max_seq: m.max_seq,
            layer_stride,
            model,
            seqs: HashMap::new(),
            emitted: HashMap::new(),
        })
    }

    pub fn meta(&self) -> &crate::runtime::artifacts::ModelMeta {
        &self.model.meta
    }

    /// Clear the emitted-token log (the backend is long-lived — one
    /// per process — so drivers reset between runs).
    pub fn reset_emitted(&mut self) {
        self.emitted.clear();
    }

    /// Deterministic synthetic prompt for a sequence id.
    fn synth_prompt(&self, id: SeqId, len: usize) -> Vec<i32> {
        let mut rng = Rng::new(0x9e37_79b9_7f4a_7c15 ^ id);
        (0..len).map(|_| rng.usize(0, self.vocab - 1) as i32).collect()
    }

    /// Model FLOPs of one decode step (Eq. 6 with the tiny config).
    fn decode_flops(&self, contexts: &[usize]) -> f64 {
        let m = &self.model.meta;
        let h = m.hidden as f64;
        let l = m.layers as f64;
        let v = m.vocab as f64;
        // a and g from meta-derived dims.
        let a = 172.0 / 64.0; // tiny-tier MLP ratio (meta lacks it; 1b tier)
        let g = (m.heads / m.kv_heads) as f64;
        let b = contexts.len() as f64;
        let sum_s: f64 = contexts.iter().map(|&s| s as f64).sum();
        let a_const = 3.0 * a + 2.0 + 2.0 / g;
        2.0 * b * (a_const * h * h * l + v * h) + 4.0 * h * l * sum_s
    }

    /// Gather per-seq caches into a batch literal layout
    /// (L, B, S, Hkv, d), padding empty slots with zeros.
    fn gather_kv(&self, ids: &[SeqId], bucket: usize) -> (Vec<f32>, Vec<f32>) {
        let total = self.layers * bucket * self.layer_stride;
        let mut k = vec![0.0f32; total];
        let mut v = vec![0.0f32; total];
        for l in 0..self.layers {
            for (b, id) in ids.iter().enumerate() {
                let s = &self.seqs[id];
                let src = l * self.layer_stride..(l + 1) * self.layer_stride;
                let dst = (l * bucket + b) * self.layer_stride;
                k[dst..dst + self.layer_stride].copy_from_slice(&s.k[src.clone()]);
                v[dst..dst + self.layer_stride].copy_from_slice(&s.v[src]);
            }
        }
        (k, v)
    }

    /// Scatter a batch KV literal back into per-seq host caches.
    fn scatter_kv(&mut self, ids: &[SeqId], bucket: usize, k: &[f32], v: &[f32]) {
        for l in 0..self.layers {
            for (b, id) in ids.iter().enumerate() {
                let src = (l * bucket + b) * self.layer_stride;
                let dst = l * self.layer_stride;
                let st = self.seqs.get_mut(id).unwrap();
                st.k[dst..dst + self.layer_stride]
                    .copy_from_slice(&k[src..src + self.layer_stride]);
                st.v[dst..dst + self.layer_stride]
                    .copy_from_slice(&v[src..src + self.layer_stride]);
            }
        }
    }

    fn do_prefill(&mut self, specs: &[(SeqId, usize)]) -> Result<()> {
        let max_prompt = self
            .model
            .meta
            .prefill_shapes
            .iter()
            .map(|&(_, s)| s)
            .max()
            .ok_or_else(|| anyhow!("no prefill buckets"))?;
        // One bucketed prefill per chunk of sequences.
        for chunk in specs.chunks(
            self.model.meta.prefill_shapes.iter().map(|&(b, _)| b).max().unwrap(),
        ) {
            let want = chunk.len();
            let lens: Vec<usize> =
                chunk.iter().map(|&(_, l)| l.min(max_prompt)).collect();
            let max_len = *lens.iter().max().unwrap();
            let (bb, bs) = self
                .model
                .meta
                .prefill_bucket(want, max_len)
                .ok_or_else(|| anyhow!("no bucket for b={want} s={max_len}"))?;
            let mut tokens = vec![0i32; bb * bs];
            let mut lengths = vec![1i32; bb];
            for (i, (&(id, _), &l)) in chunk.iter().zip(&lens).enumerate() {
                let prompt = self.synth_prompt(id, l);
                tokens[i * bs..i * bs + l].copy_from_slice(&prompt);
                lengths[i] = l as i32;
                self.seqs.insert(
                    id,
                    SeqState {
                        tokens: prompt,
                        kv_len: l,
                        k: vec![0.0; self.layers * self.layer_stride],
                        v: vec![0.0; self.layers * self.layer_stride],
                    },
                );
            }
            let (logits, kv) = self.model.prefill((bb, bs), &tokens, &lengths)?;
            if logits.iter().any(|x| x.is_nan()) {
                anyhow::bail!(
                    "NaN logits in prefill: bucket=({bb},{bs}) lengths={lengths:?}"
                );
            }
            // First token: argmax at each sequence's last valid position.
            let KvState { k, v, .. } = kv;
            let kvec = k.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
            let vvec = v.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
            // Prefill cache layout is (L, B, S, kv, d) with S = max_seq
            // already (aot pads) — stride matches layer_stride.
            let ids: Vec<SeqId> = chunk.iter().map(|&(id, _)| id).collect();
            self.scatter_kv(&ids, bb, &kvec, &vvec);
            for (i, &(id, _)) in chunk.iter().enumerate() {
                let pos = (lengths[i] as usize).saturating_sub(1);
                let row = &logits[(i * bs + pos) * self.vocab..(i * bs + pos + 1) * self.vocab];
                let tok = argmax(row);
                let st = self.seqs.get_mut(&id).unwrap();
                st.tokens.push(tok);
                self.emitted.entry(id).or_default().push(tok);
            }
        }
        Ok(())
    }

    fn do_decode(&mut self, specs: &[(SeqId, usize)]) -> Result<()> {
        for chunk in specs.chunks(
            self.model.meta.decode_batches.iter().copied().max().unwrap(),
        ) {
            let ids: Vec<SeqId> = chunk.iter().map(|&(id, _)| id).collect();
            let bucket = self
                .model
                .meta
                .decode_bucket(ids.len())
                .ok_or_else(|| anyhow!("no decode bucket for {}", ids.len()))?;
            let (kflat, vflat) = self.gather_kv(&ids, bucket);
            let m = &self.model.meta;
            let dims = [
                m.layers as i64,
                bucket as i64,
                m.max_seq as i64,
                m.kv_heads as i64,
                m.head_dim as i64,
            ];
            let k = xla::Literal::vec1(&kflat).reshape(&dims).map_err(|e| anyhow!("{e:?}"))?;
            let v = xla::Literal::vec1(&vflat).reshape(&dims).map_err(|e| anyhow!("{e:?}"))?;
            let mut tokens = vec![0i32; bucket];
            let mut lengths = vec![0i32; bucket];
            for (i, id) in ids.iter().enumerate() {
                let st = &self.seqs[id];
                tokens[i] = *st.tokens.last().unwrap();
                // Cap at max_seq - 1: the new KV lands at `lengths`.
                lengths[i] = (st.kv_len.min(self.max_seq - 1)) as i32;
            }
            let kv = KvState { k, v, batch: bucket };
            let (logits, kv2) = self.model.decode_step(kv, &tokens, &lengths)?;
            if logits.iter().any(|x| x.is_nan()) {
                let kv_nan = kflat.iter().any(|x| x.is_nan())
                    || vflat.iter().any(|x| x.is_nan());
                anyhow::bail!(
                    "NaN logits in decode: bucket={bucket} ids={ids:?} \
                     tokens={tokens:?} lengths={lengths:?} input_kv_nan={kv_nan}"
                );
            }
            let kvec = kv2.k.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
            let vvec = kv2.v.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
            self.scatter_kv(&ids, bucket, &kvec, &vvec);
            for (i, id) in ids.iter().enumerate() {
                let row = &logits[i * self.vocab..(i + 1) * self.vocab];
                let tok = argmax(row);
                let st = self.seqs.get_mut(id).unwrap();
                st.tokens.push(tok);
                st.kv_len = (st.kv_len + 1).min(self.max_seq - 1);
                self.emitted.entry(*id).or_default().push(tok);
            }
        }
        Ok(())
    }
}

fn argmax(row: &[f32]) -> i32 {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i as i32)
        .unwrap_or(0)
}

impl ExecutionBackend for PjrtBackend {
    fn prefill(&mut self, seqs: &[(SeqId, usize)]) -> StepResult {
        if seqs.is_empty() {
            return StepResult::default();
        }
        let t0 = Instant::now();
        let _guard = crate::runtime::executor::pjrt_guard();
        self.do_prefill(seqs).expect("pjrt prefill failed");
        let dt = t0.elapsed().as_secs_f64();
        // Eq. 3 linear term evaluated token-by-token: a prefill of s
        // tokens costs s times the per-token linear work plus the
        // (small at these lengths) attention term.
        let per_token = self.decode_flops(&[0]);
        let flops: f64 = seqs.iter().map(|&(_, l)| per_token * l as f64).sum();
        StepResult { seconds: dt, watts: 0.0, flops }
    }

    fn decode(&mut self, seqs: &[(SeqId, usize)]) -> StepResult {
        if seqs.is_empty() {
            return StepResult::default();
        }
        let t0 = Instant::now();
        let _guard = crate::runtime::executor::pjrt_guard();
        self.do_decode(seqs).expect("pjrt decode failed");
        let dt = t0.elapsed().as_secs_f64();
        let contexts: Vec<usize> = seqs.iter().map(|&(_, c)| c).collect();
        StepResult { seconds: dt, watts: 0.0, flops: self.decode_flops(&contexts) }
    }

    fn release(&mut self, id: SeqId) {
        self.seqs.remove(&id);
    }

    fn describe(&self) -> String {
        format!("pjrt:{}:{}", self.model.meta.tier, self.model.meta.precision)
    }
}
