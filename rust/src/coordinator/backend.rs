//! Execution backends: the same scheduling code drives either the
//! hwsim virtual testbed (`SimBackend`, used by every paper figure) or
//! real PJRT compute over the AOT artifacts (`PjrtBackend`, the
//! end-to-end validation path).
//!
//! `SimBackend` memoizes the pure analytic step model through a
//! [`StepCostCache`]: `perfmodel::{prefill, decode_step}` are exact
//! functions of `(batch, len)` for a fixed model/config, so a cached
//! [`StepBreakdown`] is bit-identical to a recomputed one by
//! construction (DESIGN.md §9). Hit/miss counters surface in
//! [`Metrics`](super::metrics::Metrics) via
//! [`ExecutionBackend::cache_stats`].

use std::collections::HashMap;

use crate::analysis::perfmodel::{self, StepBreakdown, StepConfig};
use crate::workload::llama::LlamaConfig;

use super::request::SeqId;

/// Cost of one executed step.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepResult {
    /// Step latency (virtual seconds for sim; wall seconds for PJRT).
    pub seconds: f64,
    /// Average device power during the step (W; 0 if unknown).
    pub watts: f64,
    /// Model FLOPs executed (Eq. 3/6 accounting).
    pub flops: f64,
}

/// Cumulative counters of a memoizing backend's step-cost cache.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
}

impl CacheStats {
    /// Hits over all lookups (0 when nothing was looked up).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Memoization table for the pure analytic step model, keyed on the
/// exact `(batch, len)` pair each phase is evaluated at. Exact-key
/// memoization of a deterministic function returns bit-identical
/// `StepBreakdown`s by construction — the cached value *is* the value
/// the first computation produced. Insertion stops at
/// [`StepCostCache::MAX_ENTRIES`] (lookups still count) so an
/// adversarially diverse trace cannot balloon resident memory; hits
/// simply stop growing past that point.
/// Key: `(batch, len, hbm_derate_frac bits)` — the derate joins every
/// key so degraded-mode steps can never serve a breakdown computed at
/// healthy bandwidth (cache-exact under fault injection).
type StepKey = (usize, usize, u64);

#[derive(Debug, Default)]
pub struct StepCostCache {
    prefill: HashMap<StepKey, StepBreakdown>,
    decode: HashMap<StepKey, StepBreakdown>,
    hits: u64,
    misses: u64,
}

impl StepCostCache {
    /// Cap on entries per phase map (~96 B each; two maps ≈ 50 MB
    /// worst case) — far above what real traces visit.
    pub const MAX_ENTRIES: usize = 1 << 18;

    pub fn new() -> Self {
        Self::default()
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats { hits: self.hits, misses: self.misses }
    }

    fn lookup<F>(
        map: &mut HashMap<StepKey, StepBreakdown>,
        hits: &mut u64,
        misses: &mut u64,
        key: StepKey,
        compute: F,
    ) -> StepBreakdown
    where
        F: FnOnce() -> StepBreakdown,
    {
        if let Some(bd) = map.get(&key) {
            *hits += 1;
            return bd.clone();
        }
        *misses += 1;
        let bd = compute();
        if map.len() < Self::MAX_ENTRIES {
            map.insert(key, bd.clone());
        }
        bd
    }
}

/// Abstract executor the engine drives. Sequence content is the
/// backend's business; the engine only schedules ids and lengths.
pub trait ExecutionBackend {
    /// Run prefills for `(id, prompt_len)` pairs; one batch.
    fn prefill(&mut self, seqs: &[(SeqId, usize)]) -> StepResult;

    /// Run one decode step over `(id, context_len)` pairs.
    fn decode(&mut self, seqs: &[(SeqId, usize)]) -> StepResult;

    /// Sequence finished or was evicted: drop backend state. The
    /// engine fires this for *every* sequence that leaves service —
    /// finished, evicted, or handed off — so per-sequence backend
    /// state cannot leak across a long trace (regression-tested in
    /// `tests/hotpath_equiv.rs`).
    fn release(&mut self, _id: SeqId) {}

    /// Cost of one decode step over a batch whose per-sequence
    /// contexts the caller has already reduced to their sum. Backends
    /// whose [`decode`](ExecutionBackend::decode) cost is a pure
    /// function of `(batch, total_context_tokens)` implement this so
    /// the engine's event-driven fast-forward (DESIGN.md §13) can
    /// price virtual steps in O(1) without materializing per-sequence
    /// spec slices. Must return exactly what `decode` would for any
    /// batch with this count and token sum — bit-identical, same
    /// cache-counter effects. The `None` default keeps backends that
    /// depend on per-sequence identity (real compute, audit wrappers)
    /// on the step-by-step path.
    fn decode_uniform(
        &mut self,
        _batch: usize,
        _total_context_tokens: usize,
    ) -> Option<StepResult> {
        None
    }

    /// Cumulative step-cost cache counters, if this backend memoizes
    /// (None for backends that execute real compute).
    fn cache_stats(&self) -> Option<CacheStats> {
        None
    }

    /// Degraded mode (fault injection): multiply the device's HBM
    /// bandwidth by `factor` (0 < factor <= 1) for subsequent steps;
    /// `1.0` restores healthy behaviour bit-exactly. Default: ignored
    /// (backends running real compute cannot throttle themselves).
    fn set_bw_derate(&mut self, _factor: f64) {}

    /// Device draw while this backend sits idle between steps (W).
    /// The engine bills the gaps between steps at this rate
    /// ([`Metrics::record_idle`](super::metrics::Metrics::record_idle)),
    /// so an idle engine is no longer free. 0 for backends without a
    /// power model (wall-clock backends measure, not model).
    fn idle_draw_w(&self) -> f64 {
        0.0
    }

    /// Human-readable identity for reports.
    fn describe(&self) -> String;
}

/// hwsim-backed backend: timing from the performance model, virtual
/// clock, no real numerics. This is the paper's testbed stand-in.
/// Step costs are memoized on exact `(batch, len)` keys by default
/// (`set_cache(false)` restores always-recompute, used by the
/// bit-identity equivalence tests).
///
/// `model`/`cfg` are private on purpose: the cache key assumes both
/// are fixed for the backend's lifetime, so mutating them in place
/// would silently serve breakdowns computed under the old config.
/// Build a new backend for a new configuration. The one sanctioned
/// exception is the HBM derate (fault injection's degraded mode),
/// which is part of every cache key — see
/// [`ExecutionBackend::set_bw_derate`].
pub struct SimBackend {
    model: &'static LlamaConfig,
    cfg: StepConfig,
    cache: Option<StepCostCache>,
}

impl SimBackend {
    pub fn new(model: &'static LlamaConfig, cfg: StepConfig) -> Self {
        SimBackend { model, cfg, cache: Some(StepCostCache::new()) }
    }

    pub fn model(&self) -> &'static LlamaConfig {
        self.model
    }

    pub fn cfg(&self) -> &StepConfig {
        &self.cfg
    }

    /// Toggle step-cost memoization (on by default). Turning it off
    /// drops the table and its counters.
    pub fn set_cache(&mut self, on: bool) {
        self.cache = if on { Some(StepCostCache::new()) } else { None };
    }

    /// The derate component of the step-cost cache key.
    fn derate_bits(&self) -> u64 {
        self.cfg.hbm_derate_frac.to_bits()
    }
}

impl ExecutionBackend for SimBackend {
    fn prefill(&mut self, seqs: &[(SeqId, usize)]) -> StepResult {
        if seqs.is_empty() {
            return StepResult::default();
        }
        // Batched prefill of mixed lengths: model as max-length batch
        // (padding, the common production compromise).
        let max_len = seqs.iter().map(|&(_, l)| l).max().unwrap_or(1);
        let key = (seqs.len(), max_len, self.derate_bits());
        let bd = match self.cache.as_mut() {
            Some(c) => StepCostCache::lookup(
                &mut c.prefill,
                &mut c.hits,
                &mut c.misses,
                key,
                || perfmodel::prefill(self.model, &self.cfg, key.0, key.1),
            ),
            None => perfmodel::prefill(self.model, &self.cfg, key.0, key.1),
        };
        StepResult { seconds: bd.seconds, watts: bd.watts, flops: bd.flops }
    }

    fn decode(&mut self, seqs: &[(SeqId, usize)]) -> StepResult {
        if seqs.is_empty() {
            return StepResult::default();
        }
        // Per-sequence contexts enter Eq. 6 via the average (linears
        // depend only on b; attention on sum of s_i).
        let avg: usize =
            seqs.iter().map(|&(_, l)| l).sum::<usize>() / seqs.len();
        let key = (seqs.len(), avg.max(1), self.derate_bits());
        let bd = match self.cache.as_mut() {
            Some(c) => StepCostCache::lookup(
                &mut c.decode,
                &mut c.hits,
                &mut c.misses,
                key,
                || perfmodel::decode_step(self.model, &self.cfg, key.0, key.1),
            ),
            None => perfmodel::decode_step(self.model, &self.cfg, key.0, key.1),
        };
        StepResult { seconds: bd.seconds, watts: bd.watts, flops: bd.flops }
    }

    /// The sim decode model is a pure function of
    /// `(batch, avg context)` — exactly the key [`decode`] reduces its
    /// spec slice to — so the uniform entry point routes through the
    /// *same* cache with the *same* key derivation. A fast-forwarded
    /// step therefore produces the same bits and the same hit/miss
    /// sequence a stepped one would.
    fn decode_uniform(
        &mut self,
        batch: usize,
        total_context_tokens: usize,
    ) -> Option<StepResult> {
        if batch == 0 {
            return Some(StepResult::default());
        }
        let avg = total_context_tokens / batch;
        let key = (batch, avg.max(1), self.derate_bits());
        let bd = match self.cache.as_mut() {
            Some(c) => StepCostCache::lookup(
                &mut c.decode,
                &mut c.hits,
                &mut c.misses,
                key,
                || perfmodel::decode_step(self.model, &self.cfg, key.0, key.1),
            ),
            None => perfmodel::decode_step(self.model, &self.cfg, key.0, key.1),
        };
        Some(StepResult { seconds: bd.seconds, watts: bd.watts, flops: bd.flops })
    }

    fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(|c| c.stats())
    }

    /// Degraded mode: the derate is part of every cache key (see
    /// [`StepKey`]), so mutating it here cannot serve stale healthy
    /// breakdowns — and setting it back to exactly `1.0` hits the same
    /// keys (and bits) a never-derated backend produces, because
    /// `x / 1.0` is an IEEE 754 identity.
    fn set_bw_derate(&mut self, factor: f64) {
        debug_assert!(
            factor > 0.0 && factor <= 1.0,
            "HBM derate must be in (0, 1], got {factor}"
        );
        self.cfg.hbm_derate_frac = factor;
    }

    /// Idle draw from the device spec. Busy draw is already
    /// load-dependent — `perfmodel::finish` feeds each step's achieved
    /// utilization through the calibrated `power_draw_w` curve — and a
    /// step's utilization is a pure function of the same `(batch, len)`
    /// key the [`StepCostCache`] memoizes on, so the load-dependent
    /// power model costs nothing in cache exactness: cached and
    /// recomputed steps stay bit-identical, idle draw is a config
    /// constant.
    fn idle_draw_w(&self) -> f64 {
        self.cfg.device.spec().idle_w
    }

    fn describe(&self) -> String {
        format!(
            "sim:{}:{}:{}",
            self.cfg.device.name(),
            self.model.name,
            self.cfg.precision.name()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::perfmodel::PrecisionMode;
    use crate::hwsim::spec::Device;
    use crate::workload::llama::by_name;

    fn backend() -> SimBackend {
        SimBackend::new(
            by_name("llama-8b").unwrap(),
            StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()),
        )
    }

    #[test]
    fn empty_steps_are_free() {
        let mut b = backend();
        assert_eq!(b.prefill(&[]).seconds, 0.0);
        assert_eq!(b.decode(&[]).seconds, 0.0);
    }

    #[test]
    fn decode_scales_with_batch() {
        let mut b = backend();
        let one = b.decode(&[(0, 1024)]);
        let many: Vec<(SeqId, usize)> = (0..64).map(|i| (i, 1024)).collect();
        let batch = b.decode(&many);
        // 64x the tokens for far less than 64x the time: batching works.
        assert!(batch.seconds < one.seconds * 16.0,
                "one {} batch {}", one.seconds, batch.seconds);
    }

    #[test]
    fn prefill_cost_grows_with_length() {
        let mut b = backend();
        let short = b.prefill(&[(0, 128)]);
        let long = b.prefill(&[(0, 4096)]);
        assert!(long.seconds > short.seconds * 4.0);
        assert!(long.flops > short.flops * 10.0);
    }

    #[test]
    fn describe_names_setup() {
        assert_eq!(backend().describe(), "sim:Gaudi2:llama-8b:fp8-static");
    }

    #[test]
    fn memoized_steps_are_bit_identical_to_recompute() {
        let mut cached = backend();
        let mut plain = backend();
        plain.set_cache(false);
        assert!(plain.cache_stats().is_none());
        let specs: Vec<(SeqId, usize)> = (0..32).map(|i| (i, 1024)).collect();
        let a = cached.decode(&specs); // miss: computes + stores
        let b = cached.decode(&specs); // hit: returns the stored value
        let c = plain.decode(&specs); // reference recompute
        for (x, y) in [(a.seconds, b.seconds), (a.watts, b.watts), (a.flops, b.flops)] {
            assert_eq!(x.to_bits(), y.to_bits(), "cache hit must be bit-identical");
        }
        for (x, y) in [(a.seconds, c.seconds), (a.watts, c.watts), (a.flops, c.flops)] {
            assert_eq!(x.to_bits(), y.to_bits(), "cache must match recompute");
        }
        let p1 = cached.prefill(&[(0, 777), (1, 500)]);
        let p2 = cached.prefill(&[(5, 500), (9, 777)]); // same (batch, max_len) key
        assert_eq!(p1.seconds.to_bits(), p2.seconds.to_bits());
        let cs = cached.cache_stats().unwrap();
        assert_eq!(cs.hits, 2, "one decode hit + one prefill hit");
        assert_eq!(cs.misses, 2, "one decode miss + one prefill miss");
        assert!((cs.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn decode_uniform_matches_decode_bits_and_counters() {
        // Mixed per-sequence contexts whose mean is not one of them:
        // the uniform path must reduce to the same (batch, avg) key.
        let specs: Vec<(SeqId, usize)> = vec![(0, 1000), (1, 1048), (2, 1100)];
        let total: usize = specs.iter().map(|&(_, l)| l).sum();
        let mut via_specs = backend();
        let mut via_uniform = backend();
        let a = via_specs.decode(&specs);
        let b = via_uniform.decode_uniform(specs.len(), total).expect("sim supports uniform");
        for (x, y) in [(a.seconds, b.seconds), (a.watts, b.watts), (a.flops, b.flops)] {
            assert_eq!(x.to_bits(), y.to_bits(), "uniform path must be bit-identical");
        }
        // Same cache-counter effects: a uniform call after the spec
        // call hits the entry the spec call stored, and vice versa.
        let hit = via_specs.decode_uniform(specs.len(), total).unwrap();
        assert_eq!(hit.seconds.to_bits(), a.seconds.to_bits());
        assert_eq!(via_specs.cache_stats().unwrap(), CacheStats { hits: 1, misses: 1 });
        let hit2 = via_uniform.decode(&specs);
        assert_eq!(hit2.seconds.to_bits(), b.seconds.to_bits());
        assert_eq!(via_uniform.cache_stats().unwrap(), CacheStats { hits: 1, misses: 1 });
        // Uncached backends still answer (recompute path).
        let mut plain = backend();
        plain.set_cache(false);
        let c = plain.decode_uniform(specs.len(), total).unwrap();
        assert_eq!(c.seconds.to_bits(), a.seconds.to_bits());
    }

    #[test]
    fn bw_derate_slows_steps_and_restores_bit_identically() {
        let mut healthy = backend();
        let mut faulty = backend();
        let specs: Vec<(SeqId, usize)> = (0..8).map(|i| (i, 2048)).collect();
        let base = healthy.decode(&specs);
        faulty.set_bw_derate(0.5);
        let slow = faulty.decode(&specs);
        assert!(
            slow.seconds > base.seconds,
            "halved HBM bandwidth must slow decode: {} vs {}",
            slow.seconds,
            base.seconds
        );
        // Recovery: derate back to 1.0 reproduces healthy bits — and
        // misses the derated entry (distinct key), then hits the
        // healthy one on repeat.
        faulty.set_bw_derate(1.0);
        let back = faulty.decode(&specs);
        assert_eq!(back.seconds.to_bits(), base.seconds.to_bits());
        assert_eq!(back.watts.to_bits(), base.watts.to_bits());
        let again = faulty.decode(&specs);
        assert_eq!(again.seconds.to_bits(), base.seconds.to_bits());
        assert_eq!(
            faulty.cache_stats().unwrap(),
            CacheStats { hits: 1, misses: 2 },
            "derated and healthy steps occupy distinct cache keys"
        );
        // Prefill is compute-bound (token-parallel GEMMs): the HBM
        // derate models the KV-streaming path and leaves prefill bits
        // untouched — it only shows up in prefill's cache key.
        let mut pf = backend();
        let p_base = pf.prefill(&[(0, 4096)]);
        pf.set_bw_derate(0.25);
        let p_same = pf.prefill(&[(0, 4096)]);
        assert_eq!(p_same.seconds.to_bits(), p_base.seconds.to_bits());
        assert_eq!(
            pf.cache_stats().unwrap().misses,
            2,
            "distinct keys even when the value coincides"
        );
    }

    #[test]
    fn cache_distinguishes_batch_and_length() {
        let mut b = backend();
        let one = b.decode(&[(0, 1024)]);
        let other_len = b.decode(&[(0, 2048)]);
        let other_batch = b.decode(&[(0, 1024), (1, 1024)]);
        assert_ne!(one.seconds.to_bits(), other_len.seconds.to_bits());
        assert_ne!(one.seconds.to_bits(), other_batch.seconds.to_bits());
        assert_eq!(b.cache_stats().unwrap().misses, 3, "three distinct keys");
    }
}
