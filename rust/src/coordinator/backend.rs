//! Execution backends: the same scheduling code drives either the
//! hwsim virtual testbed (`SimBackend`, used by every paper figure) or
//! real PJRT compute over the AOT artifacts (`PjrtBackend`, the
//! end-to-end validation path).

use crate::analysis::perfmodel::{self, StepConfig};
use crate::workload::llama::LlamaConfig;

use super::request::SeqId;

/// Cost of one executed step.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepResult {
    /// Step latency (virtual seconds for sim; wall seconds for PJRT).
    pub seconds: f64,
    /// Average device power during the step (W; 0 if unknown).
    pub watts: f64,
    /// Model FLOPs executed (Eq. 3/6 accounting).
    pub flops: f64,
}

/// Abstract executor the engine drives. Sequence content is the
/// backend's business; the engine only schedules ids and lengths.
pub trait ExecutionBackend {
    /// Run prefills for `(id, prompt_len)` pairs; one batch.
    fn prefill(&mut self, seqs: &[(SeqId, usize)]) -> StepResult;

    /// Run one decode step over `(id, context_len)` pairs.
    fn decode(&mut self, seqs: &[(SeqId, usize)]) -> StepResult;

    /// Sequence finished or was evicted: drop backend state.
    fn release(&mut self, _id: SeqId) {}

    /// Human-readable identity for reports.
    fn describe(&self) -> String;
}

/// hwsim-backed backend: timing from the performance model, virtual
/// clock, no real numerics. This is the paper's testbed stand-in.
pub struct SimBackend {
    pub model: &'static LlamaConfig,
    pub cfg: StepConfig,
}

impl SimBackend {
    pub fn new(model: &'static LlamaConfig, cfg: StepConfig) -> Self {
        SimBackend { model, cfg }
    }
}

impl ExecutionBackend for SimBackend {
    fn prefill(&mut self, seqs: &[(SeqId, usize)]) -> StepResult {
        if seqs.is_empty() {
            return StepResult::default();
        }
        // Batched prefill of mixed lengths: model as max-length batch
        // (padding, the common production compromise).
        let max_len = seqs.iter().map(|&(_, l)| l).max().unwrap();
        let bd = perfmodel::prefill(self.model, &self.cfg, seqs.len(), max_len);
        StepResult { seconds: bd.seconds, watts: bd.watts, flops: bd.flops }
    }

    fn decode(&mut self, seqs: &[(SeqId, usize)]) -> StepResult {
        if seqs.is_empty() {
            return StepResult::default();
        }
        // Per-sequence contexts enter Eq. 6 via the average (linears
        // depend only on b; attention on sum of s_i).
        let avg: usize =
            seqs.iter().map(|&(_, l)| l).sum::<usize>() / seqs.len();
        let bd = perfmodel::decode_step(self.model, &self.cfg, seqs.len(), avg.max(1));
        StepResult { seconds: bd.seconds, watts: bd.watts, flops: bd.flops }
    }

    fn describe(&self) -> String {
        format!(
            "sim:{}:{}:{}",
            self.cfg.device.name(),
            self.model.name,
            self.cfg.precision.name()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::perfmodel::PrecisionMode;
    use crate::hwsim::spec::Device;
    use crate::workload::llama::by_name;

    fn backend() -> SimBackend {
        SimBackend::new(
            by_name("llama-8b").unwrap(),
            StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()),
        )
    }

    #[test]
    fn empty_steps_are_free() {
        let mut b = backend();
        assert_eq!(b.prefill(&[]).seconds, 0.0);
        assert_eq!(b.decode(&[]).seconds, 0.0);
    }

    #[test]
    fn decode_scales_with_batch() {
        let mut b = backend();
        let one = b.decode(&[(0, 1024)]);
        let many: Vec<(SeqId, usize)> = (0..64).map(|i| (i, 1024)).collect();
        let batch = b.decode(&many);
        // 64x the tokens for far less than 64x the time: batching works.
        assert!(batch.seconds < one.seconds * 16.0,
                "one {} batch {}", one.seconds, batch.seconds);
    }

    #[test]
    fn prefill_cost_grows_with_length() {
        let mut b = backend();
        let short = b.prefill(&[(0, 128)]);
        let long = b.prefill(&[(0, 4096)]);
        assert!(long.seconds > short.seconds * 4.0);
        assert!(long.flops > short.flops * 10.0);
    }

    #[test]
    fn describe_names_setup() {
        assert_eq!(backend().describe(), "sim:Gaudi2:llama-8b:fp8-static");
    }
}
