//! `fp8-tco` CLI — entrypoints for the paper's experiments.
//!
//! Subcommands (no clap in the vendored set; hand-rolled parsing):
//!   tco-grid            reproduce Fig. 1
//!   gemm  M K N         time a GEMM on both simulated devices
//!   decode MODEL B S    decode-step analysis on both devices
//!   serve               smoke-run the sim serving engine
//!   info                artifact + device summary

use fp8_tco::analysis::perfmodel::{decode_step, PrecisionMode, StepConfig};
use fp8_tco::hwsim::gemm::{gemm_time, GemmConfig};
use fp8_tco::hwsim::spec::{Accum, Device, Scaling};
#[cfg(feature = "pjrt")]
use fp8_tco::runtime::ArtifactDir;
use fp8_tco::tco;
use fp8_tco::util::table::{f, Table};
use fp8_tco::workload::llama;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "tco-grid" => tco_grid(),
        "gemm" => gemm_cmd(&args[1..]),
        "decode" => decode_cmd(&args[1..]),
        "serve" => serve_cmd(),
        "info" => info_cmd(),
        _ => help(),
    }
}

fn help() {
    println!(
        "fp8-tco — datacenter TCO for LLM inference with FP8 (paper reproduction)\n\
         usage:\n\
         \x20 fp8-tco tco-grid              # Fig. 1 TCO comparison table\n\
         \x20 fp8-tco gemm M K N            # GEMM timing on simulated H100/Gaudi2\n\
         \x20 fp8-tco decode MODEL B S      # decode-step breakdown (e.g. llama-8b 64 1024)\n\
         \x20 fp8-tco serve                 # smoke-run the sim serving engine\n\
         \x20 fp8-tco info                  # devices + artifacts summary"
    );
}

fn tco_grid() {
    let mut t = Table::new(
        "Fig. 1 — TCO ratio (A/B), C_S = C_I, R_IC = 1",
        &["R_Th \\ R_SC", "1.00", "0.90", "0.80", "0.70", "0.60", "0.50",
          "0.40", "0.30", "0.20", "0.10"],
    );
    let grid = tco::fig1_grid();
    for chunk in grid.chunks(10) {
        let mut row = vec![format!("{:.2}", chunk[0].0)];
        row.extend(chunk.iter().map(|&(_, _, r)| f(r, 2)));
        t.row(row);
    }
    t.print();
}

fn gemm_cmd(args: &[String]) {
    let dims: Vec<usize> = args.iter().filter_map(|a| a.parse().ok()).collect();
    let (m, k, n) = match dims.as_slice() {
        [m, k, n] => (*m, *k, *n),
        _ => (64, 4096, 4096),
    };
    let mut t = Table::new(
        &format!("GEMM ({m},{k},{n}) on the simulated testbed"),
        &["device", "config", "TFLOPS", "MFU", "bound", "time (us)"],
    );
    for dev in [Device::Gaudi2, Device::H100] {
        for (name, cfg) in [
            ("bf16", GemmConfig::bf16()),
            ("fp8 row", GemmConfig::fp8(Scaling::PerRow,
                if dev == Device::H100 { Accum::Fast } else { Accum::Fp32 })),
            ("fp8 tensor", GemmConfig::fp8(Scaling::PerTensor,
                if dev == Device::H100 { Accum::Fast } else { Accum::Fp32 })),
        ] {
            let bd = gemm_time(dev, m, k, n, cfg);
            t.row(vec![
                dev.name().into(),
                name.into(),
                f(bd.tflops(), 1),
                f(bd.mfu * 100.0, 1),
                bd.bound_by().into(),
                f(bd.seconds * 1e6, 2),
            ]);
        }
    }
    t.print();
}

fn decode_cmd(args: &[String]) {
    let model = args
        .first()
        .and_then(|a| llama::by_name(a))
        .unwrap_or_else(|| llama::by_name("llama-8b").unwrap());
    let b: usize = args.get(1).and_then(|a| a.parse().ok()).unwrap_or(64);
    let s: usize = args.get(2).and_then(|a| a.parse().ok()).unwrap_or(1024);
    let mut t = Table::new(
        &format!("decode step: {} b={b} s={s}", model.name),
        &["device", "precision", "step ms", "tok/s", "TFLOPS", "W",
          "linears ms", "kv ms", "softmax ms", "head ms"],
    );
    for dev in [Device::Gaudi2, Device::H100] {
        for prec in [PrecisionMode::Bf16, PrecisionMode::fp8_static(),
                     PrecisionMode::fp8_dynamic()] {
            let bd = decode_step(model, &StepConfig::new(dev, prec), b, s);
            t.row(vec![
                dev.name().into(),
                prec.name().into(),
                f(bd.seconds * 1e3, 3),
                f(b as f64 / bd.seconds, 0),
                f(bd.tflops(), 1),
                f(bd.watts, 0),
                f(bd.t_linears_s * 1e3, 3),
                f(bd.t_attention_kv_s * 1e3, 3),
                f(bd.t_softmax_s * 1e3, 3),
                f(bd.t_lm_head_s * 1e3, 3),
            ]);
        }
    }
    t.print();
}

fn serve_cmd() {
    use fp8_tco::coordinator::{Engine, EngineConfig, ExecutionBackend, KvCacheConfig, SimBackend};
    use fp8_tco::workload::trace::{TraceConfig, TraceGenerator};

    let model = llama::by_name("llama-8b").unwrap();
    let kv = KvCacheConfig::from_device(model, 96e9, 1.0, 2.0, 16, 0.05);
    let backend = SimBackend::new(
        model,
        StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()),
    );
    let mut engine = Engine::new(EngineConfig::new(kv), backend);
    let mut gen = TraceGenerator::new(TraceConfig::chat(4.0), 7);
    for r in gen.take(200) {
        engine.submit(&r);
    }
    let drained = engine.run_to_completion(1_000_000);
    println!("backend: {}", engine.backend.describe());
    println!("drained: {drained}, preemptions: {}", engine.preemptions());
    println!("{}", engine.metrics.report());
}

fn info_cmd() {
    let mut t = Table::new(
        "simulated devices",
        &["device", "peak FP8 T", "peak BF16 T", "HBM TB/s", "TDP W", "SFU"],
    );
    for dev in Device::ALL {
        let s = dev.spec();
        t.row(vec![
            dev.name().into(),
            f(s.peak_fp8 / 1e12, 0),
            f(s.peak_bf16 / 1e12, 0),
            f(s.hbm_bw / 1e12, 2),
            f(s.tdp, 0),
            if s.has_sfu { "yes".into() } else { "no".into() },
        ]);
    }
    t.print();

    #[cfg(feature = "pjrt")]
    {
        let dir = ArtifactDir::discover();
        if dir.exists() {
            match dir.meta("1b") {
                Ok(meta) => println!(
                    "artifacts: {} (tier {} h={} l={} vocab={} max_seq={})",
                    dir.root.display(), meta.tier, meta.hidden, meta.layers,
                    meta.vocab, meta.max_seq
                ),
                Err(e) => println!("artifacts present but unreadable: {e}"),
            }
        } else {
            println!("artifacts: not built (run `make artifacts`)");
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("artifacts: PJRT runtime not compiled in (build with --features pjrt)");
}
