//! Synthetic request-trace generation (substitute for production
//! traffic, DESIGN.md substitution table).
//!
//! Poisson arrivals; prompt and output lengths drawn from log-normal
//! mixes. The `reasoning` mix models the paper's §1/§5.4 motivation:
//! test-time-scaling models generating thousands of output tokens.

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Arrival time (s).
    pub arrival: f64,
    pub prompt_len: usize,
    pub output_len: usize,
}

#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Mean arrival rate (requests/s).
    pub rate: f64,
    /// Log-normal (mu, sigma) of prompt lengths.
    pub prompt_mu: f64,
    pub prompt_sigma: f64,
    /// Log-normal (mu, sigma) of output lengths.
    pub output_mu: f64,
    pub output_sigma: f64,
    /// Hard clamps.
    pub max_prompt: usize,
    pub max_output: usize,
}

impl TraceConfig {
    /// Chat-style traffic: short prompts, modest outputs.
    pub fn chat(rate: f64) -> Self {
        TraceConfig {
            rate,
            prompt_mu: 5.5,    // median ~245 tokens
            prompt_sigma: 0.8,
            output_mu: 5.0,    // median ~148 tokens
            output_sigma: 0.7,
            max_prompt: 4096,
            max_output: 2048,
        }
    }

    /// Reasoning-style traffic (§1): long autoregressive outputs.
    pub fn reasoning(rate: f64) -> Self {
        TraceConfig {
            rate,
            prompt_mu: 5.5,
            prompt_sigma: 0.8,
            output_mu: 7.6,    // median ~2000 tokens
            output_sigma: 0.6,
            max_prompt: 4096,
            max_output: 16384,
        }
    }

    /// Summarization-style: long prompts, short outputs (prefill-heavy).
    pub fn summarize(rate: f64) -> Self {
        TraceConfig {
            rate,
            prompt_mu: 7.8,    // median ~2440
            prompt_sigma: 0.5,
            output_mu: 4.2,
            output_sigma: 0.5,
            max_prompt: 16384,
            max_output: 1024,
        }
    }
}

pub struct TraceGenerator {
    cfg: TraceConfig,
    rng: Rng,
    clock: f64,
    next_id: u64,
}

impl TraceGenerator {
    pub fn new(cfg: TraceConfig, seed: u64) -> Self {
        TraceGenerator { cfg, rng: Rng::new(seed), clock: 0.0, next_id: 0 }
    }

    pub fn next_request(&mut self) -> Request {
        self.clock += self.rng.exp(self.cfg.rate);
        let prompt_len = (self.rng.lognormal(self.cfg.prompt_mu, self.cfg.prompt_sigma)
            as usize)
            .clamp(1, self.cfg.max_prompt);
        let output_len = (self.rng.lognormal(self.cfg.output_mu, self.cfg.output_sigma)
            as usize)
            .clamp(1, self.cfg.max_output);
        let id = self.next_id;
        self.next_id += 1;
        Request { id, arrival: self.clock, prompt_len, output_len }
    }

    pub fn take(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next_request()).collect()
    }

    /// Consume the generator as a bounded arrival *stream* of `n`
    /// requests — the lazy form the cluster event loop merges with
    /// engine completions (requests materialize one at a time, at
    /// their true arrival timestamps).
    pub fn stream(self, n: usize) -> std::iter::Take<TraceGenerator> {
        <Self as Iterator>::take(self, n)
    }
}

/// The generator is an (infinite) arrival stream; bound it with
/// [`TraceGenerator::stream`] or `Iterator` adapters. NOTE: the
/// inherent [`TraceGenerator::take`] (eager `Vec`) shadows
/// `Iterator::take` on method-call syntax.
impl Iterator for TraceGenerator {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        Some(self.next_request())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_monotone_and_poisson_rate() {
        let mut g = TraceGenerator::new(TraceConfig::chat(10.0), 1);
        let reqs = g.take(5000);
        for w in reqs.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        let span = reqs.last().unwrap().arrival;
        let rate = reqs.len() as f64 / span;
        assert!((rate - 10.0).abs() < 1.0, "rate {rate}");
    }

    #[test]
    fn ids_unique_and_dense() {
        let mut g = TraceGenerator::new(TraceConfig::chat(1.0), 2);
        let reqs = g.take(100);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn lengths_respect_clamps() {
        let mut g = TraceGenerator::new(TraceConfig::reasoning(1.0), 3);
        for r in g.take(2000) {
            assert!(r.prompt_len >= 1 && r.prompt_len <= 4096);
            assert!(r.output_len >= 1 && r.output_len <= 16384);
        }
    }

    #[test]
    fn reasoning_mix_decodes_longer_than_chat() {
        let mean = |cfg: TraceConfig| {
            let mut g = TraceGenerator::new(cfg, 4);
            g.take(3000).iter().map(|r| r.output_len as f64).sum::<f64>() / 3000.0
        };
        let chat = mean(TraceConfig::chat(1.0));
        let reasoning = mean(TraceConfig::reasoning(1.0));
        assert!(reasoning > chat * 5.0, "chat {chat} reasoning {reasoning}");
    }

    #[test]
    fn summarize_is_prefill_heavy() {
        let mut g = TraceGenerator::new(TraceConfig::summarize(1.0), 5);
        let reqs = g.take(2000);
        let p: f64 = reqs.iter().map(|r| r.prompt_len as f64).sum();
        let o: f64 = reqs.iter().map(|r| r.output_len as f64).sum();
        assert!(p > o * 5.0, "prompt {p} output {o}");
    }

    #[test]
    fn stream_matches_eager_take() {
        let eager = TraceGenerator::new(TraceConfig::chat(5.0), 21).take(50);
        let lazy: Vec<Request> =
            TraceGenerator::new(TraceConfig::chat(5.0), 21).stream(50).collect();
        assert_eq!(lazy.len(), 50);
        for (a, b) in eager.iter().zip(&lazy) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.prompt_len, b.prompt_len);
            assert_eq!(a.output_len, b.output_len);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let mut a = TraceGenerator::new(TraceConfig::chat(5.0), 42);
        let mut b = TraceGenerator::new(TraceConfig::chat(5.0), 42);
        for _ in 0..100 {
            let (ra, rb) = (a.next_request(), b.next_request());
            assert_eq!(ra.prompt_len, rb.prompt_len);
            assert_eq!(ra.output_len, rb.output_len);
            assert_eq!(ra.arrival, rb.arrival);
        }
    }
}
