//! Synthetic request-trace generation (substitute for production
//! traffic, DESIGN.md substitution table).
//!
//! Poisson arrivals; prompt and output lengths drawn from log-normal
//! mixes. The `reasoning` mix models the paper's §1/§5.4 motivation:
//! test-time-scaling models generating thousands of output tokens.
//!
//! Beyond the stationary [`TraceGenerator`], the non-stationary layer
//! (DESIGN.md §12) models what "millions of users" actually send:
//! [`RateCurve`] is a piecewise-linear diurnal rate profile driving a
//! time-varying Poisson process by thinning, [`ArrivalProcess::Mmpp`]
//! is a 2-state Markov-modulated Poisson process for bursty
//! (overdispersed) traffic, and [`TrafficGenerator`] stamps every
//! request with a [`TenantClass`] (interactive vs batch, each with its
//! own length mix) for priority scheduling downstream.

use crate::util::rng::Rng;

/// Tenant class of a request: interactive traffic holds the tight
/// latency SLO and schedules ahead of batch (offline/bulk) traffic,
/// which tolerates queueing up to an aging bound
/// (`BatcherConfig::batch_aging_s`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum TenantClass {
    #[default]
    Interactive,
    Batch,
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Arrival time (s).
    pub arrival: f64,
    pub prompt_len: usize,
    pub output_len: usize,
    /// Tenant class (scheduling priority + per-class SLO).
    pub class: TenantClass,
}

#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Mean arrival rate (requests/s).
    pub rate: f64,
    /// Log-normal (mu, sigma) of prompt lengths.
    pub prompt_mu: f64,
    pub prompt_sigma: f64,
    /// Log-normal (mu, sigma) of output lengths.
    pub output_mu: f64,
    pub output_sigma: f64,
    /// Hard clamps.
    pub max_prompt: usize,
    pub max_output: usize,
}

impl TraceConfig {
    /// Chat-style traffic: short prompts, modest outputs.
    pub fn chat(rate: f64) -> Self {
        TraceConfig {
            rate,
            prompt_mu: 5.5,    // median ~245 tokens
            prompt_sigma: 0.8,
            output_mu: 5.0,    // median ~148 tokens
            output_sigma: 0.7,
            max_prompt: 4096,
            max_output: 2048,
        }
    }

    /// Reasoning-style traffic (§1): long autoregressive outputs.
    pub fn reasoning(rate: f64) -> Self {
        TraceConfig {
            rate,
            prompt_mu: 5.5,
            prompt_sigma: 0.8,
            output_mu: 7.6,    // median ~2000 tokens
            output_sigma: 0.6,
            max_prompt: 4096,
            max_output: 16384,
        }
    }

    /// Summarization-style: long prompts, short outputs (prefill-heavy).
    pub fn summarize(rate: f64) -> Self {
        TraceConfig {
            rate,
            prompt_mu: 7.8,    // median ~2440
            prompt_sigma: 0.5,
            output_mu: 4.2,
            output_sigma: 0.5,
            max_prompt: 16384,
            max_output: 1024,
        }
    }
}

pub struct TraceGenerator {
    cfg: TraceConfig,
    rng: Rng,
    clock: f64,
    next_id: u64,
}

impl TraceGenerator {
    pub fn new(cfg: TraceConfig, seed: u64) -> Self {
        TraceGenerator { cfg, rng: Rng::new(seed), clock: 0.0, next_id: 0 }
    }

    pub fn next_request(&mut self) -> Request {
        self.clock += self.rng.exp(self.cfg.rate);
        let prompt_len = (self.rng.lognormal(self.cfg.prompt_mu, self.cfg.prompt_sigma)
            as usize)
            .clamp(1, self.cfg.max_prompt);
        let output_len = (self.rng.lognormal(self.cfg.output_mu, self.cfg.output_sigma)
            as usize)
            .clamp(1, self.cfg.max_output);
        let id = self.next_id;
        self.next_id += 1;
        Request {
            id,
            arrival: self.clock,
            prompt_len,
            output_len,
            class: TenantClass::Interactive,
        }
    }

    pub fn take(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next_request()).collect()
    }

    /// Consume the generator as a bounded arrival *stream* of `n`
    /// requests — the lazy form the cluster event loop merges with
    /// engine completions (requests materialize one at a time, at
    /// their true arrival timestamps).
    pub fn stream(self, n: usize) -> std::iter::Take<TraceGenerator> {
        <Self as Iterator>::take(self, n)
    }
}

/// The generator is an (infinite) arrival stream; bound it with
/// [`TraceGenerator::stream`] or `Iterator` adapters. NOTE: the
/// inherent [`TraceGenerator::take`] (eager `Vec`) shadows
/// `Iterator::take` on method-call syntax.
impl Iterator for TraceGenerator {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        Some(self.next_request())
    }
}

/// Piecewise-linear arrival-rate profile: `(time_s, rate_qps)` knots,
/// linearly interpolated between knots and held flat outside them.
/// The diurnal shape and the thinning envelope both live here, and
/// [`RateCurve::expected_arrivals`] is the exact integral the
/// rate-conservation tests check empirical traces against.
#[derive(Debug, Clone)]
pub struct RateCurve {
    /// (time_s, rate_qps), strictly increasing in time, rates >= 0.
    knots: Vec<(f64, f64)>,
}

impl RateCurve {
    pub fn new(knots: Vec<(f64, f64)>) -> Self {
        assert!(!knots.is_empty(), "rate curve needs at least one knot");
        for w in knots.windows(2) {
            assert!(w[1].0 > w[0].0, "knot times must strictly increase");
        }
        assert!(knots.iter().all(|&(_, r)| r >= 0.0), "rates must be >= 0");
        assert!(knots.iter().any(|&(_, r)| r > 0.0), "curve must be positive somewhere");
        RateCurve { knots }
    }

    /// Constant rate (the stationary limit: thinning accepts every
    /// candidate and the generator reduces to plain Poisson).
    pub fn flat(rate_qps: f64) -> Self {
        RateCurve::new(vec![(0.0, rate_qps)])
    }

    /// A smooth day: hourly knots on a raised cosine with the trough
    /// (`base_qps`) at 04:00 and the peak (`peak_qps`) twelve hours
    /// later — the canonical diurnal shape the autoscaler bench runs.
    pub fn diurnal(day_s: f64, base_qps: f64, peak_qps: f64) -> Self {
        assert!(day_s > 0.0 && base_qps >= 0.0 && peak_qps >= base_qps);
        let knots = (0..=24)
            .map(|h| {
                let t_s = day_s * h as f64 / 24.0;
                let phase = 2.0 * std::f64::consts::PI * (h as f64 - 4.0) / 24.0;
                let w = 0.5 * (1.0 - phase.cos());
                (t_s, base_qps + (peak_qps - base_qps) * w)
            })
            .collect();
        RateCurve::new(knots)
    }

    /// Instantaneous rate at `t_s` (requests/s).
    pub fn rate_at(&self, t_s: f64) -> f64 {
        let k = &self.knots;
        if t_s <= k[0].0 {
            return k[0].1;
        }
        if t_s >= k[k.len() - 1].0 {
            return k[k.len() - 1].1;
        }
        let i = k.partition_point(|&(t, _)| t <= t_s);
        let (t0, r0) = k[i - 1];
        let (t1, r1) = k[i];
        r0 + (r1 - r0) * (t_s - t0) / (t1 - t0)
    }

    /// Maximum rate over the whole curve — the thinning envelope
    /// (piecewise-linear curves peak at a knot).
    pub fn peak_qps(&self) -> f64 {
        self.knots.iter().map(|&(_, r)| r).fold(0.0, f64::max)
    }

    /// True when the curve is identically zero at and after `t_s` —
    /// the flat-zero tail on which thinning could never accept
    /// another candidate. Exact for piecewise-linear curves: with
    /// rates clamped >= 0, `rate_at(t_s) == 0` plus all-zero knots
    /// past `t_s` forces every later segment to zero (a positive
    /// interior value would need a negative knot).
    pub fn is_zero_after(&self, t_s: f64) -> bool {
        self.rate_at(t_s) == 0.0 && self.knots.iter().all(|&(t, r)| t <= t_s || r == 0.0)
    }

    /// Exact expected arrival count over [t0_s, t1_s] (trapezoid rule
    /// is exact on a piecewise-linear integrand).
    pub fn expected_arrivals(&self, t0_s: f64, t1_s: f64) -> f64 {
        if t1_s <= t0_s {
            return 0.0;
        }
        // Integration nodes: the window ends plus every interior knot.
        let mut ts = vec![t0_s];
        for &(t, _) in &self.knots {
            if t > t0_s && t < t1_s {
                ts.push(t);
            }
        }
        ts.push(t1_s);
        let mut total = 0.0;
        for w in ts.windows(2) {
            total += 0.5 * (self.rate_at(w[0]) + self.rate_at(w[1])) * (w[1] - w[0]);
        }
        total
    }
}

/// How arrivals are spread over time (lengths and tenant mix are
/// orthogonal — see [`TrafficConfig`]).
#[derive(Debug, Clone)]
pub enum ArrivalProcess {
    /// Time-varying Poisson process with intensity [`RateCurve`],
    /// realized by thinning a homogeneous process at the curve's peak.
    Modulated(RateCurve),
    /// 2-state Markov-modulated Poisson process: exponential sojourns
    /// alternate between a baseline state and a burst state, each with
    /// its own Poisson rate — the classic bursty/overdispersed model
    /// (index of dispersion > 1 at every timescale above the sojourn).
    Mmpp {
        base_qps: f64,
        burst_qps: f64,
        /// Mean sojourn in the baseline state (s).
        mean_base_s: f64,
        /// Mean sojourn in the burst state (s).
        mean_burst_s: f64,
    },
}

impl ArrivalProcess {
    /// Long-run mean arrival rate (requests/s): the curve's day
    /// average for `Modulated` (taken over the knot span, which the
    /// flat extension preserves beyond it), the sojourn-weighted state
    /// mix for `Mmpp`.
    pub fn mean_qps(&self) -> f64 {
        match self {
            ArrivalProcess::Modulated(curve) => {
                let (t0, t1) =
                    (curve.knots[0].0, curve.knots[curve.knots.len() - 1].0);
                if t1 > t0 {
                    curve.expected_arrivals(t0, t1) / (t1 - t0)
                } else {
                    curve.rate_at(t0)
                }
            }
            ArrivalProcess::Mmpp { base_qps, burst_qps, mean_base_s, mean_burst_s } => {
                (base_qps * mean_base_s + burst_qps * mean_burst_s)
                    / (mean_base_s + mean_burst_s)
            }
        }
    }
}

/// Non-stationary, multi-tenant traffic: an [`ArrivalProcess`] spreads
/// arrivals over the day, and each arrival is stamped
/// interactive-or-batch with its class's own length mix. The `rate`
/// field of the per-class [`TraceConfig`]s is ignored — the arrival
/// process owns timing.
#[derive(Debug, Clone)]
pub struct TrafficConfig {
    pub arrivals: ArrivalProcess,
    /// Interactive-class length mix.
    pub interactive: TraceConfig,
    /// Batch-class length mix.
    pub batch: TraceConfig,
    /// Probability an arrival is batch-class (0 = single-tenant).
    pub batch_frac: f64,
}

impl TrafficConfig {
    /// Single-tenant chat traffic on an arbitrary arrival process.
    pub fn chat_on(arrivals: ArrivalProcess) -> Self {
        TrafficConfig {
            arrivals,
            interactive: TraceConfig::chat(0.0),
            batch: TraceConfig::summarize(0.0),
            batch_frac: 0.0,
        }
    }

    /// The production mix the diurnal bench prices: chat-shaped
    /// interactive traffic beside summarize-shaped batch jobs.
    pub fn multi_tenant(arrivals: ArrivalProcess, batch_frac: f64) -> Self {
        assert!((0.0..=1.0).contains(&batch_frac));
        TrafficConfig {
            arrivals,
            interactive: TraceConfig::chat(0.0),
            batch: TraceConfig::summarize(0.0),
            batch_frac,
        }
    }
}

/// Generator over a [`TrafficConfig`] — the non-stationary sibling of
/// [`TraceGenerator`], with the same deterministic-by-seed contract
/// and the same lazy-stream interface. Draw order per request is
/// fixed (arrival candidates, then class, then lengths), so traces
/// are reproducible byte-for-byte for a fixed (config, seed).
pub struct TrafficGenerator {
    cfg: TrafficConfig,
    rng: Rng,
    clock: f64,
    next_id: u64,
    /// MMPP state: true while in the burst state.
    bursting: bool,
    /// MMPP: when the current sojourn ends.
    state_end: f64,
}

impl TrafficGenerator {
    pub fn new(cfg: TrafficConfig, seed: u64) -> Self {
        if let ArrivalProcess::Modulated(curve) = &cfg.arrivals {
            assert!(curve.peak_qps() > 0.0, "thinning needs a positive envelope");
        }
        TrafficGenerator {
            cfg,
            rng: Rng::new(seed),
            clock: 0.0,
            next_id: 0,
            bursting: false,
            state_end: 0.0,
        }
    }

    /// Next arrival instant under the configured process.
    fn next_arrival(&mut self) -> f64 {
        match &self.cfg.arrivals {
            ArrivalProcess::Modulated(curve) => {
                // Lewis-Shedler thinning: candidates from a homogeneous
                // Poisson at the envelope (peak) rate, accepted with
                // probability rate(t)/peak. Exact for any bounded
                // intensity; rejected candidates only advance the clock.
                let peak = curve.peak_qps();
                loop {
                    // A flat-zero tail can never accept a candidate:
                    // park the arrival at +inf instead of spinning.
                    // The check consumes no randomness, so any trace
                    // with positive rate ahead is byte-identical to
                    // the unguarded loop.
                    if curve.is_zero_after(self.clock) {
                        self.clock = f64::INFINITY;
                        return self.clock;
                    }
                    self.clock += self.rng.exp(peak);
                    let accept_p = curve.rate_at(self.clock) / peak;
                    if self.rng.bool(accept_p) {
                        return self.clock;
                    }
                }
            }
            ArrivalProcess::Mmpp { base_qps, burst_qps, mean_base_s, mean_burst_s } => {
                let (base_qps, burst_qps) = (*base_qps, *burst_qps);
                let (mean_base_s, mean_burst_s) = (*mean_base_s, *mean_burst_s);
                loop {
                    if self.clock >= self.state_end {
                        // Sojourn over: flip state, draw the next one.
                        // (Also the t=0 entry: start in baseline.)
                        if self.state_end > 0.0 {
                            self.bursting = !self.bursting;
                        }
                        let mean_s =
                            if self.bursting { mean_burst_s } else { mean_base_s };
                        self.state_end = self.clock + self.rng.exp(1.0 / mean_s);
                    }
                    let rate = if self.bursting { burst_qps } else { base_qps };
                    let dt_s = if rate > 0.0 { self.rng.exp(rate) } else { f64::INFINITY };
                    if self.clock + dt_s <= self.state_end {
                        self.clock += dt_s;
                        return self.clock;
                    }
                    // Candidate falls past the sojourn boundary:
                    // discard and redraw in the next state — exact by
                    // the exponential's memorylessness.
                    self.clock = self.state_end;
                }
            }
        }
    }

    pub fn next_request(&mut self) -> Request {
        let arrival = self.next_arrival();
        let class = if self.rng.bool(self.cfg.batch_frac) {
            TenantClass::Batch
        } else {
            TenantClass::Interactive
        };
        let mix = match class {
            TenantClass::Interactive => &self.cfg.interactive,
            TenantClass::Batch => &self.cfg.batch,
        };
        let prompt_len =
            (self.rng.lognormal(mix.prompt_mu, mix.prompt_sigma) as usize)
                .clamp(1, mix.max_prompt);
        let output_len =
            (self.rng.lognormal(mix.output_mu, mix.output_sigma) as usize)
                .clamp(1, mix.max_output);
        let id = self.next_id;
        self.next_id += 1;
        Request { id, arrival, prompt_len, output_len, class }
    }

    pub fn take(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next_request()).collect()
    }

    /// Bounded lazy arrival stream (see [`TraceGenerator::stream`]).
    pub fn stream(self, n: usize) -> std::iter::Take<TrafficGenerator> {
        <Self as Iterator>::take(self, n)
    }

    /// Every request arriving before `horizon_s` — the natural bound
    /// for day-length traces, where the request *count* is a random
    /// variable but the day is not.
    pub fn until(mut self, horizon_s: f64) -> Vec<Request> {
        let mut out = Vec::new();
        loop {
            let r = self.next_request();
            if r.arrival >= horizon_s {
                return out;
            }
            out.push(r);
        }
    }
}

impl Iterator for TrafficGenerator {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        Some(self.next_request())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_monotone_and_poisson_rate() {
        let mut g = TraceGenerator::new(TraceConfig::chat(10.0), 1);
        let reqs = g.take(5000);
        for w in reqs.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        let span = reqs.last().unwrap().arrival;
        let rate = reqs.len() as f64 / span;
        assert!((rate - 10.0).abs() < 1.0, "rate {rate}");
    }

    #[test]
    fn ids_unique_and_dense() {
        let mut g = TraceGenerator::new(TraceConfig::chat(1.0), 2);
        let reqs = g.take(100);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn lengths_respect_clamps() {
        let mut g = TraceGenerator::new(TraceConfig::reasoning(1.0), 3);
        for r in g.take(2000) {
            assert!(r.prompt_len >= 1 && r.prompt_len <= 4096);
            assert!(r.output_len >= 1 && r.output_len <= 16384);
        }
    }

    #[test]
    fn reasoning_mix_decodes_longer_than_chat() {
        let mean = |cfg: TraceConfig| {
            let mut g = TraceGenerator::new(cfg, 4);
            g.take(3000).iter().map(|r| r.output_len as f64).sum::<f64>() / 3000.0
        };
        let chat = mean(TraceConfig::chat(1.0));
        let reasoning = mean(TraceConfig::reasoning(1.0));
        assert!(reasoning > chat * 5.0, "chat {chat} reasoning {reasoning}");
    }

    #[test]
    fn summarize_is_prefill_heavy() {
        let mut g = TraceGenerator::new(TraceConfig::summarize(1.0), 5);
        let reqs = g.take(2000);
        let p: f64 = reqs.iter().map(|r| r.prompt_len as f64).sum();
        let o: f64 = reqs.iter().map(|r| r.output_len as f64).sum();
        assert!(p > o * 5.0, "prompt {p} output {o}");
    }

    #[test]
    fn stream_matches_eager_take() {
        let eager = TraceGenerator::new(TraceConfig::chat(5.0), 21).take(50);
        let lazy: Vec<Request> =
            TraceGenerator::new(TraceConfig::chat(5.0), 21).stream(50).collect();
        assert_eq!(lazy.len(), 50);
        for (a, b) in eager.iter().zip(&lazy) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.prompt_len, b.prompt_len);
            assert_eq!(a.output_len, b.output_len);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let mut a = TraceGenerator::new(TraceConfig::chat(5.0), 42);
        let mut b = TraceGenerator::new(TraceConfig::chat(5.0), 42);
        for _ in 0..100 {
            let (ra, rb) = (a.next_request(), b.next_request());
            assert_eq!(ra.prompt_len, rb.prompt_len);
            assert_eq!(ra.output_len, rb.output_len);
            assert_eq!(ra.arrival, rb.arrival);
        }
    }

    #[test]
    fn trace_stream_rides_only_the_f64_stream() {
        // The seeded-trace byte-identity contract behind the
        // `Rng::range` rewrite: the generator consumes exactly one
        // exp() and two lognormal() draws per request — all on the
        // f64 stream — so an integer-path change cannot perturb it.
        // Replaying those draws on a bare Rng must reproduce the trace
        // to the bit.
        let cfg = TraceConfig::chat(5.0);
        let mut gen = TraceGenerator::new(cfg.clone(), 21);
        let mut rng = Rng::new(21);
        let mut clock = 0.0;
        for _ in 0..200 {
            let r = gen.next_request();
            clock += rng.exp(cfg.rate);
            let p = (rng.lognormal(cfg.prompt_mu, cfg.prompt_sigma) as usize)
                .clamp(1, cfg.max_prompt);
            let o = (rng.lognormal(cfg.output_mu, cfg.output_sigma) as usize)
                .clamp(1, cfg.max_output);
            assert_eq!(r.arrival.to_bits(), clock.to_bits());
            assert_eq!(r.prompt_len, p);
            assert_eq!(r.output_len, o);
            assert_eq!(r.class, TenantClass::Interactive);
        }
    }

    #[test]
    fn rate_curve_interpolates_and_integrates_exactly() {
        let c = RateCurve::new(vec![(0.0, 2.0), (10.0, 6.0), (20.0, 2.0)]);
        assert_eq!(c.rate_at(-5.0), 2.0, "flat before the first knot");
        assert_eq!(c.rate_at(25.0), 2.0, "flat after the last knot");
        assert!((c.rate_at(5.0) - 4.0).abs() < 1e-12);
        assert!((c.rate_at(15.0) - 4.0).abs() < 1e-12);
        assert_eq!(c.peak_qps(), 6.0);
        // Trapezoid over the tent: mean rate 4 over 20 s = 80 arrivals.
        assert!((c.expected_arrivals(0.0, 20.0) - 80.0).abs() < 1e-9);
        // Partial windows, including the flat extensions.
        assert!((c.expected_arrivals(-10.0, 0.0) - 20.0).abs() < 1e-9);
        assert!((c.expected_arrivals(5.0, 15.0) - 50.0).abs() < 1e-9);
        assert_eq!(c.expected_arrivals(7.0, 7.0), 0.0);
    }

    #[test]
    fn diurnal_curve_peaks_twelve_hours_after_trough() {
        let day = 86_400.0;
        let c = RateCurve::diurnal(day, 1.0, 9.0);
        assert!((c.rate_at(day * 4.0 / 24.0) - 1.0).abs() < 1e-9, "trough at 04:00");
        assert!((c.rate_at(day * 16.0 / 24.0) - 9.0).abs() < 1e-9, "peak at 16:00");
        assert_eq!(c.peak_qps(), 9.0);
        // The raised cosine averages to the midpoint over a full day.
        let mean = c.expected_arrivals(0.0, day) / day;
        assert!((mean - 5.0).abs() < 0.05, "day mean {mean}");
    }

    #[test]
    fn flat_modulated_traffic_matches_poisson_rate() {
        let cfg = TrafficConfig::chat_on(ArrivalProcess::Modulated(RateCurve::flat(8.0)));
        let reqs = TrafficGenerator::new(cfg, 3).take(4000);
        for w in reqs.windows(2) {
            assert!(w[1].arrival >= w[0].arrival, "arrivals must be monotone");
        }
        let rate = reqs.len() as f64 / reqs.last().unwrap().arrival;
        assert!((rate - 8.0).abs() < 0.8, "rate {rate}");
    }

    #[test]
    fn traffic_generator_deterministic_by_seed() {
        let cfg = || {
            TrafficConfig::multi_tenant(
                ArrivalProcess::Mmpp {
                    base_qps: 2.0,
                    burst_qps: 20.0,
                    mean_base_s: 30.0,
                    mean_burst_s: 5.0,
                },
                0.3,
            )
        };
        let a = TrafficGenerator::new(cfg(), 17).take(300);
        let b = TrafficGenerator::new(cfg(), 17).take(300);
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.arrival.to_bits(), rb.arrival.to_bits());
            assert_eq!(ra.prompt_len, rb.prompt_len);
            assert_eq!(ra.output_len, rb.output_len);
            assert_eq!(ra.class, rb.class);
        }
        let batch = a.iter().filter(|r| r.class == TenantClass::Batch).count();
        assert!(batch > 0 && batch < a.len(), "both classes present: {batch}");
    }

    #[test]
    fn until_bounds_by_horizon_not_count() {
        let cfg = TrafficConfig::chat_on(ArrivalProcess::Modulated(RateCurve::flat(5.0)));
        let reqs = TrafficGenerator::new(cfg, 9).until(50.0);
        assert!(!reqs.is_empty());
        assert!(reqs.iter().all(|r| r.arrival < 50.0));
        let n = reqs.len() as f64;
        assert!((n - 250.0).abs() < 75.0, "expected ~250 arrivals, got {n}");
    }
}
