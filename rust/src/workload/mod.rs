//! LLM inference workload models.
//!
//! * [`llama`] — the paper's FLOPs accounting (Eqs. 3–6), byte-traffic
//!   model, and a zoo of real Llama v3.x configurations.
//! * [`trace`] — synthetic request-trace generation (Poisson arrivals,
//!   prompt/output length mixes including "reasoning"-style long
//!   decodes) for the serving engine and TCO experiments.

pub mod llama;
pub mod trace;

pub use llama::{LlamaConfig, Phase, MODEL_ZOO};
pub use trace::{Request, TraceConfig, TraceGenerator};
