//! Llama FLOPs/bytes model — paper §5.2, Eqs. 3–6, verbatim.
//!
//! `f_llama(s) = 2 s h² l (3a + 2 + 2/g) + 2 s² h l + 2 v s h`   (Eq. 3)
//!
//! with the model-specific constant `A = 3a + 2 + 2/g` (Eq. 4), the
//! decode-step approximation (Eq. 5) and the batched decode form
//! (Eq. 6). Each term is tagged with the precision it runs at
//! (§5.2: linears FP8; LM head + attention BF16).

/// Inference phase (paper Fig. 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Prefill,
    Decode,
}

/// A Llama-family architecture.
#[derive(Debug, Clone)]
pub struct LlamaConfig {
    pub name: &'static str,
    /// Hidden size h.
    pub hidden: usize,
    /// Transformer blocks l.
    pub layers: usize,
    /// Query heads H.
    pub heads: usize,
    /// KV heads (GQA): g = heads / kv_heads.
    pub kv_heads: usize,
    /// Intermediate size (a·h).
    pub intermediate: usize,
    /// Vocabulary v.
    pub vocab: usize,
    /// Embedding/LM-head weight tying (Llama 3.2 1B/3B tie them).
    pub tied_embeddings: bool,
}

/// Real Llama v3.x configurations (the paper's case studies, §4-5).
pub static MODEL_ZOO: &[LlamaConfig] = &[
    LlamaConfig { name: "llama-1b", hidden: 2048, layers: 16, heads: 32,
                  kv_heads: 8, intermediate: 8192, vocab: 128256,
                  tied_embeddings: true },
    LlamaConfig { name: "llama-3b", hidden: 3072, layers: 28, heads: 24,
                  kv_heads: 8, intermediate: 8192, vocab: 128256,
                  tied_embeddings: true },
    LlamaConfig { name: "llama-8b", hidden: 4096, layers: 32, heads: 32,
                  kv_heads: 8, intermediate: 14336, vocab: 128256,
                  tied_embeddings: false },
    LlamaConfig { name: "llama-70b", hidden: 8192, layers: 80, heads: 64,
                  kv_heads: 8, intermediate: 28672, vocab: 128256,
                  tied_embeddings: false },
];

pub fn by_name(name: &str) -> Option<&'static LlamaConfig> {
    MODEL_ZOO.iter().find(|m| m.name == name)
}

/// The paper's measurement model, statically guaranteed to be in the
/// zoo — hot-path callers use this instead of `by_name(..).unwrap()`.
pub fn llama_8b() -> &'static LlamaConfig {
    by_name("llama-8b").expect("llama-8b is in the model zoo")
}

impl LlamaConfig {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    /// GQA group size g.
    pub fn gqa_groups(&self) -> f64 {
        self.heads as f64 / self.kv_heads as f64
    }

    /// MLP expansion a = intermediate / hidden.
    pub fn mlp_ratio(&self) -> f64 {
        self.intermediate as f64 / self.hidden as f64
    }

    /// The model-specific constant A = 3a + 2 + 2/g (Eq. 4).
    pub fn a_const(&self) -> f64 {
        3.0 * self.mlp_ratio() + 2.0 + 2.0 / self.gqa_groups()
    }

    /// Parameter count (weights only, tied accounting like the paper).
    pub fn param_count(&self) -> f64 {
        let h = self.hidden as f64;
        let kv = (self.kv_heads * self.head_dim()) as f64;
        let per_layer = h * h            // wq
            + 2.0 * h * kv               // wk, wv
            + h * h                      // wo
            + 3.0 * h * self.intermediate as f64; // gate/up/down
        let embed = if self.tied_embeddings { 1.0 } else { 2.0 };
        self.layers as f64 * per_layer + embed * self.vocab as f64 * h
    }

    /// Eq. 3: FLOPs of one full forward pass over sequence length s
    /// (batch 1).
    pub fn prefill_flops(&self, s: usize) -> f64 {
        let (h, l, v) = (self.hidden as f64, self.layers as f64, self.vocab as f64);
        let s = s as f64;
        2.0 * s * h * h * l * self.a_const() + 2.0 * s * s * h * l + 2.0 * v * s * h
    }

    /// Eq. 6: FLOPs of one batched decode step with per-sequence
    /// context lengths.
    pub fn decode_step_flops(&self, context_lens: &[usize]) -> f64 {
        let (h, l, v) = (self.hidden as f64, self.layers as f64, self.vocab as f64);
        let b = context_lens.len() as f64;
        let sum_s: f64 = context_lens.iter().map(|&s| s as f64).sum();
        2.0 * b * (self.a_const() * h * h * l + v * h) + 4.0 * h * l * sum_s
    }

    /// Eq. 6 split by precision (§5.2): (fp8_linear, bf16_head, bf16_attn).
    pub fn decode_step_flops_split(&self, context_lens: &[usize]) -> (f64, f64, f64) {
        let (h, l, v) = (self.hidden as f64, self.layers as f64, self.vocab as f64);
        let b = context_lens.len() as f64;
        let sum_s: f64 = context_lens.iter().map(|&s| s as f64).sum();
        let linear_fp8 = 2.0 * b * self.a_const() * h * h * l;
        let head_bf16 = 2.0 * b * v * h;
        let attn_bf16 = 4.0 * h * l * sum_s;
        (linear_fp8, head_bf16, attn_bf16)
    }

    /// KV-cache bytes for one token (both K and V, all layers).
    pub fn kv_bytes_per_token(&self, dtype_bytes: f64) -> f64 {
        2.0 * (self.layers * self.kv_heads * self.head_dim()) as f64 * dtype_bytes
    }

    /// Weight bytes at the given per-element size.
    pub fn weight_bytes(&self, dtype_bytes: f64) -> f64 {
        self.param_count() * dtype_bytes
    }

    /// Embedding + LM-head parameters (tied accounting like
    /// [`LlamaConfig::param_count`]).
    pub fn embed_param_count(&self) -> f64 {
        let embed = if self.tied_embeddings { 1.0 } else { 2.0 };
        embed * self.vocab as f64 * self.hidden as f64
    }

    /// Weight bytes with the block linears at `block_bytes`/elem and
    /// the embedding/LM head at `embed_bytes`/elem — the paper's §5.2
    /// precision split (FP8 blocks, BF16 head) made resident-footprint
    /// accurate: an "FP8 model" still stores its head in BF16.
    pub fn weight_bytes_mixed(&self, block_bytes: f64, embed_bytes: f64) -> f64 {
        let embed = self.embed_param_count();
        (self.param_count() - embed) * block_bytes + embed * embed_bytes
    }

    /// Computational intensity (FLOP/byte) of one decode step at batch
    /// b, average context s — the §5.2 analysis. Weights stream once
    /// for the whole batch; each sequence reads its own KV cache.
    pub fn decode_ci(&self, b: usize, s: usize, w_bytes: f64, kv_bytes: f64) -> f64 {
        let lens = vec![s; b];
        let flops = self.decode_step_flops(&lens);
        let bytes = self.weight_bytes(w_bytes)
            + b as f64 * s as f64 * self.kv_bytes_per_token(kv_bytes);
        flops / bytes
    }

    /// Eq. 5: incremental FLOPs of generating t tokens at context s.
    pub fn incremental_flops(&self, s: usize, t: usize) -> f64 {
        let (h, l, v) = (self.hidden as f64, self.layers as f64, self.vocab as f64);
        let (s, t) = (s as f64, t as f64);
        2.0 * t * (self.a_const() * h * h * l + v * h) + 4.0 * s * t * h * l
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn llama8b() -> &'static LlamaConfig {
        by_name("llama-8b").unwrap()
    }

    #[test]
    fn zoo_param_counts_sane() {
        // ~1.2B / 3.2B / 8B / 70B within tolerance.
        let counts: Vec<f64> = MODEL_ZOO.iter().map(|m| m.param_count()).collect();
        assert!((counts[0] / 1.2e9 - 1.0).abs() < 0.2, "{}", counts[0]);
        assert!((counts[1] / 3.2e9 - 1.0).abs() < 0.2, "{}", counts[1]);
        assert!((counts[2] / 8.0e9 - 1.0).abs() < 0.15, "{}", counts[2]);
        assert!((counts[3] / 70.0e9 - 1.0).abs() < 0.15, "{}", counts[3]);
    }

    #[test]
    fn a_const_llama8b() {
        // a = 14336/4096 = 3.5, g = 4 -> A = 10.5 + 2 + 0.5 = 13.
        assert!((llama8b().a_const() - 13.0).abs() < 1e-9);
    }

    #[test]
    fn eq3_matches_eq4_simplification() {
        let m = llama8b();
        let (h, l, v) = (m.hidden as f64, m.layers as f64, m.vocab as f64);
        for s in [1usize, 128, 4096] {
            let sf = s as f64;
            let simplified = 2.0 * sf * (m.a_const() * h * h * l + v * h)
                + 2.0 * sf * sf * h * l;
            assert!((m.prefill_flops(s) / simplified - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn eq5_is_finite_difference_of_eq3() {
        // f(s+t) - f(s) ≈ Eq. 5 for t << s.
        let m = llama8b();
        let (s, t) = (4096usize, 1usize);
        let exact = m.prefill_flops(s + t) - m.prefill_flops(s);
        let approx = m.incremental_flops(s, t);
        // Eq. 5 drops the 2t²hl + 2sthl-vs-4sthl curvature terms; at
        // t=1, s=4096 the relative error is tiny.
        assert!((exact / approx - 1.0).abs() < 1e-3,
                "exact {exact} approx {approx}");
    }

    #[test]
    fn eq6_equals_sum_of_eq5_at_t1() {
        let m = llama8b();
        let lens = [100usize, 2000, 4096];
        let batched = m.decode_step_flops(&lens);
        let individual: f64 = lens.iter().map(|&s| m.incremental_flops(s, 1)).sum();
        assert!((batched / individual - 1.0).abs() < 1e-12);
    }

    #[test]
    fn split_sums_to_total() {
        let m = llama8b();
        let lens = vec![1024usize; 64];
        let (a, b, c) = m.decode_step_flops_split(&lens);
        let total = m.decode_step_flops(&lens);
        assert!(((a + b + c) / total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn mixed_weight_bytes_keeps_head_bf16() {
        let m = llama8b();
        // Uniform BF16 is the degenerate case.
        assert_eq!(m.weight_bytes_mixed(2.0, 2.0), m.weight_bytes(2.0));
        // FP8 blocks + BF16 head sit strictly between uniform FP8 and
        // uniform BF16, offset by exactly the embedding params.
        let mixed = m.weight_bytes_mixed(1.0, 2.0);
        assert!((mixed - m.weight_bytes(1.0) - m.embed_param_count()).abs() < 1.0);
        assert!(mixed > m.weight_bytes(1.0) && mixed < m.weight_bytes(2.0));
    }

    #[test]
    fn kv_cache_ci_bounded_by_gqa_groups() {
        // §5.2: "with GQA using g groups, the CI is bounded by g".
        let m = llama8b();
        // Attention flops per step per seq: 4*h*l*s; KV bytes read:
        // s * kv_bytes_per_token(2.0).
        let s = 4096.0;
        let attn_flops = 4.0 * m.hidden as f64 * m.layers as f64 * s;
        let kv_bytes = s * m.kv_bytes_per_token(2.0);
        let ci = attn_flops / kv_bytes;
        assert!((ci - m.gqa_groups()).abs() < 1e-9, "ci {ci}");
    }

    #[test]
    fn gaudi_kv_roofline_is_19_tflops() {
        // §5.2: "g=8"-style bound — for Llama v3 (g=4 in our zoo's
        // 8B... the paper quotes g=8 meaning kv group of 8 queries);
        // the quoted number: 2.4 TB/s x 8 = 19.2 TFLOPS.
        let bw: f64 = 2.4e12;
        let max_tflops = bw * 8.0 / 1e12;
        assert!((max_tflops - 19.2).abs() < 1e-9);
    }

    #[test]
    fn decode_ci_grows_with_batch_saturating() {
        let m = llama8b();
        let ci1 = m.decode_ci(1, 1024, 1.0, 2.0);
        let ci64 = m.decode_ci(64, 1024, 1.0, 2.0);
        assert!(ci64 > ci1 * 10.0, "{ci1} {ci64}");
        // but far below the 360 needed to saturate Gaudi 2 FP8 at
        // longer contexts (the §5.2 point) — KV reads cap it.
        let ci_long = m.decode_ci(64, 8192, 1.0, 2.0);
        assert!(ci_long < 360.0, "{ci_long}");
    }
}
