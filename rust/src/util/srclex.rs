//! Minimal Rust source tokenizer backing `simlint` (the repo's
//! static-analysis pass, `src/simlint.rs`).
//!
//! The lexer is deliberately small: it only needs to be right about
//! the things that make naive grep-based linting wrong — comments
//! (line, doc, nested block), string literals (plain, byte, and raw
//! with arbitrary `#` fencing), char literals vs. lifetimes, and
//! numeric literals with exponents — so that rule text appearing
//! inside a string or a doc comment never fires a finding. Tokens
//! carry their 1-based source line for finding reports and waiver
//! matching.

/// Token class. Comments are kept as tokens (not skipped) because the
/// waiver syntax (`// simlint: allow(...)`) lives in them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `HashMap`, `t_tp_comm_s`, ...).
    Ident,
    /// Single punctuation character (`::` arrives as two `:` tokens,
    /// `->` as `-` then `>`; rules match the pairs).
    Punct,
    /// String / raw-string / byte-string / char / numeric literal.
    Literal,
    /// Line, doc, or (possibly nested) block comment, full text.
    Comment,
}

#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    /// 1-based line of the token's first character.
    pub line: usize,
}

impl Token {
    fn new(kind: TokKind, text: impl Into<String>, line: usize) -> Self {
        Token { kind, text: text.into(), line }
    }
}

/// Tokenize Rust source. Never panics: malformed input (an unclosed
/// string or comment) simply ends the current token at end-of-file,
/// which is the right behavior for a linter that must not crash on
/// the tree it is judging.
pub fn lex(src: &str) -> Vec<Token> {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut toks = Vec::new();
    let mut i = 0;
    let mut line = 1;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment (also covers /// and //! doc comments).
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i;
            while i < n && chars[i] != '\n' {
                i += 1;
            }
            toks.push(Token::new(TokKind::Comment, collect(&chars, start, i), line));
            continue;
        }
        // Block comment, nested per Rust rules.
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let start = i;
            let start_line = line;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            toks.push(Token::new(TokKind::Comment, collect(&chars, start, i), start_line));
            continue;
        }
        // Raw strings r"..." / r#"..."# (and br variants): the body is
        // opaque — rule-looking text inside must never fire.
        if c == 'r' || c == 'b' {
            let mut j = i;
            if chars[j] == 'b' {
                j += 1;
            }
            if j < n && chars[j] == 'r' {
                let mut k = j + 1;
                let mut hashes = 0usize;
                while k < n && chars[k] == '#' {
                    hashes += 1;
                    k += 1;
                }
                if k < n && chars[k] == '"' {
                    let start = i;
                    let start_line = line;
                    k += 1;
                    'scan: while k < n {
                        if chars[k] == '\n' {
                            line += 1;
                            k += 1;
                            continue;
                        }
                        if chars[k] == '"' {
                            let mut h = 0usize;
                            while h < hashes && k + 1 + h < n && chars[k + 1 + h] == '#' {
                                h += 1;
                            }
                            if h == hashes {
                                k += 1 + hashes;
                                break 'scan;
                            }
                        }
                        k += 1;
                    }
                    i = k;
                    toks.push(Token::new(
                        TokKind::Literal,
                        collect(&chars, start, i),
                        start_line,
                    ));
                    continue;
                }
            }
            // Byte string b"...": delegate to the plain-string scanner.
            if c == 'b' && i + 1 < n && chars[i + 1] == '"' {
                let start = i;
                let start_line = line;
                let (ni, nl) = scan_string(&chars, i + 1, line);
                i = ni;
                line = nl;
                toks.push(Token::new(
                    TokKind::Literal,
                    collect(&chars, start, i),
                    start_line,
                ));
                continue;
            }
            // Plain identifier starting with r/b falls through below.
        }
        if c == '"' {
            let start = i;
            let start_line = line;
            let (ni, nl) = scan_string(&chars, i, line);
            i = ni;
            line = nl;
            toks.push(Token::new(TokKind::Literal, collect(&chars, start, i), start_line));
            continue;
        }
        // Char literal vs. lifetime: 'x' and '\n' are literals; 'a in
        // `&'a str` is a lifetime tick followed by an ident.
        if c == '\'' {
            if i + 1 < n && chars[i + 1] == '\\' {
                let start = i;
                let mut k = i + 2;
                while k < n && chars[k] != '\'' {
                    k += 1;
                }
                i = (k + 1).min(n);
                toks.push(Token::new(TokKind::Literal, collect(&chars, start, i), line));
                continue;
            }
            if i + 2 < n && chars[i + 2] == '\'' {
                let start = i;
                i += 3;
                toks.push(Token::new(TokKind::Literal, collect(&chars, start, i), line));
                continue;
            }
            toks.push(Token::new(TokKind::Punct, "'", line));
            i += 1;
            continue;
        }
        if c.is_ascii_digit() {
            let start = i;
            i += 1;
            while i < n {
                let d = chars[i];
                if d.is_ascii_alphanumeric() || d == '_' {
                    i += 1;
                } else if d == '.' && i + 1 < n && chars[i + 1].is_ascii_digit() {
                    // `1.5` continues the number; `0..n` and `1.max(2)`
                    // end it at the dot.
                    i += 1;
                } else if (d == '+' || d == '-')
                    && matches!(chars[i - 1], 'e' | 'E')
                    && i + 1 < n
                    && chars[i + 1].is_ascii_digit()
                {
                    // Exponent sign: 1.5e-6, 2.2e+12.
                    i += 1;
                } else {
                    break;
                }
            }
            toks.push(Token::new(TokKind::Literal, collect(&chars, start, i), line));
            continue;
        }
        if c.is_ascii_alphabetic() || c == '_' {
            let start = i;
            i += 1;
            while i < n && (chars[i].is_ascii_alphanumeric() || chars[i] == '_') {
                i += 1;
            }
            toks.push(Token::new(TokKind::Ident, collect(&chars, start, i), line));
            continue;
        }
        toks.push(Token::new(TokKind::Punct, c, line));
        i += 1;
    }
    toks
}

/// Scan a plain string literal starting at the opening quote `chars[i]`.
/// Returns (index past the closing quote, updated line).
fn scan_string(chars: &[char], i: usize, line: usize) -> (usize, usize) {
    let n = chars.len();
    let mut k = i + 1;
    let mut l = line;
    while k < n {
        match chars[k] {
            '\\' => k += 2,
            '"' => return (k + 1, l),
            '\n' => {
                l += 1;
                k += 1;
            }
            _ => k += 1,
        }
    }
    (n, l)
}

fn collect(chars: &[char], start: usize, end: usize) -> String {
    chars[start..end.min(chars.len())].iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn basic_tokens_and_lines() {
        let toks = lex("fn main() {\n    let x_s = 1.5e-6;\n}\n");
        assert_eq!(toks[0].text, "fn");
        assert_eq!(toks[0].line, 1);
        let x = toks.iter().find(|t| t.text == "x_s").unwrap();
        assert_eq!((x.kind, x.line), (TokKind::Ident, 2));
        let num = toks.iter().find(|t| t.text == "1.5e-6").unwrap();
        assert_eq!(num.kind, TokKind::Literal);
    }

    #[test]
    fn strings_swallow_rule_text() {
        let src = r#"let s = "Instant::now().unwrap()";"#;
        assert_eq!(idents(src), vec!["let", "s"]);
    }

    #[test]
    fn raw_strings_swallow_rule_text() {
        let src = r###"let s = r#"std::time::SystemTime "quoted" panic!()"#;"###;
        assert_eq!(idents(src), vec!["let", "s"]);
        let lit = lex(src)
            .into_iter()
            .find(|t| t.kind == TokKind::Literal)
            .unwrap();
        assert!(lit.text.contains("SystemTime"));
    }

    #[test]
    fn comments_are_tokens_not_idents() {
        let src = "// simlint: allow(panic) -- reason\nfn f() {} /* unwrap() */";
        let toks = lex(src);
        let comments: Vec<_> =
            toks.iter().filter(|t| t.kind == TokKind::Comment).collect();
        assert_eq!(comments.len(), 2);
        assert!(comments[0].text.contains("allow(panic)"));
        assert_eq!(comments[0].line, 1);
        assert!(!idents(src).contains(&"unwrap".to_string()));
    }

    #[test]
    fn nested_block_comments() {
        let src = "/* outer /* inner unwrap() */ still comment */ fn f() {}";
        assert_eq!(idents(src), vec!["fn", "f"]);
    }

    #[test]
    fn doc_comments_swallow_rule_text() {
        let src = "/// calls .unwrap() on Instant\nfn f() {}";
        assert_eq!(idents(src), vec!["fn", "f"]);
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let src = "fn f<'a>(x: &'a str) -> char { '\\n' }";
        let ids = idents(src);
        assert!(ids.contains(&"a".to_string()), "lifetime ident survives");
        let lits: Vec<_> = lex(src)
            .into_iter()
            .filter(|t| t.kind == TokKind::Literal)
            .collect();
        assert_eq!(lits.len(), 1);
        assert_eq!(lits[0].text, "'\\n'");
    }

    #[test]
    fn numbers_do_not_swallow_ranges_or_methods() {
        let src = "for i in 0..n { let y = 1.max(2); let z = 50_000_000; }";
        let texts: Vec<String> = kinds(src).into_iter().map(|(_, t)| t).collect();
        assert!(texts.contains(&"0".to_string()));
        assert!(texts.contains(&"n".to_string()));
        assert!(texts.contains(&"max".to_string()));
        assert!(texts.contains(&"50_000_000".to_string()));
    }

    #[test]
    fn multiline_string_tracks_lines() {
        let src = "let s = \"a\nb\";\nlet t = 1;";
        let t = lex(src).into_iter().find(|tk| tk.text == "t").unwrap();
        assert_eq!(t.line, 3);
    }

    #[test]
    fn unclosed_string_does_not_panic() {
        let toks = lex("let s = \"never closed");
        assert!(toks.iter().any(|t| t.kind == TokKind::Literal));
    }
}
