//! Self-contained utility substrate.
//!
//! The offline vendored crate set has no `serde`/`serde_json`, no
//! `rand`, and no `criterion`, so this module provides the small,
//! fully-tested replacements the rest of the crate builds on:
//! a JSON parser/writer, a seeded PRNG, streaming statistics, an
//! ASCII table printer used by every table/figure regeneration bench,
//! a scoped-thread parallel map ([`par`]) driving the sweep grids, and
//! the minimal Rust tokenizer ([`srclex`]) behind the `simlint`
//! static-analysis pass.

pub mod json;
pub mod par;
pub mod rng;
pub mod srclex;
pub mod stats;
pub mod table;

/// Format a quantity with an SI suffix (`1.23 k`, `4.56 G`, ...).
pub fn si(value: f64) -> String {
    let (v, suffix) = si_parts(value);
    format!("{v:.2} {suffix}")
}

fn si_parts(value: f64) -> (f64, &'static str) {
    let a = value.abs();
    if a >= 1e12 {
        (value / 1e12, "T")
    } else if a >= 1e9 {
        (value / 1e9, "G")
    } else if a >= 1e6 {
        (value / 1e6, "M")
    } else if a >= 1e3 {
        (value / 1e3, "k")
    } else {
        (value, "")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn si_formats() {
        assert_eq!(si(1989.9e12), "1989.90 T");
        assert_eq!(si(2_400.0), "2.40 k");
        assert_eq!(si(0.5), "0.50 ");
        assert_eq!(si(-3.0e9), "-3.00 G");
    }
}
