//! Seeded PRNG (xoshiro256**) — deterministic workload generation and
//! the property-testing harness. No `rand` in the vendored crate set.

/// xoshiro256** by Blackman & Vigna (public domain reference impl).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 seeding, as recommended by the xoshiro authors.
        let mut x = seed.wrapping_add(0x9e3779b97f4a7c15);
        let mut s = [0u64; 4];
        for slot in &mut s {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            *slot = z ^ (z >> 31);
        }
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [lo, hi) (hi > lo).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo, "empty range");
        lo + self.next_u64() % (hi - lo)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with the given rate (inter-arrival times of a
    /// Poisson process).
    pub fn exp(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-12).ln() / rate
    }

    /// Log-normal with the given underlying mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize(0, items.len())]
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.f64() < p_true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            let v = r.range(10, 20);
            assert!((10..20).contains(&v));
        }
    }
}
