//! Seeded PRNG (xoshiro256**) — deterministic workload generation and
//! the property-testing harness. No `rand` in the vendored crate set.

/// xoshiro256** by Blackman & Vigna (public domain reference impl).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 seeding, as recommended by the xoshiro authors.
        let mut x = seed.wrapping_add(0x9e3779b97f4a7c15);
        let mut s = [0u64; 4];
        for slot in &mut s {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            *slot = z ^ (z >> 31);
        }
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [lo, hi) (hi > lo). Unbiased: Lemire's
    /// multiply-shift with rejection — the naive `next_u64() % span`
    /// overweights the low residues whenever `2^64 % span != 0` (for
    /// span 3 the bias is ~2^-63 per value, but for spans near 2^63 it
    /// reaches a full 2x). Rejection happens with probability
    /// `(2^64 mod span) / 2^64` < span/2^64, so small spans almost
    /// never loop. Consumes a variable number of `next_u64` draws;
    /// the f64 stream (exp/normal/lognormal — the trace path) never
    /// routes through here, so seeded traces are unaffected.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo, "empty range");
        let span = hi - lo;
        let threshold = span.wrapping_neg() % span;
        loop {
            let m = u128::from(self.next_u64()) * u128::from(span);
            if (m as u64) >= threshold {
                return lo + (m >> 64) as u64;
            }
        }
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with the given rate (inter-arrival times of a
    /// Poisson process).
    pub fn exp(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-12).ln() / rate
    }

    /// Log-normal with the given underlying mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize(0, items.len())]
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.f64() < p_true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            let v = r.range(10, 20);
            assert!((10..20).contains(&v));
        }
    }

    #[test]
    fn range_pinned_against_python_mirror() {
        // Lemire multiply-shift outputs computed by an independent
        // stdlib-Python implementation of xoshiro256** + the same
        // rejection rule (see python/tests/test_trace_mirror.py).
        let mut r = Rng::new(11);
        let got: Vec<u64> = (0..8).map(|_| r.range(10, 20)).collect();
        assert_eq!(got, vec![11, 17, 15, 14, 14, 13, 11, 16]);
        let mut r = Rng::new(5);
        let got: Vec<u64> = (0..4).map(|_| r.range(0, 1_000_000_000_000)).collect();
        assert_eq!(
            got,
            vec![404794302180, 463519180289, 747084197040, 302323474737]
        );
    }

    #[test]
    fn range_rejection_path_pinned() {
        // A span just above 2^63 rejects ~half of all draws, so this
        // pins the rejection loop itself (the mirror counted 8
        // rejections across these 16 draws).
        let span = (1u64 << 63) + 12345;
        let mut r = Rng::new(123);
        let got: Vec<u64> = (0..16).map(|_| r.range(0, span)).collect();
        assert_eq!(
            &got[..4],
            &[
                6036662480048362042,
                14850985635934019,
                2634583529135477697,
                6166093495432743727
            ]
        );
        for v in got {
            assert!(v < span);
        }
    }

    #[test]
    fn range_unbiased_over_small_span() {
        // With `% 3` bias the first two residue classes of a span-3
        // range get one extra preimage in 2^64 — statistically
        // invisible — but Lemire must still produce a near-uniform
        // split; this guards the obvious regression of dropping the
        // rejection threshold (e.g. `span.wrapping_neg()` without the
        // `% span`), which skews counts grossly.
        let mut r = Rng::new(31);
        let mut counts = [0u64; 3];
        for _ in 0..30_000 {
            counts[r.range(0, 3) as usize] += 1;
        }
        for c in counts {
            assert!((9_000..11_000).contains(&c), "counts {counts:?}");
        }
    }

    #[test]
    fn f64_stream_unchanged_by_range_fix() {
        // The trace path (exp/lognormal -> f64 -> next_u64) must stay
        // byte-identical across the range() rewrite: pin the raw
        // next_u64 stream against the Python mirror.
        let mut r = Rng::new(42);
        let got: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
        assert_eq!(
            got,
            vec![
                13696896915399030466,
                12641092763546669283,
                14580102322132234639,
                5279892052835703538
            ]
        );
    }
}
