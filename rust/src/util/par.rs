//! Dependency-free data parallelism for sweep grids.
//!
//! The vendored crate set has no `rayon`, so [`par_map`] provides the
//! one primitive the benches and examples need: map a function over a
//! work list on scoped OS threads (`std::thread::scope`) and return
//! the results **in input order**. Determinism contract (DESIGN.md
//! §9): every grid point must be self-contained — it builds its own
//! simulator state and derives randomness from its own seed (see
//! [`point_seed`]) — so the output is a pure function of the input
//! list, and parallel and serial execution produce byte-identical
//! downstream artifacts (`BENCH_*.json`, tables). `PAR=0` (or `PAR=1`)
//! forces the serial path as an escape hatch; any other value sets the
//! worker count; unset uses the machine's available parallelism.

use std::collections::VecDeque;
use std::sync::Mutex;

/// Worker count for sweep grids: the `PAR` env var when set (`0`/`1` =
/// serial escape hatch, anything unparsable = serial), otherwise the
/// machine's available parallelism.
pub fn sweep_threads() -> usize {
    match std::env::var("PAR") {
        Ok(v) => v.trim().parse::<usize>().unwrap_or(1).max(1),
        Err(_) => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    }
}

/// Map `f` over `items` on up to `threads` scoped threads, returning
/// results in input order. `f` receives `(index, item)`; it must be
/// `Sync` (shared by reference across workers) and self-contained per
/// point. `threads <= 1` (or a single-item list) runs serially on the
/// calling thread with zero spawn overhead — the `PAR=0` escape hatch
/// bottoms out here. A panicking point propagates its panic to the
/// caller after the scope unwinds.
pub fn par_map<T, U, F>(items: Vec<T>, threads: usize, f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(usize, T) -> U + Sync,
{
    let n = items.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        return items.into_iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }
    // Shared FIFO of (index, item): workers pull the next point as
    // they free up (contention is negligible — points are simulator
    // runs, not microtasks) and tag results with the input index.
    let queue: Mutex<VecDeque<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().collect());
    let buckets: Vec<Vec<(usize, U)>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                s.spawn(|| {
                    let mut out = Vec::new();
                    loop {
                        let next = queue.lock().unwrap().pop_front();
                        match next {
                            Some((i, x)) => out.push((i, f(i, x))),
                            None => break,
                        }
                    }
                    out
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(v) => v,
                Err(e) => std::panic::resume_unwind(e),
            })
            .collect()
    });
    let mut results: Vec<Option<U>> = (0..n).map(|_| None).collect();
    for (i, u) in buckets.into_iter().flatten() {
        debug_assert!(results[i].is_none(), "point {i} computed twice");
        results[i] = Some(u);
    }
    results
        .into_iter()
        .map(|o| o.expect("every point computed exactly once"))
        .collect()
}

/// Deterministic per-point seed: mixes a base seed with the point's
/// grid index (splitmix64 finalizer) so concurrent points never share
/// a random stream yet every run — serial or parallel — derives the
/// same seed for the same point.
pub fn point_seed(base: u64, idx: usize) -> u64 {
    let mut z = base ^ (idx as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Sweep-grid driver: the one-liner the benches and examples use to
/// evaluate independent grid points concurrently. Holds the point list
/// and a worker count (default: [`sweep_threads`], i.e. the `PAR` env
/// contract) and maps a point-evaluation function over it with
/// order-preserving [`par_map`] — callers render tables / JSON from
/// the returned Vec exactly as the serial loop did, so output bytes do
/// not depend on the worker count.
pub struct SweepGrid<P> {
    points: Vec<P>,
    threads: usize,
}

impl<P: Send> SweepGrid<P> {
    pub fn new(points: Vec<P>) -> Self {
        SweepGrid { points, threads: sweep_threads() }
    }

    /// Override the worker count (tests pin serial vs parallel
    /// explicitly instead of mutating the process environment).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Evaluate every point, returning results in point order.
    pub fn run<U: Send>(self, f: impl Fn(usize, P) -> U + Sync) -> Vec<U> {
        par_map(self.points, self.threads, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordered_results_any_thread_count() {
        let items: Vec<usize> = (0..97).collect();
        let expect: Vec<usize> = items.iter().map(|x| x * x).collect();
        for threads in [1, 2, 4, 16, 200] {
            let got = par_map(items.clone(), threads, |_, x| x * x);
            assert_eq!(got, expect, "threads={threads}");
        }
    }

    #[test]
    fn index_matches_item_position() {
        let got = par_map(vec![10, 20, 30], 3, |i, x| (i, x));
        assert_eq!(got, vec![(0, 10), (1, 20), (2, 30)]);
    }

    #[test]
    fn empty_and_single() {
        let empty: Vec<u32> = par_map(Vec::<u32>::new(), 8, |_, x| x);
        assert!(empty.is_empty());
        assert_eq!(par_map(vec![7], 8, |_, x| x + 1), vec![8]);
    }

    #[test]
    fn serial_and_parallel_bitwise_equal_floats() {
        // The determinism contract benches rely on: same inputs, same
        // bits, regardless of worker count or completion order.
        let items: Vec<u64> = (0..50).collect();
        let f = |i: usize, s: u64| {
            let mut rng = crate::util::rng::Rng::new(point_seed(s, i));
            (0..100).map(|_| rng.normal()).sum::<f64>()
        };
        let serial = par_map(items.clone(), 1, f);
        let parallel = par_map(items, 8, f);
        let a: Vec<u64> = serial.iter().map(|x| x.to_bits()).collect();
        let b: Vec<u64> = parallel.iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn point_seed_is_deterministic_and_spreads() {
        assert_eq!(point_seed(7, 3), point_seed(7, 3));
        assert_ne!(point_seed(7, 3), point_seed(7, 4));
        assert_ne!(point_seed(7, 3), point_seed(8, 3));
        // Index 0 must not collapse to the base seed's raw stream for
        // every base (the finalizer still mixes).
        assert_ne!(point_seed(1, 0), 1);
    }

    #[test]
    fn sweep_grid_runs_ordered() {
        let rows = SweepGrid::new((0..20).collect::<Vec<i64>>())
            .with_threads(4)
            .run(|i, x| format!("{i}:{x}"));
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(*row, format!("{i}:{i}"));
        }
    }
}
