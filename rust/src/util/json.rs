//! Minimal JSON parser + writer (the vendored crate set has no serde).
//!
//! Supports the full JSON value grammar the project needs: objects,
//! arrays, strings (with escapes), numbers, booleans, null. Numbers are
//! parsed as `f64`, which is lossless for every value this project
//! serializes (f32 data, small integers).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Decode an array of numbers into f32s (the golden-vector format).
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_f64()? as f32);
        }
        Some(out)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => {
                    // Collect the full UTF-8 sequence.
                    let len = utf8_len(c);
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    if first < 0x80 {
        1
    } else if first < 0xe0 {
        2
    } else if first < 0xf0 {
        3
    } else {
        4
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{item}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -1.5e3 ").unwrap(), Json::Num(-1500.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(
            j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"m":128,"x":[1.5,-2,0.25],"s":"he\"llo","b":false}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_strings() {
        let j = Json::parse("\"caf\u{e9} \\u00e9\"").unwrap();
        assert_eq!(j.as_str(), Some("café é"));
    }

    #[test]
    fn f32_vec_helper() {
        let j = Json::parse("[1, 2.5, -3]").unwrap();
        assert_eq!(j.as_f32_vec().unwrap(), vec![1.0, 2.5, -3.0]);
        assert!(Json::parse("[1, \"x\"]").unwrap().as_f32_vec().is_none());
    }
}
