//! ASCII table printer — every paper table/figure regeneration bench
//! renders through this so outputs are uniform and diffable.

#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |out: &mut String| {
            out.push('+');
            for w in &widths {
                out.push_str(&"-".repeat(w + 2));
                out.push('+');
            }
            out.push('\n');
        };
        line(&mut out);
        out.push('|');
        for (h, w) in self.headers.iter().zip(&widths) {
            out.push_str(&format!(" {h:<w$} |"));
        }
        out.push('\n');
        line(&mut out);
        for row in &self.rows {
            out.push('|');
            for (c, w) in row.iter().zip(&widths) {
                out.push_str(&format!(" {c:>w$} |"));
            }
            out.push('\n');
        }
        line(&mut out);
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Render as TSV (for piping into plotting tools).
    pub fn to_tsv(&self) -> String {
        let mut out = self.headers.join("\t");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join("\t"));
            out.push('\n');
        }
        out
    }
}

/// Convenience: format f64 with fixed decimals.
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

/// Format a ratio as a percentage string `(42.5%)`.
pub fn pct(v: f64) -> String {
    format!("({:.1}%)", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["100".into(), "x".into()]);
        let r = t.render();
        assert!(r.contains("== T =="));
        assert!(r.contains("| a   | bbbb |"));
        assert!(r.contains("| 100 |    x |"));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        Table::new("T", &["a"]).row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn tsv_export() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_tsv(), "a\tb\n1\t2\n");
    }

    #[test]
    fn helpers() {
        assert_eq!(f(1.2345, 2), "1.23");
        assert_eq!(pct(0.425), "(42.5%)");
    }
}
