//! Streaming statistics + percentile helpers for metrics reporting
//! (TTFT / TPOT / throughput distributions in the coordinator, and the
//! bench harnesses' timing summaries).

/// Online mean/min/max/variance (Welford).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact percentiles over a retained sample (fine at our scales).
#[derive(Debug, Clone, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
}

impl Percentiles {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn count(&self) -> usize {
        self.xs.len()
    }

    /// Linear-interpolated percentile, q in [0, 100].
    pub fn pct(&self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let mut v = self.xs.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = q / 100.0 * (v.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            v[lo]
        } else {
            let w = rank - lo as f64;
            v[lo] * (1.0 - w) + v[hi] * w
        }
    }

    pub fn median(&self) -> f64 {
        self.pct(50.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut p = Percentiles::new();
        for x in 1..=100 {
            p.add(x as f64);
        }
        assert!((p.median() - 50.5).abs() < 1e-9);
        assert!((p.pct(0.0) - 1.0).abs() < 1e-9);
        assert!((p.pct(100.0) - 100.0).abs() < 1e-9);
        assert!((p.pct(99.0) - 99.01).abs() < 0.02);
    }

    #[test]
    fn empty_percentile_is_nan() {
        assert!(Percentiles::new().pct(50.0).is_nan());
    }
}
