//! Streaming statistics + percentile helpers for metrics reporting
//! (TTFT / TPOT / throughput distributions in the coordinator, and the
//! bench harnesses' timing summaries).
//!
//! Percentile queries used to clone and re-sort the full sample on
//! *every* call — O(n log n) per query inside the bisection sweep's
//! hot loop. Both containers now memoize the sorted order in a
//! [`OnceLock`] (not `RefCell`: metrics travel through `util::par`
//! sweeps, so the cache must be `Sync`), invalidated by reassigning a
//! fresh lock on every mutation. Results are bit-identical to the
//! uncached path: the same multiset of values sorts to the same order.

use std::sync::OnceLock;

/// Online mean/min/max/variance (Welford).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another summary into this one (Chan et al. parallel
    /// Welford combine) — used when folding per-engine metrics into a
    /// cluster-level view.
    pub fn absorb(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let d = other.mean - self.mean;
        self.mean += d * n2 / (n1 + n2);
        self.m2 += other.m2 + d * d * n1 * n2 / (n1 + n2);
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Exact percentiles over a retained sample (fine at our scales).
#[derive(Debug, Clone, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
    /// Sorted copy of `xs`, built on the first query after a mutation.
    sorted: OnceLock<Vec<f64>>,
}

impl Percentiles {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = OnceLock::new();
    }

    pub fn count(&self) -> usize {
        self.xs.len()
    }

    /// Linear-interpolated percentile, q in [0, 100].
    pub fn pct(&self, q: f64) -> f64 {
        let sorted = self.sorted.get_or_init(|| {
            let mut v = self.xs.clone();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v
        });
        pct_of_sorted(sorted, q)
    }

    pub fn median(&self) -> f64 {
        self.pct(50.0)
    }
}

/// Linear-interpolated percentile of an owned sample, q in [0, 100].
/// NaN on an empty sample.
fn pct_of(mut v: Vec<f64>, q: f64) -> f64 {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    pct_of_sorted(&v, q)
}

/// Linear-interpolated percentile of an already-sorted sample.
fn pct_of_sorted(v: &[f64], q: f64) -> f64 {
    if v.is_empty() {
        return f64::NAN;
    }
    let rank = q / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Percentiles over *timestamped* samples: each `add` records the
/// virtual time at which the sample completed, so open-loop serving
/// runs can report steady-state percentiles over a window that
/// excludes warmup (empty system filling up) and cooldown (arrivals
/// exhausted, queues draining).
#[derive(Debug, Clone, Default)]
pub struct TimedPercentiles {
    /// (completion time, value) pairs.
    samples: Vec<(f64, f64)>,
    /// `samples` stably sorted by timestamp: window queries slice it
    /// with two binary searches instead of filtering every sample.
    by_time: OnceLock<Vec<(f64, f64)>>,
    /// Every value sorted — the whole-run percentile order.
    sorted_vals: OnceLock<Vec<f64>>,
}

impl TimedPercentiles {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, t: f64, x: f64) {
        self.samples.push((t, x));
        self.invalidate();
    }

    fn invalidate(&mut self) {
        // `OnceLock::take` needs 1.80; reassignment works on 1.70+.
        self.by_time = OnceLock::new();
        self.sorted_vals = OnceLock::new();
    }

    /// Samples stably sorted by completion time (ties keep insertion
    /// order; timestamps are never NaN, so total_cmp matches the
    /// window filter's `..=` semantics).
    fn by_time(&self) -> &[(f64, f64)] {
        self.by_time.get_or_init(|| {
            let mut v = self.samples.clone();
            v.sort_by(|a, b| a.0.total_cmp(&b.0));
            v
        })
    }

    /// The [t0, t1] slice of the time-sorted samples.
    fn window(&self, t0: f64, t1: f64) -> &[(f64, f64)] {
        let v = self.by_time();
        let lo = v.partition_point(|&(t, _)| t < t0);
        let hi = v.partition_point(|&(t, _)| t <= t1);
        &v[lo..hi.max(lo)]
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Samples whose completion time falls in [t0, t1].
    pub fn count_in(&self, t0: f64, t1: f64) -> usize {
        self.window(t0, t1).len()
    }

    /// Percentile over every sample, q in [0, 100]. NaN when empty.
    pub fn pct(&self, q: f64) -> f64 {
        let sorted = self.sorted_vals.get_or_init(|| {
            let mut v: Vec<f64> = self.samples.iter().map(|&(_, x)| x).collect();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v
        });
        pct_of_sorted(sorted, q)
    }

    /// Percentile over the samples completing in [t0, t1] (the
    /// steady-state window). NaN when no sample falls inside.
    pub fn pct_in(&self, t0: f64, t1: f64, q: f64) -> f64 {
        pct_of(self.window(t0, t1).iter().map(|&(_, x)| x).collect(), q)
    }

    pub fn median(&self) -> f64 {
        self.pct(50.0)
    }

    /// Merge another distribution's samples (cluster-level rollup of
    /// per-engine metrics).
    pub fn absorb(&mut self, other: &TimedPercentiles) {
        self.samples.extend_from_slice(&other.samples);
        self.invalidate();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut p = Percentiles::new();
        for x in 1..=100 {
            p.add(x as f64);
        }
        assert!((p.median() - 50.5).abs() < 1e-9);
        assert!((p.pct(0.0) - 1.0).abs() < 1e-9);
        assert!((p.pct(100.0) - 100.0).abs() < 1e-9);
        assert!((p.pct(99.0) - 99.01).abs() < 0.02);
    }

    #[test]
    fn empty_percentile_is_nan() {
        assert!(Percentiles::new().pct(50.0).is_nan());
    }

    #[test]
    fn timed_percentiles_window() {
        let mut p = TimedPercentiles::new();
        for i in 0..100 {
            // Sample value 1000 at t<10 (warmup junk), value i elsewhere.
            let t = i as f64;
            let x = if t < 10.0 { 1000.0 } else { t };
            p.add(t, x);
        }
        assert_eq!(p.count(), 100);
        assert_eq!(p.count_in(10.0, 99.0), 90);
        // Whole-run p95 is polluted by the warmup spikes...
        assert!(p.pct(99.0) > 99.0);
        // ...the steady-state window is not.
        assert!(p.pct_in(10.0, 99.0, 100.0) <= 99.0 + 1e-9);
        assert!(p.pct_in(200.0, 300.0, 50.0).is_nan());
    }

    #[test]
    fn percentile_cache_invalidates_on_add_and_absorb() {
        // Query, mutate, query again: the memoized sort must be
        // rebuilt, and every answer must equal a fresh uncached
        // container's, to the bit.
        let mut p = Percentiles::new();
        for x in [5.0, 1.0, 3.0] {
            p.add(x);
        }
        assert_eq!(p.pct(50.0).to_bits(), 3.0f64.to_bits());
        p.add(0.5);
        p.add(9.0);
        let mut fresh = Percentiles::new();
        for x in [5.0, 1.0, 3.0, 0.5, 9.0] {
            fresh.add(x);
        }
        for q in [0.0, 25.0, 50.0, 95.0, 100.0] {
            assert_eq!(p.pct(q).to_bits(), fresh.pct(q).to_bits());
        }

        let mut t = TimedPercentiles::new();
        for (ts, x) in [(0.0, 4.0), (2.0, 1.0), (1.0, 7.0)] {
            t.add(ts, x);
        }
        assert_eq!(t.count_in(0.5, 2.5), 2);
        let _ = t.pct_in(0.0, 2.0, 95.0); // warm the cache
        let mut other = TimedPercentiles::new();
        other.add(1.5, 2.0);
        t.absorb(&other);
        assert_eq!(t.count_in(0.5, 2.5), 3, "absorb must drop the stale window");
        let mut fresh = TimedPercentiles::new();
        for (ts, x) in [(0.0, 4.0), (2.0, 1.0), (1.0, 7.0), (1.5, 2.0)] {
            fresh.add(ts, x);
        }
        for q in [0.0, 50.0, 95.0, 100.0] {
            assert_eq!(t.pct(q).to_bits(), fresh.pct(q).to_bits());
            assert_eq!(
                t.pct_in(0.5, 2.5, q).to_bits(),
                fresh.pct_in(0.5, 2.5, q).to_bits()
            );
        }
    }

    #[test]
    fn timed_percentiles_absorb() {
        let mut a = TimedPercentiles::new();
        let mut b = TimedPercentiles::new();
        a.add(0.0, 1.0);
        b.add(1.0, 3.0);
        a.absorb(&b);
        assert_eq!(a.count(), 2);
        assert!((a.median() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn summary_absorb_matches_sequential() {
        let xs = [1.0, 5.0, 2.0, 8.0, 3.0, 9.0, 4.0];
        let mut whole = Summary::new();
        for &x in &xs {
            whole.add(x);
        }
        let mut left = Summary::new();
        let mut right = Summary::new();
        for &x in &xs[..3] {
            left.add(x);
        }
        for &x in &xs[3..] {
            right.add(x);
        }
        left.absorb(&right);
        assert_eq!(left.count(), whole.count());
        assert!((left.mean() - whole.mean()).abs() < 1e-12);
        assert!((left.variance() - whole.variance()).abs() < 1e-12);
        assert_eq!(left.min(), whole.min());
        assert_eq!(left.max(), whole.max());
        // Absorbing an empty summary is a no-op.
        left.absorb(&Summary::new());
        assert_eq!(left.count(), whole.count());
    }
}
