//! `fp8-tco` — reproduction of *"An Inquiry into Datacenter TCO for LLM
//! Inference with FP8"* (CS.LG 2025).
//!
//! Three-layer architecture (see DESIGN.md):
//!
//! * **L3 (this crate)** — serving coordinator (router / continuous
//!   batcher / KV-cache manager / prefill-decode scheduler), the
//!   H100 & Gaudi 2 hardware simulators standing in for the paper's
//!   testbed, the Llama FLOPs workload model (paper Eqs. 3–6), and the
//!   TCO model (paper Eq. 1, Figs. 1 & 9).
//! * **L2** — JAX Llama forward passes, AOT-lowered to `artifacts/`.
//! * **L1** — Pallas FP8 kernels called by L2.
//!
//! Python never runs on the request path: the rust binary loads the
//! AOT HLO artifacts through PJRT (`runtime`) and is self-contained.
//!
//! The PJRT surface (`runtime`, `coordinator::pjrt_backend`) depends
//! on the `xla` bindings and is gated behind the `pjrt` cargo feature;
//! the default build is dependency-free and covers the entire
//! simulated testbed (every paper figure and the cluster simulator).

pub mod analysis;
pub mod coordinator;
pub mod fp8;
pub mod hwsim;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod simlint;
pub mod tco;
pub mod util;
pub mod workload;
