//! Special-function (exponential) cost model — paper §5.7.
//!
//! Softmax needs `exp()` per attention score. GPUs with SFUs evaluate
//! exponentials on dedicated units *in parallel with* tensor-core
//! GEMMs; Gaudi has no SFU and must run them on its TPC vector cores
//! (11 TFLOPS BF16 on Gaudi 2), serializing with the MME. During
//! decode the exponential count scales O(B·S) — the paper identifies
//! this as Gaudi's long-sequence bottleneck.

use super::calib::{sfu_exp_rate, EXP_FLOP_EQUIV};
use super::spec::Device;

/// Time to evaluate `n_exp` exponentials, given `overlap_budget`
/// seconds of concurrent matrix-engine work they can hide behind.
pub fn exp_time(dev: Device, n_exp: f64, overlap_budget: f64) -> f64 {
    let spec = dev.spec();
    if spec.has_sfu {
        // SFU path: runs concurrently with tensor cores; only the
        // excess over the overlap budget is exposed.
        let t = n_exp / sfu_exp_rate(dev);
        (t - overlap_budget).max(0.0)
    } else {
        // TPC path: serialized with the MME.
        n_exp * EXP_FLOP_EQUIV / spec.vector_flops
    }
}

/// Exponentials per decode step: one per (sequence, head, cached key).
pub fn decode_exp_count(batch: usize, seq: usize, heads: usize) -> f64 {
    batch as f64 * seq as f64 * heads as f64
}

/// Exponentials for a full prefill: causal S^2/2 per head per sequence.
pub fn prefill_exp_count(batch: usize, seq: usize, heads: usize) -> f64 {
    batch as f64 * (seq as f64 * seq as f64 / 2.0) * heads as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sfu_hides_exponentials_under_overlap() {
        // H100 with generous overlap: exposed time ~ 0 (§5.7).
        let t = exp_time(Device::H100, 1e6, 1e-3);
        assert_eq!(t, 0.0);
    }

    #[test]
    fn gaudi_pays_serially() {
        let n = 1e6;
        let t = exp_time(Device::Gaudi2, n, 1e-3);
        assert!(t > 0.0);
        // 1e6 * 4 flops / 11 TFLOPS ~ 0.36 us
        assert!((t - n * 4.0 / 11.0e12).abs() < 1e-12);
    }

    #[test]
    fn gaudi3_faster_tpc_but_still_serial() {
        let t2 = exp_time(Device::Gaudi2, 1e8, 1.0);
        let t3 = exp_time(Device::Gaudi3, 1e8, 1.0);
        assert!(t3 < t2);
        assert!(t3 > 0.0);
    }

    #[test]
    fn decode_exp_scales_with_batch_and_seq() {
        // §5.7: softmax cost scales O(B*S) during decoding.
        let base = decode_exp_count(1, 1024, 32);
        assert_eq!(decode_exp_count(2, 1024, 32), base * 2.0);
        assert_eq!(decode_exp_count(1, 2048, 32), base * 2.0);
    }

    #[test]
    fn prefill_exp_quadratic_in_seq() {
        let s1 = prefill_exp_count(1, 1024, 32);
        let s2 = prefill_exp_count(1, 2048, 32);
        assert!((s2 / s1 - 4.0).abs() < 1e-9);
    }
}
