//! Calibrated model constants, each tied to the paper table whose
//! shape it reproduces. Everything NOT in this file is first-principles
//! (datasheet specs + architectural mechanism).
//!
//! Calibration discipline (DESIGN.md §5): a constant may encode an
//! architecture-level *descriptor* (e.g. "H100's row-wise FP32-accum
//! FP8 path tops out near 20% MFU because every WGMMA result is
//! promoted through CUDA cores", Table 3), never a per-cell fudge.

use super::spec::{Accum, Device, DType, Scaling};

/// Kernel launch + runtime dispatch overhead (seconds).
/// Calibrated: Table 6 small shapes (both devices show a size-
/// independent time floor) and Table 2/3 1K rows.
pub fn launch_overhead(dev: Device) -> f64 {
    match dev {
        Device::H100 => 7.5e-6,
        Device::A100 => 9.0e-6,
        Device::Gaudi2 => 2.2e-6,
        Device::Gaudi3 => 2.2e-6,
    }
}

/// Architecture cap on achievable MFU for FP8 GEMMs, by scaling
/// strategy and accumulation path. Calibrated: Table 3 (H100) and
/// Table 2 (Gaudi 2) 8K rows — the asymptotic plateau of each kernel
/// family.
pub fn mfu_cap_fp8(dev: Device, scaling: Scaling, accum: Accum) -> f64 {
    match dev {
        Device::H100 | Device::A100 => match (scaling, accum) {
            // Row-wise + FP32 accumulation: every tensor-core tile
            // result is promoted to CUDA cores for the scale multiply
            // -> the epilogue serializes the pipeline (Table 3: 20%).
            (Scaling::PerRow, Accum::Fp32) => 0.21,
            // Row-wise + fast (14-bit) accumulation (Table 3: ~57%).
            (Scaling::PerRow, Accum::Fast) => 0.58,
            // Per-tensor scales fold into the WGMMA epilogue.
            (Scaling::PerTensor | Scaling::Static | Scaling::HwPow2, Accum::Fp32) => 0.67,
            (Scaling::PerTensor | Scaling::Static | Scaling::HwPow2, Accum::Fast) => 0.71,
        },
        Device::Gaudi2 | Device::Gaudi3 => match scaling {
            // Row-wise scale application shares the TPC pipeline
            // (Table 2: 85.7% vs 95.0% at 8K).
            Scaling::PerRow => 0.90,
            Scaling::PerTensor | Scaling::Static => 0.985,
            // Exponent-bias trick: scale application is free in the
            // MME datapath (Table 2 HW-accel column: 98.4%).
            Scaling::HwPow2 => 1.0,
        },
    }
}

/// Architecture cap on achievable MFU for BF16 GEMMs.
/// Calibrated: Table 6 large shapes + public MLPerf-class numbers.
pub fn mfu_cap_bf16(dev: Device) -> f64 {
    match dev {
        Device::H100 | Device::A100 => 0.72,
        Device::Gaudi2 | Device::Gaudi3 => 0.95,
    }
}

/// H100 utilization ramp midpoint (matrix "effective size" where the
/// kernel reaches ~50% of its cap). Row-wise kernels use smaller tiles
/// and ramp earlier; per-tensor WGMMA pipelines need larger tiles
/// (Table 3: per-row wins below ~2K, per-tensor above).
pub fn h100_ramp_midpoint(scaling: Scaling, dtype: DType) -> f64 {
    if dtype == DType::Bf16 {
        return 1100.0;
    }
    match scaling {
        Scaling::PerRow => 1150.0,
        Scaling::PerTensor | Scaling::Static | Scaling::HwPow2 => 1750.0,
    }
}

/// H100 ramp steepness exponent (fit to Table 3 1K..8K columns).
pub const H100_RAMP_POWER: f64 = 3.0;

/// Gaudi row-wise dynamic-quantization TPC pass: effective element
/// rate (elements/s) for the amax+scale pass that cannot overlap the
/// MME (Table 2 per-row vs per-tensor deltas).
pub const GAUDI_TPC_QUANT_RATE: f64 = 5.5e12;

/// Fraction of HBM bandwidth sustained when streaming GEMM operands
/// (neither device reaches datasheet bandwidth on real kernels;
/// Table 6 4K rows).
pub fn hbm_stream_eff(dev: Device) -> f64 {
    match dev {
        Device::H100 | Device::A100 => 0.83,
        Device::Gaudi2 | Device::Gaudi3 => 0.78,
    }
}

/// Power-curve parameters: frac_of_range = min(max_frac, a * util^b),
/// P = idle + (TDP - idle) * frac. Calibrated: Table 1 power columns
/// (H100 pegs near TDP from ~40% utilization; Gaudi 2 stays well
/// under its 600 W TDP even at 94% utilization).
pub struct PowerCurve {
    pub a: f64,
    pub b: f64,
    pub max_frac: f64,
}

pub fn power_curve(dev: Device) -> PowerCurve {
    match dev {
        Device::H100 => PowerCurve { a: 1.63, b: 0.62, max_frac: 1.0 },
        Device::A100 => PowerCurve { a: 1.5, b: 0.62, max_frac: 1.0 },
        Device::Gaudi2 => PowerCurve { a: 0.78, b: 0.41, max_frac: 0.80 },
        Device::Gaudi3 => PowerCurve { a: 0.80, b: 0.45, max_frac: 0.85 },
    }
}

/// DVFS exponent: P_dynamic ∝ f^DVFS_POWER (V scales with f).
pub const DVFS_POWER: f64 = 2.2;

/// Cost of one exponential on the vector path, in FLOP-equivalents
/// (polynomial expansion + range reduction on TPC/CUDA cores).
pub const EXP_FLOP_EQUIV: f64 = 4.0;

/// SFU exponential throughput (exp/s) where present. H100: 16 SFU/SM
/// x 132 SM x ~1.6 GHz.
pub fn sfu_exp_rate(dev: Device) -> f64 {
    match dev {
        Device::H100 => 3.4e12,
        Device::A100 => 2.4e12,
        _ => 0.0,
    }
}
