//! Power model + capping governor (paper §5.5, Table 1 power columns).
//!
//! Draw is a calibrated function of matrix-engine utilization with a
//! per-device curve shape: the H100 pegs near its 700 W TDP from
//! moderate utilization, while the Gaudi 2 stays well below its 600 W
//! TDP even at high utilization (Table 1). Capping scales the clock
//! (DVFS): compute-bound time stretches by 1/f, memory-bound time is
//! unchanged — which is why the paper finds decode unaffected by a
//! 400 W cap (§5.5) while prefill throughput drops.

use super::calib::{self, DVFS_POWER};
use super::spec::Device;

/// Power cap configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PowerCap {
    None,
    /// Per-GPU cap in watts (what both vendors support today).
    PerGpu(f64),
    /// Per-rack cap: total budget shared by `gpus` (the paper's §5.5
    /// proposal, implemented as an extension).
    PerRack { watts: f64, gpus: usize },
}

/// Uncapped draw (W) at a given matrix utilization in [0, 1].
pub fn power_draw_w(dev: Device, util_frac: f64) -> f64 {
    let spec = dev.spec();
    let c = calib::power_curve(dev);
    let frac = (c.a * util_frac.max(0.0).powf(c.b)).min(c.max_frac);
    spec.idle_w + (spec.tdp - spec.idle_w) * frac
}

/// Result of applying a cap to an operation.
#[derive(Debug, Clone, Copy)]
pub struct CappedOp {
    /// Achieved clock fraction f in (0, 1].
    pub clock_frac: f64,
    /// Stretched execution time (s).
    pub seconds: f64,
    /// Power drawn under the cap (W).
    pub watts: f64,
    /// Whether the cap was physically achievable. A cap below the
    /// device's idle draw cannot be met by DVFS alone; the op is
    /// returned best-effort at the minimum clock with `watts > cap_w`
    /// and this flag false, so callers can reject the configuration
    /// instead of silently pricing an impossible power state.
    pub cap_feasible: bool,
}

/// Apply a per-GPU cap to an op with the given compute-bound time
/// fraction. `t_s`: uncapped op time; `util_frac`: uncapped engine
/// utilization; `compute_frac`: fraction of `t_s` that scales with
/// clock (compute/feed-bound), the rest is HBM-bound.
///
/// When the cap is feasible (`cap_w >= idle_w`), the reported draw
/// never exceeds `cap_w`: if the DVFS floors (clock fraction >= 0.2,
/// dynamic power >= 5% of uncapped) leave residual draw above target,
/// the governor duty-cycles the clock on average, so the cap holds at
/// the floor's time cost.
pub fn apply_cap(dev: Device, cap_w: f64, t_s: f64, util_frac: f64, compute_frac: f64) -> CappedOp {
    let spec = dev.spec();
    let p0 = power_draw_w(dev, util_frac);
    if p0 <= cap_w {
        return CappedOp { clock_frac: 1.0, seconds: t_s, watts: p0, cap_feasible: true };
    }
    // DVFS: dynamic power ~ f^DVFS_POWER. Solve for f hitting the cap.
    let dyn0 = p0 - spec.idle_w;
    let cap_feasible = cap_w >= spec.idle_w;
    let target_dyn = (cap_w - spec.idle_w).max(dyn0 * 0.05);
    let f = (target_dyn / dyn0).powf(1.0 / DVFS_POWER).clamp(0.2, 1.0);
    // Compute-bound portion stretches by 1/f; memory-bound does not.
    let seconds = t_s * (compute_frac / f + (1.0 - compute_frac));
    // Average power over the stretched op. Clamp to the cap when it is
    // feasible: the f = 0.2 clock floor can leave residual dynamic
    // power above target, which duty-cycling absorbs.
    let mut watts = spec.idle_w + dyn0 * f.powf(DVFS_POWER);
    if cap_feasible {
        watts = watts.min(cap_w);
    }
    CappedOp { clock_frac: f, seconds, watts, cap_feasible }
}

/// Per-rack capping: GPUs share a budget; a GPU may exceed the even
/// split if others draw less (§5.5). `demands`: uncapped per-GPU draw.
/// Returns the per-GPU allowed power.
pub fn rack_allocation(total_w: f64, demands: &[f64]) -> Vec<f64> {
    let n = demands.len();
    if n == 0 {
        return vec![];
    }
    let sum: f64 = demands.iter().sum();
    if sum <= total_w {
        return demands.to_vec(); // headroom for everyone
    }
    // Water-filling: satisfy small demands fully, split the remainder
    // evenly among the still-hungry.
    let mut alloc = vec![0.0; n];
    let mut remaining = total_w;
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| demands[a].total_cmp(&demands[b]));
    let mut left = n;
    for &i in &idx {
        let fair = remaining / left as f64;
        let give = demands[i].min(fair);
        alloc[i] = give;
        remaining -= give;
        left -= 1;
    }
    alloc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h100_pegs_near_tdp_at_moderate_util() {
        // Table 1: H100 draws ~690 W (99%) from ~44% utilization.
        let p = power_draw_w(Device::H100, 0.44);
        assert!(p > 650.0, "{p}");
        // ...but much less at 11% utilization (350 W measured).
        let p_small = power_draw_w(Device::H100, 0.11);
        assert!(p_small < 500.0 && p_small > 250.0, "{p_small}");
    }

    #[test]
    fn gaudi_stays_below_tdp() {
        // Table 1: Gaudi 2 draws <= 490 W at up to 94.5% utilization.
        for util in [0.4, 0.7, 0.95, 1.0] {
            let p = power_draw_w(Device::Gaudi2, util);
            assert!(p < 520.0, "util {util} -> {p} W");
        }
    }

    #[test]
    fn power_monotone_in_util() {
        for dev in Device::ALL {
            let mut last = 0.0;
            for i in 0..=20 {
                let p = power_draw_w(dev, i as f64 / 20.0);
                assert!(p >= last);
                last = p;
            }
            assert!(power_draw_w(dev, 0.0) >= dev.spec().idle_w - 1e-9);
            assert!(power_draw_w(dev, 1.0) <= dev.spec().tdp + 1e-9);
        }
    }

    #[test]
    fn cap_leaves_memory_bound_ops_unharmed() {
        // §5.5 / Fig. 3: decode (memory-bound) unaffected by 400 W cap.
        let capped = apply_cap(Device::H100, 400.0, 1e-3, 0.9, 0.05);
        assert!(capped.seconds < 1.05e-3, "{}", capped.seconds);
        assert!(capped.watts <= 400.0 + 1e-6);
    }

    #[test]
    fn cap_slows_compute_bound_ops() {
        let capped = apply_cap(Device::H100, 400.0, 1e-3, 0.9, 1.0);
        assert!(capped.seconds > 1.15e-3, "{}", capped.seconds);
        assert!(capped.clock_frac < 1.0);
    }

    #[test]
    fn no_cap_effect_when_under_budget() {
        let c = apply_cap(Device::Gaudi2, 600.0, 1e-3, 0.5, 1.0);
        assert_eq!(c.clock_frac, 1.0);
        assert_eq!(c.seconds, 1e-3);
    }

    #[test]
    fn harsh_cap_never_reports_draw_above_cap() {
        // A 110 W cap on an H100 at high utilization sits below the
        // governor's minimum dynamic power (5% of dyn0 ≈ 30.5 W over
        // idle), so the clock floor alone cannot reach the target and
        // the naive model would report watts > cap. The governor
        // duty-cycles, so the reported draw must sit exactly on the
        // cap — at the clock floor's time cost.
        let spec = Device::H100.spec();
        let cap_w = spec.idle_w + 20.0; // 110 W: feasible but brutal
        let c = apply_cap(Device::H100, cap_w, 1e-3, 0.9, 1.0);
        assert!(c.cap_feasible);
        assert!(c.watts <= cap_w + 1e-12, "watts {} > cap {}", c.watts, cap_w);
        assert!((c.watts - cap_w).abs() < 1e-9, "should sit on the cap: {}", c.watts);
        assert!(c.clock_frac >= 0.2 - 1e-12);
        assert!(c.seconds > 1e-3);
    }

    #[test]
    fn infeasible_cap_below_idle_is_flagged() {
        // No DVFS setting gets an H100 below its 90 W idle draw: the
        // op comes back best-effort with the infeasibility surfaced,
        // not silently "rescued" to a fictitious sub-idle power state.
        let spec = Device::H100.spec();
        let cap_w = spec.idle_w - 30.0;
        let c = apply_cap(Device::H100, cap_w, 1e-3, 0.9, 1.0);
        assert!(!c.cap_feasible);
        assert!(c.watts > cap_w, "best-effort draw still exceeds the cap");
        assert!(c.watts >= spec.idle_w, "draw can never go below idle");
        assert!(c.seconds > 1e-3, "best-effort op still runs slowed");
    }

    #[test]
    fn feasible_cap_keeps_flag_set_on_both_branches() {
        // Uncapped fast path and DVFS path both report feasibility.
        let under = apply_cap(Device::H100, 900.0, 1e-3, 0.9, 1.0);
        assert!(under.cap_feasible);
        let over = apply_cap(Device::H100, 400.0, 1e-3, 0.9, 1.0);
        assert!(over.cap_feasible);
        assert!(over.watts <= 400.0 + 1e-12);
    }

    #[test]
    fn rack_allocation_waterfills() {
        // 4 GPUs, 1200 W budget, uneven demand.
        let alloc = rack_allocation(1200.0, &[200.0, 200.0, 600.0, 600.0]);
        assert!((alloc[0] - 200.0).abs() < 1e-9);
        assert!((alloc[1] - 200.0).abs() < 1e-9);
        // the two hungry GPUs split the remaining 800 W
        assert!((alloc[2] - 400.0).abs() < 1e-9);
        assert!((alloc[3] - 400.0).abs() < 1e-9);
        let total: f64 = alloc.iter().sum();
        assert!(total <= 1200.0 + 1e-9);
    }

    #[test]
    fn rack_allocation_headroom_passthrough() {
        let alloc = rack_allocation(4000.0, &[300.0, 400.0]);
        assert_eq!(alloc, vec![300.0, 400.0]);
    }

    #[test]
    fn per_rack_beats_per_gpu_for_skewed_load() {
        // §5.5's argument: under per-GPU caps a hot GPU throttles even
        // when rack headroom exists; per-rack capping lets it borrow.
        let demands = [650.0, 250.0, 250.0, 250.0];
        let rack_budget = 1600.0; // = 4 x 400 W per-GPU equivalent
        let rack = rack_allocation(rack_budget, &demands);
        assert!(rack[0] > 400.0, "hot GPU should borrow: {}", rack[0]);
        // per-GPU capping would have clamped it to 400.
    }
}
