//! Device specifications (public datasheet values only — everything
//! calibrated against paper measurements lives in `calib.rs`).

/// Matrix datatypes the simulators understand.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    Bf16,
    Fp8,
    Fp32,
}

impl DType {
    pub fn bytes(self) -> f64 {
        match self {
            DType::Fp8 => 1.0,
            DType::Bf16 => 2.0,
            DType::Fp32 => 4.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::Bf16 => "bf16",
            DType::Fp8 => "fp8",
            DType::Fp32 => "fp32",
        }
    }
}

/// Activation-scaling strategy of an FP8 GEMM (paper Tables 2–3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scaling {
    /// Dynamic per-row (per-token) scales.
    PerRow,
    /// Dynamic per-tensor scale.
    PerTensor,
    /// Static (calibrated) per-tensor scale.
    Static,
    /// Gaudi hardware-accelerated power-of-2 per-tensor scale.
    HwPow2,
}

/// FP8 accumulation path (paper §3.2 "Accumulation precision").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Accum {
    /// Full FP32 accumulation (Gaudi native; H100 via CUDA-core
    /// promotion, expensive).
    Fp32,
    /// H100 tensor-core fast path (14-bit accumulator).
    Fast,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Device {
    H100,
    Gaudi2,
    Gaudi3,
    A100,
}

impl Device {
    pub const ALL: [Device; 4] = [Device::H100, Device::Gaudi2, Device::Gaudi3, Device::A100];

    pub fn name(self) -> &'static str {
        match self {
            Device::H100 => "H100",
            Device::Gaudi2 => "Gaudi2",
            Device::Gaudi3 => "Gaudi3",
            Device::A100 => "A100",
        }
    }

    pub fn spec(self) -> &'static DeviceSpec {
        match self {
            Device::H100 => &H100,
            Device::Gaudi2 => &GAUDI2,
            Device::Gaudi3 => &GAUDI3,
            Device::A100 => &A100,
        }
    }
}

/// Datasheet-level description of an accelerator.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    pub device: Device,
    /// Dense peak matrix throughput (FLOP/s).
    pub peak_fp8: f64,
    pub peak_bf16: f64,
    /// HBM bandwidth (bytes/s).
    pub hbm_bw: f64,
    /// HBM capacity (bytes).
    pub hbm_cap: f64,
    /// Vector-core throughput (FLOP/s, BF16-class) — TPC on Gaudi,
    /// CUDA cores on NVIDIA. Paper §5.7 quotes these.
    pub vector_flops: f64,
    /// Whether dedicated special-function units exist (exp/softmax can
    /// overlap with matrix work). Paper §5.7: H100 yes, Gaudi no.
    pub has_sfu: bool,
    /// Board TDP (W).
    pub tdp: f64,
    /// Idle draw (W).
    pub idle_w: f64,
    /// Matrix-engine organization (drives the thin-GEMM behaviour).
    pub engine: MatrixEngine,
    /// Core clock (Hz) used by the systolic pipeline model.
    pub clock_hz: f64,
}

/// Matrix-engine organization (paper Fig. 7).
#[derive(Debug, Clone)]
pub enum MatrixEngine {
    /// Few large reconfigurable systolic arrays (Gaudi MME, Fig. 8).
    LargeSystolic {
        /// Number of MMEs.
        units: usize,
        /// Total PE count per MME (e.g. 256*256); geometry may fold.
        pes_per_unit: usize,
        /// Allowed (rows, cols) foldings, smallest width 128 (Fig. 8).
        geometries: &'static [(usize, usize)],
    },
    /// Many small MMA units (NVIDIA tensor cores): thin GEMMs are
    /// bound by a device-wide input element-rate (elements/s).
    ManySmall {
        units: usize,
        /// Sustained operand feed, elements/s (calibrated, Table 6).
        feed_rate: f64,
        /// Native tile granularity for utilization ramps.
        tile: usize,
    },
}

pub static H100: DeviceSpec = DeviceSpec {
    device: Device::H100,
    peak_fp8: 1989.9e12,
    peak_bf16: 989.4e12,
    hbm_bw: 3.35e12,
    hbm_cap: 80.0e9,
    vector_flops: 133.8e12, // paper §5.7: BF16 CUDA-core throughput
    has_sfu: true,
    tdp: 700.0,
    idle_w: 90.0,
    engine: MatrixEngine::ManySmall {
        units: 528, // 132 SMs x 4 tensor cores
        feed_rate: 1.05e12,
        tile: 128,
    },
    clock_hz: 1.59e9,
};

pub static GAUDI2: DeviceSpec = DeviceSpec {
    device: Device::Gaudi2,
    peak_fp8: 865.0e12,
    peak_bf16: 432.0e12,
    hbm_bw: 2.4e12,
    hbm_cap: 96.0e9,
    vector_flops: 11.0e12, // paper §5.7: peak TPC BF16
    has_sfu: false,
    tdp: 600.0,
    idle_w: 100.0,
    engine: MatrixEngine::LargeSystolic {
        units: 2,
        pes_per_unit: 256 * 256,
        geometries: &[(256, 256), (128, 512), (512, 128)],
    },
    clock_hz: 1.65e9,
};

pub static GAUDI3: DeviceSpec = DeviceSpec {
    device: Device::Gaudi3,
    peak_fp8: 1835.0e12,
    peak_bf16: 1835.0e12, // Gaudi 3 white paper: BF16 == FP8 peak
    hbm_bw: 3.7e12,
    hbm_cap: 128.0e9,
    vector_flops: 28.7e12, // paper §5.7
    has_sfu: false,
    tdp: 900.0,
    idle_w: 120.0,
    engine: MatrixEngine::LargeSystolic {
        units: 8,
        pes_per_unit: 256 * 256,
        geometries: &[(256, 256), (128, 512), (512, 128)],
    },
    clock_hz: 1.6e9,
};

pub static A100: DeviceSpec = DeviceSpec {
    device: Device::A100,
    peak_fp8: 624.0e12, // no FP8 tensor cores; INT8 rate as stand-in
    peak_bf16: 312.0e12,
    hbm_bw: 2.04e12,
    hbm_cap: 80.0e9,
    vector_flops: 78.0e12,
    has_sfu: true,
    tdp: 400.0,
    idle_w: 60.0,
    engine: MatrixEngine::ManySmall {
        units: 432,
        feed_rate: 0.7e12,
        tile: 128,
    },
    clock_hz: 1.41e9,
};

impl DeviceSpec {
    pub fn peak(&self, dtype: DType) -> f64 {
        match dtype {
            DType::Fp8 => self.peak_fp8,
            DType::Bf16 => self.peak_bf16,
            DType::Fp32 => self.peak_bf16 / 2.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_quoted_specs() {
        // Numbers quoted verbatim in the paper (§3.3 Table 1 caption,
        // §5.2, §5.7).
        assert_eq!(H100.peak_fp8, 1989.9e12);
        assert_eq!(H100.tdp, 700.0);
        assert_eq!(GAUDI2.peak_fp8, 865.0e12);
        assert_eq!(GAUDI2.tdp, 600.0);
        assert_eq!(GAUDI2.hbm_bw, 2.4e12);
        assert_eq!(GAUDI2.vector_flops, 11.0e12);
        assert_eq!(GAUDI3.vector_flops, 28.7e12);
        assert_eq!(H100.vector_flops, 133.8e12);
        assert!(!GAUDI2.has_sfu && H100.has_sfu);
    }

    #[test]
    fn ci_to_saturate_gaudi2_is_360() {
        // §5.2: "a FLOP/byte ratio of at least 360 is required".
        let ci = GAUDI2.peak_fp8 / GAUDI2.hbm_bw;
        assert!((ci - 360.4).abs() < 1.0, "{ci}");
    }

    #[test]
    fn dtype_bytes() {
        assert_eq!(DType::Fp8.bytes(), 1.0);
        assert_eq!(DType::Bf16.bytes(), 2.0);
    }
}
