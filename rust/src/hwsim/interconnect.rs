//! Multi-chip interconnect cost model: scale-up fabrics (NVLink for
//! the NVIDIA parts, on-die RoCE NICs for Gaudi) and scale-out NICs,
//! with latency + bandwidth cost models for the two collectives the
//! parallelism model needs — ring all-reduce (tensor parallelism) and
//! point-to-point activation transfer (pipeline parallelism).
//!
//! The paper's measurements are single-chip; its TCO question is not.
//! A 70B/405B-class model must shard across chips, and the scale-up
//! fabric is where the vendors diverge most sharply: an H100 exposes
//! 900 GB/s of NVLink 4 (450 GB/s per direction) inside an 8-GPU
//! NVSwitch domain, while Gaudi integrates its fabric on the die as
//! RoCE NICs — 24x100 GbE on Gaudi 2, 24x200 GbE on Gaudi 3 — of
//! which 21 ports serve scale-up in the reference HLS server
//! topologies. Everything here is datasheet-level, like `spec.rs`;
//! nothing is calibrated against the paper (which does not measure
//! collectives).

use super::spec::Device;

/// One device's links to the rest of the system.
#[derive(Debug, Clone)]
pub struct InterconnectSpec {
    /// Fabric name for reports.
    pub name: &'static str,
    /// Scale-up bandwidth per device, bytes/s, per direction
    /// (NVLink aggregate or the summed scale-up RoCE ports).
    pub scale_up_bw: f64,
    /// Per-hop scale-up latency (s): link + switch/NIC traversal.
    pub scale_up_lat_s: f64,
    /// Devices reachable at scale-up bandwidth (NVSwitch domain or
    /// the directly cabled HLS box).
    pub scale_up_domain: usize,
    /// Scale-out bandwidth per device (bytes/s, per direction).
    pub scale_out_bw: f64,
    /// Per-hop scale-out latency (s).
    pub scale_out_lat_s: f64,
}

/// NVLink 4 via NVSwitch: 900 GB/s bidirectional per GPU; scale-out
/// over one 400 Gb/s NDR NIC per GPU.
pub static H100_NVLINK4: InterconnectSpec = InterconnectSpec {
    name: "NVLink4",
    scale_up_bw: 450.0e9,
    scale_up_lat_s: 1.0e-6,
    scale_up_domain: 8,
    scale_out_bw: 50.0e9,
    scale_out_lat_s: 5.0e-6,
};

/// NVLink 3: 600 GB/s bidirectional per GPU; 200 Gb/s HDR scale-out.
pub static A100_NVLINK3: InterconnectSpec = InterconnectSpec {
    name: "NVLink3",
    scale_up_bw: 300.0e9,
    scale_up_lat_s: 1.3e-6,
    scale_up_domain: 8,
    scale_out_bw: 25.0e9,
    scale_out_lat_s: 6.0e-6,
};

/// Gaudi 2 on-die RoCE: 24x100 GbE NICs, 21 ports scale-up inside the
/// HLS-2 box (all-to-all), 3 ports scale-out.
pub static GAUDI2_ROCE: InterconnectSpec = InterconnectSpec {
    name: "RoCE-24x100GbE",
    scale_up_bw: 262.5e9, // 21 x 100 Gb/s
    scale_up_lat_s: 3.0e-6,
    scale_up_domain: 8,
    scale_out_bw: 37.5e9, // 3 x 100 Gb/s
    scale_out_lat_s: 6.0e-6,
};

/// Gaudi 3: same topology, 24x200 GbE.
pub static GAUDI3_ROCE: InterconnectSpec = InterconnectSpec {
    name: "RoCE-24x200GbE",
    scale_up_bw: 525.0e9, // 21 x 200 Gb/s
    scale_up_lat_s: 2.5e-6,
    scale_up_domain: 8,
    scale_out_bw: 75.0e9, // 3 x 200 Gb/s
    scale_out_lat_s: 5.0e-6,
};

impl Device {
    pub fn interconnect(self) -> &'static InterconnectSpec {
        match self {
            Device::H100 => &H100_NVLINK4,
            Device::A100 => &A100_NVLINK3,
            Device::Gaudi2 => &GAUDI2_ROCE,
            Device::Gaudi3 => &GAUDI3_ROCE,
        }
    }
}

impl InterconnectSpec {
    /// (bandwidth, latency) governing a collective over `n` devices:
    /// scale-up while the group fits the domain, the scale-out NIC
    /// once the ring must leave the box.
    pub fn group_link(&self, n: usize) -> (f64, f64) {
        if n <= self.scale_up_domain {
            (self.scale_up_bw, self.scale_up_lat_s)
        } else {
            (self.scale_out_bw, self.scale_out_lat_s)
        }
    }

    /// Ring all-reduce of `bytes` payload over `n` devices:
    /// `2(n-1)/n * bytes / bw + 2(n-1) * latency` (reduce-scatter +
    /// all-gather, each n-1 hops). Zero for a single device.
    pub fn allreduce_time_s(&self, n: usize, bytes: f64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let (bw, lat) = self.group_link(n);
        let steps = (n - 1) as f64;
        2.0 * steps / n as f64 * bytes / bw + 2.0 * steps * lat
    }

    /// Point-to-point transfer of `bytes` between adjacent pipeline
    /// stages. `within_scale_up` selects the fabric (stages of one
    /// instance that fit the domain ride scale-up links).
    pub fn p2p_time_s(&self, bytes: f64, within_scale_up: bool) -> f64 {
        let (bw, lat) = if within_scale_up {
            (self.scale_up_bw, self.scale_up_lat_s)
        } else {
            (self.scale_out_bw, self.scale_out_lat_s)
        };
        bytes / bw + lat
    }
}

/// Cross-pool KV-migration link (disaggregated serving: a prefilled
/// request's KV cache moves from the prefill pool to the decode pool).
/// Pools live in different boxes — possibly different vendors — so the
/// transfer always rides the scale-out NICs, never a scale-up fabric.
#[derive(Debug, Clone, Copy)]
pub struct KvLink {
    /// Effective migration bandwidth (bytes/s): the slower endpoint's
    /// aggregate scale-out NICs across its instance's chips (each chip
    /// streams its own KV shard in parallel).
    pub bw: f64,
    /// Fixed per-migration latency (s): one NIC hop on each side.
    pub lat_s: f64,
}

impl KvLink {
    /// Derive the link between a prefill instance of `src_chips` chips
    /// on the `src` fabric and a decode instance of `dst_chips` chips
    /// on `dst`. Bandwidth is min of the two endpoints' aggregate
    /// scale-out NICs; latency is the sum of the two per-hop terms.
    pub fn between(
        src: &InterconnectSpec,
        src_chips: usize,
        dst: &InterconnectSpec,
        dst_chips: usize,
    ) -> KvLink {
        let src_bw = src.scale_out_bw * src_chips.max(1) as f64;
        let dst_bw = dst.scale_out_bw * dst_chips.max(1) as f64;
        KvLink {
            bw: src_bw.min(dst_bw),
            lat_s: src.scale_out_lat_s + dst.scale_out_lat_s,
        }
    }

    /// The infinite-bandwidth, zero-latency limit: migration is free,
    /// and disaggregated serving must reproduce the colocated request
    /// timeline exactly (the equivalence the property tests pin).
    pub fn infinite() -> KvLink {
        KvLink { bw: f64::INFINITY, lat_s: 0.0 }
    }

    /// Closed-form migration time: `bytes / bw + lat`. Zero bytes cost
    /// nothing (nothing crossed the fabric). Mirrored in
    /// `python/tests/test_kv_transfer_mirror.py` — keep the arithmetic
    /// order identical when editing.
    pub fn transfer_time_s(&self, bytes: f64) -> f64 {
        if bytes <= 0.0 {
            return 0.0;
        }
        bytes / self.bw + self.lat_s
    }

    /// Chunked/layerwise streaming schedule for a migration of
    /// `bytes` split into `chunks` equal pieces. Chunks are serialized
    /// on the link and each pays the per-chunk closed form
    /// `chunk_bytes / bw + lat`, so chunk `i` (0-based) lands at
    /// [`ChunkedTransfer::chunk_done_s`]`(i)` after the stream starts.
    /// The payoff is overlap: the decode side may start on layer `l`
    /// once chunks `0..=l` have landed, so the first token travels
    /// with chunk 0 at a fraction of the single-shot delay, while the
    /// total stream time `bytes/bw + chunks*lat` is monotone
    /// non-decreasing in the chunk count (each extra chunk pays one
    /// more fixed latency). `chunks = 1` reproduces
    /// [`KvLink::transfer_time_s`] bit-exactly — the limit the property
    /// tests pin. Mirrored in
    /// `python/tests/test_kv_transfer_mirror.py`; keep the arithmetic
    /// order identical when editing.
    pub fn chunked(&self, bytes: f64, chunks: usize) -> ChunkedTransfer {
        ChunkedTransfer {
            bytes,
            chunks: chunks.max(1),
            bw: self.bw,
            lat_s: self.lat_s,
        }
    }

    /// A link uniformly scaled in bandwidth (sensitivity sweeps).
    pub fn scaled_bw(&self, ratio: f64) -> KvLink {
        KvLink { bw: self.bw * ratio, lat_s: self.lat_s }
    }

    /// The same link with a different fixed latency (TTFT monotonicity
    /// experiments).
    pub fn with_latency(&self, lat_s: f64) -> KvLink {
        KvLink { bw: self.bw, lat_s }
    }
}

/// A KV migration streamed as `chunks` equal pieces over one
/// [`KvLink`] (see [`KvLink::chunked`]). Zero-byte transfers land
/// instantly regardless of chunking (nothing crossed the fabric).
#[derive(Debug, Clone, Copy)]
pub struct ChunkedTransfer {
    pub bytes: f64,
    pub chunks: usize,
    bw: f64,
    lat_s: f64,
}

impl ChunkedTransfer {
    /// Completion offset (s from stream start) of chunk `i` (0-based):
    /// `bytes*(i+1)/chunks / bw + (i+1)*lat`. The leading factor keeps
    /// the last chunk's byte term exactly `bytes / bw` (no remainder
    /// drift), so `chunks = 1` matches the single-shot closed form
    /// bit-for-bit.
    pub fn chunk_done_s(&self, i: usize) -> f64 {
        assert!(i < self.chunks, "chunk {i} of {}", self.chunks);
        if self.bytes <= 0.0 {
            return 0.0;
        }
        let k = (i + 1) as f64;
        self.bytes * k / self.chunks as f64 / self.bw + k * self.lat_s
    }

    /// When the first chunk (and the first token riding with it) lands
    /// — the overlap win: strictly earlier than the single-shot
    /// `transfer_time_s` whenever `chunks > 1` at finite bandwidth.
    pub fn first_time_s(&self) -> f64 {
        self.chunk_done_s(0)
    }

    /// When the last chunk lands: `bytes/bw + chunks*lat`, monotone
    /// non-decreasing in the chunk count.
    pub fn total_time_s(&self) -> f64 {
        self.chunk_done_s(self.chunks - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_device_collectives_are_free() {
        for dev in Device::ALL {
            let ic = dev.interconnect();
            assert_eq!(ic.allreduce_time_s(1, 1e9), 0.0);
            assert_eq!(ic.allreduce_time_s(0, 1e9), 0.0);
        }
    }

    #[test]
    fn allreduce_monotone_in_bytes_and_devices() {
        let ic = Device::H100.interconnect();
        assert!(ic.allreduce_time_s(4, 2e6) > ic.allreduce_time_s(4, 1e6));
        assert!(ic.allreduce_time_s(8, 1e6) > ic.allreduce_time_s(2, 1e6));
    }

    #[test]
    fn latency_floor_dominates_tiny_payloads() {
        // A 1 KB all-reduce is pure latency on every fabric.
        let ic = Device::Gaudi2.interconnect();
        let t = ic.allreduce_time_s(8, 1024.0);
        let lat_only = 2.0 * 7.0 * ic.scale_up_lat_s;
        assert!(t < lat_only * 1.1, "{t} vs {lat_only}");
        assert!(t >= lat_only);
    }

    #[test]
    fn nvlink_beats_gaudi2_roce_on_bandwidth_and_latency() {
        // The fabric asymmetry the multi-chip TCO story hinges on.
        let h = Device::H100.interconnect();
        let g = Device::Gaudi2.interconnect();
        assert!(h.scale_up_bw > g.scale_up_bw);
        assert!(h.scale_up_lat_s < g.scale_up_lat_s);
        let bytes = 64.0 * 4096.0 * 2.0; // a decode-batch activation
        assert!(h.allreduce_time_s(4, bytes) < g.allreduce_time_s(4, bytes));
    }

    #[test]
    fn gaudi3_fabric_doubles_gaudi2() {
        let g2 = Device::Gaudi2.interconnect();
        let g3 = Device::Gaudi3.interconnect();
        assert!((g3.scale_up_bw / g2.scale_up_bw - 2.0).abs() < 1e-9);
    }

    #[test]
    fn leaving_the_scale_up_domain_costs() {
        let ic = Device::H100.interconnect();
        let inside = ic.allreduce_time_s(8, 1e6);
        let outside = ic.allreduce_time_s(9, 1e6);
        assert!(outside > inside * 2.0, "{outside} vs {inside}");
        assert!(ic.p2p_time_s(1e6, false) > ic.p2p_time_s(1e6, true));
    }

    #[test]
    fn kv_link_bottlenecked_by_slower_endpoint() {
        let h = Device::H100.interconnect();
        let g = Device::Gaudi2.interconnect();
        let l = KvLink::between(h, 1, g, 1);
        assert_eq!(l.bw, g.scale_out_bw, "Gaudi2 NIC is the bottleneck");
        assert_eq!(l.lat_s, h.scale_out_lat_s + g.scale_out_lat_s);
        // A wider source instance cannot lift a single-chip sink.
        let l4 = KvLink::between(h, 4, g, 1);
        assert_eq!(l4.bw, l.bw);
        // Widening the sink does.
        let l44 = KvLink::between(h, 4, g, 4);
        assert!(l44.bw > l.bw);
    }

    #[test]
    fn kv_transfer_closed_form_and_limits() {
        let l = KvLink { bw: 37.5e9, lat_s: 1.1e-5 };
        let bytes = 512.0 * 131072.0; // 512 tokens of llama-8b BF16 KV
        let t = l.transfer_time_s(bytes);
        assert!((t - (bytes / 37.5e9 + 1.1e-5)).abs() < 1e-15);
        // Monotone in bytes; latency floor for tiny payloads.
        assert!(l.transfer_time_s(2.0 * bytes) > t);
        assert!(l.transfer_time_s(1.0) >= l.lat_s);
        // Nothing migrated costs nothing.
        assert_eq!(l.transfer_time_s(0.0), 0.0);
        // The infinite link is free for any payload.
        assert_eq!(KvLink::infinite().transfer_time_s(1e18), 0.0);
        // Sensitivity helpers.
        assert!(l.scaled_bw(10.0).transfer_time_s(bytes) < t);
        assert!(l.with_latency(1e-3).transfer_time_s(bytes) > t);
    }

    #[test]
    fn chunked_single_chunk_is_the_closed_form_bit_exactly() {
        let l = KvLink { bw: 37.5e9, lat_s: 1.1e-5 };
        for bytes in [1.0, 512.0 * 131072.0, 4096.0 * 327680.0] {
            let single = l.transfer_time_s(bytes);
            let c = l.chunked(bytes, 1);
            assert_eq!(c.first_time_s().to_bits(), single.to_bits());
            assert_eq!(c.total_time_s().to_bits(), single.to_bits());
        }
    }

    #[test]
    fn chunked_schedule_orders_and_limits() {
        let l = KvLink { bw: 50.0e9, lat_s: 1.0e-5 };
        let bytes = 2048.0 * 131072.0;
        let c = l.chunked(bytes, 8);
        // Chunks land strictly in order.
        for i in 1..8 {
            assert!(c.chunk_done_s(i) > c.chunk_done_s(i - 1));
        }
        // First chunk strictly beats single-shot at finite bandwidth;
        // total stream time is monotone non-decreasing in chunk count.
        let single = l.transfer_time_s(bytes);
        assert!(c.first_time_s() < single);
        let mut prev = 0.0;
        for n in 1..=32 {
            let total = l.chunked(bytes, n).total_time_s();
            assert!(total >= prev, "total not monotone at {n} chunks");
            assert!(total >= single, "chunking must not beat the wire");
            prev = total;
        }
        // Zero bytes land instantly however finely chunked.
        assert_eq!(l.chunked(0.0, 16).total_time_s(), 0.0);
        // The infinite link collapses the whole schedule to t=0.
        let free = KvLink::infinite().chunked(bytes, 8);
        assert_eq!(free.first_time_s(), 0.0);
        assert_eq!(free.total_time_s(), 0.0);
    }

    #[test]
    fn chunked_closed_form_pinned_against_python_mirror() {
        // (bytes via model table, bw, lat) cases mirrored in
        // python/tests/test_kv_transfer_mirror.py — both sides pin the
        // same first/total values so neither can drift alone.
        let cases: [(f64, f64, f64, usize, f64, f64); 2] = [
            // llama-8b ctx 2048, H100 -> H100, 4 chunks.
            (2048.0 * 131072.0, 50.0e9, 1.0e-5, 4, 0.00135217728, 0.00540870912),
            // llama-70b ctx 4096, H100 x4 -> Gaudi2 x1, 8 chunks.
            (
                4096.0 * 327680.0,
                37.5e9,
                1.1e-5,
                8,
                0.0044849242666666666,
                0.03587939413333333,
            ),
        ];
        for (bytes, bw, lat_s, chunks, first, total) in cases {
            let c = KvLink { bw, lat_s }.chunked(bytes, chunks);
            assert!((c.first_time_s() / first - 1.0).abs() < 1e-12);
            assert!((c.total_time_s() / total - 1.0).abs() < 1e-12);
        }
    }
}
