//! Calibration suite: asserts that the simulators reproduce the
//! *shape* of every hwsim-backed paper table (DESIGN.md acceptance:
//! same winner, crossovers within one grid step, ratios within ~±30%).
//!
//! Cells are checked as ratios/orderings, not absolute TFLOPS — the
//! substrate is a model, not the authors' testbed.

use super::gemm::{gemm_time, GemmConfig};
use super::power::power_draw_w;
use super::spec::{Accum, Device, Scaling};

fn tf(dev: Device, m: usize, k: usize, n: usize, cfg: GemmConfig) -> f64 {
    gemm_time(dev, m, k, n, cfg).tflops()
}

fn fp8_row(dev: Device) -> GemmConfig {
    let accum = match dev {
        Device::H100 | Device::A100 => Accum::Fast,
        _ => Accum::Fp32,
    };
    GemmConfig::fp8(Scaling::PerRow, accum)
}

/// Table 1: square FP8 GEMM, row-wise scaling. Paper (TFLOPS):
/// Gaudi2: 1K 367.9, 2K 586.2, 4K 817.1, 8K 741.8 (ratios 42-95%)
/// H100:   1K 218.3, 2K 879.7, 4K 1167.6, 8K 1084.7 (11-59%)
#[test]
fn table1_shape() {
    // Utilization rises steeply with size on both devices.
    for dev in [Device::Gaudi2, Device::H100] {
        let t1 = tf(dev, 1024, 1024, 1024, fp8_row(dev));
        let t4 = tf(dev, 4096, 4096, 4096, fp8_row(dev));
        assert!(t4 > 1.8 * t1, "{}: 1K {t1} 4K {t4}", dev.name());
    }
    // Gaudi wins at 1K, H100 wins at 4K+ (absolute TFLOPS).
    let g1 = tf(Device::Gaudi2, 1024, 1024, 1024, fp8_row(Device::Gaudi2));
    let h1 = tf(Device::H100, 1024, 1024, 1024, fp8_row(Device::H100));
    assert!(g1 > h1, "1K: gaudi {g1} h100 {h1}");
    let g4 = tf(Device::Gaudi2, 4096, 4096, 4096, fp8_row(Device::Gaudi2));
    let h4 = tf(Device::H100, 4096, 4096, 4096, fp8_row(Device::H100));
    assert!(h4 > g4, "4K: gaudi {g4} h100 {h4}");
    // Gaudi achieves much higher MFU at every size.
    for s in [1024usize, 2048, 4096, 8192] {
        let gm = gemm_time(Device::Gaudi2, s, s, s, fp8_row(Device::Gaudi2)).mfu;
        let hm = gemm_time(Device::H100, s, s, s, fp8_row(Device::H100)).mfu;
        assert!(gm > hm, "{s}: gaudi mfu {gm} h100 {hm}");
    }
}

/// Table 1 power columns: Gaudi stays below TDP; H100 pegs.
#[test]
fn table1_power_shape() {
    // At the utilizations the model achieves for 4K squares:
    let g = gemm_time(Device::Gaudi2, 4096, 4096, 4096, fp8_row(Device::Gaudi2));
    let h = gemm_time(Device::H100, 4096, 4096, 4096, fp8_row(Device::H100));
    let pg = power_draw_w(Device::Gaudi2, g.mfu);
    let ph = power_draw_w(Device::H100, h.mfu);
    assert!(pg < 0.85 * 600.0, "gaudi {pg} W");
    assert!(ph > 0.90 * 700.0, "h100 {ph} W");
    // TFLOPS/W comparable at 4K (paper: 1.8 vs 1.7).
    let eff_g = g.tflops() / pg;
    let eff_h = h.tflops() / ph;
    assert!((eff_g / eff_h) > 0.7 && (eff_g / eff_h) < 2.0, "{eff_g} {eff_h}");
}

/// Table 2: Gaudi 2 scaling strategies. Orderings:
/// per-row <= per-tensor <= hw-accel, gap shrinking toward 1K.
#[test]
fn table2_shape() {
    for s in [2048usize, 4096, 8192] {
        let row = tf(Device::Gaudi2, s, s, s,
                     GemmConfig::fp8(Scaling::PerRow, Accum::Fp32));
        let tensor = tf(Device::Gaudi2, s, s, s,
                        GemmConfig::fp8(Scaling::PerTensor, Accum::Fp32));
        let hw = tf(Device::Gaudi2, s, s, s,
                    GemmConfig::fp8(Scaling::HwPow2, Accum::Fp32));
        assert!(row < tensor && tensor <= hw, "{s}: {row} {tensor} {hw}");
        // paper 8K: row/tensor = 742/822 = 0.90
        if s == 8192 {
            let r = row / tensor;
            assert!(r > 0.78 && r < 0.97, "8K row/tensor {r}");
        }
    }
    // 8K per-tensor reaches >= 90% MFU (paper 95%).
    let bd = gemm_time(Device::Gaudi2, 8192, 8192, 8192,
                       GemmConfig::fp8(Scaling::PerTensor, Accum::Fp32));
    assert!(bd.mfu > 0.85, "mfu {}", bd.mfu);
}

/// Table 3: H100 accumulation paths.
/// FP32-accum per-row plateaus ~20%; fast accum per-row ~57%;
/// per-tensor ~66-70%; per-row beats per-tensor at 1K, loses at 8K.
#[test]
fn table3_shape() {
    let mfu = |scaling, accum, s: usize| {
        gemm_time(Device::H100, s, s, s, GemmConfig::fp8(scaling, accum)).mfu
    };
    // plateaus at 8K
    let row32 = mfu(Scaling::PerRow, Accum::Fp32, 8192);
    assert!(row32 > 0.13 && row32 < 0.27, "{row32}");
    let rowfast = mfu(Scaling::PerRow, Accum::Fast, 8192);
    assert!(rowfast > 0.45 && rowfast < 0.62, "{rowfast}");
    let tensorfast = mfu(Scaling::PerTensor, Accum::Fast, 8192);
    assert!(tensorfast > 0.60 && tensorfast < 0.75, "{tensorfast}");
    assert!(row32 < rowfast && rowfast < tensorfast);
    // crossover: per-row wins at 1K, per-tensor at 8K (fast accum).
    assert!(mfu(Scaling::PerRow, Accum::Fast, 1024)
            > mfu(Scaling::PerTensor, Accum::Fast, 1024));
    assert!(mfu(Scaling::PerRow, Accum::Fast, 8192)
            < mfu(Scaling::PerTensor, Accum::Fast, 8192));
}

/// Table 6: thin GEMMs. Checked in detail in gemm::tests; here the
/// cross-device absolute ordering on every paper shape.
#[test]
fn table6_shape() {
    for (m, kn) in [(8usize, 1024usize), (16, 1024), (32, 1024), (64, 1024),
                    (8, 2048), (16, 2048), (32, 2048), (64, 2048),
                    (8, 4096), (16, 4096), (32, 4096), (64, 4096)] {
        let gb = tf(Device::Gaudi2, m, kn, kn, GemmConfig::bf16());
        let hb = tf(Device::H100, m, kn, kn, GemmConfig::bf16());
        assert!(gb > hb, "bf16 ({m},{kn}): gaudi {gb} h100 {hb}");
        let gf = tf(Device::Gaudi2, m, kn, kn, fp8_row(Device::Gaudi2));
        let hf = tf(Device::H100, m, kn, kn, fp8_row(Device::H100));
        assert!(gf > hf, "fp8 ({m},{kn}): gaudi {gf} h100 {hf}");
    }
    // FP8:BF16 ~2x on Gaudi at 4K thin; ~1x on H100 (Fig. 6 / §5.6).
    let g_gain = tf(Device::Gaudi2, 64, 4096, 4096, fp8_row(Device::Gaudi2))
        / tf(Device::Gaudi2, 64, 4096, 4096, GemmConfig::bf16());
    assert!(g_gain > 1.4 && g_gain < 2.2, "gaudi thin gain {g_gain}");
    let h_gain = tf(Device::H100, 64, 4096, 4096, fp8_row(Device::H100))
        / tf(Device::H100, 64, 4096, 4096, GemmConfig::bf16());
    assert!(h_gain < 1.25, "h100 thin gain {h_gain}");
}

/// Within ±35% of the paper's absolute numbers on the anchor cells
/// used for calibration (sanity that the model is in the right world,
/// not just ordered correctly).
#[test]
fn absolute_anchors_within_tolerance() {
    let cases: &[(Device, usize, usize, usize, GemmConfig, f64)] = &[
        // Table 2 per-tensor E4M3 (Gaudi): 8K -> 822 TFLOPS.
        (Device::Gaudi2, 8192, 8192, 8192,
         GemmConfig::fp8(Scaling::PerTensor, Accum::Fp32), 822.0),
        // Table 2 per-tensor 4K -> 796.
        (Device::Gaudi2, 4096, 4096, 4096,
         GemmConfig::fp8(Scaling::PerTensor, Accum::Fp32), 796.0),
        // Table 3 fast per-tensor 8K -> 1388.
        (Device::H100, 8192, 8192, 8192,
         GemmConfig::fp8(Scaling::PerTensor, Accum::Fast), 1388.0),
        // Table 3 fast per-row 8K -> 1123.
        (Device::H100, 8192, 8192, 8192,
         GemmConfig::fp8(Scaling::PerRow, Accum::Fast), 1123.0),
        // Table 6 thin (64, 4096, 4096) BF16: Gaudi 144.5, H100 133.3.
        (Device::Gaudi2, 64, 4096, 4096, GemmConfig::bf16(), 144.5),
        (Device::H100, 64, 4096, 4096, GemmConfig::bf16(), 133.3),
        // Table 6 thin FP8: Gaudi 253.4.
        (Device::Gaudi2, 64, 4096, 4096,
         GemmConfig::fp8(Scaling::PerRow, Accum::Fp32), 253.4),
    ];
    for &(dev, m, k, n, cfg, paper) in cases {
        let got = tf(dev, m, k, n, cfg);
        let rel = got / paper;
        assert!(
            (0.65..=1.35).contains(&rel),
            "{} {m}x{k}x{n}: model {got:.0} vs paper {paper} (x{rel:.2})",
            dev.name()
        );
    }
}
