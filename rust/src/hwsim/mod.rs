//! Hardware performance simulators for the paper's testbed.
//!
//! The paper measures NVIDIA H100 and Intel Gaudi 2 silicon; neither is
//! available here (repro band 0), so this module implements
//! first-principles timing/power models of both accelerators built from
//! exactly the architectural mechanisms the paper uses to *explain* its
//! measurements (§3.2, §5.6, §5.7, Figs. 6–8):
//!
//! * **Gaudi 2** — two large 256×256 output-stationary systolic MMEs
//!   with reconfigurable geometry (Fig. 8), fill/drain pipeline
//!   overhead, FP32 accumulation, HBM *byte-rate* bound for streaming
//!   workloads, TPC vector cores (11 TFLOPS BF16) with **no SFU** —
//!   exponentials run on the TPCs (§5.7).
//! * **H100** — 132 SMs × 4 tensor cores (many small units): thin GEMMs
//!   are bound by the per-unit input *element-rate* (so FP8 ≈ BF16 on
//!   thin GEMMs, §5.6), accumulation-path caps for FP8 (14-bit fast
//!   accum vs FP32 promotion, §3.2), SFUs that hide softmax (§5.7).
//!
//! Every calibrated constant lives in [`calib`] with a pointer to the
//! paper table it reproduces; everything else is first-principles.

pub mod calib;
pub mod gemm;
pub mod interconnect;
pub mod mme;
pub mod power;
pub mod softmax;
pub mod spec;

pub use gemm::{gemm_time, GemmBreakdown, GemmConfig};
pub use interconnect::{ChunkedTransfer, InterconnectSpec, KvLink};
pub use power::{power_draw_w, PowerCap};
pub use spec::{Accum, Device, DeviceSpec, DType, Scaling};

#[cfg(test)]
mod calibration_tests;
