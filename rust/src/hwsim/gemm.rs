//! The GEMM timing model — the simulator's core primitive.
//!
//! `time = overhead + max(t_compute, t_hbm, t_feed) (+ t_quant)`
//!
//! * `t_compute` — matrix-engine time: the MME pipeline model for
//!   Gaudi ([`super::mme`]) or peak × ramp × cap for H100's
//!   tensor-core families ([`super::calib`]).
//! * `t_hbm` — operand + result bytes over sustained HBM bandwidth
//!   (*byte-rate* bound: this is where FP8 halves time).
//! * `t_feed` — operand *elements* over the engine's feed rate
//!   (*element-rate* bound: FP8 does NOT help; binds thin GEMMs on the
//!   many-small-unit H100 — the paper's §5.6 mechanism).
//! * `t_quant` — dynamic row-wise activation quantization where it
//!   cannot overlap the matrix engine (Gaudi TPC pass).

use super::calib;
use super::mme;
use super::spec::{Accum, DType, Device, MatrixEngine, Scaling};

/// Configuration of one GEMM invocation.
#[derive(Debug, Clone, Copy)]
pub struct GemmConfig {
    pub dtype: DType,
    /// FP8 scaling strategy (ignored for BF16/FP32).
    pub scaling: Scaling,
    /// FP8 accumulation path (ignored for BF16/FP32; Gaudi is always
    /// FP32 — paper §3.2).
    pub accum: Accum,
}

impl GemmConfig {
    pub fn bf16() -> Self {
        GemmConfig { dtype: DType::Bf16, scaling: Scaling::PerTensor, accum: Accum::Fp32 }
    }

    pub fn fp8(scaling: Scaling, accum: Accum) -> Self {
        GemmConfig { dtype: DType::Fp8, scaling, accum }
    }
}

/// Timing decomposition of one GEMM.
#[derive(Debug, Clone, Copy)]
pub struct GemmBreakdown {
    pub seconds: f64,
    pub t_compute: f64,
    pub t_hbm: f64,
    pub t_feed: f64,
    pub t_quant: f64,
    pub t_launch: f64,
    pub flops: f64,
    /// Achieved fraction of the device's dense peak for this dtype
    /// (the paper's MFU, §3.3).
    pub mfu: f64,
}

impl GemmBreakdown {
    pub fn tflops(&self) -> f64 {
        self.flops / self.seconds / 1e12
    }

    /// Which constraint binds (for reports/ablation).
    pub fn bound_by(&self) -> &'static str {
        let m = self.t_compute.max(self.t_hbm).max(self.t_feed);
        if m == self.t_compute {
            "compute"
        } else if m == self.t_hbm {
            "hbm"
        } else {
            "feed"
        }
    }
}

/// Time an (M,K,N) GEMM: `C[M,N] = A[M,K] @ B[K,N]`.
pub fn gemm_time(dev: Device, m: usize, k: usize, n: usize, cfg: GemmConfig) -> GemmBreakdown {
    let spec = dev.spec();
    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    let in_bytes = (m * k + k * n) as f64 * cfg.dtype.bytes();
    let out_bytes = (m * n) as f64 * 2.0; // BF16-class results
    let in_elems = (m * k + k * n) as f64;

    let t_hbm = (in_bytes + out_bytes) / (spec.hbm_bw * calib::hbm_stream_eff(dev));

    let (t_compute, t_feed) = match &spec.engine {
        MatrixEngine::LargeSystolic { units, geometries, .. } => {
            let macs = mme::macs_per_pe(spec, cfg.dtype);
            let timing = mme::mme_cycles(m, k, n, *units, geometries, macs);
            let cap = match cfg.dtype {
                DType::Fp8 => calib::mfu_cap_fp8(dev, cfg.scaling, Accum::Fp32),
                _ => calib::mfu_cap_bf16(dev),
            };
            let t_c = timing.cycles / spec.clock_hz / cap;
            // Feed rate follows the chosen geometry: the array consumes
            // (rows + cols) operand elements per cycle per MME.
            let (rows, cols) = timing.geometry;
            let feed_rate = *units as f64 * (rows + cols) as f64 * spec.clock_hz;
            (t_c, in_elems / feed_rate)
        }
        MatrixEngine::ManySmall { feed_rate, tile, .. } => {
            let cap = match cfg.dtype {
                DType::Fp8 => calib::mfu_cap_fp8(dev, cfg.scaling, cfg.accum),
                _ => calib::mfu_cap_bf16(dev),
            };
            // The feed bound is element-granular to first order, but
            // FP8 operands pack the smem/register stage slightly
            // better; row-wise kernels use narrower tiles that waste
            // fewer slots (Table 6: H100 FP8 thin gains of 0-18%).
            let feed_rate = feed_rate
                * match (cfg.dtype, cfg.scaling) {
                    (DType::Fp8, Scaling::PerRow) => 1.12,
                    (DType::Fp8, _) => 1.05,
                    _ => 1.0,
                };
            // Utilization ramp over effective matrix size: pipeline
            // depth grows with all three dims, but thin-M waste below
            // one tile is captured separately by the tile-alignment
            // factor (§5.2: "multiples of 128"), so M saturates there.
            let m_eff = (m.max(*tile)) as f64;
            let s_eff = (m_eff * k as f64 * n as f64).cbrt();
            let mid = calib::h100_ramp_midpoint(cfg.scaling, cfg.dtype);
            let ramp = 1.0 / (1.0 + (mid / s_eff).powf(calib::H100_RAMP_POWER));
            let align = ceil_frac(m, *tile).max(0.25) * ceil_frac(n, *tile).max(0.25);
            let eff = (cap * ramp * align).max(1e-4);
            (flops / (spec.peak(cfg.dtype) * eff), in_elems / feed_rate)
        }
    };

    // Dynamic row-wise quantization pass (activations only, M x K).
    let t_quant = if cfg.dtype == DType::Fp8 && cfg.scaling == Scaling::PerRow {
        match dev {
            Device::Gaudi2 | Device::Gaudi3 => {
                (m * k) as f64 / calib::GAUDI_TPC_QUANT_RATE
            }
            // H100 fuses the amax pass into the epilogue of the
            // previous kernel; residual cost folded into the mfu cap.
            _ => 0.0,
        }
    } else {
        0.0
    };

    let t_launch = calib::launch_overhead(dev);
    let body = t_compute.max(t_hbm).max(t_feed);
    let seconds = t_launch + body + t_quant;
    GemmBreakdown {
        seconds,
        t_compute,
        t_hbm,
        t_feed,
        t_quant,
        t_launch,
        flops,
        mfu: flops / seconds / spec.peak(cfg.dtype),
    }
}

/// Fraction of a dimension that is useful after padding to `tile`.
fn ceil_frac(dim: usize, tile: usize) -> f64 {
    let padded = dim.div_ceil(tile) * tile;
    dim as f64 / padded as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tflops(dev: Device, m: usize, k: usize, n: usize, cfg: GemmConfig) -> f64 {
        gemm_time(dev, m, k, n, cfg).tflops()
    }

    #[test]
    fn large_square_fp8_near_cap() {
        // Table 2 8K row: Gaudi 2 per-tensor ~95% of 865 TFLOPS.
        let t = tflops(Device::Gaudi2, 8192, 8192, 8192,
                       GemmConfig::fp8(Scaling::PerTensor, Accum::Fp32));
        assert!(t > 750.0 && t < 865.0, "{t}");
        // Table 3 8K: H100 per-tensor fast accum ~70% of 1990.
        let t = tflops(Device::H100, 8192, 8192, 8192,
                       GemmConfig::fp8(Scaling::PerTensor, Accum::Fast));
        assert!(t > 1150.0 && t < 1550.0, "{t}");
    }

    #[test]
    fn gaudi_beats_h100_at_1k() {
        // Table 1: Gaudi 2 367.9 vs H100 218.3 at 1K (row-wise).
        let g = tflops(Device::Gaudi2, 1024, 1024, 1024,
                       GemmConfig::fp8(Scaling::PerRow, Accum::Fp32));
        let h = tflops(Device::H100, 1024, 1024, 1024,
                       GemmConfig::fp8(Scaling::PerRow, Accum::Fast));
        assert!(g > h, "gaudi {g} h100 {h}");
    }

    #[test]
    fn h100_fp32_accum_rowwise_capped_low() {
        // Table 3: per-row FP32-accum plateaus near 20% MFU.
        let bd = gemm_time(Device::H100, 8192, 8192, 8192,
                           GemmConfig::fp8(Scaling::PerRow, Accum::Fp32));
        assert!(bd.mfu > 0.12 && bd.mfu < 0.25, "{}", bd.mfu);
    }

    #[test]
    fn thin_gemm_fp8_gain_gaudi_not_h100() {
        // The §5.6 headline: Gaudi FP8 ~2x BF16 on thin GEMMs, H100 ~1x.
        let shapes = [(32usize, 2048usize, 2048usize), (64, 2048, 2048), (64, 4096, 4096)];
        for (m, k, n) in shapes {
            let gb = tflops(Device::Gaudi2, m, k, n, GemmConfig::bf16());
            let gf = tflops(Device::Gaudi2, m, k, n,
                            GemmConfig::fp8(Scaling::PerRow, Accum::Fp32));
            let hb = tflops(Device::H100, m, k, n, GemmConfig::bf16());
            let hf = tflops(Device::H100, m, k, n,
                            GemmConfig::fp8(Scaling::PerRow, Accum::Fast));
            let g_gain = gf / gb;
            let h_gain = hf / hb;
            assert!(g_gain > 1.35, "gaudi gain {g_gain} at {m}x{k}x{n}");
            assert!(h_gain < 1.25, "h100 gain {h_gain} at {m}x{k}x{n}");
            // Gaudi wins thin GEMMs outright (Table 6).
            assert!(gb > hb && gf > hf, "{m}x{k}x{n}: {gb} {hb} / {gf} {hf}");
        }
    }

    #[test]
    fn thin_gemm_scales_linearly_with_m() {
        // Table 6: "throughput scales linearly with M on both devices"
        // i.e. time is ~constant in M.
        for dev in [Device::Gaudi2, Device::H100] {
            let t8 = gemm_time(dev, 8, 4096, 4096, GemmConfig::bf16()).seconds;
            let t64 = gemm_time(dev, 64, 4096, 4096, GemmConfig::bf16()).seconds;
            assert!(t64 / t8 < 1.6, "{} {t8} {t64}", dev.name());
        }
    }

    #[test]
    fn rowwise_slower_than_tensorwise_large_gaudi() {
        // Table 2 8K: 742 vs 822 TFLOPS.
        let r = tflops(Device::Gaudi2, 8192, 8192, 8192,
                       GemmConfig::fp8(Scaling::PerRow, Accum::Fp32));
        let t = tflops(Device::Gaudi2, 8192, 8192, 8192,
                       GemmConfig::fp8(Scaling::PerTensor, Accum::Fp32));
        assert!(r < t, "{r} {t}");
        assert!(r / t > 0.80 && r / t < 0.97, "{}", r / t);
    }

    #[test]
    fn h100_rowwise_beats_tensorwise_small() {
        // Table 3 fast-accum 1K: 237 (row) vs 147 (tensor) — row-wise
        // kernels ramp earlier; Fig. 5's "dynamic beats static on
        // H100 decode" relies on this.
        let r = tflops(Device::H100, 1024, 1024, 1024,
                       GemmConfig::fp8(Scaling::PerRow, Accum::Fast));
        let t = tflops(Device::H100, 1024, 1024, 1024,
                       GemmConfig::fp8(Scaling::PerTensor, Accum::Fast));
        assert!(r > t, "{r} {t}");
        // ...and loses at 8K.
        let r8 = tflops(Device::H100, 8192, 8192, 8192,
                        GemmConfig::fp8(Scaling::PerRow, Accum::Fast));
        let t8 = tflops(Device::H100, 8192, 8192, 8192,
                        GemmConfig::fp8(Scaling::PerTensor, Accum::Fast));
        assert!(r8 < t8, "{r8} {t8}");
    }

    #[test]
    fn hw_pow2_fastest_gaudi_path() {
        // Table 2: HW-accelerated scaling is the best Gaudi column.
        let hw = tflops(Device::Gaudi2, 8192, 8192, 8192,
                        GemmConfig::fp8(Scaling::HwPow2, Accum::Fp32));
        let pt = tflops(Device::Gaudi2, 8192, 8192, 8192,
                        GemmConfig::fp8(Scaling::PerTensor, Accum::Fp32));
        assert!(hw >= pt, "{hw} {pt}");
    }

    #[test]
    fn mfu_never_exceeds_one() {
        for dev in Device::ALL {
            for (m, k, n) in [(8, 1024, 1024), (4096, 4096, 4096), (1, 64, 64)] {
                for cfg in [GemmConfig::bf16(),
                            GemmConfig::fp8(Scaling::PerRow, Accum::Fast)] {
                    let bd = gemm_time(dev, m, k, n, cfg);
                    assert!(bd.mfu <= 1.0 + 1e-9, "{} {}", dev.name(), bd.mfu);
                    assert!(bd.seconds > 0.0);
                }
            }
        }
    }

    #[test]
    fn breakdown_identifies_binding_constraint() {
        // Thin GEMM on H100 must be feed-bound; big square compute-bound.
        let thin = gemm_time(Device::H100, 32, 4096, 4096,
                             GemmConfig::fp8(Scaling::PerRow, Accum::Fast));
        assert_eq!(thin.bound_by(), "feed");
        let big = gemm_time(Device::H100, 8192, 8192, 8192,
                            GemmConfig::fp8(Scaling::PerTensor, Accum::Fast));
        assert_eq!(big.bound_by(), "compute");
        // Thin BF16 on Gaudi is HBM-byte-bound (that's why FP8 helps).
        let gthin = gemm_time(Device::Gaudi2, 32, 4096, 4096, GemmConfig::bf16());
        assert_eq!(gthin.bound_by(), "hbm");
    }
}
