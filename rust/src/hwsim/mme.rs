//! Gaudi MME model: output-stationary systolic array with
//! reconfigurable geometry (paper Figs. 7–8).
//!
//! The MME holds an output tile of `rows x cols` PEs. Computing one
//! output tile against a K-deep reduction takes `K` cycles of
//! streaming plus a fill/drain bubble of `rows + cols` cycles (the
//! wavefront must enter and leave the array). The graph compiler picks
//! the folding (256×256, 128×512, 512×128 on Gaudi 2) that minimizes
//! total cycles for the GEMM at hand — this is what gives Gaudi its
//! superior small/thin-matrix utilization (§5.6).

use super::spec::{DType, DeviceSpec, MatrixEngine};

/// MACs/PE/cycle implied by the datasheet peak for this dtype.
pub fn macs_per_pe(spec: &DeviceSpec, dtype: DType) -> f64 {
    match &spec.engine {
        MatrixEngine::LargeSystolic { units, pes_per_unit, .. } => {
            spec.peak(dtype)
                / (*units as f64 * *pes_per_unit as f64 * 2.0 * spec.clock_hz)
        }
        MatrixEngine::ManySmall { .. } => 1.0,
    }
}

/// Cycles for an (M,K,N) GEMM on one set of systolic arrays.
#[derive(Debug, Clone, Copy)]
pub struct MmeTiming {
    pub cycles: f64,
    /// Geometry chosen by the (modelled) graph compiler.
    pub geometry: (usize, usize),
    /// Fraction of PE-cycles doing useful MACs.
    pub utilization: f64,
}

/// Model the MME array for a single GEMM.
///
/// `units`: number of MMEs; `geometries`: allowed (rows, cols)
/// foldings; `macs_per_pe`: MACs each PE retires per cycle at this
/// dtype. Derived from the datasheet peak so the engine-implied peak
/// is identical to the spec by construction (Gaudi 2: 1.0 BF16 /
/// 2.0 FP8 — each PE packs two FP8 MACs, which is exactly how its
/// FP8 peak is 2× BF16).
pub fn mme_cycles(
    m: usize,
    k: usize,
    n: usize,
    units: usize,
    geometries: &[(usize, usize)],
    macs_per_pe: f64,
) -> MmeTiming {
    let fp8_boost = macs_per_pe;
    let mut best: Option<MmeTiming> = None;
    for &(rows, cols) in geometries {
        // Output tiles needed (M maps to rows, N to cols).
        let tiles_m = m.div_ceil(rows);
        let tiles_n = n.div_ceil(cols);
        let tiles = (tiles_m * tiles_n) as f64;
        // Tiles are distributed across MMEs.
        let tiles_per_unit = (tiles / units as f64).ceil();
        // Each tile: K cycles of streaming + fill/drain bubble.
        // FP8 packs 2 MACs/PE/cycle -> halves the streaming cycles.
        let stream = (k as f64 / fp8_boost).max(1.0);
        let bubble = (rows + cols) as f64;
        let cycles = tiles_per_unit * (stream + bubble);
        let useful = (m * n) as f64 * (k as f64 / fp8_boost);
        let capacity = cycles * (units * rows * cols) as f64;
        let utilization = (useful / capacity).min(1.0);
        let t = MmeTiming { cycles, geometry: (rows, cols), utilization };
        if best.as_ref().map_or(true, |b| t.cycles < b.cycles) {
            best = Some(t);
        }
    }
    best.expect("no geometries")
}

#[cfg(test)]
mod tests {
    use super::*;

    const GEOS: &[(usize, usize)] = &[(256, 256), (128, 512), (512, 128)];

    #[test]
    fn square_large_reaches_high_utilization() {
        let t = mme_cycles(8192, 8192, 8192, 2, GEOS, 1.0);
        assert!(t.utilization > 0.9, "util {}", t.utilization);
    }

    #[test]
    fn pipeline_bubble_hurts_small_k() {
        let small = mme_cycles(1024, 1024, 1024, 2, GEOS, 1.0);
        let large = mme_cycles(8192, 8192, 8192, 2, GEOS, 1.0);
        assert!(small.utilization < large.utilization);
        // 1K square: K/(K + bubble) = 1024/1536 = 2/3.
        assert!((small.utilization - 0.66).abs() < 0.05, "{}", small.utilization);
    }

    #[test]
    fn thin_gemm_prefers_folded_geometry() {
        // M=64 wastes 3/4 of a 256-row array; the 128-row folding
        // halves the waste (Fig. 8 reconfiguration).
        let t = mme_cycles(64, 4096, 4096, 2, GEOS, 1.0);
        assert_eq!(t.geometry, (128, 512));
        let fixed = mme_cycles(64, 4096, 4096, 2, &[(256, 256)], 1.0);
        assert!(t.cycles < fixed.cycles);
    }

    #[test]
    fn fp8_doubles_throughput_when_pipelined() {
        let b = mme_cycles(4096, 4096, 4096, 2, GEOS, 1.0);
        let f = mme_cycles(4096, 4096, 4096, 2, GEOS, 2.0);
        let speedup = b.cycles / f.cycles;
        assert!(speedup > 1.7 && speedup <= 2.0, "speedup {speedup}");
    }


    #[test]
    fn macs_per_pe_matches_datasheet() {
        use super::super::spec::{GAUDI2, GAUDI3};
        // Gaudi 2: 1 BF16 MAC and 2 FP8 MACs per PE per cycle.
        assert!((macs_per_pe(&GAUDI2, DType::Bf16) - 1.0).abs() < 0.02);
        assert!((macs_per_pe(&GAUDI2, DType::Fp8) - 2.0).abs() < 0.02);
        // Gaudi 3: FP8 peak == BF16 peak (white paper).
        let b = macs_per_pe(&GAUDI3, DType::Bf16);
        let f = macs_per_pe(&GAUDI3, DType::Fp8);
        assert!((b - f).abs() < 1e-9);
    }
    #[test]
    fn tiles_round_up() {
        // 300x300 output needs 2x2 tiles of 256x256.
        let t = mme_cycles(300, 512, 300, 1, &[(256, 256)], 1.0);
        assert!((t.cycles - 4.0 * (512.0 + 512.0)).abs() < 1e-6);
    }
}
