//! LLM phase performance model: composes the Eq. 3–6 workload
//! accounting with the hwsim GEMM/attention/softmax/power models to
//! time one prefill or one batched decode step on a simulated device.
//!
//! Precision accounting follows §5.2 exactly: block linears run at the
//! configured precision, the LM head and attention stay BF16, KV cache
//! dtype is configurable (BF16 default).
//!
//! Multi-chip accounting (DESIGN.md §6): tensor parallelism adds two
//! ring all-reduces per layer (post-attention, post-MLP) over the
//! device's scale-up fabric ([`crate::hwsim::interconnect`]); pipeline
//! parallelism splits layers into `pp` stages fed by `microbatches`
//! microbatches, paying per-hop activation transfers and the classic
//! fill/drain bubble `(pp-1)/(pp-1+microbatches)`. At `tp=1, pp=1`
//! both terms are exactly zero and the step reproduces the paper's
//! single-chip model bit-for-bit.

use super::parallel::ParallelismPlan;
use crate::hwsim::calib;
use crate::hwsim::gemm::{gemm_time, GemmConfig};
use crate::hwsim::power::{self, PowerCap};
use crate::hwsim::softmax;
use crate::hwsim::spec::{Accum, Device, Scaling};
use crate::workload::llama::LlamaConfig;

/// Precision of the block linears.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PrecisionMode {
    Bf16,
    Fp8 { scaling: Scaling, accum: Accum },
}

impl PrecisionMode {
    pub fn fp8_dynamic() -> Self {
        PrecisionMode::Fp8 { scaling: Scaling::PerRow, accum: Accum::Fast }
    }

    pub fn fp8_static() -> Self {
        PrecisionMode::Fp8 { scaling: Scaling::Static, accum: Accum::Fast }
    }

    pub fn gemm_cfg(self) -> GemmConfig {
        match self {
            PrecisionMode::Bf16 => GemmConfig::bf16(),
            PrecisionMode::Fp8 { scaling, accum } => GemmConfig::fp8(scaling, accum),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PrecisionMode::Bf16 => "bf16",
            PrecisionMode::Fp8 { scaling: Scaling::PerRow, .. } => "fp8-dynamic",
            PrecisionMode::Fp8 { scaling: Scaling::Static, .. } => "fp8-static",
            PrecisionMode::Fp8 { scaling: Scaling::PerTensor, .. } => "fp8-tensor",
            PrecisionMode::Fp8 { scaling: Scaling::HwPow2, .. } => "fp8-hw",
        }
    }

    /// Resident bytes/element of the block-linear weights (FP8 halves
    /// them; the embedding/LM head stays BF16 either way — capacity
    /// checks account for that via `weight_bytes_mixed`).
    pub fn weight_bytes_per_elem(self) -> f64 {
        match self {
            PrecisionMode::Bf16 => 2.0,
            PrecisionMode::Fp8 { .. } => 1.0,
        }
    }
}

/// One simulated model execution setup.
#[derive(Debug, Clone)]
pub struct StepConfig {
    pub device: Device,
    pub precision: PrecisionMode,
    /// Tensor-parallel degree (shards heads / intermediate / vocab).
    pub tp: usize,
    /// Pipeline-parallel degree (shards layers into stages).
    pub pp: usize,
    /// Microbatches fed through the pipeline per step; 0 = auto
    /// (`pp`, i.e. just enough to keep every stage busy once filled).
    /// Ignored when `pp == 1`.
    pub microbatches: usize,
    /// KV-cache element bytes (2.0 = BF16, 1.0 = FP8 KV).
    pub kv_bytes: f64,
    pub power_cap: PowerCap,
    /// Effective HBM bandwidth multiplier in (0, 1] — fault
    /// injection's degraded mode (thermal throttling, partial-HBM
    /// fault). Applies to the KV-cache streaming term, the HBM-bound
    /// path of a decode step; compute-bound GEMM time is unaffected.
    /// `1.0` (healthy) is a bit-exact identity: `x * 1.0 == x` in
    /// IEEE 754, so un-derated runs reproduce pre-fault-layer bits.
    pub hbm_derate_frac: f64,
}

impl StepConfig {
    pub fn new(device: Device, precision: PrecisionMode) -> Self {
        StepConfig {
            device,
            precision,
            tp: 1,
            pp: 1,
            microbatches: 0,
            kv_bytes: 2.0,
            power_cap: PowerCap::None,
            hbm_derate_frac: 1.0,
        }
    }

    pub fn with_hbm_derate(mut self, frac: f64) -> Self {
        debug_assert!(frac > 0.0 && frac <= 1.0, "derate {frac} outside (0, 1]");
        self.hbm_derate_frac = frac;
        self
    }

    pub fn with_cap(mut self, watts: f64) -> Self {
        self.power_cap = PowerCap::PerGpu(watts);
        self
    }

    pub fn with_tp(mut self, tp: usize) -> Self {
        self.tp = tp;
        self
    }

    pub fn with_pp(mut self, pp: usize) -> Self {
        self.pp = pp;
        self
    }

    pub fn with_microbatches(mut self, mb: usize) -> Self {
        self.microbatches = mb;
        self
    }

    /// Adopt a [`ParallelismPlan`]'s shard shape (replicas are a
    /// cluster-level concern — `sharded_sim_cluster` consumes them —
    /// and do not alter one instance's step).
    pub fn with_plan(mut self, plan: ParallelismPlan) -> Self {
        self.tp = plan.tp.max(1);
        self.pp = plan.pp.max(1);
        self
    }
}

/// Timing decomposition of one phase step. Work terms (`t_linears_s`
/// .. `t_lm_head_s`) are per TP shard over the full batch and all
/// layers; `seconds` is the end-to-end instance latency including TP
/// collectives and the PP pipeline (fill/drain bubble + activation
/// hops). At `tp=1, pp=1` the comm terms are zero and `seconds`
/// equals the single-chip model the paper measures.
#[derive(Debug, Clone)]
pub struct StepBreakdown {
    /// Total step latency (s), post power-cap, including comm.
    pub seconds: f64,
    pub t_linears_s: f64,
    pub t_attention_kv_s: f64,
    pub t_softmax_s: f64,
    pub t_lm_head_s: f64,
    /// Time in TP ring all-reduces (2 per layer), whole step.
    pub t_tp_comm_s: f64,
    /// Time in PP activation transfers along the pipeline.
    pub t_pp_comm_s: f64,
    /// Pipeline bubble fraction `(pp-1)/(pp-1+microbatches)`; 0 when
    /// `pp == 1`.
    pub pp_bubble_frac: f64,
    /// Model FLOPs executed per chip (Eq. 3/6 over tp * pp shards).
    pub flops: f64,
    /// Achieved model throughput (FLOP/s, per chip).
    pub achieved_flops: f64,
    /// Average matrix-engine utilization driving the power model.
    pub util_frac: f64,
    /// Average power draw (W, per chip while busy).
    pub watts: f64,
}

impl StepBreakdown {
    pub fn tflops(&self) -> f64 {
        self.achieved_flops / 1e12
    }
}

/// Work-time decomposition of one decode pass (no comm, no cap).
struct DecodeWork {
    t_raw: f64,
    t_lin: f64,
    t_kv: f64,
    t_exp: f64,
    t_head: f64,
    lin_compute_frac_acc: f64,
}

fn decode_work(m: &LlamaConfig, cfg: &StepConfig, batch: usize, seq: usize) -> DecodeWork {
    let tp = cfg.tp.max(1);
    let h = m.hidden;
    // GQA: KV heads shard at most kv_heads ways; TP beyond that
    // replicates them (same rule as the capacity model).
    let kv_shard = tp.min(m.kv_heads).max(1);
    let kv_dim = m.kv_heads * m.head_dim() / kv_shard;
    let inter = m.intermediate / tp;
    let gcfg = cfg.precision.gemm_cfg();

    // --- block linears (per layer), M = batch (thin GEMM, §5.6).
    let shapes = [
        (batch, h, h / tp),      // wq
        (batch, h, kv_dim),      // wk
        (batch, h, kv_dim),      // wv
        (batch, h / tp, h),      // wo
        (batch, h, inter),       // w_gate
        (batch, h, inter),       // w_up
        (batch, inter, h),       // w_down
    ];
    let mut t_lin = 0.0;
    let mut lin_compute_frac_acc = 0.0;
    for (mm, kk, nn) in shapes {
        let bd = gemm_time(cfg.device, mm, kk, nn, gcfg);
        t_lin += bd.seconds;
        lin_compute_frac_acc += bd.seconds
            * if bd.bound_by() == "hbm" { 0.0 } else { 1.0 };
    }
    t_lin *= m.layers as f64;
    lin_compute_frac_acc *= m.layers as f64;

    // --- attention: stream each sequence's KV cache (memory-bound,
    // CI bounded by g — §5.2), plus the thin score/PV GEMMs.
    let spec = cfg.device.spec();
    // Per-chip KV shard bytes = 2 * b * s * kv_dim * kv_bytes.
    let kv_bytes_layer =
        2.0 * batch as f64 * seq as f64 * kv_dim as f64 * cfg.kv_bytes;
    let t_kv_layer = kv_bytes_layer
        / (spec.hbm_bw * calib::hbm_stream_eff(cfg.device) * cfg.hbm_derate_frac);
    let t_kv = t_kv_layer * m.layers as f64;

    // --- softmax exponentials (§5.7): b*s*heads per layer; SFU
    // devices overlap them with the layer's matrix work.
    let heads = m.heads / tp;
    let n_exp = softmax::decode_exp_count(batch, seq, heads) * m.layers as f64;
    let overlap = t_lin + t_kv;
    let t_exp = softmax::exp_time(cfg.device, n_exp, overlap);

    // --- LM head (BF16 — §5.2).
    let head = gemm_time(cfg.device, batch, h, m.vocab / tp, GemmConfig::bf16());
    let t_head = head.seconds;

    DecodeWork {
        t_raw: t_lin + t_kv + t_exp + t_head,
        t_lin,
        t_kv,
        t_exp,
        t_head,
        lin_compute_frac_acc,
    }
}

/// Time one batched decode step: `batch` sequences, each with context
/// length `seq` (uniform, the paper's measurement setup).
pub fn decode_step(m: &LlamaConfig, cfg: &StepConfig, batch: usize, seq: usize) -> StepBreakdown {
    let tp = cfg.tp.max(1);
    let w = decode_work(m, cfg, batch, seq);

    let lens = vec![seq; batch];
    let flops = m.decode_step_flops(&lens) / tp as f64;
    let spec = cfg.device.spec();
    let peak = match cfg.precision {
        PrecisionMode::Bf16 => spec.peak_bf16,
        PrecisionMode::Fp8 { .. } => spec.peak_fp8,
    };
    let util = (flops / w.t_raw / peak).min(1.0);
    let compute_frac = (w.lin_compute_frac_acc + w.t_exp) / w.t_raw;

    // A decode microbatch RE-TIMES the thin GEMMs at the smaller M:
    // decode is weight-streaming bound, so splitting the batch barely
    // shrinks per-microbatch time (the weights stream again) — which
    // is exactly why PP microbatching does not buy decode latency.
    let mb = resolve_mb(cfg, batch);
    let t_work_mb_raw = if cfg.pp.max(1) == 1 {
        w.t_raw
    } else {
        decode_work(m, cfg, batch.div_ceil(mb), seq).t_raw
    };

    let comm = CommShape { tokens: batch, hidden: m.hidden, layers: m.layers, mb, t_work_mb_raw };
    finish(cfg, w.t_raw, util, compute_frac, flops, w.t_lin, w.t_kv, w.t_exp, w.t_head, comm)
}

/// Time one prefill of `batch` sequences of length `seq`.
pub fn prefill(m: &LlamaConfig, cfg: &StepConfig, batch: usize, seq: usize) -> StepBreakdown {
    let tp = cfg.tp.max(1);
    let h = m.hidden;
    // GQA: same KV-shard saturation rule as decode/capacity.
    let kv_shard = tp.min(m.kv_heads).max(1);
    let kv_dim = m.kv_heads * m.head_dim() / kv_shard;
    let inter = m.intermediate / tp;
    let gcfg = cfg.precision.gemm_cfg();
    let mm = batch * seq; // token-parallel GEMMs (compute-bound, §5.3)

    let shapes = [
        (mm, h, h / tp),
        (mm, h, kv_dim),
        (mm, h, kv_dim),
        (mm, h / tp, h),
        (mm, h, inter),
        (mm, h, inter),
        (mm, inter, h),
    ];
    let mut t_lin = 0.0;
    for (a, b, c) in shapes {
        t_lin += gemm_time(cfg.device, a, b, c, gcfg).seconds;
    }
    t_lin *= m.layers as f64;

    // Attention GEMMs (QK^T and PV), causal-halved, BF16: batched as
    // heads*batch GEMMs of (s, d, s); one fused kernel per layer.
    let d = m.head_dim();
    let heads = m.heads / tp;
    let per_head = gemm_time(cfg.device, seq, d, seq, GemmConfig::bf16());
    let body = per_head.seconds - per_head.t_launch;
    let t_attn_layer =
        body * (heads * batch) as f64 * 2.0 * 0.5 + per_head.t_launch;
    let t_attn = t_attn_layer * m.layers as f64;

    let n_exp = softmax::prefill_exp_count(batch, seq, heads) * m.layers as f64;
    let overlap = t_lin + t_attn;
    let t_exp = softmax::exp_time(cfg.device, n_exp, overlap);

    let head = gemm_time(cfg.device, mm, h, m.vocab / tp, GemmConfig::bf16());
    let t_head = head.seconds;

    let t_raw = t_lin + t_attn + t_exp + t_head;
    let flops = batch as f64 * m.prefill_flops(seq) / tp as f64;
    let spec = cfg.device.spec();
    let peak = match cfg.precision {
        PrecisionMode::Bf16 => spec.peak_bf16,
        PrecisionMode::Fp8 { .. } => spec.peak_fp8,
    };
    let util = (flops / t_raw / peak).min(1.0);
    // Prefill is essentially all compute-bound, so a microbatch of
    // 1/mb of the tokens takes ~1/mb of the time — no re-timing pass
    // needed (unlike decode, where weights re-stream per microbatch).
    let mb = resolve_mb(cfg, mm);
    let comm = CommShape {
        tokens: mm,
        hidden: h,
        layers: m.layers,
        mb,
        t_work_mb_raw: t_raw / mb as f64,
    };
    finish(cfg, t_raw, util, 0.95, flops, t_lin, t_attn, t_exp, t_head, comm)
}

/// Microbatch count: `pp` by default (fills the pipeline exactly
/// once), clamped to the available tokens; always 1 when `pp == 1`.
fn resolve_mb(cfg: &StepConfig, tokens: usize) -> usize {
    let pp = cfg.pp.max(1);
    if pp == 1 {
        1
    } else {
        let want = if cfg.microbatches > 0 { cfg.microbatches } else { pp };
        want.clamp(1, tokens.max(1))
    }
}

/// Activation geometry the collectives move (`tokens` rows of
/// `hidden` BF16 activations, twice per layer for TP, once per stage
/// hop for PP) plus the pipeline's microbatching: `mb` microbatches,
/// each costing `t_work_mb_raw` seconds of whole-model work.
struct CommShape {
    tokens: usize,
    hidden: usize,
    layers: usize,
    mb: usize,
    t_work_mb_raw: f64,
}

#[allow(clippy::too_many_arguments)]
fn finish(
    cfg: &StepConfig,
    t_raw: f64,
    util: f64,
    compute_frac: f64,
    flops: f64,
    t_lin: f64,
    t_kv: f64,
    t_exp: f64,
    t_head: f64,
    comm: CommShape,
) -> StepBreakdown {
    // Power capping slows the on-chip work; collectives ride the
    // fabric and are unaffected.
    let (t_work, watts) = match cfg.power_cap {
        PowerCap::None => (t_raw, power::power_draw_w(cfg.device, util)),
        PowerCap::PerGpu(w) => {
            let capped = power::apply_cap(cfg.device, w, t_raw, util, compute_frac);
            (capped.seconds, capped.watts)
        }
        PowerCap::PerRack { watts, gpus } => {
            // The step model times ONE chip of a uniform deployment:
            // every sibling runs the same step, so each demands the
            // same uncapped draw. Routing through `rack_allocation`
            // (§5.5 water-filling) instead of a hand-rolled even split
            // keeps this arm consistent with the skew-aware frontier:
            // under uniform demand the allocation degenerates to the
            // even share exactly (headroom → full demand; deficit →
            // `watts / gpus`), while non-uniform rack sharing is
            // modelled at the deployment layer
            // (`tco::rack::rack_capped_per_gpu_w`), which sees real
            // per-pool demand.
            let p0 = power::power_draw_w(cfg.device, util);
            let demands = vec![p0; gpus.max(1)];
            let alloc = power::rack_allocation(watts, &demands);
            let per = alloc.first().copied().unwrap_or(watts);
            let capped = power::apply_cap(cfg.device, per, t_raw, util, compute_frac);
            (capped.seconds, capped.watts)
        }
    };

    let tp = cfg.tp.max(1);
    let pp = cfg.pp.max(1);
    let ic = cfg.device.interconnect();
    let chips = tp * pp;

    let mb = comm.mb.max(1);
    let tokens_per_mb = comm.tokens.div_ceil(mb);
    let act_bytes = tokens_per_mb as f64 * comm.hidden as f64 * 2.0;

    // TP: two ring all-reduces per layer (post-attention projection,
    // post-MLP down projection) along one microbatch's traversal of
    // the whole model.
    let t_tp_mb = if tp > 1 {
        2.0 * comm.layers as f64 * ic.allreduce_time_s(tp, act_bytes)
    } else {
        0.0
    };

    // The power cap stretches on-chip work; apply the same stretch to
    // the re-timed microbatch work (collectives ride the fabric and
    // are unaffected).
    let stretch = if t_raw > 0.0 { t_work / t_raw } else { 1.0 };

    // PP: store-and-forward pipeline over `pp` stages. Each of the
    // (mb + pp - 1) slots costs one stage's share of a microbatch's
    // work + TP comm, plus one activation hop; the fill/drain slots
    // are the bubble. All reported comm is the critical-path share,
    // so the terms stay commensurate with `seconds`.
    let (seconds, t_tp_comm, t_pp_comm, pp_bubble_frac) = if pp == 1 {
        (t_work + t_tp_mb, t_tp_mb, 0.0, 0.0)
    } else {
        let hop = ic.p2p_time_s(act_bytes, chips <= ic.scale_up_domain);
        let slots = (mb + pp - 1) as f64;
        let ppf = pp as f64;
        let slot_time = (comm.t_work_mb_raw * stretch + t_tp_mb) / ppf + hop;
        (
            slots * slot_time,
            slots * t_tp_mb / ppf,
            slots * hop,
            (pp - 1) as f64 / slots,
        )
    };

    let flops_per_chip = flops / pp as f64;
    StepBreakdown {
        seconds,
        t_linears_s: t_lin,
        t_attention_kv_s: t_kv,
        t_softmax_s: t_exp,
        t_lm_head_s: t_head,
        t_tp_comm_s: t_tp_comm,
        t_pp_comm_s: t_pp_comm,
        pp_bubble_frac,
        flops: flops_per_chip,
        achieved_flops: flops_per_chip / seconds,
        util_frac: util,
        watts,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::llama::by_name;

    fn m8b() -> &'static LlamaConfig {
        by_name("llama-8b").unwrap()
    }

    #[test]
    fn prefill_h100_roughly_2x_gaudi() {
        // Fig. 4: H100 reaches ~2x Gaudi 2 prefill TFLOPS on 8B.
        let h = prefill(m8b(), &StepConfig::new(Device::H100, PrecisionMode::fp8_static()), 1, 4096);
        let g = prefill(m8b(), &StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()), 1, 4096);
        let ratio = h.tflops() / g.tflops();
        assert!(ratio > 1.4 && ratio < 2.8, "ratio {ratio}");
    }

    #[test]
    fn decode_fp8_gain_gaudi_over_1_5x_h100_under_1_25x() {
        // Fig. 5's headline at batch 64.
        let b = 64;
        let s = 1024;
        let gb = decode_step(m8b(), &StepConfig::new(Device::Gaudi2, PrecisionMode::Bf16), b, s);
        let gf = decode_step(m8b(), &StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()), b, s);
        let hb = decode_step(m8b(), &StepConfig::new(Device::H100, PrecisionMode::Bf16), b, s);
        let hf = decode_step(m8b(), &StepConfig::new(Device::H100, PrecisionMode::fp8_dynamic()), b, s);
        let g_gain = gb.seconds / gf.seconds;
        let h_gain = hb.seconds / hf.seconds;
        assert!(g_gain >= 1.3, "gaudi gain {g_gain}");
        assert!(h_gain <= 1.25, "h100 gain {h_gain}");
    }

    #[test]
    fn gaudi_fp8_decode_competitive_with_h100() {
        // §5.4: "Gaudi 2 with FP8 achieves comparable decode throughput
        // to the H100, despite significantly lower peak GEMM".
        let b = 64;
        let s = 1024;
        let g = decode_step(m8b(), &StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()), b, s);
        let h = decode_step(m8b(), &StepConfig::new(Device::H100, PrecisionMode::fp8_dynamic()), b, s);
        let ratio = g.seconds / h.seconds;
        assert!(ratio < 1.3, "gaudi/h100 step time {ratio}");
    }

    #[test]
    fn gaudi_advantage_shrinks_with_sequence_length() {
        // §5.7 / Fig. 3: Gaudi's decode edge diminishes at long s.
        let b = 64;
        let short_ratio = {
            let g = decode_step(m8b(), &StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()), b, 256);
            let h = decode_step(m8b(), &StepConfig::new(Device::H100, PrecisionMode::fp8_dynamic()), b, 256);
            h.seconds / g.seconds
        };
        let long_ratio = {
            let g = decode_step(m8b(), &StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()), b, 8192);
            let h = decode_step(m8b(), &StepConfig::new(Device::H100, PrecisionMode::fp8_dynamic()), b, 8192);
            h.seconds / g.seconds
        };
        assert!(long_ratio < short_ratio, "short {short_ratio} long {long_ratio}");
    }

    #[test]
    fn decode_unaffected_by_400w_cap() {
        // §5.5 / Fig. 3: decode shows no deterioration at 400 W.
        let free = decode_step(m8b(), &StepConfig::new(Device::H100, PrecisionMode::fp8_dynamic()), 64, 2048);
        let capped = decode_step(
            m8b(),
            &StepConfig::new(Device::H100, PrecisionMode::fp8_dynamic()).with_cap(400.0),
            64,
            2048,
        );
        let slowdown = capped.seconds / free.seconds;
        assert!(slowdown < 1.10, "slowdown {slowdown}");
        assert!(capped.watts <= 400.0 + 1e-6);
    }

    #[test]
    fn prefill_hurt_by_400w_cap_on_h100() {
        let free = prefill(m8b(), &StepConfig::new(Device::H100, PrecisionMode::fp8_static()), 1, 4096);
        let capped = prefill(
            m8b(),
            &StepConfig::new(Device::H100, PrecisionMode::fp8_static()).with_cap(400.0),
            1,
            4096,
        );
        assert!(capped.seconds > free.seconds * 1.1, "{} vs {}", capped.seconds, free.seconds);
    }

    #[test]
    fn per_rack_uniform_demand_degenerates_to_even_share() {
        // One chip of a uniform deployment: water-filling over equal
        // demands must reproduce the even split bit-for-bit, deficit
        // and headroom alike.
        let base = StepConfig::new(Device::H100, PrecisionMode::fp8_static());
        let mut deficit = base.clone();
        deficit.power_cap = PowerCap::PerRack { watts: 8.0 * 400.0, gpus: 8 };
        let rack = prefill(m8b(), &deficit, 1, 4096);
        let even = prefill(m8b(), &base.clone().with_cap(400.0), 1, 4096);
        assert_eq!(rack.seconds.to_bits(), even.seconds.to_bits());
        assert_eq!(rack.watts.to_bits(), even.watts.to_bits());
        let mut roomy = base.clone();
        roomy.power_cap = PowerCap::PerRack { watts: 8.0 * 900.0, gpus: 8 };
        let free = prefill(m8b(), &base, 1, 4096);
        let uncapped = prefill(m8b(), &roomy, 1, 4096);
        assert_eq!(uncapped.seconds.to_bits(), free.seconds.to_bits());
        assert_eq!(uncapped.watts.to_bits(), free.watts.to_bits());
    }

    #[test]
    fn skewed_rack_lets_hot_chip_borrow_idle_headroom() {
        // §5.5's point, end to end through the step model: one chip
        // prefilling flat-out beside seven lightly loaded siblings
        // under an 8 x 400 W rack budget. Water-filling satisfies the
        // siblings' sub-400 W demands fully and hands the hot chip the
        // leftovers — more than its even share — so its capped step is
        // strictly faster than under a per-GPU 400 W cap.
        let base = StepConfig::new(Device::H100, PrecisionMode::fp8_static());
        let hot = prefill(m8b(), &base, 1, 4096);
        let p_hot = power::power_draw_w(Device::H100, hot.util_frac);
        let p_light = power::power_draw_w(Device::H100, 0.15);
        assert!(p_light < 400.0, "sibling demand must sit under the even share");
        let mut demands = vec![p_light; 8];
        demands[0] = p_hot;
        let alloc = power::rack_allocation(8.0 * 400.0, &demands);
        assert!(
            alloc[0] > 400.0,
            "hot chip must borrow past the even share: {}",
            alloc[0]
        );
        assert!(alloc[0] <= p_hot + 1e-9, "never granted more than demanded");
        let borrowed = prefill(m8b(), &base.clone().with_cap(alloc[0]), 1, 4096);
        let even = prefill(m8b(), &base.clone().with_cap(400.0), 1, 4096);
        assert!(
            borrowed.seconds < even.seconds,
            "borrowed headroom must buy prefill time: {} vs {}",
            borrowed.seconds,
            even.seconds
        );
    }

    #[test]
    fn tp_shards_reduce_per_device_time() {
        let t1 = decode_step(m8b(), &StepConfig::new(Device::H100, PrecisionMode::fp8_dynamic()), 32, 1024);
        let t4 = decode_step(
            m8b(),
            &StepConfig::new(Device::H100, PrecisionMode::fp8_dynamic()).with_tp(4),
            32,
            1024,
        );
        assert!(t4.seconds < t1.seconds);
    }

    #[test]
    fn larger_models_prefill_higher_mfu() {
        // Fig. 4: "clear trend of improved prefill throughput for
        // larger models".
        let cfg = StepConfig::new(Device::H100, PrecisionMode::fp8_static());
        let t1 = prefill(by_name("llama-1b").unwrap(), &cfg, 1, 4096);
        let t70 = prefill(by_name("llama-70b").unwrap(), &cfg, 1, 4096);
        assert!(t70.tflops() > t1.tflops(), "{} vs {}", t70.tflops(), t1.tflops());
    }

    #[test]
    fn breakdown_sums() {
        let bd = decode_step(m8b(), &StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()), 16, 512);
        let sum = bd.t_linears_s + bd.t_attention_kv_s + bd.t_softmax_s + bd.t_lm_head_s;
        assert!((sum / bd.seconds - 1.0).abs() < 1e-9);
    }
}
