//! Roofline and MFU helpers (paper §3.3, §5.2).

use crate::hwsim::spec::{DType, DeviceSpec};

/// Roofline throughput (FLOP/s) at a given computational intensity
/// (FLOP/byte): min(peak, CI × BW).
pub fn roofline_flops(spec: &DeviceSpec, dtype: DType, ci: f64) -> f64 {
    (ci * spec.hbm_bw).min(spec.peak(dtype))
}

/// Model FLOP Utilization: achieved / peak (§3.3).
pub fn mfu(achieved_flops_per_s: f64, spec: &DeviceSpec, dtype: DType) -> f64 {
    achieved_flops_per_s / spec.peak(dtype)
}

/// CI required to saturate compute (the paper's "360 FLOP/byte on
/// Gaudi 2 FP8").
pub fn saturation_ci(spec: &DeviceSpec, dtype: DType) -> f64 {
    spec.peak(dtype) / spec.hbm_bw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwsim::spec::{DType, GAUDI2, H100};

    #[test]
    fn roofline_clamps_at_peak() {
        let r = roofline_flops(&GAUDI2, DType::Fp8, 1e6);
        assert_eq!(r, GAUDI2.peak_fp8);
        let r = roofline_flops(&GAUDI2, DType::Fp8, 10.0);
        assert_eq!(r, 10.0 * GAUDI2.hbm_bw);
    }

    #[test]
    fn paper_saturation_ci() {
        // §5.2: ~360 FLOP/byte on Gaudi 2 FP8.
        let ci = saturation_ci(&GAUDI2, DType::Fp8);
        assert!((ci - 360.4).abs() < 1.0);
        // H100 needs even more (1989.9/3.35 ≈ 594).
        let ci_h = saturation_ci(&H100, DType::Fp8);
        assert!(ci_h > 550.0);
    }

    #[test]
    fn mfu_of_peak_is_one() {
        assert!((mfu(H100.peak_fp8, &H100, DType::Fp8) - 1.0).abs() < 1e-12);
    }
}
