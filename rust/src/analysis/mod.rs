//! Analysis layer: roofline/MFU math (§5.2) and the LLM phase
//! performance model that composes `workload` FLOPs with `hwsim`
//! device timing to produce the paper's Figures 2–6.

pub mod perfmodel;
pub mod roofline;

pub use perfmodel::{decode_step, prefill, PrecisionMode, StepBreakdown, StepConfig};
pub use roofline::{mfu, roofline_flops};
