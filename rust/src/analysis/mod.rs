//! Analysis layer: roofline/MFU math (§5.2), the LLM phase
//! performance model that composes `workload` FLOPs with `hwsim`
//! device timing to produce the paper's Figures 2–6, and the
//! multi-chip parallelism planner (TP/PP sharding + HBM capacity
//! feasibility) that extends the model to deployment scale, and the
//! disaggregated prefill/decode pool planner (`disagg`) that splits a
//! deployment into phase-specialized — possibly mixed-vendor — pools.

pub mod disagg;
pub mod parallel;
pub mod perfmodel;
pub mod roofline;

pub use disagg::{auto_size, DisaggPlan, PhaseAffinityPlan, PoolSpec};
pub use parallel::{
    auto_plan, check_capacity, check_step, CapacityError, CapacityFit, ParallelismPlan,
    DEFAULT_MIN_KV_TOKENS,
};
pub use perfmodel::{decode_step, prefill, PrecisionMode, StepBreakdown, StepConfig};
pub use roofline::{mfu, roofline_flops};
