//! Multi-chip parallelism planning: TP/PP sharding shapes and the HBM
//! capacity model that decides whether a (model x device x plan)
//! deployment is feasible at all.
//!
//! The seed perf model divided work by `tp` while ignoring collectives
//! and never consulted `DeviceSpec::hbm_cap`, so infeasible single-chip
//! 70B configs simulated happily. This module is the typed gate: every
//! place a `StepConfig`/`EngineConfig` is built for a real deployment
//! goes through [`check_capacity`] (weights/shard + KV budget vs. HBM)
//! or [`auto_plan`], and gets a [`CapacityError`] instead of a silent
//! impossible simulation.

use std::fmt;

use crate::hwsim::spec::Device;
use crate::workload::llama::LlamaConfig;

/// How one model instance is sharded across chips. One instance =
/// `tp * pp` chips acting as a single engine; `replicas` independent
/// instances serve behind the router.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ParallelismPlan {
    /// Tensor-parallel degree (shards heads / intermediate / vocab).
    pub tp: usize,
    /// Pipeline-parallel degree (shards layers into stages).
    pub pp: usize,
    /// Independent data-parallel replicas of the sharded instance.
    pub replicas: usize,
}

impl Default for ParallelismPlan {
    fn default() -> Self {
        ParallelismPlan { tp: 1, pp: 1, replicas: 1 }
    }
}

impl fmt::Display for ParallelismPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "tp{}", self.tp)?;
        if self.pp > 1 {
            write!(f, "-pp{}", self.pp)?;
        }
        if self.replicas > 1 {
            write!(f, "-x{}", self.replicas)?;
        }
        Ok(())
    }
}

impl ParallelismPlan {
    pub fn single() -> Self {
        ParallelismPlan::default()
    }

    pub fn tp(tp: usize) -> Self {
        ParallelismPlan { tp, pp: 1, replicas: 1 }
    }

    pub fn new(tp: usize, pp: usize) -> Self {
        ParallelismPlan { tp, pp, replicas: 1 }
    }

    pub fn with_replicas(mut self, replicas: usize) -> Self {
        self.replicas = replicas;
        self
    }

    /// Chips forming one model instance (one engine unit).
    pub fn chips_per_instance(&self) -> usize {
        self.tp.max(1) * self.pp.max(1)
    }

    /// Chips across all replicas.
    pub fn total_chips(&self) -> usize {
        self.chips_per_instance() * self.replicas.max(1)
    }
}

/// Why a deployment cannot run. Typed so callers can auto-replan
/// (grow the shard) instead of pattern-matching error strings.
#[derive(Debug, Clone, PartialEq)]
pub enum CapacityError {
    /// The plan's shape does not divide the model architecture.
    InvalidPlan { model: &'static str, plan: ParallelismPlan, reason: String },
    /// Per-chip weight shard alone exceeds usable HBM.
    WeightsExceedHbm {
        model: &'static str,
        device: Device,
        plan: ParallelismPlan,
        need_bytes: f64,
        have_bytes: f64,
    },
    /// Weights fit, but the leftover KV budget is below the floor the
    /// caller needs to serve its workload.
    KvBelowFloor {
        model: &'static str,
        device: Device,
        plan: ParallelismPlan,
        kv_tokens: usize,
        min_kv_tokens: usize,
    },
    /// Weights fit, but a concrete step's `batch x seq` KV does not
    /// (the [`check_step`] verdict — distinct from a serviceability
    /// floor so callers can tell "bad config" from "bad batch").
    StepDoesntFit {
        model: &'static str,
        device: Device,
        plan: ParallelismPlan,
        need_tokens: usize,
        have_tokens: usize,
    },
}

impl fmt::Display for CapacityError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CapacityError::InvalidPlan { model, plan, reason } => {
                write!(f, "{model} cannot shard as {plan}: {reason}")
            }
            CapacityError::WeightsExceedHbm { model, device, plan, need_bytes, have_bytes } => {
                write!(
                    f,
                    "{model} @ {plan} does not fit {}: weight shard {:.1} GB > usable HBM {:.1} GB",
                    device.name(),
                    need_bytes / 1e9,
                    have_bytes / 1e9,
                )
            }
            CapacityError::KvBelowFloor { model, device, plan, kv_tokens, min_kv_tokens } => {
                write!(
                    f,
                    "{model} @ {plan} on {}: KV budget {} tokens < floor {}",
                    device.name(),
                    kv_tokens,
                    min_kv_tokens,
                )
            }
            CapacityError::StepDoesntFit { model, device, plan, need_tokens, have_tokens } => {
                write!(
                    f,
                    "{model} @ {plan} on {}: step needs {} KV tokens (batch x seq), budget {}",
                    device.name(),
                    need_tokens,
                    have_tokens,
                )
            }
        }
    }
}

impl std::error::Error for CapacityError {}

/// Fraction of HBM held back for activations/workspace/fragmentation.
pub const HBM_RESERVE_FRAC: f64 = 0.05;

/// Minimum instance-level KV tokens for a deployment to be considered
/// serviceable: a 32-deep continuous batch of 1K contexts (the
/// paper's decode measurement shape), which also covers a handful of
/// full-length 4K chat prompts in flight.
pub const DEFAULT_MIN_KV_TOKENS: usize = 32_768;

/// What fits where, per chip and per instance.
#[derive(Debug, Clone)]
pub struct CapacityFit {
    pub plan: ParallelismPlan,
    /// Weight shard resident on each chip (bytes).
    pub weight_bytes_per_chip: f64,
    /// HBM left for KV on each chip after weights + reserve (bytes).
    pub kv_budget_bytes_per_chip: f64,
    /// KV bytes one token costs on each chip: layers/pp stages times
    /// kv_heads/min(tp, kv_heads) head shards (GQA replicates KV
    /// heads beyond `kv_heads`-way TP rather than slicing further).
    pub kv_bytes_per_token_per_chip: f64,
    /// Instance-level KV capacity in tokens (every chip holds its own
    /// shard of the same token's KV, so the instance token budget is
    /// the per-chip budget over the per-chip per-token cost).
    pub max_kv_tokens: usize,
}

/// Check that `model` sharded by `plan` fits `device` HBM with at
/// least `min_kv_tokens` of instance-level KV budget left over.
/// Weights are assumed uniformly sharded across the `tp * pp` chips
/// of one instance (embedding/LM-head asymmetry between pipeline
/// stages is ignored at this granularity); KV shards across pipeline
/// stages and at most `kv_heads` TP ways (GQA replication beyond).
pub fn check_capacity(
    model: &'static LlamaConfig,
    device: Device,
    plan: ParallelismPlan,
    weight_bytes_per_elem: f64,
    kv_bytes_per_elem: f64,
    min_kv_tokens: usize,
) -> Result<CapacityFit, CapacityError> {
    if plan.tp == 0 || plan.pp == 0 || plan.replicas == 0 {
        return Err(CapacityError::InvalidPlan {
            model: model.name,
            plan,
            reason: "tp, pp and replicas must all be >= 1".into(),
        });
    }
    if model.heads % plan.tp != 0 {
        return Err(CapacityError::InvalidPlan {
            model: model.name,
            plan,
            reason: format!("tp={} does not divide {} query heads", plan.tp, model.heads),
        });
    }
    if model.layers % plan.pp != 0 {
        return Err(CapacityError::InvalidPlan {
            model: model.name,
            plan,
            reason: format!("pp={} does not divide {} layers", plan.pp, model.layers),
        });
    }
    let chips = plan.chips_per_instance() as f64;
    // §5.2 precision split: block linears at the configured width,
    // embedding/LM head resident in BF16 regardless.
    let weight_bytes_per_chip =
        model.weight_bytes_mixed(weight_bytes_per_elem, 2.0) / chips;
    let usable = device.spec().hbm_cap * (1.0 - HBM_RESERVE_FRAC);
    if weight_bytes_per_chip > usable {
        return Err(CapacityError::WeightsExceedHbm {
            model: model.name,
            device,
            plan,
            need_bytes: weight_bytes_per_chip,
            have_bytes: usable,
        });
    }
    let kv_budget_bytes_per_chip = usable - weight_bytes_per_chip;
    // GQA: KV has only `kv_heads` shards to give — TP degrees beyond
    // that replicate KV heads instead of slicing them further, so the
    // per-chip KV footprint stops shrinking at min(tp, kv_heads).
    let kv_shards = (plan.tp.min(model.kv_heads) * plan.pp) as f64;
    let kv_bytes_per_token_per_chip = model.kv_bytes_per_token(kv_bytes_per_elem) / kv_shards;
    let max_kv_tokens = (kv_budget_bytes_per_chip / kv_bytes_per_token_per_chip) as usize;
    if max_kv_tokens < min_kv_tokens {
        return Err(CapacityError::KvBelowFloor {
            model: model.name,
            device,
            plan,
            kv_tokens: max_kv_tokens,
            min_kv_tokens,
        });
    }
    Ok(CapacityFit {
        plan,
        weight_bytes_per_chip,
        kv_budget_bytes_per_chip,
        kv_bytes_per_token_per_chip,
        max_kv_tokens,
    })
}

/// Check a concrete step shape: weights plus KV for `batch` sequences
/// of context `seq` must fit the instance. This is the gate in front
/// of `perfmodel::{decode_step, prefill}` for batch sweeps; a budget
/// miss comes back as [`CapacityError::StepDoesntFit`] naming the
/// step's demand, not as a phantom configuration "floor".
pub fn check_step(
    model: &'static LlamaConfig,
    device: Device,
    plan: ParallelismPlan,
    weight_bytes_per_elem: f64,
    kv_bytes_per_elem: f64,
    batch: usize,
    seq: usize,
) -> Result<CapacityFit, CapacityError> {
    let need = batch * seq;
    check_capacity(model, device, plan, weight_bytes_per_elem, kv_bytes_per_elem, need).map_err(
        |e| match e {
            CapacityError::KvBelowFloor { model, device, plan, kv_tokens, .. } => {
                CapacityError::StepDoesntFit {
                    model,
                    device,
                    plan,
                    need_tokens: need,
                    have_tokens: kv_tokens,
                }
            }
            other => other,
        },
    )
}

/// Candidate shard shapes in ascending chip count: prefer pure TP
/// inside the scale-up domain (one all-reduce fabric hop structure),
/// fall back to TP x PP once a single domain is not enough.
const PLAN_CANDIDATES: [(usize, usize); 8] =
    [(1, 1), (2, 1), (4, 1), (8, 1), (4, 2), (8, 2), (8, 4), (8, 8)];

/// Smallest plan (by chip count, TP-first) under which the model fits
/// the device with `min_kv_tokens` of KV headroom. Returns the last
/// capacity error when nothing fits.
pub fn auto_plan(
    model: &'static LlamaConfig,
    device: Device,
    weight_bytes_per_elem: f64,
    kv_bytes_per_elem: f64,
    min_kv_tokens: usize,
) -> Result<ParallelismPlan, CapacityError> {
    let mut last_err = None;
    for (tp, pp) in PLAN_CANDIDATES {
        let plan = ParallelismPlan::new(tp, pp);
        match check_capacity(model, device, plan, weight_bytes_per_elem, kv_bytes_per_elem, min_kv_tokens)
        {
            Ok(fit) => return Ok(fit.plan),
            Err(e @ CapacityError::InvalidPlan { .. }) => {
                // Shape mismatch, not a capacity verdict: keep looking
                // but remember it in case nothing else fits either.
                last_err.get_or_insert(e);
            }
            Err(e) => last_err = Some(e),
        }
    }
    Err(last_err.expect("candidate list is non-empty"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::llama::by_name;

    #[test]
    fn plan_display_and_chips() {
        assert_eq!(ParallelismPlan::single().to_string(), "tp1");
        assert_eq!(ParallelismPlan::new(4, 2).to_string(), "tp4-pp2");
        assert_eq!(
            ParallelismPlan::new(8, 2).with_replicas(3).to_string(),
            "tp8-pp2-x3"
        );
        assert_eq!(ParallelismPlan::new(4, 2).chips_per_instance(), 8);
        assert_eq!(ParallelismPlan::new(4, 2).with_replicas(3).total_chips(), 24);
    }

    #[test]
    fn llama8b_fits_single_h100() {
        let m = by_name("llama-8b").unwrap();
        let fit = check_capacity(m, Device::H100, ParallelismPlan::single(), 1.0, 2.0, 16_384)
            .expect("8B FP8 fits one H100");
        assert!(fit.weight_bytes_per_chip > 7e9 && fit.weight_bytes_per_chip < 10e9);
        assert!(fit.max_kv_tokens > 100_000, "{}", fit.max_kv_tokens);
    }

    #[test]
    fn llama70b_bf16_rejected_on_single_chip() {
        let m = by_name("llama-70b").unwrap();
        let err = check_capacity(m, Device::H100, ParallelismPlan::single(), 2.0, 2.0, 1)
            .unwrap_err();
        assert!(matches!(err, CapacityError::WeightsExceedHbm { .. }), "{err}");
        // The error is printable and names the offenders.
        let msg = err.to_string();
        assert!(msg.contains("llama-70b") && msg.contains("H100"), "{msg}");
    }

    #[test]
    fn llama70b_fp8_single_chip_fails_kv_floor() {
        // ~70.6 GB of FP8 weights squeeze into 76 GB usable, but the
        // ~16.6K-token KV leftover is half the serviceable floor.
        let m = by_name("llama-70b").unwrap();
        let err = check_capacity(
            m,
            Device::H100,
            ParallelismPlan::single(),
            1.0,
            2.0,
            DEFAULT_MIN_KV_TOKENS,
        )
        .unwrap_err();
        assert!(matches!(err, CapacityError::KvBelowFloor { .. }), "{err}");
    }

    #[test]
    fn llama70b_fp8_fits_at_tp2_and_above() {
        let m = by_name("llama-70b").unwrap();
        for tp in [2usize, 4, 8] {
            let fit = check_capacity(
                m,
                Device::H100,
                ParallelismPlan::tp(tp),
                1.0,
                2.0,
                DEFAULT_MIN_KV_TOKENS,
            )
            .unwrap_or_else(|e| panic!("tp{tp}: {e}"));
            assert!(fit.max_kv_tokens >= DEFAULT_MIN_KV_TOKENS);
        }
    }

    #[test]
    fn kv_budget_grows_with_shard_count() {
        let m = by_name("llama-70b").unwrap();
        let t2 = check_capacity(m, Device::H100, ParallelismPlan::tp(2), 1.0, 2.0, 1)
            .unwrap()
            .max_kv_tokens;
        let t8 = check_capacity(m, Device::H100, ParallelismPlan::tp(8), 1.0, 2.0, 1)
            .unwrap()
            .max_kv_tokens;
        assert!(t8 > t2 * 2, "tp2 {t2} tp8 {t8}");
    }

    #[test]
    fn kv_sharding_saturates_at_kv_heads() {
        // GQA: beyond kv_heads-way TP, KV is replicated, not sliced —
        // per-chip KV cost must stop shrinking (llama-8b: kv_heads=8).
        let m = by_name("llama-8b").unwrap();
        let at = |tp: usize| {
            check_capacity(m, Device::H100, ParallelismPlan::tp(tp), 1.0, 2.0, 1)
                .unwrap()
                .kv_bytes_per_token_per_chip
        };
        assert!(at(8) < at(4));
        assert_eq!(at(16), at(8), "tp16 must not pretend to halve KV again");
    }

    #[test]
    fn invalid_shapes_rejected() {
        let m = by_name("llama-8b").unwrap(); // 32 heads, 32 layers
        let bad_tp = check_capacity(m, Device::H100, ParallelismPlan::tp(3), 1.0, 2.0, 1);
        assert!(matches!(bad_tp, Err(CapacityError::InvalidPlan { .. })));
        let bad_pp =
            check_capacity(m, Device::H100, ParallelismPlan::new(1, 3), 1.0, 2.0, 1);
        assert!(matches!(bad_pp, Err(CapacityError::InvalidPlan { .. })));
        let zero = check_capacity(m, Device::H100, ParallelismPlan::new(0, 1), 1.0, 2.0, 1);
        assert!(matches!(zero, Err(CapacityError::InvalidPlan { .. })));
    }

    #[test]
    fn auto_plan_prefers_smallest_feasible_shard() {
        let m8 = by_name("llama-8b").unwrap();
        let p8 = auto_plan(m8, Device::H100, 1.0, 2.0, DEFAULT_MIN_KV_TOKENS).unwrap();
        assert_eq!(p8, ParallelismPlan::single());
        let m70 = by_name("llama-70b").unwrap();
        let p70 = auto_plan(m70, Device::H100, 1.0, 2.0, DEFAULT_MIN_KV_TOKENS).unwrap();
        assert_eq!(p70, ParallelismPlan::tp(2), "tp2 is the smallest FP8 70B fit");
        // Gaudi 2's 96 GB admits 70B FP8 on a single chip.
        let g70 = auto_plan(m70, Device::Gaudi2, 1.0, 2.0, DEFAULT_MIN_KV_TOKENS).unwrap();
        assert_eq!(g70, ParallelismPlan::single());
    }

    #[test]
    fn check_step_gates_concrete_batches() {
        let m = by_name("llama-8b").unwrap();
        // 64 x 2K contexts of BF16 KV on one H100: ~17 GB, fits.
        assert!(check_step(m, Device::H100, ParallelismPlan::single(), 1.0, 2.0, 64, 2048).is_ok());
        // 512 x 8K does not (512 GB of KV) — and the verdict names the
        // step's demand rather than a phantom configuration floor.
        let err = check_step(m, Device::H100, ParallelismPlan::single(), 1.0, 2.0, 512, 8192)
            .unwrap_err();
        match err {
            CapacityError::StepDoesntFit { need_tokens, .. } => {
                assert_eq!(need_tokens, 512 * 8192)
            }
            other => panic!("expected StepDoesntFit, got {other}"),
        }
    }
}
