//! Disaggregated prefill/decode deployment planning.
//!
//! The paper's per-phase result — prefill is compute-bound where the
//! H100's peak GEMM wins, decode is memory-bound where Gaudi's
//! thin-GEMM utilization and cheaper HBM capacity win — only becomes
//! a TCO lever if the two phases can run on *different* pools. A
//! [`DisaggPlan`] names the two pools (device, precision, TP/PP shard
//! shape, replica count each) plus the KV-migration link between
//! them; [`auto_size`] balances the replica split from the workload's
//! prefill:decode service-time ratio so neither pool idles while the
//! other saturates.

use crate::analysis::parallel::ParallelismPlan;
use crate::analysis::perfmodel::{decode_step, prefill, PrecisionMode, StepConfig};
use crate::hwsim::interconnect::KvLink;
use crate::hwsim::power::PowerCap;
use crate::hwsim::spec::Device;
use crate::workload::llama::LlamaConfig;

/// One pool of identical sharded instances serving a single phase.
#[derive(Debug, Clone, Copy)]
pub struct PoolSpec {
    pub device: Device,
    pub precision: PrecisionMode,
    /// Shard shape of one instance plus the pool's replica count.
    pub plan: ParallelismPlan,
    /// Power cap applied to every chip of the pool (None by default).
    /// A rack-capped frontier sets `PowerCap::PerGpu` here with the
    /// allocation `tco::rack::rack_capped_per_gpu_w` water-fills from
    /// the pools' uncapped demands.
    pub power_cap: PowerCap,
}

impl PoolSpec {
    pub fn new(device: Device, precision: PrecisionMode, plan: ParallelismPlan) -> Self {
        PoolSpec { device, precision, plan, power_cap: PowerCap::None }
    }

    /// Builder-style per-chip power cap (W).
    pub fn with_cap(mut self, watts: f64) -> Self {
        self.power_cap = PowerCap::PerGpu(watts);
        self
    }
}

/// A disaggregated deployment: a prefill pool, a decode pool, and the
/// scale-out link KV caches migrate across. Mixed-vendor pools (e.g.
/// H100 prefill + Gaudi decode) are first-class.
#[derive(Debug, Clone, Copy)]
pub struct DisaggPlan {
    pub prefill: PoolSpec,
    pub decode: PoolSpec,
}

impl DisaggPlan {
    pub fn new(prefill: PoolSpec, decode: PoolSpec) -> Self {
        DisaggPlan { prefill, decode }
    }

    /// Accelerators across both pools (capex/power accounting).
    pub fn total_chips(&self) -> usize {
        self.prefill.plan.total_chips() + self.decode.plan.total_chips()
    }

    /// The KV-migration link implied by the two pools' fabrics: each
    /// instance streams its KV shards over its own scale-out NICs, so
    /// the slower endpoint's aggregate NIC bandwidth governs.
    pub fn kv_link(&self) -> KvLink {
        KvLink::between(
            self.prefill.device.interconnect(),
            self.prefill.plan.chips_per_instance(),
            self.decode.device.interconnect(),
            self.decode.plan.chips_per_instance(),
        )
    }

    /// Human-readable shape for tables: "H100 tp1-x2 -> Gaudi2 tp1-x6".
    pub fn describe(&self) -> String {
        format!(
            "{} {} -> {} {}",
            self.prefill.device.name(),
            self.prefill.plan,
            self.decode.device.name(),
            self.decode.plan,
        )
    }
}

/// A PhaseAffinity deployment: a colocated pool *and* a disaggregated
/// prefill/decode pair behind one router that splits traffic by
/// prompt length — long-prefill requests (at or above
/// `affinity_prompt_tokens`) take the disaggregated path, short ones
/// stay colocated. The mixed shape hedges the disaggregation bet:
/// migration cost is only paid where the phase split wins it back,
/// and short interactive requests never cross the fabric.
#[derive(Debug, Clone, Copy)]
pub struct PhaseAffinityPlan {
    pub colocated: PoolSpec,
    pub disagg: DisaggPlan,
    /// Prompts at or above this length route to the disagg pools.
    pub affinity_prompt_tokens: usize,
}

impl PhaseAffinityPlan {
    pub fn new(
        colocated: PoolSpec,
        disagg: DisaggPlan,
        affinity_prompt_tokens: usize,
    ) -> Self {
        PhaseAffinityPlan { colocated, disagg, affinity_prompt_tokens }
    }

    /// Accelerators across all three pools (capex/power accounting).
    pub fn total_chips(&self) -> usize {
        self.colocated.plan.total_chips() + self.disagg.total_chips()
    }

    /// Human-readable shape for tables:
    /// "H100 tp1-x2 + [H100 tp1-x1 -> Gaudi2 tp1-x1] @>=512".
    pub fn describe(&self) -> String {
        format!(
            "{} {} + [{}] @>={}",
            self.colocated.device.name(),
            self.colocated.plan,
            self.disagg.describe(),
            self.affinity_prompt_tokens,
        )
    }
}

/// Split `total_replicas` instances between the two pools so the
/// per-request service demand balances: one request costs the prefill
/// pool one prompt prefill and the decode pool `output_tokens` decode
/// steps (amortized over a 32-deep continuous batch at mid-generation
/// context, the paper's measurement shape). The pool shares follow the
/// ratio of those service times — a summarize-style workload (long
/// prompts, short outputs) earns more prefill instances, a
/// reasoning-style one more decode instances. Replica counts on the
/// input [`PoolSpec`]s are overwritten; both pools keep >= 1 instance.
pub fn auto_size(
    model: &'static LlamaConfig,
    prefill_pool: PoolSpec,
    decode_pool: PoolSpec,
    prompt_tokens: usize,
    output_tokens: usize,
    total_replicas: usize,
) -> DisaggPlan {
    assert!(total_replicas >= 2, "need at least one instance per pool");
    let p_cfg = StepConfig::new(prefill_pool.device, prefill_pool.precision)
        .with_plan(prefill_pool.plan);
    let d_cfg =
        StepConfig::new(decode_pool.device, decode_pool.precision).with_plan(decode_pool.plan);
    let t_prefill = prefill(model, &p_cfg, 1, prompt_tokens.max(1)).seconds;
    let batch = 32usize;
    let ctx = (prompt_tokens + output_tokens / 2).max(1);
    let t_step = decode_step(model, &d_cfg, batch, ctx).seconds;
    let t_decode = t_step / batch as f64 * output_tokens.max(1) as f64;
    let share = t_prefill / (t_prefill + t_decode);
    let n_prefill =
        ((total_replicas as f64 * share).round() as usize).clamp(1, total_replicas - 1);
    let n_decode = total_replicas - n_prefill;
    DisaggPlan {
        prefill: PoolSpec {
            plan: prefill_pool.plan.with_replicas(n_prefill),
            ..prefill_pool
        },
        decode: PoolSpec {
            plan: decode_pool.plan.with_replicas(n_decode),
            ..decode_pool
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::llama::by_name;

    fn h100_pool() -> PoolSpec {
        PoolSpec::new(
            Device::H100,
            PrecisionMode::fp8_dynamic(),
            ParallelismPlan::single(),
        )
    }

    fn gaudi2_pool() -> PoolSpec {
        PoolSpec::new(
            Device::Gaudi2,
            PrecisionMode::fp8_static(),
            ParallelismPlan::single(),
        )
    }

    #[test]
    fn auto_size_follows_phase_demand() {
        let m = by_name("llama-8b").unwrap();
        // Summarize-shaped (long prompt, short output) vs
        // reasoning-shaped (short prompt, long output).
        let summarize = auto_size(m, h100_pool(), gaudi2_pool(), 2400, 64, 8);
        let reasoning = auto_size(m, h100_pool(), gaudi2_pool(), 256, 2000, 8);
        let (sp, sd) = (
            summarize.prefill.plan.replicas,
            summarize.decode.plan.replicas,
        );
        let (rp, rd) = (
            reasoning.prefill.plan.replicas,
            reasoning.decode.plan.replicas,
        );
        assert_eq!(sp + sd, 8);
        assert_eq!(rp + rd, 8);
        assert!(
            sp >= rp,
            "prefill-heavy workload must not earn fewer prefill instances \
             (summarize {sp}, reasoning {rp})"
        );
        assert!(rd >= 4, "reasoning traffic is decode-dominated: {rd}");
        // Both pools always keep at least one instance.
        assert!(sp >= 1 && sd >= 1 && rp >= 1 && rd >= 1);
    }

    #[test]
    fn plan_chips_and_link() {
        let m = by_name("llama-8b").unwrap();
        let plan = auto_size(m, h100_pool(), gaudi2_pool(), 256, 512, 4);
        assert_eq!(plan.total_chips(), 4, "tp1 instances: chips == replicas");
        let link = plan.kv_link();
        // Gaudi2's 3x100GbE scale-out is the bottleneck endpoint.
        assert_eq!(link.bw, 37.5e9);
        assert_eq!(link.lat_s, 5.0e-6 + 6.0e-6);
        assert!(plan.describe().contains("H100"));
        assert!(plan.describe().contains("Gaudi2"));
    }

    #[test]
    fn phase_affinity_plan_chips_and_shape() {
        let m = by_name("llama-8b").unwrap();
        let disagg = auto_size(m, h100_pool(), gaudi2_pool(), 2048, 128, 2);
        let colo = PoolSpec::new(
            Device::H100,
            PrecisionMode::fp8_dynamic(),
            ParallelismPlan::single().with_replicas(2),
        );
        let plan = PhaseAffinityPlan::new(colo, disagg, 512);
        assert_eq!(plan.total_chips(), 4, "2 colocated + 1 prefill + 1 decode");
        let d = plan.describe();
        assert!(d.contains("@>=512"), "{d}");
        assert!(d.contains("H100") && d.contains("Gaudi2"), "{d}");
    }

    #[test]
    fn wider_instances_widen_the_link() {
        let p = PoolSpec::new(
            Device::H100,
            PrecisionMode::fp8_dynamic(),
            ParallelismPlan::tp(4),
        );
        let d = gaudi2_pool();
        let plan = DisaggPlan::new(p, d);
        // Source has 4x50 GB/s of NICs but the single-chip Gaudi2 sink
        // still caps the link.
        assert_eq!(plan.kv_link().bw, 37.5e9);
        let d4 = PoolSpec::new(
            Device::Gaudi2,
            PrecisionMode::fp8_static(),
            ParallelismPlan::tp(4),
        );
        let plan4 = DisaggPlan::new(p, d4);
        assert_eq!(plan4.kv_link().bw, 150e9, "4 chips x 37.5 GB/s");
    }
}
