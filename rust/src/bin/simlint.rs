//! `simlint` driver: lint the crate tree and exit non-zero on any
//! unwaived finding. Waived findings are inventoried in the summary so
//! every `// simlint: allow(...)` stays auditable from CI logs.
//!
//! Usage: `cargo run --release --bin simlint` (from `rust/`).

use std::path::PathBuf;
use std::process::ExitCode;

use fp8_tco::simlint::check_tree;

fn main() -> ExitCode {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let findings = check_tree(&root);
    let (waived, unwaived): (Vec<_>, Vec<_>) =
        findings.into_iter().partition(|f| f.waived.is_some());

    for f in &unwaived {
        println!("{}:{}: [{}] {}", f.file, f.line, f.rule.name(), f.msg);
    }
    if !unwaived.is_empty() {
        println!();
    }
    println!(
        "simlint: {} finding(s), {} waiver(s)",
        unwaived.len(),
        waived.len()
    );
    for f in &waived {
        println!(
            "  waived {}:{} [{}] -- {}",
            f.file,
            f.line,
            f.rule.name(),
            f.waived.as_deref().unwrap_or("(no reason given)")
        );
    }
    if unwaived.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
