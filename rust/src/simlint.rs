//! `simlint`: the repo's static-analysis pass (DESIGN.md §10).
//!
//! Four rule families over the token stream of
//! [`util::srclex`](crate::util::srclex):
//!
//! * **determinism** — the simulator's bit-identity contracts
//!   (cached-vs-uncached, serial-vs-parallel) must not be broken by a
//!   wall-clock read, stray RNG, or hash-order iteration feeding an
//!   ordered decision. Flags `Instant`/`SystemTime`/`std::time`
//!   tree-wide, RNG outside `util::rng`, and iteration over
//!   `HashMap`/`HashSet`-typed names in `coordinator/`.
//! * **units** — `f64` public fn parameters/returns and public struct
//!   fields in `analysis/perfmodel.rs`, `hwsim/power.rs`,
//!   `hwsim/interconnect.rs` and `tco/` must carry a unit suffix from
//!   the fixed vocabulary (`_s`, `_j`, `_w`, `_usd`, `_tokens`,
//!   `_bytes`, `_flops`, `_frac`, their spelled-out forms, and `_per_`
//!   compounds).
//! * **unit-mix** — adding or subtracting two unit-suffixed names of
//!   *different* units in one expression (J + W, s + h) is flagged in
//!   the same files.
//! * **panic** — no `unwrap()`/`expect()`/`panic!`-family macros in
//!   the hot-path coordinator files
//!   (`engine`/`batcher`/`router`/`cluster`/`backend`); `assert!` and
//!   `debug_assert!` stay allowed (they are the audit mechanism).
//!
//! Waivers: `// simlint: allow(<rule>) -- <reason>` on the offending
//! line or the line above, or `// simlint: allow-file(<rule>) --
//! <reason>` anywhere in the file. Waived findings are not errors but
//! are inventoried by the binary (`cargo run --bin simlint`) and the
//! gate test (`tests/simlint_gate.rs`). `#[cfg(test)]` regions are
//! exempt from every rule.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use crate::util::srclex::{lex, TokKind, Token};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    Determinism,
    Units,
    UnitMix,
    Panic,
}

impl Rule {
    pub fn name(self) -> &'static str {
        match self {
            Rule::Determinism => "determinism",
            Rule::Units => "units",
            Rule::UnitMix => "unit-mix",
            Rule::Panic => "panic",
        }
    }

    fn from_name(s: &str) -> Option<Rule> {
        match s {
            "determinism" => Some(Rule::Determinism),
            "units" => Some(Rule::Units),
            "unit-mix" => Some(Rule::UnitMix),
            "panic" => Some(Rule::Panic),
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Finding {
    /// Crate-relative path (`src/...`, `benches/...`, `examples/...`).
    pub file: String,
    pub line: usize,
    pub rule: Rule,
    pub msg: String,
    /// Waiver reason when suppressed by `// simlint: allow(...)`.
    pub waived: Option<String>,
}

/// Hot-path files under the panic policy.
const PANIC_FILES: [&str; 6] = [
    "src/coordinator/engine.rs",
    "src/coordinator/batcher.rs",
    "src/coordinator/router.rs",
    "src/coordinator/cluster.rs",
    "src/coordinator/backend.rs",
    "src/coordinator/faults.rs",
];

/// Files under the unit-suffix discipline.
fn units_scoped(rel: &str) -> bool {
    rel == "src/analysis/perfmodel.rs"
        || rel == "src/hwsim/power.rs"
        || rel == "src/hwsim/interconnect.rs"
        || rel.starts_with("src/tco/")
}

/// Unit class of a name, by its last `_`-separated segment (or the
/// `_per_` compound form). `None` = not unit-bearing.
fn unit_class(name: &str) -> Option<&'static str> {
    if name.contains("_per_") {
        return Some("per");
    }
    let seg = name.rsplit('_').next().unwrap_or(name);
    match seg {
        "s" | "seconds" => Some("s"),
        "j" | "joules" => Some("j"),
        "w" | "watts" => Some("w"),
        "usd" => Some("usd"),
        "tokens" => Some("tokens"),
        "bytes" => Some("bytes"),
        "flops" => Some("flops"),
        "tflops" => Some("tflops"),
        "frac" | "ratio" | "share" => Some("frac"),
        "bw" => Some("bw"),
        "hours" => Some("hours"),
        "qps" => Some("qps"),
        _ => None,
    }
}

/// `HashMap`/`HashSet` methods whose call on a tainted name means the
/// code observes hash order.
const HASH_ITER_METHODS: [&str; 8] = [
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "into_iter",
    "drain",
    "retain",
];

struct Waivers {
    /// Rules waived for the whole file, with reasons.
    file_level: Vec<(Rule, String)>,
    /// Line -> waived rules with reasons (covers that line and the
    /// next, so a waiver sits on the offending line or just above it).
    lines: BTreeMap<usize, Vec<(Rule, String)>>,
}

impl Waivers {
    fn parse(toks: &[Token]) -> Waivers {
        let mut w = Waivers { file_level: Vec::new(), lines: BTreeMap::new() };
        for t in toks.iter().filter(|t| t.kind == TokKind::Comment) {
            let Some(pos) = t.text.find("simlint:") else { continue };
            let rest = t.text[pos + "simlint:".len()..].trim_start();
            let (file_level, rest) = if let Some(r) = rest.strip_prefix("allow-file(") {
                (true, r)
            } else if let Some(r) = rest.strip_prefix("allow(") {
                (false, r)
            } else {
                continue;
            };
            let Some(close) = rest.find(')') else { continue };
            let reason = rest[close + 1..]
                .trim_start()
                .strip_prefix("--")
                .map(|r| r.trim().to_string())
                .unwrap_or_else(|| "(no reason given)".to_string());
            for name in rest[..close].split(',') {
                if let Some(rule) = Rule::from_name(name.trim()) {
                    if file_level {
                        w.file_level.push((rule, reason.clone()));
                    } else {
                        w.lines
                            .entry(t.line)
                            .or_default()
                            .push((rule, reason.clone()));
                    }
                }
            }
        }
        w
    }

    fn lookup(&self, rule: Rule, line: usize) -> Option<&str> {
        if let Some((_, reason)) =
            self.file_level.iter().find(|(r, _)| *r == rule)
        {
            return Some(reason);
        }
        for l in [line.saturating_sub(1), line] {
            if let Some(entries) = self.lines.get(&l) {
                if let Some((_, reason)) =
                    entries.iter().find(|(r, _)| *r == rule)
                {
                    return Some(reason);
                }
            }
        }
        None
    }
}

/// Lint one file's source. `rel` is the crate-relative path; it
/// selects which rule families apply. Waived findings are returned
/// with `waived = Some(reason)` so callers can inventory them.
pub fn check_file(rel: &str, src: &str) -> Vec<Finding> {
    let toks = lex(src);
    let waivers = Waivers::parse(&toks);
    // Structural rules see only code tokens; comments matter only for
    // waivers.
    let code: Vec<&Token> = toks.iter().filter(|t| t.kind != TokKind::Comment).collect();
    let in_test = test_region_mask(&code);

    let mut raw: Vec<(Rule, usize, String)> = Vec::new();
    determinism_rule(rel, &code, &in_test, &mut raw);
    if units_scoped(rel) {
        units_rule(&code, &in_test, &mut raw);
        unit_mix_rule(&code, &in_test, &mut raw);
    }
    if PANIC_FILES.contains(&rel) {
        panic_rule(&code, &in_test, &mut raw);
    }

    raw.sort_by(|a, b| a.1.cmp(&b.1).then_with(|| a.2.cmp(&b.2)));
    raw.into_iter()
        .map(|(rule, line, msg)| Finding {
            file: rel.to_string(),
            line,
            rule,
            msg,
            waived: waivers.lookup(rule, line).map(str::to_string),
        })
        .collect()
}

/// Mark code-token indices inside `#[cfg(test)]` items (the attribute
/// through the end of the following brace-delimited item).
fn test_region_mask(code: &[&Token]) -> Vec<bool> {
    let mut mask = vec![false; code.len()];
    let is = |i: usize, k: TokKind, s: &str| {
        code.get(i).is_some_and(|t| t.kind == k && t.text == s)
    };
    let mut i = 0;
    while i < code.len() {
        let attr = is(i, TokKind::Punct, "#")
            && is(i + 1, TokKind::Punct, "[")
            && is(i + 2, TokKind::Ident, "cfg")
            && is(i + 3, TokKind::Punct, "(")
            && is(i + 4, TokKind::Ident, "test")
            && is(i + 5, TokKind::Punct, ")")
            && is(i + 6, TokKind::Punct, "]");
        if !attr {
            i += 1;
            continue;
        }
        let start = i;
        let mut j = i + 7;
        // Find the item body: first `{` (mod/fn/impl) or a terminating
        // `;` (e.g. a cfg'd `use`).
        while j < code.len()
            && !(code[j].kind == TokKind::Punct
                && (code[j].text == "{" || code[j].text == ";"))
        {
            j += 1;
        }
        if j < code.len() && code[j].text == "{" {
            let mut depth = 0usize;
            while j < code.len() {
                if code[j].kind == TokKind::Punct {
                    if code[j].text == "{" {
                        depth += 1;
                    } else if code[j].text == "}" {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                }
                j += 1;
            }
        }
        for m in mask.iter_mut().take((j + 1).min(code.len())).skip(start) {
            *m = true;
        }
        i = j + 1;
    }
    mask
}

fn determinism_rule(
    rel: &str,
    code: &[&Token],
    in_test: &[bool],
    out: &mut Vec<(Rule, usize, String)>,
) {
    let ident = |i: usize| -> Option<&str> {
        code.get(i)
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text.as_str())
    };
    let punct = |i: usize, s: &str| {
        code.get(i).is_some_and(|t| t.kind == TokKind::Punct && t.text == s)
    };

    // Pass 1: names declared with a HashMap/HashSet type (or
    // constructed from one) in coordinator files.
    let mut tainted: Vec<String> = Vec::new();
    let hash_scope = rel.starts_with("src/coordinator/");
    if hash_scope {
        for i in 0..code.len() {
            if in_test[i] {
                continue;
            }
            let Some(name) = ident(i) else { continue };
            // `name: [&mut] [path::]HashMap<...>` (field, param, let).
            let mut j = i + 1;
            let colon = punct(j, ":");
            if colon {
                j += 1;
                loop {
                    if punct(j, "&") || ident(j) == Some("mut") {
                        j += 1;
                    } else if punct(j, "'") {
                        j += 2; // lifetime tick + ident
                    } else if ident(j).is_some() && punct(j + 1, ":") && punct(j + 2, ":") {
                        j += 3; // path segment `std::` / `collections::`
                    } else {
                        break;
                    }
                }
            } else if punct(j, "=") {
                j += 1; // `let name = HashMap::new()` and friends
            } else {
                continue;
            }
            if matches!(ident(j), Some("HashMap") | Some("HashSet"))
                && !tainted.iter().any(|t| t == name)
            {
                tainted.push(name.to_string());
            }
        }
    }

    for i in 0..code.len() {
        if in_test[i] {
            continue;
        }
        let Some(name) = ident(i) else { continue };
        match name {
            // Wall clock: breaks virtual-time determinism everywhere.
            "Instant" | "SystemTime" => out.push((
                Rule::Determinism,
                code[i].line,
                format!("wall-clock type `{name}` in simulation code (virtual time only)"),
            )),
            "std" if punct(i + 1, ":")
                && punct(i + 2, ":")
                && ident(i + 3) == Some("time") =>
            {
                out.push((
                    Rule::Determinism,
                    code[i].line,
                    "`std::time` in simulation code (virtual time only)".to_string(),
                ))
            }
            // RNG outside the seeded util::rng substrate.
            "thread_rng" | "from_entropy" | "StdRng" | "SmallRng" | "RandomState"
                if rel != "src/util/rng.rs" =>
            {
                out.push((
                    Rule::Determinism,
                    code[i].line,
                    format!("`{name}`: RNG outside util::rng breaks seeded reproducibility"),
                ))
            }
            "rand" if rel != "src/util/rng.rs"
                && punct(i + 1, ":")
                && punct(i + 2, ":") =>
            {
                out.push((
                    Rule::Determinism,
                    code[i].line,
                    "`rand::` path: RNG outside util::rng breaks seeded reproducibility"
                        .to_string(),
                ))
            }
            // Hash-order iteration on a tainted name.
            _ if hash_scope && tainted.iter().any(|t| t == name) => {
                // `name.iter()` / `.values()` / ... observe hash order.
                if punct(i + 1, ".") {
                    if let Some(m) = ident(i + 2) {
                        if HASH_ITER_METHODS.contains(&m) && punct(i + 3, "(") {
                            out.push((
                                Rule::Determinism,
                                code[i].line,
                                format!(
                                    "iteration over hash-ordered `{name}.{m}()` in \
                                     coordinator/ (use BTreeMap, a sorted snapshot, \
                                     or the decode index)"
                                ),
                            ));
                        }
                    }
                }
                // `for x in [&[mut]] [chain.]name {` observes hash order.
                if punct(i + 1, "{") {
                    let mut j = i;
                    while j >= 2 && punct(j - 1, ".") && ident(j - 2).is_some() {
                        j -= 2;
                    }
                    while j >= 1 && (punct(j - 1, "&") || ident(j - 1) == Some("mut")) {
                        j -= 1;
                    }
                    if j >= 1 && ident(j - 1) == Some("in") {
                        out.push((
                            Rule::Determinism,
                            code[i].line,
                            format!(
                                "for-loop over hash-ordered `{name}` in coordinator/ \
                                 (use BTreeMap, a sorted snapshot, or the decode index)"
                            ),
                        ));
                    }
                }
            }
            _ => {}
        }
    }
}

fn units_rule(code: &[&Token], in_test: &[bool], out: &mut Vec<(Rule, usize, String)>) {
    let ident = |i: usize| -> Option<&str> {
        code.get(i)
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text.as_str())
    };
    let punct = |i: usize, s: &str| {
        code.get(i).is_some_and(|t| t.kind == TokKind::Punct && t.text == s)
    };
    let mut i = 0;
    while i < code.len() {
        if in_test[i] || ident(i) != Some("pub") {
            i += 1;
            continue;
        }
        // Public struct field `pub name: f64`.
        if let Some(fname) = ident(i + 1) {
            if punct(i + 2, ":")
                && ident(i + 3) == Some("f64")
                && (punct(i + 4, ",") || punct(i + 4, "}"))
                && unit_class(fname).is_none()
            {
                out.push((
                    Rule::Units,
                    code[i + 1].line,
                    format!("pub f64 field `{fname}` lacks a unit suffix"),
                ));
                i += 4;
                continue;
            }
        }
        // Public fn: parameters and return type.
        if ident(i + 1) != Some("fn") {
            i += 1;
            continue;
        }
        let Some(fn_name) = ident(i + 2) else {
            i += 2;
            continue;
        };
        // Find the parameter list opener (skip generics `<...>`).
        let mut j = i + 3;
        while j < code.len() && !punct(j, "(") {
            if punct(j, "{") || punct(j, ";") {
                break;
            }
            j += 1;
        }
        if !punct(j, "(") {
            i = j;
            continue;
        }
        let mut depth = 0usize;
        let open = j;
        while j < code.len() {
            if punct(j, "(") {
                depth += 1;
            } else if punct(j, ")") {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            } else if depth == 1 && code[j].kind == TokKind::Ident && punct(j + 1, ":") {
                // Parameter name at the top level of the list.
                let after_open_or_comma =
                    punct(j - 1, "(") || punct(j - 1, ",") || ident(j - 1) == Some("mut");
                if after_open_or_comma && j > open {
                    let pname = &code[j].text;
                    let mut k = j + 2;
                    loop {
                        if punct(k, "&") || ident(k) == Some("mut") {
                            k += 1;
                        } else if punct(k, "'") {
                            k += 2;
                        } else {
                            break;
                        }
                    }
                    if ident(k) == Some("f64")
                        && (punct(k + 1, ",") || punct(k + 1, ")"))
                        && unit_class(pname).is_none()
                    {
                        out.push((
                            Rule::Units,
                            code[j].line,
                            format!(
                                "f64 parameter `{pname}` of pub fn `{fn_name}` lacks \
                                 a unit suffix"
                            ),
                        ));
                    }
                }
            }
            j += 1;
        }
        // Bare-f64 return: the fn name itself must carry the unit.
        if punct(j + 1, "-")
            && punct(j + 2, ">")
            && ident(j + 3) == Some("f64")
            && (punct(j + 4, "{") || punct(j + 4, ";") || ident(j + 4) == Some("where"))
            && unit_class(fn_name).is_none()
        {
            out.push((
                Rule::Units,
                code[i + 2].line,
                format!("pub fn `{fn_name}` returns bare f64 but lacks a unit suffix"),
            ));
        }
        i = j + 1;
    }
}

fn unit_mix_rule(code: &[&Token], in_test: &[bool], out: &mut Vec<(Rule, usize, String)>) {
    let ident_tok = |i: usize| -> Option<&Token> {
        code.get(i).copied().filter(|t| t.kind == TokKind::Ident)
    };
    let punct = |i: usize, s: &str| {
        code.get(i).is_some_and(|t| t.kind == TokKind::Punct && t.text == s)
    };
    for i in 0..code.len() {
        if in_test[i] {
            continue;
        }
        let op = match code[i] {
            t if t.kind == TokKind::Punct && (t.text == "+" || t.text == "-") => &t.text,
            _ => continue,
        };
        // `->`, `+=`, `-=` and unary minus are not additive mixes.
        if punct(i + 1, ">") || punct(i + 1, "=") {
            continue;
        }
        // Left operand: a plain ident chain `a.b.c` ending just before
        // the operator, not itself part of a product or quotient.
        let Some(l) = ident_tok(i.wrapping_sub(1)) else { continue };
        let mut start = i - 1;
        while start >= 2 && punct(start - 1, ".") && ident_tok(start - 2).is_some() {
            start -= 2;
        }
        if start >= 1 && (punct(start - 1, "*") || punct(start - 1, "/")) {
            continue;
        }
        // Right operand: a plain ident chain, not a call, cast, index,
        // or the head of a product/quotient.
        let Some(mut r) = ident_tok(i + 1) else { continue };
        let mut k = i + 1;
        while punct(k + 1, ".") && ident_tok(k + 2).is_some() {
            k += 2;
            r = ident_tok(k).unwrap_or(r);
        }
        if punct(k + 1, "(")
            || punct(k + 1, "*")
            || punct(k + 1, "/")
            || punct(k + 1, "[")
            || ident_tok(k + 1).map(|t| t.text.as_str()) == Some("as")
        {
            continue;
        }
        let (Some(cl), Some(cr)) = (unit_class(&l.text), unit_class(&r.text)) else {
            continue;
        };
        if cl != cr {
            out.push((
                Rule::UnitMix,
                code[i].line,
                format!(
                    "`{} {op} {}` mixes units `{cl}` and `{cr}` in one expression",
                    l.text, r.text
                ),
            ));
        }
    }
}

fn panic_rule(code: &[&Token], in_test: &[bool], out: &mut Vec<(Rule, usize, String)>) {
    let punct = |i: usize, s: &str| {
        code.get(i).is_some_and(|t| t.kind == TokKind::Punct && t.text == s)
    };
    for i in 0..code.len() {
        if in_test[i] || code[i].kind != TokKind::Ident {
            continue;
        }
        let name = code[i].text.as_str();
        match name {
            "unwrap" | "expect" if i > 0 && punct(i - 1, ".") && punct(i + 1, "(") => {
                out.push((
                    Rule::Panic,
                    code[i].line,
                    format!(
                        "`.{name}()` on the hot path (return a typed error, \
                         use let-else + debug_assert!, or a non-panicking default)"
                    ),
                ));
            }
            "panic" | "unreachable" | "todo" | "unimplemented" if punct(i + 1, "!") => {
                out.push((
                    Rule::Panic,
                    code[i].line,
                    format!("`{name}!` on the hot path (debug_assert! is the audit form)"),
                ));
            }
            _ => {}
        }
    }
}

/// Walk `src/`, `benches/` and `../examples/` under the crate root and
/// lint every `.rs` file. File order is sorted (deterministic output).
pub fn check_tree(manifest_dir: &Path) -> Vec<Finding> {
    let roots = [
        (manifest_dir.join("src"), "src"),
        (manifest_dir.join("benches"), "benches"),
        (manifest_dir.join("../examples"), "examples"),
    ];
    let mut files: Vec<(String, PathBuf)> = Vec::new();
    for (root, label) in &roots {
        collect_rs_files(root, label, &mut files);
    }
    files.sort();
    let mut out = Vec::new();
    for (rel, path) in files {
        let Ok(src) = fs::read_to_string(&path) else { continue };
        out.extend(check_file(&rel, &src));
    }
    out
}

fn collect_rs_files(dir: &Path, rel: &str, out: &mut Vec<(String, PathBuf)>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    let mut names: Vec<(String, PathBuf, bool)> = entries
        .flatten()
        .map(|e| {
            let p = e.path();
            let name = e.file_name().to_string_lossy().into_owned();
            let is_dir = p.is_dir();
            (name, p, is_dir)
        })
        .collect();
    names.sort();
    for (name, path, is_dir) in names {
        if is_dir {
            if name != "target" {
                collect_rs_files(&path, &format!("{rel}/{name}"), out);
            }
        } else if name.ends_with(".rs") {
            out.push((format!("{rel}/{name}"), path));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn active(rel: &str, src: &str) -> Vec<Rule> {
        check_file(rel, src)
            .into_iter()
            .filter(|f| f.waived.is_none())
            .map(|f| f.rule)
            .collect()
    }

    #[test]
    fn unit_class_vocabulary() {
        assert_eq!(unit_class("t_tp_comm_s"), Some("s"));
        assert_eq!(unit_class("seconds"), Some("s"));
        assert_eq!(unit_class("usd_per_kwh"), Some("per"));
        assert_eq!(unit_class("util_frac"), Some("frac"));
        assert_eq!(unit_class("throughput_ratio"), Some("frac"));
        assert_eq!(unit_class("util"), None);
        assert_eq!(unit_class("t_linears"), None);
    }

    #[test]
    fn waiver_parse_and_lookup() {
        let src = "// simlint: allow(panic) -- startup path\nfn f(o: Option<u32>) -> u32 { o.unwrap() }";
        let fs = check_file("src/coordinator/engine.rs", src);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].waived.as_deref(), Some("startup path"));
    }

    #[test]
    fn file_level_waiver_covers_everything() {
        let src = "// simlint: allow-file(determinism) -- real hardware\n\
                   fn f() { let _ = std::time::Instant::now(); }";
        let fs = check_file("src/coordinator/pjrt_x.rs", src);
        assert!(!fs.is_empty());
        assert!(fs.iter().all(|f| f.waived.is_some()));
    }

    #[test]
    fn cfg_test_regions_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f(o: Option<u32>) -> u32 { o.unwrap() }\n}";
        assert!(active("src/coordinator/engine.rs", src).is_empty());
    }

    #[test]
    fn sorted_output_is_stable() {
        let src = "fn a() { let t = std::time::Instant::now(); }";
        let a = check_file("src/x.rs", src);
        let b = check_file("src/x.rs", src);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.line, x.msg.clone()), (y.line, y.msg.clone()));
        }
    }
}
