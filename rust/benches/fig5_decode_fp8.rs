//! FIG5: decode throughput BF16 vs FP8 on Llama-8B at batch 64 across
//! sequence lengths — Gaudi 2 (left panel: BF16 vs static FP8) and
//! H100 (right panel: BF16 vs static vs dynamic FP8).
//!
//! Paper claims: Gaudi FP8 gain >= ~1.5x; H100 gain < 1.25x; on H100,
//! dynamic scaling outperforms static (row-wise GEMMs are faster than
//! per-tensor at decode's small shapes, Table 3).

use fp8_tco::analysis::perfmodel::{decode_step, PrecisionMode, StepConfig};
use fp8_tco::hwsim::spec::Device;
use fp8_tco::util::table::{f, Table};
use fp8_tco::workload::llama;

fn main() {
    let m = llama::by_name("llama-8b").unwrap();
    let seqs = [128usize, 256, 512, 1024, 2048, 4096];

    let mut t = Table::new(
        "Fig. 5 (left) — Gaudi 2 decode tok/s, b=64",
        &["s", "bf16", "fp8 static", "gain"],
    );
    for &s in &seqs {
        let b16 = decode_step(m, &StepConfig::new(Device::Gaudi2, PrecisionMode::Bf16), 64, s);
        let f8 = decode_step(m, &StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()), 64, s);
        let gain = b16.seconds / f8.seconds;
        t.row(vec![
            s.to_string(),
            f(64.0 / b16.seconds, 0),
            f(64.0 / f8.seconds, 0),
            f(gain, 2),
        ]);
    }
    t.print();

    let mut t2 = Table::new(
        "Fig. 5 (right) — H100 decode tok/s, b=64",
        &["s", "bf16", "fp8 static", "fp8 dynamic", "best gain"],
    );
    for &s in &seqs {
        let b16 = decode_step(m, &StepConfig::new(Device::H100, PrecisionMode::Bf16), 64, s);
        let st = decode_step(m, &StepConfig::new(Device::H100, PrecisionMode::fp8_static()), 64, s);
        let dy = decode_step(m, &StepConfig::new(Device::H100, PrecisionMode::fp8_dynamic()), 64, s);
        let gain = b16.seconds / dy.seconds.min(st.seconds);
        t2.row(vec![
            s.to_string(),
            f(64.0 / b16.seconds, 0),
            f(64.0 / st.seconds, 0),
            f(64.0 / dy.seconds, 0),
            f(gain, 2),
        ]);
        // H100: dynamic >= static (paper: row-wise faster at small M).
        assert!(dy.seconds <= st.seconds * 1.001, "s={s}: dynamic >= static");
        assert!(gain < 1.25, "s={s}: H100 gain {gain} must stay under 25%");
    }
    t2.print();

    // Gaudi gain at short-to-moderate sequences >= 1.4x (paper: >= 50%
    // at its measured settings; KV reads dilute it as s grows).
    let b16 = decode_step(m, &StepConfig::new(Device::Gaudi2, PrecisionMode::Bf16), 64, 256);
    let f8 = decode_step(m, &StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()), 64, 256);
    let gaudi_gain = b16.seconds / f8.seconds;
    assert!(gaudi_gain >= 1.45, "gaudi gain {gaudi_gain}");
    println!("Gaudi FP8 gain at s=256: {gaudi_gain:.2}x (paper: '50% or greater')");

    // Cross-device: Gaudi2+FP8 comparable to H100 (§5.4).
    let g = decode_step(m, &StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()), 64, 1024);
    let h = decode_step(m, &StepConfig::new(Device::H100, PrecisionMode::fp8_dynamic()), 64, 1024);
    println!(
        "s=1024: Gaudi2 FP8 {:.0} tok/s vs H100 FP8 {:.0} tok/s",
        64.0 / g.seconds,
        64.0 / h.seconds
    );
    assert!(g.seconds < h.seconds * 1.3, "comparable decode throughput");
    println!("FIG5: REPRODUCED (shape)");
}
