//! FIG3: decode throughput vs roofline under a 400 W per-chip cap,
//! three models x sequence lengths, both devices.
//!
//! Paper claims reproduced: (a) decode is unaffected by the 400 W cap;
//! (b) H100's theoretical roofline is far higher, yet (c) Gaudi 2
//! achieves higher *measured* decode throughput in many settings,
//! (d) the Gaudi edge shrinks as sequence length grows.

use fp8_tco::analysis::perfmodel::{decode_step, PrecisionMode, StepConfig};
use fp8_tco::analysis::roofline::roofline_flops;
use fp8_tco::hwsim::spec::{DType, Device};
use fp8_tco::util::table::{f, Table};
use fp8_tco::workload::llama;

fn main() {
    let mut gaudi_wins = 0;
    let mut cells = 0;
    for name in ["llama-1b", "llama-8b", "llama-70b"] {
        let m = llama::by_name(name).unwrap();
        let mut t = Table::new(
            &format!("Fig. 3 — decode @400 W, {} b=64 (TFLOPS)", name),
            &["s", "G2 roofline", "G2 model", "H100 roofline", "H100 model",
              "G2/H100", "cap slowdown G2", "cap slowdown H100"],
        );
        for s in [256usize, 1024, 4096, 16384] {
            let mut row = vec![s.to_string()];
            let mut achieved = [0.0f64; 2];
            let mut slowdowns = [0.0f64; 2];
            for (i, dev) in [Device::Gaudi2, Device::H100].iter().enumerate() {
                let cfg = StepConfig::new(*dev, PrecisionMode::fp8_static());
                let free = decode_step(m, &cfg, 64, s);
                let capped = decode_step(m, &cfg.clone().with_cap(400.0), 64, s);
                let ci = m.decode_ci(64, s, 1.0, 2.0);
                let roof = roofline_flops(dev.spec(), DType::Fp8, ci) / 1e12;
                row.push(f(roof, 0));
                row.push(f(capped.tflops(), 1));
                achieved[i] = capped.tflops();
                slowdowns[i] = capped.seconds / free.seconds;
            }
            let ratio = achieved[0] / achieved[1];
            row.push(f(ratio, 2));
            row.push(f(slowdowns[0], 3));
            row.push(f(slowdowns[1], 3));
            // (a) cap does not hurt decode
            assert!(slowdowns[0] < 1.05 && slowdowns[1] < 1.05, "cap hurt decode");
            cells += 1;
            if ratio > 1.0 {
                gaudi_wins += 1;
            }
            t.row(row);
        }
        t.print();
        println!();
    }
    println!(
        "Gaudi 2 achieves higher measured decode throughput in {gaudi_wins}/{cells} \
         settings despite an H100 roofline ~2.3x higher (paper: 'superior \
         measured performance in many decoding settings')"
    );
    assert!(gaudi_wins * 2 >= cells, "Gaudi should win in many settings");
    println!("FIG3: REPRODUCED (shape)");
}
