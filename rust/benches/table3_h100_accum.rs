//! T3: H100 scaled FP8 GEMM — FP32 vs fast (14-bit) accumulation,
//! per-row vs per-tensor (paper §3.2 "Accumulation precision").

use fp8_tco::hwsim::gemm::{gemm_time, GemmConfig};
use fp8_tco::hwsim::spec::{Accum, Device, Scaling};
use fp8_tco::util::table::{f, pct, Table};

// Paper Table 3: (size, per-row, per-tensor) per accumulation path.
const PAPER_FP32: [(usize, f64, f64); 4] = [
    (1024, 217.0, 186.0), (2048, 299.0, 840.0),
    (4096, 362.0, 1099.0), (8192, 396.0, 1300.0),
];
const PAPER_FAST: [(usize, f64, f64); 4] = [
    (1024, 237.0, 147.0), (2048, 810.0, 896.0),
    (4096, 1136.0, 1205.0), (8192, 1123.0, 1388.0),
];

fn main() {
    let mut t = Table::new(
        "Table 3 — H100 FP8 GEMM by accumulation path (TFLOPS, peak 1989.9)",
        &["accum", "size", "per-row", "paper", "per-tensor", "paper"],
    );
    for (accum, name, paper) in [
        (Accum::Fp32, "FP32", &PAPER_FP32),
        (Accum::Fast, "Fast", &PAPER_FAST),
    ] {
        for &(s, p_row, p_tensor) in paper.iter() {
            let row = gemm_time(Device::H100, s, s, s,
                                GemmConfig::fp8(Scaling::PerRow, accum));
            let tensor = gemm_time(Device::H100, s, s, s,
                                   GemmConfig::fp8(Scaling::PerTensor, accum));
            t.row(vec![
                name.into(),
                format!("{}K", s / 1024),
                format!("{} {}", f(row.tflops(), 0), pct(row.mfu)),
                f(p_row, 0),
                format!("{} {}", f(tensor.tflops(), 0), pct(tensor.mfu)),
                f(p_tensor, 0),
            ]);
        }
    }
    t.print();

    // The table's three structural claims:
    // 1. FP32-accum row-wise plateaus near 20% MFU.
    let plateau = gemm_time(Device::H100, 8192, 8192, 8192,
                            GemmConfig::fp8(Scaling::PerRow, Accum::Fp32));
    assert!(plateau.mfu > 0.13 && plateau.mfu < 0.27, "{}", plateau.mfu);
    // 2. Fast accum recovers row-wise throughput (~3x at 8K).
    let fast = gemm_time(Device::H100, 8192, 8192, 8192,
                         GemmConfig::fp8(Scaling::PerRow, Accum::Fast));
    assert!(fast.tflops() / plateau.tflops() > 2.0);
    // 3. Crossover: per-row wins at 1K, per-tensor at 8K.
    let r1 = gemm_time(Device::H100, 1024, 1024, 1024,
                       GemmConfig::fp8(Scaling::PerRow, Accum::Fast));
    let t1 = gemm_time(Device::H100, 1024, 1024, 1024,
                       GemmConfig::fp8(Scaling::PerTensor, Accum::Fast));
    assert!(r1.tflops() > t1.tflops(), "1K: row beats tensor");
    let r8 = gemm_time(Device::H100, 8192, 8192, 8192,
                       GemmConfig::fp8(Scaling::PerRow, Accum::Fast));
    let t8 = gemm_time(Device::H100, 8192, 8192, 8192,
                       GemmConfig::fp8(Scaling::PerTensor, Accum::Fast));
    assert!(t8.tflops() > r8.tflops(), "8K: tensor beats row");
    println!("T3: REPRODUCED (shape; plateau + crossover asserted)");
}
