//! FIG6: thin-GEMM MFU comparison — Gaudi 2 holds similar MFU for
//! BF16 and FP8 at the same shape, while the H100's FP8 MFU drops
//! (its FP8 units starve on the same element feed).

use fp8_tco::hwsim::gemm::{gemm_time, GemmConfig};
use fp8_tco::hwsim::spec::{Accum, Device, Scaling};
use fp8_tco::util::table::{f, Table};

fn main() {
    let shapes: [(usize, usize); 6] = [
        (8, 1024), (32, 1024), (64, 1024),
        (8, 4096), (32, 4096), (64, 4096),
    ];
    let mut t = Table::new(
        "Fig. 6 — thin GEMM MFU (%)",
        &["(M,K=N)", "G2 bf16", "G2 fp8", "G2 drop", "H100 bf16", "H100 fp8",
          "H100 drop"],
    );
    let mut g_drops = Vec::new();
    let mut h_drops = Vec::new();
    for &(m, kn) in &shapes {
        let gb = gemm_time(Device::Gaudi2, m, kn, kn, GemmConfig::bf16()).mfu;
        let gf = gemm_time(Device::Gaudi2, m, kn, kn,
                           GemmConfig::fp8(Scaling::PerRow, Accum::Fp32)).mfu;
        let hb = gemm_time(Device::H100, m, kn, kn, GemmConfig::bf16()).mfu;
        let hf = gemm_time(Device::H100, m, kn, kn,
                           GemmConfig::fp8(Scaling::PerRow, Accum::Fast)).mfu;
        let g_drop = 1.0 - gf / gb;
        let h_drop = 1.0 - hf / hb;
        g_drops.push(g_drop);
        h_drops.push(h_drop);
        t.row(vec![
            format!("({m},{kn})"),
            f(gb * 100.0, 2),
            f(gf * 100.0, 2),
            f(g_drop * 100.0, 1),
            f(hb * 100.0, 2),
            f(hf * 100.0, 2),
            f(h_drop * 100.0, 1),
        ]);
    }
    t.print();
    let g_avg = g_drops.iter().sum::<f64>() / g_drops.len() as f64;
    let h_avg = h_drops.iter().sum::<f64>() / h_drops.len() as f64;
    println!(
        "avg FP8-vs-BF16 MFU drop: Gaudi2 {:.1}% vs H100 {:.1}% — \
         'Gaudi 2 maintains a similar MFU ... noticeable drop for the H100'",
        g_avg * 100.0,
        h_avg * 100.0
    );
    assert!(h_avg > g_avg + 0.1, "H100 must drop much more than Gaudi");
    // And the MFU gap translates into absolute thin-GEMM wins (Table 6).
    for &(m, kn) in &shapes {
        let g = gemm_time(Device::Gaudi2, m, kn, kn,
                          GemmConfig::fp8(Scaling::PerRow, Accum::Fp32));
        let h = gemm_time(Device::H100, m, kn, kn,
                          GemmConfig::fp8(Scaling::PerRow, Accum::Fast));
        assert!(g.tflops() > h.tflops());
    }
    println!("FIG6: REPRODUCED (shape)");
}
