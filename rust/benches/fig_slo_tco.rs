//! FIG-SLO: the paper's Fig. 9-style TCO map re-derived with
//! *SLO-constrained* throughput. Each cell runs the open-loop cluster
//! simulator (shared virtual clock, Poisson arrivals), binary-searches
//! the max QPS meeting TTFT p95 <= 2 s / TPOT p95 <= 50 ms, and prices
//! the surviving goodput via the rack/infra model. The final column is
//! the TCO ratio against the H100+BF16 baseline of the same traffic
//! mix — the quantity the paper's Eq. 1 calls TCO_A/TCO_B.

use fp8_tco::analysis::perfmodel::PrecisionMode;
use fp8_tco::coordinator::cluster::{max_sustainable_qps, sim_cluster, SloSpec, SweepConfig};
use fp8_tco::hwsim::spec::Device;
use fp8_tco::tco::{assumed_server_price_usd, InfraModel, RackConfig};
use fp8_tco::util::par::SweepGrid;
use fp8_tco::util::table::{f, Table};
use fp8_tco::workload::trace::TraceConfig;

const N_ENGINES: usize = 2;

fn cost_at_slo(
    infra: &InfraModel,
    dev: Device,
    prec: PrecisionMode,
    trace_at: &fn(f64) -> TraceConfig,
    slo: &SloSpec,
    sweep: &SweepConfig,
) -> Option<(f64, f64)> {
    let out = max_sustainable_qps(
        &|| sim_cluster(dev, prec, N_ENGINES),
        trace_at,
        slo,
        sweep,
    );
    out.best.map(|p| {
        let chips = infra.rack.chips_per_server as f64;
        let per_chip_tps = p.tokens_per_sec / N_ENGINES as f64;
        let cost =
            infra.cost_per_mtok(assumed_server_price_usd(dev), p.watts_mean, per_chip_tps * chips);
        (p.qps, cost)
    })
}

fn main() {
    let slo = SloSpec::interactive();
    let sweep = SweepConfig { iters: 5, n_requests: 160, seed: 13, ..SweepConfig::new(0.25, 48.0) };
    let infra = InfraModel::new(RackConfig::a100_era());
    let mixes: [(&str, fn(f64) -> TraceConfig); 2] =
        [("chat", TraceConfig::chat), ("reasoning", TraceConfig::reasoning)];
    // H100+BF16 first: it doubles as the mix's TCO-ratio baseline.
    let setups = [
        (Device::H100, PrecisionMode::Bf16),
        (Device::H100, PrecisionMode::fp8_static()),
        (Device::Gaudi2, PrecisionMode::Bf16),
        (Device::Gaudi2, PrecisionMode::fp8_static()),
    ];
    let mut t = Table::new(
        "Fig. SLO-TCO — $/Mtok at SLO and TCO ratio vs H100+BF16 (llama-8b)",
        &["mix", "device", "precision", "QPS @SLO", "$/Mtok", "TCO vs H100-bf16"],
    );
    // Every (mix x setup) cell is an independent SLO search on its own
    // fresh cluster: evaluate the whole grid concurrently (PAR=0 for
    // serial), then render in grid order — output bytes are identical
    // either way.
    let grid: Vec<(usize, Device, PrecisionMode)> = mixes
        .iter()
        .enumerate()
        .flat_map(|(mi, _)| setups.iter().map(move |&(dev, prec)| (mi, dev, prec)))
        .collect();
    let cells: Vec<Option<(f64, f64)>> = SweepGrid::new(grid).run(|_, (mi, dev, prec)| {
        cost_at_slo(&infra, dev, prec, &mixes[mi].1, &slo, &sweep)
    });
    for (mi, (mix_name, _)) in mixes.iter().enumerate() {
        let row0 = mi * setups.len();
        let base_cost = cells[row0].map(|(_, cost)| cost);
        for (si, &(dev, prec)) in setups.iter().enumerate() {
            let cell = cells[row0 + si];
            match cell {
                Some((qps, cost)) => {
                    let ratio = match base_cost {
                        Some(b) => f(cost / b, 2),
                        None => "-".into(),
                    };
                    t.row(vec![
                        (*mix_name).into(),
                        dev.name().into(),
                        prec.name().into(),
                        f(qps, 2),
                        f(cost, 3),
                        ratio,
                    ]);
                }
                None => {
                    t.row(vec![
                        (*mix_name).into(),
                        dev.name().into(),
                        prec.name().into(),
                        format!("< {}", sweep.qps_lo),
                        "-".into(),
                        "-".into(),
                    ]);
                }
            }
        }
    }
    t.print();
    println!(
        "\n(ratios < 1 mean cheaper traffic than the H100+BF16 baseline at the\n \
         same SLO — the decode-heavy reasoning mix is where thin-GEMM FP8\n \
         throughput, not peak specs, decides the column)"
    );
}
