//! T1: square FP8 GEMMs with row-wise scaling — throughput, power,
//! TFLOPS/W on both devices, model vs paper.

use fp8_tco::hwsim::gemm::{gemm_time, GemmConfig};
use fp8_tco::hwsim::power::power_draw_w;
use fp8_tco::hwsim::spec::{Accum, Device, Scaling};
use fp8_tco::util::table::{f, pct, Table};

// Paper Table 1: (size, tflops, watts) per device.
const PAPER_GAUDI2: [(usize, f64, f64); 4] = [
    (1024, 367.9, 375.0), (2048, 586.2, 460.0),
    (4096, 817.1, 460.0), (8192, 741.8, 490.0),
];
const PAPER_H100: [(usize, f64, f64); 4] = [
    (1024, 218.3, 350.0), (2048, 879.7, 690.0),
    (4096, 1167.6, 690.0), (8192, 1084.7, 690.0),
];

fn main() {
    let mut t = Table::new(
        "Table 1 — square FP8 GEMM, row-wise scaling",
        &["device", "size", "TFLOPS (model)", "TFLOPS (paper)", "W (model)",
          "W (paper)", "TFLOPS/W model", "TFLOPS/W paper"],
    );
    let mut ok = true;
    for (dev, paper, accum) in [
        (Device::Gaudi2, &PAPER_GAUDI2, Accum::Fp32),
        (Device::H100, &PAPER_H100, Accum::Fast),
    ] {
        for &(s, p_tf, p_w) in paper.iter() {
            let bd = gemm_time(dev, s, s, s, GemmConfig::fp8(Scaling::PerRow, accum));
            let w = power_draw_w(dev, bd.mfu);
            t.row(vec![
                dev.name().into(),
                format!("{}K", s / 1024),
                format!("{} {}", f(bd.tflops(), 1), pct(bd.mfu)),
                f(p_tf, 1),
                f(w, 0),
                f(p_w, 0),
                f(bd.tflops() / w, 2),
                f(p_tf / p_w, 2),
            ]);
            // shape acceptance: within 2x and same efficiency ordering
            let rel = bd.tflops() / p_tf;
            if !(0.5..=2.0).contains(&rel) {
                ok = false;
                eprintln!("DEVIATION {} {s}: model {} paper {p_tf}", dev.name(), bd.tflops());
            }
        }
    }
    t.print();
    // Qualitative claims of Table 1 / §3.3:
    let g1 = gemm_time(Device::Gaudi2, 1024, 1024, 1024,
                       GemmConfig::fp8(Scaling::PerRow, Accum::Fp32));
    let h1 = gemm_time(Device::H100, 1024, 1024, 1024,
                       GemmConfig::fp8(Scaling::PerRow, Accum::Fast));
    assert!(g1.tflops() > h1.tflops(), "Gaudi 2 higher TFLOPS at 1K");
    assert!(power_draw_w(Device::Gaudi2, 0.95) < 0.85 * 600.0,
            "Gaudi 2 stays below TDP");
    assert!(power_draw_w(Device::H100, 0.44) > 0.9 * 700.0,
            "H100 pegs near TDP from moderate utilization");
    println!("T1: {}", if ok { "REPRODUCED (shape)" } else { "DEVIATIONS — see above" });
}
