//! T6: thin GEMM throughput — the decode-phase workload (§5.6).
//! Model vs every cell of the paper's Table 6.

use fp8_tco::hwsim::gemm::{gemm_time, GemmConfig};
use fp8_tco::hwsim::spec::{Accum, Device, Scaling};
use fp8_tco::util::table::{f, Table};

// Paper Table 6: (M, K=N, gaudi_bf16, gaudi_fp8, h100_bf16, h100_fp8).
const PAPER: [(usize, usize, f64, f64, f64, f64); 12] = [
    (8, 1024, 3.3, 3.8, 1.7, 1.7),
    (16, 1024, 6.5, 11.4, 3.4, 3.9),
    (32, 1024, 12.8, 23.8, 6.5, 7.0),
    (64, 1024, 26.7, 54.0, 12.6, 14.9),
    (8, 2048, 12.4, 26.1, 6.7, 7.5),
    (16, 2048, 20.6, 48.6, 12.9, 15.0),
    (32, 2048, 48.0, 87.6, 27.1, 28.2),
    (64, 2048, 91.3, 163.2, 52.3, 60.5),
    (8, 4096, 18.8, 35.4, 14.4, 16.8),
    (16, 4096, 37.4, 67.9, 28.6, 33.5),
    (32, 4096, 73.6, 132.0, 68.3, 68.1),
    (64, 4096, 144.5, 253.4, 133.3, 133.9),
];

fn main() {
    let mut t = Table::new(
        "Table 6 — thin GEMM TFLOPS (model / paper)",
        &["(M,K,N)", "G2 bf16", "G2 fp8", "H100 bf16", "H100 fp8",
          "G2 fp8 gain", "H100 fp8 gain"],
    );
    let mut gaudi_wins = 0;
    for &(m, kn, pg_b, pg_f, ph_b, ph_f) in &PAPER {
        let gb = gemm_time(Device::Gaudi2, m, kn, kn, GemmConfig::bf16());
        let gf = gemm_time(Device::Gaudi2, m, kn, kn,
                           GemmConfig::fp8(Scaling::PerRow, Accum::Fp32));
        let hb = gemm_time(Device::H100, m, kn, kn, GemmConfig::bf16());
        let hf = gemm_time(Device::H100, m, kn, kn,
                           GemmConfig::fp8(Scaling::PerRow, Accum::Fast));
        t.row(vec![
            format!("({m},{kn},{kn})"),
            format!("{}/{}", f(gb.tflops(), 1), pg_b),
            format!("{}/{}", f(gf.tflops(), 1), pg_f),
            format!("{}/{}", f(hb.tflops(), 1), ph_b),
            format!("{}/{}", f(hf.tflops(), 1), ph_f),
            f(gb.seconds / gf.seconds, 2),
            f(hb.seconds / hf.seconds, 2),
        ]);
        // Cross-device winner on every row (the table's headline).
        assert!(gb.tflops() > hb.tflops(), "({m},{kn}) bf16: Gaudi wins");
        assert!(gf.tflops() > hf.tflops(), "({m},{kn}) fp8: Gaudi wins");
        gaudi_wins += 1;
    }
    t.print();
    println!("Gaudi 2 wins {gaudi_wins}/12 thin shapes on both dtypes (paper: 12/12)");
    // FP8 gains: ~2x Gaudi, ~1x H100 at the 4K shapes.
    let g_gain = {
        let b = gemm_time(Device::Gaudi2, 64, 4096, 4096, GemmConfig::bf16());
        let f8 = gemm_time(Device::Gaudi2, 64, 4096, 4096,
                           GemmConfig::fp8(Scaling::PerRow, Accum::Fp32));
        b.seconds / f8.seconds
    };
    let h_gain = {
        let b = gemm_time(Device::H100, 64, 4096, 4096, GemmConfig::bf16());
        let f8 = gemm_time(Device::H100, 64, 4096, 4096,
                           GemmConfig::fp8(Scaling::PerRow, Accum::Fast));
        b.seconds / f8.seconds
    };
    println!("fp8/bf16 speedup at (64,4096,4096): Gaudi2 {g_gain:.2}x (paper 1.75x), \
              H100 {h_gain:.2}x (paper 1.00x)");
    assert!(g_gain > 1.4 && h_gain < 1.25);
    println!("T6: REPRODUCED (shape; all 24 cross-device orderings hold)");
}
