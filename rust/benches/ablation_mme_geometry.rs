//! Ablation (DESIGN.md): Gaudi's reconfigurable MME geometry (Fig. 8).
//! How much of the thin-GEMM advantage comes from folding the
//! 256×256 arrays into 128×512?

use fp8_tco::hwsim::mme::{macs_per_pe, mme_cycles};
use fp8_tco::hwsim::spec::{DType, GAUDI2};
use fp8_tco::util::table::{f, Table};

fn main() {
    let full: &[(usize, usize)] = &[(256, 256), (128, 512), (512, 128)];
    let fixed: &[(usize, usize)] = &[(256, 256)];
    let macs = macs_per_pe(&GAUDI2, DType::Fp8);

    let mut t = Table::new(
        "ablation — MME folding (Gaudi 2, FP8 cycles, lower is better)",
        &["(M,K,N)", "reconfig cycles", "fixed-256 cycles", "speedup",
          "geometry chosen"],
    );
    let shapes = [
        (8usize, 1024usize, 1024usize), (64, 2048, 2048), (64, 4096, 4096),
        (128, 4096, 4096), (1024, 1024, 1024), (4096, 4096, 4096),
        (8192, 8192, 8192),
    ];
    let mut thin_speedups = Vec::new();
    for (m, k, n) in shapes {
        let a = mme_cycles(m, k, n, 2, full, macs);
        let b = mme_cycles(m, k, n, 2, fixed, macs);
        let speedup = b.cycles / a.cycles;
        if m <= 128 {
            thin_speedups.push(speedup);
        }
        t.row(vec![
            format!("({m},{k},{n})"),
            f(a.cycles, 0),
            f(b.cycles, 0),
            f(speedup, 2),
            format!("{}x{}", a.geometry.0, a.geometry.1),
        ]);
    }
    t.print();
    let avg = thin_speedups.iter().sum::<f64>() / thin_speedups.len() as f64;
    println!(
        "thin-GEMM (M<=128) mean speedup from reconfiguration: {avg:.2}x — \
         the Fig. 8 mechanism's contribution to §5.6's results"
    );
    assert!(avg > 1.2, "folding must matter for thin GEMMs");
    // Large squares shouldn't care.
    let big = mme_cycles(8192, 8192, 8192, 2, full, macs);
    let big_fixed = mme_cycles(8192, 8192, 8192, 2, fixed, macs);
    assert!((big_fixed.cycles / big.cycles - 1.0).abs() < 0.05);
    println!("ABLATION mme_geometry: folding helps thin, neutral on large");
}
