//! Ablation (paper §5.5 extension): per-GPU vs per-rack power capping.
//! Mixed prefill/decode fleet — per-rack capping lets prefill-heavy
//! GPUs borrow headroom from decode-heavy ones.

use fp8_tco::analysis::perfmodel::{decode_step, prefill, PrecisionMode, StepConfig};
use fp8_tco::hwsim::power::{apply_cap, power_draw_w, rack_allocation};
use fp8_tco::hwsim::spec::Device;
use fp8_tco::util::table::{f, Table};
use fp8_tco::workload::llama;

fn main() {
    let m = llama::by_name("llama-8b").unwrap();
    let dev = Device::H100;
    let cfg = StepConfig::new(dev, PrecisionMode::fp8_dynamic());

    // An 8-GPU server: 2 GPUs on prefill (hot), 6 on decode (cool) —
    // the Splitwise-style split of §2.2.
    let pre = prefill(m, &cfg, 4, 4096);
    let dec = decode_step(m, &cfg, 64, 1024);
    let demands: Vec<f64> = (0..8)
        .map(|i| {
            if i < 2 {
                power_draw_w(dev, pre.util_frac)
            } else {
                power_draw_w(dev, dec.util_frac)
            }
        })
        .collect();
    let budget = 8.0 * 400.0; // A100-era 400 W/GPU provisioning (§5.5)

    // Per-GPU: everyone clamped to 400 W.
    let per_gpu_pre = apply_cap(dev, 400.0, pre.seconds, pre.util_frac, 0.95);
    // Per-rack: water-filling allocation.
    let alloc = rack_allocation(budget, &demands);
    let per_rack_pre = apply_cap(dev, alloc[0], pre.seconds, pre.util_frac, 0.95);

    let mut t = Table::new(
        "ablation — power capping policy (8x H100, 3.2 kW budget)",
        &["policy", "prefill GPU W", "prefill slowdown", "decode GPU W",
          "decode slowdown", "rack W used"],
    );
    let dec_capped = apply_cap(dev, 400.0, dec.seconds, dec.util_frac, 0.05);
    t.row(vec![
        "per-GPU 400 W".into(),
        f(per_gpu_pre.watts, 0),
        f(per_gpu_pre.seconds / pre.seconds, 2),
        f(dec_capped.watts, 0),
        f(dec_capped.seconds / dec.seconds, 2),
        f(per_gpu_pre.watts * 2.0 + dec_capped.watts * 6.0, 0),
    ]);
    let dec_rack = apply_cap(dev, alloc[7], dec.seconds, dec.util_frac, 0.05);
    t.row(vec![
        "per-rack 3.2 kW".into(),
        f(per_rack_pre.watts, 0),
        f(per_rack_pre.seconds / pre.seconds, 2),
        f(dec_rack.watts, 0),
        f(dec_rack.seconds / dec.seconds, 2),
        f(per_rack_pre.watts * 2.0 + dec_rack.watts * 6.0, 0),
    ]);
    t.print();

    // The §5.5 claim: rack capping preserves the budget but speeds up
    // the throttled (prefill) GPUs.
    assert!(alloc[0] > 400.0, "prefill GPUs borrow headroom: {}", alloc[0]);
    assert!(per_rack_pre.seconds < per_gpu_pre.seconds,
            "per-rack prefill faster: {} vs {}",
            per_rack_pre.seconds, per_gpu_pre.seconds);
    assert!(alloc.iter().sum::<f64>() <= budget + 1e-6);
    println!(
        "ABLATION power_cap: per-rack capping recovers {:.0}% of prefill \
         slowdown at equal budget (§5.5's proposal quantified)",
        (per_gpu_pre.seconds - per_rack_pre.seconds)
            / (per_gpu_pre.seconds - pre.seconds).max(1e-12) * 100.0
    );
}
