//! FIG-ENERGY-FRONTIER: the Wh/Mtok-at-SLO frontier — {Llama 8B, 70B}
//! x {H100-FP8, Gaudi 3-FP8} x {uncapped, 400 W per-GPU, rack-capped}.
//! Each cell is a homogeneous disaggregated deployment (prefill pool +
//! decode pool, `auto_size`d from the chat medians) whose max Poisson
//! QPS under the interactive SLO is binary-searched, then replayed to
//! split the sustained per-chip draw per pool — busy *and* idle energy,
//! through the idle-aware ledger. The rollup prices every point three
//! ways: $/Mtok (`cost_per_mtok_disagg_plan`), facility Wh/Mtok
//! (`wh_per_mtok_disagg_plan`, PUE included), and device-level J/token.
//!
//! The rack-capped column is the new axis: the uncapped run's per-chip
//! draws become the demand vector of a 40 kW rack packed with copies of
//! the deployment, `rack_capped_per_gpu_w` water-fills the chip budget
//! (hot prefill chips borrow the headroom memory-bound decode chips
//! leave unused — not `PowerCap::PerRack`'s even share), and the QPS
//! search re-runs with each pool capped at its own allocation.
//!
//! The DVFS policy sweep adds per-pool cap tuning: each pool's
//! `power_cap` runs over a watt grid with the other pool uncapped, the
//! per-pool Wh/Mtok argmin (seeded by the uncapped point, so "no cap"
//! can win) picks the best cap for that pool, and the combined winners
//! re-measure as the `dvfs-best` row — never worse than uncapped by
//! construction, asserted.
//!
//! Grounding assertion: the 70B H100-FP8 uncapped point must land
//! within 3x of the ~0.39 J/token measured for Llama 3 70B FP8 serving
//! on H100 (J/token = sustained device W over goodput, idle included).
//!
//! Run: `cargo bench --bench fig_energy_frontier`
//! (`SWEEP_FAST=1` shrinks the search for smoke tests.)

use std::collections::BTreeMap;

use fp8_tco::analysis::disagg::{auto_size, DisaggPlan, PoolSpec};
use fp8_tco::analysis::parallel::ParallelismPlan;
use fp8_tco::analysis::perfmodel::PrecisionMode;
use fp8_tco::coordinator::cluster::{
    disagg_sim_cluster, max_sustainable_qps, replay_disagg_point, SloSpec, SweepConfig,
};
use fp8_tco::hwsim::spec::Device;
use fp8_tco::tco::{InfraModel, RackConfig};
use fp8_tco::util::json::Json;
use fp8_tco::util::par::SweepGrid;
use fp8_tco::util::table::{f, Table};
use fp8_tco::workload::llama::{by_name, LlamaConfig};
use fp8_tco::workload::trace::TraceConfig;

/// The paper-adjacent grounding point: ~0.39 J/token for Llama 3 70B
/// FP8 decode-heavy serving on H100, asserted within a 3x band.
const REF_J_PER_TOKEN_70B_H100: f64 = 0.39;

/// Rack copies fill this many chips (6 servers x 8 chips of the
/// a100-era rack) so the water-filled chip budget actually binds.
const RACK_CHIPS: usize = 48;

/// Floor of every QPS search bracket; an infeasible cell means even
/// this rate violates the SLO.
const QPS_LO: f64 = 0.2;

/// One measured frontier cell.
#[derive(Clone)]
struct Cell {
    feasible: bool,
    qps: f64,
    tokens_per_sec: f64,
    ttft_p95: f64,
    tpot_p95: f64,
    usd_per_mtok: f64,
    wh_per_mtok: f64,
    /// Device-level joules per output token: sustained draw of every
    /// chip (busy + idle, no PUE) over goodput.
    joules_per_token: f64,
    /// Per-chip sustained draw split by pool (prefill, decode), W.
    prefill_draw_w: f64,
    decode_draw_w: f64,
    /// Per-chip caps in force (0.0 = uncapped).
    prefill_cap_w: f64,
    decode_cap_w: f64,
}

fn infeasible() -> Cell {
    Cell {
        feasible: false,
        qps: 0.0,
        tokens_per_sec: 0.0,
        ttft_p95: 0.0,
        tpot_p95: 0.0,
        usd_per_mtok: 0.0,
        wh_per_mtok: 0.0,
        joules_per_token: 0.0,
        prefill_draw_w: 0.0,
        decode_draw_w: 0.0,
        prefill_cap_w: 0.0,
        decode_cap_w: 0.0,
    }
}

/// Search the plan's max QPS at SLO, replay the operating point for
/// per-pool sustained draw, and roll up the three pricing axes.
fn measure_cell(
    model: &'static LlamaConfig,
    plan: &DisaggPlan,
    caps: (f64, f64),
    slo: &SloSpec,
    sweep: &SweepConfig,
    infra: &InfraModel,
) -> Cell {
    let out = max_sustainable_qps(
        &|| {
            disagg_sim_cluster(model, plan)
                .unwrap_or_else(|e| panic!("frontier cell must be feasible: {e}"))
        },
        &TraceConfig::chat,
        slo,
        sweep,
    );
    let p = match out.best {
        None => return infeasible(),
        Some(p) => p,
    };
    let (pm, dm, _) = replay_disagg_point(
        model,
        plan,
        1,
        false,
        TraceConfig::chat(p.qps),
        sweep.n_requests,
        sweep.seed,
    )
    .expect("plan was feasible for the probe");
    let (p_chips, d_chips) =
        (plan.prefill.plan.total_chips(), plan.decode.plan.total_chips());
    let (p_w, d_w) = (pm.watts_mean(), dm.watts_mean());
    let device_w = p_w * p_chips as f64 + d_w * d_chips as f64;
    Cell {
        feasible: true,
        qps: p.qps,
        tokens_per_sec: p.tokens_per_sec,
        ttft_p95: p.ttft_p95,
        tpot_p95: p.tpot_p95,
        usd_per_mtok: infra.cost_per_mtok_disagg_plan(plan, p_w, d_w, p.tokens_per_sec),
        wh_per_mtok: infra.wh_per_mtok_disagg_plan(plan, p_w, d_w, p.tokens_per_sec),
        joules_per_token: device_w / p.tokens_per_sec,
        prefill_draw_w: p_w,
        decode_draw_w: d_w,
        prefill_cap_w: caps.0,
        decode_cap_w: caps.1,
    }
}

/// One setup's DVFS policy sweep: per-pool cap candidates (each pool
/// swept with the other uncapped), the per-pool argmins on Wh/Mtok,
/// and the combined best-cap operating point.
struct Dvfs {
    /// Winning per-pool caps (0.0 = uncapped won).
    best_prefill_cap_w: f64,
    best_decode_cap_w: f64,
    best: Cell,
    /// Every swept point: (pool, cap W, measured cell).
    pool_cells: Vec<(&'static str, f64, Cell)>,
}

/// The rack-capped frontier point: fill the rack with copies of the
/// deployment at the uncapped run's per-chip demands, water-fill the
/// chip budget, and cap each pool at its own allocation.
fn rack_caps(infra: &InfraModel, plan: &DisaggPlan, uncapped: &Cell) -> (f64, f64) {
    let (p_chips, d_chips) =
        (plan.prefill.plan.total_chips(), plan.decode.plan.total_chips());
    let copies = (RACK_CHIPS / plan.total_chips()).max(1);
    let mut demands = Vec::with_capacity(copies * plan.total_chips());
    for _ in 0..copies {
        demands.extend(std::iter::repeat(uncapped.prefill_draw_w).take(p_chips));
        demands.extend(std::iter::repeat(uncapped.decode_draw_w).take(d_chips));
    }
    let alloc = infra.rack_capped_per_gpu_w(&demands);
    (alloc[0], alloc[p_chips])
}

fn main() {
    let fast = std::env::var("SWEEP_FAST").ok().as_deref() == Some("1");
    let infra = InfraModel::new(RackConfig::a100_era());
    let slo = SloSpec::interactive();
    // Chat-mix medians drive the pool balance.
    let (p_med, o_med) = (245usize, 148usize);
    let m8 = by_name("llama-8b").unwrap();
    let m70 = by_name("llama-70b").unwrap();
    let pool = |dev: Device, plan: ParallelismPlan| {
        let prec = match dev {
            Device::H100 => PrecisionMode::fp8_dynamic(),
            _ => PrecisionMode::fp8_static(),
        };
        PoolSpec::new(dev, prec, plan)
    };
    // (model, device, instance shape, sweep ceiling). 70B needs tp2 on
    // the 80 GB H100; Gaudi 3's 128 GB holds the FP8 70B at tp1.
    type Setup = (&'static LlamaConfig, Device, ParallelismPlan, f64);
    // Ceilings sit above each deployment's saturation throughput so
    // the search converges near the true frontier (an operating point
    // deep below saturation is idle-heavy and reports inflated J/tok).
    let setups: [Setup; 4] = [
        (m8, Device::H100, ParallelismPlan::single(), 64.0),
        (m8, Device::Gaudi3, ParallelismPlan::single(), 64.0),
        (m70, Device::H100, ParallelismPlan::tp(2), 24.0),
        (m70, Device::Gaudi3, ParallelismPlan::single(), 24.0),
    ];

    // DVFS policy grid: per-pool cap candidates swept one pool at a
    // time (the other uncapped), argmin on Wh/Mtok per pool. 0 W is
    // not in the grid — "uncapped" seeds each argmin, so the reported
    // best is never worse than no policy by construction.
    let dvfs_grid: &'static [f64] = if fast { &[450.0] } else { &[350.0, 450.0, 550.0] };

    // Each setup measures its cap modes serially (the rack caps
    // derive from the uncapped demands); the four setups evaluate
    // concurrently with fixed seeds, so output bytes match serial runs.
    let grid: Vec<Setup> = setups.to_vec();
    let measured: Vec<(DisaggPlan, [Cell; 3], Option<Dvfs>)> = SweepGrid::new(grid).run(|_, setup| {
        let (model, dev, shape, qps_hi) = setup;
        let sweep = if fast {
            SweepConfig { iters: 2, n_requests: 30, seed: 17, ..SweepConfig::new(QPS_LO, qps_hi) }
        } else {
            SweepConfig { iters: 4, n_requests: 100, seed: 17, ..SweepConfig::new(QPS_LO, qps_hi) }
        };
        let plan = auto_size(model, pool(dev, shape), pool(dev, shape), p_med, o_med, 4);
        let uncapped = measure_cell(model, &plan, (0.0, 0.0), &slo, &sweep, &infra);
        let capped_plan = DisaggPlan::new(
            plan.prefill.with_cap(400.0),
            plan.decode.with_cap(400.0),
        );
        let capped =
            measure_cell(model, &capped_plan, (400.0, 400.0), &slo, &sweep, &infra);
        let racked = if uncapped.feasible {
            let (p_cap, d_cap) = rack_caps(&infra, &plan, &uncapped);
            let rack_plan =
                DisaggPlan::new(plan.prefill.with_cap(p_cap), plan.decode.with_cap(d_cap));
            measure_cell(model, &rack_plan, (p_cap, d_cap), &slo, &sweep, &infra)
        } else {
            infeasible()
        };
        // DVFS policy sweep: each pool's cap candidates run with the
        // other pool uncapped; the per-pool Wh/Mtok argmins (seeded by
        // the uncapped point) combine into the dvfs-best cell.
        let dvfs = if uncapped.feasible {
            let mut pool_cells: Vec<(&'static str, f64, Cell)> = Vec::new();
            let mut best_p = (0.0f64, uncapped.wh_per_mtok);
            let mut best_d = (0.0f64, uncapped.wh_per_mtok);
            for &cap in dvfs_grid {
                let p_plan = DisaggPlan::new(plan.prefill.with_cap(cap), plan.decode);
                let c = measure_cell(model, &p_plan, (cap, 0.0), &slo, &sweep, &infra);
                if c.feasible && c.wh_per_mtok < best_p.1 {
                    best_p = (cap, c.wh_per_mtok);
                }
                pool_cells.push(("prefill", cap, c));
                let d_plan = DisaggPlan::new(plan.prefill, plan.decode.with_cap(cap));
                let c = measure_cell(model, &d_plan, (0.0, cap), &slo, &sweep, &infra);
                if c.feasible && c.wh_per_mtok < best_d.1 {
                    best_d = (cap, c.wh_per_mtok);
                }
                pool_cells.push(("decode", cap, c));
            }
            let best = if best_p.0 == 0.0 && best_d.0 == 0.0 {
                uncapped.clone()
            } else {
                let bp = if best_p.0 > 0.0 { plan.prefill.with_cap(best_p.0) } else { plan.prefill };
                let bd = if best_d.0 > 0.0 { plan.decode.with_cap(best_d.0) } else { plan.decode };
                measure_cell(
                    model,
                    &DisaggPlan::new(bp, bd),
                    (best_p.0, best_d.0),
                    &slo,
                    &sweep,
                    &infra,
                )
            };
            Some(Dvfs {
                best_prefill_cap_w: best_p.0,
                best_decode_cap_w: best_d.0,
                best,
                pool_cells,
            })
        } else {
            None
        };
        (plan, [uncapped, capped, racked], dvfs)
    });

    // Grounding: the 70B H100-FP8 uncapped point sits in the 3x band
    // around the measured ~0.39 J/token reference.
    let (_, cells70, _) = &measured[2];
    let j = cells70[0].joules_per_token;
    assert!(cells70[0].feasible, "70B H100 uncapped cell must be feasible");
    assert!(
        j >= REF_J_PER_TOKEN_70B_H100 / 3.0 && j <= REF_J_PER_TOKEN_70B_H100 * 3.0,
        "70B H100-FP8 energy {j} J/token outside 3x of {REF_J_PER_TOKEN_70B_H100}"
    );

    // DVFS grounding: a winning nonzero cap must actually have beaten
    // the uncapped point on Wh/Mtok (the argmin was seeded with it).
    for (_, cells, dvfs) in &measured {
        let Some(d) = dvfs else { continue };
        for (pool, best_cap) in [
            ("prefill", d.best_prefill_cap_w),
            ("decode", d.best_decode_cap_w),
        ] {
            if best_cap == 0.0 {
                continue;
            }
            let won = d
                .pool_cells
                .iter()
                .find(|(p, cap, _)| *p == pool && *cap == best_cap)
                .expect("winning cap came from the sweep");
            assert!(
                won.2.feasible && won.2.wh_per_mtok <= cells[0].wh_per_mtok,
                "{pool} cap {best_cap} W won without beating uncapped"
            );
        }
    }

    let mut t = Table::new(
        "Fig. ENERGY-FRONTIER — Wh/Mtok at SLO: uncapped vs 400 W per-GPU vs \
         rack-capped (water-filled 40 kW rack) vs per-pool DVFS sweep",
        &[
            "model",
            "device",
            "cap",
            "pools",
            "cap W (p/d)",
            "QPS @SLO",
            "tok/s",
            "TPOT p95 ms",
            "$/Mtok",
            "Wh/Mtok",
            "J/tok",
        ],
    );
    let mut records: Vec<Json> = Vec::new();
    let modes = ["uncapped", "gpu-400w", "rack-capped"];
    for ((model, dev, _, _), (plan, cells, dvfs)) in setups.iter().zip(&measured) {
        // Fixed cap modes first, then the DVFS policy sweep rows and
        // the per-setup winner.
        let mut rows: Vec<(String, &Cell)> = modes
            .iter()
            .zip(cells)
            .map(|(mode, cell)| ((*mode).to_string(), cell))
            .collect();
        if let Some(d) = dvfs {
            for (pool, cap, cell) in &d.pool_cells {
                rows.push((format!("dvfs-{pool}-{cap:.0}w"), cell));
            }
            rows.push(("dvfs-best".to_string(), &d.best));
        }
        for (mode, cell) in rows {
            let mut rec = BTreeMap::new();
            rec.insert("model".into(), Json::Str(model.name.into()));
            rec.insert("device".into(), Json::Str(dev.name().into()));
            rec.insert("cap_mode".into(), Json::Str(mode.clone()));
            if mode == "dvfs-best" {
                let d = dvfs.as_ref().expect("dvfs-best row implies a sweep ran");
                rec.insert(
                    "best_prefill_cap_w".into(),
                    Json::Num(d.best_prefill_cap_w),
                );
                rec.insert("best_decode_cap_w".into(), Json::Num(d.best_decode_cap_w));
            }
            rec.insert("pools".into(), Json::Str(plan.describe()));
            rec.insert("chips".into(), Json::Num(plan.total_chips() as f64));
            rec.insert("feasible".into(), Json::Bool(cell.feasible));
            let cap_str = if cell.prefill_cap_w > 0.0 || cell.decode_cap_w > 0.0 {
                format!("{:.0}/{:.0}", cell.prefill_cap_w, cell.decode_cap_w)
            } else {
                "-".into()
            };
            if cell.feasible {
                rec.insert("qps".into(), Json::Num(cell.qps));
                rec.insert("tokens_per_sec".into(), Json::Num(cell.tokens_per_sec));
                rec.insert("ttft_p95_s".into(), Json::Num(cell.ttft_p95));
                rec.insert("tpot_p95_s".into(), Json::Num(cell.tpot_p95));
                rec.insert("usd_per_mtok".into(), Json::Num(cell.usd_per_mtok));
                rec.insert("wh_per_mtok_at_slo".into(), Json::Num(cell.wh_per_mtok));
                rec.insert("joules_per_token".into(), Json::Num(cell.joules_per_token));
                rec.insert("prefill_draw_w".into(), Json::Num(cell.prefill_draw_w));
                rec.insert("decode_draw_w".into(), Json::Num(cell.decode_draw_w));
                rec.insert("prefill_cap_w".into(), Json::Num(cell.prefill_cap_w));
                rec.insert("decode_cap_w".into(), Json::Num(cell.decode_cap_w));
                t.row(vec![
                    model.name.into(),
                    dev.name().into(),
                    mode.clone(),
                    plan.describe(),
                    cap_str,
                    f(cell.qps, 2),
                    f(cell.tokens_per_sec, 0),
                    f(cell.tpot_p95 * 1e3, 2),
                    f(cell.usd_per_mtok, 3),
                    f(cell.wh_per_mtok, 1),
                    f(cell.joules_per_token, 3),
                ]);
            } else {
                t.row(vec![
                    model.name.into(),
                    dev.name().into(),
                    mode.clone(),
                    plan.describe(),
                    cap_str,
                    format!("< {QPS_LO}"),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
            }
            records.push(Json::Obj(rec));
        }
    }
    t.print();

    let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
    let _ = std::fs::create_dir_all(&dir);
    let path = format!("{dir}/BENCH_energy_frontier.json");
    let mut root = BTreeMap::new();
    root.insert("bench".into(), Json::Str("energy_frontier".into()));
    root.insert("fast".into(), Json::Bool(fast));
    root.insert(
        "ref_j_per_token_70b_h100".into(),
        Json::Num(REF_J_PER_TOKEN_70B_H100),
    );
    root.insert("pue_ratio".into(), Json::Num(infra.rack.pue_ratio));
    root.insert(
        "dvfs_grid_w".into(),
        Json::Arr(dvfs_grid.iter().map(|&w| Json::Num(w)).collect()),
    );
    root.insert("cells".into(), Json::Arr(records));
    match std::fs::write(&path, format!("{}\n", Json::Obj(root))) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
    println!(
        "(J/tok is device energy over goodput with idle time billed at idle draw;\n \
         Wh/Mtok adds server overhead and the {:.2} PUE. The rack-capped rows cap\n \
         each pool at its water-filled share of a 40 kW rack packed with {} chips —\n \
         hot prefill chips borrow headroom cool decode chips leave unused. The\n \
         dvfs-* rows sweep each pool's cap over {:?} W with the other uncapped;\n \
         dvfs-best combines the per-pool Wh/Mtok winners)",
        infra.rack.pue_ratio, RACK_CHIPS, dvfs_grid,
    );
}
