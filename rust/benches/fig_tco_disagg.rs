//! FIG-TCO-DISAGG: the phase-split $/Mtok-at-SLO frontier — colocated
//! vs disaggregated-homogeneous vs mixed-vendor pools, each in
//! single-shot and chunked-streaming (+admission-control) flavors,
//! plus the PhaseAffinity mixed deployment, across the paper's model
//! grid and two SLO points. Each disaggregated cell builds a two-pool
//! cluster (`DisaggCluster`), migrates KV over the scale-out fabric
//! at the (chunked) closed-form cost, binary-searches the max Poisson
//! QPS meeting the SLO, and prices each pool at its own capex and
//! sustained draw (`InfraModel::cost_per_mtok_disagg`). For every
//! disaggregated plan the bench also replays the single-shot
//! operating point with chunking enabled and asserts TTFT p95 did not
//! get worse — the streaming acceptance property. Alongside the
//! table, every cell is appended to `BENCH_fig_tco_disagg.json`
//! (directory: `BENCH_JSON_DIR`, default `.`) so CI can archive the
//! trajectory and PRs stay comparable.
//!
//! Run: `cargo bench --bench fig_tco_disagg`
//! (`SWEEP_FAST=1` shrinks the search for smoke tests.)

use std::collections::BTreeMap;

use fp8_tco::analysis::disagg::{auto_size, DisaggPlan, PhaseAffinityPlan, PoolSpec};
use fp8_tco::analysis::parallel::ParallelismPlan;
use fp8_tco::analysis::perfmodel::PrecisionMode;
use fp8_tco::coordinator::cluster::{
    disagg_sim_cluster, max_sustainable_qps, phase_affinity_sim_cluster, replay_affinity_point,
    replay_disagg_point, sharded_sim_cluster, SloSpec, SweepConfig,
};
use fp8_tco::hwsim::spec::Device;
use fp8_tco::tco::{assumed_server_price_usd, InfraModel, RackConfig};
use fp8_tco::util::json::Json;
use fp8_tco::util::par::SweepGrid;
use fp8_tco::util::table::{f, Table};
use fp8_tco::workload::llama::{by_name, LlamaConfig};
use fp8_tco::workload::trace::TraceConfig;

/// KV-streaming chunk count for the streaming frontier rows.
const STREAM_CHUNKS: usize = 8;

/// One measured frontier cell.
struct Cell {
    feasible: bool,
    qps: f64,
    tokens_per_sec: f64,
    ttft_p95: f64,
    tpot_p95: f64,
    usd_per_mtok: f64,
    migrations: u64,
    bounces: u64,
    kv_gb_migrated: f64,
    /// Whole-run TTFT p95 of the pricing replay (0 when none ran) —
    /// reused by the streaming no-worse assertion so the single-shot
    /// replay is not repeated.
    replay_ttft_p95: f64,
}

fn infeasible() -> Cell {
    Cell {
        feasible: false,
        qps: 0.0,
        tokens_per_sec: 0.0,
        ttft_p95: 0.0,
        tpot_p95: 0.0,
        usd_per_mtok: 0.0,
        migrations: 0,
        bounces: 0,
        kv_gb_migrated: 0.0,
        replay_ttft_p95: 0.0,
    }
}

fn colocated_cell(
    model: &'static LlamaConfig,
    dev: Device,
    prec: PrecisionMode,
    plan: ParallelismPlan,
    slo: &SloSpec,
    sweep: &SweepConfig,
    infra: &InfraModel,
) -> Cell {
    let out = max_sustainable_qps(
        &|| {
            sharded_sim_cluster(model, dev, prec, plan)
                .unwrap_or_else(|e| panic!("colocated cell must be feasible: {e}"))
        },
        &TraceConfig::chat,
        slo,
        sweep,
    );
    match out.best {
        None => infeasible(),
        Some(p) => {
            let usd = infra.cost_per_mtok_sharded(
                assumed_server_price_usd(dev),
                plan.total_chips(),
                p.watts_mean,
                p.tokens_per_sec,
            );
            Cell {
                feasible: true,
                qps: p.qps,
                tokens_per_sec: p.tokens_per_sec,
                ttft_p95: p.ttft_p95,
                tpot_p95: p.tpot_p95,
                usd_per_mtok: usd,
                migrations: 0,
                bounces: 0,
                kv_gb_migrated: 0.0,
                replay_ttft_p95: 0.0,
            }
        }
    }
}

fn disagg_cell(
    model: &'static LlamaConfig,
    plan: &DisaggPlan,
    chunks: usize,
    admission: bool,
    slo: &SloSpec,
    sweep: &SweepConfig,
    infra: &InfraModel,
) -> Cell {
    let out = max_sustainable_qps(
        &|| {
            disagg_sim_cluster(model, plan)
                .unwrap_or_else(|e| panic!("disagg cell must be feasible: {e}"))
                .with_streaming(chunks, admission)
        },
        &TraceConfig::chat,
        slo,
        sweep,
    );
    match out.best {
        None => infeasible(),
        Some(p) => {
            // Replay the operating point to split the sustained draw
            // per pool (mixed-vendor pools price separately).
            let (pm, dm, merged) = replay_disagg_point(
                model,
                plan,
                chunks,
                admission,
                TraceConfig::chat(p.qps),
                sweep.n_requests,
                sweep.seed,
            )
            .expect("plan was feasible for the probe");
            let usd = infra.cost_per_mtok_disagg_plan(
                plan,
                pm.watts_mean(),
                dm.watts_mean(),
                p.tokens_per_sec,
            );
            Cell {
                feasible: true,
                qps: p.qps,
                tokens_per_sec: p.tokens_per_sec,
                ttft_p95: p.ttft_p95,
                tpot_p95: p.tpot_p95,
                usd_per_mtok: usd,
                migrations: merged.migrations,
                bounces: merged.bounces,
                kv_gb_migrated: merged.kv_bytes_migrated / 1e9,
                replay_ttft_p95: merged.ttft.pct(95.0),
            }
        }
    }
}

fn affinity_cell(
    model: &'static LlamaConfig,
    plan: &PhaseAffinityPlan,
    chunks: usize,
    admission: bool,
    slo: &SloSpec,
    sweep: &SweepConfig,
    infra: &InfraModel,
) -> Cell {
    let out = max_sustainable_qps(
        &|| {
            phase_affinity_sim_cluster(model, plan)
                .unwrap_or_else(|e| panic!("affinity cell must be feasible: {e}"))
                .with_streaming(chunks, admission)
        },
        &TraceConfig::chat,
        slo,
        sweep,
    );
    match out.best {
        None => infeasible(),
        Some(p) => {
            let (cm, pm, dm, merged) = replay_affinity_point(
                model,
                plan,
                chunks,
                admission,
                TraceConfig::chat(p.qps),
                sweep.n_requests,
                sweep.seed,
            )
            .expect("plan was feasible for the probe");
            let usd = infra.cost_per_mtok_phase_affinity_plan(
                plan,
                cm.watts_mean(),
                pm.watts_mean(),
                dm.watts_mean(),
                p.tokens_per_sec,
            );
            Cell {
                feasible: true,
                qps: p.qps,
                tokens_per_sec: p.tokens_per_sec,
                ttft_p95: p.ttft_p95,
                tpot_p95: p.tpot_p95,
                usd_per_mtok: usd,
                migrations: merged.migrations,
                bounces: merged.bounces,
                kv_gb_migrated: merged.kv_bytes_migrated / 1e9,
                replay_ttft_p95: merged.ttft.pct(95.0),
            }
        }
    }
}

/// The streaming acceptance property, checked on every bench point:
/// replaying a disaggregated plan's single-shot operating point with
/// chunking enabled must not worsen TTFT p95 (first-chunk delivery
/// only moves first tokens earlier; `single_p95` comes from the
/// pricing replay the single-shot cell already ran, so only the
/// chunked replay executes here). The 1 µs tolerance absorbs the
/// `(chunks-1)*lat` of extra source-KV residency a stalled prefill
/// could theoretically see — never the ms-scale regressions the
/// assertion guards against.
fn assert_streaming_ttft_no_worse(
    model: &'static LlamaConfig,
    plan: &DisaggPlan,
    qps: f64,
    n_requests: usize,
    seed: u64,
    single_p95: f64,
) {
    let (_, _, chunked) = replay_disagg_point(
        model,
        plan,
        STREAM_CHUNKS,
        false,
        TraceConfig::chat(qps),
        n_requests,
        seed,
    )
    .expect("plan was feasible for the probe");
    let c95 = chunked.ttft.pct(95.0);
    assert!(
        c95 <= single_p95 + 1e-6,
        "{}: chunked TTFT p95 {c95} worse than single-shot {single_p95} at {qps} QPS",
        plan.describe(),
    );
}

fn main() {
    let fast = std::env::var("SWEEP_FAST").ok().as_deref() == Some("1");
    let infra = InfraModel::new(RackConfig::a100_era());
    let slos: [(&str, SloSpec); 2] = [
        ("interactive", SloSpec::interactive()),
        (
            "relaxed",
            SloSpec {
                ttft_p95_s: 6.0,
                tpot_p95_s: 0.100,
                warmup_frac: 0.1,
                cooldown_frac: 0.1,
            },
        ),
    ];
    // Chat-mix medians drive the pool balance.
    let (p_med, o_med) = (245usize, 148usize);
    let m8 = by_name("llama-8b").unwrap();
    let m70 = by_name("llama-70b").unwrap();
    let h100 = |plan: ParallelismPlan| {
        PoolSpec::new(Device::H100, PrecisionMode::fp8_dynamic(), plan)
    };
    let gaudi2 = |plan: ParallelismPlan| {
        PoolSpec::new(Device::Gaudi2, PrecisionMode::fp8_static(), plan)
    };
    // (model, colocated plan, homogeneous disagg, mixed-vendor disagg,
    // PhaseAffinity mix, sweep ceiling). Equal instance budgets for
    // the colocated/disagg/mixed modes; the affinity mix spends the
    // same budget 2 colocated + 1 prefill + 1 decode.
    let affinity8 = PhaseAffinityPlan::new(
        h100(ParallelismPlan::single().with_replicas(2)),
        DisaggPlan::new(h100(ParallelismPlan::single()), gaudi2(ParallelismPlan::single())),
        2 * p_med,
    );
    let affinity70 = PhaseAffinityPlan::new(
        h100(ParallelismPlan::tp(2).with_replicas(2)),
        DisaggPlan::new(h100(ParallelismPlan::tp(2)), gaudi2(ParallelismPlan::tp(2))),
        2 * p_med,
    );
    type Setup = (
        &'static LlamaConfig,
        ParallelismPlan,
        DisaggPlan,
        DisaggPlan,
        PhaseAffinityPlan,
        f64,
    );
    let setups: [Setup; 2] = [
        (
            m8,
            ParallelismPlan::single().with_replicas(4),
            auto_size(
                m8,
                h100(ParallelismPlan::single()),
                h100(ParallelismPlan::single()),
                p_med,
                o_med,
                4,
            ),
            auto_size(
                m8,
                h100(ParallelismPlan::single()),
                gaudi2(ParallelismPlan::single()),
                p_med,
                o_med,
                4,
            ),
            affinity8,
            16.0,
        ),
        (
            m70,
            ParallelismPlan::tp(2).with_replicas(4),
            auto_size(
                m70,
                h100(ParallelismPlan::tp(2)),
                h100(ParallelismPlan::tp(2)),
                p_med,
                o_med,
                4,
            ),
            auto_size(
                m70,
                h100(ParallelismPlan::tp(2)),
                gaudi2(ParallelismPlan::single()),
                p_med,
                o_med,
                4,
            ),
            affinity70,
            8.0,
        ),
    ];

    let mut t = Table::new(
        "Fig. TCO-DISAGG — $/Mtok at SLO: colocated vs disagg (single-shot + \
         chunked streaming) vs mixed-vendor vs PhaseAffinity",
        &[
            "model",
            "SLO",
            "mode",
            "pools",
            "chips",
            "QPS @SLO",
            "tok/s",
            "TPOT p95 ms",
            "migr",
            "bounce",
            "$/Mtok @SLO",
        ],
    );
    let mut records: Vec<Json> = Vec::new();

    // One evaluation point per frontier cell. Every cell is an
    // independent SLO search on fresh clusters with a fixed seed, so
    // the whole frontier evaluates concurrently (PAR=0 forces serial);
    // rendering walks the results in build order, so table and JSON
    // bytes are identical to the serial run.
    enum CellSpec {
        Colo(&'static LlamaConfig, ParallelismPlan),
        Disagg(&'static LlamaConfig, DisaggPlan, usize, bool),
        Affinity(&'static LlamaConfig, PhaseAffinityPlan, usize, bool),
    }
    struct RowMeta {
        model_name: &'static str,
        slo_name: &'static str,
        mode: &'static str,
        pools: String,
        chips: usize,
        chunks: usize,
        qps_lo: f64,
    }
    /// Per (setup x slo) group: what the streaming acceptance
    /// assertion needs, plus the group's first row index.
    struct GroupMeta {
        model: &'static LlamaConfig,
        homog: DisaggPlan,
        mixed: DisaggPlan,
        sweep: SweepConfig,
        base: usize,
    }
    let mut points: Vec<(CellSpec, SloSpec, SweepConfig)> = Vec::new();
    let mut metas: Vec<RowMeta> = Vec::new();
    let mut groups: Vec<GroupMeta> = Vec::new();
    for (model, colo_plan, homog, mixed, affinity, qps_hi) in setups {
        for &(slo_name, slo) in &slos {
            let sweep = if fast {
                SweepConfig { iters: 2, n_requests: 30, seed: 17, ..SweepConfig::new(0.2, qps_hi) }
            } else {
                SweepConfig { iters: 4, n_requests: 100, seed: 17, ..SweepConfig::new(0.2, qps_hi) }
            };
            groups.push(GroupMeta { model, homog, mixed, sweep, base: points.len() });
            let rows: [(&'static str, String, usize, usize, CellSpec); 6] = [
                (
                    "colocated",
                    format!("H100 {colo_plan}"),
                    colo_plan.total_chips(),
                    1,
                    CellSpec::Colo(model, colo_plan),
                ),
                (
                    "disagg",
                    homog.describe(),
                    homog.total_chips(),
                    1,
                    CellSpec::Disagg(model, homog, 1, false),
                ),
                (
                    "disagg-stream",
                    homog.describe(),
                    homog.total_chips(),
                    STREAM_CHUNKS,
                    CellSpec::Disagg(model, homog, STREAM_CHUNKS, true),
                ),
                (
                    "mixed",
                    mixed.describe(),
                    mixed.total_chips(),
                    1,
                    CellSpec::Disagg(model, mixed, 1, false),
                ),
                (
                    "mixed-stream",
                    mixed.describe(),
                    mixed.total_chips(),
                    STREAM_CHUNKS,
                    CellSpec::Disagg(model, mixed, STREAM_CHUNKS, true),
                ),
                (
                    "affinity",
                    affinity.describe(),
                    affinity.total_chips(),
                    STREAM_CHUNKS,
                    CellSpec::Affinity(model, affinity, STREAM_CHUNKS, true),
                ),
            ];
            for (mode, pools, chips, chunks, spec) in rows {
                metas.push(RowMeta {
                    model_name: model.name,
                    slo_name,
                    mode,
                    pools,
                    chips,
                    chunks,
                    qps_lo: sweep.qps_lo,
                });
                points.push((spec, slo, sweep));
            }
        }
    }

    let cells: Vec<Cell> = SweepGrid::new(points).run(|_, (spec, slo, sweep)| match spec {
        CellSpec::Colo(m, plan) => colocated_cell(
            m,
            Device::H100,
            PrecisionMode::fp8_dynamic(),
            plan,
            &slo,
            &sweep,
            &infra,
        ),
        CellSpec::Disagg(m, plan, chunks, admission) => {
            disagg_cell(m, &plan, chunks, admission, &slo, &sweep, &infra)
        }
        CellSpec::Affinity(m, plan, chunks, admission) => {
            affinity_cell(m, &plan, chunks, admission, &slo, &sweep, &infra)
        }
    });

    // The streaming acceptance property: at the single-shot operating
    // point of each disaggregated plan (rows 1 and 3 of every group),
    // chunked streaming must not worsen TTFT p95.
    for g in &groups {
        for (plan, cell) in [(&g.homog, &cells[g.base + 1]), (&g.mixed, &cells[g.base + 3])] {
            if cell.feasible {
                assert_streaming_ttft_no_worse(
                    g.model,
                    plan,
                    cell.qps,
                    g.sweep.n_requests,
                    g.sweep.seed,
                    cell.replay_ttft_p95,
                );
            }
        }
    }

    for (meta, cell) in metas.into_iter().zip(&cells) {
        let mut rec = BTreeMap::new();
        rec.insert("model".into(), Json::Str(meta.model_name.into()));
        rec.insert("slo".into(), Json::Str(meta.slo_name.into()));
        rec.insert("mode".into(), Json::Str(meta.mode.into()));
        rec.insert("pools".into(), Json::Str(meta.pools.clone()));
        rec.insert("chips".into(), Json::Num(meta.chips as f64));
        rec.insert("chunks".into(), Json::Num(meta.chunks as f64));
        rec.insert("feasible".into(), Json::Bool(cell.feasible));
        if cell.feasible {
            rec.insert("qps".into(), Json::Num(cell.qps));
            rec.insert("tokens_per_sec".into(), Json::Num(cell.tokens_per_sec));
            rec.insert("ttft_p95_s".into(), Json::Num(cell.ttft_p95));
            rec.insert("tpot_p95_s".into(), Json::Num(cell.tpot_p95));
            rec.insert("usd_per_mtok".into(), Json::Num(cell.usd_per_mtok));
            rec.insert("migrations".into(), Json::Num(cell.migrations as f64));
            rec.insert("bounces".into(), Json::Num(cell.bounces as f64));
            rec.insert("kv_gb_migrated".into(), Json::Num(cell.kv_gb_migrated));
            t.row(vec![
                meta.model_name.into(),
                meta.slo_name.into(),
                meta.mode.into(),
                meta.pools,
                format!("{}", meta.chips),
                f(cell.qps, 2),
                f(cell.tokens_per_sec, 0),
                f(cell.tpot_p95 * 1e3, 2),
                format!("{}", cell.migrations),
                format!("{}", cell.bounces),
                f(cell.usd_per_mtok, 3),
            ]);
        } else {
            t.row(vec![
                meta.model_name.into(),
                meta.slo_name.into(),
                meta.mode.into(),
                meta.pools,
                format!("{}", meta.chips),
                format!("< {}", meta.qps_lo),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]);
        }
        records.push(Json::Obj(rec));
    }
    t.print();

    let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
    let _ = std::fs::create_dir_all(&dir);
    let path = format!("{dir}/BENCH_fig_tco_disagg.json");
    let mut root = BTreeMap::new();
    root.insert("bench".into(), Json::Str("fig_tco_disagg".into()));
    root.insert("fast".into(), Json::Bool(fast));
    root.insert("cells".into(), Json::Arr(records));
    match std::fs::write(&path, format!("{}\n", Json::Obj(root))) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
    println!(
        "(the mixed-vendor rows price the paper's per-phase asymmetry end-to-end:\n \
         H100 prefill + Gaudi 2 decode, KV migration charged against the fabric.\n \
         *-stream rows migrate in {STREAM_CHUNKS} chunks with decode-pool admission \
         control;\n \
         the affinity row routes prompts >= 2x the chat median to the disagg pair\n \
         and the rest to colocated engines — every streaming point is asserted\n \
         TTFT-no-worse than its single-shot twin)"
    );
}
