//! FIG-TCO-DISAGG: the phase-split $/Mtok-at-SLO frontier — colocated
//! vs disaggregated-homogeneous vs mixed-vendor pools, across the
//! paper's model grid and two SLO points. Each disaggregated cell
//! builds a two-pool cluster (`DisaggCluster`), migrates KV over the
//! scale-out fabric at the closed-form cost, binary-searches the max
//! Poisson QPS meeting the SLO, and prices each pool at its own capex
//! and sustained draw (`InfraModel::cost_per_mtok_disagg`). Alongside
//! the table, every cell is appended to `BENCH_fig_tco_disagg.json`
//! (directory: `BENCH_JSON_DIR`, default `.`) so CI can archive the
//! trajectory and PRs stay comparable.
//!
//! Run: `cargo bench --bench fig_tco_disagg`
//! (`SWEEP_FAST=1` shrinks the search for smoke tests.)

use std::collections::BTreeMap;

use fp8_tco::analysis::disagg::{auto_size, DisaggPlan, PoolSpec};
use fp8_tco::analysis::parallel::ParallelismPlan;
use fp8_tco::analysis::perfmodel::PrecisionMode;
use fp8_tco::coordinator::cluster::{
    disagg_sim_cluster, max_sustainable_qps, replay_disagg_point, sharded_sim_cluster, SloSpec,
    SweepConfig,
};
use fp8_tco::hwsim::spec::Device;
use fp8_tco::tco::{assumed_server_price, InfraModel, RackConfig};
use fp8_tco::util::json::Json;
use fp8_tco::util::table::{f, Table};
use fp8_tco::workload::llama::{by_name, LlamaConfig};
use fp8_tco::workload::trace::TraceConfig;

/// One measured frontier cell.
struct Cell {
    feasible: bool,
    qps: f64,
    tokens_per_sec: f64,
    ttft_p95: f64,
    tpot_p95: f64,
    usd_per_mtok: f64,
    migrations: u64,
    kv_gb_migrated: f64,
}

fn infeasible() -> Cell {
    Cell {
        feasible: false,
        qps: 0.0,
        tokens_per_sec: 0.0,
        ttft_p95: 0.0,
        tpot_p95: 0.0,
        usd_per_mtok: 0.0,
        migrations: 0,
        kv_gb_migrated: 0.0,
    }
}

fn colocated_cell(
    model: &'static LlamaConfig,
    dev: Device,
    prec: PrecisionMode,
    plan: ParallelismPlan,
    slo: &SloSpec,
    sweep: &SweepConfig,
    infra: &InfraModel,
) -> Cell {
    let out = max_sustainable_qps(
        &|| {
            sharded_sim_cluster(model, dev, prec, plan)
                .unwrap_or_else(|e| panic!("colocated cell must be feasible: {e}"))
        },
        &TraceConfig::chat,
        slo,
        sweep,
    );
    match out.best {
        None => infeasible(),
        Some(p) => {
            let usd = infra.cost_per_mtok_sharded(
                assumed_server_price(dev),
                plan.total_chips(),
                p.watts_mean,
                p.tokens_per_sec,
            );
            Cell {
                feasible: true,
                qps: p.qps,
                tokens_per_sec: p.tokens_per_sec,
                ttft_p95: p.ttft_p95,
                tpot_p95: p.tpot_p95,
                usd_per_mtok: usd,
                migrations: 0,
                kv_gb_migrated: 0.0,
            }
        }
    }
}

fn disagg_cell(
    model: &'static LlamaConfig,
    plan: &DisaggPlan,
    slo: &SloSpec,
    sweep: &SweepConfig,
    infra: &InfraModel,
) -> Cell {
    let out = max_sustainable_qps(
        &|| {
            disagg_sim_cluster(model, plan)
                .unwrap_or_else(|e| panic!("disagg cell must be feasible: {e}"))
        },
        &TraceConfig::chat,
        slo,
        sweep,
    );
    match out.best {
        None => infeasible(),
        Some(p) => {
            // Replay the operating point to split the sustained draw
            // per pool (mixed-vendor pools price separately).
            let (pm, dm, merged) = replay_disagg_point(
                model,
                plan,
                TraceConfig::chat(p.qps),
                sweep.n_requests,
                sweep.seed,
            );
            let usd = infra.cost_per_mtok_disagg_plan(
                plan,
                pm.watts_mean(),
                dm.watts_mean(),
                p.tokens_per_sec,
            );
            Cell {
                feasible: true,
                qps: p.qps,
                tokens_per_sec: p.tokens_per_sec,
                ttft_p95: p.ttft_p95,
                tpot_p95: p.tpot_p95,
                usd_per_mtok: usd,
                migrations: merged.migrations,
                kv_gb_migrated: merged.kv_bytes_migrated / 1e9,
            }
        }
    }
}

fn main() {
    let fast = std::env::var("SWEEP_FAST").ok().as_deref() == Some("1");
    let infra = InfraModel::new(RackConfig::a100_era());
    let slos: [(&str, SloSpec); 2] = [
        ("interactive", SloSpec::interactive()),
        (
            "relaxed",
            SloSpec {
                ttft_p95_s: 6.0,
                tpot_p95_s: 0.100,
                warmup_frac: 0.1,
                cooldown_frac: 0.1,
            },
        ),
    ];
    // Chat-mix medians drive the pool balance.
    let (p_med, o_med) = (245usize, 148usize);
    let m8 = by_name("llama-8b").unwrap();
    let m70 = by_name("llama-70b").unwrap();
    let h100 = |plan: ParallelismPlan| {
        PoolSpec::new(Device::H100, PrecisionMode::fp8_dynamic(), plan)
    };
    let gaudi2 = |plan: ParallelismPlan| {
        PoolSpec::new(Device::Gaudi2, PrecisionMode::fp8_static(), plan)
    };
    // (model, colocated plan, homogeneous disagg, mixed-vendor disagg,
    // sweep ceiling). Equal instance budgets per mode.
    let setups: [(&'static LlamaConfig, ParallelismPlan, DisaggPlan, DisaggPlan, f64); 2] = [
        (
            m8,
            ParallelismPlan::single().with_replicas(4),
            auto_size(
                m8,
                h100(ParallelismPlan::single()),
                h100(ParallelismPlan::single()),
                p_med,
                o_med,
                4,
            ),
            auto_size(
                m8,
                h100(ParallelismPlan::single()),
                gaudi2(ParallelismPlan::single()),
                p_med,
                o_med,
                4,
            ),
            16.0,
        ),
        (
            m70,
            ParallelismPlan::tp(2).with_replicas(4),
            auto_size(
                m70,
                h100(ParallelismPlan::tp(2)),
                h100(ParallelismPlan::tp(2)),
                p_med,
                o_med,
                4,
            ),
            auto_size(
                m70,
                h100(ParallelismPlan::tp(2)),
                gaudi2(ParallelismPlan::single()),
                p_med,
                o_med,
                4,
            ),
            8.0,
        ),
    ];

    let mut t = Table::new(
        "Fig. TCO-DISAGG — $/Mtok at SLO: colocated vs disaggregated vs mixed-vendor",
        &[
            "model",
            "SLO",
            "mode",
            "pools",
            "chips",
            "QPS @SLO",
            "tok/s",
            "TPOT p95 ms",
            "migrations",
            "$/Mtok @SLO",
        ],
    );
    let mut records: Vec<Json> = Vec::new();
    for (model, colo_plan, homog, mixed, qps_hi) in setups {
        for (slo_name, slo) in &slos {
            let sweep = if fast {
                SweepConfig { iters: 2, n_requests: 30, seed: 17, ..SweepConfig::new(0.2, qps_hi) }
            } else {
                SweepConfig { iters: 4, n_requests: 100, seed: 17, ..SweepConfig::new(0.2, qps_hi) }
            };
            let rows: [(&str, String, usize, Cell); 3] = [
                (
                    "colocated",
                    format!("H100 {colo_plan}"),
                    colo_plan.total_chips(),
                    colocated_cell(
                        model,
                        Device::H100,
                        PrecisionMode::fp8_dynamic(),
                        colo_plan,
                        slo,
                        &sweep,
                        &infra,
                    ),
                ),
                (
                    "disagg",
                    homog.describe(),
                    homog.total_chips(),
                    disagg_cell(model, &homog, slo, &sweep, &infra),
                ),
                (
                    "mixed",
                    mixed.describe(),
                    mixed.total_chips(),
                    disagg_cell(model, &mixed, slo, &sweep, &infra),
                ),
            ];
            for (mode, pools, chips, cell) in rows {
                let mut rec = BTreeMap::new();
                rec.insert("model".into(), Json::Str(model.name.into()));
                rec.insert("slo".into(), Json::Str((*slo_name).into()));
                rec.insert("mode".into(), Json::Str(mode.into()));
                rec.insert("pools".into(), Json::Str(pools.clone()));
                rec.insert("chips".into(), Json::Num(chips as f64));
                rec.insert("feasible".into(), Json::Bool(cell.feasible));
                if cell.feasible {
                    rec.insert("qps".into(), Json::Num(cell.qps));
                    rec.insert("tokens_per_sec".into(), Json::Num(cell.tokens_per_sec));
                    rec.insert("ttft_p95_s".into(), Json::Num(cell.ttft_p95));
                    rec.insert("tpot_p95_s".into(), Json::Num(cell.tpot_p95));
                    rec.insert("usd_per_mtok".into(), Json::Num(cell.usd_per_mtok));
                    rec.insert("migrations".into(), Json::Num(cell.migrations as f64));
                    rec.insert("kv_gb_migrated".into(), Json::Num(cell.kv_gb_migrated));
                    t.row(vec![
                        model.name.into(),
                        (*slo_name).into(),
                        mode.into(),
                        pools,
                        format!("{chips}"),
                        f(cell.qps, 2),
                        f(cell.tokens_per_sec, 0),
                        f(cell.tpot_p95 * 1e3, 2),
                        format!("{}", cell.migrations),
                        f(cell.usd_per_mtok, 3),
                    ]);
                } else {
                    t.row(vec![
                        model.name.into(),
                        (*slo_name).into(),
                        mode.into(),
                        pools,
                        format!("{chips}"),
                        format!("< {}", sweep.qps_lo),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                    ]);
                }
                records.push(Json::Obj(rec));
            }
        }
    }
    t.print();

    let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
    let _ = std::fs::create_dir_all(&dir);
    let path = format!("{dir}/BENCH_fig_tco_disagg.json");
    let mut root = BTreeMap::new();
    root.insert("bench".into(), Json::Str("fig_tco_disagg".into()));
    root.insert("fast".into(), Json::Bool(fast));
    root.insert("cells".into(), Json::Arr(records));
    match std::fs::write(&path, format!("{}\n", Json::Obj(root))) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
    println!(
        "(the mixed-vendor rows price the paper's per-phase asymmetry end-to-end:\n \
         H100 prefill + Gaudi 2 decode, KV migration charged against the fabric)"
    );
}
