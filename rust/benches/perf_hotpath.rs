//! §Perf microbenchmarks: the L3 hot paths, timed (no criterion in the
//! vendored set — fixed-iteration wall-clock with warmup).
//!
//! Targets (DESIGN.md §6, §9): hwsim gemm_time < 1 us/call so parameter
//! sweeps are instant; engine step overhead small vs modelled step
//! latency; JSON+quantize utility throughput; and the O(active)
//! scaling contract — per-step cost flat in the number of *finished*
//! sequences resident in the harvest archive (<= 2x at 10k finished vs
//! 100), with the memoized step-cost cache returning bit-identical
//! breakdowns. The event-engine section (DESIGN.md §13) races the
//! fast-forward path against the pure stepper on a decode-heavy
//! 10k-request trace — bit-identical outcomes, >= 5x wall-clock win
//! asserted. The scaling section writes `BENCH_perf_scaling.json`
//! (directory: `BENCH_JSON_DIR`, default `.`) so CI can archive the
//! perf trajectory alongside the figure benches.

use std::collections::BTreeMap;
// simlint: allow-file(determinism) -- wall-clock microbenchmark: timing real execution is the point
use std::time::Instant;

use fp8_tco::analysis::perfmodel::{decode_step, PrecisionMode, StepConfig};
use fp8_tco::coordinator::{Engine, EngineConfig, ExecutionBackend, KvCacheConfig, SimBackend};
use fp8_tco::fp8::{quantize_rtn, Format};
use fp8_tco::hwsim::gemm::{gemm_time, GemmConfig};
use fp8_tco::hwsim::spec::Device;
use fp8_tco::util::json::Json;
use fp8_tco::util::rng::Rng;
use fp8_tco::workload::llama;
use fp8_tco::workload::trace::{Request, TenantClass, TraceConfig, TraceGenerator};

fn req(id: u64, prompt_len: usize, output_len: usize) -> Request {
    Request { id, arrival: 0.0, prompt_len, output_len, class: TenantClass::Interactive }
}

fn measure<F: FnMut()>(iters: usize, f: &mut F) -> f64 {
    // warmup
    for _ in 0..iters.min(100) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    let per = measure(iters, &mut f);
    println!("{name:<44} {:>12.3} us/iter ({iters} iters)", per * 1e6);
    per
}

/// Best of three measurement passes — the asserted gates run on this
/// so a noisy-neighbor burst on a shared CI runner cannot fail an
/// unrelated PR (min is a robust estimator of the true cost floor;
/// noise only ever inflates a pass).
fn bench_min3<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    let per = (0..3)
        .map(|_| measure(iters, &mut f))
        .fold(f64::INFINITY, f64::min);
    println!("{name:<44} {:>12.3} us/iter (best of 3 x {iters} iters)", per * 1e6);
    per
}

/// An engine carrying `finished` already-completed requests in its
/// harvest archive plus 64 effectively-endless decodes in flight — the
/// shape a long trace settles into. Per-step cost must not depend on
/// `finished` (the O(active) contract).
fn engine_with_resident_finished(finished: usize) -> Engine<SimBackend> {
    let m = llama::by_name("llama-8b").unwrap();
    let kv = KvCacheConfig { block_tokens: 16, total_blocks: 1_000_000 };
    let backend =
        SimBackend::new(m, StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()));
    let mut engine = Engine::new(EngineConfig::new(kv), backend);
    // Ballast: single-token requests that finish at prefill and park
    // in the archive.
    for i in 0..finished as u64 {
        engine.submit(&req(i, 16, 1));
    }
    assert!(engine.run_to_completion(10 * finished.max(1)), "ballast must drain");
    assert_eq!(engine.finished_resident(), finished, "archive holds the history");
    // Active work: 64 decodes that outlive any measurement loop.
    for i in 0..64u64 {
        engine.submit(&req(1_000_000 + i, 64, 100_000_000));
    }
    // Warm in: prefill everything so steps are pure 64-seq decodes.
    for _ in 0..80 {
        engine.step();
    }
    engine
}

fn main() {
    println!("== perf_hotpath ==");

    // hwsim GEMM evaluation (drives every sweep).
    let mut acc = 0.0f64;
    let per_gemm = bench_min3("hwsim::gemm_time (thin fp8)", 200_000, || {
        let bd = gemm_time(Device::Gaudi2, 64, 4096, 4096,
                           GemmConfig::fp8(fp8_tco::hwsim::spec::Scaling::PerRow,
                                           fp8_tco::hwsim::spec::Accum::Fp32));
        acc += bd.seconds;
    });
    assert!(per_gemm < 1e-6, "gemm_time must stay under 1 us/call: {per_gemm}");

    // Full decode-step model.
    let m = llama::by_name("llama-8b").unwrap();
    let per_decode_model = bench("perfmodel::decode_step", 50_000, || {
        let bd = decode_step(m, &StepConfig::new(Device::Gaudi2,
                             PrecisionMode::fp8_static()), 64, 1024);
        acc += bd.seconds;
    });

    // Engine step loop: schedule+execute 64-seq decode steps on the
    // sim backend (virtual time, so this is pure coordinator cost).
    let kv = KvCacheConfig { block_tokens: 16, total_blocks: 1_000_000 };
    let backend = SimBackend::new(m, StepConfig::new(Device::Gaudi2,
                                  PrecisionMode::fp8_static()));
    let mut engine = Engine::new(EngineConfig::new(kv), backend);
    for i in 0..64u64 {
        engine.submit(&req(i, 64, 1_000_000));
    }
    // warm in: prefill everything
    for _ in 0..80 {
        engine.step();
    }
    let per_step = bench("engine.step (64-seq decode, sim)", 20_000, || {
        engine.step();
    });
    println!("  -> scheduler overhead per sequence-token: {:.1} ns",
             per_step / 64.0 * 1e9);

    // ---- O(active) scaling: step cost vs resident finished ---------
    // The contract the batcher index + harvest archive exist for: an
    // engine that has already served 10k requests must step (close to)
    // as fast as one that served 100.
    let mut e_small = engine_with_resident_finished(100);
    let per_small = bench_min3("engine.step (100 finished resident)", 5_000, || {
        e_small.step();
    });
    let mut e_big = engine_with_resident_finished(10_000);
    let per_big = bench_min3("engine.step (10k finished resident)", 5_000, || {
        e_big.step();
    });
    let scaling_ratio = per_big / per_small;
    println!("  -> step-cost ratio 10k/100 finished: {scaling_ratio:.2}x");
    assert!(
        scaling_ratio <= 2.0,
        "engine step cost must be flat in resident finished sequences: \
         {per_small}s at 100 vs {per_big}s at 10k ({scaling_ratio:.2}x)"
    );

    // ---- memoized step-cost cache: bit-identity ---------------------
    // Exact-key memoization must return the identical StepBreakdown
    // bits that a fresh computation produces.
    {
        let mut cached =
            SimBackend::new(m, StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()));
        let mut plain =
            SimBackend::new(m, StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()));
        plain.set_cache(false);
        let specs: Vec<(u64, usize)> = (0..64).map(|i| (i, 1024)).collect();
        let first = cached.decode(&specs);
        let hit = cached.decode(&specs);
        let fresh = plain.decode(&specs);
        for (a, b) in [
            (first.seconds, hit.seconds),
            (first.watts, hit.watts),
            (first.flops, hit.flops),
            (first.seconds, fresh.seconds),
            (first.watts, fresh.watts),
            (first.flops, fresh.flops),
        ] {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "memoized decode_step must be bit-identical to recompute"
            );
        }
        let cs = cached.cache_stats().expect("sim backend memoizes by default");
        assert_eq!((cs.hits, cs.misses), (1, 1));
        println!("memoized decode_step: bit-identical (hit rate {:.2})", cs.hit_rate());
    }

    // ---- percentile cache: query cost after the one-time sort ------
    // measure_load probes call pct()/pct_in() repeatedly on the same
    // frozen sample set; the cached sort order makes every call after
    // the first a partition_point + interpolation. The clone-and-sort
    // implementation this replaced paid O(n log n) *per query* (~ms at
    // this size) and fails the gate by orders of magnitude.
    let (pct_first_us, pct_query_us) = {
        use fp8_tco::util::stats::TimedPercentiles;
        let mut tp = TimedPercentiles::new();
        let mut r = Rng::new(7);
        for i in 0..200_000 {
            tp.add(i as f64 * 1e-3, r.f64());
        }
        let t0 = Instant::now();
        acc += tp.pct(95.0) + tp.pct_in(20.0, 180.0, 95.0);
        let first = t0.elapsed().as_secs_f64();
        let per = bench_min3("stats::pct+pct_in (200k samples, cached)", 50_000, || {
            acc += tp.pct(95.0) + tp.pct_in(20.0, 180.0, 95.0);
        });
        println!("  -> first query (sorts once): {:.1} us", first * 1e6);
        assert!(
            per < 50e-6,
            "cached percentile queries must not re-sort 200k samples per call: {per}s"
        );
        (first * 1e6, per * 1e6)
    };

    // ---- end-to-end: 10k-request open-loop sim ---------------------
    // The production-scale shape PR 6+ sweeps: one engine, 10k Poisson
    // chat arrivals, virtual clock. Wall time is pure coordinator +
    // model-cache cost.
    let (e2e_wall_s, e2e_steps, e2e_virtual_s, cache_hit_rate) = {
        let backend =
            SimBackend::new(m, StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()));
        let mut engine = Engine::new(
            EngineConfig::new(KvCacheConfig { block_tokens: 16, total_blocks: 1_000_000 }),
            backend,
        );
        let mut gen = TraceGenerator::new(TraceConfig::chat(50.0), 11);
        for r in gen.take(10_000) {
            engine.submit(&r);
        }
        let t0 = Instant::now();
        let drained = engine.run_to_completion(50_000_000);
        let wall = t0.elapsed().as_secs_f64();
        assert!(drained, "10k-request trace must drain");
        assert_eq!(engine.metrics.requests_done, 10_000);
        assert_eq!(engine.finished_resident(), 10_000);
        println!(
            "{:<44} {:>12.3} ms total ({} steps, {:.1} s virtual, cache hit {:.2})",
            "engine e2e (10k-request chat trace)",
            wall * 1e3,
            engine.metrics.steps,
            engine.clock(),
            engine.metrics.step_cache_hit_rate(),
        );
        (wall, engine.metrics.steps, engine.clock(), engine.metrics.step_cache_hit_rate())
    };

    // ---- event engine vs stepper: decode-heavy 10k-request trace ---
    // The event core's headline win (DESIGN.md §13): on decode-
    // dominated traffic the fast-forward path collapses per-step
    // scheduling into an O(1) analytic charge, so wall-clock drops by
    // the window length. Outputs are pinned to 1k tokens so ~64 long
    // decodes stay in flight and static windows span the gaps between
    // finishes. Both runs must stay bit-identical (the differential
    // suite's contract, re-checked here on the big trace) and the
    // event path must clear a 5x end-to-end wall-clock win.
    let (ev_wall_s, st_wall_s, ev_speedup) = {
        let decode_heavy = || -> Vec<Request> {
            let mut gen = TraceGenerator::new(TraceConfig::chat(50.0), 11);
            gen.take(10_000)
                .into_iter()
                .map(|mut r| {
                    r.output_len = 1_024;
                    r
                })
                .collect()
        };
        let run = |event_mode: bool| {
            let backend = SimBackend::new(
                m,
                StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()),
            );
            let mut engine = Engine::new(
                EngineConfig::new(KvCacheConfig {
                    block_tokens: 16,
                    total_blocks: 1_000_000,
                }),
                backend,
            );
            engine.set_event_mode(event_mode);
            for r in decode_heavy() {
                engine.submit(&r);
            }
            let t0 = Instant::now();
            let drained = engine.run_to_completion(50_000_000);
            let wall = t0.elapsed().as_secs_f64();
            assert!(drained, "decode-heavy 10k trace must drain");
            assert_eq!(engine.metrics.requests_done, 10_000);
            let fp = (
                engine.clock().to_bits(),
                engine.metrics.steps,
                engine.metrics.tokens_out,
                engine.metrics.energy_j.to_bits(),
                engine.metrics.gated_s.to_bits(),
                engine.metrics.ttft.pct(95.0).to_bits(),
                engine.metrics.e2e_latency.pct(95.0).to_bits(),
            );
            (wall, fp)
        };
        let (ev_wall, ev_fp) = run(true);
        let (st_wall, st_fp) = run(false);
        assert_eq!(ev_fp, st_fp, "event engine must be bit-identical to the stepper");
        let speedup = st_wall / ev_wall;
        println!(
            "{:<44} {:>12.3} ms event vs {:.3} ms stepper ({speedup:.1}x)",
            "engine e2e event vs stepper (10k, 1k-out)",
            ev_wall * 1e3,
            st_wall * 1e3,
        );
        assert!(
            speedup >= 5.0,
            "event engine must beat the stepper 5x on decode-heavy traffic: \
             {ev_wall:.3}s event vs {st_wall:.3}s stepper ({speedup:.2}x)"
        );
        (ev_wall, st_wall, speedup)
    };

    // FP8 scalar quantization.
    let mut rng = Rng::new(1);
    let xs: Vec<f32> = (0..4096).map(|_| rng.normal() as f32).collect();
    bench("fp8::quantize_rtn x4096", 20_000, || {
        for &x in &xs {
            acc += quantize_rtn(x, Format::E4M3FN) as f64;
        }
    });

    // JSON parse (golden-vector loading path).
    let doc = format!(
        "{{\"x\":[{}]}}",
        (0..2000).map(|i| format!("{}.5", i)).collect::<Vec<_>>().join(",")
    );
    bench("util::json parse 2k-float doc", 5_000, || {
        let j = Json::parse(&doc).unwrap();
        acc += j.get("x").unwrap().idx(0).unwrap().as_f64().unwrap();
    });

    // ---- BENCH_perf_scaling.json: seed the perf trajectory ---------
    let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
    let _ = std::fs::create_dir_all(&dir);
    let path = format!("{dir}/BENCH_perf_scaling.json");
    let mut root = BTreeMap::new();
    root.insert("bench".into(), Json::Str("perf_scaling".into()));
    root.insert("gemm_time_us".into(), Json::Num(per_gemm * 1e6));
    root.insert("decode_step_model_us".into(), Json::Num(per_decode_model * 1e6));
    root.insert("engine_step_64seq_us".into(), Json::Num(per_step * 1e6));
    root.insert("step_us_finished_100".into(), Json::Num(per_small * 1e6));
    root.insert("step_us_finished_10k".into(), Json::Num(per_big * 1e6));
    root.insert("scaling_ratio_10k_over_100".into(), Json::Num(scaling_ratio));
    root.insert("e2e_requests".into(), Json::Num(10_000.0));
    root.insert("e2e_wall_s".into(), Json::Num(e2e_wall_s));
    root.insert("e2e_steps".into(), Json::Num(e2e_steps as f64));
    root.insert("e2e_virtual_s".into(), Json::Num(e2e_virtual_s));
    root.insert("e2e_cache_hit_rate".into(), Json::Num(cache_hit_rate));
    root.insert("e2e_event_wall_s".into(), Json::Num(ev_wall_s));
    root.insert("e2e_stepper_wall_s".into(), Json::Num(st_wall_s));
    root.insert("e2e_event_speedup".into(), Json::Num(ev_speedup));
    root.insert("pct_first_query_us".into(), Json::Num(pct_first_us));
    root.insert("pct_cached_query_us".into(), Json::Num(pct_query_us));
    match std::fs::write(&path, format!("{}\n", Json::Obj(root))) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    println!("(sink {acc:.3e})");
}
