//! §Perf microbenchmarks: the L3 hot paths, timed (no criterion in the
//! vendored set — fixed-iteration wall-clock with warmup).
//!
//! Targets (DESIGN.md §6): hwsim gemm_time < 1 us/call so parameter
//! sweeps are instant; engine step overhead small vs modelled step
//! latency; JSON+quantize utility throughput.

use std::time::Instant;

use fp8_tco::analysis::perfmodel::{decode_step, PrecisionMode, StepConfig};
use fp8_tco::coordinator::{Engine, EngineConfig, KvCacheConfig, SimBackend};
use fp8_tco::fp8::{quantize_rtn, Format};
use fp8_tco::hwsim::gemm::{gemm_time, GemmConfig};
use fp8_tco::hwsim::spec::Device;
use fp8_tco::util::json::Json;
use fp8_tco::util::rng::Rng;
use fp8_tco::workload::llama;
use fp8_tco::workload::trace::Request;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // warmup
    for _ in 0..iters.min(100) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name:<44} {:>12.3} us/iter ({iters} iters)", per * 1e6);
    per
}

fn main() {
    println!("== perf_hotpath ==");

    // hwsim GEMM evaluation (drives every sweep).
    let mut acc = 0.0f64;
    let per = bench("hwsim::gemm_time (thin fp8)", 200_000, || {
        let bd = gemm_time(Device::Gaudi2, 64, 4096, 4096,
                           GemmConfig::fp8(fp8_tco::hwsim::spec::Scaling::PerRow,
                                           fp8_tco::hwsim::spec::Accum::Fp32));
        acc += bd.seconds;
    });
    assert!(per < 1e-6, "gemm_time must stay under 1 us/call: {per}");

    // Full decode-step model.
    let m = llama::by_name("llama-8b").unwrap();
    bench("perfmodel::decode_step", 50_000, || {
        let bd = decode_step(m, &StepConfig::new(Device::Gaudi2,
                             PrecisionMode::fp8_static()), 64, 1024);
        acc += bd.seconds;
    });

    // Engine step loop: schedule+execute 64-seq decode steps on the
    // sim backend (virtual time, so this is pure coordinator cost).
    let kv = KvCacheConfig { block_tokens: 16, total_blocks: 1_000_000 };
    let backend = SimBackend::new(m, StepConfig::new(Device::Gaudi2,
                                  PrecisionMode::fp8_static()));
    let mut engine = Engine::new(EngineConfig::new(kv), backend);
    for i in 0..64u64 {
        engine.submit(&Request { id: i, arrival: 0.0, prompt_len: 64,
                                 output_len: 1_000_000 });
    }
    // warm in: prefill everything
    for _ in 0..80 {
        engine.step();
    }
    let per_step = bench("engine.step (64-seq decode, sim)", 20_000, || {
        engine.step();
    });
    println!("  -> scheduler overhead per sequence-token: {:.1} ns",
             per_step / 64.0 * 1e9);

    // FP8 scalar quantization.
    let mut rng = Rng::new(1);
    let xs: Vec<f32> = (0..4096).map(|_| rng.normal() as f32).collect();
    bench("fp8::quantize_rtn x4096", 20_000, || {
        for &x in &xs {
            acc += quantize_rtn(x, Format::E4M3FN) as f64;
        }
    });

    // JSON parse (golden-vector loading path).
    let doc = format!(
        "{{\"x\":[{}]}}",
        (0..2000).map(|i| format!("{}.5", i)).collect::<Vec<_>>().join(",")
    );
    bench("util::json parse 2k-float doc", 5_000, || {
        let j = Json::parse(&doc).unwrap();
        acc += j.get("x").unwrap().idx(0).unwrap().as_f64().unwrap();
    });

    println!("(sink {acc:.3e})");
}
