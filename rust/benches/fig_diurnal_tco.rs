//! FIG-DIURNAL-TCO: price a production day — {Llama 8B, 70B} x
//! {H100-FP8, Gaudi 3-FP8} x {static fleet, autoscaled fleet} x
//! {uniform, diurnal, bursty} multi-tenant traffic.
//!
//! Every cell serves the *same* day of arrivals (70% chat-interactive,
//! 30% summarize-batch) on a 4-replica fleet. The static fleet keeps
//! all replicas powered — sized for the peak, idling through the
//! trough. The autoscaled fleet owns identical hardware but
//! power-gates replicas to 0 W when windowed queue depth runs low and
//! wakes them (after a provisioning delay) when it runs high. Both
//! ledgers are closed at one shared day end, and
//! `InfraModel::cost_per_mtok_diurnal` prices each: capex + rack share
//! for the capacity *owned*, electricity for the energy *drawn*.
//!
//! Grounding assertions, every cell: both fleets drain the day and
//! deliver identical tokens; the autoscaled fleet gates a nonzero
//! share of its replica-seconds; and the autoscaled day is never
//! costlier than the static fleet sized for peak — gating can only
//! remove electricity, never capacity (the capex terms are identical
//! by construction).
//!
//! Run: `cargo bench --bench fig_diurnal_tco`
//! (`SWEEP_FAST=1` shrinks the day for smoke tests.)

use std::collections::BTreeMap;

use fp8_tco::analysis::parallel::ParallelismPlan;
use fp8_tco::analysis::perfmodel::PrecisionMode;
use fp8_tco::coordinator::cluster::{
    autoscaled_sim_cluster, sharded_sim_cluster, AutoscalerConfig,
};
use fp8_tco::coordinator::Metrics;
use fp8_tco::hwsim::spec::Device;
use fp8_tco::tco::{assumed_server_price_usd, DayUsage, InfraModel, RackConfig};
use fp8_tco::util::json::Json;
use fp8_tco::util::par::SweepGrid;
use fp8_tco::util::table::{f, Table};
use fp8_tco::workload::llama::{by_name, LlamaConfig};
use fp8_tco::workload::trace::{
    ArrivalProcess, RateCurve, Request, TrafficConfig, TrafficGenerator,
};

const SEED: u64 = 17;

/// Fleet size every cell owns: the static fleet keeps all four
/// powered, the autoscaled fleet grows into them from `min_replicas`.
const REPLICAS: usize = 4;

const TRAFFICS: [&str; 3] = ["uniform", "diurnal", "bursty"];

/// One fleet's priced day.
struct FleetDay {
    drained: bool,
    usd_per_mtok: f64,
    wh_per_mtok: f64,
    tokens_out: u64,
    /// Share of owned replica-seconds spent power-gated at 0 W.
    gated_frac: f64,
    /// Mean per-chip draw over the whole day, gated time included (W).
    watts_mean_w: f64,
    scale_ups: u64,
    scale_downs: u64,
}

/// Serve one day of arrivals on both fleets, close both ledgers at a
/// shared day end, and price each through the diurnal TCO model.
fn price_day(
    infra: &InfraModel,
    model: &'static LlamaConfig,
    dev: Device,
    shape: ParallelismPlan,
    acfg: AutoscalerConfig,
    reqs: &[Request],
    day_s: f64,
) -> (FleetDay, FleetDay) {
    let prec = match dev {
        Device::H100 => PrecisionMode::fp8_dynamic(),
        _ => PrecisionMode::fp8_static(),
    };
    let plan = shape.with_replicas(REPLICAS);
    let chips = shape.chips_per_instance();
    let mut stat = sharded_sim_cluster(model, dev, prec, plan)
        .unwrap_or_else(|e| panic!("static fleet must fit: {e}"));
    let mut auto = autoscaled_sim_cluster(model, dev, prec, plan, acfg)
        .unwrap_or_else(|e| panic!("autoscaled fleet must fit: {e}"));
    let ok_s = stat.run(reqs.iter().cloned());
    let ok_a = auto.run(reqs.iter().cloned());
    // One shared billing window: the day, extended to whichever fleet
    // drained last (arrivals near the horizon finish past it). Both
    // closes are idempotent extensions, so capex and electricity see
    // the same timeline on both sides.
    let day_end = day_s.max(stat.makespan()).max(auto.makespan());
    stat.router.close_ledgers(day_end);
    auto.close_to(day_end);
    let sm = stat.merged_metrics();
    let am = auto.merged_metrics();
    // The rack is provisioned for the static fleet's sustained draw —
    // both fleets own identical hardware and pay identical capex; the
    // autoscaled one differs only in what it draws.
    let provision_w = sm.watts_mean();
    let price = assumed_server_price_usd(dev);
    let priced = |m: &Metrics, drained: bool, ups: u64, downs: u64| {
        let u = DayUsage::from_fleet(m, chips, day_end);
        FleetDay {
            drained,
            usd_per_mtok: infra.cost_per_mtok_diurnal(price, chips, REPLICAS, provision_w, &u),
            wh_per_mtok: infra.wh_per_mtok_diurnal(chips, &u),
            tokens_out: u.tokens_out,
            gated_frac: u.gated_replica_s / (REPLICAS as f64 * day_end),
            watts_mean_w: m.watts_mean(),
            scale_ups: ups,
            scale_downs: downs,
        }
    };
    (priced(&sm, ok_s, 0, 0), priced(&am, ok_a, auto.scale_ups, auto.scale_downs))
}

fn main() {
    let fast = std::env::var("SWEEP_FAST").ok().as_deref() == Some("1");
    // A compressed "day": the diurnal shape squeezed into two hours
    // (30 min under SWEEP_FAST) keeps the bench minutes-scale while
    // the rate dynamics still dwarf the autoscaler's reaction time.
    let day_s = if fast { 1800.0 } else { 7200.0 };
    let infra = InfraModel::new(RackConfig::a100_era());
    let acfg = AutoscalerConfig {
        min_replicas: 1,
        scale_up_depth: 3.0,
        scale_down_depth: 0.5,
        provisioning_delay_s: 30.0,
        decision_interval_s: 10.0,
        depth_window: 3,
    };
    let m8 = by_name("llama-8b").unwrap();
    let m70 = by_name("llama-70b").unwrap();
    // (model, device, instance shape, peak fleet QPS). 70B needs tp2
    // on the 80 GB H100; Gaudi 3's 128 GB holds the FP8 70B at tp1.
    // Peaks sit comfortably inside fleet capacity — this bench prices
    // accounting over a day, it does not search the SLO frontier.
    type Setup = (&'static LlamaConfig, Device, ParallelismPlan, f64);
    let setups: [Setup; 4] = [
        (m8, Device::H100, ParallelismPlan::single(), 8.0),
        (m8, Device::Gaudi3, ParallelismPlan::single(), 8.0),
        (m70, Device::H100, ParallelismPlan::tp(2), 2.0),
        (m70, Device::Gaudi3, ParallelismPlan::single(), 2.0),
    ];

    // Three days at (nearly) iso-mean traffic: flat at the diurnal
    // mean, the raised-cosine day, and an MMPP whose bursts touch the
    // same peak. 30% of arrivals are batch-class summarize jobs.
    let traffic = |name: &str, peak: f64| -> TrafficConfig {
        match name {
            "uniform" => TrafficConfig::multi_tenant(
                ArrivalProcess::Modulated(RateCurve::new(vec![
                    (0.0, 0.55 * peak),
                    (day_s, 0.55 * peak),
                ])),
                0.3,
            ),
            "diurnal" => TrafficConfig::multi_tenant(
                ArrivalProcess::Modulated(RateCurve::diurnal(day_s, 0.1 * peak, peak)),
                0.3,
            ),
            "bursty" => TrafficConfig::multi_tenant(
                ArrivalProcess::Mmpp {
                    base_qps: 0.2 * peak,
                    burst_qps: peak,
                    mean_base_s: day_s / 20.0,
                    mean_burst_s: day_s / 60.0,
                },
                0.3,
            ),
            other => panic!("unknown traffic shape {other}"),
        }
    };

    // The 12 (setup, traffic) cells evaluate concurrently; each cell
    // regenerates its trace from the fixed seed, so output bytes match
    // a serial run.
    let mut grid: Vec<(usize, &'static str)> = Vec::new();
    for si in 0..setups.len() {
        for tr in TRAFFICS {
            grid.push((si, tr));
        }
    }
    let measured: Vec<(usize, &'static str, usize, FleetDay, FleetDay)> =
        SweepGrid::new(grid).run(|_, (si, tr)| {
            let (model, dev, shape, peak) = setups[si];
            let reqs = TrafficGenerator::new(traffic(tr, peak), SEED).until(day_s);
            let (s, a) = price_day(&infra, model, dev, shape, acfg, &reqs, day_s);
            (si, tr, reqs.len(), s, a)
        });

    // Grounding: every cell drains, delivers identical tokens on both
    // fleets, gates a nonzero share when autoscaled, and the
    // autoscaled day is never costlier than static-for-peak.
    for (si, tr, _, s, a) in &measured {
        let (model, dev, _, _) = setups[*si];
        let cell = format!("{} {} {tr}", model.name, dev.name());
        assert!(s.drained && a.drained, "{cell}: both fleets must drain the day");
        assert_eq!(s.tokens_out, a.tokens_out, "{cell}: same work on both fleets");
        assert!(a.gated_frac > 0.0, "{cell}: autoscaled fleet never gated");
        assert!(
            a.usd_per_mtok <= s.usd_per_mtok * (1.0 + 1e-9),
            "{cell}: autoscaled {} $/Mtok costlier than static-for-peak {}",
            a.usd_per_mtok,
            s.usd_per_mtok
        );
    }

    let mut t = Table::new(
        "Fig. DIURNAL-TCO — $/Mtok over a day: static fleet sized for peak vs \
         replica autoscaling (power-gated sleep), multi-tenant traffic",
        &[
            "model",
            "device",
            "traffic",
            "fleet",
            "reqs",
            "Mtok",
            "gated %",
            "mean W/chip",
            "scale +/-",
            "Wh/Mtok",
            "$/Mtok",
        ],
    );
    let mut records: Vec<Json> = Vec::new();
    for (si, tr, n_reqs, s, a) in &measured {
        let (model, dev, shape, peak) = setups[*si];
        for (mode, fleet) in [("static", s), ("autoscaled", a)] {
            let mut rec = BTreeMap::new();
            rec.insert("model".into(), Json::Str(model.name.into()));
            rec.insert("device".into(), Json::Str(dev.name().into()));
            rec.insert("traffic".into(), Json::Str((*tr).into()));
            rec.insert("fleet".into(), Json::Str(mode.into()));
            rec.insert("replicas".into(), Json::Num(REPLICAS as f64));
            rec.insert(
                "chips_per_replica".into(),
                Json::Num(shape.chips_per_instance() as f64),
            );
            rec.insert("peak_qps".into(), Json::Num(peak));
            rec.insert("requests".into(), Json::Num(*n_reqs as f64));
            rec.insert("feasible".into(), Json::Bool(fleet.drained));
            rec.insert("tokens_out".into(), Json::Num(fleet.tokens_out as f64));
            rec.insert("gated_frac".into(), Json::Num(fleet.gated_frac));
            rec.insert("watts_mean_per_chip".into(), Json::Num(fleet.watts_mean_w));
            rec.insert("scale_ups".into(), Json::Num(fleet.scale_ups as f64));
            rec.insert("scale_downs".into(), Json::Num(fleet.scale_downs as f64));
            rec.insert("wh_per_mtok".into(), Json::Num(fleet.wh_per_mtok));
            rec.insert("usd_per_mtok".into(), Json::Num(fleet.usd_per_mtok));
            records.push(Json::Obj(rec));
            t.row(vec![
                model.name.into(),
                dev.name().into(),
                (*tr).into(),
                mode.into(),
                format!("{n_reqs}"),
                f(fleet.tokens_out as f64 / 1e6, 2),
                f(fleet.gated_frac * 100.0, 1),
                f(fleet.watts_mean_w, 0),
                format!("{}/{}", fleet.scale_ups, fleet.scale_downs),
                f(fleet.wh_per_mtok, 1),
                f(fleet.usd_per_mtok, 3),
            ]);
        }
    }
    t.print();

    let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
    let _ = std::fs::create_dir_all(&dir);
    let path = format!("{dir}/BENCH_diurnal_tco.json");
    let mut root = BTreeMap::new();
    root.insert("bench".into(), Json::Str("diurnal_tco".into()));
    root.insert("fast".into(), Json::Bool(fast));
    root.insert("day_s".into(), Json::Num(day_s));
    root.insert("replicas".into(), Json::Num(REPLICAS as f64));
    root.insert("pue_ratio".into(), Json::Num(infra.rack.pue_ratio));
    root.insert("cells".into(), Json::Arr(records));
    match std::fs::write(&path, format!("{}\n", Json::Obj(root))) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
    println!(
        "(both fleets own {REPLICAS} replicas and pay identical capex; the autoscaled\n \
         rows differ only in energy drawn — gated replica-seconds bill at 0 W through\n \
         the idle-aware ledger, so autoscaled <= static on every cell by construction)"
    );
}
