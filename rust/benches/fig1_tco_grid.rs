//! FIG1: regenerate the paper's Fig. 1 TCO grid and diff it against
//! the published values cell by cell.

use fp8_tco::tco::fig1_grid;
use fp8_tco::util::table::{f, Table};

/// Fig. 1 as printed in the paper (rows R_Th 1.0→0.3, cols R_SC 1.0→0.1).
const PAPER: [[f64; 10]; 8] = [
    [1.00, 0.95, 0.90, 0.85, 0.80, 0.75, 0.70, 0.65, 0.60, 0.55],
    [1.11, 1.06, 1.00, 0.94, 0.89, 0.83, 0.78, 0.72, 0.67, 0.61],
    [1.25, 1.19, 1.13, 1.06, 1.00, 0.94, 0.88, 0.81, 0.75, 0.69],
    [1.43, 1.36, 1.29, 1.21, 1.14, 1.07, 1.00, 0.93, 0.86, 0.79],
    [1.67, 1.58, 1.50, 1.42, 1.33, 1.25, 1.17, 1.08, 1.00, 0.92],
    [2.00, 1.90, 1.80, 1.70, 1.60, 1.50, 1.40, 1.30, 1.20, 1.10],
    [2.50, 2.38, 2.25, 2.13, 2.00, 1.88, 1.75, 1.63, 1.50, 1.38],
    [3.33, 3.17, 3.00, 2.83, 2.67, 2.50, 2.33, 2.17, 2.00, 1.83],
];

fn main() {
    let grid = fig1_grid();
    let mut t = Table::new(
        "Fig. 1 — TCO ratio A/B (model output; every cell == paper to 2 dp)",
        &["R_Th \\ R_SC", "1.00", "0.90", "0.80", "0.70", "0.60", "0.50",
          "0.40", "0.30", "0.20", "0.10"],
    );
    let mut max_dev = 0.0f64;
    for (ri, chunk) in grid.chunks(10).enumerate() {
        let mut row = vec![format!("{:.2}", chunk[0].0)];
        for (ci, &(_, _, v)) in chunk.iter().enumerate() {
            max_dev = max_dev.max((v - PAPER[ri][ci]).abs());
            row.push(f(v, 2));
        }
        t.row(row);
    }
    t.print();
    println!("max |model - paper| = {max_dev:.4} (rounding only)");
    assert!(max_dev < 0.005 + 1e-9, "Fig. 1 must match exactly");
    println!("FIG1: REPRODUCED (exact)");
}
