//! FIG-TCO-MULTICHIP: the $/Mtok-at-SLO frontier for multi-chip
//! deployments — the paper's Eq. 1 extended past its single-chip
//! measurements. Each cell builds a cluster of *sharded* model
//! instances (TP ring all-reduces + PP bubbles priced by
//! `hwsim::interconnect`), binary-searches the max Poisson QPS meeting
//! the interactive SLO, and prices the surviving goodput with the
//! rack/infra model. Alongside the table, every cell is appended to
//! `BENCH_fig_tco_multichip.json` (directory: `BENCH_JSON_DIR`, default
//! `.`) so CI can archive the trajectory and PRs stay comparable.
//!
//! Run: `cargo bench --bench fig_tco_multichip`
//! (`SWEEP_FAST=1` shrinks the search for smoke tests.)

use std::collections::BTreeMap;

use fp8_tco::analysis::parallel::ParallelismPlan;
use fp8_tco::analysis::perfmodel::PrecisionMode;
use fp8_tco::coordinator::cluster::{
    max_sustainable_qps, sharded_sim_cluster, SloSpec, SweepConfig,
};
use fp8_tco::hwsim::spec::Device;
use fp8_tco::tco::{assumed_server_price_usd, InfraModel, RackConfig};
use fp8_tco::util::json::Json;
use fp8_tco::util::par::SweepGrid;
use fp8_tco::util::table::{f, Table};
use fp8_tco::workload::llama::by_name;
use fp8_tco::workload::trace::TraceConfig;

fn main() {
    let fast = std::env::var("SWEEP_FAST").ok().as_deref() == Some("1");
    let slo = SloSpec::interactive();
    let sweep = if fast {
        SweepConfig { iters: 2, n_requests: 30, seed: 17, ..SweepConfig::new(0.25, 8.0) }
    } else {
        SweepConfig { iters: 4, n_requests: 120, seed: 17, ..SweepConfig::new(0.25, 32.0) }
    };
    let infra = InfraModel::new(RackConfig::a100_era());

    // The frontier: single-chip 8B baselines (paper shape) against the
    // sharded 70B deployments the interconnect model makes priceable.
    let cells: [(&str, Device, PrecisionMode, ParallelismPlan); 8] = [
        ("llama-8b", Device::H100, PrecisionMode::Bf16, ParallelismPlan::single()),
        ("llama-8b", Device::H100, PrecisionMode::fp8_dynamic(), ParallelismPlan::single()),
        ("llama-8b", Device::Gaudi2, PrecisionMode::fp8_static(), ParallelismPlan::single()),
        ("llama-70b", Device::H100, PrecisionMode::fp8_dynamic(), ParallelismPlan::tp(2)),
        ("llama-70b", Device::H100, PrecisionMode::fp8_dynamic(), ParallelismPlan::tp(4)),
        ("llama-70b", Device::H100, PrecisionMode::fp8_dynamic(), ParallelismPlan::tp(8)),
        ("llama-70b", Device::Gaudi2, PrecisionMode::fp8_static(), ParallelismPlan::single()),
        ("llama-70b", Device::Gaudi2, PrecisionMode::fp8_static(), ParallelismPlan::tp(8)),
    ];

    let mut t = Table::new(
        "Fig. TCO-MULTICHIP — $/Mtok at SLO across (device x precision x plan)",
        &[
            "model",
            "device",
            "precision",
            "plan",
            "QPS @SLO",
            "tok/s inst",
            "TPOT p95 ms",
            "W/chip",
            "$/Mtok @SLO",
        ],
    );
    let mut records: Vec<Json> = Vec::new();
    // Each cell is an independent SLO search on a fresh cluster with a
    // fixed seed: evaluate the grid concurrently (PAR=0 for serial)
    // and render in grid order, so table and JSON bytes are identical
    // to the serial run.
    let results: Vec<Option<(f64, f64, f64, f64, f64, f64)>> = SweepGrid::new(cells.to_vec())
        .run(|_, (model, dev, prec, plan)| {
            let m = by_name(model).unwrap();
            let out = max_sustainable_qps(
                &|| {
                    sharded_sim_cluster(m, dev, prec, plan)
                        .unwrap_or_else(|e| panic!("bench cell must be feasible: {e}"))
                },
                &TraceConfig::chat,
                &slo,
                &sweep,
            );
            out.best.map(|p| {
                let cost = infra.cost_per_mtok_sharded(
                    assumed_server_price_usd(dev),
                    plan.total_chips(),
                    p.watts_mean,
                    p.tokens_per_sec,
                );
                (p.qps, p.tokens_per_sec, p.ttft_p95, p.tpot_p95, p.watts_mean, cost)
            })
        });
    for ((model, dev, prec, plan), best) in cells.into_iter().zip(results) {
        let mut rec = BTreeMap::new();
        rec.insert("model".into(), Json::Str(model.into()));
        rec.insert("device".into(), Json::Str(dev.name().into()));
        rec.insert("precision".into(), Json::Str(prec.name().into()));
        rec.insert("plan".into(), Json::Str(plan.to_string()));
        rec.insert("chips".into(), Json::Num(plan.chips_per_instance() as f64));
        match best {
            Some((qps, tokens_per_sec, ttft_p95, tpot_p95, watts_mean, cost)) => {
                t.row(vec![
                    model.into(),
                    dev.name().into(),
                    prec.name().into(),
                    plan.to_string(),
                    f(qps, 2),
                    f(tokens_per_sec, 0),
                    f(tpot_p95 * 1e3, 2),
                    f(watts_mean, 0),
                    f(cost, 3),
                ]);
                rec.insert("qps".into(), Json::Num(qps));
                rec.insert("tokens_per_sec".into(), Json::Num(tokens_per_sec));
                rec.insert("ttft_p95_s".into(), Json::Num(ttft_p95));
                rec.insert("tpot_p95_s".into(), Json::Num(tpot_p95));
                rec.insert("watts_per_chip".into(), Json::Num(watts_mean));
                rec.insert("usd_per_mtok".into(), Json::Num(cost));
                rec.insert("feasible".into(), Json::Bool(true));
            }
            None => {
                t.row(vec![
                    model.into(),
                    dev.name().into(),
                    prec.name().into(),
                    plan.to_string(),
                    format!("< {}", sweep.qps_lo),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
                rec.insert("feasible".into(), Json::Bool(false));
            }
        }
        records.push(Json::Obj(rec));
    }
    t.print();

    let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
    let _ = std::fs::create_dir_all(&dir);
    let path = format!("{dir}/BENCH_fig_tco_multichip.json");
    let mut root = BTreeMap::new();
    root.insert("bench".into(), Json::Str("fig_tco_multichip".into()));
    root.insert("slo_ttft_p95_s".into(), Json::Num(slo.ttft_p95_s));
    root.insert("slo_tpot_p95_s".into(), Json::Num(slo.tpot_p95_s));
    root.insert("fast".into(), Json::Bool(fast));
    root.insert("cells".into(), Json::Arr(records));
    match std::fs::write(&path, format!("{}\n", Json::Obj(root))) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
    println!(
        "(sharded 70B rows extend the paper's Fig. 9 axis: the fabric each\n \
         vendor ships — NVLink vs on-die RoCE — is now part of the TCO verdict)"
    );
}
