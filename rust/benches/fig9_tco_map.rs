//! FIG9: the TCO map with measured scenario trajectories (paper §6).
//!
//! Derives R_Th(Gaudi2/H100) from the hwsim decode model under FP8 and
//! BF16 and at short vs long sequences, then shows where each scenario
//! lands on the Fig. 1 grid — the "FP8 shifts the balance toward the
//! green region; long sequences shift it back" narrative.

use fp8_tco::analysis::perfmodel::{decode_step, PrecisionMode, StepConfig};
use fp8_tco::hwsim::spec::Device;
use fp8_tco::tco::{tco_ratio, Scenario, TcoInputs};
use fp8_tco::util::table::{f, Table};
use fp8_tco::workload::llama;

fn r_th(prec_g: PrecisionMode, prec_h: PrecisionMode, s: usize) -> f64 {
    let m = llama::by_name("llama-8b").unwrap();
    let g = decode_step(m, &StepConfig::new(Device::Gaudi2, prec_g), 64, s);
    let h = decode_step(m, &StepConfig::new(Device::H100, prec_h), 64, s);
    h.seconds / g.seconds
}

fn main() {
    // The background map (coarse, the Fig. 9 axes).
    let mut map = Table::new(
        "Fig. 9 — TCO_A/TCO_B map (A=Gaudi2, B=H100; C_S=C_I, R_IC=1)",
        &["R_Th \\ R_SC", "1.0", "0.8", "0.6", "0.4", "0.2"],
    );
    for r_th_row in [1.6, 1.4, 1.2, 1.0, 0.8, 0.6] {
        let mut row = vec![format!("{r_th_row:.1}")];
        for r_sc in [1.0, 0.8, 0.6, 0.4, 0.2] {
            row.push(f(tco_ratio(TcoInputs::fig1(r_sc, r_th_row)), 2));
        }
        map.row(row);
    }
    map.print();

    // Scenario trajectory: BF16 -> FP8 (up), short -> long seq (down).
    let scenarios = [
        Scenario { name: "BF16 decode, s=1k".into(),
                   r_th: r_th(PrecisionMode::Bf16, PrecisionMode::Bf16, 1024), r_sc: 0.6 },
        Scenario { name: "FP8 decode, s=1k".into(),
                   r_th: r_th(PrecisionMode::fp8_static(), PrecisionMode::fp8_dynamic(), 1024), r_sc: 0.6 },
        Scenario { name: "FP8 decode, s=256".into(),
                   r_th: r_th(PrecisionMode::fp8_static(), PrecisionMode::fp8_dynamic(), 256), r_sc: 0.6 },
        Scenario { name: "FP8 decode, s=16k".into(),
                   r_th: r_th(PrecisionMode::fp8_static(), PrecisionMode::fp8_dynamic(), 16384), r_sc: 0.6 },
    ];
    let mut t = Table::new(
        "scenario trajectory at R_SC = 0.6",
        &["scenario", "R_Th (G2/H100)", "TCO ratio", "region"],
    );
    for s in &scenarios {
        let ratio = s.tco_ratio();
        t.row(vec![
            s.name.clone(),
            f(s.r_th, 2),
            f(ratio, 2),
            if ratio < 1.0 { "green (Gaudi2 cheaper)".into() }
            else { "red (H100 cheaper)".into() },
        ]);
    }
    t.print();

    // §6's two claims:
    let bf16 = scenarios[0].r_th;
    let fp8 = scenarios[1].r_th;
    assert!(fp8 > bf16, "FP8 shifts R_Th toward Gaudi: {bf16} -> {fp8}");
    let short = scenarios[2].r_th;
    let long = scenarios[3].r_th;
    assert!(long < short, "long sequences shift it back: {short} -> {long}");
    println!("FIG9: REPRODUCED (FP8 raises R_Th {bf16:.2}->{fp8:.2}; \
              16k seq lowers it {short:.2}->{long:.2})");
}
