//! T2: Gaudi 2 scaled FP8 GEMM — per-row vs per-tensor vs HW-accel,
//! E4M3 vs E5M2 (the simulator times formats identically; the paper
//! measures them near-identical — the format difference is an
//! *accuracy* story, Table 5).

use fp8_tco::hwsim::gemm::{gemm_time, GemmConfig};
use fp8_tco::hwsim::spec::{Accum, Device, Scaling};
use fp8_tco::util::table::{f, pct, Table};

// Paper Table 2 E4M3 rows: (size, per-row, per-tensor, hw-accel).
const PAPER: [(usize, f64, f64, f64); 4] = [
    (1024, 494.0, 494.0, 494.0),
    (2048, 506.0, 641.0, 641.0),
    (4096, 735.0, 796.0, 801.0),
    (8192, 742.0, 822.0, 852.0),
];

fn main() {
    let mut t = Table::new(
        "Table 2 — Gaudi 2 scaled FP8 GEMM (TFLOPS, peak 865)",
        &["size", "per-row", "paper", "per-tensor", "paper", "hw-accel", "paper"],
    );
    for &(s, p_row, p_tensor, p_hw) in &PAPER {
        let row = gemm_time(Device::Gaudi2, s, s, s,
                            GemmConfig::fp8(Scaling::PerRow, Accum::Fp32));
        let tensor = gemm_time(Device::Gaudi2, s, s, s,
                               GemmConfig::fp8(Scaling::PerTensor, Accum::Fp32));
        let hw = gemm_time(Device::Gaudi2, s, s, s,
                           GemmConfig::fp8(Scaling::HwPow2, Accum::Fp32));
        t.row(vec![
            format!("{}K", s / 1024),
            format!("{} {}", f(row.tflops(), 0), pct(row.mfu)),
            f(p_row, 0),
            format!("{} {}", f(tensor.tflops(), 0), pct(tensor.mfu)),
            f(p_tensor, 0),
            format!("{} {}", f(hw.tflops(), 0), pct(hw.mfu)),
            f(p_hw, 0),
        ]);
        // Orderings the paper's table exhibits.
        assert!(row.tflops() <= tensor.tflops() + 1e-9, "{s}: row <= tensor");
        assert!(tensor.tflops() <= hw.tflops() + 1e-9, "{s}: tensor <= hw");
    }
    // Asymptote: >= 90% MFU at 8K per-tensor (paper 95.0%).
    let bd = gemm_time(Device::Gaudi2, 8192, 8192, 8192,
                       GemmConfig::fp8(Scaling::PerTensor, Accum::Fp32));
    assert!(bd.mfu > 0.85, "8K per-tensor MFU {}", bd.mfu);
    t.print();
    println!("T2: REPRODUCED (shape; orderings asserted)");
}
