//! FIG4: prefill roofline, batch 1, static FP8 scaling, three models
//! x sequence lengths.
//!
//! Paper claims: H100 consistently ahead (up to ~2x on 8B); throughput
//! improves with model size and with sequence length until attention's
//! O(s²) share bends it back down.

use fp8_tco::analysis::perfmodel::{prefill, PrecisionMode, StepConfig};
use fp8_tco::util::table::{f, Table};
use fp8_tco::hwsim::spec::Device;
use fp8_tco::workload::llama;

fn main() {
    let seqs = [256usize, 1024, 4096, 8192, 16384];
    let mut t = Table::new(
        "Fig. 4 — prefill TFLOPS, batch 1, static FP8",
        &["model", "s", "Gaudi2", "H100", "H100/Gaudi2"],
    );
    let mut ratios_8b = Vec::new();
    for name in ["llama-1b", "llama-8b", "llama-70b"] {
        let m = llama::by_name(name).unwrap();
        for &s in &seqs {
            let g = prefill(m, &StepConfig::new(Device::Gaudi2, PrecisionMode::fp8_static()), 1, s);
            let h = prefill(m, &StepConfig::new(Device::H100, PrecisionMode::fp8_static()), 1, s);
            let ratio = h.tflops() / g.tflops();
            if name == "llama-8b" {
                ratios_8b.push(ratio);
            }
            t.row(vec![
                name.into(),
                s.to_string(),
                f(g.tflops(), 1),
                f(h.tflops(), 1),
                f(ratio, 2),
            ]);
            // The paper's Fig. 4 claim ("consistently higher") holds in
            // the compute-bound regime; for the 1B model the hidden
            // size (2048) keeps per-layer GEMMs in the range where the
            // paper's own Tables 1-3 show Gaudi 2 at far higher MFU, so
            // the model legitimately puts the two close there.
            if m.hidden >= 3072 && s >= 1024 {
                assert!(h.tflops() > g.tflops(),
                        "H100 leads prefill at {name} s={s}");
            }
        }
    }
    t.print();

    // Larger models -> higher prefill TFLOPS (at fixed s).
    let s = 4096;
    let t1b = prefill(llama::by_name("llama-1b").unwrap(),
                      &StepConfig::new(Device::H100, PrecisionMode::fp8_static()), 1, s);
    let t70 = prefill(llama::by_name("llama-70b").unwrap(),
                      &StepConfig::new(Device::H100, PrecisionMode::fp8_static()), 1, s);
    assert!(t70.tflops() > t1b.tflops(), "bigger model, higher prefill TFLOPS");

    // Long-sequence bend: throughput at 16K below the peak across seqs
    // (attention share grows).
    let m8 = llama::by_name("llama-8b").unwrap();
    let tf: Vec<f64> = seqs
        .iter()
        .map(|&s| prefill(m8, &StepConfig::new(Device::H100, PrecisionMode::fp8_static()), 1, s).tflops())
        .collect();
    let peak = tf.iter().cloned().fold(0.0, f64::max);
    assert!(*tf.last().unwrap() <= peak, "throughput bends down at long s");

    let max_ratio = ratios_8b.iter().cloned().fold(0.0, f64::max);
    println!("H100/Gaudi2 on 8B: up to {max_ratio:.2}x (paper: 'up to double')");
    println!("FIG4: REPRODUCED (shape)");
}
