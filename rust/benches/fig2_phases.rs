//! FIG2: prefill vs decode phase characterization (the paper's Fig. 2
//! "process and utilization characterization" rendered as numbers):
//! computational intensity, MFU, binding resource and utilization for
//! each phase on each device.

use fp8_tco::analysis::perfmodel::{decode_step, prefill, PrecisionMode, StepConfig};
use fp8_tco::analysis::roofline::saturation_ci;
use fp8_tco::hwsim::spec::{DType, Device};
use fp8_tco::util::table::{f, Table};
use fp8_tco::workload::llama;

fn main() {
    let m = llama::by_name("llama-8b").unwrap();
    let mut t = Table::new(
        "Fig. 2 — phase characterization (llama-8b, FP8)",
        &["phase", "device", "shape", "CI (F/B)", "MFU", "achieved TFLOPS",
          "dominant cost"],
    );
    for dev in [Device::Gaudi2, Device::H100] {
        let cfg = StepConfig::new(dev, PrecisionMode::fp8_static());
        let peak = dev.spec().peak_fp8;

        let pre = prefill(m, &cfg, 1, 4096);
        let pre_ci = pre.flops
            / (m.weight_bytes(1.0) + 4096.0 * m.kv_bytes_per_token(2.0));
        t.row(vec![
            "prefill".into(),
            dev.name().into(),
            "b=1 s=4096".into(),
            f(pre_ci, 0),
            f(pre.achieved_flops / peak, 3),
            f(pre.tflops(), 1),
            "matrix compute (GEMM-bound)".into(),
        ]);

        let dec = decode_step(m, &cfg, 64, 1024);
        let dec_ci = m.decode_ci(64, 1024, 1.0, 2.0);
        let dominant = if dec.t_linears_s > dec.t_attention_kv_s {
            "weight streaming (thin GEMM)"
        } else {
            "KV-cache bandwidth"
        };
        t.row(vec![
            "decode".into(),
            dev.name().into(),
            "b=64 s=1024".into(),
            f(dec_ci, 0),
            f(dec.achieved_flops / peak, 3),
            f(dec.tflops(), 1),
            dominant.into(),
        ]);
    }
    t.print();

    // Fig. 2's claims: prefill compute-bound (high MFU), decode
    // memory-bound (low MFU), CI gap of orders of magnitude.
    for dev in [Device::Gaudi2, Device::H100] {
        let cfg = StepConfig::new(dev, PrecisionMode::fp8_static());
        let pre = prefill(m, &cfg, 1, 4096);
        let dec = decode_step(m, &cfg, 64, 1024);
        let pre_mfu = pre.achieved_flops / dev.spec().peak_fp8;
        let dec_mfu = dec.achieved_flops / dev.spec().peak_fp8;
        assert!(pre_mfu > 2.0 * dec_mfu,
                "{}: prefill MFU {pre_mfu} vs decode {dec_mfu}", dev.name());
    }
    println!(
        "saturation CI: Gaudi2 FP8 {:.0} F/B, H100 FP8 {:.0} F/B — decode CI \
         sits far below both (§5.2)",
        saturation_ci(Device::Gaudi2.spec(), DType::Fp8),
        saturation_ci(Device::H100.spec(), DType::Fp8)
    );
    println!("FIG2: REPRODUCED (compute-bound prefill vs memory-bound decode)");
}
